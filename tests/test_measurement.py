"""Tests for the measurement stack itself: the loop-aware HLO collective
parser (the roofline's collective term depends on it) and the analytic
roofline/comm models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.base import SHAPES, VoteStrategy, get_config
from repro.core.majority_vote import comm_bytes_per_step
from repro.distributed import comm_model as CM
from repro.launch.hlo_stats import (CollectiveOp, parse_collectives,
                                    summarize)

HLO = """
HloModule test

%cond (arg: (s32[])) -> pred[] {
  %arg = (s32[]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (arg: (s32[])) -> (s32[]) {
  %arg = (s32[]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = bf16[16,128]{1,0} parameter(1)
  %ag = bf16[16,2048]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={1}
  %ar = f32[64]{0} all-reduce(%y), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  ROOT %t = (s32[]) tuple(%i)
}

ENTRY %main (p: bf16[16,128]) -> bf16[16,128] {
  %p = bf16[16,128]{1,0} parameter(0)
  %w = (s32[]) while(%init), condition=%cond, body=%body
  %rs = s8[1024]{0} reduce-scatter(%q), replica_groups=[16,16]<=[256], dimensions={0}
  ROOT %r = bf16[16,128]{1,0} copy(%p)
}
"""


def test_parser_finds_ops_and_multiplies_loop_trips():
    ops = parse_collectives(HLO, pod_stride=0)
    by_op = {}
    for o in ops:
        by_op.setdefault(o.op, []).append(o)
    # in-loop collectives carry the trip count 7
    assert by_op["all-gather"][0].trip_mult == 7
    assert by_op["all-reduce"][0].trip_mult == 7
    # entry-level reduce-scatter counted once
    assert by_op["reduce-scatter"][0].trip_mult == 1
    # sizes: all-gather result 16*2048*2 bytes, group 16
    ag = by_op["all-gather"][0]
    assert ag.bytes_result == 16 * 2048 * 2
    assert ag.group_size == 16
    # ring transit: size*(M-1)/M * trips
    expect = 16 * 2048 * 2 * 15 / 16 * 7
    assert abs(ag.transit_bytes - expect) < 1


def test_parser_group_formats_and_pod_crossing():
    ops = parse_collectives(HLO, pod_stride=256)
    # iota groups of 16 with stride <= pod_stride: no pod crossing
    assert all(not o.crosses_pod for o in ops)
    ops2 = parse_collectives(HLO, pod_stride=2)
    # explicit groups {0,1,2,3} span ids//2 in {0,1} -> crosses
    ar = [o for o in ops2 if o.op == "all-reduce"][0]
    assert ar.crosses_pod


def test_parser_loop_counting_vs_cost_analysis():
    """Documents WHY the parser exists: cost_analysis counts a scan body
    once; the parser multiplies by the trip count."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=8)[0]

    x = jnp.zeros((64, 64))
    comp = jax.jit(f).lower(x, x).compile()
    flops = compat.cost_analysis_dict(comp).get("flops", 0.0)
    assert flops < 8 * 2 * 64 ** 3 / 2  # counted (far) less than 8 bodies


def test_summarize_splits_ici_dci():
    ops = [
        CollectiveOp("all-reduce", 100, 4, False, 1000.0),
        CollectiveOp("all-gather", 100, 2, True, 500.0),
    ]
    s = summarize(ops)
    assert s["transit_bytes_ici"] == 1000.0
    assert s["transit_bytes_dci"] == 500.0


def test_comm_model_vote_cheaper_than_dense():
    for strat in VoteStrategy:
        # allgather_1bit EQUALS dense bf16 exactly at M=32 (break-even)
        c = comm_bytes_per_step(10_000_000, strat, data_size=16, pod_size=2)
        assert c["vote"] <= c["dense_allreduce"]
        c1 = comm_bytes_per_step(10_000_000, strat, data_size=16, pod_size=1)
        assert c1["vote"] < c1["dense_allreduce"]
    # hierarchical beats flat int8
    flat = comm_bytes_per_step(1 << 20, VoteStrategy.PSUM_INT8, 16)
    hier = comm_bytes_per_step(1 << 20, VoteStrategy.HIERARCHICAL, 16)
    assert hier["vote"] < flat["vote"]


def test_roofline_terms_positive_for_all_shapes():
    from benchmarks.roofline import (analytic_infer_flops,
                                     analytic_train_flops)
    for arch in ["glm4-9b", "qwen3-moe-235b-a22b", "mamba2-2.7b"]:
        cfg = get_config(arch)
        assert analytic_train_flops(cfg, 256, 4096) > 0
        assert analytic_infer_flops(cfg, 32, 32768, "prefill") > 0
        assert analytic_infer_flops(cfg, 128, 32768, "decode") > 0
    # train flops scale ~6x active params * tokens (plus attention)
    cfg = get_config("glm4-9b")
    f = analytic_train_flops(cfg, 256, 4096, remat=False)
    assert f >= 3 * 2 * cfg.param_count() * 256 * 4096


def test_step_time_estimate_monotone_in_comm():
    a = CM.step_time_estimate(1e12, 1e9, CM.collective_time(1e9))
    b = CM.step_time_estimate(1e12, 1e9, CM.collective_time(1e12))
    assert b > a
