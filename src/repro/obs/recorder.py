"""The telemetry layer's three primitives (DESIGN.md §13).

* **Counters** — one process-global :class:`CounterRegistry` of exact
  integers (wire bytes, messages, kernel launches, voter chunks,
  recompiles). Always on: incrementing an int in a dict is cheaper than
  any gate, and the launch/chunk accounting that `bench_vote_plan` and
  `bench_federated` assert against must exist with telemetry off.
  `kernels.ops.LAUNCHES` and `population.LAST_STATS` are deprecation
  shims reading this registry.
* **Spans** — host-side ``perf_counter`` timing with nesting, emitted by
  a :class:`TraceRecorder`. The default recorder is a :class:`Recorder`
  no-op whose ``span()`` returns one module-level singleton (no
  allocation, no branches in the traced program). Spans NEVER insert
  ops into a jitted graph; a span around code under ``jax.jit``
  measures *trace/dispatch* time, which is exactly the host-side cost
  the schedule walk pays per bucket — the rows say so via the
  ``host_side`` meta field.
* **Step records** — one structured row per training/scenario step
  unifying the ``WireReport`` and ``StepTrace`` fields (resolved
  strategy, payload bytes, compression vs f32, margin, flip-vs-oracle,
  per-phase seconds), written to the same JSONL sink.

Every JSONL row carries ``{"v": SCHEMA_VERSION, "kind": ...}``;
:func:`read_trace` validates the version so downstream tooling
(`scripts/trace_report.py`) fails loudly on schema drift instead of
misreading rows.

Counter semantics inside ``jit`` mirror the long-standing
``kernels.ops.LAUNCHES`` contract: an increment that runs at trace time
fires once per compilation, so the count taken at trace time equals
launches per execution. Call sites that need per-step increments (the
ScenarioRunner loop, `VoteBackend.execute` outside jit) run eagerly.
"""
from __future__ import annotations

import contextlib
import json
import time
import warnings
from typing import Any, Dict, IO, Iterator, List, Optional

#: bump on any breaking change to the JSONL row shapes below
SCHEMA_VERSION = 1

#: the row kinds a schema-1 trace may contain
ROW_KINDS = ("meta", "span", "event", "step", "counters")


# ---------------------------------------------------------------------------
# counters
# ---------------------------------------------------------------------------


class CounterRegistry:
    """Exact-integer counters under dotted names (``vote.wire.bytes``,
    ``kernel.launches.fused_majority``, ...). Three write verbs:
    monotonic :meth:`inc`, last-value :meth:`set` (gauges like the
    streamed engine's most-recent-run accounting), and high-water
    :meth:`record_max`. All values are plain Python ints — arbitrary
    precision, no float drift, cheap enough to leave always-on."""

    __slots__ = ("_c",)

    def __init__(self) -> None:
        self._c: Dict[str, int] = {}

    def inc(self, name: str, delta: int = 1) -> None:
        self._c[name] = self._c.get(name, 0) + int(delta)

    def set(self, name: str, value: int) -> None:
        self._c[name] = int(value)

    def record_max(self, name: str, value: int) -> None:
        v = int(value)
        if v > self._c.get(name, 0):
            self._c[name] = v

    def get(self, name: str, default: int = 0) -> int:
        return self._c.get(name, default)

    def snapshot(self, prefix: str = "") -> Dict[str, int]:
        """A detached copy (optionally of one dotted namespace)."""
        if not prefix:
            return dict(self._c)
        return {k: v for k, v in self._c.items() if k.startswith(prefix)}

    def delta_since(self, before: Dict[str, int],
                    prefix: str = "") -> Dict[str, int]:
        """Nonzero changes vs an earlier :meth:`snapshot`."""
        out = {}
        for k, v in self.snapshot(prefix).items():
            d = v - before.get(k, 0)
            if d:
                out[k] = d
        return out

    def reset(self, prefix: str = "") -> None:
        if not prefix:
            self._c.clear()
            return
        for k in [k for k in self._c if k.startswith(prefix)]:
            del self._c[k]


#: THE process-global registry (always on; see module docstring)
COUNTERS = CounterRegistry()


# ---------------------------------------------------------------------------
# spans / recorders
# ---------------------------------------------------------------------------


class _NoopSpan:
    """The disabled span: one module-level singleton, allocation-free on
    the hot path (``rec.span("name")`` with no attrs allocates nothing —
    asserted by tests/test_obs.py)."""

    __slots__ = ()
    dur_s = 0.0

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass


_NOOP_SPAN = _NoopSpan()


class Recorder:
    """The default no-op recorder. ``enabled`` is False, ``span()``
    returns the singleton no-op context manager, ``step``/``event`` do
    nothing. Hot paths gate attr computation on ``rec.enabled`` so the
    disabled cost is one attribute read."""

    enabled: bool = False

    def span(self, name: str, **attrs) -> Any:
        return _NOOP_SPAN

    def event(self, name: str, **attrs) -> None:
        pass

    def step(self, **fields) -> None:
        pass

    def close(self) -> None:
        pass


class _Span:
    """A live span: ``perf_counter`` on enter/exit, row written on exit
    with nesting depth + parent seq from the recorder's span stack."""

    __slots__ = ("_rec", "name", "attrs", "seq", "depth", "parent",
                 "_t0", "dur_s")

    def __init__(self, rec: "TraceRecorder", name: str,
                 attrs: Dict[str, Any]):
        self._rec = rec
        self.name = name
        self.attrs = attrs
        self.seq = -1
        self.depth = 0
        self.parent = -1
        self._t0 = 0.0
        self.dur_s = 0.0

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "_Span":
        rec = self._rec
        self.seq = rec._next_seq()
        self.depth = len(rec._stack)
        self.parent = rec._stack[-1].seq if rec._stack else -1
        rec._stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        self.dur_s = t1 - self._t0
        rec = self._rec
        if rec._stack and rec._stack[-1] is self:
            rec._stack.pop()
        else:                       # mis-nested exit: recover, don't lie
            rec._stack = [s for s in rec._stack if s is not self]
        row = {"v": SCHEMA_VERSION, "kind": "span", "seq": self.seq,
               "parent": self.parent, "depth": self.depth,
               "name": self.name, "t0_s": self._t0 - rec._origin,
               "dur_s": self.dur_s}
        if self.attrs:
            row["attrs"] = self.attrs
        rec._write(row)
        return False


class TraceRecorder(Recorder):
    """JSONL sink: a ``meta`` header row, then ``span``/``event``/
    ``step`` rows as they happen, then a final ``counters`` snapshot on
    :meth:`close`. All timing is host-side ``perf_counter`` relative to
    the recorder's origin; nothing here touches a traced value, so the
    golden digest is bit-identical with tracing on (regression-tested).
    """

    enabled = True

    def __init__(self, path_or_file, meta: Optional[Dict[str, Any]] = None):
        if hasattr(path_or_file, "write"):
            self._f: IO[str] = path_or_file
            self._own = False
            self.path = getattr(path_or_file, "name", "<stream>")
        else:
            self._f = open(path_or_file, "w")
            self._own = True
            self.path = str(path_or_file)
        self._stack: List[_Span] = []
        self._seq = 0
        self._closed = False
        self._origin = time.perf_counter()
        head = {"v": SCHEMA_VERSION, "kind": "meta",
                "schema": SCHEMA_VERSION, "unix_time": time.time(),
                "host_side": True}
        if meta:
            head.update(meta)
        self._write(head)

    # -- plumbing --

    def _next_seq(self) -> int:
        s = self._seq
        self._seq += 1
        return s

    def _write(self, row: Dict[str, Any]) -> None:
        if self._closed:
            return
        self._f.write(json.dumps(row, default=_jsonable) + "\n")

    # -- the three primitives --

    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def event(self, name: str, **attrs) -> None:
        row = {"v": SCHEMA_VERSION, "kind": "event", "seq": self._next_seq(),
               "name": name,
               "t0_s": time.perf_counter() - self._origin}
        if attrs:
            row["attrs"] = attrs
        self._write(row)

    def step(self, **fields) -> None:
        self._write({"v": SCHEMA_VERSION, "kind": "step",
                     "seq": self._next_seq(), **fields})

    def counters(self, registry: CounterRegistry = None) -> None:
        reg = registry if registry is not None else COUNTERS
        self._write({"v": SCHEMA_VERSION, "kind": "counters",
                     "values": reg.snapshot()})

    def close(self) -> None:
        if self._closed:
            return
        self.counters()
        self._closed = True
        if self._own:
            self._f.close()
        else:
            self._f.flush()


def _jsonable(x):
    """Last-resort JSON coercion for attr values (enums, 0-d arrays)."""
    for attr in ("value", "item"):
        v = getattr(x, attr, None)
        if v is not None:
            try:
                return v() if callable(v) else v
            except Exception:
                pass
    return str(x)


def read_trace(path: str) -> List[Dict[str, Any]]:
    """Parse a JSONL trace, validating the schema version of every row
    (fails loudly on drift — the versioned-schema contract)."""
    rows = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            if row.get("v") != SCHEMA_VERSION:
                raise ValueError(
                    f"{path}:{lineno}: trace row schema v={row.get('v')!r}"
                    f", this reader understands v={SCHEMA_VERSION}")
            if row.get("kind") not in ROW_KINDS:
                raise ValueError(
                    f"{path}:{lineno}: unknown row kind "
                    f"{row.get('kind')!r}; have {ROW_KINDS}")
            rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# the active recorder (module global + context-manager scoping)
# ---------------------------------------------------------------------------

_NOOP = Recorder()
_ACTIVE: Recorder = _NOOP


def get_recorder() -> Recorder:
    """The active recorder (the no-op singleton unless one was set)."""
    return _ACTIVE


def set_recorder(rec: Optional[Recorder]) -> Recorder:
    """Install `rec` as the active recorder (None -> the no-op);
    returns the previous one so callers can restore it."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = rec if rec is not None else _NOOP
    return prev


@contextlib.contextmanager
def recording(rec: Recorder) -> Iterator[Recorder]:
    """Scope `rec` as the active recorder; restores the previous one on
    exit (the recorder is NOT closed — callers own its lifetime)."""
    prev = set_recorder(rec)
    try:
        yield rec
    finally:
        set_recorder(prev)


# ---------------------------------------------------------------------------
# compile watch (jit recompile accounting)
# ---------------------------------------------------------------------------

_COMPILE_WATCH_ON = False


def install_compile_watch() -> bool:
    """Count jit compilations into ``jit.compiles`` (+ exact nanoseconds
    into ``jit.compile_ns``) and emit a ``jit.compile`` event on the
    active recorder, via ``jax.monitoring``'s duration listeners.
    Idempotent; returns False (and stays inert) if the installed jax
    has no monitoring hooks — telemetry must degrade, not crash."""
    global _COMPILE_WATCH_ON
    if _COMPILE_WATCH_ON:
        return True
    try:
        from jax import monitoring

        def _on_duration(event: str, duration: float, **kw) -> None:
            if "compile" not in event:
                return
            COUNTERS.inc("jit.compiles")
            COUNTERS.inc("jit.compile_ns", int(duration * 1e9))
            rec = get_recorder()
            if rec.enabled:
                rec.event("jit.compile", event=event, dur_s=duration)

        monitoring.register_event_duration_secs_listener(_on_duration)
    except Exception:
        return False
    _COMPILE_WATCH_ON = True
    return True


# ---------------------------------------------------------------------------
# shared helpers for the bench scripts
# ---------------------------------------------------------------------------


def emit_bench_json(rows, path: str) -> None:
    """THE bench JSON writer: ``{"rows": [{"name", "value", "derived"}]}``
    — the schema ``scripts/perf_gate.py`` gates. Accepts the benches'
    ``(name, value, derived)`` tuples or already-shaped dicts; every
    bench and the ``benchmarks.run`` driver route here (one writer, one
    schema, no copy-paste drift)."""
    out = []
    for r in rows:
        if isinstance(r, dict):
            out.append({"name": r["name"], "value": r["value"],
                        "derived": r.get("derived", "")})
        else:
            name, value, derived = r
            out.append({"name": name, "value": value, "derived": derived})
    with open(path, "w") as f:
        json.dump({"rows": out}, f, indent=1)


def add_trace_arg(ap) -> None:
    """Attach the shared ``--trace FILE`` option to a bench argparser."""
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="write an obs JSONL trace of this run "
                         "(render with scripts/trace_report.py)")


def activate_trace(args) -> Optional[TraceRecorder]:
    """Honour a parsed ``--trace`` flag: install a TraceRecorder as the
    active recorder (+ the compile watch) and return it, or None. The
    caller owns closing it (``finish_trace``)."""
    path = getattr(args, "trace", None)
    if not path:
        return None
    rec = TraceRecorder(path)
    set_recorder(rec)
    install_compile_watch()
    return rec


def finish_trace(rec: Optional[TraceRecorder]) -> None:
    """Close an ``activate_trace`` recorder (writes the final counters
    snapshot) and restore the no-op."""
    if rec is None:
        return
    set_recorder(None)
    rec.close()
    print(f"# wrote trace {rec.path}", flush=True)


# ---------------------------------------------------------------------------
# deprecation plumbing for the absorbed accounting surfaces
# ---------------------------------------------------------------------------

_WARNED: set = set()


def warn_deprecated(name: str, hint: str) -> None:
    """One DeprecationWarning per absorbed surface per process (the
    `vote_api.warn_legacy` pattern; obs cannot import vote_api)."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(f"{name} is deprecated: {hint} (DESIGN.md §13)",
                  DeprecationWarning, stacklevel=3)
