"""``sign1bit`` — the paper's codec, factored behind the API.

Encode is the identity (the engine takes ternary signs of whatever it is
handed), decode is the strategy's own unweighted majority; no state on
either side. Every wire strategy transports it, at the strategy's native
width. This codec is the refactor's fixed point: routing through it MUST
be bit-identical to the pre-codec path — the tier-2 golden digest and
``tests/test_codecs.py`` assert exactly that.
"""
from __future__ import annotations

from repro.configs.base import VoteStrategy
from repro.core.codecs.base import GradientCodec


class Sign1BitCodec(GradientCodec):
    name = "sign1bit"
    bits_per_param = 1.0
    supported_strategies = (VoteStrategy.PSUM_INT8,
                            VoteStrategy.ALLGATHER_1BIT,
                            VoteStrategy.HIERARCHICAL)
