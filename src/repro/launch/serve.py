"""Batched serving driver: prefill a batch of prompts, then decode tokens.

CPU-scale entry point (the same decode/prefill steps lower on the
production mesh in the dry-run):

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \\
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models import model as M
from repro.train.serve_step import make_decode_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    batch = M.make_batch(cfg, args.batch, args.prompt_len, key)

    max_len = args.prompt_len + args.gen
    # prefill token-by-token through the decode path for recurrent archs;
    # transformer archs use the batched prefill
    t0 = time.time()
    logits, cache = jax.jit(lambda p, b: M.prefill(cfg, p, b))(params, batch)
    # re-home the cache to max_len for decoding
    cache_full = M.init_cache(cfg, args.batch, max_len)
    if "k" in cache and cache["k"].shape[2] <= max_len:
        S = cache["k"].shape[2]
        for kk in cache:
            cache_full[kk] = jax.lax.dynamic_update_slice(
                cache_full[kk], cache[kk].astype(cache_full[kk].dtype),
                (0,) * 2 + (0,) * (cache_full[kk].ndim - 2))
    else:
        cache_full = cache
    prefill_s = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} in {prefill_s:.2f}s")

    decode = make_decode_step(cfg)
    tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tokens]
    t0 = time.time()
    for i in range(args.gen):
        pos = jnp.int32(args.prompt_len + i)
        logits_t, cache_full = decode(params, tokens, cache_full, pos)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tokens = jax.random.categorical(
                sub, logits_t / args.temperature, axis=-1
            ).astype(jnp.int32)[:, None]
        else:
            tokens = jnp.argmax(logits_t, axis=-1).astype(jnp.int32)[:, None]
        out.append(tokens)
    gen_s = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"decode: {args.gen} steps x batch {args.batch} in {gen_s:.2f}s "
          f"({args.gen * args.batch / max(gen_s, 1e-9):.1f} tok/s)")
    print("sampled token ids (first row):", np.asarray(toks)[0].tolist())


if __name__ == "__main__":
    main()
