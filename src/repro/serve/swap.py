"""Hot checkpoint swap: trainer-side emitter, server-side watcher.

The handoff rides the existing checkpoint layer unchanged — atomic
``step_<k>.tmp`` + ``os.rename`` saves and the ``LATEST`` pointer file
(checkpoint.py's POSIX-atomicity guarantee), so a watcher polling
mid-save never observes a torn checkpoint. The emitter writes a
params-only checkpoint (opt state stays trainer-private) stamped with a
monotonic ``param_version``; the watcher notices a moved ``LATEST``
pointer between decode ticks, restores through
``checkpoint.restore(like_params=...)`` — the same refit path elastic
training restores use, so a serve-side replica-count mismatch on any
per-worker leaf truncates/zero-pads by ``refit_tree_leading_axis``
rules instead of crashing — and hands the engine a
:class:`ParamUpdate` to install between steps.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint


@dataclasses.dataclass(frozen=True)
class ParamUpdate:
    """One swap-ready parameter tree (device arrays) + provenance."""

    params: Any
    version: int
    step: int
    path: str


def like_tree(params: Any) -> Any:
    """A ShapeDtypeStruct mirror of ``params`` — the ``like_params``
    the watcher restores against (verifies structure / refits leading
    axes without holding a second concrete copy)."""
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), a.dtype), params)


class CheckpointEmitter:
    """Trainer side: publish params for serving every few steps.

    Writes through :func:`checkpoint.save` with an empty opt tree, so
    the serve directory holds only what the server needs, and stamps
    ``param_version`` into the step meta (monotonic per emitter; the
    engine tags every step record with the version it decoded under).
    """

    def __init__(self, serve_dir: str):
        os.makedirs(serve_dir, exist_ok=True)
        self.serve_dir = serve_dir
        self._version = 0

    def emit(self, step: int, params: Any, *,
             version: Optional[int] = None,
             meta: Optional[Dict] = None) -> str:
        """Blocking atomic publish; returns the step directory."""
        v = self._version + 1 if version is None else int(version)
        params_h = jax.tree.map(np.asarray, params)
        path = checkpoint.save(
            self.serve_dir, step, params_h, {},
            meta={"param_version": v, **(meta or {})})
        self._version = v
        return path


class CheckpointWatcher:
    """Server side: poll the serve directory between decode ticks.

    :meth:`poll` is cheap when nothing changed (one pointer-file read);
    on a new checkpoint it restores the params, converts them to device
    arrays, and returns a :class:`ParamUpdate` for the engine to
    install. Each checkpoint is surfaced at most once.
    """

    def __init__(self, serve_dir: str, like_params: Any = None):
        self.serve_dir = serve_dir
        self.like_params = like_params
        self._seen: Optional[str] = None

    def poll(self) -> Optional[ParamUpdate]:
        path = checkpoint.latest_step_dir(self.serve_dir)
        if path is None or path == self._seen:
            return None
        params, _, _, meta = checkpoint.restore(
            self.serve_dir, like_params=self.like_params)
        self._seen = path
        return ParamUpdate(
            params=jax.tree.map(jnp.asarray, params),
            version=int(meta.get("param_version", meta.get("step", 0))),
            step=int(meta.get("step", -1)),
            path=path)
