"""The VoteEngine subsystem: one interface over every majority-vote wire
protocol (DESIGN.md §2).

The paper's parameter server is a four-stage pipeline

    pack  ->  exchange  ->  tally  ->  unpack

* **pack**     — turn a replica-local sign tensor into its wire format
                 (int counts, or 32-signs-per-uint32 packed words);
* **exchange** — the mesh collectives that move the wire format between
                 replicas (all-reduce / all-gather / reduce-scatter);
* **tally**    — compute the majority from what arrived (sign of counts,
                 or bit-sliced popcount over packed words);
* **unpack**   — decode the decision back to a ±1 sign tensor.

Each :class:`VoteStrategyImpl` realises those stages differently but is
interchangeable behind the declarative vote API (``core.vote_api``,
DESIGN.md §10): the trainer (`train/train_step.py`), the failure drills
and the benchmarks all build a ``VoteRequest`` and a backend walks these
stage methods — one wire implementation, one set of semantics, one
accounting model. :class:`VoteEngine` remains as the legacy object whose
vote methods are deprecation shims over that API.

Strategy selection: :func:`select_strategy` prices each strategy's wire
bytes through ``distributed.comm_model`` (alpha-beta ICI/DCI terms) for the
given mesh shape and parameter count; ``VoteStrategy.AUTO`` resolves to the
cheapest. The choice is compile-time (mesh shape and param count are
static), so AUTO costs nothing at runtime.

Tie conventions differ by wire format (DESIGN.md §5): integer-count
strategies use ternary signs (a tied or all-zero coordinate yields 0 —
abstention), while the 1-bit wire can only encode two states, so packed
strategies resolve ties to +1 exactly like ``kernels/ref.py``.

All vote entry points accept N-D tensors and pack along the LAST dim only:
flattening leaves would destroy their auto ('model') shardings and force
full all-gathers of every TP-sharded tensor (measured: 14.3 GB of int8
signs for qwen2-moe before this was changed).
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import sign_compress as sc
from repro.distributed import comm_model
from repro.obs import recorder as obs


# ---------------------------------------------------------------------------
# mesh helpers (shared by majority_vote and the strategies)
# ---------------------------------------------------------------------------


def vote_axes_in(mesh_axis_names: Sequence[str]) -> Tuple[str, ...]:
    """The mesh axes the vote runs over, outermost first."""
    return tuple(a for a in ("pod", "data") if a in mesh_axis_names)


def num_voters(axes: Sequence[str]) -> int:
    """Static replica count over the (manual) vote axes, inside a trace."""
    n = 1
    for a in axes:
        n *= compat.axis_size(a)
    return n


# The pack-width helpers live in vote_api (DESIGN.md §10) — one source
# of truth for every wire; re-exported here for the existing importers.
from repro.core.vote_api import count_bytes as _count_bytes  # noqa: E402
from repro.core.vote_api import count_dtype  # noqa: F401,E402
from repro.core.vote_api import pad_last as _pad_last  # noqa: E402


# ---------------------------------------------------------------------------
# strategy interface
# ---------------------------------------------------------------------------


class VoteStrategyImpl(abc.ABC):
    """One wire protocol for the majority vote.

    ``vote`` composes the four pipeline stages over the vote axes; the
    accounting methods price the exchange stage for the cost model and the
    benchmarks. Inputs to ``vote`` are replica-local int8 sign tensors
    (ternary ok); outputs are int8 majorities with this strategy's tie
    convention.
    """

    kind: VoteStrategy
    #: bits each replica puts on the wire per parameter, per exchange
    wire_bits_per_param: float
    #: tie convention of the decoded majority ("zero" or "plus_one")
    ties: str

    # ---- pipeline stages ----

    @abc.abstractmethod
    def pack(self, signs: jax.Array, n_voters: int) -> jax.Array:
        """Replica-local signs -> wire tensor."""

    @abc.abstractmethod
    def exchange(self, wire: jax.Array, axes: Sequence[str]) -> jax.Array:
        """Run the collectives; returns whatever tally needs."""

    @abc.abstractmethod
    def tally(self, arrived: jax.Array, n_voters: int) -> jax.Array:
        """Aggregate to the (still-encoded) majority decision."""

    @abc.abstractmethod
    def unpack(self, decision: jax.Array, n: int, dtype) -> jax.Array:
        """Decode the decision to (..., n) ±1/0 signs in `dtype`."""

    def vote(self, signs: jax.Array, axes: Sequence[str]) -> jax.Array:
        """signs int8 (..., n) -> int8 majority (..., n) over `axes`.

        With a recorder active, each stage is wrapped in a host-side
        span (``stage.pack`` .. ``stage.unpack``, DESIGN.md §13); under
        ``jit`` the spans measure trace time and insert NO ops, so the
        compiled program — and the golden digest — is bit-identical
        with tracing on."""
        m = num_voters(axes)
        n = signs.shape[-1]
        rec = obs.get_recorder()
        if not rec.enabled:
            wire = self.pack(signs, m)
            arrived = self.exchange(wire, axes)
            decision = self.tally(arrived, m)
            return self.unpack(decision, n, jnp.int8)
        kind = self.kind.value
        with rec.span("stage.pack", strategy=kind, n=n):
            wire = self.pack(signs, m)
        with rec.span("stage.exchange", strategy=kind, n=n):
            arrived = self.exchange(wire, axes)
        with rec.span("stage.tally", strategy=kind, n=n):
            decision = self.tally(arrived, m)
        with rec.span("stage.unpack", strategy=kind, n=n):
            return self.unpack(decision, n, jnp.int8)

    # ---- accounting (per-chip bytes; ring collective terms) ----

    def payload_bytes(self, n_params: int, n_voters: int = 2) -> float:
        """One replica's outbound wire payload (the paper's 'bits sent')."""
        return n_params * self.wire_bits_per_param / 8.0

    @abc.abstractmethod
    def ring_bytes(self, n_params: int, data_size: int,
                   pod_size: int = 1) -> Dict[str, float]:
        """Per-chip transit bytes of the exchange, split ICI/DCI, plus the
        collective count (for the latency term)."""

    def estimated_time(self, n_params: int, data_size: int,
                       pod_size: int = 1) -> float:
        b = self.ring_bytes(n_params, data_size, pod_size)
        return comm_model.collective_time(
            b["ici"], b["dci"], n_collectives=int(b["n_collectives"])).time_s


class PsumInt8Strategy(VoteStrategyImpl):
    """Integer-sum vote: one all-reduce of narrow counts, then sign.

    pack: cast ternary signs to the narrowest count dtype; exchange: psum
    over the vote axes; tally: the psum already is the count tensor; unpack:
    sign of counts (ties and all-abstain coordinates -> 0).
    """

    kind = VoteStrategy.PSUM_INT8
    wire_bits_per_param = 8.0   # int8 counts up to 127 voters
    ties = "zero"

    def pack(self, signs, n_voters):
        return signs.astype(count_dtype(n_voters))

    def exchange(self, wire, axes):
        return jax.lax.psum(wire, axis_name=tuple(axes))

    def tally(self, arrived, n_voters):
        return arrived

    def unpack(self, decision, n, dtype):
        return jnp.sign(decision).astype(dtype)

    def ring_bytes(self, n_params, data_size, pod_size=1):
        c = _count_bytes(data_size * pod_size)
        m = data_size * pod_size
        return {"ici": 2.0 * n_params * c * (data_size - 1) / data_size,
                "dci": (2.0 * (n_params / data_size) * c
                        * (pod_size - 1) / pod_size if pod_size > 1 else 0.0),
                "n_collectives": 1, "total": 2.0 * n_params * c * (m - 1) / m}


class Allgather1BitStrategy(VoteStrategyImpl):
    """The paper-faithful wire protocol: every chip plays the server.

    pack: bit-pack 32 signs per uint32 word (1 bit/param on the wire);
    exchange: all-gather the packed words over each vote axis; tally:
    bit-sliced popcount majority across the voter dim; unpack: decode the
    packed majority (ties -> +1).
    """

    kind = VoteStrategy.ALLGATHER_1BIT
    wire_bits_per_param = 1.0
    ties = "plus_one"

    def __init__(self, tally_fn: Optional[Callable] = None):
        # override point for the Pallas popcount kernel (kernels.ops.majority)
        self._tally_fn = tally_fn

    def pack(self, signs, n_voters):
        padded, _ = _pad_last(signs, sc.PACK)
        return sc.pack_signs(padded)

    def exchange(self, wire, axes):
        packed = wire
        for a in axes:   # gather over each vote axis; leading M dims stack
            packed = compat.all_gather(packed, a, tiled=False)
        # collapse the stacked gather dims into one voter dim M
        return packed.reshape((-1,) + packed.shape[len(tuple(axes)):])

    def tally(self, arrived, n_voters):
        if self._tally_fn is not None:
            return self._tally_fn(arrived)
        m = arrived.shape[0]
        shifts = jnp.arange(sc.PACK, dtype=jnp.uint32)
        bits = (arrived[..., None] >> shifts) & jnp.uint32(1)   # (M, ..., w, 32)
        counts = jnp.sum(bits.astype(jnp.int32), axis=0)        # (..., w, 32)
        maj = (2 * counts >= m).astype(jnp.uint32)
        packed_maj = jnp.zeros(maj.shape[:-1], jnp.uint32)
        for j in range(sc.PACK):   # unrolled OR (SPMD-partitioner-safe)
            packed_maj = packed_maj | (maj[..., j] << jnp.uint32(j))
        return packed_maj

    def unpack(self, decision, n, dtype):
        return sc.unpack_signs(decision, dtype)[..., :n]

    def ring_bytes(self, n_params, data_size, pod_size=1):
        # exchange() gathers pod-first (vote_axes_in order): the DCI hop
        # moves one packed payload, the ICI hop then gathers the stacked
        # (pod, w) words
        m = data_size * pod_size
        dci = (pod_size - 1) * n_params / 8.0
        ici = (data_size - 1) * pod_size * n_params / 8.0
        assert abs((ici + dci) - (m - 1) * n_params / 8.0) < 1e-6 * max(m, 1)
        return {"ici": ici, "dci": dci,
                "n_collectives": 1 + (1 if pod_size > 1 else 0),
                "total": ici + dci}


class HierarchicalStrategy(VoteStrategyImpl):
    """Count-shards within the pod, sums counts across pods, rebroadcasts
    the 1-bit result: the global majority (counts cross pods — NOT a
    vote-of-votes).

    The stages interleave two exchanges, so ``vote`` overrides the default
    composition: pack casts to counts, exchange is the int8 reduce-scatter
    (+ cross-pod psum of the scattered counts), tally is the binary sign of
    the shard's counts, and unpack re-packs the shard decision, all-gathers
    it (1 bit/param), and decodes — the second collective is part of the
    decode because every replica needs the full decision back.
    """

    kind = VoteStrategy.HIERARCHICAL
    wire_bits_per_param = 8.0   # int8 counts in the reduce-scatter
    ties = "plus_one"

    def __init__(self, data_axis: str = "data",
                 pod_axis: Optional[str] = "pod"):
        self.data_axis = data_axis
        self.pod_axis = pod_axis

    def _axes(self, axes: Sequence[str]) -> Tuple[str, Optional[str]]:
        pod = self.pod_axis if self.pod_axis in tuple(axes) else None
        return self.data_axis, pod

    def pack(self, signs, n_voters):
        return signs.astype(count_dtype(n_voters))

    def exchange(self, wire, axes):
        data_axis, pod_axis = self._axes(axes)
        counts = jax.lax.psum_scatter(
            wire, data_axis, scatter_dimension=wire.ndim - 1, tiled=True)
        if pod_axis is not None:
            counts = jax.lax.psum(counts, pod_axis)
        return counts

    def tally(self, arrived, n_voters):
        return sc.sign_binary(arrived)       # ties -> +1 (1-bit wire)

    def unpack(self, decision, n, dtype):
        # second (cheap) exchange: packed all-gather of the shard decision
        packed = compat.all_gather(
            sc.pack_signs(decision), self.data_axis,
            axis=decision.ndim - 1, tiled=True)
        return sc.unpack_signs(packed, dtype)[..., :n]

    def vote(self, signs, axes):
        data_axis, pod_axis = self._axes(axes)
        dsize = compat.axis_size(data_axis)
        m = dsize * (compat.axis_size(pod_axis) if pod_axis else 1)
        n = signs.shape[-1]
        padded, _ = _pad_last(signs, sc.PACK * dsize)
        decision = self.tally(self.exchange(self.pack(padded, m), axes), m)
        return self.unpack(decision, n, jnp.int8)

    def ring_bytes(self, n_params, data_size, pod_size=1):
        d = float(n_params)
        rs = d * 1 * (data_size - 1) / data_size        # int8 RS in pod
        xpod = ((d / data_size) * 1 * 2 * (pod_size - 1) / max(pod_size, 1)
                if pod_size > 1 else 0.0)
        ag = (d / 8) * (data_size - 1) / data_size      # packed AG
        return {"ici": rs + ag, "dci": xpod,
                "n_collectives": 2 + (1 if pod_size > 1 else 0),
                "total": rs + xpod + ag}


STRATEGIES: Dict[VoteStrategy, VoteStrategyImpl] = {
    VoteStrategy.PSUM_INT8: PsumInt8Strategy(),
    VoteStrategy.ALLGATHER_1BIT: Allgather1BitStrategy(),
    VoteStrategy.HIERARCHICAL: HierarchicalStrategy(),
}


# ---------------------------------------------------------------------------
# strategy auto-selection
# ---------------------------------------------------------------------------


def select_strategy(n_params: int, data_size: int, pod_size: int = 1,
                    codec: str = "sign1bit") -> VoteStrategy:
    """Cheapest concrete strategy under the alpha-beta comm model for this
    mesh shape, parameter count and codec. Deterministic and static
    (compile-time); single-replica meshes degenerate to PSUM_INT8 (no wire
    traffic at all). Codec-aware (DESIGN.md §8): candidates are the
    codec's supported transports and the gathered exchange is priced at
    the codec's symbol width (2 bits/param for ``ternary2bit``), so AUTO
    under a wider codec tips toward the count wires earlier.
    """
    from repro.core import codecs as codecs_mod
    c = codecs_mod.get_codec(codec)
    candidates = c.supported_strategies
    if data_size * pod_size <= 1:
        return (VoteStrategy.PSUM_INT8
                if VoteStrategy.PSUM_INT8 in candidates else candidates[0])
    times = {}
    for k in candidates:
        s = STRATEGIES[k]
        b = s.ring_bytes(n_params, data_size, pod_size)
        # the gathered exchange is linear in the symbol width; the count
        # wires carry int8 counts whatever the codec symbols were
        scale = (c.bits_per_param / s.wire_bits_per_param
                 if k == VoteStrategy.ALLGATHER_1BIT else 1.0)
        times[k] = comm_model.collective_time(
            b["ici"] * scale, b["dci"] * scale,
            n_collectives=int(b["n_collectives"])).time_s
    return min(times, key=times.get)


def resolve_strategy(strategy: VoteStrategy, n_params: int,
                     data_size: int, pod_size: int = 1,
                     codec: str = "sign1bit") -> VoteStrategy:
    if strategy == VoteStrategy.AUTO:
        return select_strategy(n_params, data_size, pod_size, codec)
    return strategy


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VoteEngine:
    """LEGACY pack -> exchange -> tally -> unpack, behind one object.

    Every vote method on this class is now a deprecation shim over the
    declarative vote API (DESIGN.md §10): it builds a
    :class:`~repro.core.vote_api.VoteRequest` from the engine's fields
    and executes it on a :class:`~repro.core.vote_api.MeshBackend`
    (``vote_stacked``: a :class:`~repro.core.vote_api.VirtualBackend`).
    The strategy registry (:data:`STRATEGIES`), the stage methods and
    the AUTO selector remain the wire's real implementation — only the
    imperative entry-point surface is deprecated.

    `axes` are the manual mesh axes the vote runs over (empty = the M=1
    single-process degenerate case where the vote is the local sign).
    `byz` compiles the Byzantine adversary models into the pack stage, so
    fault injection perturbs exactly the tensors the trainer votes on.
    `strategy` may be ``VoteStrategy.AUTO``; it resolves per tree against
    the comm cost model (needs the axis sizes, i.e. a trace context).
    `salt` namespaces the adversary PRNG stream (the Scenario Lab folds a
    scenario-id hash in here — DESIGN.md §7); pass `step` to the vote
    entry points so stochastic adversaries redraw each step.
    `codec` selects the gradient codec (DESIGN.md §8): what the workers
    encode onto the wire and how the tally decodes it. The default
    ``sign1bit`` is the paper's raw-sign majority and keeps every legacy
    entry point bit-identical; stateful codecs (``weighted_vote``) thread
    their server state through the ``*_codec`` entry points.
    """

    strategy: VoteStrategy
    axes: Tuple[str, ...] = ()
    byz: Optional[ByzantineConfig] = None
    salt: int = 0
    codec: str = "sign1bit"

    def _backend(self):
        from repro.core import vote_api as va
        return va.MeshBackend(axes=self.axes)

    def _codec(self):
        from repro.core import codecs as codecs_mod
        return codecs_mod.get_codec(self.codec)

    # ---- voting (deprecation shims over the vote API) ----

    def vote_signs(self, signs: jax.Array) -> jax.Array:
        """DEPRECATED shim: int8 signs (..., n) -> int8 majority, no
        adversary (the engine's compiled model applies in :meth:`vote`,
        not here)."""
        from repro.core import vote_api as va
        va.warn_legacy("VoteEngine.vote_signs")
        return self._backend().execute(va.VoteRequest(
            payload=signs, form="leaf", strategy=self.strategy,
            codec=self.codec, salt=self.salt)).votes

    def vote_signs_codec(self, signs: jax.Array, server_state=None):
        """DEPRECATED shim: int8 signs -> (int8 majority, new server
        state), no adversary."""
        from repro.core import vote_api as va
        va.warn_legacy("VoteEngine.vote_signs_codec")
        out = self._backend().execute(va.VoteRequest(
            payload=signs, form="leaf", strategy=self.strategy,
            codec=self.codec, salt=self.salt, server_state=server_state))
        return out.votes, out.server_state

    def vote_codec(self, values: jax.Array,
                   step: Optional[jax.Array] = None, server_state=None):
        """DEPRECATED shim: replica-local real tensor -> (majority in
        the input dtype, new server state), through the engine's
        compiled adversary and codec wire."""
        from repro.core import vote_api as va
        va.warn_legacy("VoteEngine.vote_codec")
        out = self._backend().execute(va.VoteRequest(
            payload=values, form="leaf", strategy=self.strategy,
            codec=self.codec, failures=va.FailureSpec(byz=self.byz),
            step=step, salt=self.salt, server_state=server_state))
        return out.votes, out.server_state

    def vote(self, values: jax.Array,
             step: Optional[jax.Array] = None) -> jax.Array:
        """DEPRECATED shim: replica-local real tensor -> majority of
        signs, in the input dtype."""
        from repro.core import vote_api as va
        va.warn_legacy("VoteEngine.vote")
        return self._backend().execute(va.VoteRequest(
            payload=values, form="leaf", strategy=self.strategy,
            codec=self.codec, failures=va.FailureSpec(byz=self.byz),
            step=step, salt=self.salt)).votes

    def vote_tree(self, tree, step: Optional[jax.Array] = None):
        """DEPRECATED shim: vote every leaf of a pytree; ±1 tree in the
        leaf dtypes. AUTO resolves once per tree (codec-aware, which for
        the default ``sign1bit`` codec is the historical resolution)."""
        from repro.core import vote_api as va
        va.warn_legacy("VoteEngine.vote_tree")
        return self._backend().execute(va.VoteRequest(
            payload=tree, form="tree", strategy=self.strategy,
            codec=self.codec, failures=va.FailureSpec(byz=self.byz),
            step=step, salt=self.salt)).votes

    def vote_tree_codec(self, tree, step: Optional[jax.Array] = None,
                        server_state=None):
        """DEPRECATED shim: codec-aware tree vote -> (±1 tree, new
        server state)."""
        from repro.core import vote_api as va
        va.warn_legacy("VoteEngine.vote_tree_codec")
        out = self._backend().execute(va.VoteRequest(
            payload=tree, form="tree", strategy=self.strategy,
            codec=self.codec, failures=va.FailureSpec(byz=self.byz),
            step=step, salt=self.salt, server_state=server_state))
        return out.votes, out.server_state

    def vote_stacked(self, stacked: jax.Array,
                     use_kernels: bool = True) -> jax.Array:
        """DEPRECATED shim: (M, n) host-local stacked values -> (n,)
        int8 majority on the gathered 1-bit wire (ties -> +1), fused
        Pallas kernel when `use_kernels`."""
        from repro.core import vote_api as va
        va.warn_legacy("VoteEngine.vote_stacked")
        return va.VirtualBackend(use_kernels=use_kernels).execute(
            va.VoteRequest(payload=stacked, form="stacked",
                           strategy=VoteStrategy.ALLGATHER_1BIT)).votes

    # ---- accounting ----

    def comm_bytes(self, n_params: int, data_size: int, pod_size: int = 1,
                   grad_bytes: int = 2) -> Dict[str, float]:
        """Analytic per-chip collective bytes for one vote vs a dense
        all-reduce of the same gradient (ring terms). Codec-aware: the
        gathered exchange scales with the codec's symbol width."""
        strat = STRATEGIES[resolve_strategy(
            self.strategy, n_params, data_size, pod_size, codec=self.codec)]
        d = float(n_params)
        m = data_size * pod_size
        dense = 2 * d * grad_bytes * (m - 1) / m        # ring all-reduce
        vote = strat.ring_bytes(n_params, data_size, pod_size)["total"]
        if strat.kind == VoteStrategy.ALLGATHER_1BIT:
            vote *= self._codec().bits_per_param / strat.wire_bits_per_param
        return {"dense_allreduce": dense, "vote": vote,
                "ratio": dense / vote if vote else float("inf")}
