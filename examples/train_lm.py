"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with SIGNUM + majority vote, with checkpointing every 100 steps.

The model is a glm4-family transformer scaled to ~100M params
(12 layers, d_model=512, vocab 32k). On CPU this takes a few minutes; on a
real mesh the identical step runs under launch/train.py.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import AsyncCheckpointer
from repro.configs.base import OptimizerConfig, TrainConfig, get_config
from repro.data.pipeline import SyntheticLMPipeline
from repro.models import model as M
from repro.train import train_step as TS


def config_100m():
    base = get_config("glm4-9b")
    return dataclasses.replace(
        base, name="glm4-100m", num_layers=12, d_model=512, num_heads=8,
        num_kv_heads=2, head_dim=64, d_ff=1536, vocab_size=32_000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    print(f"model: {cfg.name}  params={cfg.param_count() / 1e6:.1f}M")
    tcfg = TrainConfig(
        global_batch=args.batch, seq_len=args.seq,
        optimizer=OptimizerConfig(kind="signum_vote", learning_rate=3e-4,
                                  momentum=0.9, warmup_steps=20,
                                  total_steps=args.steps))
    art = TS.make_train_step(cfg, tcfg, mesh=None)
    params, opt_state = TS.materialize_state(cfg, tcfg, art,
                                             jax.random.PRNGKey(0))
    pipe = SyntheticLMPipeline(cfg, args.batch, args.seq, seed=0)
    ckpt = AsyncCheckpointer(args.ckpt_dir)

    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt_state, met = art.step_fn(params, opt_state, batch,
                                             jnp.int32(step))
        if step % 20 == 0 or step == args.steps - 1:
            dt = (time.time() - t0) / (step + 1)
            print(f"step {step:4d}  loss {float(met['loss']):8.4f}  "
                  f"ce {float(met['ce']):8.4f}  {dt:.2f}s/step", flush=True)
        if (step + 1) % 100 == 0:
            ckpt.save(step, params, opt_state, pipe.checkpoint(),
                      meta={"arch": cfg.name, "step": step})
    ckpt.wait()
    print(f"done in {time.time() - t0:.0f}s; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
