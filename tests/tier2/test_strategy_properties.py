"""Property tests (hypothesis): cross-strategy decision consistency.

All three wire strategies (`psum_int8`, `allgather_1bit`, `hierarchical`)
and the fused Pallas kernel must produce bit-identical decisions on
random sign tensors across odd/even voter counts, padded/unpadded shapes
(n % 32 != 0 exercises the pack padding), and f32/bf16 grad dtypes —
identical everywhere for odd M (no ties possible with ±1 inputs), and on
every untied coordinate for even M. The one documented divergence is the
tie itself (DESIGN.md §5/§7): integer-count wire -> 0 (abstain), 1-bit
wires -> +1 — pinned here at the paper's boundary regime of EXACTLY 50%
sign-flipping adversaries.

``hypothesis`` is optional: without it this module skips — the same
matrix is swept deterministically in test_strategy_consistency.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; deterministic "
    "equivalents live in test_strategy_consistency.py")
from hypothesis import given, settings, strategies as st

from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import byzantine, sign_compress as sc
from repro.kernels import ops
from repro.sim import virtual_vote

STRATS = (VoteStrategy.PSUM_INT8, VoteStrategy.ALLGATHER_1BIT,
          VoteStrategy.HIERARCHICAL)


def assert_decisions_consistent(x: np.ndarray):
    """The shared oracle: counts decide everything; strategies may only
    differ on exact ties, and only per their documented convention."""
    m, n = x.shape
    signs = np.asarray(sc.sign_ternary(jnp.asarray(x)))
    counts = signs.astype(np.int32).sum(axis=0)
    votes = {s: np.asarray(virtual_vote(jnp.asarray(signs), s))
             for s in STRATS}
    np.testing.assert_array_equal(votes[VoteStrategy.PSUM_INT8],
                                  np.sign(counts).astype(np.int8))
    packed = np.where(counts >= 0, 1, -1).astype(np.int8)
    np.testing.assert_array_equal(votes[VoteStrategy.ALLGATHER_1BIT], packed)
    np.testing.assert_array_equal(votes[VoteStrategy.HIERARCHICAL], packed)
    fused = np.asarray(ops.bitunpack(
        ops.fused_majority(jnp.asarray(x, jnp.float32)), n, jnp.int8))
    np.testing.assert_array_equal(fused, packed)
    if m % 2 == 1:      # odd M, ±1 inputs: no ties -> ALL bit-identical
        np.testing.assert_array_equal(votes[VoteStrategy.PSUM_INT8], packed)


@given(st.integers(1, 12), st.integers(1, 130),
       st.sampled_from(["float32", "bfloat16"]), st.randoms())
@settings(max_examples=60, deadline=None)
def test_strategies_and_kernel_bit_identical(m, n, dtype, rnd):
    x = np.array([[rnd.choice([-1.0, 1.0]) for _ in range(n)]
                  for _ in range(m)], np.float32)
    x = np.asarray(jnp.asarray(x, jnp.dtype(dtype)), np.float32)
    assert_decisions_consistent(x)


@given(st.integers(1, 8), st.integers(1, 96), st.randoms())
@settings(max_examples=40, deadline=None)
def test_tie_break_at_exactly_half_adversaries(half_m, n, rnd):
    """EXACTLY 50% sign-flippers: every coordinate's count is zero. The
    integer-count wire abstains (0); both 1-bit wires and the fused
    kernel resolve +1. This is the cross-strategy divergence the suite
    documents rather than papers over."""
    m = 2 * half_m
    honest = np.array([[rnd.choice([-1.0, 1.0]) for _ in range(n)]
                       for _ in range(m)], np.float32)
    honest = np.tile(honest[:1], (m, 1))            # unanimous electorate
    byz_cfg = ByzantineConfig(mode="sign_flip", num_adversaries=half_m)
    wire = np.asarray(byzantine.apply_adversary_stacked(
        jnp.asarray(sc.sign_ternary(jnp.asarray(honest))), byz_cfg))
    assert (wire.astype(np.int32).sum(axis=0) == 0).all()
    assert np.asarray(
        virtual_vote(jnp.asarray(wire), VoteStrategy.PSUM_INT8)).sum() == 0
    for strat in (VoteStrategy.ALLGATHER_1BIT, VoteStrategy.HIERARCHICAL):
        np.testing.assert_array_equal(
            np.asarray(virtual_vote(jnp.asarray(wire), strat)),
            np.ones(n, np.int8), err_msg=str(strat))
    fused = np.asarray(ops.bitunpack(
        ops.fused_majority(jnp.asarray(wire, jnp.float32)), n, jnp.int8))
    np.testing.assert_array_equal(fused, np.ones(n, np.int8))


@given(st.integers(2, 10), st.integers(33, 120), st.randoms())
@settings(max_examples=40, deadline=None)
def test_padding_never_leaks_into_decisions(m, n, rnd):
    """Unpadded (n % 32 == 0) and padded slices of the same electorate
    agree on the common prefix, for every strategy."""
    x = np.array([[rnd.choice([-1.0, 1.0]) for _ in range(n)]
                  for _ in range(m)], np.float32)
    n32 = (n // 32) * 32
    for s in STRATS:
        full = np.asarray(virtual_vote(jnp.asarray(
            sc.sign_ternary(jnp.asarray(x))), s))
        sliced = np.asarray(virtual_vote(jnp.asarray(
            sc.sign_ternary(jnp.asarray(x[:, :n32]))), s))
        np.testing.assert_array_equal(full[:n32], sliced, err_msg=str(s))
