"""Per-kernel validation: shape/dtype sweeps, allclose vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sign_compress as sc
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(n, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=(n,)).astype(dtype))


@pytest.mark.parametrize("n", [1, 31, 32, 33, 4096, 8 * 128 * 32,
                               8 * 128 * 32 + 17, 100_000])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_bitpack_roundtrip(n, dtype):
    x = _rand(n).astype(dtype)
    packed = ops.bitpack(x)
    assert packed.dtype == jnp.uint32
    assert packed.shape[0] == -(-n // 32)
    un = ops.bitunpack(packed, n)
    expect = np.where(np.asarray(x, np.float32) >= 0, 1.0, -1.0)
    np.testing.assert_array_equal(np.asarray(un), expect)


@pytest.mark.parametrize("n", [64, 4096])
def test_bitpack_matches_oracle(n):
    x = _rand(n)
    packed = ops.bitpack(x)
    oracle = ref.bitpack(x.reshape(1, -1))[0]
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(oracle))


@pytest.mark.parametrize("m", [1, 2, 3, 7, 16, 33])
@pytest.mark.parametrize("w", [1, 511, 512, 700])
def test_majority_matches_oracle(m, w):
    p = jnp.asarray(RNG.integers(0, 2 ** 32, size=(m, w), dtype=np.uint32))
    got = ops.majority(p)
    expect = ref.majority(p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_majority_semantics_vs_sign_counting():
    m, n = 9, 320
    x = RNG.normal(size=(m, n)).astype(np.float32)
    packed = jnp.stack([ops.bitpack(jnp.asarray(row)) for row in x])
    maj = ops.bitunpack(ops.majority(packed), n)
    votes = np.where(x >= 0, 1, -1).sum(axis=0)
    expect = np.where(votes >= 0, 1.0, -1.0)
    np.testing.assert_array_equal(np.asarray(maj), expect)


@pytest.mark.parametrize("beta", [0.0, 0.9, 0.99])
@pytest.mark.parametrize("n", [32, 50_016])
def test_momentum_sign_pack(beta, n):
    g, m = _rand(n), _rand(n)
    m_new, packed = ops.momentum_sign_pack(g, m, beta)
    mr, pr = ref.momentum_sign_pack(g.reshape(1, -1), m.reshape(1, -1), beta)
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(mr)[0],
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(pr)[0])


@pytest.mark.parametrize("eta,wd", [(1e-3, 0.0), (1e-2, 0.1)])
def test_apply_vote(eta, wd):
    n = 50_016
    p = _rand(n)
    votes = ops.bitpack(_rand(n))
    out = ops.apply_vote(p, votes, eta, wd)
    outr = ref.apply_vote(p.reshape(1, -1), votes.reshape(1, -1), eta, wd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(outr)[0],
                               rtol=1e-5, atol=1e-6)


def test_fused_pipeline_equals_unfused():
    """momentum_sign_pack + majority + apply_vote == the optimizer math."""
    m_workers, n = 5, 2_048
    gs = [_rand(n) for _ in range(m_workers)]
    ms = [_rand(n) for _ in range(m_workers)]
    p = _rand(n)
    beta, eta = 0.9, 1e-3
    packed = []
    new_ms = []
    for g, mom in zip(gs, ms):
        m_new, pk = ops.momentum_sign_pack(g, mom, beta)
        new_ms.append(m_new)
        packed.append(pk)
    maj = ops.majority(jnp.stack(packed))
    p_new = ops.apply_vote(p, maj, eta, 0.0)
    # unfused reference
    votes = sum(np.where(np.asarray(beta * m0 + (1 - beta) * g0) >= 0, 1, -1)
                for g0, m0 in zip(gs, ms))
    vote = np.where(votes >= 0, 1.0, -1.0)
    expect = np.asarray(p) - eta * vote
    np.testing.assert_allclose(np.asarray(p_new), expect, rtol=1e-5,
                               atol=1e-6)
