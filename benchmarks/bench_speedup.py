"""Fig. 6 analog: end-to-end step-time model — majority vote vs dense
all-reduce SGD — built from the roofline terms of the *measured* dry-run
artifacts (collective bytes from compiled HLO where available, analytic
wire model otherwise). Reports the predicted wall-clock speedup per arch,
the quantity the paper reports as '25% faster to 80 epochs'."""
from __future__ import annotations

import json
import os

from repro.configs.base import VoteStrategy, get_config
from repro.core.majority_vote import comm_bytes_per_step
from repro.distributed import comm_model as CM
from benchmarks.roofline import analytic_train_flops

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.jsonl")


def _dryrun_records():
    if not os.path.exists(RESULTS):
        return {}
    recs = {}
    for line in open(RESULTS):
        r = json.loads(line)
        if r.get("status") == "ok":
            recs[(r["arch"], r["shape"], r["mesh"], r.get("opt"))] = r
    return recs


def rows():
    out = []
    recs = _dryrun_records()
    for arch in ["zamba2-1.2b", "glm4-9b", "deepseek-67b",
                 "qwen3-moe-235b-a22b", "qwen2-moe-a2.7b"]:
        cfg = get_config(arch)
        flops_chip = analytic_train_flops(cfg, 256, 4096) / 256
        t_comp = flops_chip / CM.PEAK_FLOPS
        n_shard = cfg.param_count() // 16
        dense = comm_bytes_per_step(n_shard, VoteStrategy.PSUM_INT8, 16)
        rec = recs.get((arch, "train_4k", "16x16", "signum_vote"))
        if rec is not None:
            total_vote_arm = rec["collectives"]["transit_bytes_ici"]
            src = "HLO-measured total"
        else:
            total_vote_arm = dense["vote"]
            src = "analytic sync-only"
        # apples-to-apples: both arms carry the same activation/TP traffic;
        # they differ only in the gradient-sync bytes
        total_dense_arm = (total_vote_arm - dense["vote"]
                           + dense["dense_allreduce"])
        step_vote = CM.step_time_estimate(
            flops_chip, 0, CM.collective_time(total_vote_arm), overlap=0.7)
        step_dense = CM.step_time_estimate(
            flops_chip, 0, CM.collective_time(total_dense_arm), overlap=0.7)
        t_vote = CM.collective_time(dense["vote"]).time_s
        t_dense = CM.collective_time(dense["dense_allreduce"]).time_s
        out.append((f"fig6/{arch}/step_speedup_vote_vs_allreduce",
                    step_dense / step_vote,
                    f"compute={t_comp * 1e3:.1f}ms sync: vote="
                    f"{t_vote * 1e3:.2f}ms dense={t_dense * 1e3:.2f}ms "
                    f"({src})"))
    return out


def main() -> None:
    from benchmarks.common import rows_main
    rows_main("speedup", __doc__, rows)


if __name__ == "__main__":
    main()
