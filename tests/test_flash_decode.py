"""Flash-decode correctness: the chunked online-softmax path (and its
int8-quantized variant) must match direct attention; the sharded combine
math (m/num/den merging) must be exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L

RNG = np.random.default_rng(0)


def _direct(q, k_cache, v_cache, pos, window=None):
    B, _, K, G, D = q.shape
    T = k_cache.shape[1]
    kv_pos = np.arange(T)
    valid = kv_pos <= pos
    if window is not None:
        valid = valid & (pos - kv_pos < window)
    s = np.einsum("bskgd,btkd->bkgst", np.asarray(q, np.float32),
                  np.asarray(k_cache, np.float32)) * D ** -0.5
    s = np.where(valid[None, None, None, None, :], s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    out = np.einsum("bkgst,btkd->bkgsd", p, np.asarray(v_cache, np.float32))
    return np.moveaxis(out, 3, 1)


@pytest.mark.parametrize("pos", [0, 100, 8191])
@pytest.mark.parametrize("window", [None])
def test_chunked_decode_matches_direct(pos, window):
    B, T, K, G, D = 2, 8192, 2, 3, 16
    q = jnp.asarray(RNG.normal(size=(B, 1, K, G, D)).astype(np.float32))
    kc = jnp.asarray(RNG.normal(size=(B, T, K, D)).astype(np.float32))
    vc = jnp.asarray(RNG.normal(size=(B, T, K, D)).astype(np.float32))
    out = L._decode_attention_chunked(q, kc, vc, jnp.int32(pos), window,
                                      None, None, D ** -0.5)
    ref = _direct(q, kc, vc, pos, window)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-5)


def test_chunked_decode_with_window():
    B, T, K, G, D = 1, 8192, 1, 2, 8
    q = jnp.asarray(RNG.normal(size=(B, 1, K, G, D)).astype(np.float32))
    kc = jnp.asarray(RNG.normal(size=(B, T, K, D)).astype(np.float32))
    vc = jnp.asarray(RNG.normal(size=(B, T, K, D)).astype(np.float32))
    pos, win = 6000, 1024
    out = L._decode_attention_chunked(q, kc, vc, jnp.int32(pos),
                                      jnp.int32(win), None, None, D ** -0.5)
    ref = _direct(q, kc, vc, pos, win)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-5)


def test_quantized_cache_close_to_fp():
    B, T, K, G, D = 1, 4096, 2, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, 1, K, G, D)).astype(np.float32))
    k = RNG.normal(size=(B, T, K, D)).astype(np.float32)
    v = RNG.normal(size=(B, T, K, D)).astype(np.float32)
    kq, ks = L.quantize_kv(jnp.asarray(k))
    vq, vs = L.quantize_kv(jnp.asarray(v))
    pos = T - 1
    out_q = L._decode_attention_chunked(q, kq, vq, jnp.int32(pos), None,
                                        ks, vs, D ** -0.5)
    ref = _direct(q, jnp.asarray(k), jnp.asarray(v), pos)
    err = np.abs(np.asarray(out_q) - ref) / (np.abs(ref) + 1e-2)
    assert np.mean(err) < 0.05, np.mean(err)


def test_online_softmax_combine_identity():
    """Merging per-shard (m, num, den) partials == global softmax: the
    correctness core of flash_decode_sharded's psum combine."""
    n_shards, C, D = 4, 64, 8
    s = RNG.normal(size=(n_shards, C)).astype(np.float64)
    v = RNG.normal(size=(n_shards, C, D)).astype(np.float64)
    # per-shard partials
    m = s.max(axis=1)
    num = np.einsum("nc,ncd->nd", np.exp(s - m[:, None]), v)
    den = np.exp(s - m[:, None]).sum(axis=1)
    # combine
    m_g = m.max()
    w = np.exp(m - m_g)
    out = (num * w[:, None]).sum(0) / (den * w).sum(0)
    # reference: flat softmax over all shards
    flat = s.reshape(-1)
    p = np.exp(flat - flat.max())
    p /= p.sum()
    ref = p @ v.reshape(-1, D)
    np.testing.assert_allclose(out, ref, rtol=1e-12)


def test_quantize_kv_roundtrip_error_bounded():
    x = jnp.asarray(RNG.normal(size=(4, 128, 2, 64)).astype(np.float32) * 5)
    q, s = L.quantize_kv(x)
    deq = np.asarray(q, np.float32) * np.asarray(s, np.float32)[..., None]
    err = np.abs(deq - np.asarray(x))
    # rounding error <= scale/2, plus the bf16 quantization of the scale
    # itself contributes up to 127 * scale * 2^-8
    sc = np.asarray(s, np.float32)[..., None]
    bound = sc * (0.5 + 127 * 2.0 ** -8) + 1e-6
    assert np.all(err <= bound + 1e-5)
