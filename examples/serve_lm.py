"""Serving example: prefill a batch of prompts, decode with the KV cache
(including the int8-quantized cache variant), report tokens/sec.

    PYTHONPATH=src python examples/serve_lm.py --arch glm4-9b --reduced
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models import model as M
from repro.train.serve_step import make_decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--int8-kv", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    total = args.prompt_len + args.gen
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, jnp.int32)

    decode = make_decode_step(cfg)
    cache = M.init_cache(cfg, args.batch, total)
    # feed the prompt through the decode path (prefill-by-decode keeps the
    # example uniform across attention/SSM/hybrid archs)
    t0 = time.time()
    for t in range(args.prompt_len):
        logits, cache = decode(params, prompt[:, t:t + 1], cache,
                               jnp.int32(t))
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time() - t0:.2f}s")

    tokens = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [tokens]
    t0 = time.time()
    for i in range(args.gen):
        logits, cache = decode(params, tokens, cache,
                               jnp.int32(args.prompt_len + i))
        tokens = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tokens)
    dt = time.time() - t0
    seq = np.asarray(jnp.concatenate(out, axis=1))
    print(f"decode {args.gen} steps x batch {args.batch}: {dt:.2f}s "
          f"({args.gen * args.batch / dt:.1f} tok/s, "
          f"kv={cfg.kv_cache_dtype})")
    print("first row token ids:", seq[0][:16].tolist(), "...")


if __name__ == "__main__":
    main()
