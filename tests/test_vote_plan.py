"""VotePlan subsystem tests (DESIGN.md §9) — tier-1, single device.

Covers: deterministic layout manifest + bucket schedule (alignment, the
ragged last bucket, the ceil bucket-count bound), first-match glob codec
maps, the flatten→bucket→unflatten identity for every codec
(deterministic twins of tests/test_plan_properties.py), schedule-cost
pricing under the per-message α–β model, the stacked kernel path's
one-launch-per-bucket accounting, the optimizer plan path's exact
equality with the leaf-wise wire, and the checkpoint save/refit/restore
round-trip of bucketed EF residual and flip-EMA state.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig, VoteStrategy
from repro.core import codecs, sign_compress as sc, vote_plan as vp
from repro.core.codecs import weighted as wv
from repro.core.signum import build_optimizer
from repro.distributed import comm_model
from repro.sim.virtual_mesh import virtual_plan_vote, virtual_vote_codec

RNG = np.random.default_rng(0)

SHAPES = {"embed.table": (7, 9), "layers.w_gate": (5, 11),
          "layers.norm": (3,), "unembed.table": (6, 4)}


def _tree(shapes=SHAPES, dtypes=None):
    return {k: jnp.asarray(RNG.normal(size=s).astype(
        (dtypes or {}).get(k, np.float32))) for k, s in shapes.items()}


# ---------------------------------------------------------------------------
# building: manifest + schedule
# ---------------------------------------------------------------------------


def test_manifest_is_deterministic_and_covers_every_leaf_once():
    p1 = vp.build_plan(SHAPES, bucket_bytes=8)
    p2 = vp.build_plan(dict(reversed(list(SHAPES.items()))), bucket_bytes=8)
    assert p1 == p2                          # insertion order is irrelevant
    assert p1.n_params == sum(int(np.prod(s)) for s in SHAPES.values())
    seen = sorted((s.offset, s.offset + s.length) for s in p1.leaves)
    assert seen[0][0] == 0 and seen[-1][1] == p1.n_params
    for (_, e), (b, _) in zip(seen, seen[1:]):
        assert e == b                        # no gaps, no overlaps
    assert {s.name for s in p1.leaves} == set(SHAPES)


def test_buckets_align_and_only_last_is_ragged():
    plan = vp.build_plan({"a": (200,)}, bucket_bytes=8)  # 64-elem buckets
    lens = [b.length for b in plan.buckets]
    assert lens == [64, 64, 64, 8]
    assert all(b.length % vp.ALIGN == 0 for b in plan.buckets[:-1])
    starts = [b.start for b in plan.buckets]
    assert starts == [0, 64, 128, 192]


def test_bucket_count_bound_holds():
    """n_buckets <= ceil(n_params * bits / (8 * bucket_bytes)) — the
    acceptance bound: rounding bucket length UP to the alignment can only
    reduce the count."""
    for n in (31, 64, 1000, 4097):
        for bb in (1, 3, 8, 100):
            plan = vp.build_plan({"a": (n,)}, bucket_bytes=bb)
            assert plan.n_buckets <= -(-n // (8 * bb)), (n, bb)
            assert sum(b.length for b in plan.buckets) == n


def test_hierarchical_buckets_align_to_data_size():
    plan = vp.build_plan({"a": (2000,)}, bucket_bytes=8,
                         strategy=VoteStrategy.HIERARCHICAL, data_size=8)
    assert all(b.length % (vp.ALIGN * 8) == 0 for b in plan.buckets[:-1])


def test_codec_map_first_match_wins_and_groups_are_contiguous():
    plan = vp.build_plan(
        SHAPES, bucket_bytes=16,
        codec_map=(("embed*", "ternary2bit"), ("*.table", "weighted_vote"),
                   ("*", "sign1bit")),
        strategy=VoteStrategy.ALLGATHER_1BIT)
    lc = plan.leaf_codecs()
    assert lc["embed.table"] == "ternary2bit"      # first match, not *.table
    assert lc["unembed.table"] == "weighted_vote"
    assert lc["layers.w_gate"] == "sign1bit"
    for g in plan.groups:
        assert all(g.start <= b.start < g.start + g.total
                   for b in g.buckets)
        assert all(b.codec == g.codec for b in g.buckets)
    assert plan.has_server_state                   # weighted in the map


def test_build_validation():
    with pytest.raises(ValueError, match="unknown codec"):
        vp.build_plan(SHAPES, bucket_bytes=8, codec_map=(("*", "morse"),))
    with pytest.raises(ValueError, match="bucket_bytes"):
        vp.build_plan(SHAPES, bucket_bytes=0)
    with pytest.raises(ValueError, match="empty"):
        vp.build_plan(SHAPES, bucket_bytes=8, codec_map=(("", "sign1bit"),))
    with pytest.raises(ValueError):
        vp.build_plan({}, bucket_bytes=8)
    with pytest.raises(ValueError, match="cannot ride"):
        vp.build_plan(SHAPES, bucket_bytes=8,
                      codec_map=(("*", "weighted_vote"),),
                      strategy=VoteStrategy.PSUM_INT8)


def test_auto_prices_the_whole_schedule():
    # tiny buckets on a wide mesh: per-message alpha dominates, so AUTO
    # must refuse the two-collective hierarchical wire
    plan = vp.build_plan({"a": (100_000,)}, bucket_bytes=256,
                         strategy=VoteStrategy.AUTO, data_size=16)
    assert plan.groups[0].strategy != VoteStrategy.HIERARCHICAL
    # single replica degenerates to the count wire, no pricing needed
    plan1 = vp.build_plan({"a": (64,)}, bucket_bytes=8, data_size=1)
    assert plan1.groups[0].strategy == VoteStrategy.PSUM_INT8


def test_schedule_cost_scales_with_bucket_count():
    one = vp.build_plan({"a": (65536,)}, bucket_bytes=1 << 20,
                        strategy=VoteStrategy.ALLGATHER_1BIT)
    many = vp.build_plan({"a": (65536,)}, bucket_bytes=64,
                         strategy=VoteStrategy.ALLGATHER_1BIT)
    assert many.n_buckets > one.n_buckets == 1
    # same bytes, more alpha terms: strictly more expensive
    assert many.schedule_cost(16) > one.schedule_cost(16)


def test_comm_model_schedule_time_prices_per_message():
    one = comm_model.collective_time(1e6).time_s
    many = comm_model.schedule_time([(1e4, 0.0, 1)] * 100).time_s
    assert many == pytest.approx(one + 99 * comm_model.ALPHA_ICI)
    est = comm_model.schedule_time([(1e4, 2e3, 2), (1e4, 0.0, 1)])
    assert est.bytes_ici == 2e4 and est.bytes_dci == 2e3


def test_comm_model_overlap_discounts_trailing_alpha():
    """Under the double-buffered walk every launch latency after the
    first hides behind the previous bucket's tally; only the
    OVERLAP_ALPHA_RESIDUE fraction survives. Bandwidth stays serial (one
    wire), and a single message sees no discount at all."""
    msgs = [(1e4, 0.0, 1)] * 100
    one = comm_model.collective_time(1e6).time_s
    ovl = comm_model.schedule_time(msgs, overlap=True).time_s
    assert ovl == pytest.approx(
        one + 99 * comm_model.OVERLAP_ALPHA_RESIDUE * comm_model.ALPHA_ICI)
    assert ovl < comm_model.schedule_time(msgs).time_s
    single = [(1e6, 0.0, 1)]
    assert comm_model.schedule_time(single, overlap=True).time_s == \
        pytest.approx(comm_model.schedule_time(single).time_s)


def test_schedule_cost_overlap_discount():
    many = vp.build_plan({"a": (65536,)}, bucket_bytes=64,
                         strategy=VoteStrategy.ALLGATHER_1BIT)
    assert many.schedule_cost(16, overlap=True) < many.schedule_cost(16)
    one = vp.build_plan({"a": (65536,)}, bucket_bytes=1 << 20,
                        strategy=VoteStrategy.ALLGATHER_1BIT)
    assert one.schedule_cost(16, overlap=True) == \
        pytest.approx(one.schedule_cost(16))


def test_auto_bucket_bytes_ladder():
    """bucket_bytes=-1 resolves a concrete per-group bucket size off the
    priced candidate ladder; the resulting schedule is a valid cut (so it
    stays semantics-free by the bucket-cut property) and never exceeds
    the group's own payload."""
    plan = vp.build_plan({"a": (50_000,)},
                         bucket_bytes=vp.AUTO_BUCKET_BYTES,
                         strategy=VoteStrategy.ALLGATHER_1BIT, data_size=8)
    g = plan.groups[0]
    assert 0 < g.bucket_bytes <= -(-50_000 // 8)
    explicit = vp.build_plan({"a": (50_000,)}, bucket_bytes=g.bucket_bytes,
                             strategy=VoteStrategy.ALLGATHER_1BIT,
                             data_size=8)
    assert plan.buckets == explicit.buckets
    # joint (strategy, bucket_bytes) resolution under AUTO strategy
    joint = vp.build_plan({"a": (50_000,)},
                          bucket_bytes=vp.AUTO_BUCKET_BYTES,
                          strategy=VoteStrategy.AUTO, data_size=8)
    assert joint.groups[0].strategy != VoteStrategy.AUTO
    assert joint.groups[0].bucket_bytes > 0
    with pytest.raises(ValueError, match="bucket_bytes"):
        vp.build_plan(SHAPES, bucket_bytes=-5)


# ---------------------------------------------------------------------------
# flatten -> bucket -> unflatten identity (deterministic twins)
# ---------------------------------------------------------------------------


def test_flatten_unflatten_roundtrip_mixed_dtypes():
    dtypes = {"embed.table": np.float32, "layers.w_gate": np.float16,
              "layers.norm": np.float32, "unembed.table": np.float32}
    tree = _tree(dtypes=dtypes)
    plan = vp.build_plan(SHAPES, bucket_bytes=4)
    flat = vp.flatten_signs(plan, tree)
    assert flat.shape == (plan.n_params,) and flat.dtype == jnp.int8
    back = vp.unflatten_votes(plan, flat, tree)
    for k, leaf in tree.items():
        assert back[k].dtype == leaf.dtype and back[k].shape == leaf.shape
        np.testing.assert_array_equal(
            np.asarray(back[k], np.float32),
            np.sign(np.asarray(leaf, np.float32)))


def test_flatten_rejects_shape_drift():
    tree = _tree()
    plan = vp.build_plan(SHAPES, bucket_bytes=4)
    tree["layers.norm"] = jnp.zeros((4,), jnp.float32)
    with pytest.raises(ValueError, match="manifest"):
        vp.flatten_signs(plan, tree)


@pytest.mark.parametrize("codec", codecs.list_codecs())
def test_identity_under_every_codec_virtual(codec):
    """flatten -> bucket -> vote -> unflatten == the whole-buffer codec
    decode, for every codec and an uneven bucket cut (the deterministic
    twin of the hypothesis property)."""
    strategy = VoteStrategy.ALLGATHER_1BIT
    m, n = 9, 61
    signs = jnp.asarray(RNG.integers(-1, 2, size=(m, n)).astype(np.int8))
    plan = vp.build_plan({"x": (n,)}, bucket_bytes=4, strategy=strategy,
                         default_codec=codec)
    state = codecs.get_codec(codec).init_server_state(m)
    got, new_state = virtual_plan_vote(signs, plan, state)
    want, want_state = virtual_vote_codec(signs, strategy, codec, state)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for k in state:
        np.testing.assert_allclose(np.asarray(new_state[k]),
                                   np.asarray(want_state[k]), rtol=1e-6)


def test_weighted_multi_bucket_ema_matches_whole_buffer():
    """Weights are fixed for the step and the flip observations fold into
    ONE EMA update over the flat buffer's true coordinates, so any bucket
    cut produces the same decode AND the same new state."""
    m, n = 8, 100
    signs = jnp.asarray(np.where(RNG.integers(0, 2, size=(m, n)), 1, -1)
                        .astype(np.int8))
    ema = jnp.asarray(RNG.uniform(0.1, 0.6, size=(m,)).astype(np.float32))
    vote_ref, ema_ref = wv.decode_stacked(signs, ema)
    for bb in (2, 5, 13):
        plan = vp.build_plan({"x": (n,)}, bucket_bytes=bb,
                             strategy=VoteStrategy.ALLGATHER_1BIT,
                             default_codec="weighted_vote")
        vote, state = virtual_plan_vote(signs, plan, {"flip_ema": ema})
        np.testing.assert_array_equal(np.asarray(vote),
                                      np.asarray(vote_ref))
        np.testing.assert_allclose(np.asarray(state["flip_ema"]),
                                   np.asarray(ema_ref), rtol=1e-6)


def test_plan_vote_stacked_kernel_path_matches_virtual_walk():
    from repro.kernels import ops
    m, n = 7, 333
    stacked = jnp.asarray(RNG.normal(size=(m, n)).astype(np.float32))
    plan = vp.build_plan({"a": (128,), "b": (205,)}, bucket_bytes=8,
                         strategy=VoteStrategy.ALLGATHER_1BIT)
    ops.reset_launch_counts()
    got = vp.plan_vote_stacked(plan, stacked)
    assert ops.launch_counts()["fused_majority"] == plan.n_buckets
    want, _ = virtual_plan_vote(sc.sign_binary(stacked), plan, {})
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    jnp_path = vp.plan_vote_stacked(plan, stacked, use_kernels=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(jnp_path))


# ---------------------------------------------------------------------------
# overlapped (double-buffered) schedule executor (DESIGN.md §11): the
# issue/complete split reorders WHEN each bucket's exchange launches,
# never WHAT flows through it — votes, server state and the wire report
# must be bit-identical to the synchronous walk on BOTH backends
# ---------------------------------------------------------------------------


OVERLAP_MATRIX = [
    ("sign1bit", VoteStrategy.PSUM_INT8),
    ("sign1bit", VoteStrategy.ALLGATHER_1BIT),
    ("sign1bit", VoteStrategy.HIERARCHICAL),
    ("ternary2bit", VoteStrategy.PSUM_INT8),
    ("ternary2bit", VoteStrategy.ALLGATHER_1BIT),
    ("weighted_vote", VoteStrategy.ALLGATHER_1BIT),
]


def _wire_fields(wire):
    return (wire.n_voters, wire.payload_bytes, wire.n_messages,
            wire.strategy)


@pytest.mark.parametrize("codec,strategy", OVERLAP_MATRIX)
def test_overlap_equivalence_virtual(codec, strategy):
    from repro.core import vote_api as va
    m, n = 9, 261
    signs = jnp.asarray(RNG.integers(-1, 2, size=(m, n)).astype(np.int8))
    plan = vp.build_plan({"x": (n,)}, bucket_bytes=8, strategy=strategy,
                         default_codec=codec)
    assert plan.n_buckets > 1          # a 1-bucket pipeline proves nothing
    state = codecs.get_codec(codec).init_server_state(m)

    def run(ov):
        return va.VirtualBackend().execute(va.VoteRequest(
            payload=signs, form="stacked", plan=plan,
            server_state=state or None, overlap=ov))

    sync_o, ovl_o = run(False), run(True)
    np.testing.assert_array_equal(np.asarray(sync_o.votes),
                                  np.asarray(ovl_o.votes))
    assert sorted(sync_o.server_state) == sorted(ovl_o.server_state)
    for k in sync_o.server_state:
        np.testing.assert_array_equal(np.asarray(sync_o.server_state[k]),
                                      np.asarray(ovl_o.server_state[k]))
    assert _wire_fields(sync_o.wire) == _wire_fields(ovl_o.wire)


@pytest.mark.parametrize("codec,strategy", OVERLAP_MATRIX)
def test_overlap_equivalence_mesh(codec, strategy):
    """The mesh executor's double-buffered walk (real collective issue
    order) against its own synchronous walk AND the virtual twin, on the
    single-device M=1 mesh — the in-process slice of the tier-2 8-device
    guarantee."""
    from repro.core import vote_api as va
    n = 96
    x = jnp.asarray(RNG.normal(size=(1, n)).astype(np.float32))
    plan = vp.build_plan({"x": (n,)}, bucket_bytes=4, strategy=strategy,
                         default_codec=codec)
    assert plan.n_buckets > 1
    state = codecs.get_codec(codec).init_server_state(1)

    def run(backend, ov):
        return backend.execute(va.VoteRequest(
            payload=x, form="stacked", plan=plan,
            server_state=state or None, overlap=ov))

    m_sync = run(va.MeshBackend(), False)
    m_ovl = run(va.MeshBackend(), True)
    v_ovl = run(va.VirtualBackend(), True)
    np.testing.assert_array_equal(np.asarray(m_sync.votes),
                                  np.asarray(m_ovl.votes))
    np.testing.assert_array_equal(np.asarray(m_ovl.votes),
                                  np.asarray(v_ovl.votes))
    for k in m_sync.server_state:
        np.testing.assert_array_equal(np.asarray(m_sync.server_state[k]),
                                      np.asarray(m_ovl.server_state[k]))
    assert _wire_fields(m_sync.wire) == _wire_fields(m_ovl.wire)


# ---------------------------------------------------------------------------
# optimizer plan path (single-process; the mesh twin lives in
# tests/distributed_harness.py)
# ---------------------------------------------------------------------------


def _opt_cfg(**kw):
    return OptimizerConfig(kind="signum_vote", learning_rate=0.05, **kw)


def test_optimizer_plan_path_matches_leafwise_exactly():
    params = _tree()
    grads = _tree()
    legacy = build_optimizer(_opt_cfg(), ())
    plan = vp.build_plan(SHAPES, bucket_bytes=8)
    planned = build_optimizer(_opt_cfg(bucket_bytes=8), (), plan=plan)
    s0, s1 = legacy.init(params), planned.init(params)
    p0, s0, _ = legacy.update(grads, s0, params, jnp.int32(0))
    p1, s1, _ = planned.update(grads, s1, params, jnp.int32(0))
    for k in params:
        np.testing.assert_array_equal(np.asarray(p0[k]), np.asarray(p1[k]))
        np.testing.assert_array_equal(np.asarray(s0["momentum"][k]),
                                      np.asarray(s1["momentum"][k]))


def test_optimizer_plan_ef_subset_state():
    plan = vp.build_plan(SHAPES, bucket_bytes=8,
                         codec_map=(("embed*", "ef_sign"),))
    assert plan.worker_state_leaves == ("embed.table",)
    opt = build_optimizer(_opt_cfg(bucket_bytes=8,
                                   codec_map=(("embed*", "ef_sign"),)),
                          (), plan=plan)
    params = _tree()
    state = opt.init(params)
    assert sorted(state["error"]) == ["embed.table"]
    p1, state, _ = opt.update(_tree(), state, params, jnp.int32(0))
    # the residual moved for the EF leaf and only exists there
    assert sorted(state["error"]) == ["embed.table"]
    assert float(jnp.sum(jnp.abs(state["error"]["embed.table"]))) > 0


def test_codec_map_without_bucket_bytes_is_rejected():
    # the map rides the plan wire only: accepting it with the plan
    # disabled would silently train every leaf on the default codec
    with pytest.raises(ValueError, match="bucket_bytes > 0"):
        OptimizerConfig(codec_map=(("embed*", "ternary2bit"),))
    OptimizerConfig(codec_map=(("embed*", "ternary2bit"),),
                    bucket_bytes=4096)   # the valid spelling


def test_plan_vote_stacked_rejects_non_gathered_wires():
    stacked = jnp.asarray(RNG.normal(size=(4, 64)).astype(np.float32))
    psum_plan = vp.build_plan({"a": (64,)}, bucket_bytes=8,
                              strategy=VoteStrategy.PSUM_INT8)
    with pytest.raises(ValueError, match="gathered 1-bit wire"):
        vp.plan_vote_stacked(psum_plan, stacked)
    w_plan = vp.build_plan({"a": (64,)}, bucket_bytes=8,
                           strategy=VoteStrategy.ALLGATHER_1BIT,
                           default_codec="weighted_vote")
    with pytest.raises(ValueError, match="server-state"):
        vp.plan_vote_stacked(w_plan, stacked)


def test_optimizer_plan_ef_requires_mode_a():
    from repro.configs.base import MomentumMode
    plan = vp.build_plan(SHAPES, bucket_bytes=8,
                         codec_map=(("*", "ef_sign"),))
    with pytest.raises(ValueError, match="per_worker"):
        build_optimizer(_opt_cfg(bucket_bytes=8,
                                 codec_map=(("*", "ef_sign"),),
                                 momentum_mode=MomentumMode.GLOBAL),
                        (), plan=plan)


def test_optimizer_overlap_matches_sync_exactly():
    """OptimizerConfig.overlap only reorders the bucket walk's issue
    order — one optimizer step must stay bitwise identical."""
    params, grads = _tree(), _tree()
    plan = vp.build_plan(SHAPES, bucket_bytes=8)
    sync = build_optimizer(_opt_cfg(bucket_bytes=8), (), plan=plan)
    ovl = build_optimizer(_opt_cfg(bucket_bytes=8, overlap=True), (),
                          plan=plan)
    s0, s1 = sync.init(params), ovl.init(params)
    p0, s0, _ = sync.update(grads, s0, params, jnp.int32(0))
    p1, s1, _ = ovl.update(grads, s1, params, jnp.int32(0))
    for k in params:
        np.testing.assert_array_equal(np.asarray(p0[k]), np.asarray(p1[k]))
        np.testing.assert_array_equal(np.asarray(s0["momentum"][k]),
                                      np.asarray(s1["momentum"][k]))


def test_optimizer_delayed_vote_lags_exactly_one_step():
    """delayed_vote banks step t's majority and applies it at t+1: step 0
    moves nothing (zero buffer = abstain everywhere), and after step t+1
    the delayed iterate equals the synchronous iterate after step t
    (weight decay off isolates the vote lag)."""
    params = _tree()
    g1, g2 = _tree(), _tree()
    sync = build_optimizer(_opt_cfg(), ())
    delayed = build_optimizer(_opt_cfg(delayed_vote=True), ())
    ss, sd = sync.init(params), delayed.init(params)
    assert sorted(sd["delayed"]) == sorted(SHAPES)
    assert all(np.asarray(v).dtype == np.int8 and not np.asarray(v).any()
               for v in sd["delayed"].values())
    ps1, ss, _ = sync.update(g1, ss, params, jnp.int32(0))
    pd1, sd, _ = delayed.update(g1, sd, params, jnp.int32(0))
    for k in params:
        # step 0: buffer of zeros, parameters hold still ...
        np.testing.assert_array_equal(np.asarray(pd1[k]),
                                      np.asarray(params[k]))
        # ... but momentum never lags — only the parameter update does
        np.testing.assert_array_equal(np.asarray(sd["momentum"][k]),
                                      np.asarray(ss["momentum"][k]))
    pd2, sd, _ = delayed.update(g2, sd, params, jnp.int32(1))
    for k in params:                       # step 1 applies step 0's vote
        np.testing.assert_array_equal(np.asarray(pd2[k]),
                                      np.asarray(ps1[k]))


def test_delayed_vote_config_validation():
    from repro.configs.base import MomentumMode
    with pytest.raises(ValueError, match="no vote"):
        OptimizerConfig(kind="sgd", learning_rate=0.1, delayed_vote=True)
    with pytest.raises(ValueError, match="per_worker"):
        _opt_cfg(delayed_vote=True, momentum_mode=MomentumMode.GLOBAL)
    with pytest.raises(ValueError, match="overlap"):
        _opt_cfg(overlap=True)             # overlap without a plan
    _opt_cfg(overlap=True, bucket_bytes=vp.AUTO_BUCKET_BYTES)  # ok


# ---------------------------------------------------------------------------
# checkpoint round-trip of bucketed plan state (§6/§9)
# ---------------------------------------------------------------------------


def test_refit_tree_leading_axis():
    from repro.checkpoint.checkpoint import refit_tree_leading_axis
    tree = {"error": {"a": np.ones((8, 3)), "b": np.ones((8, 2))},
            "codec": {"flip_ema": np.arange(8, dtype=np.float32)}}
    want = {"error": {"a": (6, 3), "b": (6, 2)}, "codec": {"flip_ema": (6,)}}
    out = refit_tree_leading_axis(tree, want)
    assert out["error"]["a"].shape == (6, 3)
    np.testing.assert_array_equal(out["codec"]["flip_ema"],
                                  np.arange(6, dtype=np.float32))
    grown = refit_tree_leading_axis(out, {"error": {"a": (9, 3),
                                                    "b": (9, 2)},
                                          "codec": {"flip_ema": (9,)}})
    assert grown["codec"]["flip_ema"][6:].tolist() == [0.0, 0.0, 0.0]
    with pytest.raises(ValueError, match="structure mismatch"):
        refit_tree_leading_axis(tree, {"error": {"a": (6, 3)}})


def test_checkpoint_roundtrip_of_bucketed_plan_state(tmp_path):
    """Save a plan-configured optimizer state (per-worker EF residual for
    the mapped leaves + replicated flip-EMA), restore under a SMALLER
    voter set: every per-worker buffer refits by the §6 rule, bit-exact
    for the survivors, zero (the uninformed prior) for joiners."""
    from repro.checkpoint import checkpoint as ckpt
    m_old, m_new = 8, 6
    shapes = {"embed.table": (4, 3), "layers.w": (5,)}
    opt_state = {
        "count": np.asarray(7, np.int32),
        "momentum": {k: RNG.normal(size=(m_old,) + s).astype(np.float32)
                     for k, s in shapes.items()},
        "error": {"embed.table":
                  RNG.normal(size=(m_old, 4, 3)).astype(np.float32)},
        "codec": {"flip_ema":
                  RNG.uniform(0, 1, size=(m_old,)).astype(np.float32)},
    }
    params = {k: RNG.normal(size=s).astype(np.float32)
              for k, s in shapes.items()}
    ckpt.save(str(tmp_path), 7, params, opt_state)
    like_opt = {
        "count": jax.ShapeDtypeStruct((), jnp.int32),
        "momentum": {k: jax.ShapeDtypeStruct((m_new,) + s, jnp.float32)
                     for k, s in shapes.items()},
        "error": {"embed.table":
                  jax.ShapeDtypeStruct((m_new, 4, 3), jnp.float32)},
        "codec": {"flip_ema":
                  jax.ShapeDtypeStruct((m_new,), jnp.float32)},
    }
    _, opt_back, _, _ = ckpt.restore(str(tmp_path), like_opt=like_opt)
    np.testing.assert_array_equal(
        opt_back["error"]["embed.table"],
        opt_state["error"]["embed.table"][:m_new])
    np.testing.assert_array_equal(opt_back["codec"]["flip_ema"],
                                  opt_state["codec"]["flip_ema"][:m_new])
    # regrow: joiners at zero residual / uninformed prior
    like_opt9 = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((9,) + s.shape[1:], s.dtype)
        if s.shape and s.shape[0] == m_new else s, like_opt)
    _, opt9, _, _ = ckpt.restore(str(tmp_path), like_opt=like_opt9)
    assert opt9["codec"]["flip_ema"].shape == (9,)
    np.testing.assert_array_equal(opt9["codec"]["flip_ema"][8:], [0.0])
    np.testing.assert_array_equal(opt9["error"]["embed.table"][8:], 0.0)
