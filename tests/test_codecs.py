"""Gradient Codec subsystem acceptance tests (deterministic, tier-1;
hypothesis twins live in test_codec_properties.py).

Covers: the pack_signs shape guard (a ValueError, not an -O-erasable
assert), the ternary 2-bit wire format (roundtrip, tie/abstain semantics,
Pallas kernel vs jnp oracle), the codec registry and strategy validation,
the sign1bit fixed point (codec API == pre-codec wire path, bit for bit),
EF encode/feedback round-trips and accumulation, the weighted decode
(equal weights == unweighted majority; learned weights decode through
adversarial majorities; flip-rate estimates separate honest from
adversarial), codec-aware AUTO selection, and codec state surviving the
checkpoint elastic-refit rule beside the momentum.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import refit_leading_axis
from repro.configs.base import OptimizerConfig, VoteStrategy
from repro.core import codecs, sign_compress as sc
from repro.core.codecs import weighted as wv
from repro.core.vote_engine import select_strategy
from repro.kernels import ops, ref
from repro.sim import virtual_vote, virtual_vote_codec

RNG = np.random.default_rng(7)
STRATS = (VoteStrategy.PSUM_INT8, VoteStrategy.ALLGATHER_1BIT,
          VoteStrategy.HIERARCHICAL)


def _signs(m, n, ternary=True):
    lo = -1 if ternary else 0
    s = RNG.integers(lo, 2, size=(m, n)).astype(np.int8)
    if not ternary:
        s = np.where(s == 0, -1, 1).astype(np.int8)
    return s


# ---------------------------------------------------------------------------
# satellite: pack_signs shape guard (survives python -O)
# ---------------------------------------------------------------------------


def test_pack_signs_rejects_misaligned_shape_with_message():
    with pytest.raises(ValueError, match=r"\(3, 33\)"):
        sc.pack_signs(jnp.zeros((3, 33)))
    # the sanctioned routes: 1-D pad_to_pack and N-D pad_last
    padded, n = sc.pad_to_pack(jnp.ones((33,)))
    assert n == 33 and sc.pack_signs(padded).shape == (2,)
    padded, n = sc.pad_last(jnp.ones((3, 33)), sc.PACK)
    assert n == 33 and sc.pack_signs(padded).shape == (3, 2)


def test_pack_ternary_rejects_misaligned_shape_with_message():
    with pytest.raises(ValueError, match=r"\(9,\)"):
        sc.pack_ternary(jnp.zeros((9,), jnp.int8))


def test_pack_conventions_disagree_exactly_on_zero():
    """The 1-bit pack binarises (sign_binary: 0 -> +1); the 2-bit pack
    keeps the ternary convention (0 -> abstain) — the two wire formats'
    defining difference (DESIGN.md §5/§8)."""
    x = jnp.asarray([1.0, -1.0, 0.0, -2.0] * 8)           # 32 values
    b = sc.unpack_signs(sc.pack_signs(x))[: 4]
    np.testing.assert_array_equal(np.asarray(b), [1, -1, 1, -1])
    t = sc.unpack_ternary(sc.pack_ternary(sc.sign_ternary(x)[:32]))[:4]
    np.testing.assert_array_equal(np.asarray(t), [1, -1, 0, -1])


# ---------------------------------------------------------------------------
# ternary 2-bit wire format
# ---------------------------------------------------------------------------


def test_ternary_roundtrip_deterministic():
    s = _signs(5, 64)
    back = np.asarray(sc.unpack_ternary(sc.pack_ternary(jnp.asarray(s))))
    np.testing.assert_array_equal(back, s)


def test_ternary_majority_ties_and_abstentions_yield_zero():
    s = np.zeros((4, 16), np.int8)
    s[:2, 0], s[2:, 0] = 1, -1          # exact tie -> 0
    s[:, 1] = 0                          # unanimous abstention -> 0
    s[:3, 2], s[3, 2] = 1, -1            # 3 v 1 -> +1
    s[0, 3] = -1                         # single vote among abstainers -> -1
    maj = np.asarray(sc.unpack_ternary(
        sc.ternary_majority(sc.pack_ternary(jnp.asarray(s)))))
    np.testing.assert_array_equal(maj[:4], [0, 0, 1, -1])


@pytest.mark.parametrize("m,n", [(1, 16), (4, 100), (9, 5000)])
def test_ternary_kernels_match_oracle(m, n):
    """Pallas ternary pack + tally == the sign_compress jnp oracles."""
    s = _signs(m, n)
    flat = s[0]
    got_p = np.asarray(ops.ternary_pack(jnp.asarray(flat)))
    pad = (-n) % sc.PACK2
    want_p = np.asarray(ref.ternary_pack(
        jnp.asarray(np.pad(flat, (0, pad))[None]))[0])
    np.testing.assert_array_equal(got_p, want_p)
    packed = np.stack([np.asarray(sc.pack_ternary(jnp.asarray(
        np.pad(r, (0, pad))))) for r in s])
    got_m = np.asarray(ops.ternary_majority(jnp.asarray(packed)))
    want_m = np.asarray(ref.ternary_majority(jnp.asarray(packed)))
    np.testing.assert_array_equal(got_m, want_m)
    # and the decoded majority is the sign of the symbol sum
    dec = np.asarray(sc.unpack_ternary(jnp.asarray(want_m)))[:n]
    np.testing.assert_array_equal(dec, np.sign(s.astype(np.int32).sum(0)))


# ---------------------------------------------------------------------------
# registry / config plumbing
# ---------------------------------------------------------------------------


def test_registry_and_validation():
    assert codecs.list_codecs() == ("ef_sign", "sign1bit", "ternary2bit",
                                    "weighted_vote")
    with pytest.raises(ValueError, match="unknown codec"):
        codecs.get_codec("morse")
    with pytest.raises(ValueError, match="cannot ride"):
        codecs.get_codec("weighted_vote").validate_strategy(
            VoteStrategy.PSUM_INT8)
    with pytest.raises(ValueError, match="cannot ride"):
        codecs.get_codec("ternary2bit").validate_strategy(
            VoteStrategy.HIERARCHICAL)
    # tie conventions: codec overrides the wire's where it carries abstain
    assert codecs.get_codec("ternary2bit").ties(
        VoteStrategy.ALLGATHER_1BIT) == "zero"
    assert codecs.get_codec("weighted_vote").ties(
        VoteStrategy.ALLGATHER_1BIT) == "plus_one"
    assert codecs.get_codec("sign1bit").ties(
        VoteStrategy.ALLGATHER_1BIT) == "plus_one"
    assert codecs.get_codec("sign1bit").ties(
        VoteStrategy.PSUM_INT8) == "zero"


def test_resolved_codec_maps_legacy_error_feedback_flag():
    assert OptimizerConfig().resolved_codec == "sign1bit"
    assert OptimizerConfig(error_feedback=True).resolved_codec == "ef_sign"
    assert OptimizerConfig(codec="ternary2bit").resolved_codec \
        == "ternary2bit"
    # redundant but consistent spelling
    assert OptimizerConfig(codec="ef_sign",
                           error_feedback=True).resolved_codec == "ef_sign"
    # the legacy flag combined with a residual-free codec is a config
    # error, never a silent drop of error feedback
    with pytest.raises(ValueError, match="conflicts with codec"):
        OptimizerConfig(codec="weighted_vote",
                        error_feedback=True).resolved_codec


def test_auto_selector_is_codec_aware():
    n = 1 << 30
    # sign1bit keeps the legacy selection exactly
    assert select_strategy(n, 16) == select_strategy(n, 16, codec="sign1bit")
    # weighted can only ride the gathered wire
    assert select_strategy(n, 16, codec="weighted_vote") \
        == VoteStrategy.ALLGATHER_1BIT
    assert select_strategy(n, 1, codec="weighted_vote") \
        == VoteStrategy.ALLGATHER_1BIT
    # ternary never resolves to hierarchical (1-bit rebroadcast would
    # destroy abstention), and its 2x gathered payload tips the balance
    # to psum at bandwidth scale
    for data in (2, 8, 16, 64):
        s = select_strategy(n, data, codec="ternary2bit")
        assert s in codecs.get_codec("ternary2bit").supported_strategies


# ---------------------------------------------------------------------------
# sign1bit is a fixed point of the refactor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", STRATS)
def test_sign1bit_codec_path_bit_identical_to_plain_vote(strategy):
    signs = jnp.asarray(_signs(8, 130))
    want = np.asarray(virtual_vote(signs, strategy))
    got, state = virtual_vote_codec(signs, strategy, "sign1bit")
    np.testing.assert_array_equal(np.asarray(got), want)
    assert state == {}


def test_ternary_over_psum_is_bit_identical_to_sign1bit():
    """Ternary symbols ARE the counts psum sums: over that wire the codec
    changes nothing, so the digests must agree bit for bit."""
    signs = jnp.asarray(_signs(8, 100))
    a, _ = virtual_vote_codec(signs, VoteStrategy.PSUM_INT8, "sign1bit")
    b, _ = virtual_vote_codec(signs, VoteStrategy.PSUM_INT8, "ternary2bit")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ternary_allgather_keeps_abstention_the_1bit_wire_loses():
    """The defining divergence: an abstaining coordinate stays 0 on the
    2-bit wire but binarises to +1 on the 1-bit wire."""
    signs = np.zeros((4, 32), np.int8)          # everyone abstains
    one, _ = virtual_vote_codec(jnp.asarray(signs),
                                VoteStrategy.ALLGATHER_1BIT, "sign1bit")
    two, _ = virtual_vote_codec(jnp.asarray(signs),
                                VoteStrategy.ALLGATHER_1BIT, "ternary2bit")
    assert np.asarray(one).tolist() == [1] * 32
    assert np.asarray(two).tolist() == [0] * 32


# ---------------------------------------------------------------------------
# EF codec
# ---------------------------------------------------------------------------


def test_ef_encode_feedback_roundtrip():
    """feedback returns t - scale*vote, so encode(next) rebuilds exactly
    t + v_next - scale*vote — the residual re-enters in full."""
    c = codecs.get_codec("ef_sign")
    v = jnp.asarray([0.1, -0.2, 0.3, -0.4])
    e0 = c.init_state(v)
    t = c.encode_leaf(v, e0)
    np.testing.assert_allclose(np.asarray(t), np.asarray(v))
    vote = jnp.sign(t)
    e1 = c.feedback_leaf(t, vote, e0)
    want = np.asarray(t) - np.mean(np.abs(np.asarray(t))) \
        * np.sign(np.asarray(t))
    np.testing.assert_allclose(np.asarray(e1), want, rtol=1e-6)
    t2 = c.encode_leaf(v, e1)
    np.testing.assert_allclose(np.asarray(t2), want + np.asarray(v),
                               rtol=1e-6)


def test_ef_memory_accumulates_suppressed_coordinate():
    """A coordinate whose magnitude is far below the mean loses every
    round to the scale — its residual grows until its sign still gets
    through; with a vote that keeps disagreeing, the memory keeps
    growing instead of being silently dropped (the EF guarantee)."""
    c = codecs.get_codec("ef_sign")
    v = jnp.asarray([1e-3, 1.0, -1.0, 1.0])
    e = c.init_state(v)
    hostile = jnp.asarray([-1.0, 1.0, -1.0, 1.0])   # vote against coord 0
    mags = []
    for _ in range(5):
        t = c.encode_leaf(v, e)
        e = c.feedback_leaf(t, hostile, e)
        mags.append(float(e[0]))
    assert all(b > a for a, b in zip(mags, mags[1:])), mags


def test_ef_requires_mode_a():
    """Mode B has no worker-side encode input for a residual to fold
    into — requesting EF there is a config error, never a silent
    sign1bit run with a dead error tree."""
    from repro.configs.base import MomentumMode
    from repro.core.signum import build_optimizer
    cfg = OptimizerConfig(kind="signsgd_vote", codec="ef_sign",
                          momentum_mode=MomentumMode.GLOBAL)
    with pytest.raises(ValueError, match="per_worker"):
        build_optimizer(cfg, axes=())


def test_trainer_ef_state_matches_codec_math():
    """The optimizer's "error" state is the codec's feedback output (the
    legacy error_feedback flag routes through the codec layer)."""
    from repro.core.signum import build_optimizer
    cfg = OptimizerConfig(kind="signum_vote", momentum=0.0,
                          learning_rate=0.1, codec="ef_sign")
    opt = build_optimizer(cfg, axes=())
    p = {"w": jnp.zeros((4,))}
    state = opt.init(p)
    assert "error" in state
    g = {"w": jnp.asarray([0.1, -0.2, 0.3, -0.4])}
    _, state, _ = opt.update(g, state, p, jnp.int32(0))
    c = codecs.get_codec("ef_sign")
    t = g["w"]
    want = c.feedback_leaf(t, jnp.sign(t), None)
    np.testing.assert_allclose(np.asarray(state["error"]["w"]),
                               np.asarray(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# weighted codec
# ---------------------------------------------------------------------------


def test_weighted_equal_state_is_unweighted_majority():
    """With any equal flip_ema (the all-zero prior included) the weights
    are equal and the decode == allgather_1bit's majority, bit for bit —
    exact ties included (weighted sum 0 -> +1)."""
    signs = _signs(8, 200, ternary=False)
    signs[:4, :8], signs[4:, :8] = 1, -1        # engineered exact ties
    want = np.asarray(virtual_vote(jnp.asarray(signs),
                                   VoteStrategy.ALLGATHER_1BIT))
    for prior in (0.0, 0.3):
        vote, new = wv.decode_stacked(
            jnp.asarray(signs), jnp.full((8,), prior, jnp.float32))
        np.testing.assert_array_equal(np.asarray(vote)[:200], want)
    assert np.asarray(new).shape == (8,)


def test_weighted_decode_survives_learned_adversarial_majority():
    """The SignSGD-FD headline: with flip rates already learned, the
    decode recovers the honest direction even when 5 of 8 workers flip —
    a regime where the unweighted majority is wrong on every coordinate."""
    truth = np.where(RNG.integers(0, 2, 64) == 1, 1, -1).astype(np.int8)
    signs = np.tile(truth, (8, 1))
    signs[:5] *= -1                              # 5/8 adversarial majority
    plain = np.asarray(virtual_vote(jnp.asarray(signs),
                                    VoteStrategy.ALLGATHER_1BIT))
    np.testing.assert_array_equal(plain, -truth)  # majority IS the attack
    ema = jnp.asarray([0.95] * 5 + [0.05] * 3, jnp.float32)
    vote, _ = wv.decode_stacked(jnp.asarray(signs), ema)
    np.testing.assert_array_equal(np.asarray(vote), truth)


def test_weighted_ema_not_diluted_by_padding_lanes():
    """Regression: flip-rate observations must be measured on the true
    coordinates only. Bit-pack padding lanes always agree with the vote,
    so counting them scaled every disagreement by n/32w — at dim 100 a
    perfect flipper's observed rate came out 0.78x the truth."""
    from repro.configs.base import VoteStrategy
    from repro.sim import virtual_vote_codec
    n = 100                                     # 128 packed lanes
    truth = np.where(RNG.integers(0, 2, n) == 1, 1, -1).astype(np.int8)
    signs = np.tile(truth, (8, 1))
    signs[0] *= -1                              # one perfect flipper
    state = {"flip_ema": jnp.zeros((8,), jnp.float32)}
    _, new = virtual_vote_codec(jnp.asarray(signs),
                                VoteStrategy.ALLGATHER_1BIT,
                                "weighted_vote", state)
    ema = np.asarray(new["flip_ema"])
    np.testing.assert_allclose(ema[0], wv.RHO * 1.0, rtol=1e-6)
    np.testing.assert_allclose(ema[1:], 0.0, atol=1e-7)


def test_weighted_ema_separates_adversaries_from_honest():
    """Running the decode a few steps from the uninformed prior, constant
    sign-flippers accumulate a higher flip estimate than honest voters
    (while the honest majority holds, Theorem 2's regime)."""
    truth = np.where(RNG.integers(0, 2, 256) == 1, 1, -1).astype(np.int8)
    ema = jnp.zeros((8,), jnp.float32)
    for _ in range(6):
        signs = np.tile(truth, (8, 1))
        signs[:3] *= -1                          # 3/8 flippers
        _, ema = wv.decode_stacked(jnp.asarray(signs), ema)
    ema = np.asarray(ema)
    assert ema[:3].min() > 0.8 and ema[3:].max() < 0.2, ema
    # ...and by then the adversaries' weights are negative (inverted)
    w = np.asarray(wv.reliability_weights(jnp.asarray(ema)))
    assert (w[:3] < 0).all() and (w[3:] > 0).all()


# ---------------------------------------------------------------------------
# codec state beside the momentum: checkpoint elastic refit (§6)
# ---------------------------------------------------------------------------


def test_codec_state_survives_refit_leading_axis():
    """EF residual (per-worker, momentum-shaped) and the weighted codec's
    flip_ema refit by the same truncate-or-zero-pad rule as Mode A
    momentum: shrink keeps the survivors' memory, growth admits joiners
    at the zero prior."""
    err = RNG.normal(size=(8, 16)).astype(np.float32)
    down = refit_leading_axis(err, (5, 16))
    np.testing.assert_array_equal(down, err[:5])
    up = refit_leading_axis(down, (8, 16))
    np.testing.assert_array_equal(up[:5], err[:5])
    np.testing.assert_array_equal(up[5:], 0.0)

    ema = np.asarray([0.9, 0.8, 0.1, 0.2], np.float32)
    grown = refit_leading_axis(ema, (6,))
    np.testing.assert_array_equal(grown[:4], ema)
    np.testing.assert_array_equal(grown[4:], 0.0)  # uninformed prior
    # the zero prior decodes exactly like every other equal prior
    s = jnp.asarray(_signs(6, 64, ternary=False))
    v0, _ = wv.decode_stacked(s, jnp.zeros((6,), jnp.float32))
    v3, _ = wv.decode_stacked(s, jnp.full((6,), 0.3, jnp.float32))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v3))


def test_trainer_weighted_state_lives_beside_momentum():
    """abstract_state exposes the codec server state with the momentum —
    the shape checkpoint.restore would refit on elastic rescale."""
    from repro.configs.base import TrainConfig, get_config, reduced_config
    from repro.train import train_step as TS
    cfg = reduced_config(get_config("glm4-9b"), num_layers=1)
    tcfg = TrainConfig(
        global_batch=4, seq_len=16,
        optimizer=OptimizerConfig(kind="signum_vote", codec="weighted_vote",
                                  vote_strategy=VoteStrategy.AUTO))
    art = TS.make_train_step(cfg, tcfg, mesh=None)
    assert art.codec == "weighted_vote"
    assert art.vote_strategy == VoteStrategy.ALLGATHER_1BIT
    _, opt_abs = TS.abstract_state(cfg, tcfg, art)
    assert set(opt_abs) >= {"momentum", "codec"}
    assert opt_abs["codec"]["flip_ema"].shape == (art.n_vote_replicas,)

    tcfg_ef = dataclasses.replace(
        tcfg, optimizer=OptimizerConfig(kind="signum_vote",
                                        codec="ef_sign"))
    art_ef = TS.make_train_step(cfg, tcfg_ef, mesh=None)
    _, opt_ef = TS.abstract_state(cfg, tcfg_ef, art_ef)
    assert set(opt_ef) >= {"momentum", "error"}
    for k, leaf in opt_ef["error"].items():
        assert leaf.shape == opt_ef["momentum"][k].shape
