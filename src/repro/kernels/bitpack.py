"""Pallas TPU kernels: sign bit-packing / unpacking (32 signs per uint32).

The TPU adaptation of the paper's CUDA bit-pack: data is tiled into VMEM
as (ROWS, 32*WORDS) blocks — the trailing dim a multiple of 128 lanes —
and each block packs along the lane dimension with an unrolled shift/OR
tree over the 32 sub-lanes of each output word. The MXU is not involved;
this is pure VPU bit arithmetic, bandwidth-bound by design (1 read of the
sign source, 1/32-size write).

Block shapes: input (8, 4096) fp32/bf16 -> output (8, 128) uint32, i.e.
one (8,128) output register tile per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK = 32
ROWS = 8
WORDS = 128  # output lane dim; input lane dim = 32*128 = 4096


def _bitpack_kernel(x_ref, out_ref):
    x = x_ref[...]                                   # (ROWS, WORDS*32)
    bits = (x >= 0).astype(jnp.uint32)
    bits = bits.reshape(x.shape[0], x.shape[1] // PACK, PACK)
    acc = jnp.zeros(bits.shape[:2], jnp.uint32)
    for j in range(PACK):                            # unrolled shift/OR tree
        acc = acc | (bits[:, :, j] << jnp.uint32(j))
    out_ref[...] = acc


def _bitunpack_kernel(p_ref, out_ref, *, dtype):
    p = p_ref[...]                                   # (ROWS, WORDS)
    cols = []
    for j in range(PACK):
        bit = (p >> jnp.uint32(j)) & jnp.uint32(1)
        cols.append(jnp.where(bit == 1, 1, -1).astype(dtype))
    out = jnp.stack(cols, axis=-1)                   # (ROWS, WORDS, 32)
    out_ref[...] = out.reshape(p.shape[0], p.shape[1] * PACK)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitpack_2d(x: jax.Array, *, interpret: bool = False) -> jax.Array:
    """x (rows, 32*w) with rows % 8 == 0, w % 128 == 0 -> (rows, w) uint32."""
    rows, n = x.shape
    w = n // PACK
    grid = (rows // ROWS, w // WORDS)
    return pl.pallas_call(
        _bitpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, WORDS * PACK),
                               lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((ROWS, WORDS), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, w), jnp.uint32),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("dtype", "interpret"))
def bitunpack_2d(p: jax.Array, dtype=jnp.float32, *,
                 interpret: bool = False) -> jax.Array:
    """p (rows, w) uint32 -> (rows, 32*w) ±1 in `dtype`."""
    rows, w = p.shape
    grid = (rows // ROWS, w // WORDS)
    return pl.pallas_call(
        functools.partial(_bitunpack_kernel, dtype=dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, WORDS), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((ROWS, WORDS * PACK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, w * PACK), dtype),
        interpret=interpret,
    )(p)
