"""Mamba2 / SSD (state-space duality) block, chunked-scan formulation.

Follows the SSD algorithm of Dao & Gu (arXiv:2405.21060): the sequence is
split into chunks; within a chunk the output is computed with a masked
quadratic (attention-like) term, across chunks a linear recurrence carries
the (H, P, N) state. Single B/C group (as mamba2-2.7b).

Train path: ``mamba2_forward`` (B,S,d) -> (B,S,d).
Decode path: ``mamba2_decode_step`` carries {ssm (B,H,P,N), conv (B,W-1,CD)}.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import BATCH, shard
from repro.models.layers import rms_norm


def _segsum(x: jax.Array) -> jax.Array:
    """x (..., S) -> (..., S, S) with out[..., i, j] = sum_{j < k <= i} x_k,
    -inf above the diagonal (standard SSD helper)."""
    S = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((S, S), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B,S,C), w (W,C), b (C,)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return out + b


def _project(p, prefix: str, x: jax.Array) -> Tuple[jax.Array, ...]:
    """Three aligned projections (z | xBC | dt) — each output dim is a
    multiple of the model axis, so TP sharding flows without resharding."""
    z = x @ p[f"{prefix}_zproj"]
    xBC = x @ p[f"{prefix}_xbcproj"]
    dt = x @ p[f"{prefix}_dtproj"]
    return z, xBC, dt


def mamba2_forward(p: Dict[str, jax.Array], x_in: jax.Array, cfg,
                   prefix: str = "mamba") -> jax.Array:
    """One Mamba2 mixer (no residual). x_in (B,S,d) -> (B,S,d)."""
    s = cfg.ssm
    B, S, d = x_in.shape
    di, N, nh, P = s.d_inner(d), s.state_dim, s.n_heads(d), s.head_dim
    cs = min(s.chunk_size, S)
    while S % cs:
        cs //= 2
    nc = S // cs

    z, xBC, dt = _project(p, prefix, x_in)
    xBC = jax.nn.silu(
        causal_conv1d(xBC, p[f"{prefix}_conv_w"], p[f"{prefix}_conv_b"]))
    x, B_, C_ = jnp.split(xBC, [di, di + N], axis=-1)
    x = shard(x, BATCH, None, "model")

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p[f"{prefix}_dt_bias"])
    A = -jnp.exp(p[f"{prefix}_A_log"].astype(jnp.float32))     # (nh,)

    # Big (B,S,d_inner)-sized tensors stay bf16 (activation dtype); decay /
    # cumsum / state-recurrence math stays fp32 (small: (b,s,h) and
    # (b,nc,h,p,n)). This halves the dominant SSD temporaries.
    cdt = x_in.dtype
    xh = x.reshape(B, nc, cs, nh, P).astype(cdt)
    xh = shard(xh, BATCH, None, None, "model", None)
    Bc = B_.reshape(B, nc, cs, N).astype(cdt)
    Cc = C_.reshape(B, nc, cs, N).astype(cdt)
    dtc = dt.reshape(B, nc, cs, nh)                            # (b,c,l,h) f32
    dtc = shard(dtc, BATCH, None, None, "model")
    dA = dtc * A                                               # (b,c,l,h)
    dA_cs = jnp.cumsum(dA, axis=2)                             # (b,c,l,h)
    xdt = xh * dtc[..., None].astype(cdt)                      # x * dt

    # ---- intra-chunk (quadratic) term ----
    # L is the big intermediate: (b,c,h,l,l) — heads on 'model', bf16
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2))).astype(cdt)  # (b,c,h,l,l)
    L = shard(L, BATCH, None, "model", None, None)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)             # (b,c,l,s)
    Y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp",
                        scores, L, xdt)

    # ---- chunk states and inter-chunk recurrence (fp32) ----
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)        # (b,c,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc,
                        decay_states.astype(cdt), xdt,
                        preferred_element_type=jnp.float32)
    states = shard(states, BATCH, None, "model", None, None)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                  # (b,c,h)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                                       # emit state *entering* chunk

    init = jnp.zeros((B, nh, P, N), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)               # (b,c,h,p,n)

    state_decay = jnp.exp(dA_cs)                                # (b,c,l,h)
    Y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc,
                       prev_states.astype(cdt),
                       state_decay.astype(cdt))

    Y = (Y_diag + Y_off).reshape(B, S, nh, P)
    Y = Y + xh.reshape(B, S, nh, P) * p[f"{prefix}_D"].astype(cdt)[:, None]
    Y = Y.reshape(B, S, di)

    # gated RMSNorm then output projection
    Y = Y * jax.nn.silu(z).astype(cdt)
    Y = rms_norm(Y, p[f"{prefix}_norm_scale"], cfg.norm_eps)
    return Y @ p[f"{prefix}_out_proj"]


# ---------------------------------------------------------------------------
# decode (single-token) path
# ---------------------------------------------------------------------------


def mamba2_init_state(cfg, batch: int, dtype=jnp.float32
                      ) -> Dict[str, jax.Array]:
    s = cfg.ssm
    d = cfg.d_model
    return {
        "ssm": jnp.zeros((batch, s.n_heads(d), s.head_dim, s.state_dim),
                         jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, s.conv_dim(d)), dtype),
    }


def mamba2_decode_step(p: Dict[str, jax.Array], x_in: jax.Array, state,
                       cfg, prefix: str = "mamba"):
    """x_in (B,1,d); state {'ssm','conv'} -> (out (B,1,d), new state)."""
    s = cfg.ssm
    B, _, d = x_in.shape
    di, N, nh, P = s.d_inner(d), s.state_dim, s.n_heads(d), s.head_dim

    z, xBC, dt = _project(p, prefix, x_in[:, 0])
    # conv over [cache, new]
    window = jnp.concatenate([state["conv"], xBC[:, None, :]], axis=1)
    w = p[f"{prefix}_conv_w"]
    xBC = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w)
                      + p[f"{prefix}_conv_b"])
    new_conv = window[:, 1:]

    x, B_, C_ = jnp.split(xBC, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p[f"{prefix}_dt_bias"])
    A = -jnp.exp(p[f"{prefix}_A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                        # (B,nh)

    xh = x.reshape(B, nh, P).astype(jnp.float32)
    xdt = xh * dt[..., None]
    ssm = state["ssm"] * dA[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xdt, B_.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", ssm, C_.astype(jnp.float32))
    y = y + xh * p[f"{prefix}_D"].astype(jnp.float32)[:, None]
    y = y.reshape(B, di) * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x_in.dtype), p[f"{prefix}_norm_scale"], cfg.norm_eps)
    out = (y @ p[f"{prefix}_out_proj"])[:, None, :]
    return out, {"ssm": ssm, "conv": new_conv}
