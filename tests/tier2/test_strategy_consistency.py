"""Deterministic twin of test_strategy_properties.py (runs with or
without hypothesis): seeded sweep of the same matrix — all three wire
strategies + the fused Pallas kernel, odd/even voter counts,
padded/unpadded shapes, f32/bf16 grad dtypes, and the pinned tie-break
at exactly 50% adversaries.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import byzantine, sign_compress as sc
from repro.kernels import ops
from repro.sim import virtual_vote

STRATS = (VoteStrategy.PSUM_INT8, VoteStrategy.ALLGATHER_1BIT,
          VoteStrategy.HIERARCHICAL)
RNG = np.random.default_rng(42)


def _pm1(m, n):
    return np.where(RNG.integers(0, 2, size=(m, n)) == 1, 1.0, -1.0) \
        .astype(np.float32)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("m", [1, 2, 3, 4, 8, 9, 15, 16])
@pytest.mark.parametrize("n", [1, 31, 32, 33, 64, 129])
def test_matrix_bit_identity(m, n, dtype):
    x = np.asarray(jnp.asarray(_pm1(m, n), jnp.dtype(dtype)), np.float32)
    signs = np.asarray(sc.sign_ternary(jnp.asarray(x)))
    counts = signs.astype(np.int32).sum(axis=0)
    votes = {s: np.asarray(virtual_vote(jnp.asarray(signs), s))
             for s in STRATS}
    np.testing.assert_array_equal(votes[VoteStrategy.PSUM_INT8],
                                  np.sign(counts).astype(np.int8))
    packed = np.where(counts >= 0, 1, -1).astype(np.int8)
    np.testing.assert_array_equal(votes[VoteStrategy.ALLGATHER_1BIT], packed)
    np.testing.assert_array_equal(votes[VoteStrategy.HIERARCHICAL], packed)
    fused = np.asarray(ops.bitunpack(
        ops.fused_majority(jnp.asarray(x, jnp.float32)), n, jnp.int8))
    np.testing.assert_array_equal(fused, packed)
    if m % 2 == 1:  # odd M with ±1 inputs cannot tie: ALL bit-identical
        np.testing.assert_array_equal(votes[VoteStrategy.PSUM_INT8], packed)


@pytest.mark.parametrize("m", [2, 4, 8, 16])
def test_tie_break_at_exactly_half_adversaries(m):
    """50% sign-flippers against a unanimous electorate: count == 0 on
    every coordinate. psum_int8 abstains (0); allgather_1bit,
    hierarchical and the fused kernel resolve +1 (documented divergence,
    DESIGN.md §5/§7)."""
    n = 97
    honest = np.tile(_pm1(1, n), (m, 1))
    byz_cfg = ByzantineConfig(mode="sign_flip", num_adversaries=m // 2)
    wire = np.asarray(byzantine.apply_adversary_stacked(
        jnp.asarray(sc.sign_ternary(jnp.asarray(honest))), byz_cfg))
    assert (wire.astype(np.int32).sum(axis=0) == 0).all()
    np.testing.assert_array_equal(
        np.asarray(virtual_vote(jnp.asarray(wire), VoteStrategy.PSUM_INT8)),
        np.zeros(n, np.int8))
    for strat in (VoteStrategy.ALLGATHER_1BIT, VoteStrategy.HIERARCHICAL):
        np.testing.assert_array_equal(
            np.asarray(virtual_vote(jnp.asarray(wire), strat)),
            np.ones(n, np.int8), err_msg=str(strat))
    np.testing.assert_array_equal(
        np.asarray(ops.bitunpack(
            ops.fused_majority(jnp.asarray(wire, jnp.float32)), n,
            jnp.int8)),
        np.ones(n, np.int8))


def test_one_below_half_cannot_flip_unanimous():
    """Theorem 2's determinism core on the real wire: with fewer than half
    the voters flipped, a unanimous electorate's decision survives on
    every strategy, bit for bit."""
    m, n = 16, 200
    honest = np.tile(_pm1(1, n), (m, 1))
    byz_cfg = ByzantineConfig(mode="sign_flip", num_adversaries=m // 2 - 1)
    wire = jnp.asarray(byzantine.apply_adversary_stacked(
        jnp.asarray(sc.sign_ternary(jnp.asarray(honest))), byz_cfg))
    want = np.asarray(sc.sign_ternary(jnp.asarray(honest[0])))
    for strat in STRATS:
        np.testing.assert_array_equal(
            np.asarray(virtual_vote(wire, strat)), want, err_msg=str(strat))


def test_bf16_and_f32_grads_decide_identically():
    """Same sign pattern in bf16 and f32 gradients -> identical decisions
    (the wire carries signs; magnitude precision is irrelevant)."""
    m, n = 8, 130
    mag = RNG.uniform(0.5, 2.0, size=(m, n)).astype(np.float32)
    x32 = _pm1(m, n) * mag
    x16 = jnp.asarray(x32, jnp.bfloat16)
    for strat in STRATS:
        v32 = np.asarray(virtual_vote(sc.sign_ternary(jnp.asarray(x32)),
                                      strat))
        v16 = np.asarray(virtual_vote(sc.sign_ternary(x16), strat))
        np.testing.assert_array_equal(v32, v16, err_msg=str(strat))
