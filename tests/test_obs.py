"""The unified telemetry layer (DESIGN.md §13): spans, counters, step
records, the JSONL schema, the deprecation shims over the absorbed
accounting surfaces, the shared bench-JSON writer, the perf gate's
verdict table/exit codes, and the committed sample trace's report.

The two invariants everything else leans on:

* tracing must never move a bit of any traced computation — the golden
  scenario digest is asserted identical with the recorder on;
* the disabled recorder must be structurally free — one module-level
  no-op span singleton, no allocation on the unparameterized hot path.
"""
from __future__ import annotations

import importlib.util
import io
import json
import os

import pytest

from repro.core import population
from repro.kernels import ops
from repro.obs import recorder as obs
from repro.obs import report

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# counters: exact integers, three verbs, namespaced snapshots
# ---------------------------------------------------------------------------


def test_counter_registry_exactness():
    reg = obs.CounterRegistry()
    reg.inc("a.x")
    reg.inc("a.x", 41)
    reg.inc("a.y", 2**70)            # arbitrary precision, no float drift
    reg.set("b.gauge", 7)
    reg.set("b.gauge", 3)
    reg.record_max("b.high", 5)
    reg.record_max("b.high", 2)      # lower value must not move the mark
    assert reg.get("a.x") == 42
    assert reg.get("a.y") == 2**70
    assert reg.get("b.gauge") == 3
    assert reg.get("b.high") == 5
    assert reg.get("missing") == 0
    assert reg.snapshot("a.") == {"a.x": 42, "a.y": 2**70}


def test_counter_delta_and_prefix_reset():
    reg = obs.CounterRegistry()
    reg.inc("a.x", 10)
    reg.inc("b.y", 1)
    before = reg.snapshot()
    reg.inc("a.x", 5)
    reg.inc("c.z", 3)
    assert reg.delta_since(before) == {"a.x": 5, "c.z": 3}
    assert reg.delta_since(before, "a.") == {"a.x": 5}
    reg.reset("a.")
    assert reg.get("a.x") == 0 and reg.get("b.y") == 1


# ---------------------------------------------------------------------------
# spans: nesting, ordering, the no-op singleton
# ---------------------------------------------------------------------------


def test_span_nesting_and_ordering():
    buf = io.StringIO()
    rec = obs.TraceRecorder(buf)
    with rec.span("outer", kind="test"):
        with rec.span("inner1"):
            pass
        with rec.span("inner2") as s:
            s.set(late=1)
    rec.close()
    rows = [json.loads(line) for line in buf.getvalue().splitlines()]
    spans = {r["name"]: r for r in rows if r["kind"] == "span"}
    outer, i1, i2 = spans["outer"], spans["inner1"], spans["inner2"]
    # children close before the parent, so they appear first; nesting is
    # carried by parent seq + depth
    assert i1["parent"] == outer["seq"] and i2["parent"] == outer["seq"]
    assert i1["depth"] == 1 and i2["depth"] == 1 and outer["depth"] == 0
    assert outer["parent"] == -1
    assert i1["seq"] < i2["seq"]
    assert i2["attrs"] == {"late": 1}
    assert outer["attrs"] == {"kind": "test"}
    # parent wall time covers both children
    assert outer["dur_s"] >= i1["dur_s"] + i2["dur_s"] - 1e-9
    # every row is versioned
    assert all(r["v"] == obs.SCHEMA_VERSION for r in rows)


def test_noop_recorder_is_singleton_and_free():
    rec = obs.Recorder()
    assert not rec.enabled
    s1 = rec.span("a", x=1)
    s2 = rec.span("b")
    assert s1 is s2                       # the module-level singleton
    with s1 as s:
        s.set(anything=1)
    assert s1.dur_s == 0.0
    # unparameterized hot path allocates nothing: same object back, and
    # the call accepts being hammered
    for _ in range(1000):
        assert rec.span("hot") is s1
    rec.event("x")                        # all no-ops, no errors
    rec.step(loss=1.0)
    rec.close()


def test_get_set_recording_scoping():
    assert not obs.get_recorder().enabled
    rec = obs.TraceRecorder(io.StringIO())
    with obs.recording(rec) as r:
        assert obs.get_recorder() is r is rec
    assert not obs.get_recorder().enabled
    prev = obs.set_recorder(rec)
    assert obs.get_recorder() is rec
    obs.set_recorder(None)                # None restores the no-op
    assert not obs.get_recorder().enabled
    assert not prev.enabled


# ---------------------------------------------------------------------------
# the JSONL sink: schema round-trip, version/kind validation
# ---------------------------------------------------------------------------


def test_trace_roundtrip_and_final_counters(tmp_path):
    p = tmp_path / "t.jsonl"
    before = obs.COUNTERS.get("test.obs.roundtrip")
    rec = obs.TraceRecorder(str(p), meta={"harness": "unit"})
    with rec.span("s1"):
        pass
    rec.event("e1", detail="x")
    rec.step(step=0, loss=1.5, payload_bytes=32.0, n_coords=64)
    obs.COUNTERS.inc("test.obs.roundtrip")
    rec.close()
    rows = obs.read_trace(str(p))
    kinds = [r["kind"] for r in rows]
    assert kinds[0] == "meta" and kinds[-1] == "counters"
    assert rows[0]["harness"] == "unit" and rows[0]["host_side"] is True
    assert {"span", "event", "step"} <= set(kinds)
    # the close() snapshot carries the registry state at close time
    assert rows[-1]["values"]["test.obs.roundtrip"] == before + 1
    step = next(r for r in rows if r["kind"] == "step")
    assert step["loss"] == 1.5 and step["payload_bytes"] == 32.0


def test_read_trace_rejects_schema_drift(tmp_path):
    bad_version = tmp_path / "v.jsonl"
    bad_version.write_text(json.dumps({"v": 999, "kind": "meta"}) + "\n")
    with pytest.raises(ValueError, match="schema"):
        obs.read_trace(str(bad_version))
    bad_kind = tmp_path / "k.jsonl"
    bad_kind.write_text(
        json.dumps({"v": obs.SCHEMA_VERSION, "kind": "mystery"}) + "\n")
    with pytest.raises(ValueError, match="kind"):
        obs.read_trace(str(bad_kind))


# ---------------------------------------------------------------------------
# the absorbed surfaces: LAUNCHES and LAST_STATS are registry shims
# ---------------------------------------------------------------------------


def test_launches_shim_reads_registry():
    ops.reset_launch_counts()
    obs.COUNTERS.inc(ops.LAUNCH_PREFIX + "bitpack", 3)
    assert ops.LAUNCHES["bitpack"] == 3
    # the read went through the deprecation gate (warns once/process)
    assert "kernels.ops.LAUNCHES" in obs._WARNED
    assert ops.launch_counts() == {"bitpack": 3}
    assert len(ops.LAUNCHES) == 1 and list(ops.LAUNCHES) == ["bitpack"]
    ops.LAUNCHES.clear()
    assert ops.launch_counts() == {}


def test_last_stats_shim_reads_registry():
    obs.COUNTERS.set(population.STATS_PREFIX + "last.n_voters", 17)
    obs.COUNTERS.set(population.STATS_PREFIX + "last.peak_rows", 4)
    obs.COUNTERS.set(population.STATS_PREFIX + "last.n_chunks", 5)
    obs.COUNTERS.set(population.STATS_PREFIX + "last.n_passes", 1)
    assert population.LAST_STATS["n_voters"] == 17
    assert dict(population.LAST_STATS)["peak_rows"] == 4
    assert len(population.LAST_STATS) == 4
    with pytest.raises(KeyError):
        population.LAST_STATS["not_a_stat"]


# ---------------------------------------------------------------------------
# tracing never moves a bit: the golden scenario digest
# ---------------------------------------------------------------------------


def test_golden_digest_unchanged_with_tracing_on(tmp_path):
    from repro.sim import ScenarioRunner, ScenarioSpec
    spec = ScenarioSpec("obs-unit/golden", n_workers=4, n_steps=2, dim=64)
    ref = ScenarioRunner(spec).run()
    rec = obs.TraceRecorder(str(tmp_path / "g.jsonl"))
    with obs.recording(rec):
        traced = ScenarioRunner(spec).run()
    rec.close()
    assert traced.digest == ref.digest, (
        "the recorder perturbed a traced value — telemetry must be "
        "host-side only")
    rows = obs.read_trace(str(tmp_path / "g.jsonl"))
    steps = [r for r in rows if r["kind"] == "step"]
    assert len(steps) == 2
    s = steps[0]
    # the unified step record: StepTrace drill fields + WireReport wire
    # accounting + per-phase span seconds in ONE row
    for field in ("scenario", "backend", "n_voters", "strategy", "codec",
                  "payload_bytes", "n_messages", "n_coords",
                  "compression_vs_f32", "margin", "flip_fraction",
                  "loss", "phase_s"):
        assert field in s, f"step record lost {field}"
    assert s["payload_bytes"] > 0
    assert set(s["phase_s"]) == {"prepare", "vote", "finish"}
    assert [r["name"] for r in rows if r["kind"] == "span"
            and r["name"].startswith("scenario.")].count(
                "scenario.vote") == 2


# ---------------------------------------------------------------------------
# the shared bench JSON writer
# ---------------------------------------------------------------------------


def test_emit_bench_json_tuples_and_dicts(tmp_path):
    p = tmp_path / "bench.json"
    obs.emit_bench_json([("a_ms", 1.25, "timing"),
                         {"name": "b", "value": 2.0}], str(p))
    doc = json.loads(p.read_text())
    assert doc == {"rows": [
        {"name": "a_ms", "value": 1.25, "derived": "timing"},
        {"name": "b", "value": 2.0, "derived": ""}]}


# ---------------------------------------------------------------------------
# perf gate: verdict table + distinct exit codes
# ---------------------------------------------------------------------------


def _load_perf_gate():
    spec = importlib.util.spec_from_file_location(
        "perf_gate", os.path.join(_REPO, "scripts", "perf_gate.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _write_bench(path, rows):
    obs.emit_bench_json(rows, str(path))


def test_perf_gate_exit_codes(tmp_path):
    pg = _load_perf_gate()
    base = tmp_path / "base.json"
    _write_bench(base, [("t_ms", 10.0, ""), ("exact", 5.0, "")])

    ok = tmp_path / "ok.json"          # within tol + improvement
    _write_bench(ok, [("t_ms", 9.0, ""), ("exact", 5.0, "")])
    assert pg.main(["--baseline", str(base), "--fresh", str(ok)]) \
        == pg.EXIT_OK

    slow = tmp_path / "slow.json"      # timing regression -> 1
    _write_bench(slow, [("t_ms", 20.0, ""), ("exact", 5.0, "")])
    assert pg.main(["--baseline", str(base), "--fresh", str(slow)]) \
        == pg.EXIT_REGRESSION

    drift = tmp_path / "drift.json"    # accounting change -> 1
    _write_bench(drift, [("t_ms", 10.0, ""), ("exact", 6.0, "")])
    assert pg.main(["--baseline", str(base), "--fresh", str(drift)]) \
        == pg.EXIT_REGRESSION

    missing = tmp_path / "missing.json"   # dropped row -> 2
    _write_bench(missing, [("t_ms", 10.0, "")])
    assert pg.main(["--baseline", str(base), "--fresh", str(missing)]) \
        == pg.EXIT_MISSING_ROW

    # missing takes precedence even when a regression is also present
    both = tmp_path / "both.json"
    _write_bench(both, [("t_ms", 99.0, ""), ("new_row", 1.0, "")])
    assert pg.main(["--baseline", str(base), "--fresh", str(both)]) \
        == pg.EXIT_MISSING_ROW


def test_perf_gate_full_table_on_failure(tmp_path, capsys):
    pg = _load_perf_gate()
    base, fresh = tmp_path / "b.json", tmp_path / "f.json"
    _write_bench(base, [("t_ms", 10.0, ""), ("good", 1.0, ""),
                        ("exact", 5.0, "")])
    _write_bench(fresh, [("t_ms", 20.0, ""), ("good", 1.0, ""),
                         ("exact", 5.0, "")])
    assert pg.main(["--baseline", str(base), "--fresh", str(fresh)]) \
        == pg.EXIT_REGRESSION
    out = capsys.readouterr().out
    # the FULL table renders — passing rows included, with class and
    # threshold columns
    assert "full comparison table" in out
    for token in ("t_ms", "good", "exact", "REGRESS", "OK", "timing",
                  "+15%", "=="):
        assert token in out, f"comparison table lost {token!r}"


def test_perf_gate_compare_statuses():
    pg = _load_perf_gate()
    rows = pg.compare({"a_ms": 10.0, "b": 1.0, "gone": 2.0},
                      {"a_ms": 8.0, "b": 1.0, "new": 3.0}, tol=0.15)
    st = {r["name"]: r["status"] for r in rows}
    assert st == {"a_ms": "IMPROVED", "b": "OK", "gone": "MISSING",
                  "new": "EXTRA"}
    assert pg.verdict_exit_code(rows) == pg.EXIT_MISSING_ROW


# ---------------------------------------------------------------------------
# the committed sample trace renders every report section
# ---------------------------------------------------------------------------


def test_sample_trace_report_renders():
    sample = os.path.join(_REPO, "benchmarks", "traces",
                          "sample_trace.jsonl")
    text = report.render(sample)
    for sec in report.SECTIONS:
        assert f"== {sec} ==" in text, f"section {sec} missing"
    # the per-bucket measured-vs-predicted breakdown is the acceptance
    # bar: buckets with labels, measured times AND alpha-beta
    # predictions must be present in the committed sample
    s = report.summarize(sample)
    assert s["buckets"], "sample trace has no bucketed walks"
    assert all(b["predicted_s"] is not None for b in s["buckets"]), \
        "plan.issue spans lost the alpha-beta pred_s attr"
    assert all(b["measured_s"] > 0 for b in s["buckets"])
    assert s["schedules"], "sample trace has no plan.schedule walks"
    # both walk flavors of the PR-6 executor are in the sample
    assert {w["overlap"] for w in s["schedules"]} == {True, False}
    assert s["steps"]["n_steps"] > 0
    assert s["counters"].get("vote.wire.bytes", 0) > 0
    assert "1/32" in text        # the paper's ideal ratio is cited


def test_report_ideal_ratio_matches_paper():
    assert report.IDEAL_RATIO == pytest.approx(1.0 / 32.0)
