"""Checkpoint/restore (atomicity, async, elastic reshard) and the data
pipeline's determinism/checkpointability contracts."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as C
from repro.configs.base import get_config, reduced_config
from repro.data.pipeline import SyntheticLMPipeline
from repro.distributed.fault_tolerance import (Watchdog, plan_rescale)


def _tree():
    return {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "nested": {"b": np.ones((4,), np.int32)}}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    params, opt = _tree(), {"momentum": {"a": np.zeros((2, 3))}}
    C.save(d, 7, params, opt, {"step": 7}, meta={"arch": "x"})
    p2, o2, ds, meta = C.restore(d)
    np.testing.assert_array_equal(p2["a"], params["a"])
    np.testing.assert_array_equal(p2["nested"]["b"], params["nested"]["b"])
    np.testing.assert_array_equal(o2["momentum"]["a"], opt["momentum"]["a"])
    assert ds["step"] == 7 and meta["step"] == 7


def test_latest_pointer_monotonic(tmp_path):
    d = str(tmp_path)
    for step in (1, 5, 3):  # out-of-order save; LATEST follows writes
        C.save(d, step, {"a": np.full((2,), step, np.float32)}, {})
    p, _, _, meta = C.restore(d)
    assert meta["step"] == 3
    np.testing.assert_array_equal(p["a"], [3.0, 3.0])


def test_atomic_no_partial_dirs(tmp_path):
    d = str(tmp_path)
    C.save(d, 1, _tree(), {})
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ck = C.AsyncCheckpointer(d)
    ck.save(2, {"a": jnp.ones((3,))}, {"count": jnp.int32(2)})
    ck.wait()
    p, o, _, meta = C.restore(d)
    np.testing.assert_array_equal(p["a"], np.ones((3,)))
    assert meta["step"] == 2


def test_elastic_reshard_truncates_and_pads(tmp_path):
    """Per-worker momentum (leading vote axis) refits when M changes."""
    d = str(tmp_path)
    mom16 = {"momentum": {"w": np.arange(16 * 3, dtype=np.float32
                                         ).reshape(16, 3)}}
    C.save(d, 1, {"w": np.zeros(3, np.float32)}, mom16)
    # restore to 8 replicas: truncate
    like = {"momentum": {"w": jax.ShapeDtypeStruct((8, 3), jnp.float32)}}
    _, o8, _, _ = C.restore(d, like_opt=like)
    assert o8["momentum"]["w"].shape == (8, 3)
    np.testing.assert_array_equal(o8["momentum"]["w"],
                                  mom16["momentum"]["w"][:8])
    # restore to 32 replicas: zero-pad (new workers start cold)
    like = {"momentum": {"w": jax.ShapeDtypeStruct((32, 3), jnp.float32)}}
    _, o32, _, _ = C.restore(d, like_opt=like)
    assert o32["momentum"]["w"].shape == (32, 3)
    np.testing.assert_array_equal(o32["momentum"]["w"][16:], 0.0)


def test_plan_rescale():
    plan = plan_rescale((2, 16, 16), ("pod", "data", "model"), 256)
    assert plan.new_shape[-1] == 16            # TP preserved
    assert plan.new_replicas == 16
    plan2 = plan_rescale((16, 16), ("data", "model"), 128)
    assert plan2.new_shape == (8, 16)
    with pytest.raises(ValueError):
        plan_rescale((16, 16), ("data", "model"), 8)


def test_watchdog_fires():
    import time
    fired = []
    with Watchdog(0.05, on_timeout=lambda: fired.append(1)) as wd:
        time.sleep(0.15)
    assert wd.fired and fired


def test_watchdog_cancels():
    with Watchdog(5.0) as wd:
        pass
    assert not wd.fired


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def _pipe(**kw):
    cfg = reduced_config(get_config("glm4-9b"))
    return SyntheticLMPipeline(cfg, global_batch=8, seq_len=32, **kw)


def test_pipeline_deterministic_replay():
    p1, p2 = _pipe(seed=3), _pipe(seed=3)
    for _ in range(3):
        b1, b2 = next(p1), next(p2)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_pipeline_checkpoint_resume():
    p1 = _pipe(seed=1)
    next(p1); next(p1)
    state = p1.checkpoint()
    b_expected = next(p1)
    p2 = _pipe(seed=1)
    p2.restore(state)
    b_resumed = next(p2)
    np.testing.assert_array_equal(b_expected["tokens"], b_resumed["tokens"])


def test_pipeline_replica_sharding_partitions_global_batch():
    p = _pipe(seed=2)
    full = p.global_batch_at(5)["tokens"]
    parts = [p.replica_batch(5, r, 4)["tokens"] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_pipeline_tokens_in_vocab_and_learnable():
    p = _pipe(seed=0)
    b = next(p)["tokens"]
    assert b.min() >= 0 and b.max() < p.cfg.vocab_size
    # Markov structure: unigram distribution is far from uniform
    counts = np.bincount(b.reshape(-1), minlength=p.cfg.vocab_size)
    assert counts.max() > 3 * (b.size / p.cfg.vocab_size)


def test_pipeline_frontend_stub_shapes():
    cfg = reduced_config(get_config("whisper-tiny"))
    p = SyntheticLMPipeline(cfg, global_batch=4, seq_len=16)
    b = next(p)
    assert "enc_embeds" in b
    assert b["enc_embeds"].shape[0] == 4
    assert b["enc_embeds"].shape[2] == cfg.d_model
