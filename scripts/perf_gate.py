#!/usr/bin/env python
"""Perf regression gate: diff a fresh benchmark JSON against the
committed baseline, row by row.

Both files use the ``{"rows": [{"name", "value", "derived"}, ...]}``
schema that ``repro.obs.emit_bench_json`` writes (every bench and the
``benchmarks.run`` driver route through it). Two row classes, decided
by the row NAME:

* ``*_ms`` (timing rows): fail when the fresh value regresses past the
  committed value by more than ``--tol`` (default 15%). One-sided —
  getting faster never fails; re-commit the JSON to bank the win.
* everything else (bit-identity / accounting rows: golden digests,
  mesh==virtual flags, launch counts): any numeric change fails. These
  rows encode correctness claims, not measurements.

``derived`` strings are free-form commentary (sweep-chosen bucket
sizes, digest prefixes) and are never compared. Missing or extra rows
fail in both directions: a silently dropped acceptance row is as bad as
a regression.

On failure the gate prints the FULL per-row comparison table — every
row with its baseline value, fresh value, class, threshold and status —
so a CI log shows the whole picture, not just the first delta.

Exit codes (distinct so CI wiring can tell schema drift from a slow
host):

* ``0`` — gate passes.
* ``1`` — a timing regression or an exact-match accounting change.
* ``2`` — a row is missing or unexpected (schema/coverage drift).
  Takes precedence when both kinds of failure are present.

Usage (the ci.sh wiring snapshots the committed JSON before the smoke
lane overwrites it in place):

    cp BENCH_vote_plan.json /tmp/base.json
    python -m benchmarks.bench_vote_plan --smoke
    python scripts/perf_gate.py --baseline /tmp/base.json \\
        --fresh BENCH_vote_plan.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

#: gate verdicts: OK < REGRESS/CHANGED (exit 1) < MISSING/EXTRA (exit 2)
EXIT_OK = 0
EXIT_REGRESSION = 1
EXIT_MISSING_ROW = 2


def load_rows(path: str) -> Dict[str, float]:
    with open(path) as f:
        doc = json.load(f)
    rows = doc["rows"] if isinstance(doc, dict) else doc
    out: Dict[str, float] = {}
    for r in rows:
        if r["name"] in out:
            raise SystemExit(f"perf_gate: duplicate row {r['name']!r} "
                             f"in {path}")
        out[r["name"]] = float(r["value"])
    return out


def compare(base: Dict[str, float], fresh: Dict[str, float],
            tol: float) -> List[dict]:
    """One structured verdict per row (union of both files), sorted by
    name: ``{"name", "baseline", "fresh", "class", "threshold",
    "status"}``. Statuses: ``OK``, ``IMPROVED`` (timing got faster),
    ``REGRESS`` (timing past tolerance), ``CHANGED`` (exact row moved),
    ``MISSING`` (row disappeared), ``EXTRA`` (unblessed new row)."""
    out = []
    for name in sorted(set(base) | set(fresh)):
        b: Optional[float] = base.get(name)
        f: Optional[float] = fresh.get(name)
        timing = name.endswith("_ms")
        row = {"name": name, "baseline": b, "fresh": f,
               "class": "timing" if timing else "exact",
               "threshold": f"+{tol * 100:.0f}%" if timing else "=="}
        if b is None:
            row["status"] = "EXTRA"
        elif f is None:
            row["status"] = "MISSING"
        elif timing:
            if f > b * (1.0 + tol):
                row["status"] = "REGRESS"
            elif f < b:
                row["status"] = "IMPROVED"
            else:
                row["status"] = "OK"
        else:
            row["status"] = "OK" if f == b else "CHANGED"
        out.append(row)
    return out


def verdict_exit_code(rows: List[dict]) -> int:
    """Exit code for a :func:`compare` table. MISSING/EXTRA (coverage
    drift, exit 2) takes precedence over REGRESS/CHANGED (exit 1)."""
    statuses = {r["status"] for r in rows}
    if statuses & {"MISSING", "EXTRA"}:
        return EXIT_MISSING_ROW
    if statuses & {"REGRESS", "CHANGED"}:
        return EXIT_REGRESSION
    return EXIT_OK


def _fmt(v: Optional[float]) -> str:
    return "-" if v is None else f"{v:.6g}"


def render_table(rows: List[dict]) -> str:
    """The full comparison table (printed whole on any failure)."""
    head = ("name", "baseline", "fresh", "class", "threshold", "status")
    body = [(r["name"], _fmt(r["baseline"]), _fmt(r["fresh"]),
             r["class"], r["threshold"], r["status"]) for r in rows]
    widths = [max(len(head[i]), *(len(b[i]) for b in body)) if body
              else len(head[i]) for i in range(len(head))]
    lines = ["  ".join(h.ljust(w) for h, w in zip(head, widths)),
             "  ".join("-" * w for w in widths)]
    lines += ["  ".join(c.ljust(w) for c, w in zip(b, widths))
              for b in body]
    return "\n".join(lines)


def diff(base: Dict[str, float], fresh: Dict[str, float],
         tol: float) -> list:
    """Back-compat surface: the list of human-readable failures (empty
    = gate passes), derived from :func:`compare`."""
    failures = []
    for r in compare(base, fresh, tol):
        name, b, f = r["name"], r["baseline"], r["fresh"]
        if r["status"] == "MISSING":
            failures.append(f"row disappeared: {name} (baseline {b:.6g})")
        elif r["status"] == "EXTRA":
            failures.append(f"new row without a committed baseline: "
                            f"{name} (fresh {f:.6g}) — re-commit the "
                            "JSON to bless it")
        elif r["status"] == "REGRESS":
            failures.append(
                f"timing regression: {name} {f:.3f} ms vs baseline "
                f"{b:.3f} ms (+{(f / b - 1.0) * 100:.1f}% > "
                f"{tol * 100:.0f}% tolerance)")
        elif r["status"] == "CHANGED":
            failures.append(
                f"bit-identity/accounting row changed: {name} "
                f"{f:.6g} vs baseline {b:.6g} (exact match required)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--baseline", required=True,
                    help="committed benchmark JSON (snapshot it before "
                         "a smoke lane overwrites the file in place)")
    ap.add_argument("--fresh", required=True,
                    help="freshly produced benchmark JSON to vet")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="one-sided relative tolerance for *_ms timing "
                         "rows (default 0.15 = 15%%)")
    args = ap.parse_args(argv)

    base = load_rows(args.baseline)
    fresh = load_rows(args.fresh)
    rows = compare(base, fresh, args.tol)
    code = verdict_exit_code(rows)
    if code != EXIT_OK:
        bad = [r for r in rows if r["status"] not in ("OK", "IMPROVED")]
        print(f"perf_gate: {len(bad)} failure(s) "
              f"({args.fresh} vs {args.baseline}):")
        for r in bad:
            print(f"  FAIL [{r['status']}] {r['name']}: "
                  f"baseline {_fmt(r['baseline'])} -> "
                  f"fresh {_fmt(r['fresh'])} ({r['class']} "
                  f"{r['threshold']})")
        print("\nfull comparison table:")
        print(render_table(rows))
        print(f"\nperf_gate: exit {code} "
              f"({'missing/extra row' if code == EXIT_MISSING_ROW else 'regression/accounting change'})")
        return code
    n_timing = sum(1 for n in base if n.endswith("_ms"))
    print(f"perf_gate: OK — {len(base)} rows ({n_timing} timing within "
          f"{args.tol * 100:.0f}%, {len(base) - n_timing} exact)")
    return EXIT_OK


if __name__ == "__main__":
    sys.exit(main())
