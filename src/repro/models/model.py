"""Model integration layer: init / loss / prefill / decode for every arch.

This is the public model API the trainer, server, dry-run and tests use:

  init_params(cfg, key)                 -> flat param dict (stacked layout)
  loss_fn(cfg, params, batch)           -> (scalar loss, metrics dict)
  forward_logits(cfg, params, batch)    -> (B, S, V) logits
  init_cache(cfg, batch, max_len)       -> cache pytree (family-specific)
  prefill(cfg, params, batch)           -> (logits, cache)
  decode_step(cfg, params, tokens, cache, pos) -> (logits, cache)
  input_specs(cfg, cell)                -> ShapeDtypeStruct pytrees for the
                                           dry-run (no allocation)

Batches are dicts: ``tokens`` (B, S) int32 always; plus ``enc_embeds``
(whisper) or ``patch_embeds`` (pixtral) when the frontend is stubbed.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchFamily, ModelConfig, ShapeCell
from repro.distributed.sharding import BATCH, shard
from repro.models import encdec, hybrid, layers as L, transformer
from repro.models.mamba2 import (mamba2_decode_step, mamba2_forward,
                                 mamba2_init_state)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype: Optional[Any] = None) -> Dict[str, jax.Array]:
    dtype = dtype or _dtype(cfg)
    shapes = cfg.param_shapes()
    keys = jax.random.split(key, len(shapes))
    params = {}
    for (name, shape), k in zip(sorted(shapes.items()), keys):
        if name.endswith("_scale") or ".scale" in name:
            params[name] = jnp.ones(shape, dtype)
        elif name.endswith(("_b", "_bq", "_bk", "_bv", "_conv_b", "dt_bias")):
            params[name] = jnp.zeros(shape, dtype)
        elif name.endswith("A_log"):
            # A in [1, 16) as in mamba2 reference init
            nh = shape[-1]
            a = jnp.tile(jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)
                                 + 0.5), shape[:-1] + (1,)).reshape(shape)
            params[name] = a.astype(jnp.float32)
        elif name.endswith("mamba_D"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / math.sqrt(fan_in)
            params[name] = (jax.random.normal(k, shape, jnp.float32)
                            * std).astype(dtype)
    return params


# ---------------------------------------------------------------------------
# embedding frontends
# ---------------------------------------------------------------------------


def _vlm_split(cell_seq: int) -> Tuple[int, int]:
    """pixtral: first quarter of the sequence is image patches."""
    s_img = cell_seq // 4
    return s_img, cell_seq - s_img


def _embed_input(cfg: ModelConfig, params, batch) -> jax.Array:
    """Build the (B, S, d) input stream for decoder-style archs."""
    tok = L.embed_tokens(params["embed.table"], batch["tokens"])
    if cfg.family == ArchFamily.VLM and "patch_embeds" in batch:
        h = jnp.concatenate(
            [batch["patch_embeds"].astype(tok.dtype), tok], axis=1)
    else:
        h = tok
    return shard(h, BATCH, None, None)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def forward_logits(cfg: ModelConfig, params, batch, hook=None,
                   remat: str = "none") -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V), aux_loss scalar).

    `hook(tree, scope)` is the ZeRO-3 gather(+vote-backward) transform
    (core.majority_vote.make_fsdp_hooks); applied to top-level params here
    and to per-layer trees inside the depth scans.
    """
    if hook is not None:
        top = {k: v for k, v in params.items()
               if not k.startswith(("layers.", "encoder."))}
        params = {**params, **hook(top, "top")}
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == ArchFamily.AUDIO:
        enc = encdec.encoder_forward(params, batch["enc_embeds"], cfg,
                                     hook=hook, remat=remat)
        h = L.embed_tokens(params["embed.table"], batch["tokens"])
        S = h.shape[1]
        h = h + L.sinusoidal_positions(jnp.arange(S), cfg.d_model
                                       ).astype(h.dtype)
        h = encdec.decoder_forward(params, h, enc, cfg, hook=hook,
                                   remat=remat)
    elif cfg.family == ArchFamily.SSM:
        h = _embed_input(cfg, params, batch)
        lp = transformer._layer_tree(params)

        def body(carry, layer_p):
            if hook is not None:
                layer_p = hook(layer_p, "layers")
            x = L.rms_norm(carry, layer_p["norm1_scale"], cfg.norm_eps)
            carry = carry + mamba2_forward(layer_p, x, cfg)
            return transformer.residual_shard(carry, cfg), None

        h, _ = jax.lax.scan(transformer.maybe_remat(body, remat), h, lp)
    elif cfg.family == ArchFamily.HYBRID:
        h = _embed_input(cfg, params, batch)
        h = hybrid.hybrid_forward(params, h, cfg, hook=hook, remat=remat)
    else:
        h = _embed_input(cfg, params, batch)
        h, aux = transformer.decoder_stack(params, h, cfg, hook=hook,
                                           remat=remat)
    h = L.rms_norm(h, params["final_norm.scale"], cfg.norm_eps)
    table = params.get("unembed.table", params["embed.table"])
    logits = jnp.einsum("bsd,vd->bsv", h, table)
    return shard(logits, BATCH, None, "model"), aux


def loss_fn(cfg: ModelConfig, params, batch, hook=None, remat: str = "none"
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward_logits(cfg, params, batch, hook=hook, remat=remat)
    tokens = batch["tokens"]
    if cfg.family == ArchFamily.VLM and "patch_embeds" in batch:
        # loss only over the text segment (last `len(tokens)` positions)
        logits = logits[:, -tokens.shape[1]:]
    ce = L.cross_entropy_loss(logits[:, :-1], tokens[:, 1:])
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# caches / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype: Optional[Any] = None) -> Dict[str, jax.Array]:
    dtype = dtype or _dtype(cfg)
    if cfg.family == ArchFamily.SSM:
        st = mamba2_init_state(cfg, batch, dtype)
        return {
            "ssm": jnp.zeros((cfg.num_layers,) + st["ssm"].shape, jnp.float32),
            "conv": jnp.zeros((cfg.num_layers,) + st["conv"].shape, dtype),
        }
    if cfg.family == ArchFamily.HYBRID:
        return hybrid.hybrid_init_cache(cfg, batch, max_len, dtype)
    if cfg.family == ArchFamily.AUDIO:
        t_src = cfg.max_source_positions
        return encdec.encdec_init_cache(None, cfg, batch, max_len, t_src, dtype)
    return transformer.init_kv_cache(cfg, batch, max_len, dtype)


def prefill(cfg: ModelConfig, params, batch, hook=None
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Run the prompt; return (logits, populated cache)."""
    if hook is not None and cfg.family not in (ArchFamily.SSM,
                                               ArchFamily.HYBRID,
                                               ArchFamily.AUDIO):
        top = {k: v for k, v in params.items()
               if not k.startswith(("layers.", "encoder."))}
        params = {**params, **hook(top, "top")}
    if cfg.family == ArchFamily.AUDIO:
        enc = encdec.encoder_forward(params, batch["enc_embeds"], cfg)
        xk, xv = encdec.encdec_precompute_cross(params, enc, cfg)
        h = L.embed_tokens(params["embed.table"], batch["tokens"])
        S = h.shape[1]
        h = h + L.sinusoidal_positions(jnp.arange(S), cfg.d_model
                                       ).astype(h.dtype)
        h = encdec.decoder_forward(params, h, enc, cfg)
        h = L.rms_norm(h, params["final_norm.scale"], cfg.norm_eps)
        table = params.get("unembed.table", params["embed.table"])
        logits = jnp.einsum("bsd,vd->bsv", h, table)
        # self-attn caches from a fresh pass would need per-layer K/V; for
        # serving we re-run decoder_prefill-style below (cross K/V reused).
        cache = init_cache(cfg, batch["tokens"].shape[0], S)
        cache["xk"], cache["xv"] = xk, xv
        return logits, cache
    if cfg.family in (ArchFamily.SSM, ArchFamily.HYBRID):
        # recurrent archs: prefill == forward (state materialisation for
        # serving is chunk-scan; dry-run exercises the forward path)
        logits, _ = forward_logits(cfg, params, batch, hook=hook)
        cache = init_cache(cfg, batch["tokens"].shape[0],
                           batch["tokens"].shape[1])
        return logits, cache
    h = _embed_input(cfg, params, batch)
    h, cache = transformer.decoder_prefill(params, h, cfg, hook=hook)
    h = L.rms_norm(h, params["final_norm.scale"], cfg.norm_eps)
    table = params.get("unembed.table", params["embed.table"])
    logits = jnp.einsum("bsd,vd->bsv", h, table)
    return shard(logits, BATCH, None, "model"), cache


def decode_step(cfg: ModelConfig, params, tokens: jax.Array, cache,
                pos: jax.Array) -> Tuple[jax.Array, Any]:
    """tokens (B,1) int32; pos scalar int32 -> (logits (B,V), cache)."""
    h = L.embed_tokens(params["embed.table"], tokens)
    if cfg.family == ArchFamily.AUDIO:
        h = h + L.sinusoidal_positions(pos[None], cfg.d_model).astype(h.dtype)
        h, cache = encdec.encdec_decode_step(params, h, cache, pos, cfg)
    elif cfg.family == ArchFamily.SSM:
        lp = transformer._layer_tree(params)

        def body(carry, xs):
            layer_p, ssm, conv = xs
            x = L.rms_norm(carry, layer_p["norm1_scale"], cfg.norm_eps)
            out, st = mamba2_decode_step(
                layer_p, x, {"ssm": ssm, "conv": conv}, cfg)
            return carry + out, (st["ssm"], st["conv"])

        h, (ssm, conv) = jax.lax.scan(body, h, (lp, cache["ssm"], cache["conv"]))
        cache = {"ssm": ssm, "conv": conv}
    elif cfg.family == ArchFamily.HYBRID:
        h, cache = hybrid.hybrid_decode_step(params, h, cache, pos, cfg)
    else:
        h, cache = transformer.decoder_decode_step(params, h, cache, pos, cfg)
    h = L.rms_norm(h, params["final_norm.scale"], cfg.norm_eps)
    table = params.get("unembed.table", params["embed.table"])
    logits = jnp.einsum("bsd,vd->bsv", h, table)[:, 0]
    return logits, cache


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStructs — never allocates)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """Abstract inputs for a shape cell.

    train/prefill -> {'batch': {...}}
    decode        -> {'tokens', 'cache', 'pos'}
    """
    B, S = cell.global_batch, cell.seq_len
    dt = _dtype(cfg)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    def token_batch() -> Dict[str, Any]:
        if cfg.family == ArchFamily.AUDIO:
            t_src = cfg.max_source_positions
            return {"tokens": sds((B, S), i32),
                    "enc_embeds": sds((B, t_src, cfg.d_model), dt)}
        if cfg.family == ArchFamily.VLM:
            s_img, s_txt = _vlm_split(S)
            return {"tokens": sds((B, s_txt), i32),
                    "patch_embeds": sds((B, s_img, cfg.d_model), dt)}
        return {"tokens": sds((B, S), i32)}

    if cell.kind in ("train", "prefill"):
        return {"batch": token_batch()}

    # decode: cache of length S, one new token at pos S-1
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return {
        "tokens": sds((B, 1), i32),
        "cache": cache,
        "pos": sds((), i32),
    }


def make_batch(cfg: ModelConfig, batch: int, seq: int, key: jax.Array
               ) -> Dict[str, jax.Array]:
    """Concrete random batch (tests / examples)."""
    k1, k2 = jax.random.split(key)
    out = {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size,
                                        jnp.int32)}
    if cfg.family == ArchFamily.AUDIO:
        t_src = min(cfg.max_source_positions, 64)
        out["enc_embeds"] = jax.random.normal(
            k2, (batch, t_src, cfg.d_model), jnp.float32).astype(_dtype(cfg))
    if cfg.family == ArchFamily.VLM:
        s_img, s_txt = _vlm_split(seq)
        out["tokens"] = out["tokens"][:, :s_txt]
        out["patch_embeds"] = jax.random.normal(
            k2, (batch, s_img, cfg.d_model), jnp.float32).astype(_dtype(cfg))
    return out
