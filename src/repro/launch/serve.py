"""Batched serving driver: prefill a batch of prompts, then decode tokens.

CPU-scale entry point (the same decode/prefill steps lower on the
production mesh in the dry-run):

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \\
      --batch 4 --prompt-len 32 --gen 16

``--engine`` switches from the fixed-batch loop to the continuous-
batching :class:`repro.serve.ServeEngine` (in-flight admission over a
recycled slot pool) fed by the deterministic Poisson generator:

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \\
      --engine --requests 16 --rate 0.5

``--trace FILE`` records obs spans/counters either way (render with
scripts/trace_report.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models import model as M
from repro.obs import recorder as obs
from repro.train.serve_step import make_cache_rehome, make_decode_step


def _run_engine(cfg, params, args) -> None:
    from repro.serve import ServeConfig, ServeEngine, poisson_requests

    max_len = args.prompt_len + args.gen
    sc = ServeConfig(n_slots=args.batch, max_len=max_len,
                     prompt_pad=args.prompt_len,
                     temperature=args.temperature, seed=args.seed)
    eng = ServeEngine(cfg, params, sc)
    reqs = poisson_requests(
        n_requests=args.requests, rate=args.rate,
        vocab_size=cfg.vocab_size, prompt_lens=(args.prompt_len,),
        gen_range=(args.gen, args.gen), seed=args.seed)
    t0 = time.time()
    rep = eng.run(reqs)
    dt = time.time() - t0
    print(f"engine: {rep.completed}/{rep.n_requests} requests, "
          f"{rep.total_tokens} tokens in {rep.ticks} ticks "
          f"({dt:.2f}s, goodput {rep.goodput_tokens_per_tick:.2f} "
          f"tok/tick, occupancy {rep.occupancy_mean:.2f})")
    print(f"latency ticks p50/p95/p99: {rep.latency_p50:.1f}/"
          f"{rep.latency_p95:.1f}/{rep.latency_p99:.1f}  "
          f"ttft p50: {rep.ttft_p50:.1f}")
    first = min(rep.records)
    print("sampled token ids (first request):",
          rep.records[first].tokens)


def _run_batch(cfg, params, args) -> None:
    rec = obs.get_recorder()
    key = jax.random.PRNGKey(args.seed)
    batch = M.make_batch(cfg, args.batch, args.prompt_len, key)

    max_len = args.prompt_len + args.gen
    # prefill token-by-token through the decode path for recurrent archs;
    # transformer archs use the batched prefill
    t0 = time.time()
    with rec.span("serve.prefill", batch=args.batch,
                  prompt_len=args.prompt_len):
        logits, cache = jax.jit(
            lambda p, b: M.prefill(cfg, p, b))(params, batch)
        # one jitted re-home into the max_len decode cache (recurrent
        # state passes through, seq leaves land at the origin)
        cache = make_cache_rehome(cfg, args.batch, max_len)(cache)
    prefill_s = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} in {prefill_s:.2f}s")

    decode = make_decode_step(cfg)
    tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tokens]
    t0 = time.time()
    with rec.span("serve.decode", steps=args.gen):
        for i in range(args.gen):
            pos = jnp.int32(args.prompt_len + i)
            logits_t, cache = decode(params, tokens, cache, pos)
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                tokens = jax.random.categorical(
                    sub, logits_t / args.temperature, axis=-1
                ).astype(jnp.int32)[:, None]
            else:
                tokens = jnp.argmax(logits_t, axis=-1
                                    ).astype(jnp.int32)[:, None]
            out.append(tokens)
    gen_s = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"decode: {args.gen} steps x batch {args.batch} in {gen_s:.2f}s "
          f"({args.gen * args.batch / max(gen_s, 1e-9):.1f} tok/s)")
    print("sampled token ids (first row):", np.asarray(toks)[0].tolist())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", action="store_true",
                    help="continuous batching via repro.serve.ServeEngine "
                         "(slot pool of --batch, in-flight admission)")
    ap.add_argument("--requests", type=int, default=16,
                    help="--engine: number of Poisson requests")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="--engine: offered load in requests/tick")
    obs.add_trace_arg(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))

    rec = obs.activate_trace(args)
    try:
        if args.engine:
            _run_engine(cfg, params, args)
        else:
            _run_batch(cfg, params, args)
    finally:
        obs.finish_trace(rec)


if __name__ == "__main__":
    main()
