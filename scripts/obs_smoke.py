#!/usr/bin/env python
"""obs-smoke: prove the telemetry layer end to end (scripts/ci.sh stage).

On the 8-virtual-device platform, runs a short bucketed-overlap scenario
drill three ways:

1. **untraced reference** — the golden digest with the default no-op
   recorder (also warms the jit caches so the traced run times steady
   state, not compilation).
2. **traced** — the same spec under a :class:`repro.obs.TraceRecorder`
   writing JSONL; asserts the digest is BIT-IDENTICAL to the untraced
   run (tracing must never touch a traced value), that the
   ``vote.wire.bytes`` counter moved, and that every
   ``scripts/trace_report.py`` section renders from the trace.
3. **overhead** — measures the disabled-recorder cost (no-op span
   enter/exit x spans-per-step taken from the traced run) against the
   measured untraced step time and fails above the 2% budget the
   telemetry layer promises (DESIGN.md §13).

Usage:
    PYTHONPATH=src python scripts/obs_smoke.py [--out TRACE.jsonl]
                                               [--steps N]
                                               [--skip-overhead]

``--out`` keeps the trace (this is how the committed sample at
``benchmarks/traces/sample_trace.jsonl`` is produced); the default
writes under /tmp and is CI-disposable.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _force_devices() -> None:
    # before jax initialises; APPEND so a caller's unrelated XLA_FLAGS
    # (dump dirs etc.) survive
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()


def _add_src_path() -> None:
    try:
        import repro  # noqa: F401
    except ImportError:
        sys.path.insert(0, os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "src"))


def _spec(n_steps: int):
    """A spec exercising every telemetry surface at once: bucketed wire,
    double-buffered overlap walk, mixed codec map, adversaries."""
    from repro.configs.base import VoteStrategy
    from repro.sim import AdversarySpec, PlanSpec, ScenarioSpec
    return ScenarioSpec(
        "obs-smoke/bucketed-overlap", n_workers=8, n_steps=n_steps,
        dim=256, strategy=VoteStrategy.ALLGATHER_1BIT,
        adversary=AdversarySpec("sign_flip", 0.25),
        plan=PlanSpec(bucket_bytes=8, overlap=True,
                      leaves=(("embed.table", 96), ("body.blocks", 160)),
                      codec_map=(("embed*", "ternary2bit"),
                                 ("*", "sign1bit"))))


def main(argv=None) -> int:
    _force_devices()
    _add_src_path()
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="/tmp/obs_smoke_trace.jsonl",
                    help="where to write the JSONL trace")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--skip-overhead", action="store_true",
                    help="skip the no-op overhead measurement (timing "
                         "lane; meaningless under heavy host load)")
    args = ap.parse_args(argv)

    from repro.obs import recorder as obs
    from repro.obs import report
    from repro.sim import ScenarioRunner

    failures = 0

    def check(ok: bool, what: str) -> None:
        nonlocal failures
        print(("PASS " if ok else "FAIL ") + what, flush=True)
        if not ok:
            failures += 1

    spec = _spec(args.steps)

    # 1) traced run FIRST, on cold jit caches: the vote path's inner
    # jits trace inside the recording scope, so the plan walk's
    # issue/complete spans (which fire at trace time — the host-side
    # schedule-walk cost) land in the trace, pred_s and all
    rec = obs.TraceRecorder(args.out, meta={"harness": "obs_smoke",
                                            "scenario": spec.name,
                                            "n_steps": args.steps})
    obs.install_compile_watch()
    before = obs.COUNTERS.snapshot()
    with obs.recording(rec):
        traced = ScenarioRunner(spec, backend="virtual").run()
    rec.close()
    delta = obs.COUNTERS.delta_since(before)
    print(f"# traced digest {traced.digest[:16]}", flush=True)

    # 2) untraced reference on the now-warm caches: the digest must not
    # move by a bit either way
    ref = ScenarioRunner(spec, backend="virtual").run()
    check(traced.digest == ref.digest,
          "golden digest bit-identical with tracing on "
          f"({traced.digest[:16]})")
    check(delta.get("vote.wire.bytes", 0) > 0,
          f"vote.wire.bytes counted ({delta.get('vote.wire.bytes', 0)} B "
          "this run)")
    check(delta.get("vote.requests", 0) >= args.steps,
          f"vote.requests counted ({delta.get('vote.requests', 0)})")
    check(delta.get("plan.buckets", 0) > 0,
          f"plan.buckets counted ({delta.get('plan.buckets', 0)})")

    text = report.render(args.out)
    print(text, flush=True)
    for sec in report.SECTIONS:
        check(f"== {sec} ==" in text, f"report section renders: {sec}")
    rows = obs.read_trace(args.out)
    n_steps_rec = sum(1 for r in rows if r["kind"] == "step")
    n_spans = sum(1 for r in rows if r["kind"] == "span")
    check(n_steps_rec == args.steps,
          f"one step record per step ({n_steps_rec}/{args.steps})")
    check(n_spans > 0, f"spans recorded ({n_spans})")

    # 3) disabled-recorder overhead: the no-op span cost, scaled by the
    # spans-per-step the traced run actually took, must stay under 2% of
    # the measured untraced step time. (Conservative: disabled hot paths
    # gate attr computation on rec.enabled and skip most of these span
    # sites entirely.)
    if not args.skip_overhead:
        spans_per_step = max(1.0, n_spans / args.steps)
        n_iter = 200_000
        t0 = time.perf_counter()
        for _ in range(n_iter):
            with obs.get_recorder().span("overhead-probe"):
                pass
        per_span_s = (time.perf_counter() - t0) / n_iter
        t0 = time.perf_counter()
        ScenarioRunner(spec, backend="virtual").run()
        step_s = (time.perf_counter() - t0) / args.steps
        overhead = per_span_s * spans_per_step / step_s
        check(overhead < 0.02,
              f"no-op recorder overhead {overhead * 100:.4f}% of step "
              f"time (< 2% budget; {per_span_s * 1e9:.0f} ns/span x "
              f"{spans_per_step:.0f} spans/step vs "
              f"{step_s * 1e3:.2f} ms/step)")

    print(f"# wrote trace {args.out}", flush=True)
    print("obs-smoke: " + ("FAILED" if failures else "OK"), flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
