"""Mesh-level tests. They need 8 fake XLA devices, which must be configured
before jax initialises — so they run as a subprocess harness; the main
pytest session keeps the default single device."""
import os
import subprocess
import sys

import pytest

HARNESS = os.path.join(os.path.dirname(__file__), "distributed_harness.py")


@pytest.mark.slow
@pytest.mark.timeout(1200)
def test_distributed_harness():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, HARNESS], env=env, capture_output=True, text=True,
        timeout=1200)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distributed harness failed"
    assert "ALL DISTRIBUTED CHECKS PASSED" in proc.stdout
