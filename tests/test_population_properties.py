"""Property-based tests (hypothesis) for the streamed population engine
(DESIGN.md §12): a randomly composed population vote — voter count,
coordinate count, chunk size, sampled ids, dataset weights, adversary
mode/count, codec x strategy cell — either fails validation at BUILD
time with ValueError on BOTH forms, or executes on the dense stacked
path and the streamed engine with bit-identical votes (and, when routed
through the shared annotated implementation, bit-identical state). The
exactness-by-integers chunking argument, fuzzed.

``hypothesis`` is optional: without it this module skips; the
deterministic twins below the property test always run (tier-1).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import codecs as codecs_mod
from repro.core import vote_api as va

#: the streamed engine's realisable cells (hierarchical is rejected at
#: build time — its wire layout is O(M); asserted in test_population.py)
CELLS = [
    (VoteStrategy.PSUM_INT8, "sign1bit"),
    (VoteStrategy.PSUM_INT8, "ternary2bit"),
    (VoteStrategy.ALLGATHER_1BIT, "sign1bit"),
    (VoteStrategy.ALLGATHER_1BIT, "ternary2bit"),
    (VoteStrategy.ALLGATHER_1BIT, "weighted_vote"),
]
MODES = ["none", "sign_flip", "random", "zero", "colluding", "blind"]


def _check_pair(m, n, chunk, cell_i, mode, n_adv, sampled, weighted,
                seed):
    """Build the dense annotated request and its streamed twin from the
    same raw draws; both must validate identically, and when they
    execute, agree bit for bit (votes AND server state — both routes
    share the population engine, so state is exact)."""
    strategy, codec = CELLS[cell_i]
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    ids = (np.sort(rng.choice(4 * m, size=m, replace=False)
                   ).astype(np.int32) if sampled else
           np.arange(m, dtype=np.int32))
    w = (rng.integers(1, 100, size=m).astype(np.int32) if weighted
         else None)
    byz = (ByzantineConfig(mode=mode, num_adversaries=min(n_adv, m),
                           seed=1) if mode != "none" else None)
    pop = int(ids[-1]) + 1
    state = (codecs_mod.get_codec(codec).init_server_state(pop)
             if codec == "weighted_vote" else None)

    def build_dense():
        return va.VoteRequest(
            payload=vals, form="stacked", strategy=strategy, codec=codec,
            voter_ids=ids, weights=w, failures=va.FailureSpec(byz=byz),
            step=jnp.int32(5), salt=seed % 7, server_state=state)

    def build_streamed():
        stream = va.PopulationStream(
            n_voters=m, n_coords=n, ids=ids, weights=w,
            values=lambda want, _v=vals, _i=jnp.asarray(ids):
                _v[jnp.searchsorted(_i, want)])
        return va.VoteRequest(
            payload=stream, form="streamed", strategy=strategy,
            codec=codec, failures=va.FailureSpec(byz=byz),
            step=jnp.int32(5), salt=seed % 7, server_state=state)

    try:
        dense_req = build_dense()
    except ValueError:
        # invalid draws reject on BOTH forms — neither backend consulted
        with pytest.raises(ValueError):
            build_streamed()
        return "rejected"
    dense = va.VirtualBackend().execute(dense_req)
    streamed = va.VirtualBackend(chunk_size=chunk).execute(
        build_streamed())
    np.testing.assert_array_equal(np.asarray(dense.votes),
                                  np.asarray(streamed.votes))
    assert set(dense.server_state) == set(streamed.server_state)
    for k in dense.server_state:
        np.testing.assert_array_equal(
            np.asarray(dense.server_state[k]),
            np.asarray(streamed.server_state[k]))
    votes = np.asarray(streamed.votes)
    assert votes.shape == (n,) and votes.dtype == np.int8
    assert set(np.unique(votes)) <= {-1, 0, 1}
    return "executed"


# ---------------------------------------------------------------------------
# deterministic twins (always run; every cell, both outcomes, ragged and
# degenerate chunkings)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cell", [
    # m, n, chunk, cell_i, mode, n_adv, sampled, weighted, seed
    (1, 16, 1, 0, "none", 0, False, False, 0),
    (9, 33, 4, 1, "sign_flip", 3, True, False, 1),
    (17, 24, 5, 2, "colluding", 6, True, True, 2),
    (33, 40, 33, 3, "blind", 8, False, True, 3),
    (26, 31, 7, 4, "random", 4, True, True, 4),
    (12, 20, 100, 4, "zero", 2, False, False, 5),
])
def test_twins_deterministic(cell):
    assert _check_pair(*cell) == "executed"


def test_twins_deterministic_rejection():
    # weighted_vote cannot ride the integer-count psum wire: both the
    # dense annotated form and the streamed form reject at build time
    vals = jnp.ones((8, 16), jnp.float32)
    state = codecs_mod.get_codec("weighted_vote").init_server_state(8)
    with pytest.raises(ValueError):
        va.VoteRequest(payload=vals, form="stacked",
                       strategy=VoteStrategy.PSUM_INT8,
                       codec="weighted_vote",
                       voter_ids=np.arange(8), server_state=state)
    stream = va.PopulationStream(
        n_voters=8, n_coords=16, values=lambda ids, _v=vals: _v[ids])
    with pytest.raises(ValueError):
        va.VoteRequest(payload=stream, form="streamed",
                       strategy=VoteStrategy.PSUM_INT8,
                       codec="weighted_vote", server_state=state)


# ---------------------------------------------------------------------------
# the hypothesis sweep (guarded import so the twins above ALWAYS run)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None


if given is not None:
    @given(st.integers(1, 40), st.integers(1, 48), st.integers(1, 50),
           st.integers(0, len(CELLS) - 1), st.sampled_from(MODES),
           st.integers(0, 6), st.booleans(), st.booleans(),
           st.integers(0, 5))
    @settings(max_examples=25, deadline=None)
    def test_random_population_votes_match_dense(
            m, n, chunk, cell_i, mode, n_adv, sampled, weighted, seed):
        _check_pair(m, n, chunk, cell_i, mode, n_adv, sampled, weighted,
                    seed)
else:
    @pytest.mark.skip(reason="property sweep needs hypothesis; the "
                      "deterministic twins above cover the invariant")
    def test_random_population_votes_match_dense():
        pass
