"""``weighted_vote`` — reliability-weighted sign decoding (SignSGD-FD).

Park & Lee (arXiv:2402.01340) observe that the server need not count sign
votes uniformly: if it tracks how often each worker's vote disagrees with
the decoded direction, it can decode a *weighted* vote that discounts —
and, past 50% estimated flip rate, actively inverts — unreliable workers.
This is the Chair–Varshney optimal fusion rule for M binary channels with
flip probabilities p_m:

    w_m  = log((1 - p_m) / p_m)
    vote = sign( Σ_m w_m · s_m )          (ties → +1, the 1-bit wire rule)

A consistent sign-flipper drifts to p_m → 1, w_m < 0, and its votes turn
into evidence *for* the honest direction — gradient-sign decoding turns
the adversary's own transmissions against it. The estimate p_m is an EMA
of observed disagreement with the decoded vote, so the defense is learned
on-line; it converges to the right labelling only while the unweighted
majority starts out honest (adversary fraction < 1/2 at warm-up —
Theorem 2's regime; beyond it the roles invert). With equal state across
workers (the all-zero uninformed prior included) every weight is equal
and the decode IS the unweighted ``allgather_1bit`` majority, bit for
bit (`tests/test_codecs.py` pins both properties).

Wire: the codec rides ``allgather_1bit`` unchanged — packed 1-bit signs,
every chip plays the server — because weighting needs the individual
votes, which only the gathered wire preserves (a psum destroys them; the
per-step extra payload is the (M,) state, ~M floats, amortised to ~0
bits/param). Server state `flip_ema` is an (M,) vector replicated on
every chip, updated identically everywhere from the gathered wire, and
refits across elastic rescale by ``checkpoint.refit_leading_axis`` —
zero-padded joiners enter at the uninformed prior.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import VoteStrategy
from repro.core.codecs.base import GradientCodec

#: EMA rate of the per-worker disagreement estimate
RHO = 0.5
#: flip-probability clip: bounds the weights to ±log((1-eps)/eps) and
#: keeps the all-zero prior finite
P_MIN = 0.05


def reliability_weights(flip_ema: jax.Array) -> jax.Array:
    """(M,) flip-rate estimates -> (M,) Chair–Varshney log-odds weights,
    quantized to multiples of 1/256.

    The quantization is what makes the decode *deterministic in the
    reduction order*: every weight (and so every term w_m·s_m and every
    partial sum, |Σ| < 2^16) is an exact float32 multiple of 2^-8, so the
    weighted sum is exact integer arithmetic however XLA associates it —
    measured without it, a 12-voter exact tie summed to -1.2e-7 under one
    lowering and +0.0 under another, silently flipping the tie rule. It
    also pins the equal-weights decode to the unweighted majority bit for
    bit (ties included), and costs < 0.2% weight precision — noise next
    to the EMA's own estimation error."""
    p = jnp.clip(flip_ema, P_MIN, 1.0 - P_MIN)
    return jnp.round(jnp.log((1.0 - p) / p) * 256.0) / 256.0


def decode_leaf_fixed(stacked: jax.Array, w: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """(M, ...) ±1 signs + (M,) FIXED weights -> ((...) ±1 vote,
    (M,) per-worker mismatch counts vs that vote).

    THE weighted decode expression — shared by the mesh tally (where
    every replica holds the gathered stack), the virtual mesh, and the
    trainer's tree path (weights fixed for the step, mismatch counts
    aggregated across leaves) — so backend bit-identity holds by
    construction. Callers must crop bit-pack padding lanes BEFORE calling
    (padding always agrees with the vote, so counting it would dilute the
    flip-rate observations)."""
    wshape = (w.shape[0],) + (1,) * (stacked.ndim - 1)
    wsum = jnp.sum(w.reshape(wshape) * stacked.astype(jnp.float32), axis=0)
    vote = jnp.where(wsum >= 0, jnp.int8(1), jnp.int8(-1))
    mismatch = jnp.sum((stacked != vote[None]).astype(jnp.float32),
                       axis=tuple(range(1, stacked.ndim)))
    return vote, mismatch


def decode_stacked(stacked: jax.Array, flip_ema: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """(M, ...) ±1 signs + (M,) state -> ((...) ±1 vote, (M,) new state).

    One decode + one EMA update; `stacked` must already be cropped to the
    true coordinate count (no padding lanes)."""
    vote, mismatch = decode_leaf_fixed(stacked,
                                       reliability_weights(flip_ema))
    n = stacked.size // stacked.shape[0]
    new_ema = (1.0 - RHO) * flip_ema + RHO * mismatch / n
    return vote, new_ema


class WeightedVoteCodec(GradientCodec):
    name = "weighted_vote"
    bits_per_param = 1.0
    supported_strategies = (VoteStrategy.ALLGATHER_1BIT,)
    server_state = True

    def init_server_state(self, n_workers: int) -> Dict[str, jax.Array]:
        # all-zero = uninformed prior: equal weights, unweighted decode
        return {"flip_ema": jnp.zeros((n_workers,), jnp.float32)}

    def ties(self, strategy: VoteStrategy) -> str:
        return "plus_one"   # weighted sum >= 0 -> +1 (1-bit wire rule)
