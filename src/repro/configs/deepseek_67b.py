"""deepseek-67b — llama-arch dense transformer, GQA kv=8.

[arXiv:2401.02954; hf]  95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400.
"""
from repro.configs.base import SKIP_LONG, ArchFamily, ModelConfig, register


@register("deepseek-67b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        family=ArchFamily.DENSE,
        num_layers=95,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22016,
        vocab_size=102_400,
        head_dim=128,
        tie_embeddings=False,
        act_seq_shard=True,
        skip_shapes=(SKIP_LONG,),
    )
