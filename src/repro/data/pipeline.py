"""Deterministic, sharded, checkpointable synthetic LM data pipeline.

Production pipelines (SSTable/ArrayRecord readers) are replaced by a
seeded synthetic token stream with the same *interface contract*:

* deterministic: batch at step k is a pure function of (seed, k) — replay
  after restart yields bit-identical batches;
* sharded: each data-parallel replica draws only its slice (host-local
  reads on a real pod);
* checkpointable: the cursor is a single integer restored from the train
  checkpoint;
* schema-aware: emits the stub frontend embeddings for whisper/pixtral.

The synthetic distribution is a per-document Markov chain over the vocab
(not iid-uniform) so the loss has learnable structure — convergence tests
and examples train on it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchFamily, ModelConfig


@dataclasses.dataclass
class DataState:
    step: int = 0

    def to_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d) -> "DataState":
        return cls(step=int(d["step"]))


class SyntheticLMPipeline:
    """Markov-chain token stream.

    ``global_batch`` rows per step; ``replica_batch(replica, n_replicas)``
    returns only that replica's rows (deterministic function of step).
    """

    def __init__(self, cfg: ModelConfig, global_batch: int, seq_len: int,
                 seed: int = 0, order: int = 2):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.state = DataState()
        # small Markov backbone: vocab maps onto `order`-step cycle classes
        rng = np.random.default_rng(seed)
        self._classes = 64
        self._trans = rng.dirichlet(
            np.ones(self._classes) * 0.3, size=self._classes)
        self._class_of = rng.integers(0, self._classes, size=cfg.vocab_size)
        # tokens of each class (for sampling)
        self._members = [np.where(self._class_of == c)[0]
                         for c in range(self._classes)]
        for c in range(self._classes):
            if len(self._members[c]) == 0:
                self._members[c] = np.array([c % cfg.vocab_size])

    # ----- core determinism: batch is a pure function of (seed, step) -----
    def _rows(self, step: int, row_lo: int, row_hi: int) -> np.ndarray:
        out = np.empty((row_hi - row_lo, self.seq_len), np.int32)
        for r in range(row_lo, row_hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, r]))
            cls = rng.integers(0, self._classes)
            toks = np.empty(self.seq_len, np.int32)
            for t in range(self.seq_len):
                members = self._members[cls]
                toks[t] = members[rng.integers(0, len(members))]
                cls = rng.choice(self._classes, p=self._trans[cls])
            out[r - row_lo] = toks
        return out

    def _frontend(self, step: int, batch: int) -> Optional[np.ndarray]:
        cfg = self.cfg
        if not cfg.embed_frontend_stub:
            return None
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, 1 << 20]))
        if cfg.family == ArchFamily.AUDIO:
            t = min(cfg.max_source_positions, 64)
        else:  # VLM patches: quarter of the sequence
            t = max(self.seq_len // 4, 1)
        return rng.normal(size=(batch, t, cfg.d_model)).astype(np.float32)

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        tokens = self._rows(step, 0, self.global_batch)
        return self._assemble(step, tokens)

    def replica_batch(self, step: int, replica: int, n_replicas: int
                      ) -> Dict[str, np.ndarray]:
        per = self.global_batch // n_replicas
        tokens = self._rows(step, replica * per, (replica + 1) * per)
        return self._assemble(step, tokens)

    def _assemble(self, step: int, tokens: np.ndarray) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        batch: Dict[str, np.ndarray] = {"tokens": tokens}
        fe = self._frontend(step, tokens.shape[0])
        if fe is not None:
            if cfg.family == ArchFamily.AUDIO:
                batch["enc_embeds"] = fe
            else:
                s_img = fe.shape[1]
                batch["patch_embeds"] = fe
                batch["tokens"] = tokens[:, : self.seq_len - s_img]
        return batch

    # ----- iterator protocol with checkpointable cursor -----
    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.global_batch_at(self.state.step)
        self.state.step += 1
        return b

    def checkpoint(self) -> Dict[str, int]:
        return self.state.to_dict()

    def restore(self, d: Dict[str, int]) -> None:
        self.state = DataState.from_dict(d)
