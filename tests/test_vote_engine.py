"""VoteEngine acceptance tests (deterministic; no hypothesis needed).

* every strategy's pack -> exchange -> tally -> unpack pipeline, driven
  through the VoteEngine interface on a simulated M-voter mesh (vmapped
  stages with numpy collectives), is bit-identical to the kernels/ref.py
  oracle semantics on random TERNARY inputs, including exact-tie and
  all-abstain coordinates;
* the fused Pallas kernel is bit-identical to ref.fused_majority;
* the comm accounting and the AUTO selector are sane (monotone, resolve to
  a concrete strategy, 1-bit wire = fp32/32).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import VoteStrategy
from repro.core import sign_compress as sc
from repro.core.vote_engine import (STRATEGIES, VoteEngine, count_dtype,
                                    resolve_strategy, select_strategy)
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _ternary(m, n, tie_cols=8):
    """(m, n) int8 in {-1, 0, +1} with engineered tie / abstain columns."""
    s = RNG.integers(-1, 2, size=(m, n)).astype(np.int8)
    k = min(tie_cols, n // 3)
    if k and m >= 2:
        half = m // 2
        s[:half, :k] = 1
        s[half:, :k] = -1          # exact tie (even m) / +1 majority (odd)
        s[:, k:2 * k] = 0          # unanimous abstention
    return s


def _simulate(strategy: VoteStrategy, signs: np.ndarray) -> np.ndarray:
    """Run the strategy's OWN pack/tally/unpack stages host-side, replacing
    the mesh exchange with its numpy equivalent — the engine pipeline with
    the collective swapped out, so stage semantics are what is tested."""
    impl = STRATEGIES[strategy]
    m, n = signs.shape
    if strategy == VoteStrategy.PSUM_INT8:
        wires = np.stack([np.asarray(impl.pack(jnp.asarray(s), m))
                          for s in signs])
        arrived = wires.sum(axis=0, dtype=np.int32)      # the psum
        dec = impl.tally(jnp.asarray(arrived), m)
        return np.asarray(impl.unpack(dec, n, jnp.int8))
    if strategy == VoteStrategy.ALLGATHER_1BIT:
        wires = np.stack([np.asarray(impl.pack(jnp.asarray(s), m))
                          for s in signs])                # the all-gather
        dec = impl.tally(jnp.asarray(wires), m)
        return np.asarray(impl.unpack(dec, n, jnp.int8))
    # hierarchical, collapsed to one host "pod shard": RS+psum == full sum
    pad = (-n) % sc.PACK
    padded = np.pad(signs, ((0, 0), (0, pad)))
    counts = padded.astype(np.int32).sum(axis=0)         # RS + pod psum
    dec = impl.tally(jnp.asarray(counts), m)
    return np.asarray(sc.unpack_signs(sc.pack_signs(jnp.asarray(
        np.asarray(dec))), jnp.int8))[:n]


@pytest.mark.parametrize("strategy", [VoteStrategy.PSUM_INT8,
                                      VoteStrategy.ALLGATHER_1BIT,
                                      VoteStrategy.HIERARCHICAL])
@pytest.mark.parametrize("m,n", [(2, 64), (3, 37), (16, 200), (15, 1000)])
def test_strategy_stages_match_ref_semantics(strategy, m, n):
    """Every strategy, through its engine stages, reproduces the reference
    majority for its tie convention on ternary inputs."""
    signs = _ternary(m, n)
    got = _simulate(strategy, signs)
    counts = signs.astype(np.int32).sum(axis=0)
    if strategy == VoteStrategy.PSUM_INT8:
        expect = np.sign(counts).astype(np.int8)     # ties/abstain -> 0
    elif strategy == VoteStrategy.HIERARCHICAL:
        # counts ternary signs (0 abstains), binarises at the 1-bit
        # rebroadcast: ties -> +1
        expect = np.where(counts >= 0, 1, -1).astype(np.int8)
    else:
        # 1-bit wire: ref.py semantics — pack binarises (0 -> +1), popcount
        # majority with ties -> +1
        packed = np.stack([
            np.asarray(sc.pack_signs(jnp.asarray(
                np.pad(s, (0, (-n) % sc.PACK)).astype(np.float32))))
            for s in signs])
        maj = ref.majority(jnp.asarray(packed))
        expect = np.asarray(sc.unpack_signs(maj, jnp.int8))[:n]
    np.testing.assert_array_equal(got, expect, err_msg=str(strategy))


@pytest.mark.parametrize("m,n", [(3, 100), (5, 321), (15, 64)])
def test_all_strategies_bit_identical_to_ref_on_odd_m(m, n):
    """With ±1 inputs and odd M no coordinate can tie, so EVERY strategy's
    engine pipeline must be bit-identical to the kernels/ref.py majority."""
    signs = np.where(RNG.integers(0, 2, size=(m, n)) == 1, 1, -1) \
        .astype(np.int8)
    packed = np.stack([
        np.asarray(sc.pack_signs(jnp.asarray(
            np.pad(s, (0, (-n) % sc.PACK)).astype(np.float32))))
        for s in signs])
    expect = np.asarray(
        sc.unpack_signs(ref.majority(jnp.asarray(packed)), jnp.int8))[:n]
    for strategy in (VoteStrategy.PSUM_INT8, VoteStrategy.ALLGATHER_1BIT,
                     VoteStrategy.HIERARCHICAL):
        got = _simulate(strategy, signs)
        np.testing.assert_array_equal(got, expect, err_msg=str(strategy))


@pytest.mark.parametrize("m", [1, 2, 5, 15, 16])
def test_engine_stacked_vote_bit_identical_to_ref(m):
    """VoteEngine.vote_stacked (the fused-Pallas local tally) == ref.py on
    random ternary inputs including tie columns."""
    n = 500
    x = _ternary(m, n).astype(np.float32)
    eng = VoteEngine(strategy=VoteStrategy.ALLGATHER_1BIT)
    got = np.asarray(eng.vote_stacked(jnp.asarray(x)))
    pad = (-n) % sc.PACK
    want_packed = ref.fused_majority(jnp.asarray(np.pad(x, ((0, 0), (0, pad)))))
    want = np.asarray(sc.unpack_signs(want_packed, jnp.int8))[:n]
    np.testing.assert_array_equal(got, want)
    # and the jnp fallback agrees with the kernel path
    jnp_path = np.asarray(eng.vote_stacked(jnp.asarray(x), use_kernels=False))
    np.testing.assert_array_equal(got, jnp_path)


def test_fused_kernel_vs_staged_kernels():
    """fused_majority == bitpack-per-voter + majority (the hot path it
    replaces)."""
    m, n = 9, 10_000
    x = RNG.normal(size=(m, n)).astype(np.float32)
    fused = np.asarray(ops.fused_majority(jnp.asarray(x)))
    staged = np.asarray(ops.majority(jnp.stack(
        [ops.bitpack(jnp.asarray(r)) for r in x])))
    np.testing.assert_array_equal(fused, staged)


# ---------------------------------------------------------------------------
# accounting / selection
# ---------------------------------------------------------------------------


def test_wire_bits_allgather_is_fp32_over_32():
    impl = STRATEGIES[VoteStrategy.ALLGATHER_1BIT]
    n = 1 << 20
    assert impl.payload_bytes(n) == pytest.approx((n * 4) / 32.0)


def test_ring_bytes_match_comm_accounting():
    from repro.core.majority_vote import comm_bytes_per_step
    for strat in (VoteStrategy.PSUM_INT8, VoteStrategy.ALLGATHER_1BIT,
                  VoteStrategy.HIERARCHICAL):
        c = comm_bytes_per_step(1 << 22, strat, data_size=16, pod_size=2)
        b = STRATEGIES[strat].ring_bytes(1 << 22, 16, 2)
        assert c["vote"] == pytest.approx(b["total"])


def test_auto_resolves_to_concrete_strategy():
    for n in (1 << 10, 1 << 20, 1 << 30):
        for data, pod in ((1, 1), (8, 1), (16, 2)):
            s = resolve_strategy(VoteStrategy.AUTO, n, data, pod)
            assert s in STRATEGIES
    # concrete strategies resolve to themselves
    assert resolve_strategy(VoteStrategy.PSUM_INT8, 1, 16) \
        == VoteStrategy.PSUM_INT8


def test_auto_tracks_cost_model():
    """The selector picks bandwidth-optimal at scale, latency-optimal when
    tiny, and is the argmin of the strategies' own time estimates."""
    big = select_strategy(1 << 30, data_size=16)
    times = {k: s.estimated_time(1 << 30, 16) for k, s in STRATEGIES.items()}
    assert big == min(times, key=times.get)
    assert times[big] == min(times.values())
    assert select_strategy(1 << 30, 16) == VoteStrategy.HIERARCHICAL
    # single replica: trivially psum (no wire traffic at all)
    assert select_strategy(1 << 30, 1) == VoteStrategy.PSUM_INT8


def test_count_dtype_widens():
    assert count_dtype(16) == jnp.int8
    assert count_dtype(128) == jnp.int16
    assert count_dtype(40_000) == jnp.int32


def test_trainer_resolves_auto(tmp_path):
    """make_train_step compiles AUTO down to a concrete strategy and
    records it in the artifacts."""
    from repro.configs.base import (OptimizerConfig, TrainConfig, get_config,
                                    reduced_config)
    from repro.train import train_step as TS
    cfg = reduced_config(get_config("glm4-9b"), num_layers=1)
    tcfg = TrainConfig(
        global_batch=4, seq_len=16,
        optimizer=OptimizerConfig(kind="signum_vote",
                                  vote_strategy=VoteStrategy.AUTO))
    art = TS.make_train_step(cfg, tcfg, mesh=None)
    assert art.vote_strategy in STRATEGIES
    params, opt = TS.materialize_state(cfg, tcfg, art, jax.random.PRNGKey(0))
    from repro.models import model as M
    batch = M.make_batch(cfg, 4, 16, jax.random.PRNGKey(1))
    p2, _, _ = art.step_fn(params, opt, batch, jnp.int32(0))
    assert all(np.isfinite(np.asarray(v, np.float32)).all()
               for v in p2.values())
