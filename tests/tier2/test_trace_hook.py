"""The trainer-side trace hook: TrainConfig.diagnostics=True surfaces
per-step vote diagnostics (agreement with the vote, vote margin) in the
step metrics — the same schema the Scenario Lab traces record, captured
from a real train step.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (OptimizerConfig, TrainConfig, get_config,
                                reduced_config)
from repro.models import model as M
from repro.train import train_step as TS


def test_diagnostics_in_step_metrics():
    cfg = reduced_config(get_config("glm4-9b"), num_layers=1)
    tcfg = TrainConfig(global_batch=4, seq_len=16, diagnostics=True,
                       optimizer=OptimizerConfig(kind="signum_vote",
                                                 learning_rate=1e-3))
    art = TS.make_train_step(cfg, tcfg, mesh=None)
    params, opt = TS.materialize_state(cfg, tcfg, art, jax.random.PRNGKey(0))
    batch = M.make_batch(cfg, 4, 16, jax.random.PRNGKey(1))
    _, _, met = art.step_fn(params, opt, batch, jnp.int32(0))
    assert "vote_agreement" in met and "vote_margin" in met
    # M=1: every replica agrees with itself; margin = mean |sign| <= 1
    assert float(met["vote_agreement"]) == 1.0
    assert 0.0 < float(met["vote_margin"]) <= 1.0


def test_diagnostics_keys_present_when_all_leaves_fused():
    """Mode B with every leaf on the fused vote-in-backward path cannot
    observe the wire in the optimizer — the metric keys must still exist
    (NaN), so trace consumers never KeyError."""
    from repro.configs.base import MomentumMode
    from repro.core.signum import make_sign_optimizer

    cfg = OptimizerConfig(kind="signsgd_vote",
                          momentum_mode=MomentumMode.GLOBAL,
                          learning_rate=1e-3)
    opt = make_sign_optimizer(cfg, axes=(), voted_leaves=("w",),
                              diagnostics=True)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    _, _, diag = opt.update({"w": jnp.ones((4,))}, state, params,
                            jnp.int32(0))
    assert np.isnan(float(diag["vote_agreement"]))
    assert np.isnan(float(diag["vote_margin"]))


def test_diagnostics_off_by_default():
    cfg = reduced_config(get_config("glm4-9b"), num_layers=1)
    tcfg = TrainConfig(global_batch=4, seq_len=16,
                       optimizer=OptimizerConfig(kind="signum_vote",
                                                 learning_rate=1e-3))
    art = TS.make_train_step(cfg, tcfg, mesh=None)
    params, opt = TS.materialize_state(cfg, tcfg, art, jax.random.PRNGKey(0))
    batch = M.make_batch(cfg, 4, 16, jax.random.PRNGKey(1))
    _, _, met = art.step_fn(params, opt, batch, jnp.int32(0))
    assert "vote_margin" not in met
