"""Shared CLI for the ``rows()``-only bench modules.

`bench_speedup`, `bench_convergence`, `bench_noise` and `roofline`
predate the telemetry layer: they expose ``rows()`` for the
``benchmarks.run`` driver but had no entry point of their own, so a
standalone invocation could neither trace nor emit the perf-gate JSON.
:func:`rows_main` is the one adapter — the shared ``--trace`` flag
(``repro.obs.add_trace_arg``) plus ``--emit-json`` routed through
``repro.obs.emit_bench_json``, the single writer whose schema
``scripts/perf_gate.py`` gates — so every benchmark in the repo emits
uniform JSON and spans whichever way it is launched.
"""
from __future__ import annotations

import argparse
from typing import Callable, List, Optional, Sequence, Tuple

from repro.obs import recorder as obs

Row = Tuple[str, float, str]


def rows_main(key: str, doc: Optional[str],
              rows_fn: Callable[[], List[Row]],
              argv: Optional[Sequence[str]] = None) -> None:
    """Run one bench module standalone: print the ``name,value,derived``
    CSV (the same rows ``benchmarks.run`` would collect), honour
    ``--trace`` (suite span + counters into a JSONL trace) and
    ``--emit-json`` (perf-gate schema; default file ``BENCH_<key>.json``
    when the flag is given bare)."""
    ap = argparse.ArgumentParser(description=doc)
    default_json = f"BENCH_{key}.json"
    ap.add_argument("--emit-json", dest="json_out", nargs="?",
                    const=default_json, default=None,
                    help=f"write rows as perf-gate JSON "
                         f"(default {default_json})")
    obs.add_trace_arg(ap)
    args = ap.parse_args(argv)

    rec = obs.activate_trace(args)
    try:
        with obs.get_recorder().span("bench.suite", key=key):
            rs = rows_fn()
        print("name,value,derived")
        for name, value, derived in rs:
            print(f"{name},{value:.6g},{derived}", flush=True)
        if args.json_out:
            obs.emit_bench_json(rs, args.json_out)
            print(f"# wrote {args.json_out}", flush=True)
    finally:
        obs.finish_trace(rec)
