"""zamba2-style hybrid stack: Mamba2 backbone + one weight-shared attention
block applied after every ``shared_attn_every`` mamba layers.

The mamba backbone scans in segments (static slices of the stacked layer
params); after each full segment the shared block (single weight set,
re-invoked) runs. Each shared-block invocation owns its own KV cache slot
for decoding.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import BATCH, shard
from repro.models import layers as L
from repro.models.mamba2 import (mamba2_decode_step, mamba2_forward,
                                 mamba2_init_state)


def _layer_tree(p, prefix="layers."):
    return {k[len(prefix):]: v for k, v in p.items() if k.startswith(prefix)}


def _shared_tree(p):
    return {k[len("shared_block."):]: v for k, v in p.items()
            if k.startswith("shared_block.")}


def _segments(cfg) -> List[Tuple[int, int, bool]]:
    """(start, end, shared_after) segments of the mamba stack."""
    segs = []
    e = cfg.shared_attn_every
    start = 0
    while start < cfg.num_layers:
        end = min(start + e, cfg.num_layers)
        segs.append((start, end, end - start == e))
        start = end
    return segs


def _mamba_segment_scan(lp: Dict[str, jax.Array], h: jax.Array, cfg,
                        start: int, end: int, hook=None,
                        remat: str = "none") -> jax.Array:
    from repro.models.transformer import maybe_remat
    seg = {k: v[start:end] for k, v in lp.items()}

    def body(carry, layer_p):
        if hook is not None:
            layer_p = hook(layer_p, "layers")
        x = L.rms_norm(carry, layer_p["norm1_scale"], cfg.norm_eps)
        carry = carry + mamba2_forward(layer_p, x, cfg)
        from repro.models.transformer import residual_shard
        return residual_shard(carry, cfg), None

    h, _ = jax.lax.scan(maybe_remat(body, remat), h, seg)
    return h


def _shared_attn_block(sp: Dict[str, jax.Array], h: jax.Array, cfg
                       ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    x = L.rms_norm(h, sp["norm1_scale"], cfg.norm_eps)
    attn_out, kv = L.self_attention_block(sp, "attn", x, cfg, causal=True)
    h = h + attn_out
    x = L.rms_norm(h, sp["norm2_scale"], cfg.norm_eps)
    h = h + L.swiglu_mlp(sp, "mlp", x)
    return h, kv


def hybrid_forward(p: Dict[str, jax.Array], h: jax.Array, cfg,
                   hook=None, remat: str = "none") -> jax.Array:
    from repro.models.transformer import maybe_remat
    lp, sp = _layer_tree(p), _shared_tree(p)

    def shared_fn(sp_, h_):
        return _shared_attn_block(sp_, h_, cfg)[0]

    shared_fn = maybe_remat(shared_fn, remat)
    for start, end, shared_after in _segments(cfg):
        h = _mamba_segment_scan(lp, h, cfg, start, end, hook=hook,
                                remat=remat)
        if shared_after:
            h = shared_fn(sp, h)
    return h


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def hybrid_init_cache(cfg, batch: int, max_len: int, dtype
                      ) -> Dict[str, jax.Array]:
    st = mamba2_init_state(cfg, batch, dtype)
    n_calls = cfg.num_shared_attn_calls
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (n_calls, batch, max_len, K, hd)
    return {
        "ssm": jnp.zeros((cfg.num_layers,) + st["ssm"].shape, jnp.float32),
        "conv": jnp.zeros((cfg.num_layers,) + st["conv"].shape, dtype),
        "attn_k": jnp.zeros(shape, dtype),
        "attn_v": jnp.zeros(shape, dtype),
    }


def hybrid_decode_step(p: Dict[str, jax.Array], h: jax.Array, cache,
                       pos: jax.Array, cfg):
    lp, sp = _layer_tree(p), _shared_tree(p)
    new_ssm, new_conv = [], []
    new_k, new_v = [], []
    call_idx = 0
    for start, end, shared_after in _segments(cfg):
        seg = {k: v[start:end] for k, v in lp.items()}

        def body(carry, xs):
            layer_p, ssm, conv = xs
            x = L.rms_norm(carry, layer_p["norm1_scale"], cfg.norm_eps)
            out, st = mamba2_decode_step(layer_p, x, {"ssm": ssm, "conv": conv}, cfg)
            return carry + out, (st["ssm"], st["conv"])

        h, (ssm_seg, conv_seg) = jax.lax.scan(
            body, h, (seg, cache["ssm"][start:end], cache["conv"][start:end]))
        new_ssm.append(ssm_seg)
        new_conv.append(conv_seg)
        if shared_after:
            x = L.rms_norm(h, sp["norm1_scale"], cfg.norm_eps)
            attn_out, k_c, v_c = L.decode_self_attention(
                sp, "attn", x, cfg,
                k_cache=cache["attn_k"][call_idx],
                v_cache=cache["attn_v"][call_idx], pos=pos)
            h = h + attn_out
            x = L.rms_norm(h, sp["norm2_scale"], cfg.norm_eps)
            h = h + L.swiglu_mlp(sp, "mlp", x)
            new_k.append(k_c)
            new_v.append(v_c)
            call_idx += 1
    return h, {
        "ssm": jnp.concatenate(new_ssm, axis=0),
        "conv": jnp.concatenate(new_conv, axis=0),
        "attn_k": jnp.stack(new_k, axis=0),
        "attn_v": jnp.stack(new_v, axis=0),
    }
