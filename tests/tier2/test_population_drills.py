"""Federated-population tier-2 drill (DESIGN.md §12).

Host-count invariance of a population scenario exercising EVERY §12
axis at once — client sampling, a churn schedule (leave then join),
dataset-weighted votes, the weighted_vote reliability codec over the
gathered wire, and a colluding adversary over the logical population:
the streamed replay on a 1-device platform and on the 8-device platform
must produce one digest (every PRNG draw is keyed by logical client
id / step, never by device placement), and within each platform the
replay at a prime chunk size and at chunk_size=population must agree
bit for bit (the exactness-by-integers chunking invariant). Each
platform needs its own process (XLA device count is fixed before jax
initialises), hence the subprocess pattern of test_plan_drills.py.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_WORKER = textwrap.dedent("""
    import dataclasses
    from repro.configs.base import VoteStrategy
    from repro.core import population
    from repro.sim import (AdversarySpec, ChurnEvent, PopulationSpec,
                           ScenarioRunner, ScenarioSpec)

    spec = ScenarioSpec(
        "pop-drill/federated_all_axes", n_steps=6, dim=96, momentum=0.0,
        strategy=VoteStrategy.ALLGATHER_1BIT, codec="weighted_vote",
        adversary=AdversarySpec("colluding", 0.3),
        population=PopulationSpec(
            n_clients=60, sample_fraction=0.35, weighting="dataset",
            max_data=40,
            churn=(ChurnEvent(2, leave=20, note="region outage"),
                   ChurnEvent(4, join=33, note="rejoin + growth")),
            chunk_size=7))
    tr = ScenarioRunner(spec, backend="virtual").run()
    print("POPS", "-".join(str(s.n_population) for s in tr.steps))
    print("PEAK", population.LAST_STATS["peak_rows"])
    print("VDIGEST", tr.digest)
    # the chunking invariant, within this platform: one chunk holds the
    # whole sampled round -> dense-order accumulation, same bits
    whole = dataclasses.replace(
        spec, population=dataclasses.replace(spec.population,
                                             chunk_size=73))
    print("SDIGEST", ScenarioRunner(whole, backend="virtual").run().digest)
""")


def _run(device_count: int):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "..", "src"),
         env.get("PYTHONPATH", "")])
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={device_count}"
    proc = subprocess.run([sys.executable, "-c", _WORKER, "drill"],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "population drill worker failed"
    return {line.split()[0]: line.split()[1]
            for line in proc.stdout.splitlines()
            if line.split() and line.split()[0] in
            ("VDIGEST", "SDIGEST", "POPS", "PEAK")}


@pytest.mark.slow
@pytest.mark.timeout(900)
def test_population_drill_is_host_count_and_chunk_invariant():
    d8 = _run(8)
    d1 = _run(1)
    # the churn schedule actually moved the population (60 -> 40 -> 73)
    assert d8["POPS"] == "60-60-40-40-73-73"
    # the streamed engine never materialized more than one chunk of rows
    assert int(d8["PEAK"]) <= 7
    assert d8["VDIGEST"] == d8["SDIGEST"], (
        "population drill digest moved with the chunk size — an "
        "engine reduction is not exact integer arithmetic")
    assert d8["VDIGEST"] == d1["VDIGEST"], (
        "population drill digest differs between 8-device and 1-device "
        "replays — a PRNG stream or reduction is keyed by device "
        "placement instead of logical client id")
