"""Majority-vote aggregation of sign gradients on a TPU mesh.

The paper's parameter server is replaced by collectives (DESIGN.md §2).
All functions here run *inside* a ``shard_map`` that is manual over the
vote axes (``'data'`` and, multi-pod, ``'pod'``) — per-replica values are
visible and every collective is explicit.

Strategies (flat, over a replica-local 1-D sign tensor):

* ``psum_int8``      — int-sum of signs over the vote axes, then sign.
                       One all-reduce of int8 (int16 above 127 replicas).
* ``allgather_1bit`` — paper-faithful wire protocol: bit-pack to uint32,
                       all-gather, local popcount majority. Every chip
                       plays the server; 1 bit/param on the wire.
* ``hierarchical``   — int8 reduce-scatter within pod -> int8 psum of the
                       scattered counts across pods -> local sign ->
                       bit-packed all-gather of the result. The global
                       majority (counts cross pods, not votes-of-votes).

Plus the fused scalable path: ``make_fsdp_hooks`` returns parameter hooks
that all-gather ZeRO-3-sharded parameters in the forward pass and perform
**sign + majority vote inside the backward reduce-scatter** — the vote
rides the collective ZeRO does anyway, in int8 instead of bf16 (beyond-
paper; see DESIGN.md §3 Mode B).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import byzantine, sign_compress as sc


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def vote_axes_in(mesh_axis_names: Sequence[str]) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh_axis_names)


def num_voters(axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= jax.lax.axis_size(a)
    return n


def count_dtype(n_voters: int):
    if n_voters <= 127:
        return jnp.int8
    if n_voters <= 32_767:
        return jnp.int16
    return jnp.int32


# ---------------------------------------------------------------------------
# flat strategies
# ---------------------------------------------------------------------------


def vote_psum(signs: jax.Array, axes: Sequence[str]) -> jax.Array:
    """signs int8 (ternary ok) -> int8 majority (ties/zero-sum -> 0)."""
    acc = count_dtype(num_voters(axes))
    counts = jax.lax.psum(signs.astype(acc), axis_name=tuple(axes))
    return jnp.sign(counts).astype(jnp.int8)


def vote_allgather_1bit(signs: jax.Array, axes: Sequence[str],
                        majority_fn: Optional[Callable] = None) -> jax.Array:
    """signs int8 1-D -> int8 ±1 majority via the packed wire protocol."""
    majority_fn = majority_fn or sc.packed_majority
    flat, n = sc.pad_to_pack(signs)
    packed = sc.pack_signs(flat)
    for a in axes:  # gather over each vote axis; leading M dims stack
        packed = jax.lax.all_gather(packed, a, tiled=False)
    packed = packed.reshape(-1, packed.shape[-1])
    maj = majority_fn(packed)
    return sc.unpack_signs(maj, jnp.int8)[:n]


def vote_hierarchical(signs: jax.Array, data_axis: str,
                      pod_axis: Optional[str]) -> jax.Array:
    """signs int8 1-D -> int8 ±1; RS(int8) + pod-psum + packed AG."""
    dsize = jax.lax.axis_size(data_axis)
    flat, n = sc.pad_to_pack(signs, sc.PACK * dsize)
    acc = count_dtype(dsize * (jax.lax.axis_size(pod_axis) if pod_axis else 1))
    counts = jax.lax.psum_scatter(flat.astype(acc), data_axis, tiled=True)
    if pod_axis is not None:
        counts = jax.lax.psum(counts, pod_axis)
    shard_vote = sc.sign_binary(counts)          # ties -> +1 (1-bit wire)
    packed = sc.pack_signs(shard_vote)
    packed = jax.lax.all_gather(packed, data_axis, tiled=True)
    return sc.unpack_signs(packed, jnp.int8)[:n]


def majority_vote_flat(signs: jax.Array, strategy: VoteStrategy,
                       axes: Sequence[str]) -> jax.Array:
    if strategy == VoteStrategy.PSUM_INT8:
        return vote_psum(signs, axes)
    if strategy == VoteStrategy.ALLGATHER_1BIT:
        return vote_allgather_1bit(signs, axes)
    if strategy == VoteStrategy.HIERARCHICAL:
        pod = "pod" if "pod" in axes else None
        return vote_hierarchical(signs, "data", pod)
    raise ValueError(strategy)


# ---------------------------------------------------------------------------
# tree-level vote (Mode A / explicit path)
# ---------------------------------------------------------------------------
#
# The vote is per-leaf, packing along the LAST dim only: flattening or
# concatenating leaves would destroy their auto ('model') shardings and
# force full all-gathers of every TP-sharded tensor (measured: 14.3 GB of
# int8 signs for qwen2-moe before this was changed). The paper's
# tensor-fusion trick is instead delegated to XLA's collective combiner,
# which merges small same-type collectives on real backends.


def _pad_last(x: jax.Array, multiple: int) -> Tuple[jax.Array, int]:
    n = x.shape[-1]
    rem = (-n) % multiple
    if rem:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
        x = jnp.pad(x, pad)
    return x, n


def _vote_1bit_leaf(signs: jax.Array, axes: Sequence[str]) -> jax.Array:
    """Per-leaf paper wire protocol: pack last dim, all-gather over the
    vote axes, bit-sliced popcount majority (ties -> +1)."""
    padded, n = _pad_last(signs, sc.PACK)
    packed = sc.pack_signs(padded)
    for a in axes:
        packed = jax.lax.all_gather(packed, a, tiled=False)
    packed = packed.reshape((-1,) + packed.shape[len(axes):])  # (M, ..., w)
    m = packed.shape[0]
    shifts = jnp.arange(sc.PACK, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    counts = jnp.sum(bits.astype(jnp.int32), axis=0)            # (..., w, 32)
    maj = (2 * counts >= m).astype(jnp.uint32)
    packed_maj = jnp.zeros(maj.shape[:-1], jnp.uint32)
    for j in range(sc.PACK):
        packed_maj = packed_maj | (maj[..., j] << jnp.uint32(j))
    out = sc.unpack_signs(packed_maj, jnp.int8)
    return out[..., :n]


def _vote_hierarchical_leaf(signs: jax.Array, data_axis: str,
                            pod_axis: Optional[str]) -> jax.Array:
    """Per-leaf hierarchical vote: int8 reduce-scatter of the last dim
    within pod, psum of counts across pods, sign, packed all-gather."""
    dsize = jax.lax.axis_size(data_axis)
    padded, n = _pad_last(signs, sc.PACK * dsize)
    acc = count_dtype(dsize * (jax.lax.axis_size(pod_axis) if pod_axis else 1))
    counts = jax.lax.psum_scatter(
        padded.astype(acc), data_axis,
        scatter_dimension=padded.ndim - 1, tiled=True)
    if pod_axis is not None:
        counts = jax.lax.psum(counts, pod_axis)
    shard_vote = sc.sign_binary(counts)             # ties -> +1 (1-bit wire)
    packed = jax.lax.all_gather(
        sc.pack_signs(shard_vote), data_axis,
        axis=shard_vote.ndim - 1, tiled=True)
    return sc.unpack_signs(packed, jnp.int8)[..., :n]


def tree_vote(tree, strategy: VoteStrategy, axes: Sequence[str],
              byz: Optional[ByzantineConfig] = None):
    """Vote a pytree of local momenta/grads; returns ±1 tree (leaf dtypes).

    With no vote axes (single process) the vote of M=1 degenerates to the
    leaf's own sign.
    """
    axes = tuple(axes)
    pod = "pod" if "pod" in axes else None

    def vote_leaf(l):
        shape = l.shape
        s = sc.sign_ternary(l if l.ndim else l.reshape(1))
        if byz is not None and axes:
            s = byzantine.apply_adversary(s, byz, axes)
        if not axes:
            v = s
        elif strategy == VoteStrategy.PSUM_INT8:
            v = vote_psum(s, axes)
        elif strategy == VoteStrategy.ALLGATHER_1BIT:
            v = _vote_1bit_leaf(s, axes)
        elif strategy == VoteStrategy.HIERARCHICAL:
            v = _vote_hierarchical_leaf(s, "data", pod)
        else:
            raise ValueError(strategy)
        return v.reshape(shape).astype(l.dtype)

    return jax.tree.map(vote_leaf, tree)


def tree_mean(tree, axes: Sequence[str]):
    """Dense baseline: psum-mean of gradients over the vote axes."""
    n = num_voters(axes)
    return jax.tree.map(
        lambda g: jax.lax.psum(g, axis_name=tuple(axes)) / n, tree)


# ---------------------------------------------------------------------------
# fused ZeRO-3 gather + vote-in-backward (Mode B scalable path)
# ---------------------------------------------------------------------------


def _fsdp_dim(spec: P) -> Optional[int]:
    for i, e in enumerate(spec):
        entries = e if isinstance(e, tuple) else (e,)
        if "data" in entries:
            return i
    return None


def make_gather_vote(dim: int, data_axis: str, pod_axis: Optional[str], *,
                     vote: bool, byz: Optional[ByzantineConfig] = None,
                     out_spec: Optional[P] = None):
    """all_gather over `data_axis` on `dim` whose backward is either the
    majority vote (vote=True) or the dense psum-mean (baseline).

    The gather and the backward reduce-scatter run inside a NESTED
    shard_map that is manual over 'model' too (specs from `out_spec`):
    a manual-axis collective whose operand carries auto 'model' sharding
    on other dims makes the partitioner replicate those dims first — in
    fp32 — before gathering (measured in isolation: 13.8 GB vs 0.6 GB for
    one qwen3 MoE layer, a 16x expert-weight replication). Inside the
    fully-manual region the operand is a local block and the collective
    composes cleanly.
    """
    spec = out_spec if out_spec is not None else P()

    def _wrap(fn, in_spec, out_spec_):
        return jax.shard_map(fn, in_specs=in_spec, out_specs=out_spec_,
                             axis_names={"model"}, check_vma=False)

    @jax.custom_vjp
    def gather(x):
        def inner(xl):
            return jax.lax.all_gather(xl, data_axis, axis=dim, tiled=True)

        return _wrap(inner, (spec,), spec)(x)

    def fwd(x):
        return gather(x), None

    def _vote_inner(g):
        s = sc.sign_ternary(g)
        if byz is not None:
            axes = (pod_axis, data_axis) if pod_axis else (data_axis,)
            s = byzantine.apply_adversary(s, byz, axes)
        nvote = jax.lax.axis_size(data_axis) * (
            jax.lax.axis_size(pod_axis) if pod_axis else 1)
        counts = jax.lax.psum_scatter(
            s.astype(count_dtype(nvote)), data_axis,
            scatter_dimension=dim, tiled=True)
        if pod_axis is not None:
            counts = jax.lax.psum(counts, pod_axis)
        return jnp.sign(counts).astype(g.dtype)

    def _mean_inner(g):
        nvote = jax.lax.axis_size(data_axis) * (
            jax.lax.axis_size(pod_axis) if pod_axis else 1)
        red = jax.lax.psum_scatter(g, data_axis, scatter_dimension=dim,
                                   tiled=True)
        if pod_axis is not None:
            red = jax.lax.psum(red, pod_axis)
        return red / nvote

    def bwd_vote(_, g):
        return (_wrap(_vote_inner, (spec,), spec)(g),)

    def bwd_mean(_, g):
        return (_wrap(_mean_inner, (spec,), spec)(g),)

    gather.defvjp(fwd, bwd_vote if vote else bwd_mean)
    return gather


def make_fsdp_hooks(specs: Dict[str, P], mesh_axis_names: Sequence[str], *,
                    vote: bool, byz: Optional[ByzantineConfig] = None
                    ) -> Callable[[Dict[str, jax.Array], str], Dict[str, jax.Array]]:
    """Parameter hook for ZeRO-3 (Mode B) training.

    ``hook(tree, scope)``: gathers every FSDP-sharded ('data') param in
    `tree`; backward of each gather performs the majority vote (or dense
    mean for the baseline). `scope` is 'top' (full names) or 'layers'
    (per-layer tree inside the scan; names lack the 'layers.' prefix and
    the leading L axis, so the FSDP dim shifts down by one).
    """
    pod = "pod" if "pod" in mesh_axis_names else None

    def _auto_spec(spec: P, drop_leading: bool) -> P:
        manual = {a for a in ("pod", "data") if a in mesh_axis_names}

        def fix(e):
            if e is None:
                return None
            if isinstance(e, (tuple, list)):
                kept = tuple(x for x in e if x not in manual)
                return kept if kept else None
            return None if e in manual else e

        entries = [fix(e) for e in spec]
        if drop_leading:
            entries = entries[1:]
        return P(*entries)

    gathers_top: Dict[str, Callable] = {}
    gathers_layer: Dict[str, Callable] = {}
    for name, spec in specs.items():
        d = _fsdp_dim(spec)
        if d is None:
            continue
        if name.startswith("layers.") or name.startswith("encoder."):
            short = name.split(".", 1)[1]
            gathers_layer[short] = make_gather_vote(
                d - 1, "data", pod, vote=vote, byz=byz,
                out_spec=_auto_spec(spec, True))
        else:
            gathers_top[name] = make_gather_vote(
                d, "data", pod, vote=vote, byz=byz,
                out_spec=_auto_spec(spec, False))

    def hook(tree: Dict[str, jax.Array], scope: str) -> Dict[str, jax.Array]:
        table = gathers_top if scope == "top" else gathers_layer
        return {k: (table[k](v) if k in table else v)
                for k, v in tree.items()}

    return hook


# ---------------------------------------------------------------------------
# communication accounting (used by benchmarks; mirrors the strategies)
# ---------------------------------------------------------------------------


def comm_bytes_per_step(n_params: int, strategy: VoteStrategy,
                        data_size: int, pod_size: int = 1,
                        grad_bytes: int = 2) -> Dict[str, float]:
    """Analytic per-chip collective bytes for one vote vs a dense
    all-reduce of the same gradient (ring terms; used by bench_comm and
    cross-checked against HLO-parsed bytes in the dry-run)."""
    d = float(n_params)
    M = data_size * pod_size
    dense = 2 * d * grad_bytes * (M - 1) / M          # ring all-reduce
    if strategy == VoteStrategy.PSUM_INT8:
        vote = 2 * d * 1 * (M - 1) / M                # int8 all-reduce
    elif strategy == VoteStrategy.ALLGATHER_1BIT:
        vote = (M - 1) * d / 8                        # packed all-gather
    else:  # hierarchical
        rs = d * 1 * (data_size - 1) / data_size      # int8 RS in pod
        xpod = (d / data_size) * 1 * 2 * (pod_size - 1) / max(pod_size, 1)
        ag = (d / 8) * (data_size - 1) / data_size    # packed AG
        vote = rs + xpod + ag
    return {"dense_allreduce": dense, "vote": vote, "ratio": dense / vote}
