"""Continuous-batching serve engine: oracle equivalence, slot
recycling, admission paths, compile accounting, hot swap, traffic
determinism and telemetry (DESIGN.md §14)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.models import model as M
from repro.obs import recorder as obs
from repro.serve import (CheckpointEmitter, CheckpointWatcher, Request,
                         ServeConfig, ServeEngine, like_tree,
                         poisson_requests)

#: ONE engine shape for most tests — every distinct shape key is a
#: fresh decode-step compile, so tests deliberately share this one
SC = ServeConfig(n_slots=3, max_len=32, prompt_pad=8)


@pytest.fixture(scope="module")
def dense():
    cfg = reduced_config(get_config("glm4-9b"))
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def ssm():
    cfg = reduced_config(get_config("mamba2-2.7b"))
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _reqs(cfg, n, seed=3, rate=0.5, lens=(4, 6, 8), gens=(3, 6)):
    return poisson_requests(n_requests=n, rate=rate,
                            vocab_size=cfg.vocab_size, prompt_lens=lens,
                            gen_range=gens, seed=seed)


def _oracle(cfg, params, r, max_len):
    """Batch-1 greedy decode loop over the public model API — the
    ground truth every engine lane must match bit for bit."""
    cache = M.init_cache(cfg, 1, max_len)
    tok = jnp.array([[r.prompt[0]]], jnp.int32)
    out, pos = [], 0
    budget = min(r.max_gen, max_len - r.prompt_len)
    while len(out) < budget:
        logits, cache = M.decode_step(cfg, params, tok, cache,
                                      jnp.int32(pos))
        if pos + 1 < r.prompt_len:
            tok = jnp.array([[r.prompt[pos + 1]]], jnp.int32)
        else:
            nxt = int(jnp.argmax(logits[0]))
            out.append(nxt)
            tok = jnp.array([[nxt]], jnp.int32)
        pos += 1
    return tuple(out)


# ---------------------------------------------------------------------------
# oracle equivalence + slot isolation
# ---------------------------------------------------------------------------


def test_single_request_matches_oracle(dense):
    cfg, params = dense
    r = _reqs(cfg, 1)[0]
    rep = ServeEngine(cfg, params, SC).run([r])
    assert rep.completed == 1 and rep.dropped == 0
    assert rep.tokens_by_request()[r.req_id] == _oracle(
        cfg, params, r, SC.max_len)


def test_staggered_slots_are_isolated(dense):
    """Requests decoding at different per-slot positions (mid-flight
    admission into recycled slots) each match their own standalone
    batch-1 oracle — lanes never leak into each other."""
    cfg, params = dense
    reqs = _reqs(cfg, 7, seed=5)
    rep = ServeEngine(cfg, params, SC).run(reqs)
    assert rep.completed == 7 and rep.dropped == 0
    toks = rep.tokens_by_request()
    for r in reqs:
        assert toks[r.req_id] == _oracle(cfg, params, r, SC.max_len), \
            f"request {r.req_id} diverged from its solo oracle"
    # slots really recycled: more requests than slots, all served
    assert len({rec.slot for rec in rep.records.values()}) <= SC.n_slots


def test_ssm_family_inline(ssm):
    cfg, params = ssm
    reqs = _reqs(cfg, 4, seed=9)
    rep = ServeEngine(cfg, params, SC).run(reqs)
    assert rep.completed == 4 and rep.dropped == 0
    toks = rep.tokens_by_request()
    for r in reqs[:2]:   # recurrent state must be slot-reset on admit
        assert toks[r.req_id] == _oracle(cfg, params, r, SC.max_len)


def test_prefill_admission_matches_inline(dense):
    cfg, params = dense
    reqs = _reqs(cfg, 5, seed=11)
    sc_p = ServeConfig(n_slots=SC.n_slots, max_len=SC.max_len,
                       prompt_pad=SC.prompt_pad, admit="prefill",
                       prefill_buckets=(4, 6, 8))
    ti = ServeEngine(cfg, params, SC).run(reqs).tokens_by_request()
    tp = ServeEngine(cfg, params, sc_p).run(reqs).tokens_by_request()
    assert ti == tp


# ---------------------------------------------------------------------------
# the static-shape claim
# ---------------------------------------------------------------------------


def test_one_decode_compile_across_engines(dense):
    cfg, params = dense
    # a shape key no other test uses -> first run must compile exactly
    # once; a second engine instance must add zero compiles
    sc = ServeConfig(n_slots=2, max_len=24, prompt_pad=6)
    reqs = _reqs(cfg, 4, seed=13, lens=(4, 6))
    before = obs.COUNTERS.get("serve.decode.compiles")
    t1 = ServeEngine(cfg, params, sc).run(reqs).tokens_by_request()
    assert obs.COUNTERS.get("serve.decode.compiles") - before == 1
    t2 = ServeEngine(cfg, params, sc).run(reqs).tokens_by_request()
    assert obs.COUNTERS.get("serve.decode.compiles") - before == 1
    assert t1 == t2


def test_scheduler_and_admit_share_compiles(dense):
    cfg, params = dense
    reqs = _reqs(cfg, 4, seed=17)
    ServeEngine(cfg, params, SC).run(reqs)   # warm the shared key
    before = obs.COUNTERS.get("serve.decode.compiles")
    for sc in (ServeConfig(n_slots=SC.n_slots, max_len=SC.max_len,
                           prompt_pad=SC.prompt_pad, scheduler="static"),
               ServeConfig(n_slots=SC.n_slots, max_len=SC.max_len,
                           prompt_pad=SC.prompt_pad, admit="prefill",
                           prefill_buckets=(8,))):
        ServeEngine(cfg, params, sc).run(reqs)
    assert obs.COUNTERS.get("serve.decode.compiles") == before, \
        "host-side policy (scheduler/admit) must not re-key the jit"


# ---------------------------------------------------------------------------
# scheduling
# ---------------------------------------------------------------------------


def test_continuous_beats_static_goodput(dense):
    cfg, params = dense
    reqs = _reqs(cfg, 8, seed=19, rate=0.6)
    rep_c = ServeEngine(cfg, params, SC).run(reqs)
    rep_s = ServeEngine(
        cfg, params,
        ServeConfig(n_slots=SC.n_slots, max_len=SC.max_len,
                    prompt_pad=SC.prompt_pad,
                    scheduler="static")).run(reqs)
    assert rep_c.completed == rep_s.completed == 8
    assert rep_c.goodput_tokens_per_tick > rep_s.goodput_tokens_per_tick
    # identical tokens either way — scheduling changes latency, not math
    assert rep_c.tokens_by_request() == rep_s.tokens_by_request()


def test_eos_retires_early(dense):
    cfg, params = dense
    r = _reqs(cfg, 1, seed=23, gens=(6, 6))[0]
    probe = ServeEngine(cfg, params, SC).run([r]).tokens_by_request()
    first = probe[r.req_id][0]
    sc_eos = ServeConfig(n_slots=SC.n_slots, max_len=SC.max_len,
                         prompt_pad=SC.prompt_pad, eos_id=first)
    rep = ServeEngine(cfg, params, sc_eos).run([r])
    assert rep.completed == 1
    assert rep.tokens_by_request()[r.req_id] == (first,)


def test_max_ticks_reports_dropped(dense):
    cfg, params = dense
    reqs = _reqs(cfg, 3, seed=29)
    rep = ServeEngine(cfg, params, SC).run(reqs, max_ticks=3)
    assert rep.dropped > 0
    assert rep.completed + rep.dropped == 3


# ---------------------------------------------------------------------------
# hot checkpoint swap
# ---------------------------------------------------------------------------


def test_hot_swap_zero_dropped_and_oracle(dense, tmp_path):
    cfg, params = dense
    params2 = M.init_params(cfg, jax.random.PRNGKey(42))
    reqs = _reqs(cfg, 6, seed=31)
    emitter = CheckpointEmitter(str(tmp_path))
    eng = ServeEngine(
        cfg, params, SC,
        watcher=CheckpointWatcher(str(tmp_path), like_tree(params)))

    def on_tick(_e, t):
        if t == 8:
            emitter.emit(100, params2)

    rep = eng.run(reqs, on_tick=on_tick)
    assert rep.dropped == 0 and rep.swaps == 1
    assert eng.param_version == 1
    post = [r for r in reqs
            if rep.records[r.req_id].param_version_admit == 1]
    pre = [r for r in reqs if r not in post]
    assert post and pre, "swap must split the request stream"
    toks = rep.tokens_by_request()
    # post-swap admissions == a fresh server started on the new params
    for r in post:
        assert toks[r.req_id] == _oracle(cfg, params2, r, SC.max_len)
    # step records carry the version tag: versions never decrease
    vs = [rep.records[r.req_id].param_version_admit for r in
          sorted(reqs, key=lambda r: rep.records[r.req_id].admit_tick)]
    assert vs == sorted(vs)


def test_watcher_surfaces_each_checkpoint_once(dense, tmp_path):
    cfg, params = dense
    emitter = CheckpointEmitter(str(tmp_path))
    watcher = CheckpointWatcher(str(tmp_path), like_tree(params))
    assert watcher.poll() is None
    emitter.emit(5, params)
    upd = watcher.poll()
    assert upd is not None and upd.version == 1 and upd.step == 5
    assert watcher.poll() is None
    for got, want in zip(jax.tree.leaves(upd.params),
                         jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# traffic determinism
# ---------------------------------------------------------------------------


def test_traffic_deterministic():
    a = poisson_requests(n_requests=16, rate=0.4, vocab_size=1000, seed=4)
    b = poisson_requests(n_requests=16, rate=0.4, vocab_size=1000, seed=4)
    assert a == b
    c = poisson_requests(n_requests=16, rate=0.4, vocab_size=1000, seed=5)
    assert a != c
    # keyed by request id, not call order: a longer schedule is a
    # superset of a shorter one
    assert poisson_requests(n_requests=4, rate=0.4, vocab_size=1000,
                            seed=4) == a[:4]
    arr = [r.arrival for r in a]
    assert arr == sorted(arr) and arr[0] > 0.0
    for r in a:
        assert all(0 <= t < 1000 for t in r.prompt)


@pytest.mark.parametrize("kw", [
    dict(n_requests=-1, rate=0.5, vocab_size=10),
    dict(n_requests=1, rate=0.0, vocab_size=10),
    dict(n_requests=1, rate=0.5, vocab_size=10, prompt_lens=()),
    dict(n_requests=1, rate=0.5, vocab_size=10, gen_range=(0, 3)),
    dict(n_requests=1, rate=0.5, vocab_size=10, gen_range=(5, 3)),
])
def test_traffic_validation(kw):
    with pytest.raises(ValueError):
        poisson_requests(**kw)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(n_slots=0),
    dict(prompt_pad=0),
    dict(prompt_pad=65),               # > max_len=64
    dict(admit="bogus"),
    dict(scheduler="bogus"),
    dict(admit="prefill"),             # no buckets
    dict(admit="prefill", prefill_buckets=(8, 4)),
    dict(admit="prefill", prefill_buckets=(128,)),
])
def test_serve_config_validation(kw):
    with pytest.raises(ValueError):
        ServeConfig(**kw)


def test_engine_rejects_recurrent_prefill(ssm):
    cfg, params = ssm
    with pytest.raises(ValueError, match="recurrent"):
        ServeEngine(cfg, params,
                    ServeConfig(admit="prefill", prefill_buckets=(8,)))


def test_engine_rejects_oversize_prompt(dense):
    cfg, params = dense
    bad = Request(req_id=0, arrival=0.0,
                  prompt=tuple(range(SC.prompt_pad + 1)), max_gen=4)
    with pytest.raises(ValueError, match="prompt length"):
        ServeEngine(cfg, params, SC).run([bad])


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------


def test_traced_run_identical_and_recorded(dense, tmp_path):
    cfg, params = dense
    reqs = _reqs(cfg, 4, seed=37)
    base = ServeEngine(cfg, params, SC).run(reqs)
    path = os.path.join(str(tmp_path), "trace.jsonl")
    rec = obs.TraceRecorder(path)
    with obs.recording(rec):
        traced = ServeEngine(cfg, params, SC).run(reqs)
    rec.close()
    assert traced.tokens_by_request() == base.tokens_by_request()
    rows = obs.read_trace(path)
    steps = [r for r in rows if r["kind"] == "step"]
    assert len(steps) == traced.ticks
    assert all(s["param_version"] == 0 for s in steps)
    span_names = {r["name"] for r in rows if r["kind"] == "span"}
    assert {"serve.admit", "serve.decode", "serve.retire"} <= span_names
    counters = [r for r in rows if r["kind"] == "counters"][-1]["values"]
    assert counters["serve.admissions"] >= 4
    assert counters["serve.tokens"] >= traced.total_tokens
