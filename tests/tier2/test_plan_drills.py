"""VotePlan tier-2 drill (DESIGN.md §9; scripts/ci.sh plan-smoke stage).

Host-count invariance of a MIXED-CODEC plan — ternary2bit embeddings +
sign1bit body over the gathered wire — under a 0.375 colluding-adversary
scenario: the virtual replay on a 1-device platform, the virtual replay
on the 8-device platform, and the REAL mesh backend (shard_map over 8
replicas walking the same bucket schedule) must all produce one digest.
Each platform needs its own process (XLA device count is fixed before
jax initialises), hence the subprocess pattern of test_harness8.py.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_WORKER = textwrap.dedent("""
    import sys
    import jax
    from repro.configs.base import VoteStrategy
    from repro.sim import (AdversarySpec, PlanSpec, ScenarioRunner,
                           ScenarioSpec)

    spec = ScenarioSpec(
        "plan-drill/mixed_collude", n_workers=8, n_steps=6, dim=256,
        strategy=VoteStrategy.ALLGATHER_1BIT,
        adversary=AdversarySpec("colluding", 0.375),
        plan=PlanSpec(bucket_bytes=8,
                      leaves=(("embed.table", 96), ("body.blocks", 160)),
                      codec_map=(("embed*", "ternary2bit"),
                                 ("*", "sign1bit"))))
    print("NBUCKETS", spec.runtime_plan(8).n_buckets)
    print("VDIGEST", ScenarioRunner(spec, backend="virtual").run().digest)
    if sys.argv[1] == "mesh-too":
        assert len(jax.devices()) >= 8
        print("MDIGEST",
              ScenarioRunner(spec, backend="mesh").run().digest)
""")


def _run(device_count: int, mode: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "..", "src"),
         env.get("PYTHONPATH", "")])
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={device_count}"
    proc = subprocess.run([sys.executable, "-c", _WORKER, mode], env=env,
                          capture_output=True, text=True, timeout=900)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "plan drill worker failed"
    return {line.split()[0]: line.split()[1]
            for line in proc.stdout.splitlines()
            if line.split() and line.split()[0] in
            ("VDIGEST", "MDIGEST", "NBUCKETS")}


@pytest.mark.slow
@pytest.mark.timeout(900)
def test_mixed_codec_plan_is_host_count_and_backend_invariant():
    d8 = _run(8, "mesh-too")
    d1 = _run(1, "virtual-only")
    assert int(d8["NBUCKETS"]) > 1, "drill must actually bucket the wire"
    assert d8["VDIGEST"] == d8["MDIGEST"], (
        "mixed-codec plan: mesh backend diverged from the virtual walk")
    assert d8["VDIGEST"] == d1["VDIGEST"], (
        "mixed-codec plan digest differs between 8-device and 1-device "
        "replays — the bucket schedule is host-count dependent")
