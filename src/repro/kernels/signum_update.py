"""Pallas TPU kernels: fused SIGNUM worker-side update loops.

The optimizer step is HBM-bandwidth-bound; unfused it makes 4+ passes over
parameter-sized buffers. Two fused kernels cut that to the minimum:

``momentum_sign_pack`` — m' = beta*m + (1-beta)*g, packed = pack(sign(m'))
    one read of (g, m), one write of (m', packed/32): the entire
    pre-vote worker computation in a single pass.

``apply_vote`` — x <- x - eta*(unpack(vote) + lambda*x)
    one read of (x, packed vote), one write of x: the post-vote update,
    decoding the 1-bit vote on the fly (never materialising the ±1
    tensor in HBM).

Scalars (beta/eta/lambda) are compile-time constants (closure), matching
how the training step specialises on the optimizer config.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK = 32
ROWS = 8
WORDS = 128


def _momentum_sign_pack_kernel(g_ref, m_ref, m_out_ref, p_out_ref, *,
                               beta: float):
    g = g_ref[...]
    m = m_ref[...]
    m_new = beta * m + (1.0 - beta) * g.astype(m.dtype)
    m_out_ref[...] = m_new
    bits = (m_new >= 0).astype(jnp.uint32)
    bits = bits.reshape(m_new.shape[0], m_new.shape[1] // PACK, PACK)
    acc = jnp.zeros(bits.shape[:2], jnp.uint32)
    for j in range(PACK):
        acc = acc | (bits[:, :, j] << jnp.uint32(j))
    p_out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("beta", "interpret"))
def momentum_sign_pack(g: jax.Array, m: jax.Array, beta: float, *,
                       interpret: bool = False):
    """g/m (rows, 32*w) -> (m_new (rows, 32*w), packed (rows, w))."""
    rows, n = g.shape
    w = n // PACK
    grid = (rows // ROWS, w // WORDS)
    return pl.pallas_call(
        functools.partial(_momentum_sign_pack_kernel, beta=beta),
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, WORDS * PACK), lambda i, j: (i, j)),
                  pl.BlockSpec((ROWS, WORDS * PACK), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((ROWS, WORDS * PACK), lambda i, j: (i, j)),
                   pl.BlockSpec((ROWS, WORDS), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((rows, n), m.dtype),
                   jax.ShapeDtypeStruct((rows, w), jnp.uint32)],
        interpret=interpret,
    )(g, m)


def _apply_vote_kernel(p_ref, v_ref, out_ref, *, eta: float,
                       weight_decay: float):
    p = p_ref[...].astype(jnp.float32)                # (ROWS, WORDS*32)
    v = v_ref[...]                                    # (ROWS, WORDS) uint32
    cols = []
    for j in range(PACK):
        bit = (v >> jnp.uint32(j)) & jnp.uint32(1)
        cols.append(jnp.where(bit == 1, 1.0, -1.0))
    vote = jnp.stack(cols, axis=-1).reshape(p.shape)  # ±1 fp32
    out_ref[...] = (p - eta * (vote + weight_decay * p)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eta", "weight_decay",
                                             "interpret"))
def apply_vote(p: jax.Array, votes: jax.Array, eta: float,
               weight_decay: float, *, interpret: bool = False) -> jax.Array:
    """p (rows, 32*w), votes (rows, w) uint32 -> updated p."""
    rows, n = p.shape
    w = n // PACK
    grid = (rows // ROWS, w // WORDS)
    return pl.pallas_call(
        functools.partial(_apply_vote_kernel, eta=eta,
                          weight_decay=weight_decay),
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, WORDS * PACK), lambda i, j: (i, j)),
                  pl.BlockSpec((ROWS, WORDS), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((ROWS, WORDS * PACK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, n), p.dtype),
        interpret=interpret,
    )(p, votes)
