"""Fig. 4 analogs: fault-tolerance of the vote, two ways.

* ``rows()`` (the ``benchmarks.run`` driver path) — training a real
  (reduced) LM with majority vote while a fraction of the vote replicas
  inverts its signs. Runs the actual distributed train step on 8 fake
  devices in a subprocess (the bench process keeps 1 device).
* ``--scenario-grid`` — the Scenario Lab sweep (DESIGN.md §7): replays
  adversary fraction 0 -> 0.5 x {sign_flip, random, zero, colluding} x
  all three wire strategies through ``repro.sim.ScenarioRunner`` traces,
  from ONE config file (``benchmarks/configs/fig4_grid.json``), plus the
  boundary drills (blind >50%, stale adversaries, elastic shrink).
* ``--scenario-smoke`` — the CI lane: 3 scenarios x 2 strategies on the
  8-virtual-device host platform, each run on BOTH backends and asserted
  bit-identical (mesh collectives == virtual mesh), in well under 60 s.
* ``--breaking-point`` — the adaptive-attack lane (DESIGN.md §15):
  every attack class's measured breaking-point curve (adversary
  fraction -> loss drop) overlaid with the oblivious Theorem 2 failure
  bound, the defense-aware-vs-oblivious degradation gate, and the
  mesh==virtual / chunk-invariance identity asserts, written to
  ``BENCH_robustness.json`` (gated by scripts/perf_gate.py).

Usage:
    python -m benchmarks.bench_robustness                   # train sweep
    python -m benchmarks.bench_robustness --scenario-grid   # Fig. 4 grid
    python -m benchmarks.bench_robustness --scenario-smoke  # CI smoke
    python -m benchmarks.bench_robustness --breaking-point  # attack lane
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

_CONFIG = os.path.join(os.path.dirname(__file__), "configs",
                       "fig4_grid.json")
_BP_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_robustness.json")

_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    from repro.configs.base import (ByzantineConfig, OptimizerConfig,
                                    TrainConfig, get_config, reduced_config)
    from repro.models import model as M
    from repro.train import train_step as TS

    mesh = compat.make_mesh((8, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    out = {}
    for n_adv in [0, 1, 2, 3]:
        cfg = reduced_config(get_config("glm4-9b"), num_layers=2)
        tcfg = TrainConfig(
            global_batch=8, seq_len=32,
            optimizer=OptimizerConfig(kind="signum_vote", learning_rate=3e-3),
            byzantine=ByzantineConfig(mode="sign_flip",
                                      num_adversaries=n_adv))
        art = TS.make_train_step(cfg, tcfg, mesh=mesh)
        params, opt = TS.materialize_state(cfg, tcfg, art,
                                           jax.random.PRNGKey(0), mesh)
        batch = M.make_batch(cfg, 8, 32, jax.random.PRNGKey(1))
        batch = jax.tree.map(lambda a: jax.device_put(
            np.asarray(a), NamedSharding(mesh, P("data"))), batch)
        losses = []
        for i in range(40):
            params, opt, met = art.step_fn(params, opt, batch, jnp.int32(i))
            losses.append(float(met["loss"]))
        out[str(n_adv)] = [losses[0], losses[-1]]
    print("RESULT " + json.dumps(out))
""")


def rows():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        return [("fig4/error", -1.0, proc.stderr[-200:])]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    res = json.loads(line[len("RESULT "):])
    out = []
    for n_adv, (first, last) in sorted(res.items()):
        pct = int(n_adv) / 8 * 100
        out.append((f"fig4/loss_drop_{pct:.0f}pct_adversarial",
                    first - last,
                    f"loss {first:.2f}->{last:.2f} (8 voters, "
                    f"{n_adv} sign-flippers)"))
    return out


# ---------------------------------------------------------------------------
# Scenario Lab sweeps
# ---------------------------------------------------------------------------


def scenario_traces(config_path: str = _CONFIG, backend: str = "virtual"):
    from repro.sim import ScenarioRunner, load_scenarios
    return [ScenarioRunner(spec, backend=backend).run()
            for spec in load_scenarios(config_path)]


def scenario_rows(config_path: str = _CONFIG, backend: str = "virtual",
                  traces=None):
    """One CSV row per scenario in the config: the Fig.-4 robustness
    surface from ScenarioRunner traces."""
    if traces is None:
        traces = scenario_traces(config_path, backend)
    out = []
    for trace in traces:
        spec, s = trace.spec, trace.summary()
        adv = spec.adversary
        out.append((
            f"fig4-grid/{spec.name}",
            s["loss_drop"],
            f"loss {s['first_loss']:.3f}->{s['final_loss']:.3f} "
            f"flip={s['mean_flip_fraction']:.3f} "
            f"margin={s['mean_margin']:.3f} "
            f"({spec.n_workers} voters, {adv.mode} f={adv.fraction}, "
            f"{spec.strategy.value}, ties->{s['tie_policy']})"))
    return out


def smoke_rows():
    """3 scenarios x 2 strategies, each replayed on BOTH backends on the
    8-virtual-device platform and asserted bit-identical."""
    from repro.configs.base import VoteStrategy
    from repro.sim import AdversarySpec, ElasticEvent, ScenarioRunner, \
        ScenarioSpec
    drills = [
        ("smoke/honest", dict()),
        ("smoke/flip_25_stale_25",
         dict(adversary=AdversarySpec("sign_flip", 0.25),
              straggler_fraction=0.25)),
        ("smoke/colluding_elastic",
         dict(adversary=AdversarySpec("colluding", 0.375),
              elastic=(ElasticEvent(4, 4),))),
    ]
    out = []
    for strategy in (VoteStrategy.PSUM_INT8, VoteStrategy.ALLGATHER_1BIT):
        for name, kw in drills:
            spec = ScenarioSpec(f"{name}/{strategy.value}", n_workers=8,
                                n_steps=8, dim=128, strategy=strategy, **kw)
            tv = ScenarioRunner(spec, backend="virtual").run()
            tm = ScenarioRunner(spec, backend="mesh").run()
            assert tv.digest == tm.digest, (
                f"{spec.name}: virtual and mesh wire paths diverged "
                f"({tv.digest[:12]} != {tm.digest[:12]})")
            s = tv.summary()
            out.append((f"fig4-smoke/{spec.name}", s["loss_drop"],
                        f"mesh==virtual digest {tv.digest[:12]} "
                        f"flip={s['mean_flip_fraction']:.3f}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario-grid", action="store_true",
                    help="Fig. 4 sweep from ScenarioRunner traces")
    ap.add_argument("--scenario-smoke", action="store_true",
                    help="CI smoke: 3 scenarios x 2 strategies, "
                         "mesh-vs-virtual bit-identity on 8 devices")
    ap.add_argument("--breaking-point", action="store_true",
                    help="adaptive-attack breaking-point curves vs the "
                         "Thm 2 bound; writes BENCH_robustness.json")
    ap.add_argument("--config", default=_CONFIG,
                    help="scenario config file (default: "
                         "benchmarks/configs/fig4_grid.json)")
    ap.add_argument("--backend", default="virtual",
                    choices=("virtual", "mesh"),
                    help="--scenario-grid backend")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="also dump full per-step traces to this file")
    from repro.obs import recorder as obs
    obs.add_trace_arg(ap)
    args = ap.parse_args()

    if sum((args.scenario_smoke, args.scenario_grid,
            args.breaking_point)) > 1:
        ap.error("--scenario-smoke/--scenario-grid/--breaking-point are "
                 "exclusive")
    if not args.scenario_grid and (args.json_out or args.config != _CONFIG
                                   or args.backend != "virtual"):
        ap.error("--json/--config/--backend apply to --scenario-grid only")

    if args.breaking_point:
        # identity rows replay every adaptive mode on the mesh backend:
        # force the 8-virtual-device platform before jax initialises,
        # APPENDING so a caller's unrelated XLA_FLAGS survive
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=8").strip()
        rec = obs.activate_trace(args)
        from repro.core.attacks import breaking_point as bp
        rs = bp.breaking_point_rows()
        obs.emit_bench_json(rs, os.path.normpath(_BP_JSON))
    elif args.scenario_smoke:
        # the smoke lane *is* the 8-virtual-device platform; force the
        # device count before jax initialises, APPENDING so a caller's
        # unrelated XLA_FLAGS (dump dirs etc.) survive
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=8").strip()
        rec = obs.activate_trace(args)
        rs = smoke_rows()
    elif args.scenario_grid:
        rec = obs.activate_trace(args)
        traces = scenario_traces(args.config, args.backend)
        rs = scenario_rows(traces=traces)
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump([t.to_dict() for t in traces], f, indent=1)
    else:
        rec = obs.activate_trace(args)
        rs = rows()
    print("name,value,derived")
    for name, value, derived in rs:
        print(f"{name},{value:.6g},{derived}", flush=True)
    obs.finish_trace(rec)


if __name__ == "__main__":
    main()
