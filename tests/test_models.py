"""Per-arch smoke tests: reduced same-family config, one forward/train
step on CPU, output shapes + finiteness; decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, list_archs, reduced_config
from repro.models import model as M

ARCHS = list_archs()


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch, key):
    cfg = reduced_config(get_config(arch))
    params = M.init_params(cfg, key)
    batch = M.make_batch(cfg, 2, 64, key)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert jnp.isfinite(loss)
    assert float(loss) > 0
    # logits shape
    logits, _ = M.forward_logits(cfg, params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    # every param receives a gradient leaf of matching shape
    for k, g in grads.items():
        assert g.shape == params[k].shape, k
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))), k


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, key):
    cfg = reduced_config(get_config(arch))
    params = M.init_params(cfg, key)
    cache = M.init_cache(cfg, 2, 32)
    tokens = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = M.decode_step(cfg, params, tokens, cache, jnp.int32(3))
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["glm4-9b", "mamba2-2.7b", "zamba2-1.2b",
                                  "qwen2-moe-a2.7b"])
def test_decode_matches_forward(arch, key):
    """Greedy decode logits == teacher-forced forward logits at the same
    positions (the core serving-correctness invariant)."""
    cfg = reduced_config(get_config(arch))
    # deterministic single sample, fp32 for tight comparison
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = M.init_params(cfg, key)
    S = 16
    tokens = jax.random.randint(key, (1, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens}
    full_logits, _ = M.forward_logits(cfg, params, batch)

    cache = M.init_cache(cfg, 1, S)
    outs = []
    for t in range(S):
        logits_t, cache = M.decode_step(
            cfg, params, tokens[:, t:t + 1], cache, jnp.int32(t))
        outs.append(logits_t)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-3, atol=2e-3)


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3-12b")
    mask = cfg.local_layer_mask()
    assert len(mask) == 48
    # 5 local then 1 global, repeating
    assert mask[:6] == (True,) * 5 + (False,)
    assert sum(mask) == 40


def test_sliding_window_masks_long_range():
    """A token beyond the window cannot influence a local-attention layer."""
    import dataclasses
    cfg = reduced_config(get_config("gemma3-12b"))
    cfg = dataclasses.replace(cfg, dtype="float32", num_layers=1,
                              local_to_global=1000)  # all layers local
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    S = 64
    tokens = jax.random.randint(key, (1, S), 0, cfg.vocab_size, jnp.int32)
    logits1, _ = M.forward_logits(cfg, params, {"tokens": tokens})
    # perturb a token far outside the window of the last position
    tokens2 = tokens.at[0, 0].set((tokens[0, 0] + 1) % cfg.vocab_size)
    logits2, _ = M.forward_logits(cfg, params, {"tokens": tokens2})
    # last position: distance S-1 = 63 > window 16 -> unchanged
    np.testing.assert_allclose(np.asarray(logits1[0, -1]),
                               np.asarray(logits2[0, -1]), atol=1e-5)
    # early position inside window: changed
    assert not np.allclose(np.asarray(logits1[0, 1]),
                           np.asarray(logits2[0, 1]), atol=1e-5)


def test_param_shapes_match_init():
    for arch in ARCHS:
        cfg = reduced_config(get_config(arch))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        shapes = cfg.param_shapes()
        assert set(params) == set(shapes)
        for k in shapes:
            assert tuple(params[k].shape) == tuple(shapes[k]), k


def test_full_config_fingerprints():
    """The assigned full configs expose the published parameter budgets."""
    expect = {
        "deepseek-67b": 67.4e9, "qwen3-moe-235b-a22b": 235e9,
        "qwen1.5-32b": 35.2e9, "mamba2-2.7b": 2.7e9,
        "zamba2-1.2b": 1.10e9, "gemma3-12b": 11.8e9, "glm4-9b": 9.4e9,
        "qwen2-moe-a2.7b": 14.3e9, "pixtral-12b": 12.2e9,
        # 41.7M (not 39M): framework-wide SwiGLU MLP (3 mats) vs whisper's
        # 2-mat GELU — the depth/width/head budget matches the paper config
        "whisper-tiny": 0.0417e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.05, (arch, got, n)
    assert abs(get_config("qwen3-moe-235b-a22b").active_param_count()
               - 22.2e9) / 22.2e9 < 0.05
    assert abs(get_config("qwen2-moe-a2.7b").active_param_count()
               - 2.7e9) / 2.7e9 < 0.05


def test_int8_kv_cache_decode():
    """qwen1.5's int8 KV path: decode stays close to the bf16-cache path."""
    import dataclasses
    cfg = reduced_config(get_config("qwen1.5-32b"))
    cfg32 = dataclasses.replace(cfg, dtype="float32")
    cfg_q = dataclasses.replace(cfg32, kv_cache_dtype="int8")
    cfg_f = dataclasses.replace(cfg32, kv_cache_dtype="bfloat16")
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg32, key)
    tokens = jax.random.randint(key, (1, 8), 0, cfg.vocab_size, jnp.int32)
    outs = {}
    for name, c in [("q", cfg_q), ("f", cfg_f)]:
        cache = M.init_cache(c, 1, 8)
        o = []
        for t in range(8):
            logits_t, cache = M.decode_step(
                c, params, tokens[:, t:t + 1], cache, jnp.int32(t))
            o.append(np.asarray(logits_t, np.float32))
        outs[name] = np.stack(o, 1)
    # int8 quantization error is small relative to logit scale
    denom = np.maximum(np.abs(outs["f"]), 1.0)
    assert np.max(np.abs(outs["q"] - outs["f"]) / denom) < 0.15
