"""Adaptive-attack multi-device harness, run in a subprocess by
tests/test_attack_properties.py (so the main pytest session keeps one
CPU device).

On an 8-device host platform it drives every adaptive mode — plus a
scheduled sleeper coalition — through BOTH Scenario Lab backends and
asserts mesh == virtual bit for bit (digest equality). The adaptive
modes are deterministic given the observation (no PRNG), so any digest
split would mean the observation channel itself diverged between the
backends.

Run with ``virtual-only`` as argv[1] to skip the mesh half; the parent
test diffs the printed ADIGEST lines of an 8-device run against a
1-device run — host-count invariance of the adaptive paths, asserted.
"""
import os
import sys

if os.environ.get("XLA_FLAGS") is None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax

from repro.configs.base import VoteStrategy
from repro.core.attacks import AttackPhase
from repro.sim import AdversarySpec, ScenarioRunner, ScenarioSpec


def harness_specs():
    S = VoteStrategy
    return [
        ScenarioSpec("ah/adaptive_flip", n_workers=8, n_steps=5, dim=48,
                     strategy=S.ALLGATHER_1BIT,
                     adversary=AdversarySpec("adaptive_flip", 0.375,
                                             observe="vote")),
        # a second wire strategy: the observation threading must be
        # strategy-agnostic
        ScenarioSpec("ah/low_margin_psum", n_workers=8, n_steps=5,
                     dim=48, strategy=S.PSUM_INT8,
                     adversary=AdversarySpec("low_margin", 0.375,
                                             observe="margin")),
        ScenarioSpec("ah/reputation_weighted", n_workers=8, n_steps=6,
                     dim=48, strategy=S.ALLGATHER_1BIT,
                     codec="weighted_vote",
                     adversary=AdversarySpec("reputation", 0.375,
                                             observe="reputation")),
        # sleeper coalition waking into an adaptive mode, then growing
        ScenarioSpec("ah/scheduled", n_workers=8, n_steps=7, dim=48,
                     strategy=S.ALLGATHER_1BIT,
                     adversary=AdversarySpec(
                         "none", 0.0, observe="vote",
                         schedule=(AttackPhase(step=2,
                                               mode="adaptive_flip",
                                               fraction=0.25),
                                   AttackPhase(step=5, fraction=0.5)))),
    ]


def main() -> None:
    virtual_only = "virtual-only" in sys.argv[1:]
    for spec in harness_specs():
        vd = ScenarioRunner(spec, backend="virtual").run().digest
        print(f"ADIGEST {spec.name} {vd}")
        if not virtual_only:
            assert len(jax.devices()) >= spec.n_workers, \
                "harness needs the 8-device host platform"
            md = ScenarioRunner(spec, backend="mesh").run().digest
            assert md == vd, (
                f"{spec.name}: mesh digest {md} != virtual {vd} — the "
                "adaptive observation channel diverged between backends")
    print("ALL ATTACK HARNESS CHECKS PASSED")


if __name__ == "__main__":
    main()
