#!/usr/bin/env python3
"""API-surface check (scripts/ci.sh api-smoke stage; DESIGN.md §10).

Greps ``src/`` and asserts that NO internal code calls a deprecated
legacy vote entry point — the deprecation shims themselves (their `def`
lines) are the only occurrences allowed. Tests and examples may still
exercise the shims (that is what keeps them honest); production code
must build a :class:`repro.core.vote_api.VoteRequest` and call a
backend's ``execute``.

Also asserts that no caller outside ``src/repro/core/`` constructs a
:class:`ByzantineConfig` with arguments — the validated factories
``repro.core.attacks.build_config`` / ``coalition_config`` are the one
way to spell an adversary (they collapse honest configs to the
canonical rest state and size coalitions with the exact-``Fraction``
rule). Bare ``ByzantineConfig()`` defaults stay legal everywhere.

Exit 0 when the surface is clean, 1 with a file:line listing otherwise.
"""
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent / "src"

#: deprecated free functions: \b<name>( is a call (or a def, excluded)
FUNCTIONS = [
    "vote_with_failures", "codec_vote_with_failures",
    "plan_vote_with_failures",
    "virtual_vote", "virtual_vote_codec", "virtual_plan_vote",
    "plan_vote_signs", "plan_tree_vote",
    "tree_vote", "tree_vote_codec", "majority_vote_flat",
]

#: deprecated VoteEngine methods: any .<name>( attribute call
METHODS = [
    "vote_signs", "vote_signs_codec", "vote_codec", "vote_tree",
    "vote_tree_codec", "vote_stacked",
]

#: `.vote(` is also a *stage* method on VoteStrategyImpl (the §2 wire
#: implementation, NOT deprecated) — so bare-name receivers are checked
#: against this allowlist and only engine-shaped receivers are flagged
VOTE_RECEIVER_ALLOWED = {"impl", "strat", "strategy", "TERNARY_WIRE"}
VOTE_CALL = re.compile(r"(\w+)\.vote\(")

PATTERNS = ([re.compile(rf"\b{n}\(") for n in FUNCTIONS]
            + [re.compile(rf"\.{m}\(") for m in METHODS])

#: ByzantineConfig with an argument on the call line (bare
#: ``ByzantineConfig()`` is the legal all-defaults rest state); only
#: ``core/`` — where the attacks factories live — may construct one
#: directly.  Line-based like every other check here: splitting the
#: call across lines to dodge the grep would not survive review.
BYZ_CALL = re.compile(r"\bByzantineConfig\(\s*[^)\s]")
BYZ_ALLOWED = ROOT / "repro" / "core"


def main() -> int:
    offenders = []
    for path in sorted(ROOT.rglob("*.py")):
        for lineno, line in enumerate(
                path.read_text().splitlines(), start=1):
            if re.match(r"\s*def\s", line):     # the shim definitions
                continue
            if re.match(r"\s*#", line):         # comments
                continue
            for pat in PATTERNS:
                if pat.search(line):
                    offenders.append(
                        f"{path.relative_to(ROOT.parent)}:{lineno}: "
                        f"{line.strip()}")
            for m in VOTE_CALL.finditer(line):
                if m.group(1) not in VOTE_RECEIVER_ALLOWED:
                    offenders.append(
                        f"{path.relative_to(ROOT.parent)}:{lineno}: "
                        f"{line.strip()}  (VoteEngine.vote?)")
            if (BYZ_CALL.search(line)
                    and BYZ_ALLOWED not in path.parents):
                offenders.append(
                    f"{path.relative_to(ROOT.parent)}:{lineno}: "
                    f"{line.strip()}  (use attacks.build_config)")
    if offenders:
        print("deprecated vote entry points still called inside src/ "
              "(migrate to vote_api.VoteRequest + execute):",
              file=sys.stderr)
        for o in offenders:
            print("  " + o, file=sys.stderr)
        return 1
    print(f"api-surface OK: no internal callers of "
          f"{len(FUNCTIONS) + len(METHODS) + 1} deprecated vote entry "
          "points under src/; no arg-bearing ByzantineConfig() outside "
          "core/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
