"""Quickstart: SIGNUM with majority vote in ~40 lines.

Trains a tiny glm4-family LM on the synthetic pipeline with the paper's
optimizer (Algorithm 1), prints the loss curve, and shows the vote
machinery explicitly on a toy tensor.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (OptimizerConfig, TrainConfig, get_config,
                                reduced_config)
from repro.core import sign_compress as sc
from repro.data.pipeline import SyntheticLMPipeline
from repro.models import model as M
from repro.train import train_step as TS


def main():
    # --- the vote itself, on a toy tensor -------------------------------
    g = np.random.default_rng(0).normal(size=(5, 8))  # 5 workers, 8 params
    packed = sc.pack_signs(jnp.asarray(
        np.pad(np.sign(g), ((0, 0), (0, 24)))))       # 1 bit per sign
    vote = sc.unpack_signs(sc.packed_majority(packed))[:8]
    print("worker signs:\n", np.sign(g).astype(int))
    print("majority vote:", np.asarray(vote, int), "\n")

    # --- Algorithm 1 on a real (tiny) model -----------------------------
    cfg = reduced_config(get_config("glm4-9b"))
    tcfg = TrainConfig(
        global_batch=8, seq_len=64,
        optimizer=OptimizerConfig(kind="signum_vote",  # SIGNUM + vote
                                  learning_rate=1e-3, momentum=0.9))
    art = TS.make_train_step(cfg, tcfg, mesh=None)
    params, opt_state = TS.materialize_state(cfg, tcfg, art,
                                             jax.random.PRNGKey(0))
    pipe = SyntheticLMPipeline(cfg, 8, 64, seed=0)
    for step in range(50):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt_state, met = art.step_fn(params, opt_state, batch,
                                             jnp.int32(step))
        if step % 10 == 0 or step == 49:
            print(f"step {step:3d}  loss {float(met['loss']):.4f}")


if __name__ == "__main__":
    main()
