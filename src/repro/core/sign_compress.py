"""Sign extraction and 1-bit packing (pure-jnp reference layer).

Two sign conventions coexist (DESIGN.md §5):

* ``sign_ternary`` — ``jnp.sign`` semantics, 0 maps to 0. Used by the
  integer-sum vote strategies; a zero gradient (e.g. an expert no local
  token routed to) *abstains* rather than voting +1.
* ``sign_binary``  — ``x >= 0 -> +1 else -1``. The 1-bit wire format of the
  paper: a packed bit can only encode two states.

Packing is 32 signs per uint32 word, little-endian within the word. The
ternary codec's 2-bit format (``pack_ternary``) stores 16 symbols per
uint32 — two's-complement 2-bit fields, so it can encode the abstention
the 1-bit wire cannot (DESIGN.md §8). The Pallas kernels in
``repro.kernels`` implement the same layouts; these jnp versions are
their oracles and the fallback path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

PACK = 32
#: ternary symbols per uint32 word (2 bits each; codec ``ternary2bit``)
PACK2 = 16


def sign_ternary(x: jax.Array) -> jax.Array:
    return jnp.sign(x).astype(jnp.int8)


def sign_binary(x: jax.Array) -> jax.Array:
    return jnp.where(x >= 0, jnp.int8(1), jnp.int8(-1))


def pad_to_pack(flat: jax.Array, multiple: int = PACK) -> Tuple[jax.Array, int]:
    """Pad 1-D array to a multiple; returns (padded, original_len)."""
    n = flat.shape[0]
    rem = (-n) % multiple
    if rem:
        flat = jnp.pad(flat, (0, rem))
    return flat, n


def pad_last(x: jax.Array, multiple: int) -> Tuple[jax.Array, int]:
    """Zero-pad the LAST dim to a multiple; returns (padded, original_n).

    Delegates to the single canonical implementation in
    ``core.vote_api.pad_last`` (DESIGN.md §10), so every wire's pad
    semantics come from one function (lazy import: vote_api sits above
    this module)."""
    from repro.core.vote_api import pad_last as _impl
    return _impl(x, multiple)


def pack_signs(x: jax.Array) -> jax.Array:
    """x (..., n) any real dtype, n % 32 == 0 -> uint32 (..., n // 32).

    bit j of word w encodes sign(x[..., 32*w + j]) >= 0.
    """
    if x.shape[-1] % PACK != 0:
        # a bare assert here vanishes under `python -O`, silently packing
        # garbage from a misaligned reshape; callers either pre-pad
        # (pad_last / pad_to_pack) or get told exactly what they sent
        raise ValueError(
            f"pack_signs needs last dim % {PACK} == 0, got shape "
            f"{tuple(x.shape)}; pad with pad_to_pack/pad_last first")
    bits = (x >= 0).astype(jnp.uint32)
    words = bits.reshape(x.shape[:-1] + (x.shape[-1] // PACK, PACK))
    # unrolled shift/OR: an or-reduction is not lowerable by the CPU SPMD
    # partitioner (observed on the 256-device dry-run)
    acc = jnp.zeros(words.shape[:-1], jnp.uint32)
    for j in range(PACK):
        acc = acc | (words[..., j] << jnp.uint32(j))
    return acc


def unpack_signs(packed: jax.Array, dtype=jnp.int8) -> jax.Array:
    """uint32 (..., w) -> (..., 32*w) of ±1 in `dtype`."""
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    signs = jnp.where(bits == 1, 1, -1).astype(dtype)
    return signs.reshape(packed.shape[:-1] + (packed.shape[-1] * PACK,))


def popcount(x: jax.Array) -> jax.Array:
    """Per-word population count of a uint32 array (SWAR)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x + (x >> 4)) & 0x0F0F0F0F
    return ((x * 0x01010101) >> 24).astype(jnp.int32)


def packed_majority(packed: jax.Array) -> jax.Array:
    """(M, w) packed votes -> (w,) packed majority.

    Bit-sliced: for each bit position count set bits across M workers;
    majority bit = count*2 > M (ties -> +1, consistent with sign_binary).
    """
    M = packed.shape[0]
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)   # (M, w, 32)
    counts = jnp.sum(bits.astype(jnp.int32), axis=0)       # (w, 32)
    maj = (2 * counts >= M).astype(jnp.uint32)
    return jnp.bitwise_or.reduce(maj << shifts, axis=-1)


def compression_ratio(dtype: jnp.dtype) -> float:
    """Wire compression vs a dense gradient of `dtype` (per direction)."""
    return jnp.dtype(dtype).itemsize * 8.0


# ---------------------------------------------------------------------------
# ternary 2-bit format (codec ``ternary2bit``; DESIGN.md §8)
# ---------------------------------------------------------------------------
#
# 16 symbols per uint32, 2-bit two's complement per field, little-endian:
# +1 -> 0b01, -1 -> 0b11, 0 (abstain) -> 0b00. Unlike the 1-bit wire this
# format carries the ternary sign convention end to end, so an abstaining
# replica (zero gradient) stays an abstention on the wire and a tied
# coordinate decodes to 0, exactly like the integer-count strategies.


def pack_ternary(s: jax.Array) -> jax.Array:
    """s (..., n) int8 in {-1, 0, +1}, n % 16 == 0 -> uint32 (..., n // 16).

    bits [2j, 2j+1] of word w encode s[..., 16*w + j] in 2-bit two's
    complement (the 0b10 pattern is never produced).
    """
    if s.shape[-1] % PACK2 != 0:
        raise ValueError(
            f"pack_ternary needs last dim % {PACK2} == 0, got shape "
            f"{tuple(s.shape)}; pad with pad_last first")
    sym = (s.astype(jnp.int32) & 0x3).astype(jnp.uint32)
    fields = sym.reshape(s.shape[:-1] + (s.shape[-1] // PACK2, PACK2))
    acc = jnp.zeros(fields.shape[:-1], jnp.uint32)
    for j in range(PACK2):   # unrolled shift/OR (SPMD-partitioner-safe)
        acc = acc | (fields[..., j] << jnp.uint32(2 * j))
    return acc


def unpack_ternary(packed: jax.Array, dtype=jnp.int8) -> jax.Array:
    """uint32 (..., w) -> (..., 16*w) of {-1, 0, +1} in `dtype`."""
    shifts = jnp.arange(PACK2, dtype=jnp.uint32) * 2
    fields = (packed[..., None] >> shifts) & jnp.uint32(0x3)
    signs = jnp.where(fields == 1, 1,
                      jnp.where(fields == 3, -1, 0)).astype(dtype)
    return signs.reshape(packed.shape[:-1] + (packed.shape[-1] * PACK2,))


def ternary_majority(packed: jax.Array) -> jax.Array:
    """(M, w) packed ternary votes -> (w,) packed ternary majority.

    Field-sliced: sum the sign-extended symbols across M workers; the
    majority is the sign of the sum — abstentions abstain and exact ties
    decode to 0, matching the integer-count tie convention."""
    counts = jnp.sum(unpack_ternary(packed, jnp.int32), axis=0)
    return pack_ternary(jnp.sign(counts).astype(jnp.int8))
