"""VotePlan tier-2 drill (DESIGN.md §9; scripts/ci.sh plan-smoke stage).

Host-count invariance of a MIXED-CODEC plan — ternary2bit embeddings +
sign1bit body over the gathered wire — under a 0.375 colluding-adversary
scenario: the virtual replay on a 1-device platform, the virtual replay
on the 8-device platform, and the REAL mesh backend (shard_map over 8
replicas walking the same bucket schedule) must all produce one digest.
Each platform needs its own process (XLA device count is fixed before
jax initialises), hence the subprocess pattern of test_harness8.py.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_WORKER = textwrap.dedent("""
    import sys
    import jax
    from repro.configs.base import VoteStrategy
    from repro.sim import (AdversarySpec, PlanSpec, ScenarioRunner,
                           ScenarioSpec)

    spec = ScenarioSpec(
        "plan-drill/mixed_collude", n_workers=8, n_steps=6, dim=256,
        strategy=VoteStrategy.ALLGATHER_1BIT,
        adversary=AdversarySpec("colluding", 0.375),
        plan=PlanSpec(bucket_bytes=8,
                      leaves=(("embed.table", 96), ("body.blocks", 160)),
                      codec_map=(("embed*", "ternary2bit"),
                                 ("*", "sign1bit"))))
    print("NBUCKETS", spec.runtime_plan(8).n_buckets)
    print("VDIGEST", ScenarioRunner(spec, backend="virtual").run().digest)
    if sys.argv[1] == "mesh-too":
        assert len(jax.devices()) >= 8
        print("MDIGEST",
              ScenarioRunner(spec, backend="mesh").run().digest)
""")


def _run(device_count: int, mode: str, worker: str = _WORKER):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "..", "src"),
         env.get("PYTHONPATH", "")])
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={device_count}"
    proc = subprocess.run([sys.executable, "-c", worker, mode], env=env,
                          capture_output=True, text=True, timeout=900)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "plan drill worker failed"
    return {line.split()[0]: line.split()[1]
            for line in proc.stdout.splitlines()
            if line.split() and line.split()[0] in
            ("VDIGEST", "MDIGEST", "SDIGEST", "NBUCKETS")}


@pytest.mark.slow
@pytest.mark.timeout(900)
def test_mixed_codec_plan_is_host_count_and_backend_invariant():
    d8 = _run(8, "mesh-too")
    d1 = _run(1, "virtual-only")
    assert int(d8["NBUCKETS"]) > 1, "drill must actually bucket the wire"
    assert d8["VDIGEST"] == d8["MDIGEST"], (
        "mixed-codec plan: mesh backend diverged from the virtual walk")
    assert d8["VDIGEST"] == d1["VDIGEST"], (
        "mixed-codec plan digest differs between 8-device and 1-device "
        "replays — the bucket schedule is host-count dependent")


# ---------------------------------------------------------------------------
# delayed-vote + overlapped-walk replay drill (DESIGN.md §11): the same
# mixed-codec scenario with the double-buffered executor, a one-step vote
# delay AND a mid-run elastic shrink must (a) not move a single bit of
# the overlap axis, (b) replay identically on the real mesh, and (c) stay
# host-count invariant
# ---------------------------------------------------------------------------


_DELAYED_WORKER = textwrap.dedent("""
    import dataclasses
    import sys
    import jax
    from repro.configs.base import VoteStrategy
    from repro.sim import (AdversarySpec, ElasticEvent, PlanSpec,
                           ScenarioRunner, ScenarioSpec)

    spec = ScenarioSpec(
        "plan-drill/delayed_overlap", n_workers=8, n_steps=8, dim=256,
        strategy=VoteStrategy.ALLGATHER_1BIT,
        adversary=AdversarySpec("sign_flip", 0.25),
        elastic=(ElasticEvent(4, 6, "node loss"),),
        delayed_vote=True,
        plan=PlanSpec(bucket_bytes=8, overlap=True,
                      leaves=(("embed.table", 96), ("body.blocks", 160)),
                      codec_map=(("embed*", "ternary2bit"),
                                 ("*", "sign1bit"))))
    print("NBUCKETS", spec.runtime_plan(8).n_buckets)
    print("VDIGEST", ScenarioRunner(spec, backend="virtual").run().digest)
    # the same drill on the synchronous walk: overlap must not move a bit
    sync = dataclasses.replace(
        spec, plan=dataclasses.replace(spec.plan, overlap=False))
    print("SDIGEST", ScenarioRunner(sync, backend="virtual").run().digest)
    if sys.argv[1] == "mesh-too":
        assert len(jax.devices()) >= 8
        print("MDIGEST",
              ScenarioRunner(spec, backend="mesh").run().digest)
""")


@pytest.mark.slow
@pytest.mark.timeout(900)
def test_delayed_vote_overlap_drill_replays_bit_identically():
    d8 = _run(8, "mesh-too", worker=_DELAYED_WORKER)
    d1 = _run(1, "virtual-only", worker=_DELAYED_WORKER)
    assert int(d8["NBUCKETS"]) > 1, "drill must actually bucket the wire"
    assert d8["VDIGEST"] == d8["SDIGEST"], (
        "overlapped walk diverged from the synchronous schedule under "
        "delayed votes — the issue/complete split is not semantics-free")
    assert d8["VDIGEST"] == d8["MDIGEST"], (
        "delayed-vote drill: mesh backend diverged from the virtual walk")
    assert d8["VDIGEST"] == d1["VDIGEST"], (
        "delayed-vote drill digest differs between 8-device and 1-device "
        "replays — the delay buffer is host-count dependent")


def test_checkpoint_roundtrip_of_delayed_vote_buffer(tmp_path):
    """Save an opt_state carrying the delayed-vote buffer, restore under
    an elastic shrink: every per-worker leaf refits by the §6 leading-
    axis rule while the REPLICATED param-shaped delay buffer passes
    through bit-exact — a joiner-invariant one-round memory."""
    import numpy as np
    from repro.checkpoint import checkpoint as ckpt
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(3)
    m_old, m_new = 8, 6
    shapes = {"embed.table": (4, 3), "body.w": (5,)}
    opt_state = {
        "count": np.asarray(5, np.int32),
        "momentum": {k: rng.normal(size=(m_old,) + s).astype(np.float32)
                     for k, s in shapes.items()},
        "delayed": {k: rng.integers(-1, 2, size=s).astype(np.int8)
                    for k, s in shapes.items()},
    }
    params = {k: rng.normal(size=s).astype(np.float32)
              for k, s in shapes.items()}
    ckpt.save(str(tmp_path), 5, params, opt_state)
    like_opt = {
        "count": jax.ShapeDtypeStruct((), jnp.int32),
        "momentum": {k: jax.ShapeDtypeStruct((m_new,) + s, jnp.float32)
                     for k, s in shapes.items()},
        "delayed": {k: jax.ShapeDtypeStruct(s, jnp.int8)
                    for k, s in shapes.items()},
    }
    _, opt_back, _, _ = ckpt.restore(str(tmp_path), like_opt=like_opt)
    for k, s in shapes.items():
        assert opt_back["momentum"][k].shape == (m_new,) + s
        np.testing.assert_array_equal(opt_back["momentum"][k],
                                      opt_state["momentum"][k][:m_new])
        assert opt_back["delayed"][k].dtype == np.int8
        np.testing.assert_array_equal(opt_back["delayed"][k],
                                      opt_state["delayed"][k])
