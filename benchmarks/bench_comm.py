"""Fig. 5 analog: per-step communication of majority vote vs dense
all-reduce, from (a) the VoteEngine's analytic wire model and (b) measured
wall-clock of the engine's fused local tally on this host
(compression/vote cost incl.).

Everything here runs through :class:`repro.core.vote_engine.VoteEngine` —
the same object the trainer steps through — so the reported bytes are the
bytes the production wire protocol moves, per strategy:

* ``wire_bytes``      — one replica's outbound payload per step (the
                        paper's "bits sent" metric). For ``allgather_1bit``
                        this is exactly fp32_bytes / 32.
* ``ring transit``    — per-chip transit bytes of the full exchange under
                        the ring collective model, vs the dense baseline.
* measured kernels    — the fused sign+pack+popcount Pallas kernel
                        (one pass) vs the staged bitpack-then-popcount
                        pair, plus the SIGNUM update kernels.

CLI: ``python -m benchmarks.bench_comm --smoke`` runs a small-n correctness
+ accounting pass (CI-friendly; asserts the 1-bit wire ratio and fused
kernel == oracle) and exits nonzero on violation.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import VoteStrategy, get_config
from repro.core import vote_api
from repro.core.vote_engine import (STRATEGIES, VoteEngine, select_strategy)
from repro.distributed import comm_model
from repro.distributed.comm_model import collective_time, schedule_time
from repro.kernels import ops, ref

FP32_BITS = 32.0


def _time(fn, *args, iters=5):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def alpha_beta_rows(n_small: int = 1 << 15, n_big: int = 1 << 18,
                    m_workers: int = 8):
    """Back out the α–β constants empirically: fit t(n) = α + β·n over
    the fused vote kernel at two sizes on this host — the same two-point
    fit one runs against real collective timings on hardware — and
    report the fitted α next to the model's ``ALPHA_ICI``. The per-
    message α is what makes L leaf-sized messages cost more than one
    flat message of the same bytes (``comm_model.schedule_time``); a
    model with α = 0 prices both the same and silently biases the AUTO
    selector toward chatty schedules."""
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(m_workers, n_big))
                     .astype(np.float32))
    t_small = _time(lambda: ops.fused_majority(xs[:, :n_small]))
    t_big = _time(lambda: ops.fused_majority(xs))
    beta = max(t_big - t_small, 0.0) / (n_big - n_small)
    alpha = max(t_small - beta * n_small, 0.0)
    # the bias, priced: a 100-leaf schedule vs one flat message of the
    # same total bytes under the analytic model
    n, leaves = 1 << 22, 100
    one = collective_time(n / 8.0).time_s
    many = schedule_time([(n / 8.0 / leaves, 0.0, 1)] * leaves).time_s
    return [
        ("fig5/alpha_hat_us", alpha * 1e6,
         f"host per-launch latency from t(n)=a+b*n fit at n={n_small} vs "
         f"{n_big} (model ALPHA_ICI={comm_model.ALPHA_ICI * 1e6:g} us)"),
        ("fig5/beta_hat_ps_per_param", beta * 1e12,
         f"host per-param slope of the fused vote kernel (M={m_workers})"),
        ("fig5/leafwise_latency_tax", many / one,
         f"{leaves} leaf messages vs one flat buffer, same bytes: the "
         "alpha term schedule_time now prices per message"),
    ]


def wire_rows(n_params: int, data_size: int = 16, pod_size: int = 1,
              tag: str = ""):
    """Per-strategy wire accounting rows for one model size."""
    out = []
    fp32_payload = n_params * FP32_BITS / 8.0
    for strat in VoteStrategy:
        if strat == VoteStrategy.AUTO:
            continue
        engine = VoteEngine(strategy=strat)
        impl = STRATEGIES[strat]
        payload = impl.payload_bytes(n_params, data_size * pod_size)
        c = engine.comm_bytes(n_params, data_size, pod_size, grad_bytes=4)
        t_dense = collective_time(c["dense_allreduce"]).time_s
        t_vote = collective_time(c["vote"]).time_s
        out.append((
            f"fig5/{tag}{strat.value}_wire_bytes", payload,
            f"{impl.wire_bits_per_param:g} bits/param; fp32 payload "
            f"{fp32_payload:.3g}B -> {fp32_payload / payload:.1f}x smaller"))
        out.append((
            f"fig5/{tag}{strat.value}_comm_reduction", c["ratio"],
            f"ring transit vs fp32 dense: dense={t_dense * 1e3:.2f}ms "
            f"vote={t_vote * 1e3:.2f}ms @50GB/s/link x4"))
    auto = select_strategy(n_params, data_size, pod_size)
    out.append((f"fig5/{tag}auto_strategy",
                float(list(VoteStrategy).index(auto)),
                f"AUTO resolves to {auto.value} at data={data_size} "
                f"pod={pod_size}"))
    return out


def rows():
    out = []
    # ---- analytic wire model per arch (single-pod mesh, 16 DP voters) ----
    for arch in ["zamba2-1.2b", "glm4-9b", "deepseek-67b",
                 "qwen3-moe-235b-a22b"]:
        n = get_config(arch).param_count() // 16  # per-chip TP shard
        out.extend(wire_rows(n, data_size=16, pod_size=1, tag=f"{arch}/"))
    # ---- measured compression+vote cost (the paper's 'incl. compression')
    n = 25_000_000  # resnet50-scale, the paper's model
    m_workers = 15
    g = jnp.asarray(np.random.default_rng(0).normal(size=(n,))
                    .astype(np.float32))
    m = jnp.zeros((n,), jnp.float32)
    t_pack = _time(lambda: ops.momentum_sign_pack(g, m, 0.9))
    stacked = jnp.stack([g] * m_workers)
    t_fused = _time(lambda: ops.fused_majority(stacked))
    packed = jnp.stack([ops.bitpack(g)] * m_workers)
    t_vote = _time(lambda: ops.majority(packed))
    p = jnp.zeros((n,), jnp.float32)
    t_apply = _time(lambda: ops.apply_vote(p, packed[0], 1e-4, 0.0))
    out.append(("fig5/pack25M_ms", t_pack * 1e3,
                "fused momentum+sign+bitpack (interpret on CPU)"))
    out.append(("fig5/fusedvote25M_15workers_ms", t_fused * 1e3,
                "ONE-PASS sign+pack+popcount (VoteEngine local tally)"))
    out.append(("fig5/vote25M_15workers_ms", t_vote * 1e3,
                "staged popcount majority kernel (after packed all-gather)"))
    out.append(("fig5/apply25M_ms", t_apply * 1e3, "fused unpack+update"))
    out.extend(alpha_beta_rows())
    return out


# ---------------------------------------------------------------------------
# smoke mode (scripts/ci.sh)
# ---------------------------------------------------------------------------


def smoke() -> int:
    """Small, fast, assertive: the engine's wire accounting and the fused
    Pallas path must hold the paper's headline numbers."""
    failures = 0
    n, m_workers = 1 << 16, 15
    print("name,value,derived")
    for name, value, derived in wire_rows(n, data_size=16, tag="smoke/"):
        print(f"{name},{value:.6g},{derived}", flush=True)

    # 1-bit wire format is exactly fp32/32 per payload
    payload = STRATEGIES[VoteStrategy.ALLGATHER_1BIT].payload_bytes(n)
    fp32_payload = n * FP32_BITS / 8.0
    if payload > fp32_payload / 32.0 + 1e-9:
        print(f"FAIL: allgather_1bit payload {payload} > fp32/32 "
              f"{fp32_payload / 32.0}", file=sys.stderr)
        failures += 1

    # fused Pallas kernel == composed oracle, tie cases included
    rng = np.random.default_rng(0)
    x = rng.normal(size=(m_workers, n)).astype(np.float32)
    x[: m_workers // 2, :128] = 1.0
    x[m_workers // 2:, :128] = -1.0
    got = np.asarray(ops.fused_majority(jnp.asarray(x)))
    want = np.asarray(ref.fused_majority(jnp.asarray(x)))
    if not np.array_equal(got, want):
        print("FAIL: fused_majority != ref oracle", file=sys.stderr)
        failures += 1
    else:
        print("fig5/smoke/fused_kernel_vs_oracle,1,bit-identical "
              f"(M={m_workers}, n={n})", flush=True)

    # the API's local tally: fused-kernel backend == jnp stage backend on
    # the same VoteRequest (DESIGN.md §10)
    req = vote_api.VoteRequest(payload=jnp.asarray(x), form="stacked",
                               strategy=VoteStrategy.ALLGATHER_1BIT)
    s_fused = np.asarray(
        vote_api.VirtualBackend(use_kernels=True).execute(req).votes)
    s_ref = np.asarray(vote_api.VirtualBackend().execute(req).votes)
    if not np.array_equal(s_fused, s_ref):
        print("FAIL: fused-kernel backend != jnp backend", file=sys.stderr)
        failures += 1
    else:
        print("fig5/smoke/engine_fused_vs_jnp,1,bit-identical", flush=True)

    # the alpha-beta fix: a schedule of L messages must price strictly
    # above one message of the same total bytes (per-message latency)
    for name, value, derived in alpha_beta_rows(n_small=1 << 14,
                                                n_big=1 << 16):
        print(f"{name},{value:.6g},{derived}", flush=True)
        if name.endswith("leafwise_latency_tax") and value <= 1.0:
            print("FAIL: schedule_time prices L messages <= 1 message "
                  "(alpha term lost)", file=sys.stderr)
            failures += 1
    return failures


def main() -> None:
    from repro.obs import recorder as obs
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast correctness+accounting pass for CI")
    obs.add_trace_arg(ap)
    args = ap.parse_args()
    rec = obs.activate_trace(args)
    if args.smoke:
        failures = smoke()
        obs.finish_trace(rec)
        sys.exit(1 if failures else 0)
    print("name,value,derived")
    for name, value, derived in rows():
        print(f"{name},{value:.6g},{derived}", flush=True)
    obs.finish_trace(rec)


if __name__ == "__main__":
    main()
