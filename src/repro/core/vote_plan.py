"""VotePlan: the flat-buffer bucketed vote pipeline (DESIGN.md §9).

The leaf-wise vote (`VoteEngine.vote_tree`) runs one pack → exchange →
tally → unpack round — and one kernel launch — per tensor, pays bit-pack
padding on every small leaf, and prices L leaf messages as if they were
one. A :class:`VotePlan` is the classic DDP-style fix, built once at
trace time from the static parameter shapes:

* **layout manifest** — flatten the gradient tree into ONE contiguous
  sign buffer with a deterministic layout (leaf → offset/length/shape/
  dtype, leaves sorted by name, grouped by codec);
* **codec map** — a first-match glob map over leaf names
  (``(("embed*", "ternary2bit"), ("*", "sign1bit"))``) assigns each leaf
  a gradient codec (§8); each codec's leaves form one contiguous group;
* **bucket schedule** — each group is cut into fixed-size buckets of
  ``bucket_bytes`` wire payload (bucket length rounded UP to the pack
  alignment so every bucket but each group's ragged last one is
  pad-free: ONE padded lane set per codec group — one model-wide for
  the common single-codec plan — and per group the bucket count never
  exceeds ``ceil(group_n·bits / (8·bucket_bytes))``);
* **per-bucket strategy** — ``VoteStrategy.AUTO`` prices the WHOLE
  schedule per candidate wire through the latency-aware α–β model
  (``comm_model.schedule_time``: one α term per bucket message, which is
  what the per-leaf path silently omitted) and picks the cheapest.

Execution (:func:`plan_vote_signs`) walks the static schedule, driving
the SAME :class:`~repro.core.vote_engine.VoteStrategyImpl` stage methods
the leaf-wise engine compiles — one uniform bucket vote per schedule
entry — so the ``sign1bit`` single-bucket plan is bit-identical to the
legacy wire (the tier-2 golden digest is asserted through it). Wire
statistics (vote margin / agreement, the weighted codec's flip-rate EMA
observations) are computed once over the flat buffer's true coordinates,
never over padding lanes and never diluted leaf-by-leaf.

The plan votes replica-local signs inside the manual vote region; it
deliberately does NOT touch the fused ZeRO-3 backward path (those leaves
vote inside the reduce-scatter) and is opt-in via
``OptimizerConfig.bucket_bytes`` — flattening concatenates leaves, which
forfeits their auto 'model' shardings, so the leaf-wise path stays the
default for TP-sharded giants (see vote_engine's module docstring).
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import sign_compress as sc
from repro.core.vote_engine import STRATEGIES, num_voters
from repro.distributed import comm_model
from repro.obs import recorder as obs_rec

#: base bucket alignment: lcm of the 1-bit pack (32/word) and the ternary
#: 2-bit pack (16/word) — an aligned bucket enters every wire pad-free
ALIGN = 32

#: sentinel for ``bucket_bytes``: let the AUTO selector pick a
#: per-strategy optimal bucket size by pricing a ladder of candidate
#: sizes through the (overlap-aware) α–β schedule model
AUTO_BUCKET_BYTES = -1


# ---------------------------------------------------------------------------
# the static plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One leaf's slice of the flat buffer (offsets are global)."""

    name: str
    offset: int
    length: int
    shape: Tuple[int, ...]
    dtype: str


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One schedule entry: a uniform vote over flat[start:start+length]."""

    codec: str
    strategy: VoteStrategy
    start: int
    length: int


@dataclasses.dataclass(frozen=True)
class PlanGroup:
    """All leaves sharing one codec: a contiguous run of the flat buffer."""

    codec: str
    strategy: VoteStrategy          # resolved, never AUTO
    start: int
    total: int
    leaves: Tuple[LeafSlot, ...]
    buckets: Tuple[Bucket, ...]
    #: the bucket size the schedule was actually cut at — echoes the
    #: plan-wide request, or the AUTO selector's per-strategy choice
    #: when the plan was built with ``bucket_bytes=AUTO_BUCKET_BYTES``
    bucket_bytes: int = 0


@dataclasses.dataclass(frozen=True)
class VotePlan:
    """The trace-time layout manifest + bucket schedule (hashable/static)."""

    groups: Tuple[PlanGroup, ...]
    bucket_bytes: int
    n_params: int

    @property
    def buckets(self) -> Tuple[Bucket, ...]:
        return tuple(b for g in self.groups for b in g.buckets)

    @property
    def leaves(self) -> Tuple[LeafSlot, ...]:
        return tuple(s for g in self.groups for s in g.leaves)

    @property
    def n_buckets(self) -> int:
        return sum(len(g.buckets) for g in self.groups)

    @property
    def has_server_state(self) -> bool:
        from repro.core import codecs as codecs_mod
        return any(codecs_mod.get_codec(g.codec).server_state
                   for g in self.groups)

    @property
    def worker_state_leaves(self) -> Tuple[str, ...]:
        """Leaf names whose codec carries per-worker memory (EF residual)."""
        from repro.core import codecs as codecs_mod
        return tuple(s.name for g in self.groups for s in g.leaves
                     if codecs_mod.get_codec(g.codec).worker_state)

    def leaf_codecs(self) -> Dict[str, str]:
        return {s.name: g.codec for g in self.groups for s in g.leaves}

    def init_server_state(self, n_workers: int) -> Dict[str, jax.Array]:
        """Union of the schedule's codec server states ({} if stateless)."""
        from repro.core import codecs as codecs_mod
        state: Dict[str, jax.Array] = {}
        for g in self.groups:
            state.update(codecs_mod.get_codec(g.codec)
                         .init_server_state(n_workers))
        return state

    # ---- accounting ----

    def schedule_cost(self, data_size: int, pod_size: int = 1,
                      overlap: bool = False) -> float:
        """α–β wall-clock of the full bucket schedule (one latency term
        per bucket message — the quantity AUTO minimised). With
        ``overlap=True`` the schedule is priced as the double-buffered
        walk (:func:`run_schedule`): latency terms of every bucket after
        the first hide behind the previous bucket's tally."""
        return _schedule_time(self.buckets, data_size, pod_size, overlap)


# ---------------------------------------------------------------------------
# building
# ---------------------------------------------------------------------------


def resolve_codec_map(names: Sequence[str],
                      codec_map: Sequence[Tuple[str, str]],
                      default_codec: str = "sign1bit") -> Dict[str, str]:
    """First matching glob wins; unmatched leaves take `default_codec`.
    Every mapped codec name is validated against the registry."""
    from repro.core import codecs as codecs_mod
    for pat, codec in codec_map:
        codecs_mod.get_codec(codec)          # raises on unknown codec
        if not pat:
            raise ValueError("empty glob pattern in codec_map")
    out = {}
    for name in names:
        for pat, codec in codec_map:
            if fnmatch.fnmatchcase(name, pat):
                out[name] = codec
                break
        else:
            out[name] = default_codec
    return out


def _bucket_elems(bucket_bytes: int, bits_per_param: float,
                  align: int) -> int:
    """Bucket length in coordinates: `bucket_bytes` of wire payload,
    rounded UP to `align` so non-ragged buckets are pad-free and the
    bucket count stays ≤ ceil(n·bits / (8·bucket_bytes))."""
    elems = max(1, int(bucket_bytes * 8 / bits_per_param))
    return -(-elems // align) * align


def _group_align(strategy: VoteStrategy, data_size: int) -> int:
    # hierarchical pads each vote to PACK * data_size (its reduce-scatter
    # shards must stay word-aligned); aligning buckets to that keeps the
    # one-padded-lane-set guarantee on every wire
    if strategy == VoteStrategy.HIERARCHICAL:
        return ALIGN * max(data_size, 1)
    return ALIGN


def _message_parts(codec_bits: float, strategy: VoteStrategy, length: int,
                   data_size: int, pod_size: int
                   ) -> Tuple[float, float, int]:
    """(ici bytes, dci bytes, collective count) of one bucket message."""
    impl = STRATEGIES[strategy]
    b = impl.ring_bytes(length, data_size, pod_size)
    scale = (codec_bits / impl.wire_bits_per_param
             if strategy == VoteStrategy.ALLGATHER_1BIT else 1.0)
    return b["ici"] * scale, b["dci"] * scale, int(b["n_collectives"])


def _schedule_time(buckets: Sequence[Bucket], data_size: int,
                   pod_size: int, overlap: bool = False) -> float:
    from repro.core import codecs as codecs_mod
    return comm_model.schedule_time(
        (_message_parts(codecs_mod.get_codec(b.codec).bits_per_param,
                        b.strategy, b.length, data_size, pod_size)
         for b in buckets), overlap=overlap).time_s


def _candidate_bucket_bytes(total: int, bits_per_param: float) -> list:
    """Deterministic candidate ladder for ``AUTO_BUCKET_BYTES``: powers
    of two up to the group's whole wire payload, plus the whole-group
    single bucket itself."""
    total_bytes = max(1, -(-int(total * bits_per_param) // 8))
    ladder = [1 << k for k in range(3, 25) if (1 << k) < total_bytes]
    ladder.append(total_bytes)
    return ladder


def _resolve_group(codec_name: str, strategy: VoteStrategy, total: int,
                   bucket_bytes: int, data_size: int, pod_size: int,
                   overlap: bool = False
                   ) -> Tuple[VoteStrategy, int]:
    """Concrete (strategy, bucket_bytes) for one codec group. AUTO
    prices each candidate wire's WHOLE bucket schedule (bucket count ×
    per-message α + β·bytes) instead of one leaf-sized message, so many
    small buckets can tip the choice toward fewer/wider-count wires.
    With ``bucket_bytes=AUTO_BUCKET_BYTES`` the selector jointly sweeps
    a candidate size ladder per strategy — under overlap pricing the α
    penalty of extra buckets mostly vanishes, which is what lets the
    gathered wire keep small buckets and still win. Ties break toward
    the larger bucket (fewer messages)."""
    from repro.core import codecs as codecs_mod
    codec = codecs_mod.get_codec(codec_name)
    if strategy != VoteStrategy.AUTO:
        codec.validate_strategy(strategy)
        candidates = [strategy]
    else:
        candidates = list(codec.supported_strategies)
        if data_size * pod_size <= 1:
            candidates = [VoteStrategy.PSUM_INT8
                          if VoteStrategy.PSUM_INT8 in candidates
                          else candidates[0]]
    sizes = ([bucket_bytes] if bucket_bytes != AUTO_BUCKET_BYTES else
             _candidate_bucket_bytes(total, codec.bits_per_param))
    best = None
    for cand in candidates:
        for bb in sizes:
            buckets = _cut_buckets(codec_name, cand, 0, total, bb,
                                   data_size)
            key = (_schedule_time(buckets, data_size, pod_size, overlap),
                   -bb)
            if best is None or key < best[0]:
                best = (key, cand, bb)
    return best[1], best[2]


def _cut_buckets(codec_name: str, strategy: VoteStrategy, start: int,
                 total: int, bucket_bytes: int, data_size: int
                 ) -> Tuple[Bucket, ...]:
    from repro.core import codecs as codecs_mod
    bits = codecs_mod.get_codec(codec_name).bits_per_param
    elems = _bucket_elems(bucket_bytes, bits,
                          _group_align(strategy, data_size))
    out = []
    off = 0
    while off < total:
        length = min(elems, total - off)
        out.append(Bucket(codec=codec_name, strategy=strategy,
                          start=start + off, length=length))
        off += length
    return tuple(out)


def build_plan(shapes: Dict[str, Tuple[int, ...]], *, bucket_bytes: int,
               codec_map: Sequence[Tuple[str, str]] = (),
               default_codec: str = "sign1bit",
               strategy: VoteStrategy = VoteStrategy.AUTO,
               data_size: int = 1, pod_size: int = 1,
               dtypes: Optional[Dict[str, str]] = None,
               overlap: bool = False) -> VotePlan:
    """Build the static plan for a tree of `shapes` (leaf name → shape).

    Deterministic: leaves are laid out in sorted-name order, grouped by
    their resolved codec (groups ordered by first appearance in that
    order), so the same shapes + config always produce the same manifest
    on every host. ``bucket_bytes=AUTO_BUCKET_BYTES`` (-1) lets the AUTO
    selector sweep a candidate size ladder per strategy; ``overlap``
    prices candidate schedules as the double-buffered walk (it changes
    the selector's arithmetic only — the manifest layout never depends
    on how the schedule will be executed).
    """
    if bucket_bytes <= 0 and bucket_bytes != AUTO_BUCKET_BYTES:
        raise ValueError(
            f"bucket_bytes must be positive (or AUTO_BUCKET_BYTES=-1 for "
            f"the priced ladder), got {bucket_bytes}")
    names = sorted(shapes)
    if not names:
        raise ValueError("cannot build a VotePlan over an empty tree")
    leaf_codec = resolve_codec_map(names, codec_map, default_codec)
    codec_order = []
    for name in names:
        if leaf_codec[name] not in codec_order:
            codec_order.append(leaf_codec[name])
    groups = []
    offset = 0
    for codec_name in codec_order:
        members = [n for n in names if leaf_codec[n] == codec_name]
        slots, start = [], offset
        for n in members:
            shape = tuple(shapes[n])
            length = 1
            for d in shape:
                length *= d
            slots.append(LeafSlot(
                name=n, offset=offset, length=length, shape=shape,
                dtype=(dtypes or {}).get(n, "float32")))
            offset += length
        total = offset - start
        resolved, group_bytes = _resolve_group(
            codec_name, strategy, total, bucket_bytes, data_size,
            pod_size, overlap)
        groups.append(PlanGroup(
            codec=codec_name, strategy=resolved, start=start, total=total,
            leaves=tuple(slots),
            buckets=_cut_buckets(codec_name, resolved, start, total,
                                 group_bytes, data_size),
            bucket_bytes=group_bytes))
    return VotePlan(groups=tuple(groups), bucket_bytes=bucket_bytes,
                    n_params=offset)


# ---------------------------------------------------------------------------
# flatten / unflatten (the layout round-trip)
# ---------------------------------------------------------------------------


def flatten_signs(plan: VotePlan, tree) -> jax.Array:
    """Tree of replica-local values → (n_params,) int8 ternary signs in
    manifest order (sign extraction per leaf, then concatenation — both
    elementwise, so bit-identical to the leaf-wise sign path)."""
    parts = []
    for slot in plan.leaves:
        leaf = tree[slot.name]
        if tuple(leaf.shape) != slot.shape:
            raise ValueError(
                f"leaf {slot.name!r} has shape {tuple(leaf.shape)}, plan "
                f"manifest says {slot.shape}")
        parts.append(sc.sign_ternary(leaf).reshape(-1))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unflatten_votes(plan: VotePlan, flat: jax.Array, tree) -> Dict:
    """(n_params,) flat votes → tree of leaf-shaped votes in each leaf's
    own dtype (the inverse of :func:`flatten_signs`)."""
    out = {}
    for slot in plan.leaves:
        leaf = tree[slot.name]
        out[slot.name] = (flat[slot.offset:slot.offset + slot.length]
                          .reshape(slot.shape).astype(leaf.dtype))
    return out


# ---------------------------------------------------------------------------
# execution: the schedule executor (DESIGN.md §11) — one walk, two wires,
# two issue orders. Each bucket's vote is split at the exchange boundary
# into ``issue`` (pack + put the collective on the wire) and ``complete``
# (tally + unpack + codec decode of what arrived), so the walk can either
# run them back-to-back (the synchronous schedule) or double-buffer:
# bucket k's exchange is issued while bucket k-1 completes, handing XLA's
# latency-hiding scheduler an async-collective window per bucket. Both
# orders run the SAME stage dataflow per bucket, so they are bit-identical
# by construction — and pinned to each other by the tier-1 equivalence
# matrix in tests/test_vote_plan.py.
# ---------------------------------------------------------------------------


class MeshBucketWire:
    """issue/complete over the real collectives, inside a manual mesh
    region over `axes` (the §2 stage methods `vote_api._plan_walk`
    composed, split at the exchange)."""

    def __init__(self, axes: Sequence[str]):
        self.axes = tuple(axes)

    def issue(self, bucket: Bucket, seg: jax.Array) -> jax.Array:
        m = num_voters(self.axes)
        if bucket.codec == "ternary2bit" \
                and bucket.strategy == VoteStrategy.ALLGATHER_1BIT:
            from repro.core.codecs.ternary import TERNARY_WIRE
            return TERNARY_WIRE.exchange(TERNARY_WIRE.pack(seg, m),
                                         self.axes)
        if bucket.codec == "weighted_vote":
            impl = STRATEGIES[VoteStrategy.ALLGATHER_1BIT]
            return impl.exchange(impl.pack(seg, m), self.axes)
        impl = STRATEGIES[bucket.strategy]
        if bucket.strategy == VoteStrategy.HIERARCHICAL:
            # the reduce-scatter's shards must stay word-aligned: pad to
            # PACK * data_size BEFORE pack (HierarchicalStrategy.vote)
            from repro import compat
            data_axis, _ = impl._axes(self.axes)
            seg, _ = sc.pad_last(seg, sc.PACK * compat.axis_size(data_axis))
        return impl.exchange(impl.pack(seg, m), self.axes)

    def complete(self, bucket: Bucket, arrived: jax.Array,
                 w: Optional[jax.Array]):
        """-> (votes int8 (length,), mismatch (M,) or None)."""
        m = num_voters(self.axes)
        if bucket.codec == "ternary2bit" \
                and bucket.strategy == VoteStrategy.ALLGATHER_1BIT:
            from repro.core.codecs.ternary import TERNARY_WIRE
            return TERNARY_WIRE.unpack(TERNARY_WIRE.tally(arrived, m),
                                       bucket.length, jnp.int8), None
        if bucket.codec == "weighted_vote":
            from repro.core.codecs import weighted
            # crop the bit-pack padding lanes BEFORE decoding: padding
            # always agrees with the vote and would dilute the flip
            # observations
            stacked = sc.unpack_signs(arrived, jnp.int8)[..., :bucket.length]
            return weighted.decode_leaf_fixed(stacked, w)
        impl = STRATEGIES[bucket.strategy]
        # for HIERARCHICAL the unpack stage carries the second (cheap)
        # collective — the packed all-gather of the shard decision — so
        # under overlap it is issued alongside the NEXT bucket's
        # reduce-scatter, exactly the double-buffering we want
        return impl.unpack(impl.tally(arrived, m), bucket.length,
                           jnp.int8), None


class VirtualBucketWire:
    """issue/complete over the host-side exchange equivalents of a
    stacked (M, n) voter dim — `vote_api._virtual_plan_walk` split at
    the (virtualised) exchange, so the overlapped order replays
    bit-identically off-mesh."""

    def __init__(self, m: int):
        self.m = m

    def issue(self, bucket: Bucket, seg: jax.Array) -> jax.Array:
        m = self.m
        if bucket.codec == "ternary2bit" \
                and bucket.strategy == VoteStrategy.ALLGATHER_1BIT:
            from repro.core.codecs.ternary import TERNARY_WIRE
            return TERNARY_WIRE.pack(seg, m)   # gather = already stacked
        if bucket.codec == "weighted_vote":
            return STRATEGIES[VoteStrategy.ALLGATHER_1BIT].pack(seg, m)
        impl = STRATEGIES[bucket.strategy]
        if bucket.strategy == VoteStrategy.PSUM_INT8:
            wire = impl.pack(seg, m)
            # psum over the vote axes == sum over the voter dim, in the
            # wire dtype (safe: |sum| <= M <= dtype max)
            return jnp.sum(wire, axis=0).astype(wire.dtype)
        if bucket.strategy == VoteStrategy.ALLGATHER_1BIT:
            return impl.pack(seg, m)
        if bucket.strategy == VoteStrategy.HIERARCHICAL:
            # virtual single-pod mesh: data axis = all M voters; pad so
            # the reduce-scatter shards stay word-aligned
            padded, _ = sc.pad_last(seg, sc.PACK * m)
            wire = impl.pack(padded, m)
            summed = jnp.sum(wire, axis=0).astype(wire.dtype)
            return summed.reshape(m, padded.shape[-1] // m)
        raise ValueError(f"virtual wire cannot realise {bucket.strategy!r}")

    def complete(self, bucket: Bucket, arrived: jax.Array,
                 w: Optional[jax.Array]):
        m = self.m
        if bucket.codec == "ternary2bit" \
                and bucket.strategy == VoteStrategy.ALLGATHER_1BIT:
            from repro.core.codecs.ternary import TERNARY_WIRE
            return TERNARY_WIRE.unpack(TERNARY_WIRE.tally(arrived, m),
                                       bucket.length, jnp.int8), None
        if bucket.codec == "weighted_vote":
            from repro.core.codecs import weighted
            stacked = sc.unpack_signs(arrived, jnp.int8)[:, :bucket.length]
            return weighted.decode_leaf_fixed(stacked, w)
        impl = STRATEGIES[bucket.strategy]
        if bucket.strategy == VoteStrategy.HIERARCHICAL:
            # unpack stage: pack each shard's decision, 'all-gather' =
            # concatenate in replica order
            decision = impl.tally(arrived, m)
            packed = sc.pack_signs(decision).reshape(-1)
            return sc.unpack_signs(packed, jnp.int8)[:bucket.length], None
        return impl.unpack(impl.tally(arrived, m), bucket.length,
                           jnp.int8), None


def run_schedule(plan: VotePlan, buf: jax.Array, wire,
                 server_state=None, overlap: bool = False):
    """Walk the bucket schedule over `buf` (the (n_params,) flat signs
    on the mesh wire, or the (M, n_params) stacked buffer on the virtual
    wire) -> (votes (.., n_params) int8, new server state).

    ``overlap=False`` completes each bucket before issuing the next (the
    historical synchronous walk). ``overlap=True`` double-buffers:
    bucket k is issued, THEN bucket k-1 completes, so tally/unpack of
    one bucket overlaps the next bucket's exchange. Per-bucket dataflow
    is identical either way — only the issue order changes — so the two
    walks are bit-identical; server-stateful codecs decode every bucket
    under weights FIXED for the step and fold ONE flip-rate EMA update
    across the schedule, normalised by the weighted buckets' true
    coordinate count (padding lanes never observed)."""
    state = dict(server_state) if server_state else {}
    w = None
    if plan.has_server_state:
        from repro.core.codecs import weighted
        if "flip_ema" not in state:
            raise ValueError(
                "plan carries a server-stateful codec; thread its server "
                "state (init_server_state) through the request")
        w = weighted.reliability_weights(state["flip_ema"])
    buckets = plan.buckets
    # exact bucket accounting, always on (trace-time semantics under jit:
    # one increment per compile = buckets walked per execution)
    obs_rec.COUNTERS.inc("plan.buckets", len(buckets))

    def seg(b: Bucket) -> jax.Array:
        return jax.lax.slice_in_dim(buf, b.start, b.start + b.length,
                                    axis=-1)

    rec = obs_rec.get_recorder()
    if rec.enabled:
        # host-side spans per bucket issue/complete, the issue span
        # carrying the α–β model's predicted exchange time — the
        # measured-vs-predicted pair trace_report.py aggregates. The
        # virtual wire's voter dim is its own mesh; the real wire reads
        # the region's axis sizes.
        data = (wire.m if hasattr(wire, "m") else num_voters(wire.axes))
        from repro.core import codecs as codecs_mod

        def _issue(k: int) -> jax.Array:
            b = buckets[k]
            ici, dci, ncoll = _message_parts(
                codecs_mod.get_codec(b.codec).bits_per_param, b.strategy,
                b.length, data, 1)
            pred = comm_model.collective_time(
                ici, dci, n_collectives=ncoll).time_s
            with rec.span("plan.issue", bucket=k, codec=b.codec,
                          strategy=b.strategy.value, length=b.length,
                          pred_s=pred):
                return wire.issue(b, seg(b))

        def _complete(k: int, inflight):
            b = buckets[k]
            with rec.span("plan.complete", bucket=k, codec=b.codec,
                          strategy=b.strategy.value):
                return wire.complete(b, inflight, w)
    else:
        def _issue(k: int) -> jax.Array:
            return wire.issue(buckets[k], seg(buckets[k]))

        def _complete(k: int, inflight):
            return wire.complete(buckets[k], inflight, w)

    def _walk():
        done = []
        if overlap and len(buckets) > 1:
            inflight = _issue(0)
            for k in range(1, len(buckets)):
                nxt = _issue(k)
                done.append(_complete(k - 1, inflight))
                inflight = nxt
            done.append(_complete(len(buckets) - 1, inflight))
        else:
            for k in range(len(buckets)):
                done.append(_complete(k, _issue(k)))
        return done

    if rec.enabled:
        with rec.span("plan.schedule", n_buckets=len(buckets),
                      overlap=bool(overlap and len(buckets) > 1)):
            done = _walk()
    else:
        done = _walk()
    votes, mismatch, total_w = [], None, 0
    for b, (vote, mis) in zip(buckets, done):
        votes.append(vote)
        if mis is not None:
            mismatch = mis if mismatch is None else mismatch + mis
            total_w += b.length
    if mismatch is not None:
        from repro.core.codecs import weighted
        state["flip_ema"] = ((1.0 - weighted.RHO) * state["flip_ema"]
                             + weighted.RHO * mismatch / total_w)
    out = jnp.concatenate(votes) if len(votes) > 1 else votes[0]
    return out, state


# ---------------------------------------------------------------------------
# execution: deprecation shims over the vote API (DESIGN.md §10) — the
# schedule walks now live in `vote_api` (the mesh walk and its
# exchange-virtualised twin side by side, sharing the §2 stage methods
# and pinned to each other by the tier-2 mesh==virtual drills)
# ---------------------------------------------------------------------------


def plan_vote_signs(plan: VotePlan, flat_signs: jax.Array,
                    axes: Tuple[str, ...], server_state=None):
    """DEPRECATED shim: the schedule walk over (n_params,) effective
    int8 signs (post-stale, post-adversary) inside the manual vote
    region → ((n_params,) int8 votes, new server state)."""
    from repro.core import vote_api as va
    va.warn_legacy("vote_plan.plan_vote_signs")
    out = va.MeshBackend(axes=tuple(axes)).execute(va.VoteRequest(
        payload=flat_signs, form="leaf", plan=plan,
        server_state=server_state))
    return out.votes, out.server_state


def plan_tree_vote(plan: VotePlan, tree, axes: Sequence[str],
                   byz: Optional[ByzantineConfig] = None, step=None,
                   salt: int = 0, server_state=None,
                   diagnostics: bool = False):
    """DEPRECATED shim: the trainer's plan path — tree of replica-local
    values → (±1 tree in leaf dtypes, new server state, diagnostics
    dict) through one flat bucketed wire buffer."""
    from repro.core import vote_api as va
    va.warn_legacy("vote_plan.plan_tree_vote")
    out = va.MeshBackend(axes=tuple(axes)).execute(va.VoteRequest(
        payload=tree, form="tree", plan=plan,
        failures=va.FailureSpec(byz=byz), step=step, salt=salt,
        server_state=server_state, diagnostics=diagnostics))
    diag = {}
    if diagnostics:
        diag = {"vote_margin": out.wire.margin,
                "vote_agreement": out.wire.agreement}
    return out.votes, out.server_state, diag


# ---------------------------------------------------------------------------
# execution: the host-local stacked path (kernels)
# ---------------------------------------------------------------------------


def plan_vote_stacked(plan: VotePlan, stacked: jax.Array,
                      use_kernels: bool = True) -> jax.Array:
    """Host-local simulation path over a stacked (M, n_params) buffer:
    ONE fused sign+pack+popcount kernel launch per bucket, each on the
    bucket's uniform shape (the leaf-wise path launched once per leaf).
    1-bit buckets take the Pallas kernel; ternary buckets take the jnp
    ternary tally (their 2-bit wire has no binary-majority kernel).

    Realises the GATHERED wire only: the fused kernel's binary majority
    (ties → +1) is ``allgather_1bit``'s tie rule, not the count wires',
    and it has no server-state decode — plans whose schedule needs
    either are rejected rather than silently mis-decoded (use
    :func:`plan_vote_signs` / ``virtual_plan_vote`` for those)."""
    from repro.kernels import ops
    votes = []
    for bucket in plan.buckets:
        if bucket.strategy != VoteStrategy.ALLGATHER_1BIT:
            raise ValueError(
                f"plan_vote_stacked realises the gathered 1-bit wire; "
                f"bucket strategy {bucket.strategy.value!r} has different "
                "tie semantics (use plan_vote_signs / virtual_plan_vote)")
        if bucket.codec == "weighted_vote":
            raise ValueError(
                "plan_vote_stacked has no server-state decode; route "
                "weighted_vote plans through virtual_plan_vote")
        seg = stacked[:, bucket.start:bucket.start + bucket.length]
        if bucket.codec == "ternary2bit":
            s = sc.sign_ternary(seg)
            padded, _ = sc.pad_last(s, sc.PACK2)
            maj = sc.ternary_majority(sc.pack_ternary(padded))
            votes.append(sc.unpack_ternary(maj, jnp.int8)[:bucket.length])
        elif use_kernels:
            packed = ops.fused_majority(seg)
            votes.append(ops.bitunpack(packed, bucket.length, jnp.int8))
        else:
            padded, _ = sc.pad_last(seg, sc.PACK)
            maj = sc.packed_majority(sc.pack_signs(padded))
            votes.append(sc.unpack_signs(maj, jnp.int8)[:bucket.length])
    return jnp.concatenate(votes) if len(votes) > 1 else votes[0]
