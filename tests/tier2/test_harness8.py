"""Drives tests/tier2/scenario_harness.py in subprocesses.

Two runs: the 8-virtual-device platform (full checks: mesh == virtual
bit-identity, compat shims, adversary lemma) and a 1-device platform in
``virtual-only`` mode. The VDIGEST lines of both runs must match exactly
— the Scenario Lab's "reproducible across host counts" guarantee as a
string diff.
"""
import os
import subprocess
import sys

import pytest

HARNESS = os.path.join(os.path.dirname(__file__), "scenario_harness.py")


def _run(device_count: int, *args: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "..", "src"),
         env.get("PYTHONPATH", "")])
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={device_count}"
    proc = subprocess.run([sys.executable, HARNESS, *args], env=env,
                          capture_output=True, text=True, timeout=1200)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "scenario harness failed"
    assert "ALL SCENARIO HARNESS CHECKS PASSED" in proc.stdout
    return {line.split()[1]: line.split()[2]
            for line in proc.stdout.splitlines()
            if line.startswith("VDIGEST ")}


@pytest.mark.slow
@pytest.mark.timeout(1200)
def test_scenario_harness_8dev_and_host_count_invariance():
    d8 = _run(8)
    d1 = _run(1, "virtual-only")
    assert d8 and set(d8) == set(d1)
    for name in d8:
        assert d8[name] == d1[name], (
            f"{name}: digest differs between 8-device and 1-device "
            "replays — per-scenario seeding is host-count dependent")
