"""Failure-mode composition (satellite coverage):

* straggler substitution x Byzantine perturbation in ONE step — the order
  is pinned (stale first, then adversary): a stale adversary corrupts its
  STALE vector, which is observably different from corrupting a fresh one;
* ``simulate_stragglers`` + ``straggler_mask_for`` through a real (tiny)
  mesh region, composed with the engine's compiled adversary;
* ``ElasticPlan`` reshard with Mode-A per-worker momentum truncation /
  zero-padding round-tripping through ``checkpoint.restore``.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import byzantine, sign_compress as sc
from repro.distributed import fault_tolerance as ft
from repro.sim import AdversarySpec, ScenarioRunner, ScenarioSpec
from repro.sim.virtual_mesh import VirtualVoteEngine


# ---------------------------------------------------------------------------
# stale x adversary ordering
# ---------------------------------------------------------------------------


def test_stale_adversary_corrupts_stale_vector():
    """Replica 0 is both stale and a sign-flipper: what reaches the wire
    must be -prev[0], not -fresh[0] (and the two differ)."""
    rng = np.random.default_rng(0)
    fresh = np.where(rng.integers(0, 2, (4, 40)) == 1, 1, -1).astype(np.int8)
    prev = np.where(rng.integers(0, 2, (4, 40)) == 1, 1, -1).astype(np.int8)
    assert (fresh[0] != prev[0]).any()
    eng = VirtualVoteEngine(VoteStrategy.PSUM_INT8,
                            ByzantineConfig(mode="sign_flip",
                                            num_adversaries=1))
    eff = np.asarray(eng.effective_signs(
        jnp.asarray(fresh, jnp.float32), jnp.asarray(prev), n_stale=1))
    np.testing.assert_array_equal(eff[0], -prev[0])      # stale THEN flip
    np.testing.assert_array_equal(eff[1:], fresh[1:])
    assert (eff[0] != -fresh[0]).any()                   # != fresh adversary


def test_stale_honest_vs_stale_adversary_differ_in_vote():
    """Same scenario, adversary on/off: with the adversary also straggling
    the vote must reflect the flipped STALE vector."""
    signs = np.ones((3, 8), np.int8)
    prev = -np.ones((3, 8), np.int8)
    honest = VirtualVoteEngine(VoteStrategy.PSUM_INT8)
    evil = VirtualVoteEngine(VoteStrategy.PSUM_INT8,
                             ByzantineConfig(mode="sign_flip",
                                             num_adversaries=1))
    v_honest, _ = honest.vote_with_failures(
        jnp.asarray(signs, jnp.float32), jnp.asarray(prev), n_stale=1)
    v_evil, _ = evil.vote_with_failures(
        jnp.asarray(signs, jnp.float32), jnp.asarray(prev), n_stale=1)
    # 1 stale: honest wire is (-1, +1, +1) -> +1; the evil straggler
    # flips its STALE -1 back to +1 -> unanimous +1 (same vote, larger
    # margin)
    np.testing.assert_array_equal(np.asarray(v_honest), np.ones(8))
    np.testing.assert_array_equal(np.asarray(v_evil), np.ones(8))
    # 2 stale: honest wire (-1, -1, +1) -> -1, but with replica 0 evil
    # the wire is (+1, -1, +1) -> +1 — the composed failure changes the
    # DECISION, which neither failure does alone
    v_h2, _ = honest.vote_with_failures(
        jnp.asarray(signs, jnp.float32), jnp.asarray(prev), n_stale=2)
    v_e2, _ = evil.vote_with_failures(
        jnp.asarray(signs, jnp.float32), jnp.asarray(prev), n_stale=2)
    np.testing.assert_array_equal(np.asarray(v_h2), -np.ones(8))
    np.testing.assert_array_equal(np.asarray(v_e2), np.ones(8))


def test_mesh_region_compose_stale_and_adversary_one_device():
    """vote_with_failures through a real shard_map region (1-device mesh,
    partial-auto: the trainer's configuration) composes both failures."""
    from jax.sharding import PartitionSpec as P
    from repro.core.vote_engine import VoteEngine

    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    eng = VoteEngine(strategy=VoteStrategy.PSUM_INT8, axes=("data",),
                     byz=ByzantineConfig(mode="sign_flip",
                                         num_adversaries=1))

    def f(vals, prev, step):
        out = ft.vote_with_failures(eng, vals[0], prev[0], n_stale=1,
                                    step=step)
        return out[None]

    sh = compat.shard_map(f, mesh=mesh, in_specs=(P("data"), P("data"), P()),
                          out_specs=P("data"), axis_names={"data"},
                          check_vma=False)
    vals = jnp.ones((1, 16), jnp.float32)
    prev = -jnp.ones((1, 16), jnp.int8)
    out = np.asarray(jax.jit(sh)(vals, prev, jnp.int32(0)))[0]
    # M=1, replica 0 stale AND adversarial: vote = -(-1) = +1... the stale
    # substitution hands -1, the flip makes it +1
    np.testing.assert_array_equal(out, np.ones(16, np.float32))


def test_straggler_mask_and_simulate_compose_pointwise():
    signs = jnp.asarray(np.arange(12).reshape(4, 3) % 3 - 1, jnp.int8)
    prev = jnp.asarray(-np.ones((4, 3)), jnp.int8)
    mask = (jnp.arange(4) < 2)[:, None]
    out = np.asarray(ft.simulate_stragglers(signs, prev, mask))
    np.testing.assert_array_equal(out[:2], -np.ones((2, 3)))
    np.testing.assert_array_equal(out[2:], np.asarray(signs)[2:])


# ---------------------------------------------------------------------------
# ElasticPlan + checkpoint restore round-trip (Mode A momentum)
# ---------------------------------------------------------------------------


def test_plan_rescale_keeps_tp_and_shrinks_data():
    plan = ft.plan_rescale((4, 2), ("data", "model"), surviving_devices=6)
    assert plan.new_axes == ("data", "model")
    assert plan.new_shape == (2, 2)          # largest pow2 data fit
    assert plan.new_replicas == 2
    with pytest.raises(ValueError):
        ft.plan_rescale((4, 8), ("data", "model"), surviving_devices=4)


@pytest.mark.parametrize("new_m,kind", [(2, "truncates"), (6, "zero-pads")])
def test_mode_a_momentum_roundtrip_through_restore(tmp_path, new_m, kind):
    """Save per-worker (leading vote-axis) momentum for M=4, restore under
    a rescaled replica count: truncate-or-zero-pad along axis 0, exactly
    the Scenario Lab's elastic rule (§6)."""
    from repro.checkpoint import checkpoint as ckpt

    rng = np.random.default_rng(1)
    params = {"w": rng.normal(size=(8, 3)).astype(np.float32)}
    mom = {"w": rng.normal(size=(4, 8, 3)).astype(np.float32)}
    opt = {"count": np.int32(7), "momentum": mom}
    ckpt.save(str(tmp_path), 7, params, opt)

    like_opt = {"count": jax.ShapeDtypeStruct((), jnp.int32),
                "momentum": {"w": jax.ShapeDtypeStruct((new_m, 8, 3),
                                                       jnp.float32)}}
    _, opt_r, _, meta = ckpt.restore(str(tmp_path), like_opt=like_opt)
    got = opt_r["momentum"]["w"]
    assert got.shape == (new_m, 8, 3)
    keep = min(new_m, 4)
    np.testing.assert_array_equal(got[:keep], mom["w"][:keep])
    if new_m > 4:   # joiners start with zero momentum (stale-but-honest)
        np.testing.assert_array_equal(got[4:], 0.0)
    assert meta["step"] == 7


def test_runner_elastic_refit_matches_checkpoint_rule():
    """The runner's mid-run rescale applies checkpoint.refit_leading_axis:
    growing the voter set back must leave survivors' momentum intact and
    hand joiners zeros — visible as the joiners abstaining if immediately
    stale (prev_signs zero-padded)."""
    from repro.checkpoint.checkpoint import refit_leading_axis
    v = np.arange(12, dtype=np.float32).reshape(4, 3)
    shrunk = refit_leading_axis(v, (2, 3))
    np.testing.assert_array_equal(shrunk, v[:2])
    grown = refit_leading_axis(shrunk, (5, 3))
    np.testing.assert_array_equal(grown[:2], v[:2])
    np.testing.assert_array_equal(grown[2:], 0.0)
    with pytest.raises(ValueError):
        refit_leading_axis(v, (4, 7))


def test_elastic_scenario_digest_invariant_to_backend_shape():
    """Elastic drill is deterministic and its noise stream depends only on
    the CURRENT voter count — shrinking at step k and starting at the
    smaller size agree from that step's noise onward (trace sanity)."""
    from repro.sim import ElasticEvent
    spec = ScenarioSpec("el/det", n_workers=6, n_steps=8, dim=48,
                        adversary=AdversarySpec("random", 0.3),
                        elastic=(ElasticEvent(4, 3),))
    t1 = ScenarioRunner(spec).run()
    t2 = ScenarioRunner(spec).run()
    assert t1.digest == t2.digest
    assert [s.n_workers for s in t1.steps] == [6] * 4 + [3] * 4
