"""Sharding rules: parameter-name / activation -> PartitionSpec.

The mesh has axes ``('data', 'model')`` single-pod or ``('pod', 'data',
'model')`` multi-pod (launch/mesh.py). Batch is sharded over
``('pod','data')`` jointly; weights are TP-sharded over ``'model'`` and
(for Mode-B archs) FSDP-sharded over ``'data'``.

``shard(x, spec)`` is the in-model annotation helper: it applies
``with_sharding_constraint`` when tracing under a non-empty mesh and is the
identity otherwise, so the same model code runs in single-device tests and
in the 512-chip dry-run.
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

# ---------------------------------------------------------------------------
# activation annotation helper
# ---------------------------------------------------------------------------


def shard(x: jax.Array, *spec) -> jax.Array:
    """Constrain ``x`` to PartitionSpec(*spec) if a mesh is active.

    Robustness rules so model code can annotate unconditionally:
    * axis names absent from the mesh (e.g. 'pod' single-pod) are dropped;
    * axes Manual in the current context (inside shard_map) are dropped —
      they are already consumed;
    * entries whose dimension is not divisible by the axis size are
      dropped (e.g. 60 experts on a 16-wide model axis).
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    manual = {n for n, t in zip(mesh.axis_names, mesh.axis_types)
              if t == compat.AxisType.Manual}
    avail = set(mesh.axis_names) - manual
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

    def fix(entry, dim):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(e for e in entry if e in avail)
        else:
            kept = (entry,) if entry in avail else ()
        total = 1
        for e in kept:
            total *= sizes.get(e, 1)
        if not kept or total <= 1 or dim % total != 0:
            return None
        return kept if len(kept) > 1 else kept[0]

    fixed = P(*(fix(e, d) for e, d in zip(spec, x.shape)))
    if all(e is None for e in fixed):
        return x
    # legacy JAX resolves bare PartitionSpecs only under `with mesh:`; when
    # the compat layer knows the concrete mesh, bind it explicitly.
    concrete = getattr(mesh, "concrete", None)
    if concrete is not None:
        fixed = jax.sharding.NamedSharding(concrete, fixed)
    return jax.lax.with_sharding_constraint(x, fixed)


def mesh_axis_size(name: str) -> int:
    """Size of a mesh axis in the current (abstract) mesh context; 1 if
    absent/no mesh. Includes Manual axes (shard_map context)."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    return sizes.get(name, 1)


def batch_axes() -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data")


BATCH = ("pod", "data")  # spec entry for the batch dimension


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------

# Patterns are matched in order against the flat param name. `fsdp` entries
# ('data',) are only applied when the arch's optimizer runs in Mode B
# (global momentum); Mode A keeps params replicated over 'data' so each
# replica votes on the full TP shard.
#
# Legend for spec entries: "M" = 'model' (TP/EP), "F" = 'data' (FSDP), None.
_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings: vocab over model only — deliberately NOT FSDP-sharded:
    # at vocab/16 they are ~100 MB/chip, and gathering a (vocab-sharded,
    # d-FSDP) table forces the SPMD partitioner into an involuntary full
    # fp32 rematerialisation (measured 2x 3.35 GiB on deepseek-67b). Their
    # gradients take the explicit per-leaf vote instead.
    (r"^embed\.table$", ("M", None)),
    (r"^unembed\.table$", ("M", None)),
    (r"^enc_embed\.pos$", (None, None)),
    # attention (stacked: leading L axis)
    (r"\.attn_wq$|\.xattn_wq$", (None, "F", "M")),
    (r"\.attn_wk$|\.xattn_wk$", (None, "F", "M")),
    (r"\.attn_wv$|\.xattn_wv$", (None, "F", "M")),
    (r"\.attn_wo$|\.xattn_wo$", (None, "M", "F")),
    (r"\.attn_b[qkv]$|\.xattn_b[qkv]$", (None, "M")),
    # dense mlp / shared-expert mlp (stacked)
    (r"\.(mlp|shared)_w_gate$", (None, "F", "M")),
    (r"\.(mlp|shared)_w_up$", (None, "F", "M")),
    (r"\.(mlp|shared)_w_down$", (None, "M", "F")),
    (r"\.shared_gate_w$", (None, "F", None)),
    # MoE experts: expert axis over model (EP); when num_experts is not
    # divisible by the model axis (qwen2-moe: 60 experts on 16) param_spec
    # falls back to sharding the per-expert d_ff (TP-within-expert).
    (r"\.experts_w_gate$", (None, "M", "F", "M2")),
    (r"\.experts_w_up$", (None, "M", "F", "M2")),
    (r"\.experts_w_down$", (None, "M", "M2", "F")),
    (r"\.router_w$", (None, "F", None)),
    # mamba2 (stacked): inner dim over model
    (r"\.mamba_(zproj|xbcproj|dtproj)$", (None, "F", "M")),
    (r"\.mamba_out_proj$", (None, "M", "F")),
    (r"\.mamba_conv_w$", (None, None, "M")),
    (r"\.mamba_conv_b$", (None, "M")),
    (r"\.mamba_norm_scale$", (None, "M")),
    (r"\.mamba_(dt_bias|A_log|D)$", (None, "M")),
    # zamba2 shared block (no leading L axis)
    (r"^shared_block\.attn_wq$", ("F", "M")),
    (r"^shared_block\.attn_wk$", ("F", "M")),
    (r"^shared_block\.attn_wv$", ("F", "M")),
    (r"^shared_block\.attn_wo$", ("M", "F")),
    (r"^shared_block\.mlp_w_gate$", ("F", "M")),
    (r"^shared_block\.mlp_w_up$", ("F", "M")),
    (r"^shared_block\.mlp_w_down$", ("M", "F")),
    # norms etc: replicated
    (r".*", (None,) * 8),
)


def _entry(tag: Optional[str], fsdp: bool) -> Optional[object]:
    if tag in ("M", "M2"):
        return "model"
    if tag == "F":
        return "data" if fsdp else None
    return tag


def param_spec(name: str, shape: Tuple[int, ...], *, fsdp: bool,
               mesh_axes: Tuple[str, ...] = ("data", "model"),
               mesh_shape: Optional[Dict[str, int]] = None) -> P:
    """PartitionSpec for parameter `name` of `shape`.

    Drops a sharded axis whenever the dim is not divisible by the mesh
    axis size (e.g. kv-head projections smaller than the model axis).
    "M2" entries are fallbacks: used only when the "M" dim dropped.
    """
    for pat, tags in _RULES:
        if re.search(pat, name):
            tags = list(tags[: len(shape)])
            tags += [None] * (len(shape) - len(tags))
            entries = [_entry(t, fsdp) if t != "M2" else None for t in tags]
            if mesh_shape:
                for i, e in enumerate(entries):
                    if e is not None and shape[i] % mesh_shape.get(e, 1) != 0:
                        entries[i] = None
            # activate "M2" fallback if the primary "M" was dropped
            if "M2" in tags and not any(
                    e == "model" for e in entries):
                i = tags.index("M2")
                if not mesh_shape or shape[i] % mesh_shape.get("model", 1) == 0:
                    entries[i] = "model"
            # never shard the same mesh axis twice in one spec
            seen = set()
            for i, e in enumerate(entries):
                if e in seen:
                    entries[i] = None
                elif e is not None:
                    seen.add(e)
            return P(*entries)
    raise AssertionError("unreachable: catch-all rule")


def param_specs(shapes: Dict[str, Tuple[int, ...]], *, fsdp: bool,
                mesh_shape: Optional[Dict[str, int]] = None) -> Dict[str, P]:
    return {
        k: param_spec(k, v, fsdp=fsdp, mesh_shape=mesh_shape)
        for k, v in shapes.items()
    }


# ---------------------------------------------------------------------------
# KV-cache sharding
# ---------------------------------------------------------------------------


def kv_cache_spec(num_kv_heads: int, model_axis: int) -> Tuple[P, str]:
    """Spec for (L, B, S, Hkv, hd) caches.

    Shard heads over 'model' when divisible, else shard the sequence
    (flash-decode style — XLA handles the partial-softmax reduction).
    """
    if num_kv_heads % model_axis == 0:
        return P(None, BATCH, None, "model", None), "heads"
    return P(None, BATCH, "model", None, None), "seq"
