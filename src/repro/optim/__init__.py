"""Optimizer facade: the paper's sign-vote family plus dense baselines.

Implementations live in `repro.core.signum` (they are the paper's core
contribution); this package re-exports the stable public API.
"""
from repro.core.signum import (Optimizer, build_optimizer, lr_at,
                               make_dense_optimizer, make_sign_optimizer)

__all__ = ["Optimizer", "build_optimizer", "lr_at", "make_dense_optimizer",
           "make_sign_optimizer"]
