"""whisper-tiny — encoder-decoder transformer; conv frontend stubbed.

[arXiv:2212.04356; unverified]  4L (enc) + 4L (dec) d_model=384 6H (kv=6)
d_ff=1536 vocab=51865.  ``input_specs()`` provides precomputed mel-frame
embeddings in place of the 2x conv1d stem (embed_frontend_stub).
"""
from repro.configs.base import SKIP_LONG, ArchFamily, ModelConfig, register


@register("whisper-tiny")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family=ArchFamily.AUDIO,
        num_layers=4,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        d_ff=1536,
        vocab_size=51_865,
        head_dim=64,
        encoder_layers=4,
        max_source_positions=1500,
        embed_frontend_stub=True,
        rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
        tie_embeddings=True,
        skip_shapes=(SKIP_LONG,),
    )
