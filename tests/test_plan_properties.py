"""Property-based tests (hypothesis) for the VotePlan subsystem
(DESIGN.md §9):

* the layout manifest partitions [0, n_params) exactly — every leaf
  once, no gaps, no overlaps — for arbitrary tree structures;
* flatten → bucket → vote → unflatten is the identity against the
  whole-buffer codec decode for EVERY codec, under arbitrary voter
  counts, dims and bucket sizes (the bucket cut is semantics-free);
* bucket counts respect the ceil(n·bits/(8·bucket_bytes)) bound at any
  bucket_bytes;
* the weighted codec's one-EMA-update-per-step rule is invariant to the
  bucket cut.

``hypothesis`` is optional: without it this module skips (tier-1 covers
the same invariants deterministically in tests/test_vote_plan.py).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; deterministic "
    "equivalents live in tests/test_vote_plan.py")
from hypothesis import given, settings, strategies as st

from repro.configs.base import VoteStrategy
from repro.core import codecs, vote_plan as vp
from repro.core.codecs import weighted as wv
from repro.sim.virtual_mesh import virtual_plan_vote, virtual_vote_codec

leaf_names = st.text(
    alphabet="abcdefgh.", min_size=1, max_size=12).filter(
    lambda s: s.strip("."))
tree_shapes = st.dictionaries(
    leaf_names,
    st.lists(st.integers(1, 7), min_size=0, max_size=3).map(tuple),
    min_size=1, max_size=8)


@given(tree_shapes, st.integers(1, 64))
@settings(max_examples=100, deadline=None)
def test_manifest_partitions_exactly(shapes, bucket_bytes):
    plan = vp.build_plan(shapes, bucket_bytes=bucket_bytes)
    total = sum(int(np.prod(s)) for s in shapes.values())
    assert plan.n_params == total
    spans = sorted((s.offset, s.offset + s.length) for s in plan.leaves)
    assert spans[0][0] == 0 and spans[-1][1] == total
    assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))
    bspans = sorted((b.start, b.start + b.length) for b in plan.buckets)
    assert bspans[0][0] == 0 and bspans[-1][1] == total
    assert all(a[1] == b[0] for a, b in zip(bspans, bspans[1:]))
    assert plan.n_buckets <= sum(
        -(-g.total * int(codecs.get_codec(g.codec).bits_per_param)
          // (8 * bucket_bytes)) + 1 for g in plan.groups)


@given(st.integers(2, 12), st.integers(1, 120), st.integers(1, 16),
       st.sampled_from(sorted(codecs.list_codecs())), st.randoms())
@settings(max_examples=100, deadline=None)
def test_bucket_cut_is_semantics_free(m, n, bucket_bytes, codec, rnd):
    """Any bucket cut decodes identically to the whole-buffer codec wire
    (vote AND server state)."""
    strategy = VoteStrategy.ALLGATHER_1BIT
    signs = np.array([[rnd.choice([-1, 0, 1]) for _ in range(n)]
                      for _ in range(m)], np.int8)
    plan = vp.build_plan({"x": (n,)}, bucket_bytes=bucket_bytes,
                         strategy=strategy, default_codec=codec)
    state = codecs.get_codec(codec).init_server_state(m)
    got, new_state = virtual_plan_vote(jnp.asarray(signs), plan, state)
    want, want_state = virtual_vote_codec(jnp.asarray(signs), strategy,
                                          codec, state)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for k in state:
        np.testing.assert_allclose(np.asarray(new_state[k]),
                                   np.asarray(want_state[k]), rtol=1e-6)


@given(tree_shapes, st.integers(1, 32), st.randoms())
@settings(max_examples=50, deadline=None)
def test_flatten_unflatten_identity_arbitrary_trees(shapes, bucket_bytes,
                                                    rnd):
    tree = {k: jnp.asarray(np.asarray(
        [rnd.gauss(0, 1) for _ in range(int(np.prod(s)))],
        np.float32).reshape(s)) for k, s in shapes.items()}
    plan = vp.build_plan(shapes, bucket_bytes=bucket_bytes)
    flat = vp.flatten_signs(plan, tree)
    back = vp.unflatten_votes(plan, flat, tree)
    for k, leaf in tree.items():
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.sign(np.asarray(leaf)))


@given(st.integers(2, 12), st.integers(2, 120), st.integers(1, 16),
       st.sampled_from(sorted(codecs.list_codecs())), st.randoms())
@settings(max_examples=100, deadline=None)
def test_overlap_walk_is_bit_identical(m, n, bucket_bytes, codec, rnd):
    """The double-buffered issue order (DESIGN.md §11) never changes the
    decode: overlap=True equals overlap=False bit-for-bit — votes AND
    server state — for every codec, voter count, dim and bucket cut."""
    from repro.core import vote_api as va
    signs = np.array([[rnd.choice([-1, 0, 1]) for _ in range(n)]
                      for _ in range(m)], np.int8)
    plan = vp.build_plan({"x": (n,)}, bucket_bytes=bucket_bytes,
                         strategy=VoteStrategy.ALLGATHER_1BIT,
                         default_codec=codec)
    state = codecs.get_codec(codec).init_server_state(m)

    def run(ov):
        return va.VirtualBackend().execute(va.VoteRequest(
            payload=jnp.asarray(signs), form="stacked", plan=plan,
            server_state=state or None, overlap=ov))

    sync_o, ovl_o = run(False), run(True)
    np.testing.assert_array_equal(np.asarray(sync_o.votes),
                                  np.asarray(ovl_o.votes))
    for k in sync_o.server_state:
        np.testing.assert_array_equal(np.asarray(sync_o.server_state[k]),
                                      np.asarray(ovl_o.server_state[k]))


@given(st.integers(2, 10), st.integers(2, 80), st.integers(1, 10),
       st.randoms())
@settings(max_examples=50, deadline=None)
def test_weighted_ema_invariant_to_bucket_cut(m, n, bucket_bytes, rnd):
    signs = np.array([[rnd.choice([-1, 1]) for _ in range(n)]
                      for _ in range(m)], np.int8)
    ema = np.asarray([rnd.uniform(0.05, 0.7) for _ in range(m)],
                     np.float32)
    vote_ref, ema_ref = wv.decode_stacked(jnp.asarray(signs),
                                          jnp.asarray(ema))
    plan = vp.build_plan({"x": (n,)}, bucket_bytes=bucket_bytes,
                         strategy=VoteStrategy.ALLGATHER_1BIT,
                         default_codec="weighted_vote")
    vote, state = virtual_plan_vote(jnp.asarray(signs), plan,
                                    {"flip_ema": jnp.asarray(ema)})
    np.testing.assert_array_equal(np.asarray(vote), np.asarray(vote_ref))
    np.testing.assert_allclose(np.asarray(state["flip_ema"]),
                               np.asarray(ema_ref), rtol=1e-6)
