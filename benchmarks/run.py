"""Benchmark driver — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows:
  fig1  toy-quadratic convergence incl. adversaries   (bench_convergence)
  fig2  gradient-noise unimodality/symmetry on an LM  (bench_noise)
  fig3  SNR vs the critical line                      (bench_noise)
  fig4  Byzantine training robustness sweep           (bench_robustness)
  fig5  communication volume/time vs dense all-reduce (bench_comm)
  fig6  end-to-end step-time speedup model            (bench_speedup)
  roofline  per-cell terms from the dry-run artifacts (roofline)

Run:  PYTHONPATH=src python -m benchmarks.run [--only fig1,fig5]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys (fig1..fig6,roofline)")
    args = ap.parse_args()

    from benchmarks import (bench_comm, bench_convergence, bench_noise,
                            bench_robustness, bench_speedup, roofline)
    suites = {
        "fig1": bench_convergence, "fig2": bench_noise, "fig3": bench_noise,
        "fig4": bench_robustness, "fig5": bench_comm, "fig6": bench_speedup,
        "roofline": roofline,
    }
    only = set(args.only.split(",")) if args.only else None
    seen_mods = set()
    print("name,value,derived")
    failures = 0
    for key, mod in suites.items():
        if only and key not in only:
            continue
        if id(mod) in seen_mods:
            continue
        seen_mods.add(id(mod))
        try:
            for name, value, derived in mod.rows():
                print(f"{name},{value:.6g},{derived}", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{key}/ERROR,-1,see stderr", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
