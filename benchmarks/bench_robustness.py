"""Fig. 4 analog: training a real (reduced) LM with majority vote while a
fraction of the vote replicas behaves adversarially (sign inversion — the
strongest non-cooperating adversary). Runs the actual distributed train
step on 8 fake devices in a subprocess (the bench process keeps 1 device).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    from repro.configs.base import (ByzantineConfig, OptimizerConfig,
                                    TrainConfig, get_config, reduced_config)
    from repro.models import model as M
    from repro.train import train_step as TS

    mesh = compat.make_mesh((8, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    out = {}
    for n_adv in [0, 1, 2, 3]:
        cfg = reduced_config(get_config("glm4-9b"), num_layers=2)
        tcfg = TrainConfig(
            global_batch=8, seq_len=32,
            optimizer=OptimizerConfig(kind="signum_vote", learning_rate=3e-3),
            byzantine=ByzantineConfig(mode="sign_flip",
                                      num_adversaries=n_adv))
        art = TS.make_train_step(cfg, tcfg, mesh=mesh)
        params, opt = TS.materialize_state(cfg, tcfg, art,
                                           jax.random.PRNGKey(0), mesh)
        batch = M.make_batch(cfg, 8, 32, jax.random.PRNGKey(1))
        batch = jax.tree.map(lambda a: jax.device_put(
            np.asarray(a), NamedSharding(mesh, P("data"))), batch)
        losses = []
        for i in range(40):
            params, opt, met = art.step_fn(params, opt, batch, jnp.int32(i))
            losses.append(float(met["loss"]))
        out[str(n_adv)] = [losses[0], losses[-1]]
    print("RESULT " + json.dumps(out))
""")


def rows():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        return [("fig4/error", -1.0, proc.stderr[-200:])]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    res = json.loads(line[len("RESULT "):])
    out = []
    for n_adv, (first, last) in sorted(res.items()):
        pct = int(n_adv) / 8 * 100
        out.append((f"fig4/loss_drop_{pct:.0f}pct_adversarial",
                    first - last,
                    f"loss {first:.2f}->{last:.2f} (8 voters, "
                    f"{n_adv} sign-flippers)"))
    return out
