"""Pallas TPU kernels for the paper's compute hot spots (fused
sign+bitpack+popcount majority, bit-pack/unpack, popcount vote, fused
SIGNUM update) with jnp oracles in ref.py.

``fused_vote.fused_majority_2d`` is the VoteEngine's one-pass local tally;
``bitpack``/``vote`` remain as the staged pair for the paths where pack and
tally are separated by a collective (the 1-bit wire protocol)."""
from repro.kernels import ops, ref  # noqa: F401
