"""Fig. 1 analog: signSGD / SIGNUM / majority vote on the paper's toy
quadratic (1000-dim, N(0,1) per-coordinate gradient noise), including the
adversarial variants (27 workers, sign-flippers)."""
from __future__ import annotations

import numpy as np

from repro.core import theory


def run(dim=1000, noise=1.0, steps=300, m_workers=27, lr=2e-2, alpha=0.0,
        momentum=0.0, seed=0):
    f, grad_oracle, x0 = theory.quadratic_problem(dim, noise, seed)
    rng = np.random.default_rng(seed + 1)
    x = x0.copy()
    n_adv = int(round(alpha * m_workers))
    mom = np.zeros((m_workers, dim))
    traj = [f(x)]
    for _ in range(steps):
        votes = np.zeros(dim)
        for m in range(m_workers):
            g = grad_oracle(x, rng)
            mom[m] = momentum * mom[m] + (1 - momentum) * g
            s = np.sign(mom[m])
            votes += (-s if m < n_adv else s)
        x = x - lr * np.sign(votes)
        traj.append(f(x))
    return np.asarray(traj)


def run_sgd(dim=1000, noise=1.0, steps=300, m_workers=27, lr=2e-2, seed=0):
    f, grad_oracle, x0 = theory.quadratic_problem(dim, noise, seed)
    rng = np.random.default_rng(seed + 1)
    x = x0.copy()
    traj = [f(x)]
    for _ in range(steps):
        g = np.mean([grad_oracle(x, rng) for _ in range(m_workers)], axis=0)
        x = x - lr * g
        traj.append(f(x))
    return np.asarray(traj)


def rows():
    out = []
    sgd = run_sgd()
    out.append(("fig1/sgd_27workers_final_f", sgd[-1],
                f"f0={sgd[0]:.1f}"))
    for name, kw in [
        ("signsgd_1worker", dict(m_workers=1)),
        ("majority_27workers", dict(m_workers=27)),
        ("signum_27workers_beta0.9", dict(m_workers=27, momentum=0.9)),
        ("majority_27w_33pct_adversarial", dict(m_workers=27, alpha=1 / 3)),
        ("majority_27w_44pct_adversarial", dict(m_workers=27, alpha=12 / 27)),
    ]:
        t = run(**kw)
        out.append((f"fig1/{name}_final_f", t[-1],
                    f"f0={t[0]:.1f};reduction={t[0] / max(t[-1], 1e-12):.1f}x"))
    return out


def main() -> None:
    from benchmarks.common import rows_main
    rows_main("convergence", __doc__, rows)


if __name__ == "__main__":
    main()
