"""Federated-scale voter populations through the streamed engine (§12).

The population axis (DESIGN.md §12) decouples the voter count M from
host memory and device count: a ``"streamed"`` VoteRequest runs the
stacked exchange in voter-chunks, so an M in the 10^4–10^5 range votes
with peak sign-buffer memory O(chunk_size x dim) instead of O(M x dim).
This benchmark is the CI face of that claim:

* ``--smoke`` (scripts/ci.sh federated-smoke stage, <10 s) — federated
  ScenarioRunner drills (client sampling, churn, dataset-weighted votes,
  the weighted_vote codec over a churning population), the
  streamed==dense bit-identity gate at every probed M <= 1024, the
  chunk-size digest-invariance gate, and the M=100,000 scale row whose
  value IS ``population.LAST_STATS["peak_rows"]`` — asserted bounded by
  the chunk size, never by M. Writes the machine-readable baseline
  ``BENCH_federated.json`` (gated by scripts/perf_gate.py).
* ``rows()`` (the ``benchmarks.run`` driver path) — the same lane.

Usage:
    python -m benchmarks.bench_federated --smoke
"""
from __future__ import annotations

import argparse
import json

_JSON_DEFAULT = "BENCH_federated.json"

#: the streamed==dense probe size (the §12 acceptance bar is
#: bit-identity at every M <= 1024 — the full ladder below the bar
#: is walked by tests/test_population.py; this lane probes the bar
#: itself, with a ragged final chunk)
_EQ_SIZES = (1024,)


def _drill_rows():
    """Federated ScenarioRunner drills: one per population axis."""
    from repro.configs.base import VoteStrategy
    from repro.sim import (AdversarySpec, ChurnEvent, PopulationSpec,
                           ScenarioRunner, ScenarioSpec)

    # ONE tiny chunk size shared by every drill: chunk=6 divides almost
    # every round's sampled voter count, maximizes the chunk-schedule
    # coverage (many partial-tally accumulations per vote) AND keys the
    # jitted chunk stages to one or two compiled shapes across all three
    # drills — which is what keeps this lane under 10 s (the ragged-tail
    # shapes are drilled further by tests/test_population*.py)
    cells = [
        ("uniform/psum_int8", ScenarioSpec(
            "fed-smoke/uniform", n_steps=3, dim=64, momentum=0.0,
            strategy=VoteStrategy.PSUM_INT8,
            adversary=AdversarySpec("sign_flip", 0.2),
            population=PopulationSpec(n_clients=200, sample_fraction=0.12,
                                      chunk_size=6))),
        ("dataset/allgather_1bit", ScenarioSpec(
            "fed-smoke/dataset", n_steps=3, dim=64, momentum=0.0,
            strategy=VoteStrategy.ALLGATHER_1BIT,
            adversary=AdversarySpec("colluding", 0.3),
            population=PopulationSpec(n_clients=120, sample_fraction=0.3,
                                      weighting="dataset", max_data=50,
                                      chunk_size=6))),
        ("weighted_vote/churn", ScenarioSpec(
            "fed-smoke/weighted", n_steps=5, dim=64, momentum=0.0,
            strategy=VoteStrategy.ALLGATHER_1BIT, codec="weighted_vote",
            adversary=AdversarySpec("blind", 0.25, flip_prob=0.8),
            population=PopulationSpec(
                n_clients=90, sample_fraction=0.4, weighting="dataset",
                churn=(ChurnEvent(2, leave=30, note="dropout"),
                       ChurnEvent(4, join=15, note="rejoin")),
                chunk_size=6))),
    ]
    out = []
    import dataclasses
    for i, (label, spec) in enumerate(cells):
        tr = ScenarioRunner(spec).run()
        s = tr.summary()
        note = ""
        if i == 0:
            # the chunk-size invariance gate: a one-chunk (= dense-order)
            # chunking must reproduce the digest bit for bit (every
            # drill is re-drilled this way in tests/test_population.py;
            # one representative here keeps the lane under 10 s)
            respec = dataclasses.replace(
                spec, population=dataclasses.replace(
                    spec.population, chunk_size=spec.population.n_clients))
            tr2 = ScenarioRunner(respec).run()
            # RuntimeError, not assert: the acceptance bar must survive
            # `python -O` (the defect class pack_signs once shed)
            if tr2.digest != tr.digest:
                raise RuntimeError(
                    f"{spec.name}: chunk size changed the drill digest "
                    f"({tr.digest[:12]} != {tr2.digest[:12]})")
            note = " chunk-invariant"
        out.append((
            f"federated-smoke/{label}", s["loss_drop"],
            f"pop={spec.population.n_clients} "
            f"sample={spec.population.sample_fraction:g} "
            f"flip={s['mean_flip_fraction']:.3f}"
            f"{note} {tr.digest[:12]}"))
    return out


def _equivalence_row():
    """streamed == dense bit-identity at every probed M <= 1024: the
    same voters, ids and dataset weights through (a) the dense stacked
    annotated path and (b) the streamed engine at a ragged chunk size —
    votes AND server state compared exactly."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import ByzantineConfig, VoteStrategy
    from repro.core import codecs as codecs_mod
    from repro.core import vote_api as va

    # 43 leaves a ragged 35-row tail at 1024, so even and ragged
    # chunk boundaries are both exercised
    be = va.VirtualBackend(chunk_size=43)
    checked = 0
    for m in _EQ_SIZES:
        n = 48
        key = jax.random.PRNGKey(m)
        vals = jax.random.normal(key, (m, n), jnp.float32)
        rng = np.random.default_rng(m)
        ids = np.sort(rng.choice(4 * m, size=m, replace=False)
                      ).astype(np.int32)
        w = rng.integers(1, 64, size=m).astype(np.int32)
        # a FIXED adversary count: the config is a jit static arg of the
        # chunk stage, so sharing it across probe sizes compiles each
        # chunk shape once instead of once per M
        byz = ByzantineConfig(mode="colluding", num_adversaries=5, seed=5)
        # two transport-extreme cells: the integer-count wire and the
        # reliability-weighted gathered wire (the full codec x strategy
        # matrix is walked by tests/test_population.py)
        for strategy, codec in [
                (VoteStrategy.PSUM_INT8, "sign1bit"),
                (VoteStrategy.ALLGATHER_1BIT, "weighted_vote")]:
            state = (codecs_mod.get_codec(codec).init_server_state(4 * m)
                     if codec == "weighted_vote" else None)
            dense = be.execute(va.VoteRequest(
                payload=vals, form="stacked", strategy=strategy,
                codec=codec, voter_ids=ids, weights=w,
                failures=va.FailureSpec(byz=byz), step=jnp.int32(3),
                salt=11, server_state=state))
            stream = va.PopulationStream(
                n_voters=m, n_coords=n, ids=ids, weights=w,
                values=lambda want, _v=vals, _i=jnp.asarray(ids):
                    _v[jnp.searchsorted(_i, want)])
            streamed = be.execute(va.VoteRequest(
                payload=stream, form="streamed", strategy=strategy,
                codec=codec, failures=va.FailureSpec(byz=byz),
                step=jnp.int32(3), salt=11, server_state=state))
            if not np.array_equal(np.asarray(dense.votes),
                                  np.asarray(streamed.votes)):
                raise RuntimeError(
                    f"streamed != dense votes at M={m} "
                    f"{codec}/{strategy.value}")
            for k2 in (dense.server_state or {}):
                if not np.array_equal(
                        np.asarray(dense.server_state[k2]),
                        np.asarray(streamed.server_state[k2])):
                    raise RuntimeError(
                        f"streamed != dense state[{k2!r}] at M={m} "
                        f"{codec}/{strategy.value}")
            checked += 1
    return ("federated-smoke/streamed_eq_dense", 1.0,
            f"bit-identical votes+state over {checked} cells at "
            f"M={list(_EQ_SIZES)} (sampled ids, dataset weights, "
            "colluding byz)")


def _scale_row():
    """The §12 acceptance row: a 100,000-client population, 10% client
    sampling, one churn event — run on this single host, with peak
    materialized sign rows read from ``population.LAST_STATS`` and
    asserted bounded by the chunk size, not by M."""
    from repro.configs.base import VoteStrategy
    from repro.core import population
    from repro.sim import (AdversarySpec, ChurnEvent, PopulationSpec,
                           ScenarioRunner, ScenarioSpec)

    chunk = 2000
    # honest population: the memory bound is an engine property, and
    # skipping the adversary also skips the oracle replay — the lane's
    # adversarial coverage lives in the drills above. The churn sizes
    # keep both rounds' sampled cohorts (10 000 and 12 000) exact
    # multiples of the chunk, so the big shapes compile exactly once
    spec = ScenarioSpec(
        "fed-smoke/scale-100k", n_steps=2, dim=64, momentum=0.0,
        strategy=VoteStrategy.PSUM_INT8,
        population=PopulationSpec(
            n_clients=100_000, sample_fraction=0.1,
            churn=(ChurnEvent(1, join=25_000, leave=5_000,
                              note="scale churn"),),
            chunk_size=chunk))
    tr = ScenarioRunner(spec).run()
    stats = dict(population.LAST_STATS)
    if stats["peak_rows"] > chunk:
        raise RuntimeError(
            f"peak materialized rows {stats['peak_rows']} exceed "
            f"chunk_size={chunk}: the streamed engine leaked an O(M) "
            "buffer")
    if stats["n_voters"] < 10_000:
        raise RuntimeError(
            f"scale drill sampled only {stats['n_voters']} voters; "
            "expected ~10% of a 100k population")
    return ("federated-smoke/scale_100k_peak_rows",
            float(stats["peak_rows"]),
            f"M=100000 sample=0.1 churn@1 -> {stats['n_voters']} voters "
            f"in {stats['n_chunks']} chunks, peak {stats['peak_rows']} "
            f"rows <= chunk {chunk}; final pop "
            f"{tr.steps[-1].n_population}")


def smoke_rows():
    return _drill_rows() + [_equivalence_row(), _scale_row()]


#: the benchmarks.run driver path — the smoke lane IS the federated
#: benchmark (the population engine is host-side by construction; there
#: is no separate subprocess sweep to run)
rows = smoke_rows


def emit_json(rs, path: str) -> None:
    """Machine-readable baseline, same ``{"rows": [...]}`` schema as
    ``benchmarks.run --emit-json`` (gated by scripts/perf_gate.py);
    delegates to :func:`repro.obs.emit_bench_json` (one shared writer)."""
    from repro.obs import emit_bench_json
    emit_bench_json(rs, path)


def main() -> None:
    from repro.obs import recorder as obs
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="federated drill sweep + streamed==dense and "
                         "memory-bound gates (CI lane, <10 s)")
    ap.add_argument("--emit-json", dest="json_out", nargs="?",
                    const=_JSON_DEFAULT, default=None,
                    help=f"write rows as JSON (default {_JSON_DEFAULT})")
    obs.add_trace_arg(ap)
    args = ap.parse_args()

    rec = obs.activate_trace(args)
    rs = smoke_rows()
    if args.smoke and args.json_out is None:   # CI smoke seeds the JSON
        args.json_out = _JSON_DEFAULT
    print("name,value,derived")
    for name, value, derived in rs:
        print(f"{name},{value:.6g},{derived}", flush=True)
    if args.json_out:
        emit_json(rs, args.json_out)
        print(f"# wrote {args.json_out}", flush=True)
    obs.finish_trace(rec)


if __name__ == "__main__":
    main()
