"""Continuous-batching serve engine (DESIGN.md §14).

A fixed pool of decode *slots* under ONE jitted decode step: finished
sequences retire (EOS / generation budget / cache exhaustion) and queued
prompts are admitted mid-flight, yet the compiled program never changes
— every array in the engine state has a static shape keyed only to
``(n_slots, max_len, prompt_pad)``, and per-slot scheduling is carried
by *values* (position / length / budget vectors and an active mask),
never by shapes. The obs compile counters prove it: a whole serve run —
admissions, retirements, a hot parameter swap — performs exactly one
``serve.decode.compiles`` increment (`benchmarks/bench_serving.py`
gates this row).

Slot recycling is safe without clearing attention caches because decode
attends under a ``kv_pos <= pos`` mask and writes position ``pos``
before the mask ever permits reading it — a recycled slot overwrites
each stale KV row strictly before its new occupant can attend to it.
Recurrent leaves (``ssm``/``conv``) carry no position mask, so
:func:`_serve_fns` zeroes exactly those lanes at admission.

Two admission paths share one sampling rule (so they are bit-identical
and the tests cross-check them):

* ``inline`` — prompt tokens are streamed through the decode step one
  per tick; universal (works for SSM / hybrid state too).
* ``prefill`` — the prompt runs through ``model.prefill`` at a padded
  bucket length and the produced cache is written into the slot with a
  slot-indexed ``dynamic_update_slice``; right-padding is harmless
  because causal attention never reads past ``plen - 1`` for the first
  token, and decode overwrites each padded KV row before attending to
  it. Transformer-family only (``model.prefill`` returns unpopulated
  state for recurrent archs).

Hot checkpoint swap: :meth:`ServeEngine.swap` replaces the parameter
tree *between* decode ticks. Slot state (cache included) is donated
through every step, the decode jit is keyed on shapes only, and
requests never reference parameters outside the step — so a swap drops
nothing in flight and triggers no recompile; step records carry the
``param_version`` tag so traces show which params produced which
tokens.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchFamily, ModelConfig
from repro.models import model as M
from repro.obs import recorder as obs
from repro.serve.traffic import Request

#: cache leaves holding recurrent state — no position mask protects
#: them, so admission must zero the slot's lane (attention leaves are
#: protected by the write-before-read ``kv_pos <= pos`` discipline)
_RECURRENT_LEAVES = ("ssm", "conv")

#: families whose ``model.prefill`` returns a populated cache
_PREFILL_FAMILIES = (ArchFamily.DENSE, ArchFamily.MOE, ArchFamily.VLM)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static engine shape + policy. Every field is part of the jit
    key (via the lru-cached :func:`_serve_fns` builder), so two engines
    with equal configs share one compiled decode step."""

    n_slots: int = 4
    max_len: int = 64              # KV/position capacity per slot
    prompt_pad: int = 32           # prompt buffer width (inline path)
    temperature: float = 0.0       # <=0 -> greedy argmax
    seed: int = 0                  # sampling PRNG root (keyed per req/pos)
    eos_id: Optional[int] = None   # None -> retire on budget only
    admit: str = "inline"          # "inline" | "prefill"
    scheduler: str = "continuous"  # "continuous" | "static"
    prefill_buckets: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
        if not (1 <= self.prompt_pad <= self.max_len):
            raise ValueError(
                f"need 1 <= prompt_pad <= max_len, got prompt_pad="
                f"{self.prompt_pad}, max_len={self.max_len}")
        if self.admit not in ("inline", "prefill"):
            raise ValueError(f"admit must be 'inline' or 'prefill', "
                             f"got {self.admit!r}")
        if self.scheduler not in ("continuous", "static"):
            raise ValueError(f"scheduler must be 'continuous' or "
                             f"'static', got {self.scheduler!r}")
        if self.admit == "prefill":
            b = self.prefill_buckets
            if not b or tuple(sorted(b)) != tuple(b) or b[0] < 1 \
                    or b[-1] > self.max_len:
                raise ValueError(
                    "prefill admission needs ascending prefill_buckets "
                    f"within [1, max_len], got {b}")


@dataclasses.dataclass
class RequestRecord:
    """Host-side lifecycle of one request (ticks are engine-loop
    rounds; ``arrival`` keeps the generator's fractional tick)."""

    req_id: int
    arrival: float
    admit_tick: int = -1
    first_token_tick: int = -1
    finish_tick: int = -1
    slot: int = -1
    param_version_admit: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def finished(self) -> bool:
        return self.finish_tick >= 0

    @property
    def ttft(self) -> float:
        return self.first_token_tick - self.arrival

    @property
    def latency(self) -> float:
        return self.finish_tick - self.arrival


@dataclasses.dataclass
class ServeReport:
    """One run's outcome. Everything except occupancy is derived from
    the deterministic tick schedule, so equal seeds give equal reports
    bit for bit (the perf gate's exact rows rely on this)."""

    ticks: int
    n_requests: int
    completed: int
    dropped: int
    total_tokens: int
    goodput_tokens_per_tick: float
    ttft_p50: float
    latency_p50: float
    latency_p95: float
    latency_p99: float
    tpot_mean: float
    occupancy_mean: float
    swaps: int
    records: Dict[int, RequestRecord]

    def tokens_by_request(self) -> Dict[int, Tuple[int, ...]]:
        """req_id -> sampled token ids (the bit-identity surface the
        traced-vs-untraced and swap-oracle gates compare)."""
        return {rid: tuple(r.tokens) for rid, r in
                sorted(self.records.items())}


def _percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile — integer index into the sorted sample,
    no interpolation, so the value is exactly reproducible."""
    if not xs:
        return 0.0
    s = sorted(xs)
    i = min(len(s) - 1, max(0, math.ceil(q / 100.0 * len(s)) - 1))
    return float(s[i])


# ---------------------------------------------------------------------------
# the compiled kernel set (shared across engines via lru_cache)
# ---------------------------------------------------------------------------


class _ServeFns:
    """The jitted callables for one (cfg, ServeConfig) key: ``step``,
    ``admit`` and per-bucket ``admit_prefill_for(Lb)``. Built once per
    key; every :class:`ServeEngine` with equal configs reuses the same
    instance (hence the same XLA executables — the one-compile
    acceptance row holds across engine instances, not just ticks)."""

    def __init__(self, cfg: ModelConfig, sc: ServeConfig):
        self.cfg, self.sc = cfg, sc
        self._prefill: Dict[int, Callable] = {}
        n_slots, max_len = sc.n_slots, sc.max_len
        prompt_pad = sc.prompt_pad

        def _slot_decode(params, tok, cache_b, pos):
            # one lane: re-add the batch=1 axis the model API expects
            # (cache leaves are (L, B, ...) — B sits at axis 1)
            cache1 = {k: v[:, None] for k, v in cache_b.items()}
            logits, cache1 = M.decode_step(
                cfg, params, tok[None, None], cache1, pos)
            return logits[0], {k: v[:, 0] for k, v in cache1.items()}

        vdecode = jax.vmap(_slot_decode, in_axes=(None, 0, 1, 0),
                           out_axes=(0, 1))

        def _sample_one(logits, req, pos):
            """One slot's next token. The key depends only on
            (seed, req_id, position), so inline and prefill admission
            sample identically and replays are order-independent."""
            if sc.temperature <= 0.0:
                return jnp.argmax(logits).astype(jnp.int32)
            k = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(sc.seed), req), pos)
            return jax.random.categorical(
                k, logits / sc.temperature).astype(jnp.int32)

        @functools.partial(jax.jit, donate_argnums=(1,))
        def step(params, state):
            # trace-time increment == compiles (recorder.py contract)
            obs.COUNTERS.inc("serve.decode.compiles")
            pos, active = state["pos"], state["active"]
            logits, cache = vdecode(params, state["tokens"],
                                    state["cache"], pos)
            nxt = jax.vmap(_sample_one)(logits, state["req"], pos)
            in_prompt = (pos + 1) < state["plen"]
            emit = active & ~in_prompt
            gen = state["gen"] + emit.astype(jnp.int32)
            stop = gen >= state["max_gen"]
            if sc.eos_id is not None:
                stop = stop | (nxt == sc.eos_id)
            done = active & ((emit & stop) | (pos + 1 >= max_len))
            nactive = active & ~done
            idx = jnp.minimum(pos + 1, prompt_pad - 1)
            prompt_next = jnp.take_along_axis(
                state["prompts"], idx[:, None], axis=1)[:, 0]
            fed = jnp.where(in_prompt, prompt_next, nxt)
            out = {"tok": nxt, "emit": emit, "done": done}
            return {
                "cache": cache,
                "tokens": jnp.where(active, fed, state["tokens"]),
                "pos": jnp.where(nactive, pos + 1, pos),
                "plen": state["plen"],
                "gen": gen,
                "max_gen": state["max_gen"],
                "req": state["req"],
                "active": nactive,
                "prompts": state["prompts"],
            }, out

        @functools.partial(jax.jit, donate_argnums=(0,))
        def admit(state, slot, prompt, plen, max_gen, req):
            obs.COUNTERS.inc("serve.admit.compiles")
            cache = dict(state["cache"])
            for k in _RECURRENT_LEAVES:
                if k in cache:
                    cache[k] = cache[k].at[:, slot].set(0)
            return {
                "cache": cache,
                "tokens": state["tokens"].at[slot].set(prompt[0]),
                "pos": state["pos"].at[slot].set(0),
                "plen": state["plen"].at[slot].set(plen),
                "gen": state["gen"].at[slot].set(0),
                "max_gen": state["max_gen"].at[slot].set(max_gen),
                "req": state["req"].at[slot].set(req),
                "active": state["active"].at[slot].set(True),
                "prompts": state["prompts"].at[slot].set(prompt),
            }

        self.step = step
        self.admit = admit
        self._sample_one = _sample_one
        self._max_len = max_len

    def admit_prefill_for(self, lb: int) -> Callable:
        """The jitted prefill-admission for bucket length ``lb`` (one
        compile per bucket, cached for the life of the fns object)."""
        fn = self._prefill.get(lb)
        if fn is not None:
            return fn
        cfg, sc, max_len = self.cfg, self.sc, self._max_len
        sample_one = self._sample_one

        @functools.partial(jax.jit, donate_argnums=(1,))
        def admitp(params, state, slot, prompt, plen, max_gen, req):
            obs.COUNTERS.inc("serve.prefill.compiles")
            logits, pcache = M.prefill(cfg, params,
                                       {"tokens": prompt[:lb][None]})
            cache = {}
            for k, v in state["cache"].items():
                src = pcache[k].astype(v.dtype)
                starts = (0, slot) + (0,) * (v.ndim - 2)
                cache[k] = jax.lax.dynamic_update_slice(v, src, starts)
            lg = jax.lax.dynamic_index_in_dim(logits[0], plen - 1,
                                              axis=0, keepdims=False)
            first = sample_one(lg, req, plen - 1)
            stop = max_gen <= 1
            if sc.eos_id is not None:
                stop = stop | (first == sc.eos_id)
            done0 = stop | (plen >= max_len)
            return {
                "cache": cache,
                "tokens": state["tokens"].at[slot].set(first),
                "pos": state["pos"].at[slot].set(plen),
                "plen": state["plen"].at[slot].set(plen),
                "gen": state["gen"].at[slot].set(1),
                "max_gen": state["max_gen"].at[slot].set(max_gen),
                "req": state["req"].at[slot].set(req),
                "active": state["active"].at[slot].set(~done0),
                "prompts": state["prompts"].at[slot].set(prompt),
            }, {"tok": first, "done": done0}

        self._prefill[lb] = admitp
        return admitp


@functools.lru_cache(maxsize=None)
def _serve_fns_cached(cfg: ModelConfig, n_slots: int, max_len: int,
                      prompt_pad: int, temperature: float, seed: int,
                      eos_id: Optional[int]) -> _ServeFns:
    return _ServeFns(cfg, ServeConfig(
        n_slots=n_slots, max_len=max_len, prompt_pad=prompt_pad,
        temperature=temperature, seed=seed, eos_id=eos_id))


def _serve_fns(cfg: ModelConfig, sc: ServeConfig) -> _ServeFns:
    """One kernel set per (model config, engine *shape+sampling*) key.

    ``admit`` and ``scheduler`` are host-side policy — they pick which
    compiled callables run, never what they compute — so they are
    deliberately NOT part of the key: the static-batching baseline and
    a prefill-admission engine reuse the continuous engine's decode
    executable (the bench's one-compile row counts across all lanes).
    """
    return _serve_fns_cached(cfg, sc.n_slots, sc.max_len, sc.prompt_pad,
                             sc.temperature, sc.seed, sc.eos_id)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """The host-side scheduler over the compiled kernel set: admits
    arrived requests into free slots, runs one decode tick for the
    whole pool, reads back (token, emit, done) flags, retires finished
    slots, and swaps parameters between ticks."""

    def __init__(self, cfg: ModelConfig, params: Any,
                 serve_cfg: ServeConfig = ServeConfig(), *,
                 param_version: int = 0, watcher: Any = None):
        if cfg.family == ArchFamily.AUDIO:
            raise ValueError(
                "ServeEngine serves token prompts; AUDIO archs need "
                "encoder features per request (use launch/serve.py)")
        if serve_cfg.admit == "prefill" \
                and cfg.family not in _PREFILL_FAMILIES:
            raise ValueError(
                f"prefill admission needs a populated model.prefill "
                f"cache; {cfg.family.name} is recurrent — use "
                f"admit='inline'")
        self.cfg = cfg
        self.sc = serve_cfg
        self.params = params
        self.param_version = int(param_version)
        self.watcher = watcher
        self.fns = _serve_fns(cfg, serve_cfg)
        self._state = self._init_state()
        self._slot_req: List[Optional[int]] = [None] * serve_cfg.n_slots

    def _init_state(self) -> Dict[str, jax.Array]:
        sc = self.sc
        n = sc.n_slots
        return {
            "cache": M.init_cache(self.cfg, n, sc.max_len),
            "tokens": jnp.zeros((n,), jnp.int32),
            "pos": jnp.zeros((n,), jnp.int32),
            "plen": jnp.ones((n,), jnp.int32),
            "gen": jnp.zeros((n,), jnp.int32),
            "max_gen": jnp.ones((n,), jnp.int32),
            "req": jnp.zeros((n,), jnp.int32),
            "active": jnp.zeros((n,), bool),
            "prompts": jnp.zeros((n, sc.prompt_pad), jnp.int32),
        }

    # -- parameter swap --

    def swap(self, params: Any, version: int) -> None:
        """Install a new parameter tree between ticks. Nothing in slot
        state references the old params, so in-flight requests simply
        continue under the new ones at their next decode tick."""
        rec = obs.get_recorder()
        with rec.span("serve.swap", version=int(version)):
            self.params = jax.tree.map(jnp.asarray, params)
        self.param_version = int(version)
        obs.COUNTERS.inc("serve.swaps")

    def _poll_watcher(self) -> None:
        upd = self.watcher.poll()
        if upd is not None and upd.version != self.param_version:
            self.swap(upd.params, upd.version)

    # -- admission --

    def _validate(self, r: Request) -> None:
        sc = self.sc
        cap = (sc.prefill_buckets[-1] if sc.admit == "prefill"
               else sc.prompt_pad)
        if not (1 <= r.prompt_len <= cap):
            raise ValueError(
                f"request {r.req_id}: prompt length {r.prompt_len} "
                f"outside [1, {cap}]")
        if r.prompt_len >= sc.max_len:
            raise ValueError(
                f"request {r.req_id}: prompt length {r.prompt_len} "
                f"leaves no room to generate within max_len="
                f"{sc.max_len}")

    def _admit_one(self, r: Request, slot: int, t: int,
                   records: Dict[int, RequestRecord]) -> int:
        """Admit one request into a free slot; returns 1 if it finished
        at admission (prefill hit EOS/budget on the first token)."""
        rec = obs.get_recorder()
        sc = self.sc
        plen = r.prompt_len
        eff_gen = min(r.max_gen, sc.max_len - plen)
        prompt = np.zeros((sc.prompt_pad,), np.int32)
        prompt[:plen] = r.prompt
        row = RequestRecord(req_id=r.req_id, arrival=r.arrival,
                            admit_tick=t, slot=slot,
                            param_version_admit=self.param_version)
        records[r.req_id] = row
        obs.COUNTERS.inc("serve.admissions")
        if sc.admit == "prefill":
            lb = next(b for b in sc.prefill_buckets if b >= plen)
            with rec.span("serve.prefill", req=r.req_id, bucket=lb):
                self._state, out = self.fns.admit_prefill_for(lb)(
                    self.params, self._state, slot, prompt, plen,
                    eff_gen, r.req_id)
                out = jax.device_get(out)
            row.tokens.append(int(out["tok"]))
            row.first_token_tick = t
            obs.COUNTERS.inc("serve.tokens")
            if bool(out["done"]):
                row.finish_tick = t
                obs.COUNTERS.inc("serve.retired")
                return 1
        else:
            with rec.span("serve.admit", req=r.req_id):
                self._state = self.fns.admit(
                    self._state, slot, prompt, plen, eff_gen, r.req_id)
        self._slot_req[slot] = r.req_id
        return 0

    def _admit_arrived(self, queue: deque, t: int,
                       records: Dict[int, RequestRecord]) -> int:
        """Fill free slots from the arrived queue; returns the number
        of requests that finished at admission. The static scheduler
        only admits into an EMPTY pool (the whole batch completes
        together — the baseline continuous batching beats)."""
        free = [i for i, s in enumerate(self._slot_req) if s is None]
        if self.sc.scheduler == "static" \
                and len(free) < self.sc.n_slots:
            return 0
        finished = 0
        for slot in free:
            if not queue or queue[0].arrival > t:
                break
            finished += self._admit_one(queue.popleft(), slot, t,
                                        records)
        return finished

    # -- the run loop --

    def run(self, requests: Sequence[Request], *,
            max_ticks: int = 100_000,
            on_tick: Optional[Callable[["ServeEngine", int], None]] = None
            ) -> ServeReport:
        """Serve ``requests`` to completion (or ``max_ticks``). One
        tick = optional watcher poll + admissions + one pooled decode
        step + retirement readback. Deterministic: equal (requests,
        config, params) give bit-identical reports, traced or not."""
        for r in requests:
            self._validate(r)
        rec = obs.get_recorder()
        queue = deque(sorted(requests,
                             key=lambda r: (r.arrival, r.req_id)))
        records: Dict[int, RequestRecord] = {}
        remaining = len(queue)
        swaps0 = obs.COUNTERS.get("serve.swaps")
        occupancy_ticks = 0
        t = 0
        while remaining > 0 and t < max_ticks:
            if on_tick is not None:
                on_tick(self, t)
            if self.watcher is not None:
                self._poll_watcher()
            remaining -= self._admit_arrived(queue, t, records)
            n_active = sum(s is not None for s in self._slot_req)
            emitted = 0
            if n_active:
                with rec.span("serve.decode", tick=t):
                    self._state, out = self.fns.step(self.params,
                                                     self._state)
                    out = jax.device_get(out)
                emitted, retired = self._collect(out, t, records)
                remaining -= retired
            occupancy_ticks += n_active
            obs.COUNTERS.inc("serve.ticks")
            obs.COUNTERS.inc("serve.slot_occupancy_ticks", n_active)
            if rec.enabled:
                rec.step(kind_detail="serve", tick=t, active=n_active,
                         emitted=emitted,
                         param_version=self.param_version)
            t += 1
        # prefill-admitted tokens are counted at admission, not decode
        total_tokens = sum(len(r.tokens) for r in records.values())
        return self._report(records, len(requests), t, total_tokens,
                            occupancy_ticks,
                            obs.COUNTERS.get("serve.swaps") - swaps0)

    def _collect(self, out: Dict[str, np.ndarray], t: int,
                 records: Dict[int, RequestRecord]) -> Tuple[int, int]:
        rec = obs.get_recorder()
        tok, emit, done = out["tok"], out["emit"], out["done"]
        emitted = retired = 0
        for slot, rid in enumerate(self._slot_req):
            if rid is None:
                continue
            row = records[rid]
            if emit[slot]:
                if row.first_token_tick < 0:
                    row.first_token_tick = t
                row.tokens.append(int(tok[slot]))
                emitted += 1
            if done[slot]:
                with rec.span("serve.retire", req=rid, tick=t):
                    row.finish_tick = t
                    self._slot_req[slot] = None
                retired += 1
                obs.COUNTERS.inc("serve.retired")
        obs.COUNTERS.inc("serve.tokens", emitted)
        return emitted, retired

    def _report(self, records, n_requests, ticks, total_tokens,
                occupancy_ticks, swaps) -> ServeReport:
        fin = [r for r in records.values() if r.finished]
        lat = [r.latency for r in fin]
        tpots = [(r.finish_tick - r.first_token_tick)
                 / (len(r.tokens) - 1)
                 for r in fin if len(r.tokens) > 1]
        denom = max(ticks, 1)
        return ServeReport(
            ticks=ticks,
            n_requests=n_requests,
            completed=len(fin),
            dropped=n_requests - len(fin),
            total_tokens=total_tokens,
            goodput_tokens_per_tick=total_tokens / denom,
            ttft_p50=_percentile([r.ttft for r in fin], 50),
            latency_p50=_percentile(lat, 50),
            latency_p95=_percentile(lat, 95),
            latency_p99=_percentile(lat, 99),
            tpot_mean=(sum(tpots) / len(tpots)) if tpots else 0.0,
            occupancy_mean=occupancy_ticks
            / (denom * self.sc.n_slots),
            swaps=swaps,
            records=records,
        )
