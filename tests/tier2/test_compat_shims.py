"""Regression tests for the repro/compat.py emulation layer (satellite:
"so the next JAX bump can't silently break it").

The shims under test: ``shard_map`` kwarg mapping (axis_names/check_vma),
``axis_index`` / ``all_gather`` partial-auto emulations (with the `like=`
anchor), ``pad_trailing`` / ``zeros_like_traced``, ``set_mesh`` /
``get_abstract_mesh`` context views, and ``make_mesh`` axis_types
tolerance — all on the 1-device harness here; the 8-device half lives in
``tests/tier2/scenario_harness.py`` (XLA_FLAGS-forced device count, run
by test_harness8.py).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import AxisType


def _mesh11():
    return compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(AxisType.Auto,) * 2)


def _mesh1():
    return compat.make_mesh((1,), ("data",),
                            axis_types=(AxisType.Auto,))


# ---------------------------------------------------------------------------
# shard_map kwarg surface
# ---------------------------------------------------------------------------


def test_shard_map_kwargs_partial_manual():
    """New-style kwargs (axis_names subset, check_vma) run on any JAX;
    'model' stays auto."""
    mesh = _mesh11()

    def f(x):
        return x * compat.axis_size("data")

    sh = compat.shard_map(f, mesh=mesh, in_specs=(P("data"),),
                          out_specs=P("data"), axis_names={"data"},
                          check_vma=False)
    out = jax.jit(sh)(jnp.ones((1, 4)))
    np.testing.assert_array_equal(np.asarray(out), np.ones((1, 4)))


def test_shard_map_full_manual_defaults():
    """Omitted axis_names means manual over every mesh axis."""
    mesh = _mesh1()

    def f(x):
        return x + compat.axis_size("data")

    sh = compat.shard_map(f, mesh=mesh, in_specs=(P("data"),),
                          out_specs=P("data"))
    out = jax.jit(sh)(jnp.zeros((1, 3)))
    np.testing.assert_array_equal(np.asarray(out), np.ones((1, 3)))


def test_shard_map_mesh_from_context():
    """mesh=None resolves from the set_mesh context (both API families)."""
    mesh = _mesh11()
    with compat.set_mesh(mesh):
        sh = compat.shard_map(lambda x: x * 2.0, in_specs=(P("data"),),
                              out_specs=P("data"), axis_names={"data"},
                              check_vma=False)
        out = jax.jit(sh)(jnp.ones((1, 2)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((1, 2)))


def test_get_abstract_mesh_views():
    mesh = _mesh11()
    with compat.set_mesh(mesh):
        view = compat.get_abstract_mesh()
        assert not view.empty
        assert tuple(view.axis_names) == ("data", "model")
    # outside any context: empty view, never an exception
    outside = compat.get_abstract_mesh()
    assert hasattr(outside, "empty")


# ---------------------------------------------------------------------------
# collectives and index emulation (partial-auto region)
# ---------------------------------------------------------------------------


def test_axis_index_with_anchor_partial_auto():
    mesh = _mesh11()

    def f(x):
        idx = compat.axis_index("data", like=x)
        return x + idx.astype(x.dtype)

    sh = compat.shard_map(f, mesh=mesh, in_specs=(P("data"),),
                          out_specs=P("data"), axis_names={"data"},
                          check_vma=False)
    out = jax.jit(sh)(jnp.zeros((1, 4)))
    np.testing.assert_array_equal(np.asarray(out), np.zeros((1, 4)))


def test_all_gather_tiled_and_stacked_partial_auto():
    mesh = _mesh11()
    x = jnp.arange(6, dtype=jnp.float32).reshape(1, 6)

    def f(xl):
        t = compat.all_gather(xl[0], "data", axis=0, tiled=True)
        s = compat.all_gather(xl[0], "data", tiled=False)
        return t[None], s[None]

    sh = compat.shard_map(f, mesh=mesh, in_specs=(P("data"),),
                          out_specs=(P("data"), P("data")),
                          axis_names={"data"}, check_vma=False)
    tiled, stacked = jax.jit(sh)(x)
    np.testing.assert_array_equal(np.asarray(tiled)[0], np.asarray(x)[0])
    np.testing.assert_array_equal(np.asarray(stacked)[0, 0],
                                  np.asarray(x)[0])


def test_pad_trailing_and_zeros_like_inside_region():
    mesh = _mesh11()

    def f(x):
        p = compat.pad_trailing(x[0], 3)
        z = compat.zeros_like_traced(x[0], jnp.int8)
        return p[None], z[None]

    sh = compat.shard_map(f, mesh=mesh, in_specs=(P("data"),),
                          out_specs=(P("data"), P("data")),
                          axis_names={"data"}, check_vma=False)
    p, z = jax.jit(sh)(jnp.ones((1, 5)))
    np.testing.assert_array_equal(
        np.asarray(p)[0], np.concatenate([np.ones(5), np.zeros(3)]))
    assert np.asarray(z).dtype == np.int8
    np.testing.assert_array_equal(np.asarray(z)[0], np.zeros(5))


def test_pad_trailing_noop_and_plain():
    x = jnp.ones((2, 5))
    assert compat.pad_trailing(x, 0) is x
    np.testing.assert_array_equal(
        np.asarray(compat.pad_trailing(x, 2))[:, 5:], np.zeros((2, 2)))


def test_axis_size_inside_and_make_mesh_tolerance():
    # make_mesh must accept axis_types on every JAX (dropping if needed)
    mesh = compat.make_mesh((1,), ("data",),
                            axis_types=(AxisType.Auto,))
    assert tuple(mesh.axis_names) == ("data",)

    def f(x):
        return x * compat.axis_size("data")

    sh = compat.shard_map(f, mesh=mesh, in_specs=(P("data"),),
                          out_specs=P("data"))
    np.testing.assert_array_equal(
        np.asarray(jax.jit(sh)(jnp.ones((1, 2)))), np.ones((1, 2)))


def test_engine_vote_runs_inside_one_device_region():
    """The full VoteEngine wire path (every strategy) composes with the
    compat layer on the 1-device partial-auto mesh — the configuration
    every laptop run of the trainer uses."""
    from repro.configs.base import VoteStrategy
    from repro.core.vote_engine import VoteEngine

    mesh = _mesh11()
    x = jnp.asarray(np.linspace(-1, 1, 37)[None], jnp.float32)
    for strategy in (VoteStrategy.PSUM_INT8, VoteStrategy.ALLGATHER_1BIT,
                     VoteStrategy.HIERARCHICAL):
        eng = VoteEngine(strategy=strategy, axes=("data",))

        def f(vals):
            return eng.vote(vals[0])[None]

        sh = compat.shard_map(f, mesh=mesh, in_specs=(P("data"),),
                              out_specs=P("data"), axis_names={"data"},
                              check_vma=False)
        out = np.asarray(jax.jit(sh)(x))[0]
        want = np.sign(np.asarray(x)[0])
        if strategy != VoteStrategy.PSUM_INT8:
            want = np.where(np.asarray(x)[0] >= 0, 1, -1)  # M=1 binarises
        np.testing.assert_array_equal(out, want, err_msg=str(strategy))
