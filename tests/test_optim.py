"""Optimizer unit tests: single-process semantics of every optimizer kind,
LR schedule, error feedback, and end-to-end learning on a tiny model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ByzantineConfig, MomentumMode,
                                OptimizerConfig, TrainConfig, get_config,
                                reduced_config)
from repro.core.signum import build_optimizer, lr_at
from repro.models import model as M
from repro.train import train_step as TS


def _params():
    return {"w": jnp.asarray([[1.0, -2.0], [0.5, 0.0]]),
            "b": jnp.asarray([0.1, -0.1])}


def test_signsgd_single_worker_is_sign_descent():
    cfg = OptimizerConfig(kind="signsgd_vote", momentum=0.0,
                          learning_rate=0.1)
    opt = build_optimizer(cfg, axes=())
    p = _params()
    g = {"w": jnp.asarray([[0.3, -0.7], [0.0, 2.0]]),
         "b": jnp.asarray([-1.0, 1.0])}
    state = opt.init(p)
    p2, state, _ = opt.update(g, state, p, jnp.int32(0))
    np.testing.assert_allclose(
        np.asarray(p2["w"]),
        np.asarray(p["w"]) - 0.1 * np.sign(np.asarray(g["w"])), rtol=1e-6)


def test_signum_momentum_update():
    cfg = OptimizerConfig(kind="signum_vote", momentum=0.5,
                          learning_rate=0.1,
                          momentum_mode=MomentumMode.PER_WORKER)
    opt = build_optimizer(cfg, axes=())
    p = _params()
    state = opt.init(p)
    g1 = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g2 = {"w": -3.0 * jnp.ones((2, 2)), "b": -3.0 * jnp.ones((2,))}
    p1, state, _ = opt.update(g1, state, p, jnp.int32(0))
    # v = 0.5*0 + 0.5*1 = 0.5 -> sign +1
    np.testing.assert_allclose(np.asarray(p1["b"]),
                               np.asarray(p["b"]) - 0.1, rtol=1e-6)
    p2, state, _ = opt.update(g2, state, p1, jnp.int32(1))
    # v = 0.5*0.5 + 0.5*(-3) = -1.25 -> sign -1
    np.testing.assert_allclose(np.asarray(p2["b"]),
                               np.asarray(p1["b"]) + 0.1, rtol=1e-6)


def test_weight_decay_applied():
    cfg = OptimizerConfig(kind="signsgd_vote", momentum=0.0,
                          learning_rate=0.1, weight_decay=0.5)
    opt = build_optimizer(cfg, axes=())
    p = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([1.0])}
    state = opt.init(p)
    p2, _, _ = opt.update(g, state, p, jnp.int32(0))
    # x - eta*(sign + wd*x) = 2 - 0.1*(1 + 0.5*2) = 1.8
    np.testing.assert_allclose(np.asarray(p2["w"]), [1.8], rtol=1e-6)


@pytest.mark.parametrize("kind", ["sgd", "sgdm", "adam"])
def test_dense_baselines_descend(kind):
    cfg = OptimizerConfig(kind=kind, learning_rate=0.05)
    opt = build_optimizer(cfg, axes=())

    p = {"w": jnp.asarray([3.0, -4.0])}
    state = opt.init(p)
    for k in range(200):
        g = {"w": p["w"]}  # grad of 0.5||w||^2
        p, state, _ = opt.update(g, state, p, jnp.int32(k))
    assert float(jnp.sum(p["w"] ** 2)) < 1e-2


def test_lr_schedule():
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10,
                          total_steps=110)
    assert float(lr_at(cfg, jnp.int32(0))) == 0.0
    assert float(lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr_at(cfg, jnp.int32(110))) == pytest.approx(0.0, abs=1e-6)


def test_error_feedback_accumulates():
    cfg = OptimizerConfig(kind="signum_vote", momentum=0.0,
                          learning_rate=0.1, error_feedback=True,
                          momentum_mode=MomentumMode.PER_WORKER)
    opt = build_optimizer(cfg, axes=())
    p = {"w": jnp.zeros((4,))}
    state = opt.init(p)
    assert "error" in state
    g = {"w": jnp.asarray([0.1, -0.2, 0.3, -0.4])}
    _, state, _ = opt.update(g, state, p, jnp.int32(0))
    # error = t - mean|t| * sign(t)
    t = np.asarray(g["w"])
    expect = t - np.mean(np.abs(t)) * np.sign(t)
    np.testing.assert_allclose(np.asarray(state["error"]["w"]), expect,
                               rtol=1e-5)


def test_end_to_end_training_loss_decreases():
    cfg = reduced_config(get_config("glm4-9b"), num_layers=2)
    tcfg = TrainConfig(global_batch=8, seq_len=32,
                       optimizer=OptimizerConfig(kind="signum_vote",
                                                 learning_rate=3e-3))
    art = TS.make_train_step(cfg, tcfg, mesh=None)
    params, opt_state = TS.materialize_state(cfg, tcfg, art,
                                             jax.random.PRNGKey(0))
    batch = M.make_batch(cfg, 8, 32, jax.random.PRNGKey(1))
    first = last = None
    for i in range(25):
        params, opt_state, met = art.step_fn(params, opt_state, batch,
                                             jnp.int32(i))
        if first is None:
            first = float(met["loss"])
        last = float(met["loss"])
    assert last < first - 1.0, (first, last)


def test_microbatched_equals_full_batch_grads():
    """Accumulated microbatch gradients match the full-batch gradient, so
    the sign/vote sees identical input (Algorithm 1 semantics)."""
    cfg = reduced_config(get_config("glm4-9b"), num_layers=1)
    cfg = dataclasses.replace(cfg, dtype="float32")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = M.make_batch(cfg, 8, 16, key)
    g_full = jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0])(params)
    gs = []
    for i in range(4):
        mb = jax.tree.map(lambda x: x[i * 2:(i + 1) * 2], batch)
        gs.append(jax.grad(lambda p: M.loss_fn(cfg, p, mb)[0])(params))
    g_acc = jax.tree.map(lambda *x: sum(x) / 4, *gs)
    for k in g_full:
        np.testing.assert_allclose(np.asarray(g_acc[k]),
                                   np.asarray(g_full[k]),
                                   rtol=1e-4, atol=1e-5)
