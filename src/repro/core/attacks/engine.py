"""The stateful attack engine (DESIGN.md §15).

``core/byzantine.py`` models adversaries as stateless per-step sign
transforms — a pure function of (honest signs, replica id, step, salt).
That covers the paper's Fig. 4 threat model but not the regime Mengoli
et al. 2025 call out: adversaries that *observe* the protocol and adapt.
This module adds that observation loop without forking the vote path:

* an adaptive adversary is still a :class:`~repro.configs.base.
  ByzantineConfig` mode, dispatched from :func:`repro.core.byzantine.
  evil_signs` like every oblivious mode — same predicate (``id <
  num_adversaries``), same stale-then-adversary ordering (§7);
* what is new is the **observation channel**: a small dict of arrays
  (previous round's vote / |tally| counts / the defense's reputation
  EMA) threaded through ``VoteRequest.attack_obs`` and consumed inside
  the jitted vote as *traced* inputs. The channel is produced by
  :class:`AttackState` — the attacker's memory, carried beside the
  server state by the Scenario Lab and updated once per round from the
  published :class:`~repro.core.vote_api.VoteOutcome`.

Everything the attacker observes is public protocol output (the
broadcast vote, its tally magnitudes, the weights the server would
assign next round). The reputation channel deserves a note: the
weighted_vote flip-EMA is a deterministic public function of each
voter's *own* sent signs and the published vote, so a defense-aware
attacker reconstructs the server's opinion of itself exactly — no
side channel is assumed.

Adaptive modes
  adaptive_flip — replay the negation of the previous round's vote
                  (channel ``vote``). The strongest 1-round-delayed
                  oracle flipper: where the vote is persistent this is
                  exactly anti-vote; honest at step 0.
  low_margin    — flip only the ``target_fraction`` of coordinates with
                  the smallest previous |tally| (channel ``margin``) —
                  concentrating the coalition's budget where the vote is
                  nearly tied, the Mengoli et al. observation that
                  per-coordinate margins, not dimension counts, set the
                  breaking point.
  reputation    — game the weighted_vote flip-EMA (channel
                  ``reputation``): vote honestly while own reputation is
                  damaged (EMA >= ``strike_below``), strike (negate)
                  while trusted. The on-off oscillation holds the EMA in
                  the codec's blind spot instead of saturating it.

All three are deterministic given the observation — no PRNG — so
mesh == virtual bit-identity reduces to feeding both backends the same
``attack_obs``, which the Scenario Lab does by construction.

:func:`build_config` / :func:`coalition_config` are the sanctioned
``ByzantineConfig`` constructors (``scripts/check_api_surface.py``
forbids direct construction with arguments outside ``core/``): they
validate the mode against *both* mode tables and count coalition
members through the exact-``Fraction`` ``count_for_fraction`` rule, so
the dense, population, and scheduled paths can never round a boundary
fraction differently.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ByzantineConfig
from repro.core.codecs import weighted as _weighted

#: adaptive modes, dispatched from byzantine.evil_signs (mode tables are
#: disjoint: an adaptive mode never shadows an oblivious one)
ATTACK_MODES = ("adaptive_flip", "low_margin", "reputation")

#: the observation channel each adaptive mode consumes
MODE_CHANNEL = {"adaptive_flip": "vote",
                "low_margin": "margin",
                "reputation": "reputation"}

#: legal values of AdversarySpec.observe / AttackState.observation
OBSERVE_CHANNELS = ("none", "vote", "margin", "reputation")

#: exactly the arrays each channel exposes to the attacker (the
#: VoteRequest validates attack_obs against this table — an attacker
#: never sees more of the AttackState than its channel grants)
CHANNEL_KEYS = {"none": (),
                "vote": ("prev_vote",),
                "margin": ("prev_vote", "prev_abs_counts"),
                "reputation": ("rep",)}


def required_channel(modes: Iterable[str]) -> str:
    """The single observation channel a set of (scheduled) modes needs,
    or ``"none"``. More than one distinct channel is an error: one
    AttackState observation is built per round, and a schedule that
    hops channels would need the union — reject it at build time."""
    chans = sorted({MODE_CHANNEL[m] for m in modes if m in MODE_CHANNEL})
    if len(chans) > 1:
        raise ValueError(
            f"attack schedule mixes observation channels {chans}; "
            "a schedule may hop fraction and mode but all adaptive "
            "modes in it must share one channel")
    return chans[0] if chans else "none"


def adaptive_evil_signs(signs: jax.Array, cfg: ByzantineConfig,
                        idx: jax.Array, obs: Optional[Dict[str, Any]], *,
                        step: Optional[jax.Array] = None,
                        salt: int = 0) -> jax.Array:
    """What adaptive replica ``idx`` sends, given the observation.

    Deterministic in (signs, cfg, idx, obs) — adaptive modes draw no
    PRNG, so cross-backend bit-identity needs no key discipline beyond
    feeding both backends the same ``obs``. ``step``/``salt`` are
    accepted for signature parity with the oblivious modes.
    """
    del step, salt
    if obs is None:
        raise ValueError(
            f"adaptive mode {cfg.mode!r} needs its observation channel "
            f"({MODE_CHANNEL.get(cfg.mode)!r}) threaded as "
            "VoteRequest.attack_obs — build it with "
            "AttackState.observation()")
    if cfg.mode == "adaptive_flip":
        # negate last round's broadcast vote; coords the vote abstained
        # on (0, incl. the pre-first-round state) are sent honestly
        pv = obs["prev_vote"].astype(signs.dtype)
        return jnp.where(pv == 0, signs, (-pv).astype(signs.dtype))
    if cfg.mode == "low_margin":
        # flip AGAINST the previous vote on the target_fraction of
        # coordinates with the smallest previous |tally|; honest
        # elsewhere (and everywhere at step 0, when all counts are 0
        # but so is prev_vote)
        pv = obs["prev_vote"].astype(signs.dtype)
        counts = obs["prev_abs_counts"]
        n = counts.shape[-1]
        k = max(1, min(n, int(round(cfg.target_fraction * n))))
        thresh = jnp.sort(counts)[k - 1]
        struck = (counts <= thresh) & (pv != 0)
        return jnp.where(struck, (-pv).astype(signs.dtype), signs)
    if cfg.mode == "reputation":
        # strike while trusted, rebuild while burnt: the flip-EMA
        # starts at 0 (fully trusted), so the attacker strikes round 0,
        # gets caught, votes honestly until the EMA decays back under
        # strike_below, then strikes again
        strike = obs["rep"][idx] < cfg.strike_below
        return jnp.where(strike, -signs, signs)
    raise ValueError(f"unknown adaptive attack mode {cfg.mode!r}; "
                     f"have {ATTACK_MODES}")


# ---------------------------------------------------------------------------
# the attacker's memory
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttackState:
    """The attacker's memory, one instance per scenario run.

    Carried beside the server state with the same discipline (§15): the
    runner owns it, updates it exactly once per round from the published
    outcome, refits its voter axis on elastic rescale / churn exactly
    like the reliability EMA, and hands attackers only the slice their
    channel grants via :meth:`observation`.

    ``prev_vote`` (n,) int8 and ``prev_abs_counts`` (n,) int32 describe
    the *previous* round's broadcast; both start at zero, which encodes
    "no round yet" (adaptive modes read that as: act honest).
    ``rep`` (M,) float32 mirrors the weighted_vote flip-EMA over the
    logical population (zeros when the codec is not in play — a
    reputation attacker then strikes every round, degenerating to
    sign_flip, which is exactly what no-defense means).
    """

    prev_vote: Any
    prev_abs_counts: Any
    rep: Any

    @classmethod
    def init(cls, n_coords: int, n_voters: int) -> "AttackState":
        return cls(prev_vote=jnp.zeros((n_coords,), jnp.int8),
                   prev_abs_counts=jnp.zeros((n_coords,), jnp.int32),
                   rep=jnp.zeros((n_voters,), jnp.float32))

    def observation(self, channel: str) -> Optional[Dict[str, Any]]:
        """The dict an attacker on ``channel`` may see (None for
        ``"none"``) — exactly :data:`CHANNEL_KEYS`, nothing more."""
        if channel not in OBSERVE_CHANNELS:
            raise ValueError(f"unknown observation channel {channel!r}; "
                             f"have {OBSERVE_CHANNELS}")
        keys = CHANNEL_KEYS[channel]
        if not keys:
            return None
        return {k: getattr(self, k) for k in keys}

    def refit(self, n_voters: int) -> "AttackState":
        """Elastic-rescale / churn refit: the per-voter reputation axis
        truncates or zero-pads by the checkpoint rule (new voters enter
        fully trusted, like a fresh flip-EMA row); the per-coordinate
        arrays are untouched."""
        from repro.checkpoint.checkpoint import refit_leading_axis
        rep = jnp.asarray(refit_leading_axis(
            np.asarray(self.rep), (n_voters,)))
        return dataclasses.replace(self, rep=rep)


@jax.jit
def _update(prev_rep, vote, counts, eff):
    wire = jnp.where(eff >= 0, jnp.int8(1), jnp.int8(-1))
    v = jnp.where(vote >= 0, jnp.int8(1), jnp.int8(-1))
    mis = jnp.mean((wire != v[None, :]).astype(jnp.float32), axis=-1)
    rep = (1.0 - _weighted.RHO) * prev_rep + _weighted.RHO * mis
    return (jnp.sign(vote).astype(jnp.int8),
            jnp.abs(counts).astype(jnp.int32), rep)


def update_attack_state(state: AttackState, vote, counts,
                        eff) -> AttackState:
    """One round's observation: the published vote, its per-coordinate
    signed tally, and the (M, n) effective signs that reached the wire.

    ``rep`` replays the weighted_vote flip-EMA *exactly* — same
    binarized wire signs (pack/unpack maps abstentions to +1), same
    ``(1-RHO)*ema + RHO*mismatch/n`` expression — because that EMA is a
    public deterministic function of public data; an attacker tracking
    it is not guessing, it is bookkeeping.
    """
    pv, pc, rep = _update(state.rep, jnp.asarray(vote),
                          jnp.asarray(counts), jnp.asarray(eff))
    return AttackState(prev_vote=pv, prev_abs_counts=pc, rep=rep)


@jax.jit
def _rep_update_at(rep, ids, mis_frac):
    upd = (1.0 - _weighted.RHO) * rep[ids] + _weighted.RHO * mis_frac
    return rep.at[ids].set(upd)


def update_attack_state_population(state: AttackState, vote, counts,
                                   ids, mis_frac) -> AttackState:
    """The population-path round update: the EMA touches only the
    sampled logical ids (mirroring the codec's own streamed update);
    ``mis_frac`` is each sampled voter's mismatch fraction vs the vote,
    assembled chunk-by-chunk by the runner."""
    vote = jnp.asarray(vote)
    counts = jnp.asarray(counts)
    rep = _rep_update_at(state.rep, jnp.asarray(ids, dtype=jnp.int32),
                         jnp.asarray(mis_frac, dtype=jnp.float32))
    return AttackState(prev_vote=jnp.sign(vote).astype(jnp.int8),
                       prev_abs_counts=jnp.abs(counts).astype(jnp.int32),
                       rep=rep)


# ---------------------------------------------------------------------------
# the sanctioned ByzantineConfig constructors
# ---------------------------------------------------------------------------


def build_config(mode: str, num_adversaries: int = 0, *, seed: int = 0,
                 flip_prob: float = 0.5, target_fraction: float = 0.25,
                 strike_below: float = 0.1) -> ByzantineConfig:
    """Validated :class:`ByzantineConfig` for an absolute adversary
    count — the one constructor all callers outside ``core/`` use
    (enforced by ``scripts/check_api_surface.py``)."""
    from repro.core import byzantine
    if mode not in byzantine.MODES and mode not in ATTACK_MODES:
        raise ValueError(f"unknown adversary mode {mode!r}; have "
                         f"{byzantine.MODES} plus adaptive {ATTACK_MODES}")
    if num_adversaries < 0:
        raise ValueError(f"num_adversaries must be >= 0, got "
                         f"{num_adversaries}")
    if mode == "none" or num_adversaries == 0:
        # honest collapses to the canonical rest state so config
        # equality (segment/jit cache keys) never splits on a knob that
        # cannot matter
        mode, num_adversaries = "none", 0
    return ByzantineConfig(mode=mode, num_adversaries=num_adversaries,
                           seed=seed, flip_prob=flip_prob,
                           target_fraction=target_fraction,
                           strike_below=strike_below)


def coalition_config(mode: str, fraction: float, n_workers: int, *,
                     seed: int = 0, flip_prob: float = 0.5,
                     target_fraction: float = 0.25,
                     strike_below: float = 0.1) -> ByzantineConfig:
    """:func:`build_config` with the coalition sized from a fraction by
    the exact-``Fraction`` half-up rule (``distributed.fault_tolerance.
    count_for_fraction``) — the single rounding used by the dense,
    population, and scheduled paths alike, so boundary fractions such
    as 7/15 can never round differently between backends."""
    from repro.distributed.fault_tolerance import count_for_fraction
    if not 0.0 <= fraction <= 1.0:
        raise ValueError(f"adversary fraction must be in [0, 1], got "
                         f"{fraction}")
    return build_config(mode, count_for_fraction(fraction, n_workers),
                        seed=seed, flip_prob=flip_prob,
                        target_fraction=target_fraction,
                        strike_below=strike_below)
