"""Fig. 4 demo: train the same model with 0%..44% of vote replicas acting
adversarially (sign inversion) and show the vote shrugging it off.

First the failure composition is shown declaratively — the adversary is
DATA on a ``VoteRequest`` (a :class:`FailureSpec`), not a separate code
path (DESIGN.md §10) — then the REAL distributed train step runs over 8
fake devices (data=8), where the adversaries are actual mesh replicas
keyed by axis_index, exactly as they would be on a pod.

    python examples/byzantine_demo.py            # full sweep
    python examples/byzantine_demo.py --smoke    # CI-sized (seconds)
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import (ByzantineConfig, OptimizerConfig,
                                TrainConfig, VoteStrategy, get_config,
                                reduced_config)
from repro.core import vote_api as va
from repro.models import model as M
from repro.train import train_step as TS


def vote_request_demo():
    """8 honest workers vs 3 of them flipping signs: same VoteRequest,
    only the FailureSpec differs."""
    g = np.random.default_rng(1).normal(size=(8, 6)).astype(np.float32)
    honest = va.VoteRequest(payload=jnp.asarray(g), form="stacked",
                            strategy=VoteStrategy.PSUM_INT8)
    attacked = va.VoteRequest(
        payload=jnp.asarray(g), form="stacked",
        strategy=VoteStrategy.PSUM_INT8,
        failures=va.FailureSpec(byz=ByzantineConfig(mode="sign_flip",
                                                    num_adversaries=3)))
    backend = va.VirtualBackend()
    print("honest vote:   ", np.asarray(backend.execute(honest).votes))
    print("3/8 flipped:   ", np.asarray(backend.execute(attacked).votes))
    print("(the adversary is request data — same wire, same backend)\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, same code path)")
    args = ap.parse_args()
    vote_request_demo()

    mesh = compat.make_mesh((8, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    # high-adversarial cases use a re-tuned (lower) learning rate, exactly
    # as the paper does for its 43% case (Fig. 4 right)
    cells = [(0, 3e-3), (1, 3e-3), (2, 3e-3), (3, 3e-3),
             (3, 1e-3), (5, 1e-3)]
    n_steps, n_layers, seq = 40, 2, 32
    shrink = {}
    if args.smoke:
        # one adversarial cell (the honest wire is already shown above):
        # the 8-dev step still compiles and the loss still drops under
        # 3/8 sign-flippers, in CI-budget seconds
        cells, n_steps, n_layers, seq = [(3, 3e-3)], 3, 1, 16
        shrink = dict(d_model=64, d_ff=128, vocab_size=128)
    print(f"{'adversaries':>12s} {'alpha':>6s} {'lr':>7s} "
          f"{'loss_0':>8s} {'loss_T':>8s}")
    for n_adv, lr in cells:
        cfg = reduced_config(get_config("glm4-9b"), num_layers=n_layers,
                             **shrink)
        tcfg = TrainConfig(
            global_batch=8, seq_len=seq,
            optimizer=OptimizerConfig(kind="signum_vote",
                                      learning_rate=lr),
            byzantine=ByzantineConfig(mode="sign_flip",
                                      num_adversaries=n_adv))
        art = TS.make_train_step(cfg, tcfg, mesh=mesh)
        params, opt = TS.materialize_state(cfg, tcfg, art,
                                           jax.random.PRNGKey(0), mesh)
        batch = M.make_batch(cfg, 8, seq, jax.random.PRNGKey(1))
        batch = jax.tree.map(
            lambda a: jax.device_put(np.asarray(a),
                                     NamedSharding(mesh, P("data"))), batch)
        first = last = None
        for i in range(n_steps):
            params, opt, met = art.step_fn(params, opt, batch, jnp.int32(i))
            if first is None:
                first = float(met["loss"])
            last = float(met["loss"])
        note = "  <- 5/8 adversarial: vote rightly fails" if n_adv > 4 else ""
        print(f"{n_adv:>12d} {n_adv / 8:6.2f} {lr:7.0e} "
              f"{first:8.3f} {last:8.3f}{note}")


if __name__ == "__main__":
    main()
