"""Per-arch training presets: optimizer mode, FSDP, remat (DESIGN.md §3/§5).

Mode A (paper-faithful per-worker momentum) wherever the momentum fits a
chip; Mode B (vote-on-sign + global momentum, fused ZeRO backward) for the
three archs whose per-replica momentum exceeds HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import (ByzantineConfig, MomentumMode,
                                OptimizerConfig, ShapeCell, TrainConfig,
                                VoteStrategy, get_config)

# archs that need the scalable Mode-B + ZeRO-3 path
MODE_B_ARCHS = ("qwen1.5-32b", "deepseek-67b", "qwen3-moe-235b-a22b")
# Mode-A archs whose fp32 per-worker momentum is tight -> bf16 momentum
BF16_MOMENTUM_ARCHS = ("gemma3-12b", "pixtral-12b", "glm4-9b",
                       "qwen2-moe-a2.7b")
# per-arch grad-accumulation for Mode A train cells (activation memory)
MICROBATCHES = {"whisper-tiny": 8, "zamba2-1.2b": 4, "mamba2-2.7b": 4,
                "qwen2-moe-a2.7b": 8, "qwen3-moe-235b-a22b": 4}


def default_optimizer(arch: str, *, kind: str = "signum_vote",
                      vote_strategy: Optional[VoteStrategy] = None
                      ) -> OptimizerConfig:
    if kind in ("sgd", "sgdm", "adam"):
        return OptimizerConfig(kind=kind, learning_rate=1e-4, momentum=0.9)
    if arch in MODE_B_ARCHS:
        return OptimizerConfig(
            kind="signsgd_vote",
            momentum_mode=MomentumMode.GLOBAL,
            vote_strategy=vote_strategy or VoteStrategy.HIERARCHICAL,
            learning_rate=1e-4, momentum=0.9)
    mom_dtype = ("bfloat16" if arch in BF16_MOMENTUM_ARCHS else "float32")
    return OptimizerConfig(
        kind="signum_vote",
        momentum_mode=MomentumMode.PER_WORKER,
        vote_strategy=vote_strategy or VoteStrategy.PSUM_INT8,
        momentum_dtype=mom_dtype,
        learning_rate=1e-4, momentum=0.9)


def default_train_config(arch: str, cell: ShapeCell, *,
                         kind: str = "signum_vote",
                         vote_strategy: Optional[VoteStrategy] = None,
                         byzantine: Optional[ByzantineConfig] = None
                         ) -> TrainConfig:
    opt = default_optimizer(arch, kind=kind, vote_strategy=vote_strategy)
    # Mode A holds params replicated over 'data'; grad-accumulate in
    # microbatches to bound activation memory (Mode B relies on ZeRO-3 +
    # remat + sequence-parallel residuals instead).
    # Mode B microbatching: each microbatch's backward votes (the fused
    # reduce-scatter), and the +-1 votes accumulate in the slice-shaped
    # grad buffer (~1 GB at 67B) — majority-of-microbatch-votes semantics,
    # recorded in DESIGN.md §3.
    micro = MICROBATCHES.get(arch, 8)
    return TrainConfig(
        global_batch=cell.global_batch,
        seq_len=cell.seq_len,
        microbatches=micro,
        # big archs additionally use sqrt-remat over layer groups
        remat="nested" if arch in MODE_B_ARCHS else "full",
        fsdp=arch in MODE_B_ARCHS,
        optimizer=opt,
        byzantine=byzantine or ByzantineConfig(),
    )
