"""The vote API (DESIGN.md §10): one declarative entry point for every
majority vote, on every backend.

Four PRs of growth multiplied the paper's single concept — workers send
sign vectors, the server returns a majority decision — into ~15
imperative entry points, one per point in the payload-form × codec ×
failure × backend grid. This module collapses that grid back into data:

* :class:`VoteRequest` **says what to vote on** — the payload (a
  replica-local leaf, a host-local stacked ``(M, n)`` buffer, or a tree
  of leaves), the wire (strategy or AUTO, codec, optional
  :class:`~repro.core.vote_plan.VotePlan` bucket schedule), the failure
  composition (:class:`FailureSpec`: stale-vote stragglers + the
  compiled Byzantine model), the PRNG discipline (``step``/``salt``),
  and the incoming server state.
* A :class:`VoteBackend` **executes it** — :class:`MeshBackend` drives
  the real collectives (inside a manual ``shard_map`` region for
  leaf/tree payloads, or by building the ``shard_map`` itself for
  stacked payloads, exactly like the Scenario Lab's mesh path);
  :class:`VirtualBackend` runs the same stage methods over a stacked
  voter dim with the exchange virtualised (host-count independent).
* :class:`VoteOutcome` **returns the decision** — votes in the
  payload's original form, the updated server state, and a
  :class:`WireReport` (bytes/messages/margin/agreement) computed once.

Requests are *validated at build time*: unsupported codec × strategy
combinations, missing server state, stale substitution without a
previous-signs source, or a payload that does not match its plan's
manifest are all rejected with actionable messages before any tracing
happens, and both backends see the identical request — which is how the
mesh == virtual bit-identity invariants are proven once instead of
per-variant.

Every legacy entry point (``VoteEngine.vote*``,
``fault_tolerance.*_vote_with_failures``, ``virtual_mesh.virtual_*``,
``vote_plan.plan_vote_signs``/``plan_tree_vote``) is now a deprecation
shim that builds a :class:`VoteRequest` and calls ``execute`` — see the
migration table in DESIGN.md §10.

This module is also the single home of the pack-width helpers
(:func:`pad_last`, :func:`count_dtype`) that ``vote_engine``,
``vote_plan`` and the virtual mesh used to carry as near-duplicates.
"""
from __future__ import annotations

import abc
import dataclasses
import functools
import warnings
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import byzantine, sign_compress as sc
from repro.obs import recorder as obs

FORMS = ("leaf", "stacked", "tree", "streamed")
MESH_STYLES = ("data_model", "data_only")


# ---------------------------------------------------------------------------
# consolidated pack-width helpers (single source of truth; DESIGN.md §10)
# ---------------------------------------------------------------------------


def count_dtype(n_voters: int):
    """Narrowest signed integer that can hold a vote count of `n_voters`."""
    if n_voters <= 127:
        return jnp.int8
    if n_voters <= 32_767:
        return jnp.int16
    return jnp.int32


def count_bytes(n_voters: int) -> int:
    return jnp.dtype(count_dtype(n_voters)).itemsize


def pad_last(x: jax.Array, multiple: int) -> Tuple[jax.Array, int]:
    """Zero-pad the LAST dim to a multiple; returns (padded, original_n).

    Routed through ``compat.pad_trailing`` so padding stays safe inside
    legacy partial-auto shard_map (raw ``jnp.pad``'s constant-pad
    lowering aborts there). This is THE padding helper — `vote_engine`,
    `vote_plan`, `sign_compress` and the virtual mesh all delegate here,
    so the wire's pad semantics cannot silently diverge per module."""
    n = x.shape[-1]
    return compat.pad_trailing(x, (-n) % multiple), n


# ---------------------------------------------------------------------------
# deprecation plumbing for the legacy entry points
# ---------------------------------------------------------------------------

_WARNED: set = set()


def warn_legacy(name: str, hint: str = "") -> None:
    """Emit ONE DeprecationWarning per legacy entry point per process
    (module-level once-guard): the shims stay usable in hot loops and
    old notebooks without drowning them in repeats."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated: build a repro.core.vote_api.VoteRequest "
        f"and call MeshBackend/VirtualBackend.execute() instead"
        + (f" ({hint})" if hint else "") + "; see DESIGN.md §10",
        DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# the request / outcome dataclasses
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FailureSpec:
    """The failure composition applied in front of the wire, in the
    pinned order (DESIGN.md §7): stale-vote straggler substitution first
    (the first `n_stale` replicas vote with the request's ``prev``
    signs), THEN the compiled Byzantine model (`byz`) — so a straggling
    adversary corrupts its *stale* vector. Crashed/mute workers are the
    ``zero``-mode adversary (an abstention mask on the count wires)."""

    n_stale: int = 0
    byz: Optional[ByzantineConfig] = None

    def __post_init__(self):
        from repro.core.attacks.engine import ATTACK_MODES
        if self.n_stale < 0:
            raise ValueError(f"n_stale must be >= 0, got {self.n_stale}")
        if (self.byz is not None and self.byz.mode not in byzantine.MODES
                and self.byz.mode not in ATTACK_MODES):
            raise ValueError(f"unknown adversary mode {self.byz.mode!r}; "
                             f"have {byzantine.MODES} plus adaptive "
                             f"{ATTACK_MODES}")

    @property
    def active(self) -> bool:
        return self.n_stale > 0 or (self.byz is not None
                                    and self.byz.mode != "none")

    @property
    def adaptive(self) -> bool:
        """True when the adversary is one of the ``repro.core.attacks``
        modes, which additionally consume ``VoteRequest.attack_obs``."""
        from repro.core.attacks.engine import ATTACK_MODES
        return self.byz is not None and self.byz.mode in ATTACK_MODES


@dataclasses.dataclass(frozen=True)
class WireReport:
    """What one executed vote put on the wire — computed once, here,
    instead of re-derived per caller. `payload_bytes` is one replica's
    outbound payload (the paper's "bits sent"); `n_messages` counts the
    wire rounds (1 per leaf/flat vote, one per bucket under a plan);
    `strategy` is the resolved wire (None for a mixed-strategy plan or
    the M=1 no-wire degenerate case). `margin`/`agreement` are the §7
    diagnostics (traced scalars), present when the request asked for
    them."""

    n_voters: int
    payload_bytes: float
    n_messages: int
    strategy: Optional[VoteStrategy]
    margin: Optional[jax.Array] = None
    agreement: Optional[jax.Array] = None


@dataclasses.dataclass(frozen=True)
class VoteOutcome:
    """votes in the payload's original form + updated server state + the
    wire report.

    ``wire_signs`` is the (M, n) int8 sign tensor that actually reached
    the wire (sign extraction -> stale substitution -> adversary, the
    pinned §7 order) — populated by the dense VirtualBackend path so
    trace capture observes exactly what was voted instead of recomputing
    the failure composition (and re-drawing the adversary PRNG) outside
    ``execute()``. ``None`` on the mesh path (the stack never exists on
    one host), the fused-kernel path (the kernel consumes raw values),
    and the streamed path (never materialized by design).

    ``counts`` is the per-coordinate signed tally ((n,) integer array,
    at the wire's own weight scale) — populated by the streamed path,
    where it feeds the attack engine's ``margin`` observation channel
    (DESIGN.md §15) without re-walking the stream; the stack never
    being materialized means no caller can recompute it after the
    fact. ``None`` elsewhere (dense callers tally ``wire_signs``)."""

    votes: Any
    server_state: Dict[str, Any]
    wire: WireReport
    wire_signs: Any = None
    counts: Any = None


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class PopulationStream:
    """A voter population yielded in chunks instead of materialized as
    one dense (M, n) stack — the ``"streamed"`` request form (DESIGN.md
    §12). The engine calls ``values`` (and ``prev``, when stale
    substitution is requested) with int32 chunks of **logical voter
    ids** and never holds more than ``chunk_size`` rows at once, so M
    decouples from both host memory and device count.

    * ``values``  — callable, (k,) int32 logical ids -> (k, n_coords)
      real values (the sampled clients' gradients). Must be a pure
      function of the ids so chunking cannot change the vote.
    * ``ids``     — optional (n_voters,) strictly-increasing non-negative
      logical indices (a client-sampled round); default = arange
      (full participation). Adversary/stale predicates and PRNG streams
      key on these ids, not row positions.
    * ``prev``    — optional callable, same contract as ``values``,
      returning (k, n_coords) int8 prev signs for stale substitution.
    * ``weights`` — optional (n_voters,) positive int dataset sizes
      aligned to ``ids``: each client casts weight-many votes
      (FedAvg-style dataset weighting, composing with the
      ``weighted_vote`` codec's reliability weights).
    """

    n_voters: int
    n_coords: int
    values: Any
    ids: Any = None
    prev: Any = None
    weights: Any = None

    def __post_init__(self):
        if self.n_voters < 1:
            raise ValueError(f"n_voters must be >= 1, got {self.n_voters}")
        if self.n_coords < 1:
            raise ValueError(f"n_coords must be >= 1, got {self.n_coords}")
        if not callable(self.values):
            raise ValueError("values must be a callable (ids) -> (k, n) "
                             f"chunk producer, got "
                             f"{type(self.values).__name__}")
        if self.prev is not None and not callable(self.prev):
            raise ValueError("prev must be a callable (ids) -> (k, n) "
                             "int8 chunk producer (same contract as "
                             f"values), got {type(self.prev).__name__}")
        if self.ids is not None:
            ids = np.asarray(self.ids)
            if ids.shape != (self.n_voters,):
                raise ValueError(f"ids must have shape ({self.n_voters},) "
                                 f"aligned to the stream rows, got "
                                 f"{ids.shape}")
            if not np.issubdtype(ids.dtype, np.integer):
                raise ValueError(f"ids must be integer logical indices, "
                                 f"got dtype {ids.dtype}")
            if ids.size and (int(ids.min()) < 0
                             or np.any(np.diff(ids) <= 0)):
                raise ValueError("ids must be strictly increasing "
                                 "non-negative logical voter indices "
                                 "(sort the sampled set)")
        if self.weights is not None:
            w = np.asarray(self.weights)
            if w.shape != (self.n_voters,):
                raise ValueError(f"weights must have shape "
                                 f"({self.n_voters},) aligned to the "
                                 f"stream rows, got {w.shape}")
            if not np.issubdtype(w.dtype, np.integer):
                raise ValueError("weights are integer vote counts "
                                 "(dataset sizes), got dtype "
                                 f"{w.dtype}")
            if w.size and int(w.min()) < 1:
                raise ValueError("weights must be >= 1 (a zero-data "
                                 "client does not vote; drop it from "
                                 "the sample instead)")

    def row_ids(self) -> np.ndarray:
        """The logical id of every stream row, materialized ((M,) int32)."""
        if self.ids is None:
            return np.arange(self.n_voters, dtype=np.int32)
        return np.asarray(self.ids, dtype=np.int32)


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class VoteRequest:
    """One declarative vote. Validated on construction — an invalid
    request never reaches a backend, and both backends reject the same
    requests with the same error class.

    `payload` + `form`:
      * ``"leaf"``    — one replica-local tensor ``(..., n)`` inside a
        manual mesh region (real values or int8 signs; signs are a fixed
        point of the sign extraction).
      * ``"stacked"`` — a host-local ``(M, n)`` buffer of all M voters'
        values (the Scenario Lab / benchmark form).
      * ``"tree"``    — a dict of replica-local leaves (the trainer's
        form; votes come back leaf-shaped in each leaf's dtype).
      * ``"streamed"`` — a :class:`PopulationStream` yielding voter
        chunks on demand (the federated-population form, DESIGN.md §12;
        VirtualBackend only — never materializes the (M, n) stack).

    `strategy` may be ``AUTO`` (resolved against the comm cost model,
    codec-aware); `plan` switches execution to the §9 bucket schedule
    (whose per-group codecs/strategies then supersede `codec`/
    `strategy`); `overlap` selects the double-buffered schedule walk
    (§11: bucket k's exchange issued while bucket k-1 tallies — needs a
    plan, bit-identical to the synchronous walk); `failures` composes
    stale substitution (needs `prev`) and the Byzantine model;
    `step`/`salt` feed the adversary PRNG discipline; `server_state`
    threads stateful codecs' decode memory; `diagnostics` (tree form
    only) asks for margin/agreement in the :class:`WireReport`.

    `voter_ids`/`weights` annotate a **stacked** payload with logical
    voter identities / integer dataset-size vote multiplicities — the
    dense twin of the streamed form's :class:`PopulationStream` axes
    (VirtualBackend only; the mesh's voters are physical replicas). A
    streamed request carries both on the stream instead.

    `attack_obs` is the adaptive adversary's observation dict
    (DESIGN.md §15): required exactly when ``failures.byz`` is one of
    the ``repro.core.attacks`` modes, validated against the mode's
    channel (``attacks.CHANNEL_KEYS``) so an attacker never sees more
    of the :class:`~repro.core.attacks.AttackState` than its channel
    grants. Build it with ``AttackState.observation(channel)``."""

    payload: Any
    form: str = "leaf"
    strategy: VoteStrategy = VoteStrategy.AUTO
    codec: str = "sign1bit"
    plan: Optional[Any] = None            # core.vote_plan.VotePlan
    failures: FailureSpec = FailureSpec()
    prev: Any = None
    step: Any = None
    salt: int = 0
    server_state: Optional[Dict[str, Any]] = None
    diagnostics: bool = False
    overlap: bool = False
    voter_ids: Any = None
    weights: Any = None
    attack_obs: Any = None

    # ---- build-time validation -----------------------------------------

    def __post_init__(self):
        from repro.core import codecs as codecs_mod
        if self.form not in FORMS:
            raise ValueError(f"unknown payload form {self.form!r}; "
                             f"have {FORMS}")
        codec = codecs_mod.get_codec(self.codec)     # raises on unknown
        if not isinstance(self.strategy, VoteStrategy):
            raise ValueError(f"strategy must be a VoteStrategy, got "
                             f"{self.strategy!r}")
        if self.plan is None and self.strategy != VoteStrategy.AUTO:
            codec.validate_strategy(self.strategy)
        if self.form == "tree":
            if not isinstance(self.payload, dict) or not self.payload:
                raise ValueError(
                    "tree-form payload must be a non-empty dict of "
                    f"leaves, got {type(self.payload).__name__}")
        elif self.form == "streamed":
            self._validate_streamed()
        else:
            if not hasattr(self.payload, "shape"):
                raise ValueError(
                    f"{self.form}-form payload must be an array, got "
                    f"{type(self.payload).__name__}")
            if self.form == "stacked" and len(self.payload.shape) != 2:
                raise ValueError(
                    "stacked-form payload must be (M, n) — M voters by n "
                    f"coordinates — got shape {tuple(self.payload.shape)}")
        if self.failures.n_stale > 0:
            has_prev = (self.payload.prev is not None
                        if self.form == "streamed" else
                        self.prev is not None)
            if not has_prev:
                raise ValueError(
                    f"failures.n_stale={self.failures.n_stale} substitutes "
                    "stale votes but the request has no prev signs to "
                    "substitute (set VoteRequest.prev"
                    + (" / PopulationStream.prev"
                       if self.form == "streamed" else "") + ")")
        self._validate_voter_axes()
        self._validate_attack_obs()
        self._validate_plan()
        # a stacked request always decodes through the codec (even M=1),
        # so missing server state is a build-time error there; leaf/tree
        # requests may execute in the no-axes M=1 degenerate case where
        # the vote is the local sign and no decode state is ever touched
        # (the legacy entry points allowed exactly that), so the backend
        # raises at execution instead when the region has vote axes
        needs_state = (self.plan.has_server_state if self.plan is not None
                       else codec.server_state)
        if (needs_state and not self.server_state
                and self.form in ("stacked", "streamed")):
            raise ValueError(
                f"codec {self.codec!r} (or the plan's codec map) keeps "
                "server-side decode state; thread it through "
                "VoteRequest.server_state (init_server_state for the "
                "uninformed prior)")
        if self.diagnostics and self.form != "tree":
            raise ValueError(
                "diagnostics (margin/agreement in the WireReport) are "
                "computed over a voted tree; leaf/stacked callers "
                "measure their own quantities (form="
                f"{self.form!r})")
        if self.overlap and self.plan is None:
            raise ValueError(
                "overlap=True double-buffers a plan's bucket schedule; "
                "attach a VotePlan (VoteRequest.plan / "
                "OptimizerConfig.bucket_bytes) or drop overlap")

    def _validate_streamed(self):
        if not isinstance(self.payload, PopulationStream):
            raise ValueError(
                "streamed-form payload must be a PopulationStream, got "
                f"{type(self.payload).__name__}")
        if self.plan is not None:
            raise ValueError(
                "the streamed population engine accumulates one flat "
                "coordinate buffer and has no bucket walk; drop the "
                "plan or use the stacked form")
        if self.overlap:
            raise ValueError(
                "overlap double-buffers a plan's bucket schedule; the "
                "streamed form has no plan to overlap")
        if self.prev is not None:
            raise ValueError(
                "a streamed request's prev signs are a chunk producer "
                "on the stream (PopulationStream.prev), not a dense "
                "VoteRequest.prev array")
        if self.voter_ids is not None or self.weights is not None:
            raise ValueError(
                "a streamed request carries voter ids and weights on "
                "the PopulationStream (ids=/weights=), not on the "
                "VoteRequest")

    def _validate_voter_axes(self):
        if self.voter_ids is None and self.weights is None:
            return
        if self.form != "stacked":
            raise ValueError(
                "voter_ids/weights annotate the rows of a stacked "
                f"(M, n) payload, not the {self.form!r} form (streamed "
                "requests carry them on the PopulationStream)")
        if self.plan is not None:
            raise ValueError(
                "voter_ids/weights do not compose with a bucketed plan "
                "yet; drop the plan (the population engine accumulates "
                "one flat buffer)")
        m = self.payload.shape[0]
        for name, arr in (("voter_ids", self.voter_ids),
                          ("weights", self.weights)):
            if arr is None:
                continue
            a = np.asarray(arr)
            if a.shape != (m,):
                raise ValueError(f"{name} must have shape ({m},) aligned "
                                 f"to the stacked rows, got {a.shape}")
            if not np.issubdtype(a.dtype, np.integer):
                raise ValueError(f"{name} must be an integer array, got "
                                 f"dtype {a.dtype}")
        if self.voter_ids is not None:
            ids = np.asarray(self.voter_ids)
            if ids.size and (int(ids.min()) < 0
                             or np.any(np.diff(ids) <= 0)):
                raise ValueError(
                    "voter_ids must be strictly increasing non-negative "
                    "logical voter indices (sort the sampled set)")
        if self.weights is not None:
            w = np.asarray(self.weights)
            if w.size and int(w.min()) < 1:
                raise ValueError(
                    "weights must be >= 1 (a zero-data client does not "
                    "vote; drop it from the sample instead)")

    def _validate_attack_obs(self):
        from repro.core.attacks import engine as attacks
        if not self.failures.adaptive:
            if self.attack_obs is not None:
                raise ValueError(
                    "attack_obs carries an adaptive adversary's "
                    "observation channel, but the request's adversary "
                    "mode is oblivious or absent — drop attack_obs or "
                    f"use one of the adaptive modes {attacks.ATTACK_MODES}")
            return
        byz = self.failures.byz
        if self.form not in ("stacked", "streamed"):
            raise ValueError(
                f"adaptive adversary mode {byz.mode!r} observes the "
                "previous round's flat broadcast vote; the "
                f"{self.form!r} form has no such observation channel "
                "(use the stacked or streamed form)")
        channel = attacks.MODE_CHANNEL[byz.mode]
        keys = attacks.CHANNEL_KEYS[channel]
        if (not isinstance(self.attack_obs, dict)
                or set(self.attack_obs) != set(keys)):
            got = (sorted(self.attack_obs) if isinstance(self.attack_obs,
                                                         dict)
                   else type(self.attack_obs).__name__)
            raise ValueError(
                f"adaptive mode {byz.mode!r} observes the {channel!r} "
                f"channel: attack_obs must be a dict with exactly the "
                f"keys {sorted(keys)} (AttackState.observation builds "
                f"it), got {got}")
        n = (self.payload.n_coords if self.form == "streamed"
             else self.payload.shape[1])
        for k in ("prev_vote", "prev_abs_counts"):
            if k in self.attack_obs:
                shape = tuple(np.shape(self.attack_obs[k]))
                if shape != (n,):
                    raise ValueError(
                        f"attack_obs[{k!r}] must have shape ({n},) "
                        f"aligned to the vote coordinates, got {shape}")
        if "rep" in self.attack_obs:
            shape = tuple(np.shape(self.attack_obs["rep"]))
            if self.form == "streamed":
                ids = self.payload.row_ids()
                need = int(ids[-1]) + 1 if ids.size else 1
            elif self.voter_ids is not None:
                ids = np.asarray(self.voter_ids)
                need = int(ids[-1]) + 1 if ids.size else 1
            else:
                need = self.payload.shape[0]
            if len(shape) != 1 or shape[0] < need:
                raise ValueError(
                    "attack_obs['rep'] must be a 1-D per-voter array "
                    f"covering every logical voter id (need >= {need} "
                    f"entries, got shape {shape}) — refit it on "
                    "rescale/churn like the flip-EMA "
                    "(AttackState.refit)")

    def _validate_plan(self):
        if self.plan is None:
            return
        plan = self.plan
        if self.form == "tree":
            names = {s.name for s in plan.leaves}
            keys = set(self.payload)
            if names != keys:
                raise ValueError(
                    "plan manifest and tree payload disagree: plan has "
                    f"{sorted(names - keys)} extra / misses "
                    f"{sorted(keys - names)}")
            for slot in plan.leaves:
                got = tuple(self.payload[slot.name].shape)
                if got != slot.shape:
                    raise ValueError(
                        f"leaf {slot.name!r} has shape {got}, plan "
                        f"manifest says {slot.shape}")
            return
        n = self.payload.shape[-1]
        if n != plan.n_params:
            raise ValueError(
                f"{self.form} payload has {n} coordinates, plan manifest "
                f"says {plan.n_params}")
        if self.form == "leaf" and len(self.payload.shape) != 1:
            raise ValueError(
                "a planned leaf payload is the flat (n_params,) buffer "
                f"in manifest order, got shape {tuple(self.payload.shape)}")

    def __repr__(self):  # payloads are arrays — keep the repr readable
        return (f"VoteRequest(form={self.form!r}, strategy="
                f"{self.strategy.value!r}, codec={self.codec!r}, "
                f"plan={'yes' if self.plan is not None else None}, "
                f"failures={self.failures}, salt={self.salt})")


# ---------------------------------------------------------------------------
# static wire accounting (the WireReport's bytes/messages half)
# ---------------------------------------------------------------------------


def _static_wire(plan, codec_name: str, resolved: Optional[VoteStrategy],
                 n_params: int, n_messages: int,
                 n_voters: int) -> WireReport:
    from repro.core import codecs as codecs_mod
    if plan is not None:
        payload = sum(
            g.total * codecs_mod.get_codec(g.codec).wire_bits(g.strategy)
            / 8.0 for g in plan.groups)
        strategies = {g.strategy for g in plan.groups}
        return WireReport(
            n_voters=n_voters, payload_bytes=payload,
            n_messages=plan.n_buckets,
            strategy=strategies.pop() if len(strategies) == 1 else None)
    if resolved is None or resolved == VoteStrategy.AUTO:
        # M=1 degenerate case: the vote is the local sign, no wire at all
        return WireReport(n_voters=n_voters, payload_bytes=0.0,
                          n_messages=0, strategy=None)
    c = codecs_mod.get_codec(codec_name)
    return WireReport(n_voters=n_voters,
                      payload_bytes=n_params * c.wire_bits(resolved) / 8.0,
                      n_messages=n_messages, strategy=resolved)


# ---------------------------------------------------------------------------
# in-region execution (absorbed from VoteEngine / fault_tolerance /
# vote_plan.plan_vote_signs — the mesh collectives path)
# ---------------------------------------------------------------------------


def _region_sizes(axes: Sequence[str]) -> Tuple[int, int]:
    data = compat.axis_size("data") if "data" in axes else 1
    pod = compat.axis_size("pod") if "pod" in axes else 1
    return data, pod


def _wire_vote_signs(signs: jax.Array, axes: Tuple[str, ...],
                     strategy: VoteStrategy, codec_name: str,
                     server_state):
    """int8 signs -> (int8 majority, new server state) over the manual
    `axes`, through the resolved strategy's stage methods and the
    codec's decode (the absorbed ``VoteEngine.vote_signs_codec``)."""
    from repro.core import codecs as codecs_mod
    from repro.core import vote_engine as ve
    c = codecs_mod.get_codec(codec_name)
    state = server_state if server_state is not None else {}
    if not axes:
        return signs, state
    data, pod = _region_sizes(axes)
    strat = ve.STRATEGIES[ve.resolve_strategy(strategy, signs.size, data,
                                              pod, codec=codec_name)]
    c.validate_strategy(strat.kind)
    if c.name == "ternary2bit" \
            and strat.kind == VoteStrategy.ALLGATHER_1BIT:
        from repro.core.codecs.ternary import TERNARY_WIRE
        return TERNARY_WIRE.vote(signs, axes), state
    if c.server_state:
        if not state:
            raise ValueError(
                f"codec {c.name!r} needs its server state threaded "
                "through the request (init_server_state)")
        from repro.core.codecs import weighted
        impl = ve.STRATEGIES[VoteStrategy.ALLGATHER_1BIT]
        m = ve.num_voters(axes)
        n = signs.shape[-1]
        arrived = impl.exchange(impl.pack(signs, m), axes)
        # crop the bit-pack padding lanes BEFORE decoding: padding
        # always agrees with the vote, so counting it would dilute
        # the flip-rate observations by n/32w
        stacked = sc.unpack_signs(arrived, jnp.int8)[..., :n]
        vote, new_ema = weighted.decode_stacked(stacked,
                                                state["flip_ema"])
        return vote, {**state, "flip_ema": new_ema}
    return strat.vote(signs, axes), state


def _plan_walk(plan, flat_signs: jax.Array, axes: Tuple[str, ...],
               server_state, overlap: bool = False):
    """The bucket-schedule walk (absorbed ``vote_plan.plan_vote_signs``,
    now the §11 executor's mesh wire): (n_params,) effective int8 signs
    -> ((n_params,) int8 votes, new server state). `overlap` selects the
    double-buffered issue order (bit-identical; see
    ``vote_plan.run_schedule``)."""
    from repro.core import vote_plan as vp
    if not axes:                     # M=1 degenerate case: vote = sign
        return flat_signs, dict(server_state) if server_state else {}
    return vp.run_schedule(plan, flat_signs, vp.MeshBucketWire(axes),
                           server_state, overlap=overlap)


def _leaf_execute(values: jax.Array, axes: Tuple[str, ...],
                  strategy: VoteStrategy, codec_name: str, plan,
                  byz: Optional[ByzantineConfig], salt: int, n_stale: int,
                  prev, step, server_state, overlap: bool = False,
                  obs=None):
    """One replica-local vote inside the manual region, with the full
    failure composition in the pinned order: stale substitution on the
    RAW payload (a straggling adversary corrupts its stale vector), sign
    extraction, the compiled adversary, then the wire (leaf-wise or the
    plan's bucket walk). Returns (votes in the payload dtype, state)."""
    from repro.distributed.fault_tolerance import (simulate_stragglers,
                                                   straggler_mask_for)
    axes = tuple(axes)
    if n_stale and prev is not None:
        mask = straggler_mask_for(axes, n_stale, like=values)
        values = simulate_stragglers(values, prev, mask)
    if plan is not None:
        signs = sc.sign_ternary(values)
        if byz is not None and axes:
            signs = byzantine.apply_adversary(signs, byz, axes, step=step,
                                              salt=salt, obs=obs)
        vote, new_state = _plan_walk(plan, signs, axes, server_state,
                                     overlap)
        return vote.astype(values.dtype), new_state
    shape = values.shape
    s = sc.sign_ternary(values if values.ndim else values.reshape(1))
    if byz is not None and axes:
        s = byzantine.apply_adversary(s, byz, axes, step=step, salt=salt,
                                      obs=obs)
    vote, new_state = _wire_vote_signs(s, axes, strategy, codec_name,
                                       server_state)
    return vote.reshape(shape).astype(values.dtype), new_state


# ---- tree execution (absorbed VoteEngine.vote_tree_codec /
# vote_plan.plan_tree_vote + the §7 diagnostics, computed once) ----------


def _tree_agreement(local: Dict, votes: Dict) -> jax.Array:
    """Fraction of coordinates where this replica's sign matches the
    vote."""
    num = sum(jnp.sum(sc.sign_ternary(l) == sc.sign_ternary(v))
              for l, v in zip(jax.tree.leaves(local),
                              jax.tree.leaves(votes)))
    den = sum(v.size for v in jax.tree.leaves(votes))
    return num / den


def _tree_margin(local: Dict, axes: Sequence[str],
                 byz: Optional[ByzantineConfig] = None,
                 step=None, salt: int = 0) -> jax.Array:
    """Mean |vote count| / M over all coordinates, measured on the signs
    that actually reach the wire (the compiled adversary re-applied with
    the same PRNG keys as the vote) — the §7 per-step margin."""
    from repro.core import vote_engine as ve
    leaves = jax.tree.leaves(local)
    m = ve.num_voters(axes) if axes else 1
    counts = []
    for l in leaves:
        s = sc.sign_ternary(l)
        if byz is not None and axes:
            s = byzantine.apply_adversary(s, byz, axes, step=step,
                                          salt=salt)
        if axes:
            counts.append(jax.lax.psum(s.astype(jnp.int32), tuple(axes)))
        else:
            counts.append(s.astype(jnp.int32))
    num = sum(jnp.sum(jnp.abs(c)) for c in counts)
    den = sum(l.size for l in leaves) * m
    return num / den


def _plan_tree_execute(plan, tree, axes: Tuple[str, ...],
                       byz: Optional[ByzantineConfig], step, salt: int,
                       server_state, diagnostics: bool,
                       overlap: bool = False):
    """The trainer's plan path (absorbed ``vote_plan.plan_tree_vote``):
    sign extraction per leaf, ONE flat buffer, the compiled adversary
    applied once to the whole wire buffer, then the bucket walk.
    Diagnostics are computed once over the flat buffer's true
    coordinates — the padded lanes the bucketed wire adds are never
    observed."""
    from repro.core import vote_engine as ve
    from repro.core import vote_plan as vp
    axes = tuple(axes)
    honest = vp.flatten_signs(plan, tree)
    eff = honest
    if byz is not None and axes:
        eff = byzantine.apply_adversary(eff, byz, axes, step=step,
                                        salt=salt)
    flat_votes, new_state = _plan_walk(plan, eff, axes, server_state,
                                       overlap)
    margin = agreement = None
    if diagnostics:
        m = ve.num_voters(axes) if axes else 1
        if axes:
            counts = jax.lax.psum(eff.astype(jnp.int32), axes)
        else:
            counts = eff.astype(jnp.int32)
        margin = jnp.sum(jnp.abs(counts)) / (plan.n_params * m)
        agreement = jnp.mean((honest == flat_votes).astype(jnp.float32))
    return (vp.unflatten_votes(plan, flat_votes, tree), new_state,
            margin, agreement)


def _tree_execute(tree, axes: Tuple[str, ...], strategy: VoteStrategy,
                  codec_name: str, byz: Optional[ByzantineConfig], step,
                  salt: int, server_state, diagnostics: bool):
    """Leaf-wise tree vote (absorbed ``VoteEngine.vote_tree_codec``).
    AUTO resolves once per tree on the total parameter count
    (codec-aware). Server-stateful codecs decode every leaf under this
    step's weights and fold ONE aggregate reliability update across the
    whole tree."""
    from repro.core import codecs as codecs_mod
    from repro.core import vote_engine as ve
    axes = tuple(axes)
    c = codecs_mod.get_codec(codec_name)
    resolved = strategy
    if strategy == VoteStrategy.AUTO and axes:
        total = sum(l.size for l in jax.tree.leaves(tree))
        data, pod = _region_sizes(axes)
        resolved = ve.select_strategy(total, data, pod, codec=codec_name)
    state = server_state if server_state is not None else {}
    if not c.server_state or not axes:
        votes = jax.tree.map(
            lambda leaf: _leaf_execute(leaf, axes, resolved, codec_name,
                                       None, byz, salt, 0, None, step,
                                       None)[0], tree)
        new_state = state
    else:
        # weighted decode with weights FIXED for the step, one EMA update
        c.validate_strategy(resolved)
        if not state:
            raise ValueError(
                f"codec {c.name!r} needs its server state threaded "
                "through the request (init_server_state)")
        from repro.core.codecs import weighted
        impl = ve.STRATEGIES[VoteStrategy.ALLGATHER_1BIT]
        m = ve.num_voters(axes)
        w = weighted.reliability_weights(state["flip_ema"])
        leaves, treedef = jax.tree.flatten(tree)
        out, mismatch, total_n = [], jnp.zeros_like(w), 0
        for leaf in leaves:
            shape = leaf.shape
            s = sc.sign_ternary(leaf if leaf.ndim else leaf.reshape(1))
            if byz is not None:
                s = byzantine.apply_adversary(s, byz, axes, step=step,
                                              salt=salt)
            n = s.shape[-1]
            arrived = impl.exchange(impl.pack(s, m), axes)
            # crop padding lanes before decoding (see _wire_vote_signs)
            stacked = sc.unpack_signs(arrived, jnp.int8)[..., :n]
            vote, mis = weighted.decode_leaf_fixed(stacked, w)
            mismatch = mismatch + mis
            total_n += stacked.size // stacked.shape[0]
            out.append(vote.reshape(shape).astype(leaf.dtype))
        new_ema = ((1.0 - weighted.RHO) * state["flip_ema"]
                   + weighted.RHO * mismatch / total_n)
        votes = jax.tree.unflatten(treedef, out)
        new_state = {**state, "flip_ema": new_ema}
    margin = agreement = None
    if diagnostics:
        agreement = _tree_agreement(tree, votes)
        margin = _tree_margin(tree, axes, byz, step, salt)
    return votes, new_state, margin, agreement, resolved


# ---------------------------------------------------------------------------
# virtualised execution (absorbed virtual_mesh.virtual_* — the exchange
# stage replaced by its exact host-side equivalent over a voter dim)
# ---------------------------------------------------------------------------


def effective_stacked_signs(values: jax.Array, prev=None, n_stale: int = 0,
                            byz: Optional[ByzantineConfig] = None,
                            step=None, salt: int = 0,
                            ids=None, obs=None) -> jax.Array:
    """The (M, n) int8 sign tensor that actually reaches the wire: sign
    extraction -> stale substitution (voter index < n_stale) -> adversary
    perturbation, in the pinned §7 order.

    ``ids`` (int32 (M,)) overrides the per-row voter index with logical
    population identities: both failure predicates and the adversary
    PRNG then depend on who each voter IS, not where its row landed, so
    a sampled or chunk-streamed round composes the same failures as the
    dense stack (default ``None`` = row position, the historical
    semantics)."""
    from repro.distributed.fault_tolerance import simulate_stragglers
    signs = sc.sign_ternary(values)
    m = signs.shape[0]
    idx = (jnp.arange(m, dtype=jnp.int32) if ids is None
           else jnp.asarray(ids).astype(jnp.int32))
    if n_stale and prev is not None:
        mask = (idx < n_stale)[:, None]
        signs = simulate_stragglers(signs, prev.astype(signs.dtype), mask)
    if byz is not None:
        signs = byzantine.apply_adversary_stacked(signs, byz, step=step,
                                                  salt=salt, ids=idx,
                                                  obs=obs)
    return signs


def _virtual_wire_vote(signs: jax.Array,
                       strategy: VoteStrategy) -> jax.Array:
    """(M, n) stacked int8 signs -> (n,) int8 majority, through the
    strategy's own pack/tally/unpack stages (exchange virtualised)."""
    from repro.core.vote_engine import STRATEGIES
    impl = STRATEGIES[strategy]
    m, n = signs.shape

    if strategy == VoteStrategy.PSUM_INT8:
        wire = impl.pack(signs, m)                       # (M, n) counts
        # psum over the vote axes == sum over the voter dim; the mesh op
        # accumulates in the wire dtype (safe: |sum| <= M <= dtype max)
        arrived = jnp.sum(wire, axis=0).astype(wire.dtype)
        return impl.unpack(impl.tally(arrived, m), n, jnp.int8)

    if strategy == VoteStrategy.ALLGATHER_1BIT:
        wire = impl.pack(signs, m)                       # (M, w) packed
        # the all-gather hands every replica the stacked wire — which is
        # exactly what the virtual mesh already holds
        return impl.unpack(impl.tally(wire, m), n, jnp.int8)

    if strategy == VoteStrategy.HIERARCHICAL:
        # virtual single-pod mesh: data axis = all M voters, no pod axis.
        # Mirrors HierarchicalStrategy.vote: pad to PACK * dsize so the
        # reduce-scatter shards stay word-aligned.
        padded, _ = pad_last(signs, sc.PACK * m)
        wire = impl.pack(padded, m)                      # (M, n_pad) counts
        # psum_scatter(tiled) over 'data': shard r of the summed counts
        summed = jnp.sum(wire, axis=0).astype(wire.dtype)
        shards = summed.reshape(m, padded.shape[-1] // m)
        decision = impl.tally(shards, m)                 # sign_binary/shard
        # unpack stage: pack each shard's decision, all-gather (tiled) the
        # packed words across 'data' = concatenate in replica order
        packed = sc.pack_signs(decision).reshape(-1)
        return sc.unpack_signs(packed, jnp.int8)[:n]

    raise ValueError(f"virtual mesh cannot realise {strategy!r}")


def _virtual_codec_vote(signs: jax.Array, strategy: VoteStrategy,
                        codec: str, server_state):
    """(M, n) stacked int8 signs -> ((n,) int8 majority, new server
    state) through the codec's wire stages, exchange virtualised."""
    state = server_state if server_state is not None else {}
    m, n = signs.shape

    if codec in ("sign1bit", "ef_sign"):
        # identical wire to the plain majority: only the encode input
        # (caller-side) differs
        return _virtual_wire_vote(signs, strategy), state

    if codec == "ternary2bit":
        if strategy == VoteStrategy.PSUM_INT8:
            # ternary symbols ARE the counts psum already sums
            return _virtual_wire_vote(signs, strategy), state
        from repro.core.codecs.ternary import TERNARY_WIRE
        wire = TERNARY_WIRE.pack(signs, m)       # (M, w) 2-bit packed
        return TERNARY_WIRE.unpack(TERNARY_WIRE.tally(wire, m), n,
                                   jnp.int8), state

    if codec == "weighted_vote":
        from repro.core.codecs import weighted
        from repro.core.vote_engine import STRATEGIES
        impl = STRATEGIES[VoteStrategy.ALLGATHER_1BIT]
        wire = impl.pack(signs, m)               # (M, w) 1-bit packed
        # crop the padding lanes before decoding, exactly like the mesh
        # tally: padding always agrees with the vote and would dilute
        # the flip-rate observations
        stacked = sc.unpack_signs(wire, jnp.int8)[:, :n]
        vote, new_ema = weighted.decode_stacked(stacked,
                                                state["flip_ema"])
        return vote, {**state, "flip_ema": new_ema}

    raise ValueError(f"virtual mesh cannot realise codec {codec!r}")


def _virtual_plan_walk(signs: jax.Array, plan, server_state,
                       overlap: bool = False):
    """(M, n_params) stacked int8 signs -> ((n_params,) int8 votes, new
    server state) through the plan's bucket schedule, exchange
    virtualised per bucket (the §11 executor's virtual wire) — the SAME
    static schedule the mesh walk drives, so plan drills hold mesh ==
    virtual bit-identity under either issue order."""
    from repro.core import vote_plan as vp
    m, n = signs.shape
    if n != plan.n_params:
        raise ValueError(f"stacked buffer has {n} coords, plan manifest "
                         f"says {plan.n_params}")
    return vp.run_schedule(plan, signs, vp.VirtualBucketWire(m),
                           server_state, overlap=overlap)


@functools.partial(jax.jit, static_argnames=("strategy", "codec", "plan",
                                             "n_stale", "byz", "salt",
                                             "overlap"))
def _virtual_execute(values, prev, step, server_state, attack_obs, *,
                     strategy, codec, plan, n_stale, byz, salt, overlap):
    # attack_obs is TRACED (the adaptive observation changes every
    # round; baking it static would recompile per step)
    eff = effective_stacked_signs(values, prev, n_stale, byz, step, salt,
                                  obs=attack_obs)
    if plan is not None:
        votes, state = _virtual_plan_walk(eff, plan, server_state, overlap)
    else:
        votes, state = _virtual_codec_vote(eff, strategy, codec,
                                           server_state)
    return votes, state, eff


# ---------------------------------------------------------------------------
# the backends
# ---------------------------------------------------------------------------


class VoteBackend(abc.ABC):
    """Executes :class:`VoteRequest`\\ s. Exactly two implementations
    exist — :class:`MeshBackend` (the real collectives) and
    :class:`VirtualBackend` (host-side exchange equivalents) — and the
    tier-2 harness proves them bit-identical on the same requests."""

    name: str = "?"

    def supports(self, request: VoteRequest) -> bool:
        """Capability introspection: can this backend execute the
        (already-validated) request?"""
        return self.why_unsupported(request) is None

    @abc.abstractmethod
    def why_unsupported(self, request: VoteRequest) -> Optional[str]:
        """None if supported, else an actionable reason."""

    def execute(self, request: VoteRequest) -> VoteOutcome:
        """Run the vote; raises ValueError (with the
        :meth:`why_unsupported` reason) on unsupported requests.

        Concrete template (DESIGN.md §13): capability check, the
        backend's :meth:`_execute`, then telemetry — a ``vote.execute``
        span when a recorder is active, and the exact wire counters
        (``vote.requests`` / ``vote.wire.bytes`` / ``vote.wire.
        messages``) from the outcome's once-computed WireReport,
        always. Both backends emit identical counter values for the
        same request because both count the SAME static report (the
        tier-2 obs drill asserts it). Under ``jit`` the increments run
        at trace time — once per compilation, the `kernels.ops`
        launch-count semantics."""
        self._check(request)
        rec = obs.get_recorder()
        if rec.enabled:
            with rec.span("vote.execute", backend=self.name,
                          form=request.form, codec=request.codec):
                out = self._execute(request)
        else:
            out = self._execute(request)
        c = obs.COUNTERS
        c.inc("vote.requests")
        c.inc("vote.wire.bytes", int(round(out.wire.payload_bytes)))
        c.inc("vote.wire.messages", out.wire.n_messages)
        return out

    @abc.abstractmethod
    def _execute(self, request: VoteRequest) -> VoteOutcome:
        """The backend's execution body (request already validated)."""

    def _check(self, request: VoteRequest) -> None:
        why = self.why_unsupported(request)
        if why is not None:
            raise ValueError(f"{self.name} backend cannot execute this "
                             f"request: {why}")


class MeshBackend(VoteBackend):
    """The real shard_map path.

    * ``leaf`` / ``tree`` requests execute **inside** an existing manual
      mesh region over `axes` (the trainer's configuration — construct
      with ``MeshBackend(axes=art.vote_axes)``); empty axes is the M=1
      single-process degenerate case.
    * ``stacked`` requests build the ``shard_map`` themselves: an M-wide
      'data' mesh over the first M local devices (`mesh_style` picks the
      trainer's partial-auto ``(M, 1)`` layout or a fully-manual ``(M,)``
      one), inputs round-tripped through numpy so outputs stay
      uncommitted when mesh sizes alternate in one process (elastic
      drills). Compiled executables are cached per static request
      configuration.
    """

    name = "mesh"

    def __init__(self, axes: Optional[Sequence[str]] = None,
                 mesh_style: str = "data_model"):
        if mesh_style not in MESH_STYLES:
            raise ValueError(f"unknown mesh_style {mesh_style!r}; "
                             f"have {MESH_STYLES}")
        self.axes = tuple(axes) if axes is not None else None
        self.mesh_style = mesh_style
        self._cache: Dict[Any, Any] = {}

    # ---- capability ----------------------------------------------------

    def why_unsupported(self, request: VoteRequest) -> Optional[str]:
        if request.form == "streamed":
            return ("the streamed population form virtualises more "
                    "voters than any physical mesh holds replicas; use "
                    "VirtualBackend")
        if request.voter_ids is not None or request.weights is not None:
            return ("logical voter ids / dataset-size vote weights "
                    "describe a virtual population; the mesh backend's "
                    "voters are physical replicas (use VirtualBackend)")
        if request.form == "stacked":
            m = request.payload.shape[0]
            have = len(jax.devices())
            if m > have:
                return (f"stacked execution needs {m} devices for "
                        f"{m} voters, have {have} (use VirtualBackend, "
                        "or XLA_FLAGS="
                        "--xla_force_host_platform_device_count=N)")
            return None
        if self.axes is None:
            return (f"{request.form}-form requests run inside a manual "
                    "mesh region; construct MeshBackend(axes=...) with "
                    "the vote axes")
        return None

    # ---- execution -----------------------------------------------------

    def _execute(self, request: VoteRequest) -> VoteOutcome:
        if request.form == "stacked":
            return self._execute_stacked(request)
        if request.form == "tree":
            return self._execute_tree(request)
        return self._execute_leaf(request)

    def _execute_leaf(self, req: VoteRequest) -> VoteOutcome:
        f = req.failures
        votes, state = _leaf_execute(
            req.payload, self.axes, req.strategy, req.codec, req.plan,
            f.byz, req.salt, f.n_stale, req.prev, req.step,
            req.server_state, req.overlap)
        from repro.core import vote_engine as ve
        if self.axes:
            data, pod = _region_sizes(self.axes)
            resolved = (None if req.plan is not None else
                        ve.resolve_strategy(req.strategy,
                                            req.payload.size, data, pod,
                                            codec=req.codec))
            n_voters = data * pod
        else:
            resolved, n_voters = None, 1
        wire = _static_wire(req.plan, req.codec, resolved,
                            req.payload.size, 1, n_voters)
        return VoteOutcome(votes=votes, server_state=state, wire=wire)

    def _execute_tree(self, req: VoteRequest) -> VoteOutcome:
        from repro.core import vote_engine as ve
        f = req.failures
        if req.plan is not None:
            votes, state, margin, agreement = _plan_tree_execute(
                req.plan, req.payload, self.axes, f.byz, req.step,
                req.salt, req.server_state, req.diagnostics, req.overlap)
            resolved = None
        else:
            votes, state, margin, agreement, resolved = _tree_execute(
                req.payload, self.axes, req.strategy, req.codec, f.byz,
                req.step, req.salt, req.server_state, req.diagnostics)
        if self.axes:
            data, pod = _region_sizes(self.axes)
            n_voters = data * pod
        else:
            n_voters, resolved = 1, None
        total = sum(l.size for l in jax.tree.leaves(req.payload))
        wire = _static_wire(req.plan, req.codec, resolved, total,
                            len(jax.tree.leaves(req.payload)), n_voters)
        wire = dataclasses.replace(wire, margin=margin,
                                   agreement=agreement)
        return VoteOutcome(votes=votes, server_state=state, wire=wire)

    # ---- stacked: the self-built shard_map (absorbed from the Scenario
    # Lab's mesh vote path) ----------------------------------------------

    def _stacked_fn(self, m: int, strategy: VoteStrategy, codec: str,
                    plan, byz, salt: int, n_stale: int, stateful: bool,
                    has_prev: bool, has_step: bool,
                    overlap: bool = False):
        key = (m, strategy, codec, plan, byz, salt, n_stale, stateful,
               has_prev, has_step, overlap)
        if key in self._cache:
            return self._cache[key]
        from jax.sharding import Mesh, PartitionSpec as P
        devs = np.array(jax.devices()[:m])
        if self.mesh_style == "data_model":
            mesh = Mesh(devs.reshape(m, 1), ("data", "model"))
        else:
            mesh = Mesh(devs, ("data",))
        manual = {"data"}
        axes = ("data",)

        # the adaptive observation dict rides as one more (replicated,
        # P()-spec) input — an empty dict for oblivious requests, so the
        # arity is uniform and jit's pytree structure separates the two
        def body(vals, prev, step, cstate, aobs):
            out, new_state = _leaf_execute(
                vals[0], axes, strategy, codec, plan, byz, salt, n_stale,
                prev[0] if has_prev else None,
                step if has_step else None, cstate, overlap,
                obs=aobs if aobs else None)
            return out[None], new_state

        # arity/specs vary with the static request shape; every variant
        # funnels into the same `body`
        if stateful:
            def f(vals, prev, step, cstate, aobs):
                return body(vals, prev, step, cstate, aobs)
            in_specs = (P("data"), P("data") if has_prev else P(),
                        P(), P(), P())
            out_specs = (P("data"), P())
        else:
            def f(vals, prev, step, aobs):
                return body(vals, prev, step, {}, aobs)[0]
            in_specs = (P("data"), P("data") if has_prev else P(), P(),
                        P())
            out_specs = P("data")
        sh = compat.shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, axis_names=manual,
                              check_vma=False)
        fn = jax.jit(sh)
        self._cache[key] = fn
        return fn

    def _execute_stacked(self, req: VoteRequest) -> VoteOutcome:
        from repro.core import vote_engine as ve
        m, n = req.payload.shape
        f = req.failures
        stateful = bool(req.server_state)
        has_prev = req.prev is not None
        has_step = req.step is not None
        fn = self._stacked_fn(m, req.strategy, req.codec, req.plan,
                              f.byz, req.salt, f.n_stale, stateful,
                              has_prev, has_step, req.overlap)
        # host round-trips keep every array uncommitted: jit outputs
        # committed to one request's mesh devices would conflict with a
        # later (smaller) mesh in the same process (elastic drills)
        vals = np.asarray(req.payload)
        prev = np.asarray(req.prev) if has_prev else np.zeros((), np.int8)
        step = (np.asarray(req.step) if has_step
                else np.zeros((), np.int32))
        aobs = ({} if req.attack_obs is None else
                {k: np.asarray(a) for k, a in req.attack_obs.items()})
        if stateful:
            out, new_state = fn(vals, prev, step,
                                {k: np.asarray(a)
                                 for k, a in req.server_state.items()},
                                aobs)
            state = {k: jnp.asarray(np.asarray(a))
                     for k, a in new_state.items()}
        else:
            out = fn(vals, prev, step, aobs)
            state = dict(req.server_state or {})
        votes = jnp.asarray(np.asarray(out)[0].astype(np.int8))
        resolved = (None if req.plan is not None else
                    ve.resolve_strategy(req.strategy, n, m, 1,
                                        codec=req.codec))
        wire = _static_wire(req.plan, req.codec, resolved, n, 1, m)
        return VoteOutcome(votes=votes, server_state=state, wire=wire)


class VirtualBackend(VoteBackend):
    """The host-count-independent backend: ``stacked`` and ``streamed``
    requests only, exchange collectives replaced by their
    mathematically-exact equivalents over the voter dim (DESIGN.md §7).
    Bit-identical to :class:`MeshBackend` on the same request — asserted
    by the tier-2 harness and the hypothesis property suite.

    ``streamed`` requests run the §12 population engine: the stacked
    exchange in voter-chunks of ``chunk_size`` rows (chunk -> pack ->
    partial tally accumulate, exact integer arithmetic), peak sign
    memory O(chunk_size x n) instead of O(M x n), bit-identical to the
    dense stacked path by construction.

    ``use_kernels=True`` routes plain gathered-1-bit requests through
    the fused Pallas sign+pack+popcount kernel (the benchmark hot path);
    anything the kernel cannot realise (count-wire tie semantics,
    failure composition, server state, plans) is rejected rather than
    silently mis-decoded."""

    name = "virtual"

    def __init__(self, use_kernels: bool = False, chunk_size: int = 2048):
        self.use_kernels = use_kernels
        self.chunk_size = int(chunk_size)
        if self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")

    def why_unsupported(self, request: VoteRequest) -> Optional[str]:
        if request.form not in ("stacked", "streamed"):
            return ("the virtual backend executes host-local stacked "
                    f"(M, n) payloads or streamed populations, not "
                    f"{request.form!r} (use MeshBackend inside the mesh "
                    "region)")
        if request.form == "streamed":
            if self.use_kernels:
                return ("the fused-kernel path consumes one dense (M, n) "
                        "buffer; the streamed population engine exists "
                        "to never materialize it (use "
                        "VirtualBackend(use_kernels=False))")
            if request.strategy == VoteStrategy.HIERARCHICAL:
                return ("hierarchical's reduce-scatter wire pads to "
                        "PACK*M words — O(M) layout the streamed engine "
                        "exists to avoid; use psum_int8 or "
                        "allgather_1bit")
            return None
        if self.use_kernels:
            if request.overlap:
                return ("the fused-kernel path runs one fused launch per "
                        "request and cannot double-buffer a bucket "
                        "schedule (overlap=True); use "
                        "VirtualBackend(use_kernels=False)")
            if request.plan is not None:
                return ("the fused-kernel path has no bucket walk; use "
                        "vote_plan.plan_vote_stacked or "
                        "VirtualBackend(use_kernels=False)")
            if request.codec != "sign1bit":
                return ("the fused kernel realises the raw 1-bit wire "
                        f"only, not codec {request.codec!r}")
            if request.strategy != VoteStrategy.ALLGATHER_1BIT:
                return ("the fused kernel's binary majority (ties -> +1) "
                        "is allgather_1bit's tie rule, not "
                        f"{request.strategy.value!r}'s")
            if request.failures.active:
                return ("the fused kernel consumes raw voter values; "
                        "compose failures via "
                        "VirtualBackend(use_kernels=False)")
        return None

    def _execute(self, request: VoteRequest) -> VoteOutcome:
        req = request
        if req.form == "streamed":
            return self._execute_streamed(req)
        if req.voter_ids is not None or req.weights is not None:
            return self._execute_annotated(req)
        m, n = req.payload.shape
        eff = None
        if self.use_kernels:
            from repro.kernels import ops
            packed = ops.fused_majority(req.payload)
            votes = ops.bitunpack(packed, n, jnp.int8)
            state = dict(req.server_state or {})
            resolved = VoteStrategy.ALLGATHER_1BIT
        else:
            from repro.core import vote_engine as ve
            resolved = (None if req.plan is not None else
                        ve.resolve_strategy(req.strategy, n, m, 1,
                                            codec=req.codec))
            f = req.failures
            votes, state, eff = _virtual_execute(
                req.payload, req.prev, req.step, req.server_state,
                req.attack_obs,
                strategy=resolved, codec=req.codec, plan=req.plan,
                n_stale=f.n_stale, byz=f.byz, salt=req.salt,
                overlap=req.overlap)
        wire = _static_wire(req.plan, req.codec, resolved, n, 1, m)
        return VoteOutcome(votes=votes, server_state=state, wire=wire,
                           wire_signs=eff)

    def _execute_annotated(self, req: VoteRequest) -> VoteOutcome:
        """A stacked payload annotated with voter_ids/weights — the
        dense twin of a streamed request. Executes through the SAME
        population engine (one chunk spanning all M rows), so the
        chunked and dense decodes share one implementation and cannot
        drift: bit-identity is by construction, not by parallel
        maintenance of two float decode paths."""
        from repro.core import population
        m, n = req.payload.shape
        payload = jnp.asarray(req.payload)
        ids_np = (np.asarray(req.voter_ids, dtype=np.int32)
                  if req.voter_ids is not None
                  else np.arange(m, dtype=np.int32))
        ids_j = jnp.asarray(ids_np)

        def rows(ids):   # logical ids -> payload rows (ids_np sorted)
            return payload[jnp.searchsorted(ids_j, ids)]

        prev = None
        if req.prev is not None:
            prev_j = jnp.asarray(req.prev)
            prev = lambda ids: prev_j[jnp.searchsorted(ids_j, ids)]
        stream = PopulationStream(
            n_voters=m, n_coords=n, values=rows,
            ids=ids_np if req.voter_ids is not None else None,
            prev=prev,
            weights=(None if req.weights is None
                     else np.asarray(req.weights)))
        out = self._execute_stream_request(req, stream, chunk_size=m)
        # one more pass for the wire signs (dense M is small by
        # definition — the streamed form exists for the large-M case)
        f = req.failures
        eff = population._chunk_signs(stream, ids_np, req.step,
                                      f.n_stale, f.byz, req.salt,
                                      obs=req.attack_obs)
        return dataclasses.replace(out, wire_signs=eff)

    def _execute_streamed(self, req: VoteRequest) -> VoteOutcome:
        return self._execute_stream_request(req, req.payload,
                                            chunk_size=self.chunk_size)

    def _execute_stream_request(self, req: VoteRequest, stream,
                                chunk_size: int) -> VoteOutcome:
        from repro.core import population
        from repro.core import vote_engine as ve
        m, n = stream.n_voters, stream.n_coords
        resolved = ve.resolve_strategy(req.strategy, n, m, 1,
                                       codec=req.codec)
        f = req.failures
        votes, state, margin, counts = population.streamed_vote(
            stream, strategy=resolved, codec=req.codec,
            n_stale=f.n_stale, byz=f.byz, step=req.step, salt=req.salt,
            server_state=req.server_state, chunk_size=chunk_size,
            attack_obs=req.attack_obs)
        wire = _static_wire(req.plan, req.codec, resolved, n, 1, m)
        wire = dataclasses.replace(wire, margin=margin)
        return VoteOutcome(votes=votes, server_state=state, wire=wire,
                           counts=counts)


__all__ = [
    "FailureSpec", "MeshBackend", "PopulationStream", "VirtualBackend",
    "VoteBackend", "VoteOutcome", "VoteRequest", "WireReport",
    "count_dtype", "count_bytes", "effective_stacked_signs", "pad_last",
    "warn_legacy",
]
