"""qwen3-moe-235b-a22b — MoE with 128 routed experts, top-8, no shared.

[hf:Qwen/Qwen3-30B-A3B family; hf]  94L d_model=4096 64H (GQA kv=4)
expert d_ff=1536 vocab=151936.
"""
from repro.configs.base import SKIP_LONG, ArchFamily, ModelConfig, MoEConfig, register


@register("qwen3-moe-235b-a22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family=ArchFamily.MOE,
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=151_936,
        head_dim=128,
        moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=1536),
        tie_embeddings=False,
        act_seq_shard=True,
        skip_shapes=(SKIP_LONG,),
    )
