"""tier-2 suite: the Scenario Lab's regression lane (DESIGN.md §7).

Everything under tests/tier2/ carries the ``tier2`` marker automatically,
so ``pytest -m tier2`` selects exactly this lane (scripts/ci.sh runs it as
its own stage) while the plain tier-1 invocation still includes it.
"""
import os

import pytest

_HERE = os.path.abspath(os.path.dirname(__file__))


def pytest_collection_modifyitems(items):
    # this hook sees the whole session's items; mark only this directory's
    for item in items:
        if str(item.fspath).startswith(_HERE):
            item.add_marker(pytest.mark.tier2)
