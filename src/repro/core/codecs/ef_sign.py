"""``ef_sign`` — error-feedback sign compression (EF-signSGD family).

Sign compression is biased: the magnitude information it discards never
re-enters the update, which is what breaks plain signSGD on adversarially
scaled coordinates (Karimireddy et al., 2019). Error feedback fixes it
with one per-worker residual: fold the last step's compression error into
this step's encode input, so discarded magnitude accumulates until it
flips a sign and eventually gets through.

Per worker, with `v` the momentum (or gradient) and `e` the residual:

    t      = v + e                        (encode input)
    wire   = sign(t)                      (same 1-bit symbols as sign1bit)
    e'     = t - mean|t| * vote           (residual vs what was APPLIED)

The residual is measured against the *decoded vote*, not the local sign —
the update every worker actually applies — which is this repo's EF-sign
variant (DESIGN.md §3, now §8): the memory absorbs both the local
compression error and the vote's disagreement with the local direction.

The wire is bit-identical to ``sign1bit`` (only the encode input
differs), so every strategy transports it and the decode is the plain
majority. Worker state `e` is momentum-shaped, lives beside the momentum
in the optimizer state under the existing ``"error"`` key, and refits
across elastic rescale by ``checkpoint.refit_leading_axis`` (§6).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import VoteStrategy
from repro.core.codecs.base import GradientCodec


class EFSignCodec(GradientCodec):
    name = "ef_sign"
    bits_per_param = 1.0
    supported_strategies = (VoteStrategy.PSUM_INT8,
                            VoteStrategy.ALLGATHER_1BIT,
                            VoteStrategy.HIERARCHICAL)
    worker_state = True

    def init_state(self, values: jax.Array) -> jax.Array:
        return jnp.zeros(values.shape, values.dtype)

    def encode_leaf(self, values: jax.Array,
                    state: Optional[jax.Array]) -> jax.Array:
        if state is None:
            return values
        return state + values

    def feedback_leaf(self, encoded: jax.Array, vote: jax.Array,
                      state: Optional[jax.Array]) -> jax.Array:
        # scale = mean|t| per worker: the 1-bit symbol carries no
        # magnitude, so the residual prices the vote at the tensor's own
        # mean amplitude (the signum.py EF rule, unchanged)
        scale = jnp.mean(jnp.abs(encoded))
        return encoded - scale * vote.astype(encoded.dtype)
