"""Shared neural-net layers: norms, RoPE, GQA attention, SwiGLU MLP.

All functions are pure; parameters arrive as individual arrays (slices of
the flat stacked-parameter dict). Compute dtype follows the inputs
(bf16 by default); softmax and normalization statistics run in fp32.

Attention is exact but *query-chunked*: for long sequences the score
matrix is materialised only ``(B, H, chunk, T)`` at a time (lax.scan over
query chunks, each chunk rematerialised in the backward pass), which keeps
peak memory linear in ``T`` per chunk — the pure-JAX analogue of
memory-efficient attention. Supports causal, sliding-window (gemma3),
bidirectional (whisper encoder) and single-token decode-vs-cache paths.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.distributed.sharding import BATCH, shard

Q_CHUNK = 1024  # query-chunk size for long-sequence attention


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def _row_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """sum(a*b) over the last dim with fp32 accumulation, WITHOUT operand
    promotion (jnp.einsum's VJP upcasts operands to fp32, materialising
    full-stream fp32 copies — measured 6x (B,S,d) fp32 buffers per layer at
    deepseek-67b scale; lax.dot_general keeps operands bf16)."""
    nd = a.ndim - 1
    dims = (((nd,), (nd,)), (tuple(range(nd)), tuple(range(nd))))
    return jax.lax.dot_general(a, b, dims,
                               preferred_element_type=jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with an explicitly bf16 backward.

    Statistics (sum-of-squares, per-position inv-rms) accumulate in fp32;
    every stream-sized tensor in forward AND backward stays in the input
    dtype. The naive formulation's VJP drags fp32 copies of the residual
    stream through every layer.
    """
    return _rms_fwd(x, scale, eps)[0]


def _rms_fwd(x, scale, eps):
    inv = jax.lax.rsqrt(_row_dot(x, x) / x.shape[-1] + eps)   # (B,S) f32
    y = x * inv.astype(x.dtype)[..., None] * scale.astype(x.dtype)
    return y, (x, scale, inv)


def _rms_bwd(eps, res, g):
    x, scale, inv = res
    d = x.shape[-1]
    invb = inv.astype(x.dtype)[..., None]
    t = g * scale.astype(x.dtype)                              # bf16 stream
    m = _row_dot(x, t) / d                                     # (B,S) f32
    coef = (m * inv ** 3).astype(x.dtype)[..., None]
    dx = t * invb - x * coef
    dscale = jnp.sum((g * x * invb).astype(jnp.float32),
                     axis=tuple(range(x.ndim - 1))).astype(scale.dtype)
    return dx, dscale


rms_norm.defvjp(_rms_fwd, _rms_bwd)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float
                 ) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) int32 -> cos/sin (..., head_dim//2) fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, D); cos/sin (..., S, D//2) broadcast over heads."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(dtype)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings; positions (...,) int."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10_000.0) / max(half - 1, 1)))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# ---------------------------------------------------------------------------
# attention core
# ---------------------------------------------------------------------------


def _attn_form(num_heads: int, num_kv: int) -> str:
    """How to keep attention sharded over 'model' (GQA reshape (H)->(K,G)
    breaks head sharding whenever K doesn't divide the axis — measured as
    a 16 GiB all-heads score gather per q-chunk per layer on deepseek
    prefill, 45.6 TB/chip/step):

      grouped — K divides the axis: shard kv heads (zamba2, qwen2-moe);
      repeat  — H divides but K doesn't: repeat KV to H heads, shard H
                (deepseek, glm4, gemma3, pixtral, qwen3);
      seq     — neither divides (qwen1.5 H=40, whisper H=6): shard the
                query-chunk dim of the scores instead.
    """
    from repro.distributed.sharding import mesh_axis_size
    m = mesh_axis_size("model")
    if m <= 1 or num_kv % m == 0:
        return "grouped"
    if num_heads % m == 0:
        return "repeat"
    return "seq"


def _scores_softmax_out(q: jax.Array, k: jax.Array, v: jax.Array,
                        mask: Optional[jax.Array], scale: float,
                        form: str = "grouped") -> jax.Array:
    """q (B,S,K,G,D), k/v (B,T,K,D), mask broadcastable to (B,K,G,S,T)."""
    if form == "grouped":
        scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                            preferred_element_type=jnp.float32) * scale
        scores = shard(scores, None, "model", None, None, None)
        if mask is not None:
            scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
        probs = jax.nn.softmax(scores, axis=-1)
        probs = shard(probs, None, "model", None, None, None)
        out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
        return out
    # repeat / seq forms: flatten to (B,S,H,D) with KV repeated per group
    B, S, K, G, D = q.shape
    qh = q.reshape(B, S, K * G, D)
    kh = jnp.repeat(k, G, axis=2) if G > 1 else k
    vh = jnp.repeat(v, G, axis=2) if G > 1 else v
    spec = ((None, "model", None, None) if form == "repeat"
            else (None, None, "model", None))   # shard the q-chunk rows
    scores = jnp.einsum("bshd,bthd->bhst", qh, kh,
                        preferred_element_type=jnp.float32) * scale
    scores = shard(scores, *spec)
    if mask is not None:
        # mask arrives as (..,K,G,S,T) or broadcastable; flatten head dims
        m = jnp.broadcast_to(mask, mask.shape)
        if m.ndim == 5:
            m = m.reshape(m.shape[0], -1, m.shape[3], m.shape[4])
        scores = jnp.where(m, scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = shard(probs, *spec)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(vh.dtype), vh)
    return out.reshape(B, S, K, G, D)


def _causal_window_mask(q_pos: jax.Array, kv_pos: jax.Array,
                        window: Optional[jax.Array]) -> jax.Array:
    """(S,T) bool; window None => plain causal, else sliding window.

    `window` may be a traced scalar (per-layer local/global selection under
    a layer scan)."""
    rel = q_pos[:, None] - kv_pos[None, :]
    mask = rel >= 0
    if window is not None:
        mask = mask & (rel < window)
    return mask


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool,
              q_positions: Optional[jax.Array] = None,
              kv_positions: Optional[jax.Array] = None,
              window: Optional[jax.Array] = None,
              kv_valid_len: Optional[jax.Array] = None,
              q_chunk: int = Q_CHUNK) -> jax.Array:
    """Exact attention with GQA grouping and query chunking.

    q: (B, S, H, D); k/v: (B, T, K, D) with H = K * G.
    Returns (B, S, H, D).
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = D ** -0.5
    qg = q.reshape(B, S, K, G, D)
    form = _attn_form(H, K)

    if q_positions is None:
        q_positions = jnp.arange(S)
    if kv_positions is None:
        kv_positions = jnp.arange(T)

    def mask_for(qpos: jax.Array) -> Optional[jax.Array]:
        m = None
        if causal:
            m = _causal_window_mask(qpos, kv_positions, window)
        if kv_valid_len is not None:
            valid = kv_positions[None, :] < kv_valid_len[:, None]  # (B,T)
            valid = valid[:, None, None, None, :]
            m = valid if m is None else (m[None, None, None] & valid)
        if m is not None and m.ndim == 2:
            m = m[None, None, None]  # (1,1,1,S,T)
        return m

    if S <= max(q_chunk, 1) or S % q_chunk != 0:
        out = _scores_softmax_out(qg, k, v, mask_for(q_positions), scale,
                                  form)
        return out.reshape(B, S, H, D)

    # --- chunked path: scan over query chunks, remat each chunk ---
    n_chunks = S // q_chunk
    qg_c = qg.reshape(B, n_chunks, q_chunk, K, G, D)
    qpos_c = q_positions.reshape(n_chunks, q_chunk)

    @jax.checkpoint
    def body(carry, xs):
        q_i, qpos_i = xs
        o = _scores_softmax_out(q_i, k, v, mask_for(qpos_i), scale, form)
        return carry, o

    _, out_c = jax.lax.scan(
        body, None, (jnp.moveaxis(qg_c, 1, 0), qpos_c))
    out = jnp.moveaxis(out_c, 0, 1).reshape(B, S, H, D)
    return out


# ---------------------------------------------------------------------------
# attention block (projection + rope + core + output)
# ---------------------------------------------------------------------------


def attn_project_qkv(p: dict, prefix: str, x: jax.Array, num_heads: int,
                     num_kv_heads: int, head_dim: int, *, bias: bool
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    q = x @ p[f"{prefix}_wq"]
    k = x @ p[f"{prefix}_wk"]
    v = x @ p[f"{prefix}_wv"]
    if bias:
        q = q + p[f"{prefix}_bq"]
        k = k + p[f"{prefix}_bk"]
        v = v + p[f"{prefix}_bv"]
    q = q.reshape(B, S, num_heads, head_dim)
    k = k.reshape(B, S, num_kv_heads, head_dim)
    v = v.reshape(B, S, num_kv_heads, head_dim)
    return q, k, v


def self_attention_block(
    p: dict, prefix: str, x: jax.Array, cfg, *,
    causal: bool = True,
    window: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    use_rope: bool = True,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full self-attention sublayer (no residual). Returns (out, (k, v))."""
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q, k, v = attn_project_qkv(p, prefix, x, H, K, hd, bias=cfg.qkv_bias)
    q = shard(q, BATCH, None, "model", None)
    k = shard(k, BATCH, None, None, None)
    v = shard(v, BATCH, None, None, None)
    if positions is None:
        positions = jnp.arange(S)
    if use_rope and cfg.rope_theta:
        cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    out = attention(q, k, v, causal=causal,
                    q_positions=positions, kv_positions=positions,
                    window=window)
    out = shard(out, BATCH, None, "model", None)
    out = out.reshape(B, S, H * hd) @ p[f"{prefix}_wo"]
    return out, (k, v)


def cross_attention_block(p: dict, prefix: str, x: jax.Array,
                          k: jax.Array, v: jax.Array, cfg) -> jax.Array:
    """Cross-attention against precomputed encoder k/v (whisper)."""
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    q = x @ p[f"{prefix}_wq"]
    if cfg.qkv_bias:
        q = q + p[f"{prefix}_bq"]
    q = q.reshape(B, S, H, hd)
    out = attention(q, k, v, causal=False)
    return out.reshape(B, S, H * hd) @ p[f"{prefix}_wo"]


def project_kv_cross(p: dict, prefix: str, enc: jax.Array, cfg
                     ) -> Tuple[jax.Array, jax.Array]:
    B, T, _ = enc.shape
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = enc @ p[f"{prefix}_wk"]
    v = enc @ p[f"{prefix}_wv"]
    if cfg.qkv_bias:
        k = k + p[f"{prefix}_bk"]
        v = v + p[f"{prefix}_bv"]
    return k.reshape(B, T, K, hd), v.reshape(B, T, K, hd)


# --- decode path (single new token against a cache) -----------------------

KV_CHUNK = 4096  # online-softmax chunk for long / quantized caches


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(..., hd) bf16 -> (int8 values, (...,) bf16 scale), symmetric."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    q = jnp.round(x.astype(jnp.float32) / scale[..., None]).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _decode_attention_chunked(qg: jax.Array, k_cache: jax.Array,
                              v_cache: jax.Array, pos: jax.Array,
                              window: Optional[jax.Array],
                              k_scale: Optional[jax.Array],
                              v_scale: Optional[jax.Array],
                              scale: float) -> jax.Array:
    """Online-softmax (flash-decode) attention of one query against a long
    (optionally int8-quantized) cache; dequantisation happens per KV chunk
    so the full bf16 cache never materialises.

    qg (B,1,K,G,D); caches (B,T,K,D); scales (B,T,K) or None.
    """
    B, _, K, G, D = qg.shape
    T = k_cache.shape[1]
    chunk = min(KV_CHUNK, T)
    n_chunks = T // chunk
    compute_dt = jnp.bfloat16 if k_scale is not None else k_cache.dtype
    qc = qg.astype(compute_dt)

    def body(carry, idx):
        m, num, den = carry
        start = idx * chunk
        # optimization_barrier blocks XLA from canonicalising
        # convert(slice(cache)) into slice(convert(cache)) and hoisting a
        # full-cache fp32 copy out of the loop (measured 2 x 6.4 GiB on
        # deepseek decode_32k).
        ks = jax.lax.optimization_barrier(
            jax.lax.dynamic_slice_in_dim(k_cache, start, chunk, 1))
        vs = jax.lax.optimization_barrier(
            jax.lax.dynamic_slice_in_dim(v_cache, start, chunk, 1))
        if k_scale is not None:
            ksc = jax.lax.dynamic_slice_in_dim(k_scale, start, chunk, 1)
            vsc = jax.lax.dynamic_slice_in_dim(v_scale, start, chunk, 1)
            ks = ks.astype(compute_dt) * ksc.astype(compute_dt)[..., None]
            vs = vs.astype(compute_dt) * vsc.astype(compute_dt)[..., None]
        kv_pos = start + jnp.arange(chunk)
        valid = kv_pos <= pos
        if window is not None:
            valid = valid & (pos - kv_pos < window)
        # chunk-sized tensors stay in the cache dtype; only the (B,K,G,1,C)
        # scores and running stats are fp32
        s = jnp.einsum("bskgd,btkd->bkgst", qc, ks
                       ).astype(jnp.float32) * scale
        s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # fully-masked chunks (m or m_new == -inf) must not poison the
        # accumulators: exp(-inf - -inf) = NaN (found by test_flash_decode
        # on windowed decode, where early chunks lie outside the window)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        num = num * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(compute_dt), vs
        ).astype(jnp.float32)
        den = den * corr + jnp.sum(p, axis=-1)
        return (m_new, num, den), None

    m0 = jnp.full((B, K, G, 1), -jnp.inf, jnp.float32)
    num0 = jnp.zeros((B, K, G, 1, D), jnp.float32)
    den0 = jnp.zeros((B, K, G, 1), jnp.float32)
    (m, num, den), _ = jax.lax.scan(body, (m0, num0, den0),
                                    jnp.arange(n_chunks))
    out = num / jnp.maximum(den[..., None], 1e-30)
    return jnp.moveaxis(out, 3, 1)  # (B,1,K,G,D)


def _flash_decode_local(qg, kc, vc, ksc, vsc, pos, shard_start, window,
                        scale):
    """Shard-local online-softmax over the local KV shard.

    qg (B,1,K,G,D); kc/vc (B,T_loc,K,D); returns (m, num, den) partials.
    """
    B, _, K, G, D = qg.shape
    T_loc = kc.shape[1]
    chunk = min(KV_CHUNK, T_loc)
    n_chunks = T_loc // chunk
    compute_dt = jnp.bfloat16 if ksc is not None else kc.dtype
    qc = qg.astype(compute_dt)

    def body(carry, idx):
        m, num, den = carry
        start = idx * chunk
        ks = jax.lax.optimization_barrier(
            jax.lax.dynamic_slice_in_dim(kc, start, chunk, 1))
        vs = jax.lax.optimization_barrier(
            jax.lax.dynamic_slice_in_dim(vc, start, chunk, 1))
        if ksc is not None:
            k_s = jax.lax.dynamic_slice_in_dim(ksc, start, chunk, 1)
            v_s = jax.lax.dynamic_slice_in_dim(vsc, start, chunk, 1)
            ks = ks.astype(compute_dt) * k_s.astype(compute_dt)[..., None]
            vs = vs.astype(compute_dt) * v_s.astype(compute_dt)[..., None]
        kv_pos = shard_start + start + jnp.arange(chunk)
        valid = kv_pos <= pos
        if window is not None:
            valid = valid & (pos - kv_pos < window)
        s = jnp.einsum("bskgd,btkd->bkgst", qc, ks
                       ).astype(jnp.float32) * scale
        s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        pr = jnp.exp(s - m_safe[..., None])
        pr = jnp.where(jnp.isfinite(s), pr, 0.0)
        num = num * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", pr.astype(compute_dt), vs
        ).astype(jnp.float32)
        den = den * corr + jnp.sum(pr, axis=-1)
        return (m_new, num, den), None

    m0 = jnp.full((B, K, G, 1), -jnp.inf, jnp.float32)
    num0 = jnp.zeros((B, K, G, 1, D), jnp.float32)
    den0 = jnp.zeros((B, K, G, 1), jnp.float32)
    (m, num, den), _ = jax.lax.scan(body, (m0, num0, den0),
                                    jnp.arange(n_chunks))
    return m, num, den


def _masked_local_update(cache, new, pos, shard_start):
    """Write `new` (B,1,...) at global `pos` iff it lands in this shard."""
    T_loc = cache.shape[1]
    local = pos - shard_start
    in_range = (local >= 0) & (local < T_loc)
    idx = jnp.clip(local, 0, T_loc - 1)
    start = (0, idx) + (0,) * (cache.ndim - 2)
    old = jax.lax.dynamic_slice(cache, start, new.shape)
    val = jnp.where(in_range, new.astype(cache.dtype), old)
    return jax.lax.dynamic_update_slice(cache, val, start)


def flash_decode_sharded(q, k, v, k_cache, v_cache, pos, *, window=None,
                         k_scale=None, v_scale=None, axis: str = "model"):
    """Distributed flash-decode: cache sequence-sharded over `axis`.

    Each shard updates its slice locally (no resharded dynamic-update —
    the naive SPMD lowering round-trips the whole cache through fp32
    selects) and computes a local online softmax; the cross-shard combine
    exchanges only (m, num, den): ~(B,K,G,D) floats per layer.

    Returns (out (B,1,K,G,D) f32, new caches [, new scales]).
    """
    from jax.sharding import PartitionSpec as P

    B, _, K, D = k.shape
    H = q.shape[2]
    scale = D ** -0.5
    qg = q.reshape(B, 1, K, H // K, D)
    quantized = k_scale is not None

    def local_fn(qg, k_new, v_new, kc, vc, ksc, vsc, pos):
        nshard = compat.axis_size(axis)
        t_loc = kc.shape[1]
        shard_start = compat.axis_index(axis, like=kc) * t_loc
        if quantized:
            kq, ks_new = quantize_kv(k_new)
            vq, vs_new = quantize_kv(v_new)
            kc = _masked_local_update(kc, kq, pos, shard_start)
            vc = _masked_local_update(vc, vq, pos, shard_start)
            ksc = _masked_local_update(ksc, ks_new, pos, shard_start)
            vsc = _masked_local_update(vsc, vs_new, pos, shard_start)
        else:
            kc = _masked_local_update(kc, k_new, pos, shard_start)
            vc = _masked_local_update(vc, v_new, pos, shard_start)
            ksc = vsc = None  # dummies in the unquantized path
        m, num, den = _flash_decode_local(qg, kc, vc, ksc, vsc, pos,
                                          shard_start, window, scale)
        m_g = jax.lax.pmax(m, axis)
        w = jnp.exp(m - m_g)
        num = jax.lax.psum(num * w[..., None], axis)
        den = jax.lax.psum(den * w, axis)
        out = num / jnp.maximum(den[..., None], 1e-30)
        if quantized:
            return out, kc, vc, ksc, vsc
        return out, kc, vc

    cache_spec = P(None, axis, None, None)
    scale_spec = P(None, axis, None)
    in_specs = (P(), P(), P(), cache_spec, cache_spec,
                scale_spec if quantized else P(),
                scale_spec if quantized else P(), P())
    out_specs = ((P(), cache_spec, cache_spec)
                 + ((scale_spec, scale_spec) if quantized else ()))
    fn = compat.shard_map(local_fn, in_specs=in_specs, out_specs=out_specs,
                          axis_names={axis}, check_vma=False)
    ksc_in = k_scale if quantized else jnp.zeros((), jnp.float32)
    vsc_in = v_scale if quantized else jnp.zeros((), jnp.float32)
    return fn(qg, k, v, k_cache, v_cache, ksc_in, vsc_in, pos)


def _should_flash_decode(num_kv_heads: int, seq_len: int) -> bool:
    """Use the sharded flash-decode when the cache is sequence-sharded
    (kv heads don't divide the model axis) and long enough to matter."""
    from repro.distributed.sharding import mesh_axis_size
    msize = mesh_axis_size("model")
    return (msize > 1 and num_kv_heads % msize != 0
            and seq_len % msize == 0 and seq_len >= 4096)


def decode_self_attention(
    p: dict, prefix: str, x: jax.Array, cfg, *,
    k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array,
    use_rope: bool = True, window: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None, v_scale: Optional[jax.Array] = None,
):
    """x (B,1,d); caches (B,Smax,K,hd) bf16 or int8 (+scales).

    Returns (out, new_k_cache, new_v_cache[, new_k_scale, new_v_scale]).
    """
    B = x.shape[0]
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q, k, v = attn_project_qkv(p, prefix, x, H, K, hd, bias=cfg.qkv_bias)
    if use_rope and cfg.rope_theta:
        posb = jnp.full((1,), 0, jnp.int32) + pos
        cos, sin = rope_cos_sin(posb, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    quantized = k_scale is not None
    T = k_cache.shape[1]

    if _should_flash_decode(K, T):
        res = flash_decode_sharded(
            q, k, v, k_cache, v_cache, pos, window=window,
            k_scale=k_scale, v_scale=v_scale)
        out = res[0].astype(x.dtype).reshape(B, 1, H * hd) @ p[f"{prefix}_wo"]
        if quantized:
            return (out,) + tuple(res[1:])
        return out, res[1], res[2]

    if quantized:
        kq, ksc = quantize_kv(k)
        vq, vsc = quantize_kv(v)
        k_cache = jax.lax.dynamic_update_slice(k_cache, kq, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, vq, (0, pos, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(k_scale, ksc, (0, pos, 0))
        v_scale = jax.lax.dynamic_update_slice(v_scale, vsc, (0, pos, 0))
    else:
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos, 0, 0))
    qg = q.reshape(B, 1, K, H // K, hd)

    if quantized or T > KV_CHUNK:
        out = _decode_attention_chunked(
            qg, k_cache, v_cache, pos, window, k_scale, v_scale, hd ** -0.5)
        out = out.astype(x.dtype)
    else:
        kv_pos = jnp.arange(T)
        valid = kv_pos <= pos
        if window is not None:
            valid = valid & (pos - kv_pos < window)
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, k_cache,
                            preferred_element_type=jnp.float32) * hd ** -0.5
        scores = jnp.where(valid[None, None, None, None, :], scores,
                           jnp.finfo(jnp.float32).min)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v_cache.dtype),
                         v_cache)
    out = out.reshape(B, 1, H * hd) @ p[f"{prefix}_wo"]
    if quantized:
        return out, k_cache, v_cache, k_scale, v_scale
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def swiglu_mlp(p: dict, prefix: str, x: jax.Array) -> jax.Array:
    # constraints on gate/up pin the *cotangent* shardings too (wsc is
    # self-transposing) — without them the backward all-gathers the hidden
    # cotangent to full d_ff (2 GiB/layer at zamba2 scale).
    gate = shard(x @ p[f"{prefix}_w_gate"], BATCH, None, "model")
    up = shard(x @ p[f"{prefix}_w_up"], BATCH, None, "model")
    h = jax.nn.silu(gate) * up
    h = shard(h, BATCH, None, "model")
    return h @ p[f"{prefix}_w_down"]


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def embed_tokens(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(table: jax.Array, h: jax.Array) -> jax.Array:
    logits = jnp.einsum("bsd,vd->bsv", h, table,
                        preferred_element_type=jnp.float32)
    return shard(logits, BATCH, None, "model")


@jax.custom_vjp
def cross_entropy_loss(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token CE; logits (B,S,V) bf16/f32, targets (B,S) int32.

    custom-vjp so the backward emits the cotangent in the *logits dtype*:
    the naive ``astype(f32)`` formulation drags fp32 through the unembed
    backward dots — measured ~10 concurrent (B,S,d) fp32 buffers at
    deepseek-67b scale. Statistics still accumulate in fp32.
    """
    loss, _ = _ce_fwd(logits, targets)
    return loss


def _ce_stats(logits, targets):
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return logz, gold


def _ce_fwd(logits, targets):
    logz, gold = _ce_stats(logits, targets)
    loss = jnp.mean(logz - gold)
    return loss, (logits, targets, logz)


def _ce_bwd(res, g):
    logits, targets, logz = res
    n = logz.size
    p = jnp.exp(logits.astype(jnp.float32) - logz[..., None])
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    dlogits = ((p - onehot) * (g / n)).astype(logits.dtype)
    return dlogits, None


cross_entropy_loss.defvjp(_ce_fwd, _ce_bwd)
