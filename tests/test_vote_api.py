"""The declarative vote API (DESIGN.md §10): request validation, backend
capability introspection, the WireReport accounting, the deprecation
once-guard — and bitwise shim→new-API equality for EVERY legacy vote
entry point (the satellite acceptance bar: each shim must delegate, not
re-implement)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import majority_vote as mv
from repro.core import sign_compress as sc
from repro.core import vote_api as va
from repro.core import vote_plan as vp
from repro.core.vote_engine import VoteEngine
from repro.distributed import fault_tolerance as ft
from repro.sim import virtual_mesh as vmesh

RNG = np.random.default_rng(0)
BYZ = ByzantineConfig(mode="sign_flip", num_adversaries=1)


def _stacked(m=5, n=70):
    return jnp.asarray(RNG.normal(size=(m, n)).astype(np.float32))


def _signs(m=5, n=70):
    return jnp.asarray(RNG.integers(-1, 2, size=(m, n)).astype(np.int8))


def _quiet(fn, *a, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*a, **kw)


# ---------------------------------------------------------------------------
# request validation (build-time rejection, actionable messages)
# ---------------------------------------------------------------------------


def test_unknown_codec_rejected():
    with pytest.raises(ValueError, match="unknown codec"):
        va.VoteRequest(payload=_signs(), form="stacked", codec="nope")


def test_unknown_form_rejected():
    with pytest.raises(ValueError, match="unknown payload form"):
        va.VoteRequest(payload=_signs(), form="flat")


def test_codec_strategy_combo_rejected_at_build():
    with pytest.raises(ValueError, match="cannot ride strategy"):
        va.VoteRequest(payload=_signs(), form="stacked",
                       strategy=VoteStrategy.PSUM_INT8,
                       codec="weighted_vote",
                       server_state={"flip_ema": jnp.zeros(5)})


def test_stacked_payload_must_be_2d():
    with pytest.raises(ValueError, match="must be \\(M, n\\)"):
        va.VoteRequest(payload=jnp.zeros(8, jnp.int8), form="stacked")


def test_stale_without_prev_rejected():
    with pytest.raises(ValueError, match="no prev signs"):
        va.VoteRequest(payload=_signs(), form="stacked",
                       failures=va.FailureSpec(n_stale=2))


def test_stateful_codec_without_state_rejected():
    with pytest.raises(ValueError, match="server-side decode state"):
        va.VoteRequest(payload=_signs(), form="stacked",
                       strategy=VoteStrategy.ALLGATHER_1BIT,
                       codec="weighted_vote")


def test_stateful_codec_no_axes_degenerate_passes_through():
    """Legacy semantics pinned: with NO vote axes (M=1 single process)
    the stateful-codec entry points returned the signs untouched and
    never demanded decode state — the leaf/tree forms must keep that
    (state is only required where a decode actually runs)."""
    s = sc.sign_ternary(
        jnp.asarray(RNG.normal(size=(40,)).astype(np.float32)))
    eng = VoteEngine(strategy=VoteStrategy.ALLGATHER_1BIT, axes=(),
                     codec="weighted_vote")
    got, state = _quiet(eng.vote_signs_codec, s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(s))
    assert state == {}
    # but inside a region WITH vote axes the missing state is an error
    def f(vals):
        out = va.MeshBackend(axes=("data",)).execute(va.VoteRequest(
            payload=vals[0], form="leaf",
            strategy=VoteStrategy.ALLGATHER_1BIT, codec="weighted_vote"))
        return out.votes[None]
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    sh = compat.shard_map(f, mesh=mesh, in_specs=(P("data"),),
                          out_specs=P("data"), axis_names={"data"},
                          check_vma=False)
    with pytest.raises(ValueError, match="server state"):
        jax.jit(sh)(s[None])


def test_plan_payload_mismatch_rejected():
    plan = vp.build_plan({"x": (64,)}, bucket_bytes=8)
    with pytest.raises(ValueError, match="plan manifest"):
        va.VoteRequest(payload=_signs(5, 70), form="stacked", plan=plan)


def test_plan_tree_name_mismatch_rejected():
    plan = vp.build_plan({"x": (8,)}, bucket_bytes=8)
    with pytest.raises(ValueError, match="disagree"):
        va.VoteRequest(payload={"y": jnp.zeros(8)}, form="tree",
                       plan=plan)


def test_diagnostics_need_tree_form():
    with pytest.raises(ValueError, match="diagnostics"):
        va.VoteRequest(payload=_signs(), form="stacked", diagnostics=True)


def test_tree_payload_must_be_nonempty_dict():
    with pytest.raises(ValueError, match="non-empty dict"):
        va.VoteRequest(payload={}, form="tree")


def test_bad_adversary_mode_rejected():
    with pytest.raises(ValueError, match="unknown adversary mode"):
        va.FailureSpec(byz=ByzantineConfig(mode="martian"))


def test_overlap_without_plan_rejected():
    # overlap double-buffers a bucket SCHEDULE; with no plan there is
    # nothing to pipeline and silently ignoring the flag would hide a
    # misconfigured trainer
    with pytest.raises(ValueError, match="overlap"):
        va.VoteRequest(payload=_signs(), form="stacked", overlap=True)


# ---------------------------------------------------------------------------
# capability introspection
# ---------------------------------------------------------------------------


def test_supports_matrix():
    stacked = va.VoteRequest(payload=_signs(1, 32), form="stacked")
    leaf = va.VoteRequest(payload=jnp.zeros(32, jnp.int8), form="leaf")
    assert va.VirtualBackend().supports(stacked)
    assert not va.VirtualBackend().supports(leaf)
    assert va.MeshBackend().supports(stacked)          # 1 voter, 1 device
    assert not va.MeshBackend().supports(leaf)         # no axes given
    assert va.MeshBackend(axes=("data",)).supports(leaf)
    big = va.VoteRequest(payload=_signs(64, 32), form="stacked")
    if len(jax.devices()) < 64:
        assert not va.MeshBackend().supports(big)
        with pytest.raises(ValueError, match="devices"):
            va.MeshBackend().execute(big)


def test_kernel_backend_capability():
    vb = va.VirtualBackend(use_kernels=True)
    ok = va.VoteRequest(payload=_stacked(), form="stacked",
                        strategy=VoteStrategy.ALLGATHER_1BIT)
    assert vb.supports(ok)
    psum = va.VoteRequest(payload=_stacked(), form="stacked",
                          strategy=VoteStrategy.PSUM_INT8)
    assert not vb.supports(psum)       # count-wire tie semantics
    with pytest.raises(ValueError, match="tie rule"):
        vb.execute(psum)
    failed = va.VoteRequest(payload=_stacked(), form="stacked",
                            strategy=VoteStrategy.ALLGATHER_1BIT,
                            failures=va.FailureSpec(byz=BYZ))
    assert not vb.supports(failed)


def test_kernel_backend_rejects_overlap():
    """The fused kernel is one launch per request — it cannot
    double-buffer a bucket schedule; the rejection must say so and name
    the way out (use_kernels=False executes the same request)."""
    vb = va.VirtualBackend(use_kernels=True)
    plan = vp.build_plan({"x": (70,)}, bucket_bytes=4,
                         strategy=VoteStrategy.ALLGATHER_1BIT)
    req = va.VoteRequest(payload=_signs(), form="stacked", plan=plan,
                         overlap=True)
    assert not vb.supports(req)
    with pytest.raises(ValueError, match="double-buffer"):
        vb.execute(req)
    out = va.VirtualBackend(use_kernels=False).execute(req)
    assert out.votes.shape == (70,)


# ---------------------------------------------------------------------------
# WireReport accounting (computed once, on the outcome)
# ---------------------------------------------------------------------------


def test_wire_report_bytes_and_messages():
    x = _signs(4, 64)
    out = va.VirtualBackend().execute(va.VoteRequest(
        payload=x, form="stacked", strategy=VoteStrategy.ALLGATHER_1BIT))
    assert out.wire.n_voters == 4
    assert out.wire.payload_bytes == 64 / 8.0          # 1 bit/param
    assert out.wire.n_messages == 1
    assert out.wire.strategy == VoteStrategy.ALLGATHER_1BIT

    plan = vp.build_plan({"x": (64,)}, bucket_bytes=4,
                         strategy=VoteStrategy.ALLGATHER_1BIT)
    outp = va.VirtualBackend().execute(va.VoteRequest(
        payload=x, form="stacked", plan=plan))
    assert outp.wire.n_messages == plan.n_buckets > 1
    assert outp.wire.payload_bytes == 64 / 8.0


def test_wire_report_diagnostics_on_tree():
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    tree = {"a": jnp.asarray(RNG.normal(size=(1, 48)).astype(np.float32))}
    backend = va.MeshBackend(axes=("data",))

    def f(t):
        out = backend.execute(va.VoteRequest(
            payload={"a": t["a"][0]}, form="tree",
            strategy=VoteStrategy.PSUM_INT8, diagnostics=True))
        return out.votes["a"][None], out.wire.margin, out.wire.agreement

    sh = compat.shard_map(f, mesh=mesh, in_specs=({"a": P("data")},),
                          out_specs=(P("data"), P(), P()),
                          axis_names={"data"}, check_vma=False)
    votes, margin, agreement = jax.jit(sh)(tree)
    assert float(agreement) == 1.0                     # M=1: vote == sign
    assert 0.0 <= float(margin) <= 1.0


# ---------------------------------------------------------------------------
# deprecation once-guard
# ---------------------------------------------------------------------------


def test_legacy_shims_warn_exactly_once():
    va._WARNED.discard("virtual_mesh.virtual_vote")
    s = _signs(3, 40)
    with pytest.warns(DeprecationWarning, match="virtual_vote"):
        vmesh.virtual_vote(s, VoteStrategy.PSUM_INT8)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        vmesh.virtual_vote(s, VoteStrategy.PSUM_INT8)  # guarded: silent


# ---------------------------------------------------------------------------
# shim -> new-API bitwise equality, one assertion per legacy name
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", [VoteStrategy.PSUM_INT8,
                                      VoteStrategy.ALLGATHER_1BIT,
                                      VoteStrategy.HIERARCHICAL])
def test_shim_virtual_vote(strategy):
    s = _signs()
    got = _quiet(vmesh.virtual_vote, s, strategy)
    want = va.VirtualBackend().execute(va.VoteRequest(
        payload=s, form="stacked", strategy=strategy)).votes
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("codec", ["sign1bit", "ef_sign", "ternary2bit",
                                   "weighted_vote"])
def test_shim_virtual_vote_codec(codec):
    from repro.core import codecs as codecs_mod
    s = _signs()
    state = codecs_mod.get_codec(codec).init_server_state(5)
    got, gstate = _quiet(vmesh.virtual_vote_codec, s,
                         VoteStrategy.ALLGATHER_1BIT, codec, state)
    out = va.VirtualBackend().execute(va.VoteRequest(
        payload=s, form="stacked", strategy=VoteStrategy.ALLGATHER_1BIT,
        codec=codec, server_state=state))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(out.votes))
    for k in gstate:
        np.testing.assert_array_equal(np.asarray(gstate[k]),
                                      np.asarray(out.server_state[k]))


def test_shim_virtual_plan_vote():
    s = _signs(4, 96)
    plan = vp.build_plan({"a": (40,), "b": (56,)}, bucket_bytes=8,
                         strategy=VoteStrategy.ALLGATHER_1BIT,
                         codec_map=(("a", "ternary2bit"),))
    got, _ = _quiet(vmesh.virtual_plan_vote, s, plan, {})
    want = va.VirtualBackend().execute(va.VoteRequest(
        payload=s, form="stacked", plan=plan)).votes
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("use_kernels", [True, False])
def test_shim_vote_stacked(use_kernels):
    x = _stacked()
    got = _quiet(VoteEngine(strategy=VoteStrategy.PSUM_INT8).vote_stacked,
                 x, use_kernels)
    want = va.VirtualBackend(use_kernels=use_kernels).execute(
        va.VoteRequest(payload=x, form="stacked",
                       strategy=VoteStrategy.ALLGATHER_1BIT)).votes
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _region_pair(legacy_fn, new_fn, *arrays):
    """Run a legacy entry and its new-API twin inside the SAME 1-device
    partial-auto mesh region; return both results as numpy."""
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)

    def wrap(f):
        def g(*args):
            return f(*[a[0] for a in args])[None]
        sh = compat.shard_map(
            g, mesh=mesh, in_specs=tuple(P("data") for _ in arrays),
            out_specs=P("data"), axis_names={"data"}, check_vma=False)
        return np.asarray(jax.jit(sh)(*[a[None] for a in arrays]))[0]

    return wrap(legacy_fn), wrap(new_fn)


def test_shim_engine_vote_and_vote_signs():
    eng = VoteEngine(strategy=VoteStrategy.PSUM_INT8, axes=("data",),
                     byz=BYZ, salt=7)
    backend = va.MeshBackend(axes=("data",))
    x = jnp.asarray(RNG.normal(size=(40,)).astype(np.float32))

    got, want = _region_pair(
        lambda v: _quiet(eng.vote, v, jnp.int32(3)),
        lambda v: backend.execute(va.VoteRequest(
            payload=v, form="leaf", strategy=eng.strategy,
            failures=va.FailureSpec(byz=BYZ), step=jnp.int32(3),
            salt=7)).votes,
        x)
    np.testing.assert_array_equal(got, want)

    s = sc.sign_ternary(x)
    got, want = _region_pair(
        lambda v: _quiet(eng.vote_signs, v),
        lambda v: backend.execute(va.VoteRequest(
            payload=v, form="leaf", strategy=eng.strategy,
            salt=7)).votes,
        s)
    np.testing.assert_array_equal(got, want)


def test_shim_engine_codec_entries():
    eng = VoteEngine(strategy=VoteStrategy.ALLGATHER_1BIT, axes=("data",),
                     codec="ternary2bit")
    backend = va.MeshBackend(axes=("data",))
    x = jnp.asarray(RNG.normal(size=(40,)).astype(np.float32))

    got, want = _region_pair(
        lambda v: _quiet(eng.vote_codec, v)[0],
        lambda v: backend.execute(va.VoteRequest(
            payload=v, form="leaf", strategy=eng.strategy,
            codec="ternary2bit")).votes,
        x)
    np.testing.assert_array_equal(got, want)

    s = sc.sign_ternary(x)
    got, want = _region_pair(
        lambda v: _quiet(eng.vote_signs_codec, v)[0],
        lambda v: backend.execute(va.VoteRequest(
            payload=v, form="leaf", strategy=eng.strategy,
            codec="ternary2bit")).votes,
        s)
    np.testing.assert_array_equal(got, want)


def test_shim_tree_entries():
    tree = {"a": jnp.asarray(RNG.normal(size=(24,)).astype(np.float32)),
            "b": jnp.asarray(RNG.normal(size=(3, 16)).astype(np.float32))}
    backend = va.MeshBackend(axes=())     # degenerate M=1, no region
    for legacy, req_codec in [
            (lambda: _quiet(mv.tree_vote, tree, VoteStrategy.PSUM_INT8,
                            ()), "sign1bit"),
            (lambda: _quiet(mv.tree_vote_codec, tree,
                            VoteStrategy.PSUM_INT8, (),
                            codec="ternary2bit")[0], "ternary2bit"),
            (lambda: _quiet(VoteEngine(
                strategy=VoteStrategy.PSUM_INT8).vote_tree, tree),
             "sign1bit"),
            (lambda: _quiet(VoteEngine(
                strategy=VoteStrategy.PSUM_INT8,
                codec="ternary2bit").vote_tree_codec, tree)[0],
             "ternary2bit")]:
        got = legacy()
        want = backend.execute(va.VoteRequest(
            payload=tree, form="tree", strategy=VoteStrategy.PSUM_INT8,
            codec=req_codec)).votes
        for k in tree:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(want[k]))


def test_shim_majority_vote_flat():
    s = sc.sign_ternary(
        jnp.asarray(RNG.normal(size=(40,)).astype(np.float32)))
    got, want = _region_pair(
        lambda v: _quiet(mv.majority_vote_flat, v,
                         VoteStrategy.ALLGATHER_1BIT, ("data",)),
        lambda v: va.MeshBackend(axes=("data",)).execute(va.VoteRequest(
            payload=v, form="leaf",
            strategy=VoteStrategy.ALLGATHER_1BIT)).votes,
        s)
    np.testing.assert_array_equal(got, want)


def test_shim_vote_with_failures_family():
    eng = VoteEngine(strategy=VoteStrategy.PSUM_INT8, axes=("data",),
                     byz=BYZ)
    backend = va.MeshBackend(axes=("data",))
    x = jnp.asarray(RNG.normal(size=(40,)).astype(np.float32))
    prev = jnp.asarray(RNG.integers(-1, 2, size=(40,)).astype(np.int8))

    def new_req(v, p, **kw):
        return va.VoteRequest(
            payload=v, form="leaf", strategy=eng.strategy,
            failures=va.FailureSpec(n_stale=1, byz=BYZ), prev=p,
            step=jnp.int32(2), **kw)

    got, want = _region_pair(
        lambda v, p: _quiet(ft.vote_with_failures, eng, v, p, 1,
                            jnp.int32(2)),
        lambda v, p: backend.execute(new_req(v, p)).votes,
        x, prev)
    np.testing.assert_array_equal(got, want)

    got, want = _region_pair(
        lambda v, p: _quiet(ft.codec_vote_with_failures, eng, v, p, 1,
                            jnp.int32(2))[0],
        lambda v, p: backend.execute(new_req(v, p)).votes,
        x, prev)
    np.testing.assert_array_equal(got, want)

    plan = vp.build_plan({"x": (40,)}, bucket_bytes=4,
                         strategy=VoteStrategy.PSUM_INT8)
    got, want = _region_pair(
        lambda v, p: _quiet(ft.plan_vote_with_failures, eng, plan, v, p,
                            1, jnp.int32(2))[0],
        lambda v, p: backend.execute(
            dataclasses_replace_plan(new_req(v, p), plan)).votes,
        x, prev)
    np.testing.assert_array_equal(got, want)


def dataclasses_replace_plan(req, plan):
    import dataclasses
    return dataclasses.replace(req, plan=plan)


def test_shim_plan_vote_signs_and_plan_tree_vote():
    plan = vp.build_plan({"x": (40,)}, bucket_bytes=4,
                         strategy=VoteStrategy.PSUM_INT8)
    s = sc.sign_ternary(
        jnp.asarray(RNG.normal(size=(40,)).astype(np.float32)))
    got, want = _region_pair(
        lambda v: _quiet(vp.plan_vote_signs, plan, v, ("data",))[0],
        lambda v: va.MeshBackend(axes=("data",)).execute(va.VoteRequest(
            payload=v, form="leaf", plan=plan)).votes,
        s)
    np.testing.assert_array_equal(got, want)

    tree = {"x": jnp.asarray(RNG.normal(size=(40,)).astype(np.float32))}
    got = _quiet(vp.plan_tree_vote, plan, tree, (), byz=None)[0]
    want = va.MeshBackend(axes=()).execute(va.VoteRequest(
        payload=tree, form="tree", plan=plan)).votes
    np.testing.assert_array_equal(np.asarray(got["x"]),
                                  np.asarray(want["x"]))


# ---------------------------------------------------------------------------
# cross-backend bit-identity at M=1 (the in-process slice of the tier-2
# 8-device harness guarantee)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec,strategy", [
    ("sign1bit", VoteStrategy.PSUM_INT8),
    ("sign1bit", VoteStrategy.ALLGATHER_1BIT),
    ("ternary2bit", VoteStrategy.ALLGATHER_1BIT),
    ("weighted_vote", VoteStrategy.ALLGATHER_1BIT),
])
def test_mesh_equals_virtual_single_voter(codec, strategy):
    from repro.core import codecs as codecs_mod
    x = jnp.asarray(RNG.normal(size=(1, 48)).astype(np.float32))
    state = codecs_mod.get_codec(codec).init_server_state(1)
    req = va.VoteRequest(payload=x, form="stacked", strategy=strategy,
                         codec=codec, server_state=state or None)
    vout = va.VirtualBackend().execute(req)
    mout = va.MeshBackend().execute(req)
    np.testing.assert_array_equal(np.asarray(vout.votes),
                                  np.asarray(mout.votes))
    assert np.asarray(vout.votes).dtype == np.int8
    for k in vout.server_state:
        np.testing.assert_array_equal(np.asarray(vout.server_state[k]),
                                      np.asarray(mout.server_state[k]))
