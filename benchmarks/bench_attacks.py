"""Adaptive-attack breaking points: measured vs the Theorem 2 bound.

Thin ``benchmarks.run`` adapter over
:mod:`repro.core.attacks.breaking_point` — every attack class's
adversary-fraction -> loss-drop curve with the oblivious failure-bound
overlay, plus the defense-aware degradation gate. The identity asserts
(mesh==virtual, chunk invariance) need the 8-virtual-device platform
and are skipped when the host has fewer devices; the CI lane
(``bench_robustness --breaking-point``) always forces the devices and
runs them.
"""
from __future__ import annotations


def rows():
    import jax

    from repro.core.attacks import breaking_point as bp
    return bp.breaking_point_rows(with_identity=len(jax.devices()) >= 8)
