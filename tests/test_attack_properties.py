"""Property twins for the attack engine (DESIGN.md §15).

The invariant under test: a randomly generated AttackSchedule either
(a) fails AdversarySpec build-time validation — deterministically, with
the same error on every attempt — or (b) runs, in which case the drill
is bit-identical across repeated runs, across population chunk sizes,
and (via the subprocess harness tests/attack_harness.py) across the
mesh and virtual backends and across host device counts.

The generator is seeded ``np.random`` (no ambient entropy), so the
deterministic lane below always runs; an equivalent hypothesis-driven
lane runs when hypothesis is installed (importorskip otherwise).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import VoteStrategy
from repro.core import attacks
from repro.sim import (AdversarySpec, PopulationSpec, ScenarioRunner,
                       ScenarioSpec)

HARNESS = os.path.join(os.path.dirname(__file__), "attack_harness.py")

#: phase-mode pool: obliviouses, adaptives (two channels, so mixing
#: draws are possible), inherit (None), and one invalid name
_MODES = (None, "none", "sign_flip", "colluding", "adaptive_flip",
          "low_margin", "reputation", "bogus_mode")
_FRACTIONS = (None, 0.0, 0.25, 0.375, 7 / 15, 0.5, 1.5)


def _random_phase_dicts(rng):
    """A few random phase dicts — deliberately allowed to be invalid
    (step 0, duplicate steps, bad fraction/mode, nothing overridden)."""
    n = int(rng.integers(0, 4))
    steps = rng.integers(0, 8, size=n)          # 0 and duplicates occur
    out = []
    for s in steps:
        out.append(dict(step=int(s),
                        mode=_MODES[rng.integers(len(_MODES))],
                        fraction=_FRACTIONS[rng.integers(
                            len(_FRACTIONS))]))
    return out


def _build_spec(phase_dicts, base_mode):
    """AttackPhases -> AdversarySpec -> ScenarioSpec, letting every
    build-time validator see the raw material; the observe channel is
    derived the way a correct caller would."""
    schedule = tuple(attacks.AttackPhase(**d) for d in phase_dicts)
    observe = attacks.required_channel(
        attacks.modes_used(schedule, base_mode))
    adv = AdversarySpec(base_mode, 0.25, observe=observe,
                        schedule=schedule)
    codec = "weighted_vote" if observe == "reputation" else "sign1bit"
    return ScenarioSpec(
        f"prop/{base_mode}", n_workers=6, n_steps=6, dim=16,
        strategy=VoteStrategy.ALLGATHER_1BIT, codec=codec, adversary=adv)


def _outcome(phase_dicts, base_mode):
    """(("error", message)) on rejection, (("digest", hex)) on a run."""
    try:
        spec = _build_spec(phase_dicts, base_mode)
    except (ValueError, TypeError) as e:
        return ("error", str(e))
    return ("digest", ScenarioRunner(spec, backend="virtual").run().digest)


def test_random_schedules_reject_or_run_deterministically():
    rng = np.random.default_rng(0)
    rejected = ran = 0
    for _ in range(12):
        phase_dicts = _random_phase_dicts(rng)
        base = ("none", "sign_flip",
                "adaptive_flip")[int(rng.integers(3))]
        first = _outcome(phase_dicts, base)
        second = _outcome(phase_dicts, base)
        assert first == second, (phase_dicts, base, first, second)
        if first[0] == "error":
            rejected += 1
        else:
            ran += 1
            # a schedule that runs also survives the JSON round trip
            spec = _build_spec(phase_dicts, base)
            back = ScenarioSpec.from_dict(
                json.loads(json.dumps(spec.to_dict())))
            assert back == spec
    # the generator must actually exercise both arms
    assert rejected > 0 and ran > 0, (rejected, ran)


def test_hypothesis_schedules_reject_or_run_identically():
    """The same invariant driven by hypothesis, when available."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    phase = st.fixed_dictionaries({
        "step": st.integers(min_value=0, max_value=7),
        "mode": st.sampled_from(_MODES),
        "fraction": st.sampled_from(_FRACTIONS)})

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(phases=st.lists(phase, max_size=3),
               base=st.sampled_from(("none", "sign_flip",
                                     "adaptive_flip")))
    def run(phases, base):
        assert _outcome(phases, base) == _outcome(phases, base)

    run()


def test_population_adaptive_chunk_invariance():
    """The streamed adaptive path must not depend on how the sampled
    population is chunked (chunk sizes straddling the 12-client sample:
    smaller, coprime, and one-shot)."""
    digests = set()
    for chunk in (3, 7, 24):
        spec = ScenarioSpec(
            "prop/chunks", n_workers=8, n_steps=4, dim=24, momentum=0.0,
            strategy=VoteStrategy.ALLGATHER_1BIT,
            adversary=AdversarySpec("low_margin", 0.375,
                                    observe="margin"),
            population=PopulationSpec(n_clients=24, sample_fraction=0.5,
                                      chunk_size=chunk))
        digests.add(ScenarioRunner(spec, backend="virtual").run().digest)
    assert len(digests) == 1, digests


def _run_harness(device_count, *args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={device_count}"
    proc = subprocess.run([sys.executable, HARNESS, *args], env=env,
                          capture_output=True, text=True, timeout=1200)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "attack harness failed"
    assert "ALL ATTACK HARNESS CHECKS PASSED" in proc.stdout
    return {line.split()[1]: line.split()[2]
            for line in proc.stdout.splitlines()
            if line.startswith("ADIGEST ")}


@pytest.mark.slow
@pytest.mark.timeout(1200)
def test_attack_mesh_equals_virtual_and_host_count_invariant():
    """Every adaptive mode + the scheduled sleeper: mesh == virtual on
    8 devices (asserted inside the harness), and the virtual digests
    match a 1-device replay (host-count invariance)."""
    d8 = _run_harness(8)
    d1 = _run_harness(1, "virtual-only")
    assert d8 and set(d8) == set(d1)
    for name in d8:
        assert d8[name] == d1[name], (
            f"{name}: adaptive digest differs between 8-device and "
            "1-device replays")
