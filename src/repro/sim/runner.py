"""ScenarioRunner: deterministic failure drills through the vote path.

Executes a :class:`~repro.sim.scenario.ScenarioSpec` on the paper's toy
objective (the 1000-dim quadratic family of Fig. 1, reduced): every voter
m holds the true gradient ``x`` plus N(0, sigma^2) noise, keeps per-worker
SIGNUM momentum (Algorithm 1), and the update applies the majority vote of
the momenta's signs. What makes it a *failure drill* is everything between
the local sign and the decision: stale-vote straggler substitution,
Byzantine perturbation, and elastic voter-set rescale — all DATA on one
declarative :class:`~repro.core.vote_api.VoteRequest` (DESIGN.md §10),
executed through the SAME code the trainer compiles.

Two interchangeable backends (bit-identical; asserted by tier-2) — both
build LITERALLY the same VoteRequest per step:

* ``virtual`` — :class:`~repro.core.vote_api.VirtualBackend`: the
  host-count-independent virtual mesh (any M on any device count).
* ``mesh``    — :class:`~repro.core.vote_api.MeshBackend`: the real
  thing, a ``shard_map`` over an M-wide 'data' axis on actual mesh
  replicas (requires M <= local device count; the tier-2 harness runs it
  on the 8-virtual-device platform).

Every step emits a :class:`StepTrace` (vote margin, fraction of
coordinates flipped vs the honest-majority oracle, convergence proxy);
the run digest hashes the raw vote bytes, so "reproducible" means
bit-identical, not approximately-equal (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (refit_leading_axis,
                                         refit_tree_leading_axis)
from repro.configs.base import VoteStrategy
from repro.core import attacks
from repro.core import codecs as codecs_mod
from repro.core import sign_compress as sc
from repro.core import vote_api as va
from repro.core.vote_engine import STRATEGIES
from repro.distributed.fault_tolerance import count_for_fraction
from repro.obs import recorder as obs
from repro.sim.scenario import ScenarioSpec

BACKENDS = ("virtual", "mesh")


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StepTrace:
    """One step's structured trace record (schema: DESIGN.md §7).

    In population mode (§12) ``n_workers`` is the number of clients
    actually *sampled* into the round (the voters), ``n_population``
    the logical population they were drawn from (0 in the classic dense
    drills), and ``n_adversaries`` counts adversaries over the LOGICAL
    population — the realized adversarial fraction of a sampled round
    varies with the draw, which is exactly the federated threat model."""

    step: int
    n_workers: int
    n_adversaries: int
    n_stale: int
    margin: float          # mean |vote count| / M  (1 = unanimous)
    flip_fraction: float   # coords where vote != honest-majority oracle
    loss: float            # convergence proxy: 0.5 * mean(x^2) after update
    n_population: int = 0  # logical client population (§12; 0 = dense)


@dataclasses.dataclass(frozen=True)
class ScenarioTrace:
    """Full run record: spec + per-step traces + bit-level digest."""

    spec: ScenarioSpec
    backend: str
    steps: Tuple[StepTrace, ...]
    digest: str            # sha256 over every step's raw vote bytes + x
    #: the codec server state after the last step (e.g. the weighted
    #: vote's flip-EMA) — observability for defense-vs-attacker analysis
    #: (attacks/breaking_point.py reads the final reliability weights);
    #: not part of to_dict(), the JSON surface is unchanged
    final_server_state: Any = None

    def summary(self) -> Dict[str, Any]:
        impl = STRATEGIES[self.spec.strategy]
        codec = codecs_mod.get_codec(self.spec.codec)
        d = self.spec.dim
        # price the exchange at each step's ACTUAL voter count (elastic
        # events change it mid-run); payload bytes/replica are
        # m-independent for every strategy (bits/param is fixed). The
        # gathered exchange scales with the codec's symbol width (§8).
        wire_scale = (codec.bits_per_param / impl.wire_bits_per_param
                      if self.spec.strategy == VoteStrategy.ALLGATHER_1BIT
                      else 1.0)
        if self.spec.plan.enabled:
            # bucketed wire: price the WHOLE schedule (one alpha term per
            # bucket message — comm_model.schedule_time); one plan build
            # per distinct voter count, not per step
            plans = {m: self.spec.runtime_plan(m)
                     for m in {s.n_workers for s in self.steps}}
            est = float(np.mean(
                [plans[s.n_workers].schedule_cost(
                    s.n_workers, overlap=self.spec.plan.overlap)
                 for s in self.steps]))
            n_buckets = plans[self.steps[0].n_workers].n_buckets
        else:
            est = wire_scale * float(
                np.mean([impl.estimated_time(d, s.n_workers)
                         for s in self.steps]))
            n_buckets = 0
        return {
            "plan_buckets": n_buckets,
            "scenario": self.spec.name,
            "strategy": self.spec.strategy.value,
            "codec": self.spec.codec,
            "bits_per_param": codec.wire_bits(self.spec.strategy),
            "backend": self.backend,
            "tie_policy": self.spec.tie_policy,
            "first_loss": self.steps[0].loss,
            "final_loss": self.steps[-1].loss,
            "loss_drop": self.steps[0].loss - self.steps[-1].loss,
            "mean_margin": float(np.mean([s.margin for s in self.steps])),
            "mean_flip_fraction": float(
                np.mean([s.flip_fraction for s in self.steps])),
            "max_flip_fraction": float(
                np.max([s.flip_fraction for s in self.steps])),
            "wire_bytes_per_replica": d * codec.wire_bits(
                self.spec.strategy) / 8.0,
            "est_exchange_time_s": est,
            "digest": self.digest,
        }

    def to_dict(self) -> Dict[str, Any]:
        return {"spec": self.spec.to_dict(), "backend": self.backend,
                "digest": self.digest,
                "steps": [dataclasses.asdict(s) for s in self.steps],
                "summary": self.summary()}

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)


# ---------------------------------------------------------------------------
# deterministic keys (scenario id + step folded; DESIGN.md §7)
# ---------------------------------------------------------------------------


def _root_key(spec: ScenarioSpec) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(spec.seed), spec.salt)


def _noise(spec: ScenarioSpec, step: int, m: int) -> jax.Array:
    """Per-(scenario, step) gradient noise for m voters — independent of
    backend, device count and elastic history (shape depends only on the
    CURRENT voter count)."""
    key = jax.random.fold_in(jax.random.fold_in(_root_key(spec), 1), step)
    return jax.random.normal(key, (m, spec.dim), jnp.float32)


def _init_x(spec: ScenarioSpec) -> jax.Array:
    key = jax.random.fold_in(_root_key(spec), 0)
    return jax.random.normal(key, (spec.dim,), jnp.float32)


# population-mode keys (§12): every draw is keyed by LOGICAL client id
# and/or step — never by sampling order, chunk boundary, or device
# placement — so a round replays bit-identically whatever the host count
# or chunk size. Gradient noise uses the jax PRNG (tag 1, like the dense
# drills); client sampling (tag 2) and dataset sizes (tag 3) use a
# stateless splitmix64 hash in pure numpy — the host-side draws are
# O(population) per round, and hashing keeps them free of per-population
# jit recompiles (jax.random.permutation compiles once per distinct
# population size — ruinous across a churn schedule) while staying
# bit-stable across library versions.

_SM64 = (np.uint64(0x9E3779B97F4A7C15), np.uint64(0xBF58476D1CE4E5B9),
         np.uint64(0x94D049BB133111EB))


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """The splitmix64 finalizer over a uint64 array (elementwise,
    vectorized, wrap-around arithmetic)."""
    x = (x + _SM64[0]).astype(np.uint64)
    x = ((x ^ (x >> np.uint64(30))) * _SM64[1]).astype(np.uint64)
    x = ((x ^ (x >> np.uint64(27))) * _SM64[2]).astype(np.uint64)
    return x ^ (x >> np.uint64(31))


def _hash_stream(spec: ScenarioSpec, tag: int, step: int = 0) -> np.ndarray:
    """A (1,) uint64 stream constant chaining (seed, salt, tag, step)."""
    h = np.zeros(1, dtype=np.uint64)
    for v in (spec.seed, spec.salt, tag, step):
        h = _splitmix64(h ^ np.uint64(v))
    return h


def _sample_ids(spec: ScenarioSpec, step: int, pop: int, k: int
                ) -> np.ndarray:
    """The sorted logical ids of the k clients sampled into `step`'s
    round: every id gets a (salt, step)-keyed hash score, the k smallest
    win — a uniform draw without replacement. Full participation skips
    the draw entirely, so turning sampling on cannot perturb any other
    stream."""
    if k >= pop:
        return np.arange(pop, dtype=np.int32)
    score = _splitmix64(np.arange(pop, dtype=np.uint64)
                        ^ _hash_stream(spec, 2, step))
    sel = np.argpartition(score, k - 1)[:k]
    return np.sort(sel).astype(np.int32)


def _client_sizes(spec: ScenarioSpec, ids: np.ndarray) -> np.ndarray:
    """Dataset sizes for a batch of clients, uniform on
    [min_data, max_data], hashed once per LOGICAL id (no step in the
    key): a client's dataset size is a property of the client, stable
    across rounds and churn — ids keep their sizes however the
    population around them changes."""
    pspec = spec.population
    r = _splitmix64(np.asarray(ids, dtype=np.uint64)
                    ^ _hash_stream(spec, 3))
    span = np.uint64(pspec.max_data - pspec.min_data + 1)
    return (pspec.min_data + (r % span)).astype(np.int32)


@jax.jit
def _pop_rows(ids, x, step, noise_root, noise_scale):
    """A chunk of client gradient rows: x plus per-(step, client) noise.
    Module-level jit on purpose — every spec-dependent quantity is a
    traced argument, so the compilation is keyed by SHAPES only and one
    compile serves every scenario in a sweep."""
    def one(cid):
        key = jax.random.fold_in(jax.random.fold_in(noise_root, step), cid)
        return x + noise_scale * jax.random.normal(key, x.shape,
                                                   jnp.float32)
    return jax.vmap(one)(ids)


def _population_rows(spec: ScenarioSpec):
    """The per-chunk gradient-row callback for the population stream."""
    noise_root = jax.random.fold_in(_root_key(spec), 1)
    scale = jnp.float32(spec.noise_scale)

    def rows(ids, x, step):
        return _pop_rows(ids, x, step, noise_root, scale)

    return rows


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------


class ScenarioRunner:
    """Executes one spec; ``run()`` returns the :class:`ScenarioTrace`.

    `backend` is "virtual" (default, host-count independent) or "mesh"
    (real shard_map collectives; every segment's voter count must fit the
    local device count). `mesh_style` picks the mesh layout for the mesh
    backend: "data_model" = an (M, 1) ('data', 'model') mesh, manual over
    'data' only — the trainer's partial-auto configuration, which on
    legacy JAX exercises the compat emulation layer; "data_only" = a
    fully-manual (M,) mesh using the native collective lowerings.
    """

    def __init__(self, spec: ScenarioSpec, backend: str = "virtual",
                 mesh_style: str = "data_model"):
        if backend not in BACKENDS:
            raise ValueError(f"backend {backend!r} not in {BACKENDS}")
        if mesh_style not in ("data_model", "data_only"):
            raise ValueError(f"unknown mesh_style {mesh_style!r}")
        self.spec = spec
        self.backend = backend
        self.mesh_style = mesh_style
        if spec.population.enabled and backend != "virtual":
            raise ValueError(
                f"population mode ({spec.name!r}) virtualises more "
                "voters than any physical mesh holds replicas; it runs "
                "on backend='virtual' only (the streamed engine, §12)")
        # the execution backend: both build LITERALLY the same
        # VoteRequest per step; only the executor differs (DESIGN.md §10)
        if backend == "mesh":
            need = max([spec.n_workers] + [e.n_workers for e in spec.elastic])
            have = len(jax.devices())
            if need > have:
                raise ValueError(
                    f"mesh backend needs {need} devices for "
                    f"{spec.name!r}, have {have} (use backend='virtual', "
                    "or XLA_FLAGS=--xla_force_host_platform_device_count=N)")
            self._exec = va.MeshBackend(mesh_style=mesh_style)
        else:
            # population mode streams in spec-pinned voter chunks; the
            # default matches core.population.DEFAULT_CHUNK, so dense
            # drills are unaffected
            self._exec = va.VirtualBackend(
                chunk_size=spec.population.chunk_size)

    # ---- per-segment compiled pieces (rebuilt at elastic boundaries) ----

    def _segment(self, m: int, byz_cfg):
        spec = self.spec
        codec = codecs_mod.get_codec(spec.codec)
        byz = byz_cfg if byz_cfg.mode != "none" else None
        n_stale = count_for_fraction(spec.straggler_fraction, m)
        beta = spec.momentum
        has_ef = codec.worker_state
        # the bucketed wire schedule (§9); rebuilt per segment because
        # only the hierarchical alignment depends on the voter count
        plan = spec.runtime_plan(m)
        oracle_backend = va.VirtualBackend()

        @jax.jit
        def prepare(x, v, err, prev, cstate, noise, step, aobs):
            g = x[None, :] + spec.noise_scale * noise
            v2 = beta * v + (1.0 - beta) * g if beta > 0 else g
            # codec encode: fold the EF residual into the vote input (§8);
            # t == v2 for residual-free codecs, so the legacy path is
            # bit-identical
            t = err + v2 if has_ef else v2
            fresh = sc.sign_ternary(t)
            eff = va.effective_stacked_signs(t, prev, n_stale, byz, step,
                                             spec.salt, obs=aobs)
            # honest-majority oracle through the SAME codec decode (and
            # the same bucket schedule when the plan axis is on): a
            # failure-free VoteRequest on the virtual backend; state is
            # read-only here — the oracle must not advance the
            # reliability EMA
            oracle = oracle_backend.execute(va.VoteRequest(
                payload=fresh, form="stacked", strategy=spec.strategy,
                codec=spec.codec, plan=plan, server_state=cstate)).votes
            counts = jnp.sum(eff.astype(jnp.int32), axis=0)
            margin = jnp.mean(jnp.abs(counts).astype(jnp.float32)) / m
            return v2, t, fresh, eff, oracle, counts, margin

        @jax.jit
        def finish(x, applied, vote, oracle):
            # `applied` is what moves the iterate (the PREVIOUS step's
            # banked vote under delayed_vote, the fresh vote otherwise);
            # the flip trace always scores the FRESH vote against the
            # oracle — the delay shifts the update, not the decision
            flip = jnp.mean((vote != oracle).astype(jnp.float32))
            x2 = x - spec.learning_rate * applied.astype(jnp.float32)
            loss = 0.5 * jnp.mean(x2 * x2)
            return x2, flip, loss

        @jax.jit
        def ef_feedback(t, vote):
            # per-worker residual vs the APPLIED vote (codec feedback_leaf
            # semantics, vmapped over the stacked voter dim)
            scale = jnp.mean(jnp.abs(t), axis=1, keepdims=True)
            return t - scale * vote[None, :].astype(t.dtype)

        return prepare, finish, ef_feedback, n_stale, plan

    # ---- telemetry (DESIGN.md §13) ----

    def _record_step(self, rec, trace: StepTrace, wire,
                     phase_s: Dict[str, float], n_chunks: int = 0) -> None:
        """One unified step record: the StepTrace drill fields joined
        with the WireReport wire accounting and the per-phase span
        times, written to the active recorder's JSONL sink."""
        d = self.spec.dim
        payload = float(wire.payload_bytes)
        fields = dict(
            scenario=self.spec.name, backend=self.backend,
            step=trace.step, n_voters=trace.n_workers,
            n_population=trace.n_population,
            n_adversaries=trace.n_adversaries, n_stale=trace.n_stale,
            strategy=self.spec.strategy.value, codec=self.spec.codec,
            payload_bytes=payload, n_messages=int(wire.n_messages),
            n_coords=d, compression_vs_f32=payload / (4.0 * d),
            margin=trace.margin, flip_fraction=trace.flip_fraction,
            loss=trace.loss, phase_s=phase_s)
        if n_chunks:
            fields["n_chunks"] = n_chunks
        rec.step(**fields)

    # ---- the drill ----

    def run(self) -> ScenarioTrace:
        if self.spec.population.enabled:
            return self._run_population()
        spec = self.spec
        codec = codecs_mod.get_codec(spec.codec)
        x = _init_x(spec)
        m = spec.workers_at(0)
        v = jnp.zeros((m, spec.dim), jnp.float32)        # per-worker momentum
        # codec worker state: the EF residual, stacked like the momentum
        err = jnp.zeros((m, spec.dim), jnp.float32)
        # last step's locally COMPUTED signs (pre-stale, pre-adversary):
        # that is what a straggler re-submits; failures then apply to the
        # substituted vector (vote_with_failures order)
        prev = jnp.zeros((m, spec.dim), jnp.int8)
        # delayed-vote buffer (§11): the one-round-old majority applied
        # this step. Replicated (dim,), so elastic rescales never touch
        # it; zeros at step 0 -> the first update is a no-op, matching
        # the trainer's weight-decay-only first step
        pending = jnp.zeros((spec.dim,), jnp.int8)
        att = spec.adversary
        # the attacker's memory (§15): carried beside the server state,
        # updated once per round from the published outcome, refit on
        # elastic rescale like the reliability EMA
        astate = (attacks.AttackState.init(spec.dim, m) if att.adaptive
                  else None)
        # segments cache per (m, byz_cfg): an attack schedule swaps the
        # adversary config between steps, and re-jitting the whole
        # prepare/finish pipeline at every phase flip would dwarf the
        # step; config equality is exact because build_config collapses
        # honest phases to the canonical rest state
        segs: Dict = {}

        def segment(m_, cfg):
            key = (m_, cfg)
            if key not in segs:
                segs[key] = self._segment(m_, cfg)
            return segs[key]

        byz_cfg = att.byz_config_at(0, m, spec.seed)
        prepare, finish, ef_feedback, n_stale, plan = segment(m, byz_cfg)
        # codec server state: replicated decode memory (reliability EMA);
        # under a plan the schedule's codec set decides what exists
        if plan is not None:
            cstate = plan.init_server_state(m)
        else:
            cstate = (codec.init_server_state(m) if codec.server_state
                      else {})
        digest = hashlib.sha256()
        steps: List[StepTrace] = []
        rec = obs.get_recorder()
        for step in range(spec.n_steps):
            m_now = spec.workers_at(step)
            if m_now != m:
                # elastic rescale: per-worker state — momentum, EF
                # residual, stale vector, reliability EMA — refits by the
                # checkpoint rule (truncate / zero-pad axis 0, §6):
                # joiners start with zero momentum, zero residual, an
                # abstaining stale vector, and the uninformed-prior weight
                v = jnp.asarray(refit_leading_axis(
                    np.asarray(v), (m_now, spec.dim)))
                err = jnp.asarray(refit_leading_axis(
                    np.asarray(err), (m_now, spec.dim)))
                prev = jnp.asarray(refit_leading_axis(
                    np.asarray(prev), (m_now, spec.dim)))
                cstate = jax.tree.map(
                    jnp.asarray, refit_tree_leading_axis(
                        cstate, {k: (m_now,) + tuple(a.shape[1:])
                                 for k, a in cstate.items()}))
                m = m_now
                if astate is not None:
                    astate = astate.refit(m)
            # schedule resolution: the config in force THIS step (equal
            # to the base config when the schedule is empty, so
            # schedule-free runs reuse one cached segment and keep their
            # historical digests)
            byz_cfg = att.byz_config_at(step, m, spec.seed)
            prepare, finish, ef_feedback, n_stale, plan = segment(m, byz_cfg)
            # the observation the current phase's adversary may see —
            # None unless the phase's mode is adaptive, so oblivious
            # phases trace exactly the legacy signature
            aobs = (astate.observation(att.observe)
                    if astate is not None
                    and byz_cfg.mode in attacks.ATTACK_MODES else None)
            noise = _noise(spec, step, m)
            step_t = jnp.int32(step)
            # tracing never touches a traced value — the spans time host
            # perf_counter around each phase (block_until_ready so the
            # async dispatch doesn't bill one phase's work to the next),
            # so the run digest is bit-identical with the recorder on
            # (regression-tested by tests/test_obs.py)
            with rec.span("scenario.prepare", step=step) as sp_prep:
                v, t, fresh, eff, oracle, counts, margin = prepare(
                    x, v, err, prev, cstate, noise, step_t, aobs)
                if rec.enabled:
                    jax.block_until_ready(oracle)
            # ONE declarative request per step, identical on both
            # backends — payload is the raw stacked encode input, the
            # failure composition is data, the executor is the only
            # thing that differs (DESIGN.md §10). The mesh backend
            # round-trips through numpy internally so elastic segments
            # with different mesh sizes coexist in one process. (The
            # executor re-derives the effective signs prepare() captured
            # for the margin trace — the cost of keeping the request
            # backend-identical; both derivations are jitted.)
            with rec.span("scenario.vote", step=step,
                          backend=self.backend) as sp_vote:
                out = self._exec.execute(va.VoteRequest(
                    payload=t, form="stacked", strategy=spec.strategy,
                    codec=spec.codec, plan=plan,
                    failures=va.FailureSpec(n_stale=n_stale, byz=byz_cfg
                                            if byz_cfg.mode != "none"
                                            else None),
                    prev=prev, step=step_t, salt=spec.salt,
                    server_state=cstate, overlap=spec.plan.overlap,
                    attack_obs=aobs))
                if rec.enabled:
                    jax.block_until_ready(out.votes)
            vote, cstate = out.votes, out.server_state
            if spec.delayed_vote:
                applied, pending = pending, vote
            else:
                applied = vote
            with rec.span("scenario.finish", step=step) as sp_fin:
                x, flip, loss = finish(x, applied, vote, oracle)
                if codec.worker_state:
                    err = ef_feedback(t, vote)
                if rec.enabled:
                    jax.block_until_ready(x)
            prev = fresh
            if astate is not None:
                # one observation per round, from PUBLISHED outputs only:
                # the broadcast vote, its tally, and the wire signs the
                # reputation bookkeeping replays (all public, §15)
                astate = attacks.update_attack_state(astate, vote, counts,
                                                     eff)
            digest.update(np.asarray(vote).tobytes())
            trace = StepTrace(
                step=step, n_workers=m,
                n_adversaries=byz_cfg.num_adversaries, n_stale=n_stale,
                margin=float(margin), flip_fraction=float(flip),
                loss=float(loss))
            steps.append(trace)
            if rec.enabled:
                self._record_step(rec, trace, out.wire, phase_s={
                    "prepare": sp_prep.dur_s, "vote": sp_vote.dur_s,
                    "finish": sp_fin.dur_s})
        digest.update(np.asarray(x, np.float32).tobytes())
        return ScenarioTrace(spec=spec, backend=self.backend,
                             steps=tuple(steps), digest=digest.hexdigest(),
                             final_server_state=cstate)

    # ---- the federated drill (population mode, DESIGN.md §12) ----

    def _run_population(self) -> ScenarioTrace:
        """The streamed-population variant of :meth:`run`: each round
        samples clients from the logical population, streams their
        gradient rows through :func:`repro.core.population.streamed_vote`
        in voter-chunks (never materializing the population), and
        applies the (optionally dataset-weighted) majority to the
        iterate. Bit-identical across host counts, chunk sizes and
        backend wiring because every PRNG draw is keyed by logical
        client id / step and every tally is exact integer arithmetic."""
        spec = self.spec
        pspec = spec.population
        codec = codecs_mod.get_codec(spec.codec)
        rows = _population_rows(spec)
        x = _init_x(spec)
        pop = pspec.clients_at(0)
        # codec server state lives over the LOGICAL population (the
        # weighted vote tracks every client's reliability, sampled into
        # a round or not)
        cstate = codec.init_server_state(pop) if codec.server_state else {}
        att = spec.adversary
        # attacker memory over the LOGICAL population (ids, not rows):
        # the reputation mirror refits on churn like the flip-EMA
        astate = (attacks.AttackState.init(spec.dim, pop) if att.adaptive
                  else None)
        from repro.core import population as pop_engine
        pending = jnp.zeros((spec.dim,), jnp.int8)   # delayed-vote buffer
        digest = hashlib.sha256()
        steps: List[StepTrace] = []
        rec = obs.get_recorder()
        for step in range(spec.n_steps):
            pop_now = pspec.clients_at(step)
            if pop_now != pop:
                # churn: per-client server state — the weighted vote's
                # (pop,) flip-rate EMA — refits by the checkpoint rule
                # (§6): leavers truncate off the top of the id range,
                # joiners zero-pad in at the uninformed prior
                if cstate:
                    cstate = jax.tree.map(
                        jnp.asarray, refit_tree_leading_axis(
                            cstate,
                            {key: (pop_now,) + tuple(np.asarray(a).shape[1:])
                             for key, a in cstate.items()}))
                pop = pop_now
                if astate is not None:
                    astate = astate.refit(pop)
            # adversary count is pinned to the LOGICAL population (ids <
            # num_adversaries act evil); the realized count in a sampled
            # round varies with the draw. byz_config_at resolves the
            # attack schedule too — equal to byz_config when no schedule
            byz_cfg = att.byz_config_at(step, pop, spec.seed)
            byz = byz_cfg if byz_cfg.mode != "none" else None
            aobs = (astate.observation(att.observe)
                    if astate is not None
                    and byz_cfg.mode in attacks.ATTACK_MODES else None)
            k = max(1, count_for_fraction(pspec.sample_fraction, pop))
            ids = _sample_ids(spec, step, pop, k)
            step_t = jnp.int32(step)

            def values(cids, _x=x, _t=step_t):
                return rows(cids, _x, _t)

            stream = va.PopulationStream(
                n_voters=k, n_coords=spec.dim, values=values, ids=ids,
                weights=(_client_sizes(spec, ids)
                         if pspec.weighting == "dataset" else None))
            if byz is not None:
                # honest-majority oracle for the flip trace: the same
                # stream, failure-free, state read-only (runs FIRST so
                # the population.last.* counters reflect the real vote)
                oracle, _, _, _ = pop_engine.streamed_vote(
                    stream, strategy=spec.strategy, codec=spec.codec,
                    step=step_t, salt=spec.salt, server_state=cstate,
                    chunk_size=pspec.chunk_size)
            chunks_before = obs.COUNTERS.get("population.chunks")
            with rec.span("scenario.vote", step=step,
                          backend=self.backend) as sp_vote:
                out = self._exec.execute(va.VoteRequest(
                    payload=stream, form="streamed", strategy=spec.strategy,
                    codec=spec.codec, failures=va.FailureSpec(byz=byz),
                    step=step_t, salt=spec.salt, server_state=cstate,
                    attack_obs=aobs))
                if rec.enabled:
                    jax.block_until_ready(out.votes)
            vote, cstate = out.votes, out.server_state
            flip = (float(jnp.mean((vote != oracle).astype(jnp.float32)))
                    if byz is not None else 0.0)
            if spec.delayed_vote:
                applied, pending = pending, vote
            else:
                applied = vote
            x = x - spec.learning_rate * applied.astype(jnp.float32)
            loss = float(0.5 * jnp.mean(x * x))
            if astate is not None:
                if att.observe == "reputation":
                    # replay the flip-EMA observation the codec makes:
                    # per-sampled-voter mismatch counts vs the published
                    # vote, assembled chunk-by-chunk over the SAME wire
                    # signs the round produced (public bookkeeping, §15)
                    mis = np.zeros(k, np.float32)
                    for lo, ids_np in pop_engine._chunks(
                            stream, pspec.chunk_size):
                        eff_c = pop_engine._chunk_signs(
                            stream, ids_np, step_t, 0, byz, spec.salt,
                            obs=aobs)
                        mis[lo:lo + len(ids_np)] = np.asarray(
                            pop_engine._chunk_mismatch(eff_c, vote))
                    astate = attacks.update_attack_state_population(
                        astate, vote, out.counts, ids, mis / spec.dim)
                else:
                    astate = attacks.update_attack_state_population(
                        astate, vote, out.counts,
                        np.zeros(0, np.int32), np.zeros(0, np.float32))
            digest.update(np.asarray(vote).tobytes())
            trace = StepTrace(
                step=step, n_workers=k,
                n_adversaries=byz_cfg.num_adversaries, n_stale=0,
                margin=float(out.wire.margin), flip_fraction=flip,
                loss=loss, n_population=pop)
            steps.append(trace)
            if rec.enabled:
                self._record_step(
                    rec, trace, out.wire, phase_s={"vote": sp_vote.dur_s},
                    n_chunks=obs.COUNTERS.get("population.chunks")
                    - chunks_before)
        digest.update(np.asarray(x, np.float32).tobytes())
        return ScenarioTrace(spec=spec, backend=self.backend,
                             steps=tuple(steps), digest=digest.hexdigest(),
                             final_server_state=cstate)


def run_scenarios(specs, backend: str = "virtual",
                  mesh_style: str = "data_model") -> List[ScenarioTrace]:
    return [ScenarioRunner(s, backend=backend, mesh_style=mesh_style).run()
            for s in specs]
