"""Unified telemetry: spans, counters and step records (DESIGN.md §13).

One subsystem behind every quantitative claim in the repo — the exact
wire/launch/chunk accounting the benches assert against, host-side span
timing for the schedule walks, and per-step structured records unifying
`WireReport` + `StepTrace`, all emitted to a versioned JSONL sink that
`scripts/trace_report.py` aggregates. Import as ``from repro import
obs`` (or ``from repro.obs import recorder as obs`` inside hot modules).
"""
from repro.obs.recorder import (COUNTERS, CounterRegistry, Recorder,
                                SCHEMA_VERSION, TraceRecorder,
                                activate_trace, add_trace_arg,
                                emit_bench_json, finish_trace,
                                get_recorder, install_compile_watch,
                                read_trace, recording, set_recorder,
                                warn_deprecated)

__all__ = [
    "COUNTERS", "CounterRegistry", "Recorder", "SCHEMA_VERSION",
    "TraceRecorder", "activate_trace", "add_trace_arg",
    "emit_bench_json", "finish_trace", "get_recorder",
    "install_compile_watch", "read_trace", "recording", "set_recorder",
    "warn_deprecated",
]
