"""Core: the paper's contribution — sign compression, majority vote,
SIGNUM/signSGD optimizers, Byzantine adversaries, theory predictors."""
from repro.core import byzantine, majority_vote, sign_compress, signum, theory  # noqa: F401
