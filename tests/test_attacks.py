"""Unit tests for the stateful attack engine (DESIGN.md §15).

Covers the sanctioned ByzantineConfig factories (honest collapse,
exact-Fraction coalition counting shared by the dense / population /
scheduled paths), the adaptive per-mode sign semantics, the
AttackState memory (channel-sliced observation, elastic refit, and the
rep EMA replaying the weighted_vote flip-EMA bit for bit), the
time-varying schedule algebra, the AdversarySpec observe/schedule
build-time validation, VoteRequest.attack_obs validation, and the
defense-aware-vs-oblivious degradation gate.
"""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import VoteStrategy
from repro.core import attacks
from repro.core.attacks import breaking_point as bp
from repro.core.codecs import weighted
from repro.core import vote_api as va
from repro.distributed.fault_tolerance import count_for_fraction
from repro.sim import AdversarySpec, ScenarioRunner, ScenarioSpec


# ---------------------------------------------------------------------------
# the sanctioned factories
# ---------------------------------------------------------------------------


def test_build_config_validates_and_collapses_honest():
    with pytest.raises(ValueError, match="unknown adversary mode"):
        attacks.build_config("nope", 2)
    with pytest.raises(ValueError, match=">= 0"):
        attacks.build_config("sign_flip", -1)
    # honest collapses to the canonical rest state either way, so
    # config equality (the runner's segment cache key) cannot split on
    # knobs that do not matter
    a = attacks.build_config("sign_flip", 0)
    b = attacks.build_config("none", 5)
    assert (a.mode, a.num_adversaries) == ("none", 0)
    assert a == b == attacks.build_config("none", 0)
    cfg = attacks.build_config("adaptive_flip", 3, strike_below=0.2)
    assert (cfg.mode, cfg.num_adversaries, cfg.strike_below) == \
        ("adaptive_flip", 3, 0.2)


def test_coalition_config_fraction_range():
    with pytest.raises(ValueError, match=r"\[0, 1\]"):
        attacks.coalition_config("sign_flip", 1.5, 8)


@pytest.mark.parametrize("fraction,n,expect", [
    (0.5, 16, 8),        # the DESIGN.md §7 tie boundary, half-up
    (0.5, 15, 8),        # 7.5 rounds half-up to 8
    (7 / 15, 15, 7),     # exact-Fraction: 7.0, no float drift
    (0.375, 8, 3),
    (1 / 3, 9, 3),
    (0.0, 8, 0),
])
def test_coalition_counting_is_unified(fraction, n, expect):
    """Satellite (a): dense AdversarySpec.byz_config, the factory, and
    the schedule path all size the coalition through ONE half-up
    exact-``Fraction`` rule — boundary fractions can never round
    differently between backends."""
    assert count_for_fraction(fraction, n) == expect
    cfg = attacks.coalition_config("sign_flip", fraction, n)
    assert cfg.num_adversaries == (0 if expect == 0 else expect)
    spec = AdversarySpec("sign_flip", fraction)
    assert spec.byz_config(n, seed=0).num_adversaries == \
        cfg.num_adversaries
    # schedule resolution at a later step uses the same rule
    sched = AdversarySpec("none", 0.0, schedule=(
        attacks.AttackPhase(step=2, mode="sign_flip", fraction=fraction),))
    assert sched.byz_config_at(5, n, seed=0).num_adversaries == \
        cfg.num_adversaries
    assert sched.byz_config_at(1, n, seed=0).mode == "none"


def test_required_channel_rejects_mixing():
    assert attacks.required_channel(["sign_flip", "none"]) == "none"
    assert attacks.required_channel(["adaptive_flip", "colluding"]) == \
        "vote"
    with pytest.raises(ValueError, match="mixes observation channels"):
        attacks.required_channel(["adaptive_flip", "reputation"])


# ---------------------------------------------------------------------------
# adaptive sign semantics
# ---------------------------------------------------------------------------


def test_adaptive_evil_signs_requires_observation():
    cfg = attacks.build_config("adaptive_flip", 2)
    with pytest.raises(ValueError, match="observation channel"):
        attacks.adaptive_evil_signs(jnp.ones((4,), jnp.int8), cfg,
                                    jnp.int32(0), None)


def test_adaptive_flip_negates_prev_vote_honest_on_abstain():
    cfg = attacks.build_config("adaptive_flip", 1)
    signs = jnp.asarray([1, 1, -1, -1], jnp.int8)
    obs = {"prev_vote": jnp.asarray([1, -1, 0, 1], jnp.int8)}
    out = np.asarray(attacks.adaptive_evil_signs(signs, cfg,
                                                 jnp.int32(0), obs))
    # anti-vote where the vote spoke, honest where it abstained (incl.
    # the all-zero step-0 state => fully honest first round)
    assert out.tolist() == [-1, 1, -1, -1]
    zero = {"prev_vote": jnp.zeros((4,), jnp.int8)}
    assert np.array_equal(
        np.asarray(attacks.adaptive_evil_signs(signs, cfg, jnp.int32(0),
                                               zero)),
        np.asarray(signs))


def test_low_margin_strikes_smallest_tallies_only():
    cfg = attacks.build_config("low_margin", 1, target_fraction=0.25)
    n = 8
    signs = jnp.ones((n,), jnp.int8)
    pv = jnp.asarray([1, -1, 1, -1, 1, -1, 1, -1], jnp.int8)
    counts = jnp.asarray([7, 1, 6, 5, 3, 8, 2, 4], jnp.int32)
    out = np.asarray(attacks.adaptive_evil_signs(
        signs, cfg, jnp.int32(0), {"prev_vote": pv,
                                   "prev_abs_counts": counts}))
    # k = 0.25 * 8 = 2 smallest |tallies| (coords 1 and 6) flipped
    # AGAINST the previous vote; everywhere else honest
    assert out.tolist() == [1, 1, 1, 1, 1, 1, -1, 1]


def test_reputation_strikes_while_trusted():
    cfg = attacks.build_config("reputation", 2, strike_below=0.1)
    signs = jnp.ones((4,), jnp.int8)
    rep = jnp.asarray([0.0, 0.5], jnp.float32)
    struck = np.asarray(attacks.adaptive_evil_signs(
        signs, cfg, jnp.int32(0), {"rep": rep}))
    honest = np.asarray(attacks.adaptive_evil_signs(
        signs, cfg, jnp.int32(1), {"rep": rep}))
    # id 0 is fully trusted (EMA 0 < strike_below) -> strikes; id 1 is
    # burnt (0.5 >= strike_below) -> rebuilds by voting honestly
    assert struck.tolist() == [-1, -1, -1, -1]
    assert honest.tolist() == [1, 1, 1, 1]


# ---------------------------------------------------------------------------
# the attacker's memory
# ---------------------------------------------------------------------------


def test_attack_state_init_and_observation_slicing():
    st = attacks.AttackState.init(6, 4)
    assert st.prev_vote.shape == (6,) and st.prev_vote.dtype == jnp.int8
    assert st.prev_abs_counts.shape == (6,)
    assert st.rep.shape == (4,)
    assert st.observation("none") is None
    assert set(st.observation("vote")) == {"prev_vote"}
    assert set(st.observation("margin")) == {"prev_vote",
                                             "prev_abs_counts"}
    assert set(st.observation("reputation")) == {"rep"}
    with pytest.raises(ValueError, match="unknown observation channel"):
        st.observation("everything")


def test_attack_state_refit_pads_and_truncates():
    st = attacks.AttackState.init(3, 4)
    st = dataclasses.replace(st, rep=jnp.asarray([0.1, 0.2, 0.3, 0.4],
                                                 jnp.float32))
    grown = st.refit(6)
    assert np.allclose(np.asarray(grown.rep),
                       [0.1, 0.2, 0.3, 0.4, 0.0, 0.0])
    shrunk = st.refit(2)
    assert np.allclose(np.asarray(shrunk.rep), [0.1, 0.2])
    # per-coordinate arrays untouched
    assert grown.prev_vote.shape == (3,)


def test_attack_state_rep_replays_weighted_flip_ema_exactly():
    """The reputation channel is public bookkeeping: one round of
    update_attack_state must land on the very same EMA the weighted
    codec's decode_stacked computes from the same wire."""
    rng = np.random.default_rng(7)
    m, n = 5, 32
    eff = jnp.asarray(rng.choice([-1, 1], size=(m, n)).astype(np.int8))
    ema0 = jnp.asarray(rng.uniform(0, 0.6, size=m).astype(np.float32))
    vote, ema1 = weighted.decode_stacked(eff, ema0)
    st = dataclasses.replace(attacks.AttackState.init(n, m), rep=ema0)
    st = attacks.update_attack_state(st, vote, vote.astype(jnp.int32),
                                     eff)
    np.testing.assert_array_equal(np.asarray(st.rep), np.asarray(ema1))
    np.testing.assert_array_equal(np.asarray(st.prev_vote),
                                  np.asarray(vote))


def test_update_attack_state_population_touches_sampled_ids_only():
    st = attacks.AttackState.init(4, 6)
    st = dataclasses.replace(st, rep=jnp.full((6,), 0.4, jnp.float32))
    vote = jnp.asarray([1, -1, 1, -1], jnp.int8)
    st2 = attacks.update_attack_state_population(
        st, vote, vote.astype(jnp.int32),
        np.asarray([1, 4], np.int32), np.asarray([1.0, 0.0], np.float32))
    rep = np.asarray(st2.rep)
    # sampled ids move by the codec's (1-RHO)*ema + RHO*mis rule
    assert np.isclose(rep[1], (1 - weighted.RHO) * 0.4 + weighted.RHO)
    assert np.isclose(rep[4], (1 - weighted.RHO) * 0.4)
    # unsampled ids keep their EMA (mirrors the streamed codec update)
    assert np.allclose(rep[[0, 2, 3, 5]], 0.4)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def test_attack_phase_validation():
    with pytest.raises(ValueError, match="must be >= 1"):
        attacks.AttackPhase(step=0, fraction=0.5)
    with pytest.raises(ValueError, match="overrides nothing"):
        attacks.AttackPhase(step=3)
    with pytest.raises(ValueError, match=r"\[0, 1\]"):
        attacks.AttackPhase(step=3, fraction=1.5)
    with pytest.raises(ValueError, match="unknown AttackPhase.mode"):
        attacks.AttackPhase(step=3, mode="nope")


def test_validate_schedule_rejects_disorder():
    p2 = attacks.AttackPhase(step=2, fraction=0.25)
    p5 = attacks.AttackPhase(step=5, mode="colluding")
    attacks.validate_schedule((p2, p5))        # in order: fine
    with pytest.raises(ValueError, match="strictly increasing"):
        attacks.validate_schedule((p5, p2))
    with pytest.raises(ValueError, match="strictly increasing"):
        attacks.validate_schedule((p2, attacks.AttackPhase(
            step=2, mode="zero")))
    with pytest.raises(ValueError, match="must be AttackPhase"):
        attacks.validate_schedule(({"step": 2, "fraction": 0.5},))


def test_phase_at_inherits_unset_fields():
    sched = (attacks.AttackPhase(step=2, fraction=0.25),
             attacks.AttackPhase(step=4, mode="colluding"),
             attacks.AttackPhase(step=6, fraction=0.5, mode="none"))
    assert attacks.phase_at(sched, "sign_flip", 0.0, 1) == \
        ("sign_flip", 0.0)
    assert attacks.phase_at(sched, "sign_flip", 0.0, 2) == \
        ("sign_flip", 0.25)   # fraction overridden, mode inherited
    assert attacks.phase_at(sched, "sign_flip", 0.0, 5) == \
        ("colluding", 0.25)   # mode overridden, fraction carried over
    assert attacks.phase_at(sched, "sign_flip", 0.0, 99) == \
        ("none", 0.5)
    assert attacks.modes_used(sched, "sign_flip") == \
        ("sign_flip", "colluding", "none")


# ---------------------------------------------------------------------------
# AdversarySpec build-time validation + JSON round-trip
# ---------------------------------------------------------------------------


def test_adversary_spec_channel_must_match_mode():
    with pytest.raises(ValueError, match="consume the 'vote' channel"):
        AdversarySpec("adaptive_flip", 0.25)            # observe unset
    with pytest.raises(ValueError, match="consume the 'margin'"):
        AdversarySpec("low_margin", 0.25, observe="vote")
    with pytest.raises(ValueError, match="no adaptive mode consumes"):
        AdversarySpec("sign_flip", 0.25, observe="vote")
    ok = AdversarySpec("reputation", 0.25, observe="reputation")
    assert ok.adaptive
    assert not AdversarySpec("colluding", 0.25).adaptive


def test_adversary_spec_schedule_channel_resolution():
    # a sleeper schedule reaching an adaptive mode needs its channel,
    # even though the base mode is oblivious
    with pytest.raises(ValueError, match="consume the 'vote' channel"):
        AdversarySpec("none", 0.0, schedule=(
            attacks.AttackPhase(step=3, mode="adaptive_flip",
                                fraction=0.375),))
    spec = AdversarySpec("none", 0.0, observe="vote", schedule=(
        attacks.AttackPhase(step=3, mode="adaptive_flip",
                            fraction=0.375),))
    assert spec.phase_at(2) == ("none", 0.0)
    assert spec.phase_at(3) == ("adaptive_flip", 0.375)
    # two adaptive modes on different channels can never share a run
    with pytest.raises(ValueError, match="mixes observation channels"):
        AdversarySpec("adaptive_flip", 0.25, observe="vote", schedule=(
            attacks.AttackPhase(step=4, mode="reputation"),))


def test_scheduled_scenario_json_round_trip():
    spec = ScenarioSpec(
        "rt/sched", n_workers=8, n_steps=6, dim=32,
        strategy=VoteStrategy.ALLGATHER_1BIT, codec="weighted_vote",
        adversary=AdversarySpec(
            "none", 0.0, observe="reputation",
            schedule=(attacks.AttackPhase(step=2, mode="reputation",
                                          fraction=0.375),
                      attacks.AttackPhase(step=5, fraction=0.25))))
    back = ScenarioSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back == spec
    assert back.adversary.schedule[0] == attacks.AttackPhase(
        step=2, mode="reputation", fraction=0.375)


# ---------------------------------------------------------------------------
# VoteRequest.attack_obs validation
# ---------------------------------------------------------------------------


def _stacked_request(**kw):
    payload = jnp.ones((4, 16), jnp.int8)
    kw.setdefault("form", "stacked")
    kw.setdefault("strategy", VoteStrategy.ALLGATHER_1BIT)
    return va.VoteRequest(payload=payload, **kw)


def test_attack_obs_rejected_for_oblivious_modes():
    with pytest.raises(ValueError, match="oblivious or absent"):
        _stacked_request(
            failures=va.FailureSpec(byz=attacks.build_config(
                "sign_flip", 2)),
            attack_obs={"prev_vote": jnp.zeros((16,), jnp.int8)})


def test_attack_obs_required_and_exact_for_adaptive_modes():
    fails = va.FailureSpec(byz=attacks.build_config("adaptive_flip", 2))
    with pytest.raises(ValueError, match="must be a dict"):
        _stacked_request(failures=fails)
    with pytest.raises(ValueError, match="exactly the keys"):
        _stacked_request(failures=fails,
                         attack_obs={"prev_vote": jnp.zeros((16,),
                                                            jnp.int8),
                                     "rep": jnp.zeros((4,))})
    with pytest.raises(ValueError, match=r"shape \(16,\)"):
        _stacked_request(failures=fails,
                         attack_obs={"prev_vote": jnp.zeros((8,),
                                                            jnp.int8)})
    # the channel slice AttackState builds passes as-is
    st = attacks.AttackState.init(16, 4)
    req = _stacked_request(failures=fails, attack_obs=st.observation(
        "vote"))
    assert set(req.attack_obs) == {"prev_vote"}
    # leaf form has no broadcast-vote observation channel
    with pytest.raises(ValueError, match="stacked or streamed"):
        va.VoteRequest(payload=jnp.ones((16,)), form="leaf",
                       failures=fails,
                       attack_obs=st.observation("vote"))


def test_attack_obs_rep_covers_all_logical_voters():
    fails = va.FailureSpec(byz=attacks.build_config("reputation", 2))
    with pytest.raises(ValueError, match="every logical voter id"):
        _stacked_request(failures=fails,
                         attack_obs={"rep": jnp.zeros((2,), jnp.float32)})
    _stacked_request(failures=fails,
                     attack_obs={"rep": jnp.zeros((4,), jnp.float32)})


# ---------------------------------------------------------------------------
# end-to-end: determinism + the defense-aware degradation gate
# ---------------------------------------------------------------------------


def _adaptive_spec(name, mode, observe, **kw):
    kw.setdefault("strategy", VoteStrategy.ALLGATHER_1BIT)
    if kw.get("codec") == "weighted_vote":
        pass
    return ScenarioSpec(name, n_workers=8, n_steps=5, dim=24,
                        adversary=AdversarySpec(mode, 0.375,
                                                observe=observe), **kw)


@pytest.mark.parametrize("mode,observe,codec", [
    ("adaptive_flip", "vote", "sign1bit"),
    ("low_margin", "margin", "sign1bit"),
    ("reputation", "reputation", "weighted_vote"),
])
def test_adaptive_runs_are_deterministic(mode, observe, codec):
    spec = _adaptive_spec(f"det/{mode}", mode, observe, codec=codec)
    t1 = ScenarioRunner(spec, backend="virtual").run()
    t2 = ScenarioRunner(spec, backend="virtual").run()
    assert t1.digest == t2.digest
    # the adversary acted at SOME step (reputation oscillates honest/
    # strike, so the last step alone may be in the rebuild half)
    assert max(s.flip_fraction for s in t1.steps) > 0.0


def test_defense_aware_attacker_degrades_weighted_vote():
    """Acceptance gate: the reputation attacker measurably retains the
    reliability weight the flip-EMA strips from an oblivious coalition
    of the same size — the §15 defense-aware claim, asserted."""
    name, value, derived = bp.defense_degradation(
        fraction=0.3, n_workers=15, dim=48, n_steps=10)
    assert name == "breaking/defense_aware_degradation"
    assert value > 0.5, derived
