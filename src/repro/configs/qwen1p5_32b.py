"""qwen1.5-32b — dense transformer with QKV bias.

[hf:Qwen/Qwen1.5-0.5B family; hf]  64L d_model=5120 40H (GQA kv=40,
i.e. MHA) d_ff=27392 vocab=152064.
"""
from repro.configs.base import SKIP_LONG, ArchFamily, ModelConfig, register


@register("qwen1.5-32b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family=ArchFamily.DENSE,
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,
        d_ff=27392,
        vocab_size=152_064,
        head_dim=128,
        qkv_bias=True,
        tie_embeddings=False,
        act_seq_shard=True,
        kv_cache_dtype="int8",  # MHA cache at 32k x 128 needs 5.5TB bf16
        skip_shapes=(SKIP_LONG,),
    )
