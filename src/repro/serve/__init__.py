"""Continuous-batching serve engine + hot checkpoint swap (DESIGN.md §14).

The serving counterpart of the training stack: a fixed decode-slot pool
under one jitted step (``engine``), deterministic splitmix64-keyed
Poisson traffic (``traffic``), and the trainer->server parameter
handoff over atomic checkpoints (``swap``).
"""
from repro.serve.engine import (RequestRecord, ServeConfig, ServeEngine,
                                ServeReport)
from repro.serve.swap import (CheckpointEmitter, CheckpointWatcher,
                              ParamUpdate, like_tree)
from repro.serve.traffic import Request, poisson_requests

__all__ = [
    "CheckpointEmitter", "CheckpointWatcher", "ParamUpdate", "Request",
    "RequestRecord", "ServeConfig", "ServeEngine", "ServeReport",
    "like_tree", "poisson_requests",
]
