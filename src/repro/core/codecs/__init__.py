"""Gradient Codec subsystem (DESIGN.md §8).

    from repro.core import codecs
    codec = codecs.get_codec("ef_sign")

Four codecs ship (registry ``CODECS``):

| codec           | encode                      | decode                     | state        |
|-----------------|-----------------------------|----------------------------|--------------|
| ``sign1bit``    | raw signs (the paper)       | unweighted majority        | none         |
| ``ef_sign``     | signs of value + EF residual| unweighted majority        | worker       |
| ``ternary2bit`` | ternary symbols, 2-bit pack | sign of symbol sum (ties→0)| none         |
| ``weighted_vote``| raw signs                  | Chair–Varshney weighted    | server       |

``sign1bit`` is pinned bit-identical to the pre-codec wire path; the
others are the compression/robustness frontier every future compression
or defense PR plugs into.
"""
from repro.core.codecs.base import GradientCodec
from repro.core.codecs.ef_sign import EFSignCodec
from repro.core.codecs.sign1bit import Sign1BitCodec
from repro.core.codecs.ternary import TERNARY_WIRE, Ternary2BitCodec
from repro.core.codecs.weighted import (WeightedVoteCodec, decode_stacked,
                                        reliability_weights)

CODECS = {c.name: c for c in (Sign1BitCodec(), EFSignCodec(),
                              Ternary2BitCodec(), WeightedVoteCodec())}

DEFAULT_CODEC = "sign1bit"


def get_codec(name: str) -> GradientCodec:
    if name not in CODECS:
        raise ValueError(f"unknown codec {name!r}; have {sorted(CODECS)}")
    return CODECS[name]


def list_codecs():
    return tuple(sorted(CODECS))


__all__ = [
    "CODECS", "DEFAULT_CODEC", "EFSignCodec", "GradientCodec",
    "Sign1BitCodec", "TERNARY_WIRE", "Ternary2BitCodec",
    "WeightedVoteCodec", "decode_stacked", "get_codec", "list_codecs",
    "reliability_weights",
]
