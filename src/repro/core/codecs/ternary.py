"""``ternary2bit`` — abstain-capable 2-bit packed wire.

The 1-bit wire's defect (DESIGN.md §5) is that it cannot say "no vote":
abstentions (a zero gradient — an expert no token routed to, a crashed
worker's zero substitute) binarise to +1 at pack time, and ties resolve
+1. The integer-count strategies keep abstention but pay 8 bits/param.
This codec is the middle point: ternary symbols {-1, 0, +1} packed 16 per
uint32 (2-bit two's complement, ``sign_compress.pack_ternary``), so the
gathered exchange costs 2 bits/param — 2× the paper's wire, 16× under
fp32 — while the decode keeps full ternary semantics: majority = sign of
the symbol sum, abstentions abstain, ties → 0 on every transport.

Transports: on ``allgather_1bit``'s exchange shape the packed ternary
words replace the packed sign bits (the 2-bit wire proper, tallied by the
``kernels/ternary_pack.py`` Pallas kernel on the stacked path); on
``psum_int8`` the ternary symbols ARE the counts the strategy already
sums, so that transport is untouched — and bit-identical to ``sign1bit``
over it, which ``tests/test_codecs.py`` pins. ``hierarchical`` is
excluded: its 1-bit rebroadcast would re-binarise the decision and
silently destroy exactly what this codec buys.

Stateless on both sides.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import VoteStrategy
from repro.core import sign_compress as sc
from repro.core.codecs.base import GradientCodec


class TernaryWire:
    """The 2-bit packed transport, shaped like a VoteStrategyImpl's four
    stages so the mesh engine composes them over collectives and the
    virtual mesh replays them over a stacked voter dim (exchange is the
    only stage either path swaps)."""

    wire_bits_per_param = 2.0
    ties = "zero"

    def pack(self, signs: jax.Array, n_voters: int) -> jax.Array:
        padded, _ = sc.pad_last(signs, sc.PACK2)
        return sc.pack_ternary(padded)

    def exchange(self, wire: jax.Array, axes: Sequence[str]) -> jax.Array:
        packed = wire
        for a in axes:   # gather over each vote axis; leading M dims stack
            packed = compat.all_gather(packed, a, tiled=False)
        return packed.reshape((-1,) + packed.shape[len(tuple(axes)):])

    def tally(self, arrived: jax.Array, n_voters: int) -> jax.Array:
        counts = jnp.sum(sc.unpack_ternary(arrived, jnp.int32), axis=0)
        return jnp.sign(counts).astype(jnp.int8)   # decoded, not re-packed

    def unpack(self, decision: jax.Array, n: int, dtype) -> jax.Array:
        return decision[..., :n].astype(dtype)

    def vote(self, signs: jax.Array, axes: Sequence[str]) -> jax.Array:
        from repro.core.vote_engine import num_voters
        m = num_voters(axes)
        n = signs.shape[-1]
        return self.unpack(
            self.tally(self.exchange(self.pack(signs, m), axes), m),
            n, jnp.int8)


TERNARY_WIRE = TernaryWire()


class Ternary2BitCodec(GradientCodec):
    name = "ternary2bit"
    bits_per_param = 2.0
    supported_strategies = (VoteStrategy.PSUM_INT8,
                            VoteStrategy.ALLGATHER_1BIT)

    def ties(self, strategy: VoteStrategy) -> str:
        return "zero"   # ternary symbols carry abstention on every wire
