"""Fault-tolerance machinery: stragglers, elastic rescale, watchdog.

The paper's claim (§3.4) is that majority vote *is* the fault-tolerance
mechanism: any bounded-influence failure (stale vote, random bits, crash,
adversary) is just another ≤1-vote perturbation, covered by Theorem 2 up
to 50% bad replicas. This module supplies the runtime plumbing around
that property:

* ``simulate_stragglers`` — stale-vote substitution: a replica that misses
  the step deadline contributes its *previous* sign vector instead of
  blocking the step (synchronous step, no tail latency). In-JAX, used by
  tests/benchmarks to quantify convergence vs fraction-stale.
* ``ElasticPlan`` — host-side logic mapping a surviving device set to a
  new mesh and instructing the checkpoint reshard (vote semantics depend
  only on the replica *count*, so DP rescale is transparent; Mode A
  momenta are truncated / zero-padded by checkpoint.restore).
* ``Watchdog`` — wall-clock supervision of the train loop; on a stuck
  step (collective hang after a node failure) it triggers the
  restore-and-rescale path in launch/train.py.
* ``vote_with_failures`` (+ the codec/plan variants) — DEPRECATED shims
  over the vote API (DESIGN.md §10): the failure composition is now DATA
  on a :class:`~repro.core.vote_api.VoteRequest`
  (:class:`~repro.core.vote_api.FailureSpec`), executed by the same
  backend the trainer steps through — robustness experiments measure the
  production wire protocol, not a lookalike.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from fractions import Fraction
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat


# ---------------------------------------------------------------------------
# straggler mitigation (stale-vote substitution)
# ---------------------------------------------------------------------------


def simulate_stragglers(signs: jax.Array, prev_signs: jax.Array,
                        straggler_mask: jax.Array) -> jax.Array:
    """Elementwise: replicas flagged in `straggler_mask` (scalar bool per
    replica, e.g. from axis_index comparisons) vote with last step's signs."""
    return jnp.where(straggler_mask, prev_signs, signs)


def straggler_mask_for(axis_names: Sequence[str], n_stale: int,
                       like=None) -> jax.Array:
    """First `n_stale` replicas along the vote axes are stale this step.
    `like` anchors the legacy-JAX index emulation (compat.axis_index)."""
    from repro.core.byzantine import replica_index
    return replica_index(axis_names, like=like) < n_stale


def count_for_fraction(fraction: float, n_replicas: int) -> int:
    """Replicas a fraction maps to, with explicit half-up rounding so the
    boundary regimes land where the paper's figures put them (0.5 of 16
    -> 8, i.e. *exactly* 50% — the tie regime DESIGN.md §7 pins).

    The product is taken in exact rational arithmetic (the float value
    of ``fraction`` is honored bit-for-bit): at federated-scale
    populations ``int(fraction * n + 0.5)`` accumulates float error and
    can land one replica off the half-up boundary.
    """
    if not 0.0 <= fraction <= 1.0:
        raise ValueError(f"fraction {fraction} outside [0, 1]")
    half_up = int(Fraction(fraction) * n_replicas + Fraction(1, 2))
    return min(n_replicas, half_up)


def _failure_request(engine, payload, prev_signs, n_stale, step,
                     server_state=None, plan=None):
    """The legacy (engine, stale, adversary) triple as one declarative
    :class:`~repro.core.vote_api.VoteRequest` (prev-less calls keep the
    historical no-substitution semantics)."""
    from repro.core import vote_api as va
    return va.VoteRequest(
        payload=payload, form="leaf", strategy=engine.strategy,
        codec=engine.codec, plan=plan,
        failures=va.FailureSpec(
            n_stale=n_stale if prev_signs is not None else 0,
            byz=engine.byz),
        prev=prev_signs, step=step, salt=engine.salt,
        server_state=server_state)


def vote_with_failures(engine, signs: jax.Array,
                       prev_signs: Optional[jax.Array] = None,
                       n_stale: int = 0, step=None) -> jax.Array:
    """DEPRECATED shim: one aggregation under failures — stale-vote
    substitution, then the engine's compiled adversary, then the wire —
    now a :class:`~repro.core.vote_api.VoteRequest` with a
    :class:`~repro.core.vote_api.FailureSpec`, executed on the mesh
    backend."""
    from repro.core import vote_api as va
    va.warn_legacy("fault_tolerance.vote_with_failures")
    return va.MeshBackend(axes=engine.axes).execute(
        _failure_request(engine, signs, prev_signs, n_stale, step)).votes


def codec_vote_with_failures(engine, signs: jax.Array,
                             prev_signs: Optional[jax.Array] = None,
                             n_stale: int = 0, step=None,
                             server_state=None):
    """DEPRECATED shim: codec-aware :func:`vote_with_failures`; returns
    ``(vote, new_server_state)``."""
    from repro.core import vote_api as va
    va.warn_legacy("fault_tolerance.codec_vote_with_failures")
    out = va.MeshBackend(axes=engine.axes).execute(
        _failure_request(engine, signs, prev_signs, n_stale, step,
                         server_state))
    return out.votes, out.server_state


def plan_vote_with_failures(engine, plan, values: jax.Array,
                            prev_signs: Optional[jax.Array] = None,
                            n_stale: int = 0, step=None,
                            server_state=None):
    """DEPRECATED shim: bucketed :func:`vote_with_failures` (DESIGN.md
    §9) — the same failure composition applied once to the flat wire
    buffer, then the plan's bucket schedule; returns
    ``(vote, new_server_state)``."""
    from repro.core import vote_api as va
    va.warn_legacy("fault_tolerance.plan_vote_with_failures")
    out = va.MeshBackend(axes=engine.axes).execute(
        _failure_request(engine, values, prev_signs, n_stale, step,
                         server_state, plan=plan))
    return out.votes, out.server_state


# ---------------------------------------------------------------------------
# elastic rescale
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Mapping from a failure event to the survivor configuration."""

    old_shape: Tuple[int, ...]
    old_axes: Tuple[str, ...]
    new_shape: Tuple[int, ...]
    new_axes: Tuple[str, ...]
    note: str

    @property
    def new_replicas(self) -> int:
        n = 1
        for a, s in zip(self.new_axes, self.new_shape):
            if a in ("pod", "data"):
                n *= s
        return n


def plan_rescale(old_shape: Tuple[int, ...], old_axes: Tuple[str, ...],
                 surviving_devices: int) -> ElasticPlan:
    """Choose the survivor mesh after losing devices.

    Policy: keep the 'model' axis intact (TP degree is baked into layouts
    and kernels); shrink 'data' (and drop 'pod' if a whole pod died) to the
    largest power-of-two fit. The majority vote is indifferent to the DP
    width — Theorem 2's M simply decreases.
    """
    sizes = dict(zip(old_axes, old_shape))
    model = sizes.get("model", 1)
    if surviving_devices < model:
        raise ValueError(
            f"cannot keep TP degree {model} with {surviving_devices} devices")
    avail_dp = surviving_devices // model
    new_dp = 1
    while new_dp * 2 <= avail_dp:
        new_dp *= 2
    if "pod" in sizes and new_dp >= sizes["data"]:
        pods = new_dp // sizes["data"]
        return ElasticPlan(old_shape, old_axes,
                           (pods, sizes["data"], model),
                           ("pod", "data", "model"),
                           f"kept {pods} pod(s), data={sizes['data']}")
    return ElasticPlan(old_shape, old_axes, (new_dp, model),
                       ("data", "model"),
                       f"flattened to data={new_dp}, model={model}")


def make_mesh_from_plan(plan: ElasticPlan):
    return compat.make_mesh(
        plan.new_shape, plan.new_axes,
        axis_types=(compat.AxisType.Auto,) * len(plan.new_shape))


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------


class Watchdog:
    """Detects a stuck step (e.g. a collective hanging on a dead peer) and
    invokes `on_timeout`. Use as a context manager around blocking work."""

    def __init__(self, timeout_s: float,
                 on_timeout: Optional[Callable[[], None]] = None):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self.fired = False
        self._timer: Optional[threading.Timer] = None

    def _fire(self):
        self.fired = True
        if self.on_timeout is not None:
            self.on_timeout()

    def __enter__(self):
        self._timer = threading.Timer(self.timeout_s, self._fire)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, *exc):
        if self._timer is not None:
            self._timer.cancel()
        return False
