"""Time-varying coalitions: the adversary fraction/mode as a step
schedule (DESIGN.md §15).

An :class:`AttackPhase` is a step-keyed override in the style of the
Scenario Lab's ``ElasticEvent`` / ``ChurnEvent``: *at* ``step`` the
coalition's ``fraction`` and/or ``mode`` change, and stay changed until
a later phase overrides them again. Fields left ``None`` inherit —
a phase may grow the coalition without touching the mode, or swap a
sleeper coalition from ``"none"`` to ``"sign_flip"`` without restating
the fraction. Phases are JSON-round-trippable (plain dicts via
:func:`dataclasses.asdict`) so scheduled scenarios serialize through
``ScenarioSpec.to_dict``/``from_dict`` like every other axis.

Because the coalition is re-counted at each phase boundary through the
same exact-``Fraction`` rule as the base spec (``coalition_config``),
and because phase resolution is a pure function of the step, a schedule
composes freely with elastic rescale (the fraction re-applies to the
new M) and client churn (logical ids keep their adversary predicate).

``step`` must be >= 1: the pre-run coalition is the spec's own
``mode``/``fraction``, not a phase — a "phase at 0" would silently
shadow the base spec, so it is rejected instead.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.attacks.engine import ATTACK_MODES


@dataclasses.dataclass(frozen=True)
class AttackPhase:
    """At ``step``, override the coalition's ``fraction`` and/or
    ``mode`` (``None`` inherits the value in force)."""

    step: int
    fraction: Optional[float] = None
    mode: Optional[str] = None

    def __post_init__(self):
        from repro.core import byzantine
        if self.step < 1:
            raise ValueError(
                f"AttackPhase.step must be >= 1 (got {self.step}); the "
                "pre-run coalition is the AdversarySpec's own "
                "mode/fraction, not a phase")
        if self.fraction is None and self.mode is None:
            raise ValueError(
                f"AttackPhase(step={self.step}) overrides nothing — "
                "set fraction and/or mode")
        if self.fraction is not None and not 0.0 <= self.fraction <= 1.0:
            raise ValueError(f"AttackPhase.fraction must be in [0, 1], "
                             f"got {self.fraction}")
        if (self.mode is not None and self.mode not in byzantine.MODES
                and self.mode not in ATTACK_MODES):
            raise ValueError(
                f"unknown AttackPhase.mode {self.mode!r}; have "
                f"{byzantine.MODES} plus adaptive {ATTACK_MODES}")


def validate_schedule(schedule: Sequence[AttackPhase]) -> None:
    """Reject non-phase entries and non-strictly-increasing steps (two
    phases at one step would make "the value in force" order-dependent)."""
    prev = 0
    for p in schedule:
        if not isinstance(p, AttackPhase):
            raise ValueError(f"schedule entries must be AttackPhase, "
                             f"got {type(p).__name__}")
        if p.step <= prev:
            raise ValueError(
                f"AttackPhase steps must be strictly increasing, got "
                f"step {p.step} after {prev}")
        prev = p.step


def phase_at(schedule: Sequence[AttackPhase], base_mode: str,
             base_fraction: float, step: int) -> Tuple[str, float]:
    """The (mode, fraction) in force at ``step``: the base values with
    every phase whose ``step`` <= the query applied in order."""
    mode, fraction = base_mode, base_fraction
    for p in schedule:
        if p.step > step:
            break
        if p.mode is not None:
            mode = p.mode
        if p.fraction is not None:
            fraction = p.fraction
    return mode, fraction


def modes_used(schedule: Sequence[AttackPhase],
               base_mode: str) -> Tuple[str, ...]:
    """Every mode the run can be in (base + overrides), for channel
    resolution at build time."""
    modes = [base_mode] + [p.mode for p in schedule if p.mode is not None]
    return tuple(dict.fromkeys(modes))
