"""Training launcher with checkpoint/restart, watchdog and elastic rescale.

CPU-scale entry point (full-scale runs use the same code path under a real
TPU mesh — the mesh simply comes from jax.devices()):

  PYTHONPATH=src python -m repro.launch.train --arch zamba2-1.2b \\
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault-tolerance loop: every step runs under a Watchdog; on timeout or
crash the launcher restores the latest checkpoint (possibly onto a smaller
survivor mesh via distributed.fault_tolerance.plan_rescale) and resumes.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import AsyncCheckpointer, latest_step_dir, restore
from repro.configs.base import (SHAPES, OptimizerConfig, ShapeCell,
                                TrainConfig, get_config, reduced_config)
from repro.core import attacks
from repro.configs.presets import default_train_config
from repro.data.pipeline import SyntheticLMPipeline
from repro.distributed.fault_tolerance import Watchdog
from repro.models import model as M
from repro.obs import recorder as obs
from repro.train import train_step as TS


def build(arch: str, *, reduced: bool, batch: int, seq: int,
          opt_kind: str, lr: float, momentum: float, microbatches: int,
          byz_mode: str, byz_n: int):
    cfg = get_config(arch)
    if reduced:
        cfg = reduced_config(cfg)
    opt = OptimizerConfig(kind=opt_kind, learning_rate=lr, momentum=momentum)
    tcfg = TrainConfig(
        global_batch=batch, seq_len=seq, microbatches=microbatches,
        optimizer=opt,
        byzantine=attacks.build_config(byz_mode, byz_n))
    return cfg, tcfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--opt", default="signum_vote")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--byzantine", default="none")
    ap.add_argument("--adversaries", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--serve-dir", default=None,
                    help="publish params-only serving checkpoints here "
                         "(repro.serve.CheckpointWatcher hot-swaps them "
                         "into a live ServeEngine)")
    ap.add_argument("--serve-every", type=int, default=50,
                    help="publish to --serve-dir every N steps")
    ap.add_argument("--watchdog-s", type=float, default=600.0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    obs.add_trace_arg(ap)
    args = ap.parse_args()
    trace_rec = obs.activate_trace(args)
    rec = obs.get_recorder()

    cfg, tcfg = build(args.arch, reduced=args.reduced, batch=args.batch,
                      seq=args.seq, opt_kind=args.opt, lr=args.lr,
                      momentum=args.momentum,
                      microbatches=args.microbatches,
                      byz_mode=args.byzantine, byz_n=args.adversaries)
    art = TS.make_train_step(cfg, tcfg, mesh=None)
    params, opt_state = TS.materialize_state(
        cfg, tcfg, art, jax.random.PRNGKey(args.seed))
    pipe = SyntheticLMPipeline(cfg, args.batch, args.seq, seed=args.seed)

    emitter = None
    if args.serve_dir:
        from repro.serve import CheckpointEmitter
        emitter = CheckpointEmitter(args.serve_dir)

    ckpt: Optional[AsyncCheckpointer] = None
    start_step = 0
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        if latest_step_dir(args.ckpt_dir):
            params, opt_state, data_state, meta = restore(
                args.ckpt_dir, like_params=params, like_opt=opt_state)
            pipe.restore(data_state)
            start_step = int(meta["step"]) + 1
            print(f"restored checkpoint at step {meta['step']}")

    pipe.state.step = start_step
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        with Watchdog(args.watchdog_s) as wd:
            with rec.span("train.step", step=step) as sp:
                params, opt_state, metrics = art.step_fn(
                    params, opt_state, batch, jnp.int32(step))
                loss = float(metrics["loss"])
        if rec.enabled:
            rec.step(kind_detail="train", step=step, loss=loss,
                     arch=args.arch, opt=args.opt,
                     phase_s={"step": sp.dur_s})
        if wd.fired:
            raise TimeoutError(f"step {step} exceeded {args.watchdog_s}s")
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d}  loss {loss:8.4f}  "
                  f"({dt / max(step - start_step + 1, 1):.3f}s/step)",
                  flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step, params, opt_state, pipe.checkpoint(),
                      meta={"arch": args.arch, "step": step})
        if emitter and (step + 1) % args.serve_every == 0:
            with rec.span("serve.emit", step=step):
                emitter.emit(step, params, meta={"arch": args.arch})
    if ckpt:
        ckpt.save(args.steps - 1, params, opt_state, pipe.checkpoint(),
                  meta={"arch": args.arch, "step": args.steps - 1})
        ckpt.wait()
    if emitter and args.steps % args.serve_every != 0:
        emitter.emit(args.steps - 1, params, meta={"arch": args.arch})
    obs.finish_trace(trace_rec)
    print("done.")


if __name__ == "__main__":
    main()
