"""ScenarioRunner: deterministic failure drills through the vote path.

Executes a :class:`~repro.sim.scenario.ScenarioSpec` on the paper's toy
objective (the 1000-dim quadratic family of Fig. 1, reduced): every voter
m holds the true gradient ``x`` plus N(0, sigma^2) noise, keeps per-worker
SIGNUM momentum (Algorithm 1), and the update applies the majority vote of
the momenta's signs. What makes it a *failure drill* is everything between
the local sign and the decision: stale-vote straggler substitution,
Byzantine perturbation, and elastic voter-set rescale — all through the
SAME code the trainer compiles (``fault_tolerance.vote_with_failures`` /
``core.byzantine`` / the VoteEngine strategy stages).

Two interchangeable backends (bit-identical; asserted by tier-2):

* ``virtual`` — the host-count-independent virtual mesh
  (:mod:`repro.sim.virtual_mesh`): any M on any device count.
* ``mesh``    — the real thing: a ``shard_map`` over an M-wide 'data'
  axis calling ``fault_tolerance.vote_with_failures`` on actual mesh
  replicas (requires M <= local device count; the tier-2 harness runs it
  on the 8-virtual-device platform).

Every step emits a :class:`StepTrace` (vote margin, fraction of
coordinates flipped vs the honest-majority oracle, convergence proxy);
the run digest hashes the raw vote bytes, so "reproducible" means
bit-identical, not approximately-equal (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.checkpoint.checkpoint import (refit_leading_axis,
                                         refit_tree_leading_axis)
from repro.configs.base import VoteStrategy
from repro.core import codecs as codecs_mod
from repro.core import sign_compress as sc
from repro.core.vote_engine import STRATEGIES, VoteEngine
from repro.distributed.fault_tolerance import (codec_vote_with_failures,
                                               count_for_fraction,
                                               plan_vote_with_failures,
                                               vote_with_failures)
from repro.sim.scenario import ScenarioSpec
from repro.sim.virtual_mesh import (VirtualVoteEngine, virtual_plan_vote,
                                    virtual_vote, virtual_vote_codec)

BACKENDS = ("virtual", "mesh")


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StepTrace:
    """One step's structured trace record (schema: DESIGN.md §7)."""

    step: int
    n_workers: int
    n_adversaries: int
    n_stale: int
    margin: float          # mean |vote count| / M  (1 = unanimous)
    flip_fraction: float   # coords where vote != honest-majority oracle
    loss: float            # convergence proxy: 0.5 * mean(x^2) after update


@dataclasses.dataclass(frozen=True)
class ScenarioTrace:
    """Full run record: spec + per-step traces + bit-level digest."""

    spec: ScenarioSpec
    backend: str
    steps: Tuple[StepTrace, ...]
    digest: str            # sha256 over every step's raw vote bytes + x

    def summary(self) -> Dict[str, Any]:
        impl = STRATEGIES[self.spec.strategy]
        codec = codecs_mod.get_codec(self.spec.codec)
        d = self.spec.dim
        # price the exchange at each step's ACTUAL voter count (elastic
        # events change it mid-run); payload bytes/replica are
        # m-independent for every strategy (bits/param is fixed). The
        # gathered exchange scales with the codec's symbol width (§8).
        wire_scale = (codec.bits_per_param / impl.wire_bits_per_param
                      if self.spec.strategy == VoteStrategy.ALLGATHER_1BIT
                      else 1.0)
        if self.spec.plan.enabled:
            # bucketed wire: price the WHOLE schedule (one alpha term per
            # bucket message — comm_model.schedule_time); one plan build
            # per distinct voter count, not per step
            plans = {m: self.spec.runtime_plan(m)
                     for m in {s.n_workers for s in self.steps}}
            est = float(np.mean(
                [plans[s.n_workers].schedule_cost(s.n_workers)
                 for s in self.steps]))
            n_buckets = plans[self.steps[0].n_workers].n_buckets
        else:
            est = wire_scale * float(
                np.mean([impl.estimated_time(d, s.n_workers)
                         for s in self.steps]))
            n_buckets = 0
        return {
            "plan_buckets": n_buckets,
            "scenario": self.spec.name,
            "strategy": self.spec.strategy.value,
            "codec": self.spec.codec,
            "bits_per_param": codec.wire_bits(self.spec.strategy),
            "backend": self.backend,
            "tie_policy": self.spec.tie_policy,
            "first_loss": self.steps[0].loss,
            "final_loss": self.steps[-1].loss,
            "loss_drop": self.steps[0].loss - self.steps[-1].loss,
            "mean_margin": float(np.mean([s.margin for s in self.steps])),
            "mean_flip_fraction": float(
                np.mean([s.flip_fraction for s in self.steps])),
            "max_flip_fraction": float(
                np.max([s.flip_fraction for s in self.steps])),
            "wire_bytes_per_replica": d * codec.wire_bits(
                self.spec.strategy) / 8.0,
            "est_exchange_time_s": est,
            "digest": self.digest,
        }

    def to_dict(self) -> Dict[str, Any]:
        return {"spec": self.spec.to_dict(), "backend": self.backend,
                "digest": self.digest,
                "steps": [dataclasses.asdict(s) for s in self.steps],
                "summary": self.summary()}

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)


# ---------------------------------------------------------------------------
# deterministic keys (scenario id + step folded; DESIGN.md §7)
# ---------------------------------------------------------------------------


def _root_key(spec: ScenarioSpec) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(spec.seed), spec.salt)


def _noise(spec: ScenarioSpec, step: int, m: int) -> jax.Array:
    """Per-(scenario, step) gradient noise for m voters — independent of
    backend, device count and elastic history (shape depends only on the
    CURRENT voter count)."""
    key = jax.random.fold_in(jax.random.fold_in(_root_key(spec), 1), step)
    return jax.random.normal(key, (m, spec.dim), jnp.float32)


def _init_x(spec: ScenarioSpec) -> jax.Array:
    key = jax.random.fold_in(_root_key(spec), 0)
    return jax.random.normal(key, (spec.dim,), jnp.float32)


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------


class ScenarioRunner:
    """Executes one spec; ``run()`` returns the :class:`ScenarioTrace`.

    `backend` is "virtual" (default, host-count independent) or "mesh"
    (real shard_map collectives; every segment's voter count must fit the
    local device count). `mesh_style` picks the mesh layout for the mesh
    backend: "data_model" = an (M, 1) ('data', 'model') mesh, manual over
    'data' only — the trainer's partial-auto configuration, which on
    legacy JAX exercises the compat emulation layer; "data_only" = a
    fully-manual (M,) mesh using the native collective lowerings.
    """

    def __init__(self, spec: ScenarioSpec, backend: str = "virtual",
                 mesh_style: str = "data_model"):
        if backend not in BACKENDS:
            raise ValueError(f"backend {backend!r} not in {BACKENDS}")
        if mesh_style not in ("data_model", "data_only"):
            raise ValueError(f"unknown mesh_style {mesh_style!r}")
        self.spec = spec
        self.backend = backend
        self.mesh_style = mesh_style
        if backend == "mesh":
            need = max([spec.n_workers] + [e.n_workers for e in spec.elastic])
            have = len(jax.devices())
            if need > have:
                raise ValueError(
                    f"mesh backend needs {need} devices for "
                    f"{spec.name!r}, have {have} (use backend='virtual', "
                    "or XLA_FLAGS=--xla_force_host_platform_device_count=N)")

    # ---- per-segment compiled pieces (rebuilt at elastic boundaries) ----

    def _segment(self, m: int):
        spec = self.spec
        codec = codecs_mod.get_codec(spec.codec)
        byz_cfg = spec.adversary.byz_config(m, spec.seed)
        byz = byz_cfg if byz_cfg.mode != "none" else None
        n_stale = count_for_fraction(spec.straggler_fraction, m)
        veng = VirtualVoteEngine(spec.strategy, byz, spec.salt,
                                 codec=spec.codec)
        beta = spec.momentum
        has_ef = codec.worker_state
        # the bucketed wire schedule (§9); rebuilt per segment because
        # only the hierarchical alignment depends on the voter count
        plan = spec.runtime_plan(m)

        @jax.jit
        def prepare(x, v, err, prev, cstate, noise, step):
            g = x[None, :] + spec.noise_scale * noise
            v2 = beta * v + (1.0 - beta) * g if beta > 0 else g
            # codec encode: fold the EF residual into the vote input (§8);
            # t == v2 for residual-free codecs, so the legacy path is
            # bit-identical
            t = err + v2 if has_ef else v2
            fresh = sc.sign_ternary(t)
            eff = veng.effective_signs(t, prev, n_stale, step)
            # honest-majority oracle through the SAME codec decode (and
            # the same bucket schedule when the plan axis is on); state
            # is read-only here — the oracle must not advance the
            # reliability EMA
            if plan is not None:
                oracle, _ = virtual_plan_vote(fresh, plan, cstate)
            else:
                oracle, _ = virtual_vote_codec(fresh, spec.strategy,
                                               spec.codec, cstate)
            counts = jnp.sum(eff.astype(jnp.int32), axis=0)
            margin = jnp.mean(jnp.abs(counts).astype(jnp.float32)) / m
            return v2, t, fresh, eff, oracle, margin

        @jax.jit
        def finish(x, vote, oracle):
            flip = jnp.mean((vote != oracle).astype(jnp.float32))
            x2 = x - spec.learning_rate * vote.astype(jnp.float32)
            loss = 0.5 * jnp.mean(x2 * x2)
            return x2, flip, loss

        @jax.jit
        def ef_feedback(t, vote):
            # per-worker residual vs the APPLIED vote (codec feedback_leaf
            # semantics, vmapped over the stacked voter dim)
            scale = jnp.mean(jnp.abs(t), axis=1, keepdims=True)
            return t - scale * vote[None, :].astype(t.dtype)

        if self.backend == "mesh":
            mesh_vote = self._mesh_vote_fn(m, byz, n_stale, plan)
        else:
            mesh_vote = None
        return (prepare, finish, ef_feedback, mesh_vote, byz_cfg, n_stale,
                plan)

    def _mesh_vote_fn(self, m: int, byz, n_stale: int, plan=None):
        """jit(shard_map(vote_with_failures)) over an M-wide 'data' axis —
        the production wire path on real mesh replicas. Codec-parametric:
        non-default codecs route through ``codec_vote_with_failures``,
        server-stateful ones thread their replicated decode memory, and a
        plan-enabled spec walks the bucket schedule through
        ``plan_vote_with_failures`` (§9)."""
        from jax.sharding import Mesh, PartitionSpec as P
        spec = self.spec
        codec = codecs_mod.get_codec(spec.codec)
        devs = np.array(jax.devices()[:m])
        if self.mesh_style == "data_model":
            mesh = Mesh(devs.reshape(m, 1), ("data", "model"))
            manual = {"data"}
        else:
            mesh = Mesh(devs, ("data",))
            manual = {"data"}
        engine = VoteEngine(strategy=spec.strategy, axes=("data",),
                            byz=byz, salt=spec.salt, codec=spec.codec)

        if plan is not None:
            if plan.has_server_state:
                def f_plan_state(vals, prev, step, cstate):
                    out, new_state = plan_vote_with_failures(
                        engine, plan, vals[0], prev[0], n_stale=n_stale,
                        step=step, server_state=cstate)
                    return out[None], new_state

                sh = compat.shard_map(
                    f_plan_state, mesh=mesh,
                    in_specs=(P("data"), P("data"), P(), P()),
                    out_specs=(P("data"), P()), axis_names=manual,
                    check_vma=False)
                return jax.jit(sh)

            def f_plan(vals, prev, step):
                out, _ = plan_vote_with_failures(
                    engine, plan, vals[0], prev[0], n_stale=n_stale,
                    step=step)
                return out[None]

            sh = compat.shard_map(
                f_plan, mesh=mesh, in_specs=(P("data"), P("data"), P()),
                out_specs=P("data"), axis_names=manual, check_vma=False)
            return jax.jit(sh)

        if codec.server_state:
            def f_state(vals, prev, step, cstate):
                out, new_state = codec_vote_with_failures(
                    engine, vals[0], prev[0], n_stale=n_stale, step=step,
                    server_state=cstate)
                return out[None], new_state

            sh = compat.shard_map(
                f_state, mesh=mesh,
                in_specs=(P("data"), P("data"), P(), P()),
                out_specs=(P("data"), P()), axis_names=manual,
                check_vma=False)
            return jax.jit(sh)

        if spec.codec != "sign1bit":
            def f_codec(vals, prev, step):
                out, _ = codec_vote_with_failures(
                    engine, vals[0], prev[0], n_stale=n_stale, step=step)
                return out[None]

            sh = compat.shard_map(
                f_codec, mesh=mesh, in_specs=(P("data"), P("data"), P()),
                out_specs=P("data"), axis_names=manual, check_vma=False)
            return jax.jit(sh)

        def f(vals, prev, step):
            out = vote_with_failures(engine, vals[0], prev[0],
                                     n_stale=n_stale, step=step)
            return out[None]

        sh = compat.shard_map(
            f, mesh=mesh, in_specs=(P("data"), P("data"), P()),
            out_specs=P("data"), axis_names=manual, check_vma=False)
        return jax.jit(sh)

    # ---- the drill ----

    def run(self) -> ScenarioTrace:
        spec = self.spec
        codec = codecs_mod.get_codec(spec.codec)
        x = _init_x(spec)
        m = spec.workers_at(0)
        v = jnp.zeros((m, spec.dim), jnp.float32)        # per-worker momentum
        # codec worker state: the EF residual, stacked like the momentum
        err = jnp.zeros((m, spec.dim), jnp.float32)
        # last step's locally COMPUTED signs (pre-stale, pre-adversary):
        # that is what a straggler re-submits; failures then apply to the
        # substituted vector (vote_with_failures order)
        prev = jnp.zeros((m, spec.dim), jnp.int8)
        prepare, finish, ef_feedback, mesh_vote, byz_cfg, n_stale, plan = \
            self._segment(m)
        # codec server state: replicated decode memory (reliability EMA);
        # under a plan the schedule's codec set decides what exists
        if plan is not None:
            cstate = plan.init_server_state(m)
        else:
            cstate = (codec.init_server_state(m) if codec.server_state
                      else {})
        stateful = bool(cstate)
        digest = hashlib.sha256()
        steps: List[StepTrace] = []
        for step in range(spec.n_steps):
            m_now = spec.workers_at(step)
            if m_now != m:
                # elastic rescale: per-worker state — momentum, EF
                # residual, stale vector, reliability EMA — refits by the
                # checkpoint rule (truncate / zero-pad axis 0, §6):
                # joiners start with zero momentum, zero residual, an
                # abstaining stale vector, and the uninformed-prior weight
                v = jnp.asarray(refit_leading_axis(
                    np.asarray(v), (m_now, spec.dim)))
                err = jnp.asarray(refit_leading_axis(
                    np.asarray(err), (m_now, spec.dim)))
                prev = jnp.asarray(refit_leading_axis(
                    np.asarray(prev), (m_now, spec.dim)))
                cstate = jax.tree.map(
                    jnp.asarray, refit_tree_leading_axis(
                        cstate, {k: (m_now,) + tuple(a.shape[1:])
                                 for k, a in cstate.items()}))
                m = m_now
                prepare, finish, ef_feedback, mesh_vote, byz_cfg, \
                    n_stale, plan = self._segment(m)
            noise = _noise(spec, step, m)
            step_t = jnp.int32(step)
            v, t, fresh, eff, oracle, margin = prepare(x, v, err, prev,
                                                       cstate, noise,
                                                       step_t)
            if self.backend == "mesh":
                # host round-trips keep every array uncommitted: jit
                # outputs committed to one segment's mesh devices would
                # conflict with the next segment's (smaller) mesh
                args = (np.asarray(t), np.asarray(prev), np.int32(step))
                if stateful:
                    out, new_state = mesh_vote(
                        *args, {k: np.asarray(a) for k, a in
                                cstate.items()})
                    cstate = {k: jnp.asarray(np.asarray(a))
                              for k, a in new_state.items()}
                else:
                    out = mesh_vote(*args)
                vote = jnp.asarray(np.asarray(out)[0].astype(np.int8))
            elif plan is not None:
                vote, cstate = virtual_plan_vote(eff, plan, cstate)
            else:
                vote, cstate = virtual_vote_codec(eff, spec.strategy,
                                                  spec.codec, cstate)
            x, flip, loss = finish(x, vote, oracle)
            if codec.worker_state:
                err = ef_feedback(t, vote)
            prev = fresh
            digest.update(np.asarray(vote).tobytes())
            steps.append(StepTrace(
                step=step, n_workers=m,
                n_adversaries=byz_cfg.num_adversaries, n_stale=n_stale,
                margin=float(margin), flip_fraction=float(flip),
                loss=float(loss)))
        digest.update(np.asarray(x, np.float32).tobytes())
        return ScenarioTrace(spec=spec, backend=self.backend,
                             steps=tuple(steps), digest=digest.hexdigest())


def run_scenarios(specs, backend: str = "virtual",
                  mesh_style: str = "data_model") -> List[ScenarioTrace]:
    return [ScenarioRunner(s, backend=backend, mesh_style=mesh_style).run()
            for s in specs]
