"""Sign extraction and 1-bit packing (pure-jnp reference layer).

Two sign conventions coexist (DESIGN.md §5):

* ``sign_ternary`` — ``jnp.sign`` semantics, 0 maps to 0. Used by the
  integer-sum vote strategies; a zero gradient (e.g. an expert no local
  token routed to) *abstains* rather than voting +1.
* ``sign_binary``  — ``x >= 0 -> +1 else -1``. The 1-bit wire format of the
  paper: a packed bit can only encode two states.

Packing is 32 signs per uint32 word, little-endian within the word. The
Pallas kernels in ``repro.kernels`` implement the same layout; these jnp
versions are their oracles and the fallback path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

PACK = 32


def sign_ternary(x: jax.Array) -> jax.Array:
    return jnp.sign(x).astype(jnp.int8)


def sign_binary(x: jax.Array) -> jax.Array:
    return jnp.where(x >= 0, jnp.int8(1), jnp.int8(-1))


def pad_to_pack(flat: jax.Array, multiple: int = PACK) -> Tuple[jax.Array, int]:
    """Pad 1-D array to a multiple; returns (padded, original_len)."""
    n = flat.shape[0]
    rem = (-n) % multiple
    if rem:
        flat = jnp.pad(flat, (0, rem))
    return flat, n


def pack_signs(x: jax.Array) -> jax.Array:
    """x (..., n) any real dtype, n % 32 == 0 -> uint32 (..., n // 32).

    bit j of word w encodes sign(x[..., 32*w + j]) >= 0.
    """
    assert x.shape[-1] % PACK == 0, x.shape
    bits = (x >= 0).astype(jnp.uint32)
    words = bits.reshape(x.shape[:-1] + (x.shape[-1] // PACK, PACK))
    # unrolled shift/OR: an or-reduction is not lowerable by the CPU SPMD
    # partitioner (observed on the 256-device dry-run)
    acc = jnp.zeros(words.shape[:-1], jnp.uint32)
    for j in range(PACK):
        acc = acc | (words[..., j] << jnp.uint32(j))
    return acc


def unpack_signs(packed: jax.Array, dtype=jnp.int8) -> jax.Array:
    """uint32 (..., w) -> (..., 32*w) of ±1 in `dtype`."""
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    signs = jnp.where(bits == 1, 1, -1).astype(dtype)
    return signs.reshape(packed.shape[:-1] + (packed.shape[-1] * PACK,))


def popcount(x: jax.Array) -> jax.Array:
    """Per-word population count of a uint32 array (SWAR)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x + (x >> 4)) & 0x0F0F0F0F
    return ((x * 0x01010101) >> 24).astype(jnp.int32)


def packed_majority(packed: jax.Array) -> jax.Array:
    """(M, w) packed votes -> (w,) packed majority.

    Bit-sliced: for each bit position count set bits across M workers;
    majority bit = count*2 > M (ties -> +1, consistent with sign_binary).
    """
    M = packed.shape[0]
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)   # (M, w, 32)
    counts = jnp.sum(bits.astype(jnp.int32), axis=0)       # (w, 32)
    maj = (2 * counts >= M).astype(jnp.uint32)
    return jnp.bitwise_or.reduce(maj << shifts, axis=-1)


def compression_ratio(dtype: jnp.dtype) -> float:
    """Wire compression vs a dense gradient of `dtype` (per direction)."""
    return jnp.dtype(dtype).itemsize * 8.0
