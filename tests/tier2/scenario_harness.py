"""Scenario Lab multi-device validation harness, run in a subprocess by
test_harness8.py (so the main pytest session keeps 1 CPU device).

On an 8-device host platform it validates the Scenario Lab's central
claim — the virtual mesh IS the wire path:

  1. compat shims on 8 devices: axis_index / all_gather partial-auto
     emulations, straggler_mask_for, and apply_adversary (mesh) ==
     apply_adversary_stacked (virtual) for every stochastic mode;
  2. mesh backend == virtual backend, bit for bit (digest equality), for
     every strategy x adversary-mode x straggler x elastic composition,
     on both mesh styles (partial-auto 'data_model' and fully-manual
     'data_only');
  3. the honest path decides bit-identically across all three strategies
     on the mesh backend (odd voter count).

Run with ``virtual-only`` as argv[1] to skip the mesh half — the parent
test runs that mode under a 1-device platform and diffs the printed
VDIGEST lines against the 8-device run, which is the "reproducible
across host counts" guarantee, asserted rather than assumed.
"""
import os
import sys

if os.environ.get("XLA_FLAGS") is None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import AxisType
from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import byzantine, sign_compress as sc
from repro.distributed import fault_tolerance as ft
from repro.sim import (AdversarySpec, ElasticEvent, PlanSpec,
                       ScenarioRunner, ScenarioSpec)

RNG = np.random.default_rng(0)


def harness_specs():
    S = VoteStrategy
    return [
        # odd voter count: honest path must be strategy-independent
        ScenarioSpec("h8/honest7", n_workers=7, n_steps=5, dim=129,
                     strategy=S.PSUM_INT8),
        ScenarioSpec("h8/flip_stale", n_workers=8, n_steps=5, dim=128,
                     strategy=S.ALLGATHER_1BIT,
                     adversary=AdversarySpec("sign_flip", 0.25),
                     straggler_fraction=0.25),
        ScenarioSpec("h8/random", n_workers=8, n_steps=5, dim=100,
                     strategy=S.PSUM_INT8,
                     adversary=AdversarySpec("random", 0.375)),
        ScenarioSpec("h8/blind_half", n_workers=8, n_steps=5, dim=96,
                     strategy=S.HIERARCHICAL,
                     adversary=AdversarySpec("blind", 0.5, flip_prob=0.8)),
        ScenarioSpec("h8/zero", n_workers=8, n_steps=4, dim=64,
                     strategy=S.HIERARCHICAL,
                     adversary=AdversarySpec("zero", 0.25)),
        ScenarioSpec("h8/collude_elastic", n_workers=8, n_steps=9, dim=64,
                     strategy=S.PSUM_INT8,
                     adversary=AdversarySpec("colluding", 0.375),
                     straggler_fraction=0.125,
                     elastic=(ElasticEvent(3, 4, "pod loss"),
                              ElasticEvent(6, 6, "rejoin"))),
        # codec axis (DESIGN.md §8): every non-default codec through the
        # same mesh==virtual and host-count-invariance gauntlet
        ScenarioSpec("h8/ef_flip_stale", n_workers=8, n_steps=6, dim=100,
                     strategy=S.ALLGATHER_1BIT, codec="ef_sign",
                     adversary=AdversarySpec("sign_flip", 0.25),
                     straggler_fraction=0.25),
        ScenarioSpec("h8/ternary_random", n_workers=8, n_steps=6, dim=90,
                     strategy=S.ALLGATHER_1BIT, codec="ternary2bit",
                     adversary=AdversarySpec("random", 0.375)),
        ScenarioSpec("h8/weighted_flip_elastic", n_workers=8, n_steps=8,
                     dim=96, strategy=S.ALLGATHER_1BIT,
                     codec="weighted_vote",
                     adversary=AdversarySpec("sign_flip", 0.375),
                     elastic=(ElasticEvent(4, 6, "pod loss"),)),
        # VotePlan axis (DESIGN.md §9): bucketed wire schedules through
        # the same mesh==virtual and host-count-invariance gauntlet —
        # a mixed-codec plan under a colluding coalition, a weighted
        # plan crossing an elastic rescale, and a bucketed hierarchical
        # wire with stragglers
        ScenarioSpec("h8/plan_mixed_collude", n_workers=8, n_steps=6,
                     dim=128, strategy=S.ALLGATHER_1BIT,
                     adversary=AdversarySpec("colluding", 0.375),
                     plan=PlanSpec(bucket_bytes=8,
                                   leaves=(("embed.table", 48),
                                           ("body.w", 80)),
                                   codec_map=(("embed*", "ternary2bit"),
                                              ("*", "sign1bit")))),
        ScenarioSpec("h8/plan_weighted_elastic", n_workers=8, n_steps=8,
                     dim=96, strategy=S.ALLGATHER_1BIT,
                     codec="weighted_vote",
                     adversary=AdversarySpec("sign_flip", 0.375),
                     elastic=(ElasticEvent(4, 6, "pod loss"),),
                     plan=PlanSpec(bucket_bytes=6)),
        ScenarioSpec("h8/plan_hier_stale", n_workers=8, n_steps=5,
                     dim=100, strategy=S.HIERARCHICAL,
                     straggler_fraction=0.25,
                     adversary=AdversarySpec("random", 0.25),
                     plan=PlanSpec(bucket_bytes=5)),
    ]


# ---------------------------------------------------------------------------
# 1. compat shims on the 8-device mesh
# ---------------------------------------------------------------------------


def check_compat_shims_8dev():
    mesh = compat.make_mesh((8, 1), ("data", "model"),
                            axis_types=(AxisType.Auto,) * 2)

    def f(x):
        idx = compat.axis_index("data", like=x)       # emulated on legacy
        g = compat.all_gather(x[0], "data", tiled=False)
        mask = ft.straggler_mask_for(("data",), 3, like=x)
        return (jnp.full((1,), idx, jnp.int32),
                g[None],
                jnp.full((1,), mask))

    sh = compat.shard_map(f, mesh=mesh, in_specs=(P("data"),),
                          out_specs=(P("data"), P("data"), P("data")),
                          axis_names={"data"}, check_vma=False)
    x = jnp.asarray(RNG.normal(size=(8, 12)).astype(np.float32))
    idx, gathered, mask = jax.jit(sh)(x)
    np.testing.assert_array_equal(np.asarray(idx), np.arange(8))
    for r in range(8):
        np.testing.assert_array_equal(np.asarray(gathered)[r],
                                      np.asarray(x))
    np.testing.assert_array_equal(np.asarray(mask),
                                  np.arange(8) < 3)
    print("OK compat shims on 8 devices (axis_index/all_gather/mask)")


def check_adversary_mesh_equals_stacked():
    """apply_adversary on 8 real replicas == apply_adversary_stacked on
    the stacked tensor — the lemma behind mesh==virtual, directly."""
    mesh = compat.make_mesh((8, 1), ("data", "model"),
                            axis_types=(AxisType.Auto,) * 2)
    signs = jnp.asarray(
        RNG.integers(-1, 2, size=(8, 77)).astype(np.int8))
    for mode in ("sign_flip", "zero", "random", "colluding", "blind"):
        cfg = ByzantineConfig(mode=mode, num_adversaries=3, seed=5,
                              flip_prob=0.7)

        def f(s, step):
            out = byzantine.apply_adversary(s[0], cfg, ("data",),
                                            step=step, salt=99)
            return out[None]

        sh = compat.shard_map(f, mesh=mesh, in_specs=(P("data"), P()),
                              out_specs=P("data"), axis_names={"data"},
                              check_vma=False)
        got = np.asarray(jax.jit(sh)(signs, jnp.int32(4)))
        want = np.asarray(byzantine.apply_adversary_stacked(
            signs, cfg, step=jnp.int32(4), salt=99))
        np.testing.assert_array_equal(got, want, err_msg=mode)
    print("OK apply_adversary mesh == stacked for every mode")


# ---------------------------------------------------------------------------
# 2./3. backend bit-identity
# ---------------------------------------------------------------------------


def check_backends(mesh_too: bool):
    for spec in harness_specs():
        tv = ScenarioRunner(spec, backend="virtual").run()
        print(f"VDIGEST {spec.name} {tv.digest}")
        if not mesh_too:
            continue
        styles = ("data_model", "data_only") \
            if spec.name == "h8/flip_stale" else ("data_model",)
        for style in styles:
            tm = ScenarioRunner(spec, backend="mesh",
                                mesh_style=style).run()
            assert tm.digest == tv.digest, (
                f"{spec.name} [{style}]: mesh != virtual "
                f"({tm.digest[:12]} vs {tv.digest[:12]})")
        print(f"OK mesh == virtual: {spec.name}")


def check_honest_mesh_strategy_identity():
    digests = {}
    for strategy in (VoteStrategy.PSUM_INT8, VoteStrategy.ALLGATHER_1BIT,
                     VoteStrategy.HIERARCHICAL):
        spec = ScenarioSpec("h8/honest_id", n_workers=7, n_steps=4, dim=96,
                            strategy=strategy)
        digests[strategy.value] = ScenarioRunner(
            spec, backend="mesh").run().digest
    assert len(set(digests.values())) == 1, digests
    print("OK honest path bit-identical across strategies on the mesh")


if __name__ == "__main__":
    virtual_only = len(sys.argv) > 1 and sys.argv[1] == "virtual-only"
    check_backends(mesh_too=not virtual_only)
    if not virtual_only:
        check_compat_shims_8dev()
        check_adversary_mesh_equals_stacked()
        check_honest_mesh_strategy_identity()
    print("ALL SCENARIO HARNESS CHECKS PASSED")
