"""Pallas TPU kernel: popcount majority vote over packed sign words.

The "server" inner loop of the paper-faithful ``allgather_1bit`` strategy:
after the packed all-gather every chip holds (M, w) uint32 words and must
produce the (w,) packed majority. Bit-sliced counting: for each of the 32
bit positions, count set bits across the M voters (vectorised over the
word/lane dim), compare against M/2, re-pack. No unpacking to float ever
touches HBM — the whole vote is integer VPU work on VMEM tiles.

Block shape: (M, 512) words per grid step (M is small — the vote runs over
data-parallel replicas, 16..32 — so a whole voter column fits VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK = 32
WBLOCK = 512


def _majority_kernel(p_ref, out_ref, *, m_voters: int):
    p = p_ref[...]                                    # (M, WBLOCK) uint32
    acc = jnp.zeros((p.shape[1],), jnp.uint32)
    for j in range(PACK):                             # bit-sliced count
        bits = (p >> jnp.uint32(j)) & jnp.uint32(1)   # (M, W)
        cnt = jnp.sum(bits.astype(jnp.int32), axis=0)  # (W,)
        maj = (2 * cnt >= m_voters).astype(jnp.uint32)
        acc = acc | (maj << jnp.uint32(j))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def majority_packed(packed: jax.Array, *, interpret: bool = False
                    ) -> jax.Array:
    """packed (M, w) uint32, w % 512 == 0 -> (w,) packed majority."""
    m, w = packed.shape
    grid = (w // WBLOCK,)
    return pl.pallas_call(
        functools.partial(_majority_kernel, m_voters=m),
        grid=grid,
        in_specs=[pl.BlockSpec((m, WBLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((WBLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.uint32),
        interpret=interpret,
    )(packed)
