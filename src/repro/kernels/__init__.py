"""Pallas TPU kernels for the paper's compute hot spots (bit-pack, popcount
majority vote, fused SIGNUM update) with jnp oracles in ref.py."""
from repro.kernels import ops, ref  # noqa: F401
