"""Pallas TPU kernel: fused sign-extraction + bit-pack + popcount majority.

The VoteEngine's single-pass local tally (DESIGN.md §2): given the M
voters' raw real-valued tensors — momenta in the host-local simulation
path, or the would-be wire payloads in the benchmarks — produce the packed
uint32 majority words directly. The separate ``bitpack`` (pack each voter)
and ``vote`` (popcount over packed words) kernels made M+1 passes over HBM
and materialised M packed intermediates; this kernel reads the (M, n)
source once and writes only the n/32-word decision:

    bits    = x >= 0                      (sign extraction, binary wire
                                           convention: ties -> +1)
    counts  = sum over M of bits          (bit-sliced popcount)
    maj     = 2*counts >= M
    words   = pack 32 maj bits per uint32 (little-endian within the word)

Pure VPU bit arithmetic on VMEM tiles, bandwidth-bound by design: one read
of the sign source, one 1/(32*M)-size write. The MXU is not involved.

Block shapes: input (M, 4096) fp32/bf16 -> output (128,) uint32 per grid
step; M is small (the vote runs over data-parallel replicas, 16..32) so a
whole voter column fits VMEM (M=32 fp32: 512 KB per block).

``kernels/ref.py`` (``ref.fused_majority``) is the correctness oracle;
``kernels/ops.fused_majority`` is the shape-handling public wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK = 32
WORDS = 128  # output lane dim; input lane dim = 32*128 = 4096


def _fused_majority_kernel(x_ref, out_ref, *, m_voters: int):
    x = x_ref[...]                                    # (M, WORDS*32) real
    bits = (x >= 0).astype(jnp.int32)
    counts = jnp.sum(bits, axis=0)                    # (WORDS*32,) popcount
    maj = (2 * counts >= m_voters).astype(jnp.uint32)
    maj = maj.reshape(WORDS, PACK)
    acc = jnp.zeros((WORDS,), jnp.uint32)
    for j in range(PACK):                             # unrolled shift/OR tree
        acc = acc | (maj[:, j] << jnp.uint32(j))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_majority_2d(x: jax.Array, *, interpret: bool = False) -> jax.Array:
    """x (M, n) real with n % 4096 == 0 -> (n // 32,) uint32 packed majority.

    bit j of word k encodes majority(x[:, 32*k + j] >= 0), ties -> +1.
    """
    m, n = x.shape
    w = n // PACK
    grid = (w // WORDS,)
    return pl.pallas_call(
        functools.partial(_fused_majority_kernel, m_voters=m),
        grid=grid,
        in_specs=[pl.BlockSpec((m, WORDS * PACK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((WORDS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.uint32),
        interpret=interpret,
    )(x)
