"""Whisper-style encoder-decoder transformer.

The conv frontend is a STUB per the brief: ``input_specs()`` feeds
precomputed mel-frame embeddings ``(B, T_src, d)``; the encoder adds a
learned positional table and runs bidirectional blocks. The decoder is
causal with cross-attention against the encoder output; positions are
fixed sinusoids (the learned-table difference is immaterial for the
systems study and noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import BATCH, shard
from repro.models import layers as L


def _tree(p, prefix):
    return {k[len(prefix):]: v for k, v in p.items() if k.startswith(prefix)}


def encoder_forward(p: Dict[str, jax.Array], enc_embeds: jax.Array, cfg,
                    hook=None, remat: str = "none") -> jax.Array:
    """enc_embeds (B, T_src, d) -> (B, T_src, d)."""
    from repro.models.transformer import maybe_remat
    T = enc_embeds.shape[1]
    h = enc_embeds + p["enc_embed.pos"][:T].astype(enc_embeds.dtype)
    ep = _tree(p, "encoder.")

    def body(carry, layer_p):
        if hook is not None:
            layer_p = hook(layer_p, "layers")
        x = L.rms_norm(carry, layer_p["norm1_scale"], cfg.norm_eps)
        attn_out, _ = L.self_attention_block(
            layer_p, "attn", x, cfg, causal=False, use_rope=False)
        carry = carry + attn_out
        x = L.rms_norm(carry, layer_p["norm2_scale"], cfg.norm_eps)
        carry = carry + L.swiglu_mlp(layer_p, "mlp", x)
        return shard(carry, BATCH, None, None), None

    h, _ = jax.lax.scan(maybe_remat(body, remat), h, ep)
    return L.rms_norm(h, p["enc_final_norm.scale"], cfg.norm_eps)


def decoder_forward(p: Dict[str, jax.Array], h: jax.Array, enc: jax.Array,
                    cfg, hook=None, remat: str = "none") -> jax.Array:
    """h (B,S,d) token embeddings (+sinusoid positions added by caller)."""
    from repro.models.transformer import maybe_remat
    lp = _tree(p, "layers.")

    def body(carry, layer_p):
        if hook is not None:
            layer_p = hook(layer_p, "layers")
        x = L.rms_norm(carry, layer_p["norm1_scale"], cfg.norm_eps)
        attn_out, _ = L.self_attention_block(
            layer_p, "attn", x, cfg, causal=True, use_rope=False)
        carry = carry + attn_out
        x = L.rms_norm(carry, layer_p["norm_xattn_scale"], cfg.norm_eps)
        k, v = L.project_kv_cross(layer_p, "xattn", enc, cfg)
        carry = carry + L.cross_attention_block(layer_p, "xattn", x, k, v, cfg)
        x = L.rms_norm(carry, layer_p["norm2_scale"], cfg.norm_eps)
        carry = carry + L.swiglu_mlp(layer_p, "mlp", x)
        return shard(carry, BATCH, None, None), None

    h, _ = jax.lax.scan(maybe_remat(body, remat), h, lp)
    return h


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def encdec_init_cache(p, cfg, batch: int, max_len: int, t_src: int, dtype
                      ) -> Dict[str, jax.Array]:
    K, hd, Ld = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_layers
    return {
        "k": jnp.zeros((Ld, batch, max_len, K, hd), dtype),
        "v": jnp.zeros((Ld, batch, max_len, K, hd), dtype),
        "xk": jnp.zeros((Ld, batch, t_src, K, hd), dtype),
        "xv": jnp.zeros((Ld, batch, t_src, K, hd), dtype),
    }


def encdec_precompute_cross(p: Dict[str, jax.Array], enc: jax.Array, cfg
                            ) -> Tuple[jax.Array, jax.Array]:
    """Per-layer cross-attention K/V from the encoder output."""
    lp = _tree(p, "layers.")

    def body(carry, layer_p):
        k, v = L.project_kv_cross(layer_p, "xattn", enc, cfg)
        return carry, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, lp)
    return ks, vs


def encdec_decode_step(p: Dict[str, jax.Array], h: jax.Array, cache,
                       pos: jax.Array, cfg):
    """h (B,1,d); cache from encdec_init_cache with xk/xv filled."""
    lp = _tree(p, "layers.")

    def body(carry, xs):
        layer_p, k_c, v_c, xk, xv = xs
        x = L.rms_norm(carry, layer_p["norm1_scale"], cfg.norm_eps)
        attn_out, k_c, v_c = L.decode_self_attention(
            layer_p, "attn", x, cfg, k_cache=k_c, v_cache=v_c, pos=pos,
            use_rope=False)
        carry = carry + attn_out
        x = L.rms_norm(carry, layer_p["norm_xattn_scale"], cfg.norm_eps)
        carry = carry + L.cross_attention_block(layer_p, "xattn", x, xk, xv, cfg)
        x = L.rms_norm(carry, layer_p["norm2_scale"], cfg.norm_eps)
        carry = carry + L.swiglu_mlp(layer_p, "mlp", x)
        return carry, (k_c, v_c)

    h, (ks, vs) = jax.lax.scan(
        body, h, (lp, cache["k"], cache["v"], cache["xk"], cache["xv"]))
    return h, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}
