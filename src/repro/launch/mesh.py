"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ('data', 'model').
Multi-pod:  (2, 16, 16) = 512 chips, axes ('pod', 'data', 'model') — the
'pod' axis carries only int8 vote counts (DESIGN.md §2).

A function, not a module-level constant: importing this module never
touches jax device state.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=(compat.AxisType.Auto,) * len(shape))


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def pod_stride(mesh) -> int:
    """Linear device-id stride between pods (for HLO group attribution)."""
    sizes = mesh_axis_sizes(mesh)
    return sizes.get("data", 1) * sizes.get("model", 1)
