import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16,16) or (2,16,16), the
abstract (never-allocated) train/serve state, lowers the jitted step,
compiles it, and records:

  * memory_analysis()        — proves the cell fits per-chip HBM,
  * cost_analysis()          — HLO FLOPs / bytes for the roofline,
  * parsed collective bytes  — the roofline's collective term
                               (launch.hlo_stats),
  * the config fingerprint (params, active params, mode, vote strategy).

Results append to a JSON-lines file consumed by benchmarks/roofline.py
and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k
  python -m repro.launch.dryrun --all                     # every cell
  python -m repro.launch.dryrun --all --multi-pod         # 512-chip mesh
  python -m repro.launch.dryrun --arch X --shape Y --opt sgdm   # baseline
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import SHAPES, get_config, list_archs
from repro.configs.presets import MODE_B_ARCHS, default_train_config
from repro.launch.hlo_stats import parse_collectives, summarize
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes, pod_stride
from repro.models import model as M
from repro.train import serve_step as SS, train_step as TS


def skip_reason(arch: str, shape: str) -> Optional[str]:
    cfg = get_config(arch)
    for name, reason in cfg.skip_shapes:
        if name == shape:
            return reason
    return None


def _compile_stats(lowered, mesh) -> Dict[str, Any]:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compat.cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    colls = parse_collectives(hlo, pod_stride(mesh))
    n_chips = mesh.devices.size
    return {
        "compile_s": round(compile_s, 1),
        "flops_per_chip": float(cost.get("flops", 0.0)),
        "hbm_bytes_per_chip": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_chip": (mem.argument_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    + mem.output_size_in_bytes
                                    - mem.alias_size_in_bytes),
        },
        "collectives": summarize(colls),
        "n_chips": n_chips,
    }


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             opt_kind: str = "signum_vote",
             vote_strategy: Optional[str] = None) -> Dict[str, Any]:
    """Lower + compile one cell; returns the stats record."""
    from repro.configs.base import VoteStrategy

    record: Dict[str, Any] = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "opt": opt_kind, "status": "ok",
    }
    reason = skip_reason(arch, shape)
    if reason:
        record.update(status="skip", reason=reason)
        return record

    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    record["params"] = cfg.param_count()
    record["active_params"] = cfg.active_param_count()

    vs = VoteStrategy(vote_strategy) if vote_strategy else None
    with compat.set_mesh(mesh):
        if cell.kind == "train":
            tcfg = default_train_config(arch, cell, kind=opt_kind,
                                        vote_strategy=vs)
            record["mode"] = tcfg.optimizer.momentum_mode.value
            record["fsdp"] = tcfg.fsdp
            record["microbatches"] = tcfg.microbatches
            record["remat"] = tcfg.remat
            art = TS.make_train_step(cfg, tcfg, mesh)
            # post-resolution (AUTO has been priced against the mesh here)
            record["vote_strategy"] = (
                art.vote_strategy.value if art.vote_strategy is not None
                else "per_bucket")   # mixed-strategy VotePlan schedule
            p_abs, o_abs = TS.abstract_state(cfg, tcfg, art, mesh)
            batch_struct = M.input_specs(cfg, cell)["batch"]
            batch_abs = {
                k: jax.ShapeDtypeStruct(
                    v.shape, v.dtype,
                    sharding=NamedSharding(mesh, art.batch_spec[k]))
                for k, v in batch_struct.items()}
            step_abs = jax.ShapeDtypeStruct((), jnp.int32,
                                            sharding=NamedSharding(mesh, P()))
            lowered = art.step_fn.lower(p_abs, o_abs, batch_abs, step_abs)
        else:
            fsdp = arch in MODE_B_ARCHS
            record["fsdp"] = fsdp
            inputs = SS.abstract_serve_inputs(cfg, cell, mesh, fsdp=fsdp)
            if cell.kind == "prefill":
                fn = SS.make_prefill_sharded(
                    cfg, mesh, fsdp=fsdp, global_batch=cell.global_batch)
                lowered = fn.lower(inputs["params"], inputs["batch"])
            else:
                fn = SS.make_decode_step(cfg)
                lowered = fn.lower(inputs["params"], inputs["tokens"],
                                   inputs["cache"], inputs["pos"])
        record.update(_compile_stats(lowered, mesh))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", default="signum_vote")
    ap.add_argument("--vote-strategy", default=None)
    ap.add_argument("--out", default="dryrun_results.jsonl")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    with open(args.out, "a") as f:
        for arch, shape in cells:
            print(f"=== {arch} x {shape} "
                  f"({'2x16x16' if args.multi_pod else '16x16'}) ===",
                  flush=True)
            try:
                rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                               opt_kind=args.opt,
                               vote_strategy=args.vote_strategy)
            except Exception as e:  # record failures; the dry-run must not die
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if args.multi_pod else "16x16",
                       "opt": args.opt, "status": "error",
                       "error": f"{type(e).__name__}: {e}"}
            f.write(json.dumps(rec) + "\n")
            f.flush()
            status = rec["status"]
            if status == "ok":
                mem = rec["memory"]["peak_bytes_per_chip"] / 2**30
                print(f"  ok: {rec['flops_per_chip']:.3e} flops/chip, "
                      f"peak {mem:.2f} GiB/chip, "
                      f"{rec['collectives']['n_collectives']} collectives, "
                      f"compile {rec['compile_s']}s", flush=True)
            else:
                print(f"  {status}: {rec.get('reason', rec.get('error'))}",
                      flush=True)


if __name__ == "__main__":
    main()
