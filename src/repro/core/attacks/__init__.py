"""Adaptive / scheduled / defense-aware adversaries (DESIGN.md §15).

The adversary surface as a subsystem with its own state discipline:
:mod:`~repro.core.attacks.engine` holds the adaptive sign transforms,
the :class:`AttackState` observation memory, and the sanctioned
``ByzantineConfig`` factories; :mod:`~repro.core.attacks.schedule`
holds the step-keyed time-varying coalition. ``breaking_point`` (the
measured-vs-predicted fraction sweep) imports the Scenario Lab and is
deliberately NOT imported here — ``core.byzantine`` lazily dispatches
into this package from inside the vote, and pulling ``sim`` in at that
point would be a cycle.
"""
from repro.core.attacks.engine import (ATTACK_MODES, CHANNEL_KEYS,
                                       MODE_CHANNEL, OBSERVE_CHANNELS,
                                       AttackState, adaptive_evil_signs,
                                       build_config, coalition_config,
                                       required_channel,
                                       update_attack_state,
                                       update_attack_state_population)
from repro.core.attacks.schedule import (AttackPhase, modes_used,
                                         phase_at, validate_schedule)

__all__ = [
    "ATTACK_MODES", "CHANNEL_KEYS", "MODE_CHANNEL", "OBSERVE_CHANNELS",
    "AttackPhase", "AttackState", "adaptive_evil_signs", "build_config",
    "coalition_config", "modes_used", "phase_at", "required_channel",
    "update_attack_state", "update_attack_state_population",
    "validate_schedule",
]
