"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Routing: softmax router, top-k experts per token. Dispatch: tokens are
sorted by expert id and gathered into an ``(E, C, d)`` buffer (capacity
``C = ceil(T*k/E * capacity_factor)``); tokens beyond capacity are dropped
(standard GShard semantics). Expert GEMMs run as batched ``(E, C, d) x
(E, d, f)`` einsums so the expert axis shards over ``'model'`` (EP) and the
token gather/scatter lowers to an all-to-all on real meshes.

Covers both assigned MoE archs: qwen2-moe (4 shared experts merged into one
5632-wide branch with a learned sigmoid gate) and qwen3-moe (pure routed).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import BATCH, mesh_axis_size, shard
from repro.models.layers import swiglu_mlp


def _capacity(num_tokens: int, num_experts: int, top_k: int,
              factor: float) -> int:
    cap = int(math.ceil(num_tokens * top_k / num_experts * factor))
    return max(8, int(math.ceil(cap / 8)) * 8)  # pad to 8 for TPU tiling


def route_topk(router_logits: jax.Array, top_k: int
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(T, E) -> (weights (T,k), experts (T,k), aux_loss scalar).

    Router probabilities are renormalised over the selected top-k (qwen
    convention). Aux loss is the standard Switch load-balancing loss.
    """
    T, E = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # load-balancing aux: E * sum_e f_e * p_e
    one_hot = jax.nn.one_hot(experts, E, dtype=jnp.float32)  # (T,k,E)
    frac_tokens = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * mean_probs)
    return weights, experts, aux


def moe_ffn(p: Dict[str, jax.Array], x: jax.Array, moe_cfg
            ) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (out (B,S,d), aux_loss).

    Expects params: router_w (d,E), experts_w_gate/up (E,d,f),
    experts_w_down (E,f,d); optionally shared_* for the shared branch.
    """
    B, S, d = x.shape
    T = B * S
    E, k = moe_cfg.num_experts, moe_cfg.top_k
    C = _capacity(T, E, k, moe_cfg.capacity_factor)

    xt = x.reshape(T, d)
    logits = xt @ p["router_w"]
    weights, experts, aux = route_topk(logits, k)

    # ---- sort-based dispatch ----
    flat_expert = experts.reshape(-1)                       # (T*k,)
    flat_token = jnp.repeat(jnp.arange(T), k)               # token of each slot
    flat_weight = weights.reshape(-1)
    order = jnp.argsort(flat_expert)                        # stable
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_weight = flat_weight[order]
    # position within expert segment
    same = jnp.cumsum(jax.nn.one_hot(sorted_expert, E, dtype=jnp.int32),
                      axis=0)
    pos_in_expert = jnp.take_along_axis(
        same, sorted_expert[:, None], axis=1)[:, 0] - 1     # (T*k,)
    keep = pos_in_expert < C
    # scatter slot -> (E, C) token index buffer (dropped slots point at T,
    # a zero pad row)
    slot_dest = sorted_expert * C + pos_in_expert
    slot_dest = jnp.where(keep, slot_dest, E * C)           # overflow bin
    buf_token = jnp.full((E * C + 1,), T, dtype=jnp.int32)
    buf_token = buf_token.at[slot_dest].set(sorted_token.astype(jnp.int32))
    buf_weight = jnp.zeros((E * C + 1,), dtype=jnp.float32)
    buf_weight = buf_weight.at[slot_dest].set(sorted_weight)
    buf_token = buf_token[: E * C].reshape(E, C)
    buf_weight = buf_weight[: E * C].reshape(E, C)

    # gather tokens into expert buffers (pad row T = zeros)
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    expert_in = jnp.take(xt_pad, buf_token, axis=0)         # (E, C, d)
    # EP when E divides the model axis; otherwise TP-within-expert over f
    # (qwen2-moe: 60 experts on a 16-wide axis).
    ep = E % max(mesh_axis_size("model"), 1) == 0
    if ep:
        expert_in = shard(expert_in, "model", None, None)

    # ---- expert GEMMs (batched over E) ----
    gate = jnp.einsum("ecd,edf->ecf", expert_in, p["experts_w_gate"])
    up = jnp.einsum("ecd,edf->ecf", expert_in, p["experts_w_up"])
    if ep:
        gate = shard(gate, "model", None, None)
        up = shard(up, "model", None, None)
    else:
        gate = shard(gate, None, None, "model")
        up = shard(up, None, None, "model")
    h = jax.nn.silu(gate) * up
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["experts_w_down"])

    # ---- combine: weighted scatter-add back to tokens ----
    # constrain BEFORE the scatter: the scatter's backward is a take whose
    # cotangent is otherwise unconstrained on E — the partitioner then
    # computes dW with E replicated and gathers the expert weights to match
    if ep:
        expert_out = shard(expert_out, "model", None, None)
    expert_out = expert_out * buf_weight[..., None].astype(expert_out.dtype)
    if ep:
        expert_out = shard(expert_out, "model", None, None)
    out = jnp.zeros((T + 1, d), expert_out.dtype)
    out = out.at[buf_token.reshape(-1)].add(
        expert_out.reshape(E * C, d))
    out = out[:T]

    # ---- shared-expert branch (qwen2-moe) ----
    if "shared_w_gate" in p:
        shared = swiglu_mlp(p, "shared", x).reshape(T, d)
        gate_logit = xt @ p["shared_gate_w"]                # (T,1)
        out = out + jax.nn.sigmoid(
            gate_logit.astype(jnp.float32)).astype(shared.dtype) * shared

    out = out.reshape(B, S, d)
    return shard(out, BATCH, None, None), aux * moe_cfg.router_aux_weight
