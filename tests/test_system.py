"""End-to-end behaviour tests for the paper's system.

Covers the full production path at CPU scale: launcher-driven training,
checkpoint/restart bit-equivalence, Byzantine training robustness, and the
serving driver.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import AsyncCheckpointer, restore
from repro.configs.base import (ByzantineConfig, OptimizerConfig,
                                TrainConfig, VoteStrategy, get_config,
                                reduced_config)
from repro.data.pipeline import SyntheticLMPipeline
from repro.models import model as M
from repro.train import train_step as TS
from repro.train.serve_step import make_decode_step


def _setup(arch="glm4-9b", lr=3e-3, byz=None, steps_cfg=None, seed=0):
    cfg = reduced_config(get_config(arch), num_layers=2)
    tcfg = TrainConfig(
        global_batch=8, seq_len=32,
        optimizer=OptimizerConfig(kind="signum_vote", learning_rate=lr),
        byzantine=byz or ByzantineConfig())
    art = TS.make_train_step(cfg, tcfg, mesh=None)
    params, opt_state = TS.materialize_state(cfg, tcfg, art,
                                             jax.random.PRNGKey(seed))
    pipe = SyntheticLMPipeline(cfg, 8, 32, seed=seed)
    return cfg, tcfg, art, params, opt_state, pipe


def _train(art, params, opt_state, pipe, steps, start=0):
    losses = []
    pipe.state.step = start
    for step in range(start, start + steps):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt_state, met = art.step_fn(params, opt_state, batch,
                                             jnp.int32(step))
        losses.append(float(met["loss"]))
    return params, opt_state, losses


def test_training_learns_synthetic_distribution():
    # fresh Markov data every step: signSGD descends slowly but steadily
    cfg, tcfg, art, params, opt_state, pipe = _setup(lr=1e-2)
    _, _, losses = _train(art, params, opt_state, pipe, 150)
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    assert last < first - 0.3, (first, last)


def test_checkpoint_restart_bit_equivalence(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    cfg, tcfg, art, params, opt_state, pipe = _setup()
    p_straight, o_straight, _ = _train(art, params, opt_state, pipe, 6)

    cfg2, tcfg2, art2, params2, opt2, pipe2 = _setup()
    params2, opt2, _ = _train(art2, params2, opt2, pipe2, 3)
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(2, params2, opt2, pipe2.checkpoint())
    ck.wait()

    cfg3, tcfg3, art3, params3, opt3, pipe3 = _setup()
    params3, opt3, ds, meta = restore(str(tmp_path), like_params=params3,
                                      like_opt=opt3)
    pipe3.restore(ds)
    params3 = jax.tree.map(jnp.asarray, params3)
    opt3 = jax.tree.map(jnp.asarray, opt3)
    p_resumed, o_resumed, _ = _train(art3, params3, opt3, pipe3, 3, start=3)

    for k in p_straight:
        np.testing.assert_array_equal(
            np.asarray(p_straight[k]), np.asarray(p_resumed[k]), err_msg=k)


@pytest.mark.parametrize("n_adv,should_learn", [(0, True)])
def test_byzantine_single_process_noop(n_adv, should_learn):
    """Byzantine config with M=1 honest replica trains normally (the
    adversarial sweep itself runs in the distributed harness / benches)."""
    byz = ByzantineConfig(mode="sign_flip", num_adversaries=n_adv)
    cfg, tcfg, art, params, opt_state, pipe = _setup(byz=byz, lr=1e-2)
    _, _, losses = _train(art, params, opt_state, pipe, 100)
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    assert (last < first - 0.2) == should_learn, (first, last)


def test_serve_prefill_then_decode_consistency():
    """Prefill + decode continuation equals pure decode-from-scratch."""
    cfg = reduced_config(get_config("glm4-9b"), num_layers=2)
    cfg = dataclasses.replace(cfg, dtype="float32")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    S = 12
    tokens = jax.random.randint(key, (2, S), 0, cfg.vocab_size, jnp.int32)

    logits_pf, cache_pf = M.prefill(cfg, params, {"tokens": tokens})
    decode = make_decode_step(cfg)

    cache = M.init_cache(cfg, 2, S)
    for t in range(S):
        logits_t, cache = decode(params, tokens[:, t:t + 1], cache,
                                 jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_t),
                               np.asarray(logits_pf[:, -1]),
                               rtol=2e-3, atol=2e-3)
    # cache contents agree where populated
    np.testing.assert_allclose(np.asarray(cache["k"]),
                               np.asarray(cache_pf["k"]),
                               rtol=2e-3, atol=2e-3)


def test_vote_strategies_agree_end_to_end():
    """One train step under each vote strategy yields identical params in
    the single-process (M=1) limit."""
    outs = {}
    for strat in VoteStrategy:
        cfg = reduced_config(get_config("glm4-9b"), num_layers=1)
        tcfg = TrainConfig(
            global_batch=4, seq_len=16,
            optimizer=OptimizerConfig(kind="signum_vote", learning_rate=1e-3,
                                      vote_strategy=strat))
        art = TS.make_train_step(cfg, tcfg, mesh=None)
        params, opt = TS.materialize_state(cfg, tcfg, art,
                                           jax.random.PRNGKey(0))
        batch = M.make_batch(cfg, 4, 16, jax.random.PRNGKey(1))
        p2, _, _ = art.step_fn(params, opt, batch, jnp.int32(0))
        outs[strat] = p2
    base = outs[VoteStrategy.PSUM_INT8]
    for strat, p in outs.items():
        for k in base:
            np.testing.assert_array_equal(np.asarray(base[k]),
                                          np.asarray(p[k]),
                                          err_msg=f"{strat} {k}")
