"""Public jit'd wrappers around the Pallas kernels.

Handle arbitrary 1-D/N-D inputs (pad + reshape to the kernels' tiled 2-D
layout), and dispatch ``interpret=True`` automatically on non-TPU backends
so the same call sites work in CPU tests and on real hardware.

Every wrapper counts its invocations under ``kernel.launches.<name>``
in the global :data:`repro.obs.COUNTERS` registry (one wrapper call =
one ``pallas_call`` in the lowered program, so inside ``jit`` the count
taken at trace time equals launches per execution). The VotePlan
benchmark (``benchmarks/bench_vote_plan.py``) reads these counters to
prove the bucketed path issues one fused-kernel launch per bucket where
the leaf-wise path launched once per tensor. :data:`LAUNCHES` remains
as a deprecation shim over the registry; `launch_counts` /
`reset_launch_counts` are the supported surface.
"""
from __future__ import annotations

import functools
from collections.abc import Mapping
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import (bitpack as _bp, fused_vote as _fv,
                           signum_update as _su, ternary_pack as _tp,
                           vote as _vt)
from repro.obs.recorder import COUNTERS, warn_deprecated

PACK = 32
PACK2 = 16
TILE = 8 * 128 * PACK  # elements per (ROWS, WORDS*32) block
TILE2 = 8 * 128 * PACK2  # elements per (ROWS, WORDS*16) ternary block

#: the registry namespace of the kernel-launch counters
LAUNCH_PREFIX = "kernel.launches."


def _launch(name: str) -> None:
    COUNTERS.inc(LAUNCH_PREFIX + name)


def reset_launch_counts() -> None:
    COUNTERS.reset(LAUNCH_PREFIX)


def launch_counts() -> Dict[str, int]:
    return {k[len(LAUNCH_PREFIX):]: v
            for k, v in COUNTERS.snapshot(LAUNCH_PREFIX).items()}


class _LaunchShim(Mapping):
    """DEPRECATED Counter-alike view of the ``kernel.launches.*``
    registry namespace (the old module-global). Reads/writes go straight
    through to :data:`repro.obs.COUNTERS`, so the cross-run clobber
    hazard of a second mutable accounting surface is gone."""

    def __getitem__(self, name: str) -> int:
        warn_deprecated("kernels.ops.LAUNCHES",
                        "read repro.obs.COUNTERS (kernel.launches.*) or "
                        "ops.launch_counts()")
        return COUNTERS.get(LAUNCH_PREFIX + name)

    def __setitem__(self, name: str, value: int) -> None:
        warn_deprecated("kernels.ops.LAUNCHES",
                        "read repro.obs.COUNTERS (kernel.launches.*) or "
                        "ops.launch_counts()")
        COUNTERS.set(LAUNCH_PREFIX + name, int(value))

    def __iter__(self):
        return iter(launch_counts())

    def __len__(self) -> int:
        return len(launch_counts())

    def clear(self) -> None:
        reset_launch_counts()


#: DEPRECATED shim (see :class:`_LaunchShim`)
LAUNCHES = _LaunchShim()


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_2d(flat: jax.Array) -> Tuple[jax.Array, int]:
    """Pad a 1-D array to a TILE multiple and reshape (rows, 4096)."""
    n = flat.shape[0]
    rem = (-n) % TILE
    if rem:
        flat = jnp.pad(flat, (0, rem))
    return flat.reshape(-1, 128 * PACK), n


def bitpack(x: jax.Array) -> jax.Array:
    """Any-shape real array -> (ceil(n/32),) uint32 of packed sign bits
    (padding bits are sign(0)=+1)."""
    _launch("bitpack")
    flat2d, n = _to_2d(x.reshape(-1))
    packed = _bp.bitpack_2d(flat2d, interpret=_interpret())
    return packed.reshape(-1)[: -(-n // PACK)]


def bitunpack(packed: jax.Array, n: int, dtype=jnp.float32) -> jax.Array:
    """(w,) uint32 -> (n,) ±1 `dtype` (first n of 32*w)."""
    _launch("bitunpack")
    w = packed.shape[0]
    rem = (-w) % (8 * 128)
    if rem:
        packed = jnp.pad(packed, (0, rem))
    out = _bp.bitunpack_2d(packed.reshape(-1, 128), dtype,
                           interpret=_interpret())
    return out.reshape(-1)[:n]


def fused_majority(x: jax.Array) -> jax.Array:
    """(M, n) real voter values -> (ceil(n/32),) uint32 packed majority in
    ONE pass (fused sign+bitpack+popcount; ties and padding -> sign(0)=+1)."""
    _launch("fused_majority")
    m, n = x.shape
    rem = (-n) % (128 * PACK)
    if rem:
        x = jnp.pad(x, ((0, 0), (0, rem)))
    packed = _fv.fused_majority_2d(x, interpret=_interpret())
    return packed[: -(-n // PACK)]


def majority(packed: jax.Array) -> jax.Array:
    """(M, w) uint32 -> (w,) packed majority (ties -> +1)."""
    _launch("majority")
    m, w = packed.shape
    rem = (-w) % _vt.WBLOCK
    if rem:
        packed = jnp.pad(packed, ((0, 0), (0, rem)))
    return _vt.majority_packed(packed, interpret=_interpret())[:w]


def ternary_pack(s: jax.Array) -> jax.Array:
    """Any-shape ternary sign array -> (ceil(n/16),) uint32 of packed 2-bit
    symbols (padding fields are 0 = abstain)."""
    _launch("ternary_pack")
    flat = s.reshape(-1).astype(jnp.int32)
    n = flat.shape[0]
    rem = (-n) % TILE2
    if rem:
        flat = jnp.pad(flat, (0, rem))
    packed = _tp.ternary_pack_2d(flat.reshape(-1, 128 * PACK2),
                                 interpret=_interpret())
    return packed.reshape(-1)[: -(-n // PACK2)]


def ternary_unpack(packed: jax.Array, n: int, dtype=jnp.int8) -> jax.Array:
    """(w,) uint32 -> (n,) {-1,0,+1} `dtype` (first n of 16*w).

    Not counted in LAUNCHES: this wrapper lowers to the pure-jnp oracle,
    no pallas_call."""
    from repro.core import sign_compress as sc
    return sc.unpack_ternary(packed, dtype)[:n]


def ternary_majority(packed: jax.Array) -> jax.Array:
    """(M, w) uint32 packed ternary -> (w,) packed ternary majority
    (abstentions abstain, ties -> 0)."""
    _launch("ternary_majority")
    m, w = packed.shape
    rem = (-w) % _tp.WBLOCK
    if rem:
        packed = jnp.pad(packed, ((0, 0), (0, rem)))
    return _tp.ternary_tally_packed(packed, interpret=_interpret())[:w]


def momentum_sign_pack(g: jax.Array, m: jax.Array, beta: float
                       ) -> Tuple[jax.Array, jax.Array]:
    """Flat g/m (n,) -> (m_new (n,), packed (ceil(n/32),))."""
    _launch("momentum_sign_pack")
    n = g.shape[0]
    g2, _ = _to_2d(g)
    m2, _ = _to_2d(m)
    m_new, packed = _su.momentum_sign_pack(g2, m2, beta,
                                           interpret=_interpret())
    return m_new.reshape(-1)[:n], packed.reshape(-1)[: -(-n // PACK)]


def apply_vote(p: jax.Array, votes: jax.Array, eta: float,
               weight_decay: float) -> jax.Array:
    """Flat p (n,), votes (ceil(n/32),) packed -> updated p (n,)."""
    _launch("apply_vote")
    n = p.shape[0]
    p2, _ = _to_2d(p)
    w = votes.shape[0]
    rem = p2.shape[0] * 128 - w
    if rem:
        votes = jnp.pad(votes, (0, rem))
    out = _su.apply_vote(p2, votes.reshape(-1, 128), eta, weight_decay,
                         interpret=_interpret())
    return out.reshape(-1)[:n]
