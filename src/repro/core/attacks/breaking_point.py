"""Measured vs. predicted breaking points (DESIGN.md §15).

Theorem 2 guarantees convergence while the per-coordinate vote failure
bound (``core.theory.vote_failure_bound``) stays below 1/2 — the bound
blows up as the adversarial fraction approaches 1/2, and it is proved
for *blind* adversaries. This module measures where each attack class
ACTUALLY breaks the drill and overlays the oblivious-theory line, which
makes the adaptive-attack headline quantitative: an observation channel
lets a coalition cross below the blind-adversary breaking point the
paper's analysis prices in.

``sweep`` runs one attack class over an adversary-fraction grid through
the Scenario Lab (same drill, same seeds — only the coalition varies)
and reports, per fraction, the measured loss drop next to the predicted
failure bound ``min(1, 1/((1-2a) sqrt(M) S))`` at the drill's initial
SNR. The *measured* breaking fraction is the smallest grid fraction at
which the drill makes no meaningful progress (loss drop <= 5% of the
honest drop); the *predicted* one is the smallest fraction at which the
oblivious bound goes vacuous (>= 1/2, i.e. no better than a coin flip).

Deliberately NOT imported from ``repro.core.attacks`` — this module
imports the Scenario Lab, and ``core.byzantine`` dispatches into the
attacks package from inside the jitted vote; pulling ``sim`` into that
import path would be a cycle. Import it explicitly::

    from repro.core.attacks import breaking_point
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import theory
from repro.distributed.fault_tolerance import count_for_fraction

#: the adversary-fraction grid every curve walks (0 anchors the honest
#: drop the breaking criterion is relative to; 0.5 is the theory wall)
FRACTIONS = (0.0, 0.25, 0.375, 0.5)

#: "no meaningful progress": loss drop <= this share of the honest drop
BREAK_REL_TOL = 0.05

#: the attack classes the bench sweeps — label -> AdversarySpec axes.
#: ``sleeper`` builds its coalition as a mid-run schedule (the base spec
#: is honest), so its curve measures the *time-varying* breaking point.
ATTACK_CLASSES: Tuple[Dict[str, Any], ...] = (
    dict(label="colluding", mode="colluding", observe="none"),
    dict(label="adaptive_flip", mode="adaptive_flip", observe="vote"),
    dict(label="low_margin", mode="low_margin", observe="margin"),
    dict(label="sleeper", mode="none", observe="none", sleeper=True),
    dict(label="reputation", mode="reputation", observe="reputation",
         codec="weighted_vote"),
)


def predicted_failure_bound(snr: float, m_workers: int, alpha: float
                            ) -> float:
    """``min(1, vote_failure_bound)`` — clamped because the Thm 2 bound
    is a probability; vacuous (1.0) at and beyond ``alpha = 1/2``."""
    if alpha >= 0.5:
        return 1.0
    return float(min(1.0, theory.vote_failure_bound(
        np.asarray(snr), m_workers, alpha)))


def _make_spec(cls: Dict[str, Any], fraction: float, *, n_workers: int,
               dim: int, n_steps: int, seed: int):
    from repro.configs.base import VoteStrategy
    from repro.core.attacks import AttackPhase
    from repro.sim.scenario import AdversarySpec, ScenarioSpec
    codec = cls.get("codec", "sign1bit")
    kw: Dict[str, Any] = dict(codec=codec)
    if codec == "weighted_vote":
        kw["strategy"] = VoteStrategy.ALLGATHER_1BIT
    if cls.get("sleeper") and fraction > 0:
        # honest base spec, the coalition wakes mid-run
        adv = AdversarySpec(
            mode="none", fraction=0.0,
            schedule=(AttackPhase(step=max(1, n_steps // 3),
                                  mode="sign_flip", fraction=fraction),))
    else:
        adv = AdversarySpec(mode=cls["mode"] if fraction > 0 else "none",
                            fraction=fraction,
                            observe=(cls["observe"] if fraction > 0
                                     else "none"))
    # ONE name (-> one salt -> one x0 / noise stream) per codec family:
    # every point on every curve replays the SAME drill, so a curve
    # measures the attack, not the noise realization — and the honest
    # f=0 anchor is literally the same run for every class
    return ScenarioSpec(
        name=f"bp/{codec}", n_workers=n_workers,
        dim=dim, n_steps=n_steps, seed=seed, **kw, adversary=adv)


def sweep(cls: Dict[str, Any], *, fractions: Sequence[float] = FRACTIONS,
          n_workers: int = 15, dim: int = 48, n_steps: int = 6,
          seed: int = 0, backend: str = "virtual",
          _anchors: Optional[Dict[str, Dict[str, Any]]] = None
          ) -> Dict[str, Any]:
    """One attack class's measured-vs-predicted breaking-point curve.

    ``_anchors`` (codec -> f=0 summary) lets ``breaking_point_rows``
    share the honest anchor run across classes — with per-family names
    the anchor is the same drill for every class, so re-running it per
    class would only burn compile time."""
    from repro.sim.runner import ScenarioRunner, _init_x
    points: List[Dict[str, Any]] = []
    snr = None
    for f in fractions:
        spec = _make_spec(cls, f, n_workers=n_workers, dim=dim,
                          n_steps=n_steps, seed=seed)
        if snr is None:
            # the drill's gradient is x + noise_scale*N(0,1): initial
            # per-coordinate SNR is |x0|/sigma, averaged for the overlay
            snr = float(np.mean(np.abs(np.asarray(_init_x(spec))))
                        / max(spec.noise_scale, 1e-30))
        codec = spec.codec
        if f == 0 and _anchors is not None and codec in _anchors:
            s = _anchors[codec]
        else:
            s = ScenarioRunner(spec, backend=backend).run().summary()
            if f == 0 and _anchors is not None:
                _anchors[codec] = s
        alpha = count_for_fraction(f, n_workers) / n_workers
        points.append(dict(
            fraction=f, alpha=alpha,
            loss_drop=s["loss_drop"], final_loss=s["final_loss"],
            mean_flip=s["mean_flip_fraction"],
            predicted_bound=predicted_failure_bound(snr, n_workers, alpha)))
    honest_drop = points[0]["loss_drop"]
    measured = next((p["fraction"] for p in points
                     if p["loss_drop"] <= BREAK_REL_TOL * honest_drop), 1.0)
    predicted = next((p["fraction"] for p in points
                      if p["predicted_bound"] >= 0.5), 1.0)
    return dict(label=cls["label"], snr=snr, n_workers=n_workers,
                points=points, measured_breaking_fraction=measured,
                predicted_breaking_fraction=predicted)


def curve_rows(curve: Dict[str, Any]) -> List[Tuple[str, float, str]]:
    """A sweep result as ``(name, value, derived)`` bench rows."""
    label = curve["label"]
    out = []
    for p in curve["points"]:
        out.append((
            f"breaking/{label}/loss_drop_f{p['fraction']:g}",
            p["loss_drop"],
            f"final={p['final_loss']:.4f} flip={p['mean_flip']:.3f} "
            f"alpha={p['alpha']:.3f} "
            f"pred_bound={p['predicted_bound']:.3f}"))
    out.append((
        f"breaking/{label}/measured_breaking_fraction",
        curve["measured_breaking_fraction"],
        f"theory(oblivious)={curve['predicted_breaking_fraction']:g} "
        f"snr={curve['snr']:.3f} M={curve['n_workers']} "
        f"(measured < theory means the observation channel beats the "
        f"blind-adversary analysis)"))
    return out


def defense_degradation(*, fraction: float = 0.3, n_workers: int = 15,
                        dim: int = 48, n_steps: int = 10, seed: int = 0,
                        backend: str = "virtual") -> Tuple[str, float, str]:
    """How much a defense-AWARE attacker degrades the weighted vote's
    identification signal vs. an oblivious colluding coalition of the
    same size. Both drills run the weighted_vote codec; the metric is
    the mean Chair–Varshney reliability weight the defense assigns the
    ADVERSARIES at the end of the run (read off the final flip-EMA
    server state). An oblivious coalition disagrees with the decode
    every round, so its EMA saturates and its weight collapses to ~0 —
    the defense works. The reputation attacker strikes only while its
    own EMA (which it replays exactly — public bookkeeping) is below
    ``strike_below``, holding itself inside the defense's blind spot:
    it keeps near-honest weight WHILE still flipping votes. Positive
    value = the aware attacker retains weight the oblivious one loses,
    i.e. the EMA defense is measurably degraded."""
    from repro.core.codecs.weighted import reliability_weights
    from repro.sim.runner import ScenarioRunner
    n_adv = count_for_fraction(fraction, n_workers)
    weights, flips = {}, {}
    for label, cls in (("oblivious", dict(label="obl", mode="colluding",
                                          observe="none",
                                          codec="weighted_vote")),
                       ("aware", dict(label="aware", mode="reputation",
                                      observe="reputation",
                                      codec="weighted_vote"))):
        spec = _make_spec(cls, fraction, n_workers=n_workers, dim=dim,
                          n_steps=n_steps, seed=seed)
        trace = ScenarioRunner(spec, backend=backend).run()
        ema = np.asarray(trace.final_server_state["flip_ema"])
        weights[label] = float(np.mean(
            np.asarray(reliability_weights(ema))[:n_adv]))
        # damage the attacker still does late in the run, after the
        # defense has had time to learn its labelling
        flips[label] = float(np.mean(
            [s.flip_fraction for s in trace.steps[n_steps // 2:]]))
    return ("breaking/defense_aware_degradation",
            weights["aware"] - weights["oblivious"],
            f"weighted_vote f={fraction:g}: mean adversary weight "
            f"aware={weights['aware']:.3f} vs oblivious"
            f"={weights['oblivious']:.3f}; late-run flip fraction "
            f"aware={flips['aware']:.3f} vs oblivious"
            f"={flips['oblivious']:.3f} (positive = the aware attacker "
            f"keeps the weight the flip-EMA strips from the oblivious "
            f"one)")


def identity_rows(*, dim: int = 32, n_steps: int = 5, seed: int = 0
                  ) -> List[Tuple[str, float, str]]:
    """The §15 equivalence gates as bench rows (asserted, not just
    reported): every adaptive mode replays bit-identically mesh vs
    virtual (needs >= 8 devices — the bench forces the 8-virtual-device
    platform), and a streamed adaptive population is chunk-invariant."""
    import jax

    from repro.configs.base import VoteStrategy
    from repro.core.attacks import AttackPhase
    from repro.sim.runner import ScenarioRunner
    from repro.sim.scenario import (AdversarySpec, PopulationSpec,
                                    ScenarioSpec)
    out: List[Tuple[str, float, str]] = []
    m = 8
    if len(jax.devices()) < m:
        raise RuntimeError(
            f"identity_rows needs >= {m} devices (run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={m})")
    # one mesh drill composing ALL the new axes — a mid-run schedule
    # flipping an observation-driven coalition on, against the weighted
    # defense — keeps the bench fast; the full mode x backend matrix
    # lives in tests/test_attack_properties.py
    drills = [
        ("scheduled_reputation",
         AdversarySpec("none", 0.0, observe="reputation",
                       schedule=(AttackPhase(step=2, mode="reputation",
                                             fraction=0.375),)),
         dict(codec="weighted_vote",
              strategy=VoteStrategy.ALLGATHER_1BIT)),
    ]
    for label, adv, kw in drills:
        spec = ScenarioSpec(name=f"bp-id/{label}", n_workers=m, dim=dim,
                            n_steps=n_steps, seed=seed, adversary=adv,
                            **kw)
        tv = ScenarioRunner(spec, backend="virtual").run()
        tm = ScenarioRunner(spec, backend="mesh").run()
        assert tv.digest == tm.digest, (
            f"{label}: adaptive attack diverged across backends "
            f"({tv.digest[:12]} != {tm.digest[:12]})")
        out.append((f"breaking/identity/{label}_mesh_eq_virtual", 1.0,
                    f"digest {tv.digest[:12]}"))
    digests = []
    for chunk in (3, 7, 24):
        spec = ScenarioSpec(
            name="bp-id/pop", n_workers=m, dim=dim, n_steps=n_steps,
            seed=seed, momentum=0.0,
            population=PopulationSpec(n_clients=24, sample_fraction=0.5,
                                      chunk_size=chunk),
            adversary=AdversarySpec("low_margin", 0.375,
                                    observe="margin"))
        digests.append(ScenarioRunner(spec, backend="virtual").run().digest)
    assert len(set(digests)) == 1, (
        f"adaptive population vote depends on chunk size: {digests}")
    out.append(("breaking/identity/population_chunk_invariant", 1.0,
                f"chunks (3,7,24) digest {digests[0][:12]}"))
    return out


def breaking_point_rows(*, fractions: Sequence[float] = FRACTIONS,
                        n_workers: int = 15, dim: int = 48,
                        n_steps: int = 6, seed: int = 0,
                        backend: str = "virtual",
                        with_identity: bool = True
                        ) -> List[Tuple[str, float, str]]:
    """The full bench: every attack class's curve, the defense-aware
    degradation gate, and (when the platform has the devices) the
    mesh==virtual / chunk-invariance identity rows. This is what
    ``benchmarks.bench_robustness --breaking-point`` commits to
    ``BENCH_robustness.json``."""
    rows: List[Tuple[str, float, str]] = []
    anchors: Dict[str, Dict[str, Any]] = {}
    for cls in ATTACK_CLASSES:
        rows.extend(curve_rows(sweep(
            cls, fractions=fractions, n_workers=n_workers, dim=dim,
            n_steps=n_steps, seed=seed, backend=backend,
            _anchors=anchors)))
    rows.append(defense_degradation(n_workers=n_workers, dim=dim,
                                    seed=seed, backend=backend))
    if with_identity:
        rows.extend(identity_rows(seed=seed))
    return rows
