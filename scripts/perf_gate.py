#!/usr/bin/env python
"""Perf regression gate: diff a fresh benchmark JSON against the
committed baseline, row by row.

Both files use the ``{"rows": [{"name", "value", "derived"}, ...]}``
schema that ``benchmarks.run --emit-json`` and the ``--smoke`` lanes
write. Two row classes, decided by the row NAME:

* ``*_ms`` (timing rows): fail when the fresh value regresses past the
  committed value by more than ``--tol`` (default 15%). One-sided —
  getting faster never fails; re-commit the JSON to bank the win.
* everything else (bit-identity / accounting rows: golden digests,
  mesh==virtual flags, launch counts): any numeric change fails. These
  rows encode correctness claims, not measurements.

``derived`` strings are free-form commentary (sweep-chosen bucket
sizes, digest prefixes) and are never compared. Missing or extra rows
fail in both directions: a silently dropped acceptance row is as bad as
a regression.

Usage (the ci.sh wiring snapshots the committed JSON before the smoke
lane overwrites it in place):

    cp BENCH_vote_plan.json /tmp/base.json
    python -m benchmarks.bench_vote_plan --smoke
    python scripts/perf_gate.py --baseline /tmp/base.json \\
        --fresh BENCH_vote_plan.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict


def load_rows(path: str) -> Dict[str, float]:
    with open(path) as f:
        doc = json.load(f)
    rows = doc["rows"] if isinstance(doc, dict) else doc
    out: Dict[str, float] = {}
    for r in rows:
        if r["name"] in out:
            raise SystemExit(f"perf_gate: duplicate row {r['name']!r} "
                             f"in {path}")
        out[r["name"]] = float(r["value"])
    return out


def diff(base: Dict[str, float], fresh: Dict[str, float],
         tol: float) -> list:
    """The list of human-readable failures (empty = gate passes)."""
    failures = []
    for name in sorted(set(base) - set(fresh)):
        failures.append(f"row disappeared: {name} "
                        f"(baseline {base[name]:.6g})")
    for name in sorted(set(fresh) - set(base)):
        failures.append(f"new row without a committed baseline: {name} "
                        f"(fresh {fresh[name]:.6g}) — re-commit the "
                        "JSON to bless it")
    for name in sorted(set(base) & set(fresh)):
        b, f = base[name], fresh[name]
        if name.endswith("_ms"):
            if f > b * (1.0 + tol):
                failures.append(
                    f"timing regression: {name} {f:.3f} ms vs baseline "
                    f"{b:.3f} ms (+{(f / b - 1.0) * 100:.1f}% > "
                    f"{tol * 100:.0f}% tolerance)")
        elif f != b:
            failures.append(
                f"bit-identity/accounting row changed: {name} "
                f"{f:.6g} vs baseline {b:.6g} (exact match required)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--baseline", required=True,
                    help="committed benchmark JSON (snapshot it before "
                         "a smoke lane overwrites the file in place)")
    ap.add_argument("--fresh", required=True,
                    help="freshly produced benchmark JSON to vet")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="one-sided relative tolerance for *_ms timing "
                         "rows (default 0.15 = 15%%)")
    args = ap.parse_args()

    base = load_rows(args.baseline)
    fresh = load_rows(args.fresh)
    failures = diff(base, fresh, args.tol)
    if failures:
        print(f"perf_gate: {len(failures)} failure(s) "
              f"({args.fresh} vs {args.baseline}):")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    n_timing = sum(1 for n in base if n.endswith("_ms"))
    print(f"perf_gate: OK — {len(base)} rows ({n_timing} timing within "
          f"{args.tol * 100:.0f}%, {len(base) - n_timing} exact)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
