"""Fault-tolerance machinery: stragglers, elastic rescale, watchdog.

The paper's claim (§3.4) is that majority vote *is* the fault-tolerance
mechanism: any bounded-influence failure (stale vote, random bits, crash,
adversary) is just another ≤1-vote perturbation, covered by Theorem 2 up
to 50% bad replicas. This module supplies the runtime plumbing around
that property:

* ``simulate_stragglers`` — stale-vote substitution: a replica that misses
  the step deadline contributes its *previous* sign vector instead of
  blocking the step (synchronous step, no tail latency). In-JAX, used by
  tests/benchmarks to quantify convergence vs fraction-stale.
* ``ElasticPlan`` — host-side logic mapping a surviving device set to a
  new mesh and instructing the checkpoint reshard (vote semantics depend
  only on the replica *count*, so DP rescale is transparent; Mode A
  momenta are truncated / zero-padded by checkpoint.restore).
* ``Watchdog`` — wall-clock supervision of the train loop; on a stuck
  step (collective hang after a node failure) it triggers the
  restore-and-rescale path in launch/train.py.
* ``vote_with_failures`` — the failure drill's aggregation path: stale-vote
  substitution + Byzantine perturbation feeding the SAME
  :class:`~repro.core.vote_engine.VoteEngine` the trainer steps through,
  so robustness experiments measure the production wire protocol, not a
  lookalike.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat


# ---------------------------------------------------------------------------
# straggler mitigation (stale-vote substitution)
# ---------------------------------------------------------------------------


def simulate_stragglers(signs: jax.Array, prev_signs: jax.Array,
                        straggler_mask: jax.Array) -> jax.Array:
    """Elementwise: replicas flagged in `straggler_mask` (scalar bool per
    replica, e.g. from axis_index comparisons) vote with last step's signs."""
    return jnp.where(straggler_mask, prev_signs, signs)


def straggler_mask_for(axis_names: Sequence[str], n_stale: int,
                       like=None) -> jax.Array:
    """First `n_stale` replicas along the vote axes are stale this step.
    `like` anchors the legacy-JAX index emulation (compat.axis_index)."""
    from repro.core.byzantine import replica_index
    return replica_index(axis_names, like=like) < n_stale


def count_for_fraction(fraction: float, n_replicas: int) -> int:
    """Replicas a fraction maps to, with explicit half-up rounding so the
    boundary regimes land where the paper's figures put them (0.5 of 16
    -> 8, i.e. *exactly* 50% — the tie regime DESIGN.md §7 pins)."""
    if not 0.0 <= fraction <= 1.0:
        raise ValueError(f"fraction {fraction} outside [0, 1]")
    return min(n_replicas, int(fraction * n_replicas + 0.5))


def vote_with_failures(engine, signs: jax.Array,
                       prev_signs: Optional[jax.Array] = None,
                       n_stale: int = 0, step=None) -> jax.Array:
    """One aggregation under failures, through the trainer's engine.

    Runs inside the manual vote region: substitutes stale votes for the
    first `n_stale` replicas (when `prev_signs` is given), then lets the
    engine apply its compiled Byzantine model and wire protocol — so a
    straggling adversary perturbs its *stale* vector, exactly as a real
    stale-then-corrupted worker would. The paper's point (§3.4) made
    executable: every failure mode enters as a ≤1-vote perturbation to the
    same pack → exchange → tally → unpack pipeline. `step` feeds the
    stochastic adversary models' per-step PRNG fold.
    """
    if n_stale and prev_signs is not None:
        mask = straggler_mask_for(engine.axes, n_stale, like=signs)
        signs = simulate_stragglers(signs, prev_signs, mask)
    return engine.vote(signs, step)


def codec_vote_with_failures(engine, signs: jax.Array,
                             prev_signs: Optional[jax.Array] = None,
                             n_stale: int = 0, step=None,
                             server_state=None):
    """Codec-aware :func:`vote_with_failures`: same failure composition
    (stale substitution, then the engine's compiled adversary, then the
    wire), decoded through the engine's gradient codec (DESIGN.md §8).
    Returns ``(vote, new_server_state)`` so stateful decoders (the
    weighted vote's reliability estimates) thread through the drill."""
    if n_stale and prev_signs is not None:
        mask = straggler_mask_for(engine.axes, n_stale, like=signs)
        signs = simulate_stragglers(signs, prev_signs, mask)
    return engine.vote_codec(signs, step, server_state)


def plan_vote_with_failures(engine, plan, values: jax.Array,
                            prev_signs: Optional[jax.Array] = None,
                            n_stale: int = 0, step=None,
                            server_state=None):
    """Bucketed :func:`vote_with_failures` (DESIGN.md §9): the SAME
    failure composition — stale-vote substitution, then the engine's
    compiled adversary — applied ONCE to the flat wire buffer, then the
    :class:`~repro.core.vote_plan.VotePlan` schedule walked bucket by
    bucket through the production stage methods. Returns
    ``(vote, new_server_state)``; `values` is the replica-local flat
    (n_params,) real buffer in manifest order."""
    from repro.core import byzantine, sign_compress as sc
    from repro.core import vote_plan as vp
    if n_stale and prev_signs is not None:
        mask = straggler_mask_for(engine.axes, n_stale, like=values)
        values = simulate_stragglers(values, prev_signs, mask)
    signs = sc.sign_ternary(values)
    if engine.byz is not None and engine.axes:
        signs = byzantine.apply_adversary(signs, engine.byz, engine.axes,
                                          step=step, salt=engine.salt)
    vote, new_state = vp.plan_vote_signs(plan, signs, engine.axes,
                                         server_state)
    return vote.astype(values.dtype), new_state


# ---------------------------------------------------------------------------
# elastic rescale
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Mapping from a failure event to the survivor configuration."""

    old_shape: Tuple[int, ...]
    old_axes: Tuple[str, ...]
    new_shape: Tuple[int, ...]
    new_axes: Tuple[str, ...]
    note: str

    @property
    def new_replicas(self) -> int:
        n = 1
        for a, s in zip(self.new_axes, self.new_shape):
            if a in ("pod", "data"):
                n *= s
        return n


def plan_rescale(old_shape: Tuple[int, ...], old_axes: Tuple[str, ...],
                 surviving_devices: int) -> ElasticPlan:
    """Choose the survivor mesh after losing devices.

    Policy: keep the 'model' axis intact (TP degree is baked into layouts
    and kernels); shrink 'data' (and drop 'pod' if a whole pod died) to the
    largest power-of-two fit. The majority vote is indifferent to the DP
    width — Theorem 2's M simply decreases.
    """
    sizes = dict(zip(old_axes, old_shape))
    model = sizes.get("model", 1)
    if surviving_devices < model:
        raise ValueError(
            f"cannot keep TP degree {model} with {surviving_devices} devices")
    avail_dp = surviving_devices // model
    new_dp = 1
    while new_dp * 2 <= avail_dp:
        new_dp *= 2
    if "pod" in sizes and new_dp >= sizes["data"]:
        pods = new_dp // sizes["data"]
        return ElasticPlan(old_shape, old_axes,
                           (pods, sizes["data"], model),
                           ("pod", "data", "model"),
                           f"kept {pods} pod(s), data={sizes['data']}")
    return ElasticPlan(old_shape, old_axes, (new_dp, model),
                       ("data", "model"),
                       f"flattened to data={new_dp}, model={model}")


def make_mesh_from_plan(plan: ElasticPlan):
    return compat.make_mesh(
        plan.new_shape, plan.new_axes,
        axis_types=(compat.AxisType.Auto,) * len(plan.new_shape))


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------


class Watchdog:
    """Detects a stuck step (e.g. a collective hanging on a dead peer) and
    invokes `on_timeout`. Use as a context manager around blocking work."""

    def __init__(self, timeout_s: float,
                 on_timeout: Optional[Callable[[], None]] = None):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self.fired = False
        self._timer: Optional[threading.Timer] = None

    def _fire(self):
        self.fired = True
        if self.on_timeout is not None:
            self.on_timeout()

    def __enter__(self):
        self._timer = threading.Timer(self.timeout_s, self._fire)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, *exc):
        if self._timer is not None:
            self._timer.cancel()
        return False
