"""Distributed train-step factory.

The step is ``jax.jit(shard_map(local_step))`` — **manual** over the vote
axes (``'data'``, ``'pod'``) so per-replica gradients are visible and the
majority vote's collectives are explicit, **auto** over ``'model'`` so XLA
SPMD partitions the TP/EP matmuls (DESIGN.md §4; validated against a flat
reference before the framework was built).

Paths through the step:

* Mode A (per-worker momentum, paper Algorithm 1): params replicated over
  the vote axes; explicit ``tree_vote`` inside the optimizer; per-worker
  momentum stored with a leading vote-axis dimension.
* Mode B + FSDP (scalable): ZeRO-3 param gathering via hooks whose
  backward **is** the majority vote (int8 reduce-scatter) — see
  ``core.majority_vote.make_fsdp_hooks``; only small replicated leaves
  vote explicitly.
* Dense baselines (sgd/sgdm/adam): same harness, psum-mean aggregation.

Without a mesh the factory returns a single-process step (M=1: the vote
degenerates to sign) for tests and CPU examples.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import (ModelConfig, MomentumMode, TrainConfig,
                                VoteStrategy)
from repro.core import vote_plan as vp
from repro.core.majority_vote import make_fsdp_hooks
from repro.core.signum import build_optimizer
from repro.core.vote_engine import resolve_strategy
from repro.distributed import sharding as shd
from repro.models import model as M


# ---------------------------------------------------------------------------
# spec helpers
# ---------------------------------------------------------------------------


def _manual_only(spec: P, manual: Tuple[str, ...]) -> P:
    """Strip non-manual axes from a PartitionSpec (for shard_map in_specs)."""
    def fix(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(x for x in e if x in manual)
            return kept if kept else None
        return e if e in manual else None

    return P(*(fix(e) for e in spec))


def _auto_only(spec: P, manual: Tuple[str, ...]) -> P:
    """Strip manual axes from a PartitionSpec (constraints inside shard_map)."""
    def fix(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(x for x in e if x not in manual)
            return kept if kept else None
        return None if e in manual else e

    return P(*(fix(e) for e in spec))


def _constrain_grads(grads: Dict[str, jax.Array], specs: Dict[str, P],
                     manual: Tuple[str, ...]) -> Dict[str, jax.Array]:
    """Pin each gradient leaf to its parameter's auto-axis sharding.

    Without this the SPMD partitioner is free to choose any sharding for
    the weight-gradient dots and routinely picks one that forces a
    full-size cotangent all-gather (measured: 6 x 2 GiB fp32 gathers on
    zamba2's shared block)."""
    out = {}
    for k, g in grads.items():
        spec = _auto_only(specs[k], manual)
        out[k] = compat.with_sharding_constraint(g, spec)
    return out


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


@dataclasses.dataclass
class StepArtifacts:
    """Everything the trainer / dry-run needs alongside the step fn."""

    step_fn: Callable
    param_specs: Dict[str, P]          # full specs (data+model)
    param_shard_specs: Dict[str, P]    # manual-only (shard_map in_specs)
    opt_specs: Any
    batch_spec: Any
    n_vote_replicas: int
    vote_axes: Tuple[str, ...]
    fused_leaves: Tuple[str, ...]
    #: resolved (never AUTO); under a plan, the schedule's strategy when
    #: unique, None for mixed-strategy schedules (see `plan`)
    vote_strategy: Optional[VoteStrategy] = None
    codec: str = "sign1bit"            # resolved gradient codec (§8)
    plan: Optional[vp.VotePlan] = None  # bucketed wire schedule (§9)


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    mesh=None) -> StepArtifacts:
    opt_cfg = tcfg.optimizer
    byz = tcfg.byzantine if tcfg.byzantine.mode != "none" else None
    is_sign = opt_cfg.kind in ("signum_vote", "signsgd_vote")
    per_worker = (is_sign and opt_cfg.momentum_mode == MomentumMode.PER_WORKER
                  and opt_cfg.momentum > 0)

    shapes = cfg.param_shapes()
    axis_names = tuple(mesh.axis_names) if mesh is not None else ()
    vote_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    sizes = _mesh_axis_sizes(mesh) if mesh is not None else {}
    n_votes = int(np.prod([sizes.get(a, 1) for a in vote_axes])) if mesh else 1

    # AUTO resolves here, once, against the comm cost model — mesh shape,
    # param count and codec are static, so the whole step compiles against
    # one wire protocol and the dry-run records which one won. The codec
    # restricts the candidate set and prices the gathered exchange at its
    # symbol width (DESIGN.md §8).
    codec_name = opt_cfg.resolved_codec
    resolved = resolve_strategy(opt_cfg.vote_strategy, cfg.param_count(),
                                sizes.get("data", 1), sizes.get("pod", 1),
                                codec=codec_name)
    if resolved != opt_cfg.vote_strategy:
        opt_cfg = dataclasses.replace(opt_cfg, vote_strategy=resolved)
    if is_sign:
        from repro.core import codecs as codecs_mod
        codecs_mod.get_codec(codec_name).validate_strategy(resolved)

    specs = shd.param_specs(shapes, fsdp=tcfg.fsdp, mesh_shape=sizes or None)
    fused = tcfg.fsdp and mesh is not None
    hook = (make_fsdp_hooks(specs, axis_names, vote=is_sign, byz=byz)
            if fused else None)
    fused_leaves = tuple(
        k for k, s in specs.items()
        if any("data" in (e if isinstance(e, tuple) else (e,))
               for e in s if e is not None)) if fused else ()

    # VotePlan (§9): flatten the explicitly-voted leaves (everything the
    # fused ZeRO backward does NOT already vote) into one bucketed wire
    # buffer. Built here, once — shapes, mesh sizes and codec map are all
    # static — with the ORIGINAL strategy so AUTO prices the whole bucket
    # schedule per codec group instead of one tree-sized message.
    plan = None
    if is_sign and opt_cfg.bucket_bytes != 0:
        # Mode B consults voted_leaves and votes only the raw remainder
        # explicitly; Mode A votes the FULL momentum tree regardless of
        # FSDP hooks, so its plan must cover every leaf
        explicit = ({k: v for k, v in shapes.items()
                     if k not in fused_leaves}
                    if opt_cfg.momentum_mode == MomentumMode.GLOBAL
                    else dict(shapes))
        if explicit:
            plan = vp.build_plan(
                explicit, bucket_bytes=opt_cfg.bucket_bytes,
                codec_map=opt_cfg.codec_map, default_codec=codec_name,
                strategy=tcfg.optimizer.vote_strategy,
                data_size=sizes.get("data", 1),
                pod_size=sizes.get("pod", 1),
                dtypes={k: cfg.dtype for k in explicit},
                overlap=opt_cfg.overlap)
            # the plan's schedule is the wire that actually compiles:
            # report ITS resolution (None when a mixed map resolved
            # different strategies per group — art.plan has the detail),
            # not the leaf-wise single-message pricing
            group_strats = {g.strategy for g in plan.groups}
            resolved = (group_strats.pop() if len(group_strats) == 1
                        else None)

    # byz also passes to the optimizer: non-FSDP leaves vote explicitly and
    # the same replicas must act adversarially on them.
    opt = build_optimizer(opt_cfg, vote_axes, byz=byz,
                          fused_leaves=fused_leaves,
                          diagnostics=tcfg.diagnostics,
                          n_vote_replicas=n_votes, plan=plan)

    def loss_of(p, b):
        return M.loss_fn(cfg, p, b, hook=hook, remat=tcfg.remat)

    def local_step(params, opt_state, batch, step):
        # ---- unwrap per-worker momentum (leading vote axis, local = 1) ----
        if per_worker:
            opt_state = {**opt_state}
            for key in ("momentum", "error"):
                if key in opt_state:
                    opt_state[key] = jax.tree.map(lambda v: v[0],
                                                  opt_state[key])
        # ---- local gradients (manual over vote axes => no auto psum) ----
        if tcfg.microbatches > 1:
            # Sign optimizers accumulate in bf16: only the sign of the sum
            # survives, and an fp32 accumulator's dtype demand propagates
            # back through the scan transpose, doubling every stacked
            # gradient buffer (measured on qwen2-moe). Dense baselines keep
            # fp32.
            acc_dt = (jnp.bfloat16 if is_sign else jnp.float32)

            def split(x):
                return x.reshape((tcfg.microbatches,
                                  x.shape[0] // tcfg.microbatches)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                (loss, met), g = jax.value_and_grad(
                    loss_of, has_aux=True)(params, mb)
                carry = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), carry, g)
                return carry, (loss, met)

            zeros = jax.tree.map(
                lambda p: compat.zeros_like_traced(p, acc_dt), params)
            if compat.SCAN_OVER_MANUAL_XS_SAFE or mesh is None:
                grads, (losses, mets) = jax.lax.scan(acc_body, zeros, micro)
            else:
                # legacy partial-auto: scan over batch-derived xs aborts the
                # SPMD partitioner — unroll (identical accumulation)
                grads, acc = zeros, []
                for i in range(tcfg.microbatches):
                    grads, lm = acc_body(
                        grads, jax.tree.map(lambda x: x[i], micro))
                    acc.append(lm)
                losses, mets = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *acc)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, mets)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        if mesh is not None:
            grads = _constrain_grads(grads, specs, vote_axes)

        # ---- optimizer (vote happens inside) ----
        new_params, new_state, diag = opt.update(grads, opt_state, params,
                                                 step)
        # ---- re-wrap per-worker momentum ----
        if per_worker:
            new_state = {**new_state}
            for key in ("momentum", "error"):
                if key in new_state:
                    new_state[key] = jax.tree.map(lambda v: v[None],
                                                  new_state[key])
        # ---- metrics: average over replicas ----
        if vote_axes:
            loss = jax.lax.pmean(loss, vote_axes)
            metrics = jax.tree.map(
                lambda x: jax.lax.pmean(x, vote_axes), metrics)
        metrics = {**metrics, "loss": loss, **diag}
        return new_params, new_state, metrics

    # ------------------------------------------------------------------
    if mesh is None:
        return StepArtifacts(
            step_fn=jax.jit(local_step), param_specs=specs,
            param_shard_specs={k: P() for k in specs}, opt_specs=None,
            batch_spec=None, n_vote_replicas=1, vote_axes=(),
            fused_leaves=fused_leaves, vote_strategy=resolved,
            codec=codec_name, plan=plan)

    manual = vote_axes
    p_manual = {k: _manual_only(s, manual) for k, s in specs.items()}

    # opt-state manual specs mirror param layout; per-worker momentum gets
    # the leading vote-axis spec.
    state_shape = jax.eval_shape(
        opt.init, {k: jax.ShapeDtypeStruct(v, jnp.float32)
                   for k, v in shapes.items()})
    opt_manual: Dict[str, Any] = {}
    for key in state_shape:
        if key in ("momentum", "error"):
            # "error" may be a subset of the params under a plan codec
            # map (only the EF-mapped leaves carry a residual)
            names = tuple(state_shape[key])
            if per_worker:
                opt_manual[key] = {
                    k: P(manual, *_manual_only(specs[k], manual))
                    for k in names}
            else:
                opt_manual[key] = {k: p_manual[k] for k in names}
        elif key in ("m", "v"):  # dense-baseline moments follow params
            opt_manual[key] = dict(p_manual)
        elif key == "delayed":   # one-round vote buffer: param layout,
            opt_manual[key] = dict(p_manual)   # replicated over the vote
        else:
            opt_manual[key] = P()

    batch_struct = M.input_specs(
        cfg, type("C", (), {"global_batch": tcfg.global_batch,
                            "seq_len": tcfg.seq_len, "kind": "train",
                            "name": "train"})())["batch"]
    batch_spec = jax.tree.map(lambda _: P(manual), batch_struct)

    step_fn = jax.jit(compat.shard_map(
        local_step, mesh=mesh,
        in_specs=(p_manual, opt_manual, batch_spec, P()),
        out_specs=(p_manual, opt_manual, P()),
        axis_names=set(manual), check_vma=False),
        donate_argnums=(0, 1))  # params/opt update in place

    return StepArtifacts(
        step_fn=step_fn, param_specs=specs, param_shard_specs=p_manual,
        opt_specs=opt_manual, batch_spec=batch_spec,
        n_vote_replicas=n_votes, vote_axes=vote_axes,
        fused_leaves=fused_leaves, vote_strategy=resolved,
        codec=codec_name, plan=plan)


# ---------------------------------------------------------------------------
# state construction
# ---------------------------------------------------------------------------


def abstract_state(cfg: ModelConfig, tcfg: TrainConfig, art: StepArtifacts,
                   mesh=None) -> Tuple[Any, Any]:
    """ShapeDtypeStructs of (params, opt_state) with full shardings attached
    (for the dry-run lowering: no allocation ever happens)."""
    opt_cfg = tcfg.optimizer
    per_worker = (opt_cfg.kind in ("signum_vote", "signsgd_vote")
                  and opt_cfg.momentum_mode == MomentumMode.PER_WORKER
                  and opt_cfg.momentum > 0)
    dt = jnp.dtype(cfg.dtype)
    shapes = cfg.param_shapes()

    def mk(shape, dtype, spec):
        if mesh is None:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jax.ShapeDtypeStruct(
            shape, dtype, sharding=NamedSharding(mesh, spec))

    params = {k: mk(v, dt, art.param_specs[k]) for k, v in shapes.items()}

    mom_dt = jnp.dtype(opt_cfg.momentum_dtype)
    opt_state: Dict[str, Any] = {"count": mk((), jnp.int32, P())}
    is_sign = opt_cfg.kind in ("signum_vote", "signsgd_vote")
    needs_mom = (opt_cfg.momentum > 0
                 and opt_cfg.kind in ("signum_vote", "signsgd_vote", "sgdm",
                                      "adam"))

    def momentum_like(names=None):
        keep = shapes if names is None else {k: shapes[k] for k in names}
        if per_worker:
            return {k: mk((art.n_vote_replicas,) + v, mom_dt,
                          P(art.vote_axes or None, *art.param_specs[k]))
                    for k, v in keep.items()}
        return {k: mk(v, mom_dt, art.param_specs[k])
                for k, v in keep.items()}

    if is_sign and needs_mom:
        opt_state["momentum"] = momentum_like()
    if is_sign and opt_cfg.delayed_vote:
        # one-round vote buffer (§11): leaf-shaped int8, param sharding
        # (replicated over the vote axes — every replica applies the
        # same previous decision); refit_tree_leading_axis passes it
        # through unchanged at elastic events (no leading voter axis)
        opt_state["delayed"] = {k: mk(v, jnp.int8, art.param_specs[k])
                                for k, v in shapes.items()}
    if is_sign:
        from repro.core import codecs as codecs_mod
        codec = codecs_mod.get_codec(opt_cfg.resolved_codec)
        if art.plan is not None:   # per-leaf codecs come from the plan (§9)
            ef_names = art.plan.worker_state_leaves
            if ef_names:   # EF residual: momentum-shaped, mapped leaves only
                opt_state["error"] = momentum_like(ef_names)
            if art.plan.has_server_state:
                opt_state["codec"] = {
                    "flip_ema": mk((art.n_vote_replicas,), jnp.float32, P())}
        else:
            if codec.worker_state:   # EF residual: momentum-shaped (§8)
                opt_state["error"] = momentum_like()
            if codec.server_state:   # decode memory: replicated (M,) vector
                opt_state["codec"] = {
                    "flip_ema": mk((art.n_vote_replicas,), jnp.float32, P())}
    if opt_cfg.kind in ("sgdm", "adam"):
        opt_state["m"] = {k: mk(v, jnp.float32, art.param_specs[k])
                          for k, v in shapes.items()}
        if opt_cfg.kind == "adam":
            opt_state["v"] = dict(opt_state["m"])
    return params, opt_state


def materialize_state(cfg: ModelConfig, tcfg: TrainConfig,
                      art: StepArtifacts, key: jax.Array, mesh=None
                      ) -> Tuple[Any, Any]:
    """Concrete (params, opt_state) placed per the full shardings."""
    p_abs, o_abs = abstract_state(cfg, tcfg, art, mesh)

    def init_fn(k):
        params = M.init_params(cfg, k)
        opt = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), o_abs)
        return params, opt

    if mesh is None:
        return jax.jit(init_fn)(key)
    shardings = jax.tree.map(lambda s: s.sharding, (p_abs, o_abs))
    return jax.jit(init_fn, out_shardings=shardings)(key)
