"""mamba2-2.7b — pure SSD (state-space duality) backbone, attention-free.

[arXiv:2405.21060; unverified]  64L d_model=2560 d_ff=0 vocab=50280,
ssm_state=128, head_dim=64 (80 heads at expand=2).
"""
from repro.configs.base import ArchFamily, ModelConfig, SSMConfig, register


@register("mamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family=ArchFamily.SSM,
        num_layers=64,
        d_model=2560,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50_280,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256),
        tie_embeddings=True,
    )
