"""gemma3-12b — dense transformer, 5:1 local:global sliding-window pattern.

[hf:google/gemma-3-1b-pt family; unverified]  48L d_model=3840 16H
(GQA kv=8) d_ff=15360 vocab=262144; sliding window 1024, 128k context.
"""
from repro.configs.base import SKIP_LONG, ArchFamily, ModelConfig, register


@register("gemma3-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        family=ArchFamily.DENSE,
        num_layers=48,
        d_model=3840,
        num_heads=16,
        num_kv_heads=8,
        d_ff=15360,
        vocab_size=262_144,
        head_dim=256,
        sliding_window=1024,
        local_to_global=5,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        # global layers are full attention -> long_500k skipped per brief
        skip_shapes=(SKIP_LONG,),
    )
