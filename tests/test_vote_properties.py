"""Property-based tests (hypothesis) for the system's invariants:

* pack/unpack roundtrip for arbitrary sign patterns,
* majority == sign of sum of signs, for every strategy wire format,
* Byzantine bound: with alpha < 1/2 sign-flippers, the vote equals the
  honest-unanimous sign whenever honest replicas agree (the determinism
  core of Theorem 2),
* vote is permutation-invariant in the workers,
* abstention (zero gradient) never flips an otherwise-decided vote,
* the fused sign+pack+popcount kernel is bit-identical to the composed
  oracle (kernels/ref.py) on arbitrary inputs including exact ties.

``hypothesis`` is optional: without it this module skips (tier-1 still
covers the same invariants deterministically in test_vote_engine.py).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; "
    "deterministic equivalents live in test_vote_engine.py")
from hypothesis import given, settings, strategies as st

from repro.core import sign_compress as sc
from repro.kernels import ops, ref

signs_arrays = st.integers(1, 200).flatmap(
    lambda n: st.lists(st.sampled_from([-1, 1]), min_size=n, max_size=n))


@given(signs_arrays)
@settings(max_examples=200, deadline=None)
def test_pack_unpack_roundtrip(bits):
    x = np.asarray(bits, np.float32)
    padded, n = sc.pad_to_pack(jnp.asarray(x))
    packed = sc.pack_signs(padded)
    un = np.asarray(sc.unpack_signs(packed))[:n]
    np.testing.assert_array_equal(un, x)


@given(st.integers(1, 33), st.integers(1, 8), st.randoms())
@settings(max_examples=100, deadline=None)
def test_majority_is_sign_of_sum(m, words, rnd):
    data = np.array([[rnd.getrandbits(32) for _ in range(words)]
                     for _ in range(m)], dtype=np.uint32)
    maj = sc.packed_majority(jnp.asarray(data))
    signs = np.asarray(sc.unpack_signs(jnp.asarray(data), jnp.int32))
    votes = signs.sum(axis=0)
    expect = np.where(votes >= 0, 1, -1)
    got = np.asarray(sc.unpack_signs(maj[None], jnp.int32))[0]
    np.testing.assert_array_equal(got, expect)


@given(st.integers(0, 49), st.integers(1, 30), st.randoms())
@settings(max_examples=100, deadline=None)
def test_byzantine_bound(adv_pct, dim, rnd):
    """alpha < 1/2 sign-flipping adversaries cannot flip a unanimous
    honest vote (Theorem 2's worst-case adversary, deterministic core)."""
    m = 16
    n_adv = (m * adv_pct) // 100  # < m/2 by construction
    honest = np.array([rnd.choice([-1, 1]) for _ in range(dim)], np.int32)
    votes = np.tile(honest, (m - n_adv, 1)).sum(axis=0) \
        + np.tile(-honest, (n_adv, 1)).sum(axis=0) if n_adv else \
        np.tile(honest, (m, 1)).sum(axis=0)
    vote = np.sign(votes)
    np.testing.assert_array_equal(vote, honest)


@given(st.integers(2, 12), st.integers(1, 20), st.randoms())
@settings(max_examples=100, deadline=None)
def test_vote_permutation_invariant(m, dim, rnd):
    signs = np.array([[rnd.choice([-1, 1]) for _ in range(dim)]
                      for _ in range(m)], np.int32)
    v1 = np.sign(signs.sum(axis=0))
    perm = rnd.sample(range(m), m)
    v2 = np.sign(signs[perm].sum(axis=0))
    np.testing.assert_array_equal(v1, v2)


@given(st.integers(3, 15), st.integers(1, 20), st.randoms())
@settings(max_examples=100, deadline=None)
def test_abstention_never_flips_decided_vote(m, dim, rnd):
    """sign(0)=0 abstention (MoE experts with no routed tokens) can only
    weaken a majority, never reverse it."""
    signs = np.array([[rnd.choice([-1, 1]) for _ in range(dim)]
                      for _ in range(m)], np.int32)
    base = signs.sum(axis=0)
    k = rnd.randrange(m)
    signs_abs = signs.copy()
    signs_abs[:k] = 0
    after = signs_abs.sum(axis=0)
    decided = np.abs(base) > k  # margin exceeds removed votes
    np.testing.assert_array_equal(np.sign(after)[decided],
                                  np.sign(base)[decided])


@given(st.integers(1, 9), st.integers(1, 130), st.randoms())
@settings(max_examples=50, deadline=None)
def test_fused_kernel_matches_oracle(m, n, rnd):
    """ONE-PASS sign+pack+popcount (kernels/fused_vote.py) == the composed
    pack_signs -> packed_majority oracle, bit for bit."""
    x = np.array([[rnd.uniform(-1, 1) for _ in range(n)] for _ in range(m)],
                 np.float32)
    got = np.asarray(ops.fused_majority(jnp.asarray(x)))
    pad = (-n) % sc.PACK
    xp = np.pad(x, ((0, 0), (0, pad)))
    want = np.asarray(ref.fused_majority(jnp.asarray(xp)))
    np.testing.assert_array_equal(got, want)


@given(st.integers(1, 4), st.integers(1, 64), st.randoms())
@settings(max_examples=50, deadline=None)
def test_fused_kernel_tie_convention(half_m, n, rnd):
    """Exact ties (half the voters +, half -) resolve to +1, matching the
    1-bit wire convention of sign_binary / ref.majority."""
    m = 2 * half_m
    x = np.array([[rnd.uniform(0.1, 1) for _ in range(n)]
                  for _ in range(m)], np.float32)
    x[half_m:] *= -1.0
    got = np.asarray(ops.bitunpack(ops.fused_majority(jnp.asarray(x)), n))
    np.testing.assert_array_equal(got, np.ones(n, np.float32))


@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=1,
                max_size=100))
@settings(max_examples=200, deadline=None)
def test_sign_conventions(vals):
    x = jnp.asarray(np.asarray(vals, np.float32))
    t = np.asarray(sc.sign_ternary(x))
    b = np.asarray(sc.sign_binary(x))
    xv = np.asarray(x)
    # JAX flushes subnormals to zero (FTZ); they belong to the zero class
    nz = np.abs(xv) >= np.finfo(np.float32).tiny
    np.testing.assert_array_equal(t[nz], np.sign(xv[nz]).astype(np.int8))
    np.testing.assert_array_equal(
        b[nz], np.where(xv[nz] >= 0, 1, -1).astype(np.int8))
    # binary and ternary agree wherever x is nonzero (and normal)
    np.testing.assert_array_equal(t[nz], b[nz])
