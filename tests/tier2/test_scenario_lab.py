"""Scenario Lab acceptance tests (virtual backend; 1 device is enough —
the mesh-backend bit-identity lane is tests/tier2/test_harness8.py).

Covers: spec validation and (de)serialisation, grid expansion from one
config, deterministic per-scenario seeding (two runs -> one digest; a
pinned golden digest for drift detection), the honest-path bit-identity
of all three wire strategies, the exactly-50%-adversaries tie semantics
per wire format, the >50% failure regime, colluding-vs-independent
adversary strength, and elastic rescale bookkeeping.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import VoteStrategy
from repro.core import sign_compress as sc
from repro.distributed.fault_tolerance import count_for_fraction
from repro.sim import (AdversarySpec, ElasticEvent, PlanSpec,
                       ScenarioRunner, ScenarioSpec, ScenarioTrace,
                       expand_grid, fig4_grid, load_scenarios,
                       preset_scenarios, virtual_vote)

STRATS = (VoteStrategy.PSUM_INT8, VoteStrategy.ALLGATHER_1BIT,
          VoteStrategy.HIERARCHICAL)


# ---------------------------------------------------------------------------
# spec schema
# ---------------------------------------------------------------------------


def test_spec_roundtrips_through_dict_and_json():
    spec = ScenarioSpec("io/x", n_workers=9, n_steps=7, dim=33,
                        strategy=VoteStrategy.HIERARCHICAL,
                        adversary=AdversarySpec("blind", 0.3, flip_prob=0.9),
                        straggler_fraction=0.25,
                        elastic=(ElasticEvent(3, 5, "died"),))
    back = ScenarioSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back == spec


def test_spec_validation():
    with pytest.raises(ValueError):
        ScenarioSpec("bad", strategy=VoteStrategy.AUTO)
    with pytest.raises(ValueError):
        ScenarioSpec("bad", adversary=AdversarySpec("voldemort", 0.1))
    with pytest.raises(ValueError):
        ScenarioSpec("bad", adversary=AdversarySpec("random", 1.5))
    with pytest.raises(ValueError):
        ScenarioSpec("bad", elastic=(ElasticEvent(5, 2), ElasticEvent(3, 4)))
    # a tie policy the wire format cannot realise is rejected...
    with pytest.raises(ValueError):
        ScenarioSpec("bad", strategy=VoteStrategy.ALLGATHER_1BIT,
                     tie_break="zero")
    # ...and the matching one is accepted
    ScenarioSpec("ok", strategy=VoteStrategy.PSUM_INT8, tie_break="zero")
    assert ScenarioSpec("ok2").tie_policy == "zero"


def test_workers_at_follows_elastic_schedule():
    spec = ScenarioSpec("el/x", n_workers=8, n_steps=30,
                        elastic=(ElasticEvent(10, 4), ElasticEvent(20, 6)))
    assert [spec.workers_at(s) for s in (0, 9, 10, 19, 20, 29)] == \
        [8, 8, 4, 4, 6, 6]


def test_count_for_fraction_boundaries():
    assert count_for_fraction(0.0, 16) == 0
    assert count_for_fraction(0.5, 16) == 8      # EXACTLY 50%: the tie regime
    assert count_for_fraction(0.5, 15) == 8      # half-up
    assert count_for_fraction(1.0, 16) == 16
    with pytest.raises(ValueError):
        count_for_fraction(-0.1, 8)


def test_grid_expansion_and_config_file(tmp_path):
    specs = fig4_grid(n_workers=8, n_steps=5, dim=32,
                      fractions=(0.0, 0.5), modes=("sign_flip", "colluding"),
                      strategies=("psum_int8", "allgather_1bit"))
    # fraction 0 collapses to ONE honest anchor per strategy (shared
    # curve origin): 2 strategies x (1 anchor + 2 modes x 1 nonzero)
    assert len(specs) == 2 * (1 + 2)
    assert len({s.name for s in specs}) == len(specs)
    anchors = [s for s in specs if s.adversary.fraction == 0.0]
    assert len(anchors) == 2 and all(
        s.adversary.mode == "none" for s in anchors)
    # sub-percent fractions must stay distinct (names salt PRNG streams)
    fine = fig4_grid(fractions=(0.001, 0.002), modes=("zero",),
                     strategies=("psum_int8",))
    assert len({s.name for s in fine}) == 2
    assert len({s.salt for s in fine}) == 2
    doc = {"defaults": {"n_workers": 4, "n_steps": 3, "dim": 16},
           "scenarios": [{"name": "a"},
                         {"name": "b", "strategy": "hierarchical"}],
           "grid": {"prefix": "g", "fractions": [0.25],
                    "modes": ["zero"], "strategies": ["psum_int8"]}}
    p = tmp_path / "cfg.json"
    p.write_text(json.dumps(doc))
    loaded = load_scenarios(str(p))
    assert [s.name for s in loaded] == ["a", "b", "g/zero/psum_int8/f0.25"]
    assert loaded[1].strategy == VoteStrategy.HIERARCHICAL
    assert loaded[2].n_workers == 4          # defaults overlay the grid too
    # duplicate names across scenarios/grid alias PRNG streams: rejected
    doc["scenarios"].append({"name": "g/zero/psum_int8/f0.25"})
    p.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="duplicate scenario names"):
        load_scenarios(str(p))


def test_shipped_fig4_config_loads():
    import os
    cfg = os.path.join(os.path.dirname(__file__), "..", "..", "benchmarks",
                       "configs", "fig4_grid.json")
    specs = load_scenarios(cfg)
    # the acceptance sweep: fraction 0->0.5 x 4 modes x 3 strategies,
    # with the honest fraction-0 anchor shared across modes per strategy
    grid = [s for s in specs if s.name.count("/") == 3]
    assert len(grid) == 3 * (1 + 4 * 4)
    fr = {s.adversary.fraction for s in grid}
    assert min(fr) == 0.0 and max(fr) == 0.5
    assert {s.strategy for s in grid} == set(STRATS)
    assert {s.adversary.mode for s in grid if s.adversary.fraction > 0} == \
        {"sign_flip", "random", "zero", "colluding"}


# ---------------------------------------------------------------------------
# determinism (satellite: per-scenario seeding, golden trace)
# ---------------------------------------------------------------------------


def _spec(name="det/x", **kw):
    base = dict(n_workers=15, n_steps=6, dim=128,
                strategy=VoteStrategy.ALLGATHER_1BIT,
                adversary=AdversarySpec("random", 0.25),
                straggler_fraction=0.2)
    base.update(kw)
    return ScenarioSpec(name, **base)


def test_two_runs_bit_identical():
    t1 = ScenarioRunner(_spec()).run()
    t2 = ScenarioRunner(_spec()).run()
    assert t1.digest == t2.digest
    assert [s.margin for s in t1.steps] == [s.margin for s in t2.steps]


def test_scenario_id_folds_into_prng_stream():
    """Two scenarios differing only in name draw different adversary
    noise (the salt separates sweeps), same name -> same stream."""
    ta = ScenarioRunner(_spec(name="salt/a")).run()
    tb = ScenarioRunner(_spec(name="salt/b")).run()
    ta2 = ScenarioRunner(_spec(name="salt/a")).run()
    assert ta.digest == ta2.digest
    assert ta.digest != tb.digest


GOLDEN_SPEC = ScenarioSpec(
    "golden/fixed", n_workers=16, n_steps=10, dim=64,
    strategy=VoteStrategy.ALLGATHER_1BIT,
    adversary=AdversarySpec("sign_flip", 0.25),
    straggler_fraction=0.125, noise_scale=0.0)
# sha256 over the run's raw vote bytes + final iterate. Pinned so ANY
# drift in the wire pipeline, the adversary/straggler transforms, the
# seeding discipline, or JAX's stable-RNG init draw shows up as a diff
# here rather than as a silent change in every robustness figure.
GOLDEN_DIGEST = \
    "99ff4debfe023768e6391a8eeb976187d8dd3d5f748ba86c33e2a4690bbe32b1"


def test_golden_trace_digest():
    t = ScenarioRunner(GOLDEN_SPEC).run()
    assert t.digest == GOLDEN_DIGEST, (
        "golden trace drifted: if the change to the vote path is "
        f"intentional, re-pin GOLDEN_DIGEST to {t.digest}")


def test_golden_trace_through_explicit_sign1bit_codec():
    """The codec refactor's no-op proof (DESIGN.md §8): requesting the
    sign1bit codec EXPLICITLY routes the drill through the codec API and
    must reproduce the pre-codec golden digest unchanged — the default
    wire path and the codec path are one path."""
    spec = ScenarioSpec.from_dict(
        {**GOLDEN_SPEC.to_dict(), "codec": "sign1bit"})
    assert spec == GOLDEN_SPEC          # default codec == explicit codec
    t = ScenarioRunner(spec).run()
    assert t.digest == GOLDEN_DIGEST, (
        "sign1bit through the codec API diverged from the pre-codec "
        f"wire path: {t.digest}")


# ---------------------------------------------------------------------------
# vote semantics through scenarios
# ---------------------------------------------------------------------------


def test_honest_path_bit_identical_across_strategies():
    """Acceptance: with an odd voter count (no ties possible) the three
    wire formats decide identically, so the honest drill digests match."""
    digests = {s: ScenarioRunner(
        ScenarioSpec("honest/fix", n_workers=15, n_steps=6, dim=257,
                     strategy=s)).run().digest for s in STRATS}
    assert len(set(digests.values())) == 1, digests


def test_tie_at_exactly_half_adversaries():
    """The paper's boundary: 8 of 16 sign-flippers, zero noise -> every
    count is exactly zero. Integer-count wire abstains (no update); 1-bit
    wires resolve +1 (DESIGN.md §5/§7) — divergence documented, pinned."""
    def run(strategy):
        spec = ScenarioSpec("tie/half", n_workers=16, n_steps=4, dim=64,
                            strategy=strategy, noise_scale=0.0,
                            adversary=AdversarySpec("sign_flip", 0.5))
        return ScenarioRunner(spec).run()

    t_psum = run(VoteStrategy.PSUM_INT8)
    # abstention: x never moves -> loss exactly flat, margin exactly 0
    assert all(s.margin == 0.0 for s in t_psum.steps)
    losses = [s.loss for s in t_psum.steps]
    assert losses.count(losses[0]) == len(losses)
    for strategy in (VoteStrategy.ALLGATHER_1BIT, VoteStrategy.HIERARCHICAL):
        t = run(strategy)
        assert all(s.margin == 0.0 for s in t.steps)
        # ties -> +1: the update marches every coordinate downward by
        # lr each step, so the iterate changes
        assert t.steps[-1].loss != t.steps[0].loss


def test_below_half_tolerated_above_half_fails():
    """Theorem 2 end to end: 25% sign-flippers converge; 75% drive the
    iterate away (the vote rightly follows the adversarial majority)."""
    def final_loss(frac):
        spec = ScenarioSpec(f"t2/{frac}", n_workers=16, n_steps=25, dim=128,
                            adversary=AdversarySpec("sign_flip", frac))
        return ScenarioRunner(spec).run().summary()
    ok = final_loss(0.25)
    bad = final_loss(0.75)
    assert ok["final_loss"] < ok["first_loss"] * 0.5
    assert bad["final_loss"] > bad["first_loss"]


def test_colluding_flips_more_than_independent_random():
    """The coordinated coalition's whole weight lands on one direction, so
    at equal fraction it flips more coordinates than independent random
    adversaries (whose perturbation half-cancels)."""
    def mean_flip(mode):
        spec = ScenarioSpec(f"cmp/{mode}", n_workers=16, n_steps=12, dim=512,
                            adversary=AdversarySpec(mode, 0.375))
        return ScenarioRunner(spec).run().summary()["mean_flip_fraction"]
    assert mean_flip("colluding") > mean_flip("random")


def test_blind_flip_prob_interpolates():
    """blind(p=1) == sign_flip; blind(p=0) == honest, bit for bit."""
    def digest(mode, p=0.5):
        spec = ScenarioSpec("blind/interp", n_workers=15, n_steps=5, dim=96,
                            adversary=AdversarySpec(mode, 0.4, flip_prob=p))
        return ScenarioRunner(spec).run().digest
    assert digest("blind", 1.0) == digest("sign_flip")
    assert digest("blind", 0.0) == digest("none")


def test_elastic_rescale_traced_and_momentum_refit():
    spec = ScenarioSpec("el/trace", n_workers=8, n_steps=9, dim=64,
                        adversary=AdversarySpec("sign_flip", 0.25),
                        elastic=(ElasticEvent(3, 4), ElasticEvent(6, 6)))
    t = ScenarioRunner(spec).run()
    assert [s.n_workers for s in t.steps] == [8] * 3 + [4] * 3 + [6] * 3
    # adversary count tracks the CURRENT voter set
    assert [s.n_adversaries for s in t.steps] == [2] * 3 + [1] * 3 + [2] * 3
    # deterministic despite the rescale
    assert t.digest == ScenarioRunner(spec).run().digest


def test_trace_schema_and_summary():
    t = ScenarioRunner(_spec(name="schema/x")).run()
    assert isinstance(t, ScenarioTrace)
    d = t.to_dict()
    assert set(d) == {"spec", "backend", "digest", "steps", "summary"}
    s = d["summary"]
    for key in ("first_loss", "final_loss", "mean_margin",
                "mean_flip_fraction", "wire_bytes_per_replica",
                "est_exchange_time_s", "tie_policy", "digest"):
        assert key in s, key
    # 1-bit wire: payload is exactly fp32/32 of the gradient
    assert s["wire_bytes_per_replica"] == pytest.approx(128 * 4 / 32)
    json.loads(t.to_json())  # serialisable


def test_presets_all_run():
    for spec in preset_scenarios():
        small = ScenarioSpec.from_dict(
            {**spec.to_dict(), "n_steps": min(spec.n_steps, 3), "dim": 32})
        t = ScenarioRunner(small).run()
        assert len(t.steps) == small.n_steps
        assert np.isfinite([s.loss for s in t.steps]).all()


def test_codec_spec_roundtrips_and_validates():
    spec = ScenarioSpec("cod/io", n_workers=9, codec="ternary2bit",
                        strategy=VoteStrategy.ALLGATHER_1BIT,
                        tie_break="zero")
    back = ScenarioSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back == spec and back.codec == "ternary2bit"
    assert back.tie_policy == "zero"    # codec overrides the 1-bit wire
    with pytest.raises(ValueError, match="unknown codec"):
        ScenarioSpec("bad", codec="morse")
    with pytest.raises(ValueError, match="cannot ride"):
        ScenarioSpec("bad", codec="weighted_vote",
                     strategy=VoteStrategy.PSUM_INT8)
    with pytest.raises(ValueError, match="cannot ride"):
        ScenarioSpec("bad", codec="ternary2bit",
                     strategy=VoteStrategy.HIERARCHICAL)
    # a tie policy the codec's wire cannot realise is rejected
    with pytest.raises(ValueError):
        ScenarioSpec("bad", codec="ternary2bit",
                     strategy=VoteStrategy.ALLGATHER_1BIT,
                     tie_break="plus_one")


def test_codec_grid_axis_expansion():
    specs = expand_grid({
        "prefix": "cg", "fractions": [0.0, 0.25], "modes": ["sign_flip"],
        "strategies": ["allgather_1bit"],
        "codecs": ["sign1bit", "ef_sign", "ternary2bit", "weighted_vote"],
        "base": {"n_workers": 8, "n_steps": 3, "dim": 32}})
    assert len(specs) == 4 * 2
    assert {s.codec for s in specs} == {"sign1bit", "ef_sign",
                                        "ternary2bit", "weighted_vote"}
    assert all(s.name.startswith("cg/") for s in specs)
    # the codec-less grid keeps its historical names (and PRNG salts)
    legacy = expand_grid({"prefix": "cg", "fractions": [0.25],
                          "modes": ["sign_flip"],
                          "strategies": ["allgather_1bit"],
                          "base": {"n_workers": 8, "n_steps": 3,
                                   "dim": 32}})
    assert legacy[0].name == "cg/sign_flip/allgather_1bit/f0.25"


def test_ternary_codec_tie_at_half_abstains_on_the_1bit_exchange():
    """At exactly 50% sign-flippers the sign1bit 1-bit wire marches +1
    (ties binarise); the ternary codec on the SAME exchange abstains —
    the 2-bit field carries what the 1-bit wire cannot (§8)."""
    def run(codec):
        spec = ScenarioSpec(f"codtie/{codec}", n_workers=16, n_steps=4,
                            dim=64, strategy=VoteStrategy.ALLGATHER_1BIT,
                            codec=codec, noise_scale=0.0,
                            adversary=AdversarySpec("sign_flip", 0.5))
        return ScenarioRunner(spec).run()
    t1 = run("sign1bit")
    assert t1.steps[-1].loss != t1.steps[0].loss      # ties -> +1, x moves
    t2 = run("ternary2bit")
    losses = [s.loss for s in t2.steps]
    assert losses.count(losses[0]) == len(losses)     # abstains, x frozen
    assert all(s.margin == 0.0 for s in t2.steps)


def test_ef_codec_changes_trajectory_but_not_the_wire_format():
    """ef_sign rides the identical wire (same bits/param, same tie rule)
    yet the residual changes what gets encoded from step 2 on."""
    base = dict(n_workers=15, n_steps=6, dim=128,
                strategy=VoteStrategy.ALLGATHER_1BIT)
    t_plain = ScenarioRunner(ScenarioSpec("efx/a", **base)).run()
    t_ef = ScenarioRunner(
        ScenarioSpec("efx/a", codec="ef_sign", **base)).run()
    s_plain, s_ef = t_plain.summary(), t_ef.summary()
    assert s_plain["bits_per_param"] == s_ef["bits_per_param"] == 1.0
    assert s_plain["tie_policy"] == s_ef["tie_policy"] == "plus_one"
    assert t_plain.digest != t_ef.digest
    assert np.isfinite([s.loss for s in t_ef.steps]).all()


def test_weighted_codec_learns_down_the_adversaries():
    """Under 37.5% sign-flippers the weighted decode's flip fraction (vs
    the honest oracle) collapses once the reliability EMA has one step of
    observations — the SignSGD-FD defense through the production drill
    path. The window is the gradient-dominated phase: near the optimum
    noise swamps the honest signs, every worker's disagreement estimate
    converges, and the discrimination (rightly) washes out."""
    base = dict(n_workers=16, n_steps=8, dim=512,
                strategy=VoteStrategy.ALLGATHER_1BIT,
                adversary=AdversarySpec("sign_flip", 0.375))
    t_plain = ScenarioRunner(ScenarioSpec("wdef/x", **base)).run()
    t_w = ScenarioRunner(
        ScenarioSpec("wdef/x", codec="weighted_vote", **base)).run()
    # step 0 decodes from the uninformed prior: identical to plain
    assert t_w.steps[0].flip_fraction == t_plain.steps[0].flip_fraction
    learned = slice(1, 6)
    plain_flip = float(np.mean(
        [s.flip_fraction for s in t_plain.steps[learned]]))
    w_flip = float(np.mean([s.flip_fraction for s in t_w.steps[learned]]))
    assert w_flip < 0.6 * plain_flip, (w_flip, plain_flip)
    assert np.isfinite([s.loss for s in t_w.steps]).all()


def test_plan_spec_roundtrips_and_validates():
    spec = ScenarioSpec("plan/io", n_workers=8, dim=64,
                        strategy=VoteStrategy.ALLGATHER_1BIT,
                        plan=PlanSpec(bucket_bytes=8,
                                      leaves=(("embed", 32), ("body", 32)),
                                      codec_map=(("embed*", "ternary2bit"),
                                                 ("*", "sign1bit"))))
    back = ScenarioSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back == spec and back.plan.enabled
    assert back.runtime_plan(8).n_buckets == spec.runtime_plan(8).n_buckets
    # a pre-plan serialised spec (no "plan" key) loads with it disabled
    legacy = {k: v for k, v in spec.to_dict().items() if k != "plan"}
    assert not ScenarioSpec.from_dict(legacy).plan.enabled
    with pytest.raises(ValueError, match="sum to dim"):
        ScenarioSpec("bad", dim=64,
                     plan=PlanSpec(bucket_bytes=8, leaves=(("a", 10),)))
    with pytest.raises(ValueError, match="bucket_bytes > 0"):
        PlanSpec(codec_map=(("*", "sign1bit"),))
    # a mapped codec the wire cannot carry is rejected at spec time
    with pytest.raises(ValueError, match="cannot ride"):
        ScenarioSpec("bad", strategy=VoteStrategy.PSUM_INT8, dim=64,
                     plan=PlanSpec(bucket_bytes=8,
                                   codec_map=(("*", "weighted_vote"),)))
    # worker-state codecs stay a spec-level choice, never a map entry
    with pytest.raises(ValueError, match="per-worker state"):
        ScenarioSpec("bad", strategy=VoteStrategy.ALLGATHER_1BIT, dim=64,
                     plan=PlanSpec(bucket_bytes=8,
                                   codec_map=(("*", "ef_sign"),)))
    # tie_break must be realisable by the MAPPED codecs, not just the
    # spec-level one: an all-ternary map resolves ties to 0
    with pytest.raises(ValueError, match="resolves ties"):
        ScenarioSpec("bad", strategy=VoteStrategy.ALLGATHER_1BIT, dim=64,
                     tie_break="plus_one",
                     plan=PlanSpec(bucket_bytes=8,
                                   codec_map=(("*", "ternary2bit"),)))
    # a map mixing conventions reports per-segment semantics honestly
    mixed = ScenarioSpec(
        "ok3", strategy=VoteStrategy.ALLGATHER_1BIT, dim=64,
        plan=PlanSpec(bucket_bytes=8, leaves=(("embed", 32), ("body", 32)),
                      codec_map=(("embed*", "ternary2bit"),
                                 ("*", "sign1bit"))))
    assert mixed.wire_codecs() == ("sign1bit", "ternary2bit")
    assert mixed.tie_policy == "mixed"


def test_golden_trace_through_single_bucket_plan():
    """The VotePlan refactor's fixed point (§9): the sign1bit
    single-bucket plan drives the same wire through the bucket schedule
    and MUST reproduce the pre-plan golden digest bit for bit — and so
    must any other bucket cut, because the sign1bit majority is
    coordinate-wise."""
    for bucket_bytes in (1 << 20, 4):
        spec = ScenarioSpec.from_dict(
            {**GOLDEN_SPEC.to_dict(),
             "plan": {"bucket_bytes": bucket_bytes}})
        t = ScenarioRunner(spec).run()
        assert t.digest == GOLDEN_DIGEST, (
            f"bucketed wire (bucket_bytes={bucket_bytes}) diverged from "
            f"the golden trace: {t.digest}")


def test_plan_summary_prices_the_schedule():
    base = dict(n_workers=8, n_steps=3, dim=256,
                strategy=VoteStrategy.ALLGATHER_1BIT)
    s_leaf = ScenarioRunner(ScenarioSpec("plansum/a", **base)).run() \
        .summary()
    s_plan = ScenarioRunner(ScenarioSpec(
        "plansum/a", plan=PlanSpec(bucket_bytes=8), **base)).run() \
        .summary()
    assert s_leaf["plan_buckets"] == 0
    assert s_plan["plan_buckets"] == 4
    # same bytes, one alpha term per bucket: the schedule prices higher
    # than the single-message wire (the latency the plan trades against
    # per-leaf chatter is now visible, not silently zero)
    assert s_plan["est_exchange_time_s"] > s_leaf["est_exchange_time_s"]
    assert s_plan["digest"] == s_leaf["digest"]   # sign1bit fixed point


def test_plan_mixed_codec_tie_semantics():
    """In one bucketed vote, ternary-mapped coordinates abstain on a
    50% tie while sign1bit-mapped coordinates march +1 — per-bucket
    codecs deliver per-segment tie semantics on a single wire."""
    spec = ScenarioSpec(
        "plantie/mixed", n_workers=16, n_steps=3, dim=64,
        strategy=VoteStrategy.ALLGATHER_1BIT, noise_scale=0.0,
        adversary=AdversarySpec("sign_flip", 0.5),
        plan=PlanSpec(bucket_bytes=4,
                      leaves=(("embed", 32), ("body", 32)),
                      codec_map=(("embed*", "ternary2bit"),
                                 ("*", "sign1bit"))))
    t = ScenarioRunner(spec).run()
    plan = spec.runtime_plan(16)
    assert {g.codec for g in plan.groups} == {"ternary2bit", "sign1bit"}
    # every count is exactly zero: margin 0, but the sign1bit segment's
    # ties binarise to +1 so the iterate still moves
    assert all(s.margin == 0.0 for s in t.steps)
    assert t.steps[-1].loss != t.steps[0].loss
    # the pure-ternary plan abstains everywhere: the iterate freezes
    pure = ScenarioSpec(
        "plantie/tern", n_workers=16, n_steps=3, dim=64,
        strategy=VoteStrategy.ALLGATHER_1BIT, codec="ternary2bit",
        noise_scale=0.0, adversary=AdversarySpec("sign_flip", 0.5),
        plan=PlanSpec(bucket_bytes=4))
    tp = ScenarioRunner(pure).run()
    losses = [s.loss for s in tp.steps]
    assert losses.count(losses[0]) == len(losses)


def test_virtual_vote_matches_ref_oracle():
    """The virtual wire path == kernels/ref.py majority on ±1 signs (odd
    M), for every strategy — no lookalike aggregation."""
    from repro.kernels import ref
    rng = np.random.default_rng(7)
    signs = np.where(rng.integers(0, 2, size=(9, 130)) == 1, 1, -1) \
        .astype(np.int8)
    pad = (-130) % sc.PACK
    packed = np.stack([np.asarray(sc.pack_signs(jnp.asarray(
        np.pad(s, (0, pad)).astype(np.float32)))) for s in signs])
    want = np.asarray(sc.unpack_signs(ref.majority(jnp.asarray(packed)),
                                      jnp.int8))[:130]
    for strategy in STRATS:
        got = np.asarray(virtual_vote(jnp.asarray(signs), strategy))
        np.testing.assert_array_equal(got, want, err_msg=str(strategy))
