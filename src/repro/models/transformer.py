"""Decoder-only transformer stack (dense + MoE variants).

Layers are stored stacked (leading ``L`` axis) and the stack is a single
``lax.scan`` over depth, keeping HLO size O(1) in depth — required for the
95-layer dry-run compiles. The gemma3 5:1 local:global pattern rides
through the scan as a per-layer boolean; local layers select a
sliding-window mask width, global layers the full context (same HLO for
every layer, so the scan stays homogeneous).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import BATCH, shard
from repro.models import layers as L
from repro.models.moe import moe_ffn


def _layer_tree(p: Dict[str, jax.Array], prefix: str = "layers."
                ) -> Dict[str, jax.Array]:
    return {k[len(prefix):]: v for k, v in p.items() if k.startswith(prefix)}


def residual_shard(h: jax.Array, cfg) -> jax.Array:
    """Residual-stream constraint between blocks; sequence-parallel for
    big Mode-B archs (cfg.act_seq_shard) so scan residuals store 1/16."""
    if cfg.act_seq_shard:
        return shard(h, BATCH, "model", None)
    return shard(h, BATCH, None, None)


def maybe_remat(fn, remat: str):
    if remat in ("full", "nested"):
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return fn


def _best_group(n_layers: int) -> int:
    """Divisor of L nearest sqrt(L) — the sqrt-remat group size."""
    import math
    best, target = 1, math.sqrt(n_layers)
    for k in range(1, n_layers + 1):
        if n_layers % k == 0 and abs(k - target) < abs(best - target):
            best = k
    return best


def _window_for(cfg, is_local: jax.Array, seq_len: int) -> Optional[jax.Array]:
    if not cfg.sliding_window:
        return None
    return jnp.where(is_local, cfg.sliding_window, seq_len + 1)


def decoder_block(lp: Dict[str, jax.Array], h: jax.Array, cfg, *,
                  window: Optional[jax.Array],
                  positions: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """One pre-norm block. Returns (h, aux_loss)."""
    attn_in = L.rms_norm(h, lp["norm1_scale"], cfg.norm_eps)
    attn_out, _ = L.self_attention_block(
        lp, "attn", attn_in, cfg, causal=True, window=window,
        positions=positions)
    h = h + attn_out
    ffn_in = L.rms_norm(h, lp["norm2_scale"], cfg.norm_eps)
    if cfg.moe.enabled:
        ffn_out, aux = moe_ffn(lp, ffn_in, cfg.moe)
    else:
        ffn_out = L.swiglu_mlp(lp, "mlp", ffn_in)
        aux = jnp.zeros((), jnp.float32)
    h = h + ffn_out
    return residual_shard(h, cfg), aux


def decoder_stack(p: Dict[str, jax.Array], h: jax.Array, cfg,
                  positions: Optional[jax.Array] = None,
                  hook=None, remat: str = "none"
                  ) -> Tuple[jax.Array, jax.Array]:
    """Scan the stacked layers. Returns (h, total_aux_loss).

    `hook(layer_tree, 'layers')` is the ZeRO-3 gather(+vote-bwd) transform;
    with remat it sits inside the checkpointed body, so gathered params are
    re-gathered (not stored) for the backward pass — exactly ZeRO-3.
    """
    lp = _layer_tree(p)
    local = jnp.asarray(cfg.local_layer_mask(), dtype=bool)
    S = h.shape[1]
    L = cfg.num_layers

    def body(carry, xs):
        layer_p, is_local = xs
        if hook is not None:
            layer_p = hook(layer_p, "layers")
        window = _window_for(cfg, is_local, S)
        carry, aux = decoder_block(layer_p, carry, cfg, window=window,
                                   positions=positions)
        return carry, aux

    if remat == "nested" and L >= 4:
        # sqrt-remat: outer scan over groups is checkpointed; residuals are
        # stored only at group boundaries (L/k of them), each group's
        # interior recomputed during its backward. Peak residual memory
        # drops from L x act to (L/k + k) x act.
        k = _best_group(L)
        lp_g = {n: v.reshape((L // k, k) + v.shape[1:])
                for n, v in lp.items()}
        local_g = local.reshape(L // k, k)

        @jax.checkpoint
        def outer(carry, xs):
            gp, gl = xs
            carry, auxes = jax.lax.scan(body, carry, (gp, gl))
            return carry, jnp.sum(auxes)

        h, auxes = jax.lax.scan(outer, h, (lp_g, local_g))
        return h, jnp.sum(auxes)

    h, auxes = jax.lax.scan(maybe_remat(body, remat), h, (lp, local))
    return h, jnp.sum(auxes)


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> Dict[str, jax.Array]:
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, K, hd)
    if cfg.kv_cache_dtype == "int8":
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
                "v_scale": jnp.zeros(shape[:-1], jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decoder_prefill(p: Dict[str, jax.Array], h: jax.Array, cfg, hook=None
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Forward pass that also returns the populated KV cache."""
    lp = _layer_tree(p)
    local = jnp.asarray(cfg.local_layer_mask(), dtype=bool)
    S = h.shape[1]

    quantized = cfg.kv_cache_dtype == "int8"

    def body(carry, xs):
        layer_p, is_local = xs
        if hook is not None:
            layer_p = hook(layer_p, "layers")
        window = _window_for(cfg, is_local, S)
        attn_in = L.rms_norm(carry, layer_p["norm1_scale"], cfg.norm_eps)
        attn_out, (k, v) = L.self_attention_block(
            layer_p, "attn", attn_in, cfg, causal=True, window=window)
        carry = carry + attn_out
        ffn_in = L.rms_norm(carry, layer_p["norm2_scale"], cfg.norm_eps)
        if cfg.moe.enabled:
            ffn_out, _ = moe_ffn(layer_p, ffn_in, cfg.moe)
        else:
            ffn_out = L.swiglu_mlp(layer_p, "mlp", ffn_in)
        carry = carry + ffn_out
        # shard the produced cache over 'model': heads when divisible,
        # else sequence (otherwise a 32k cache leaf is replicated 16x)
        from repro.distributed.sharding import mesh_axis_size
        if cfg.num_kv_heads % max(mesh_axis_size("model"), 1) == 0:
            k = shard(k, None, None, "model", None)
            v = shard(v, None, None, "model", None)
        else:
            k = shard(k, None, "model", None, None)
            v = shard(v, None, "model", None, None)
        if quantized:
            kq, ksc = L.quantize_kv(k)
            vq, vsc = L.quantize_kv(v)
            return carry, (kq, vq, ksc, vsc)
        return carry, (k, v)

    if quantized:
        h, (ks, vs, kscs, vscs) = jax.lax.scan(body, h, (lp, local))
        return h, {"k": ks, "v": vs, "k_scale": kscs, "v_scale": vscs}
    h, (ks, vs) = jax.lax.scan(body, h, (lp, local))
    return h, {"k": ks, "v": vs}


def decoder_decode_step(p: Dict[str, jax.Array], h: jax.Array,
                        cache: Dict[str, jax.Array], pos: jax.Array, cfg
                        ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """h (B,1,d); cache {'k','v'} (L,B,Smax,K,hd); pos scalar int32."""
    lp = _layer_tree(p)
    local = jnp.asarray(cfg.local_layer_mask(), dtype=bool)

    quantized = "k_scale" in cache
    keys = (("k", "v", "k_scale", "v_scale") if quantized else ("k", "v"))

    # The cache rides the loop CARRY (sliced/written back per layer) rather
    # than scan xs->ys: stacked xs and stacked ys are separate buffers,
    # double-buffering a multi-GB cache; carries alias in place.
    def body(i, carry):
        h, cache = carry
        layer_p = jax.tree.map(lambda a: a[i], lp)
        is_local = local[i]
        sliced = {kk: cache[kk][i] for kk in keys}
        window = None
        if cfg.sliding_window:
            window = jnp.where(is_local, cfg.sliding_window, 1 << 30)
        attn_in = L.rms_norm(h, layer_p["norm1_scale"], cfg.norm_eps)
        res = L.decode_self_attention(
            layer_p, "attn", attn_in, cfg, k_cache=sliced["k"],
            v_cache=sliced["v"], pos=pos, window=window,
            k_scale=sliced.get("k_scale"), v_scale=sliced.get("v_scale"))
        attn_out = res[0]
        h = h + attn_out
        ffn_in = L.rms_norm(h, layer_p["norm2_scale"], cfg.norm_eps)
        if cfg.moe.enabled:
            ffn_out, _ = moe_ffn(layer_p, ffn_in, cfg.moe)
        else:
            ffn_out = L.swiglu_mlp(layer_p, "mlp", ffn_in)
        h = h + ffn_out
        cache = {
            kk: jax.lax.dynamic_update_index_in_dim(cache[kk], r, i, 0)
            for kk, r in zip(keys, res[1:])}
        return h, cache

    h, cache = jax.lax.fori_loop(0, cfg.num_layers, body, (h, cache))
    return h, cache
