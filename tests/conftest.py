"""Shared pytest setup.

Puts ``src/`` on sys.path so ``pytest`` works without exporting PYTHONPATH
(the tier-1 command in ROADMAP.md still sets it; both paths converge here).
Markers are registered in pytest.ini.
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if _SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(_SRC))
