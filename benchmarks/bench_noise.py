"""Fig. 2/3 analog: gradient-noise unimodality/symmetry and per-coordinate
SNR vs the critical line, measured on a real LM (reduced glm4) trained on
the synthetic pipeline — the empirical basis of Assumption 4."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.core import theory
from repro.data.pipeline import SyntheticLMPipeline
from repro.models import model as M


def _grad_samples(cfg, params, pipe, coords, n_samples=24):
    """Per-sample gradients at `coords` of the first mlp weight."""
    out = []
    for i in range(n_samples):
        batch = {k: jnp.asarray(v) for k, v in pipe.global_batch_at(i).items()}
        g = jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0])(params)
        w = np.asarray(g["layers.mlp_w_gate"], np.float32).reshape(-1)
        out.append(w[coords])
    return np.asarray(out)  # (n_samples, n_coords)


def rows():
    cfg = reduced_config(get_config("glm4-9b"), num_layers=2)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    pipe = SyntheticLMPipeline(cfg, global_batch=8, seq_len=64, seed=0)
    rng = np.random.default_rng(0)
    dim = int(np.prod(cfg.param_shapes()["layers.mlp_w_gate"]))
    coords = rng.integers(0, dim, size=256)
    samples = _grad_samples(cfg, params, pipe, coords)

    # Fig 2: unimodality/symmetry proxies
    centered = samples - samples.mean(axis=0, keepdims=True)
    std = centered.std(axis=0) + 1e-12
    skew = np.mean((centered / std) ** 3, axis=0)
    kurt = np.mean((centered / std) ** 4, axis=0)
    # Fig 3: SNR distribution vs critical line
    snr = np.abs(samples.mean(axis=0)) / std
    frac_below = float(np.mean(snr < theory.CRITICAL_SNR))
    return [
        ("fig2/mean_abs_skewness", float(np.mean(np.abs(skew))),
         "symmetric -> ~0"),
        ("fig2/mean_excess_kurtosis", float(np.mean(kurt - 3.0)),
         "unimodal-ish; Gaussian -> 0"),
        ("fig3/mean_snr", float(np.mean(snr)),
         f"critical={theory.CRITICAL_SNR:.3f}"),
        ("fig3/frac_coords_below_critical_snr", frac_below,
         "paper: ~1.0 after warmup"),
        ("fig3/max_snr", float(np.max(snr)), ""),
    ]


def main() -> None:
    from benchmarks.common import rows_main
    rows_main("noise", __doc__, rows)


if __name__ == "__main__":
    main()
