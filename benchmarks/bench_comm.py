"""Fig. 5 analog: per-step communication of majority vote vs dense
all-reduce, from (a) the analytic wire model and (b) measured wall-clock of
the actual kernels + vote math on this host (compression/vote cost incl.).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import VoteStrategy, get_config
from repro.core.majority_vote import comm_bytes_per_step
from repro.distributed.comm_model import collective_time
from repro.kernels import ops


def _time(fn, *args, iters=5):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def rows():
    out = []
    # ---- analytic wire model per arch (single-pod mesh, 16 DP voters) ----
    for arch in ["zamba2-1.2b", "glm4-9b", "deepseek-67b",
                 "qwen3-moe-235b-a22b"]:
        n = get_config(arch).param_count() // 16  # per-chip TP shard
        for strat in VoteStrategy:
            c = comm_bytes_per_step(n, strat, data_size=16, pod_size=1)
            t_dense = collective_time(c["dense_allreduce"]).time_s
            t_vote = collective_time(c["vote"]).time_s
            out.append((
                f"fig5/{arch}/{strat.value}_comm_reduction",
                c["ratio"],
                f"dense={t_dense * 1e3:.2f}ms vote={t_vote * 1e3:.2f}ms "
                f"@50GB/s/link x4"))
    # ---- measured compression+vote cost (the paper's 'incl. compression')
    n = 25_000_000  # resnet50-scale, the paper's model
    g = jnp.asarray(np.random.default_rng(0).normal(size=(n,))
                    .astype(np.float32))
    m = jnp.zeros((n,), jnp.float32)
    t_pack = _time(lambda: ops.momentum_sign_pack(g, m, 0.9))
    packed = jnp.stack([ops.bitpack(g)] * 15)
    t_vote = _time(lambda: ops.majority(packed))
    p = jnp.zeros((n,), jnp.float32)
    t_apply = _time(lambda: ops.apply_vote(p, packed[0], 1e-4, 0.0))
    out.append(("fig5/pack25M_ms", t_pack * 1e3,
                "fused momentum+sign+bitpack (interpret on CPU)"))
    out.append(("fig5/vote25M_15workers_ms", t_vote * 1e3,
                "popcount majority kernel"))
    out.append(("fig5/apply25M_ms", t_apply * 1e3, "fused unpack+update"))
    return out
