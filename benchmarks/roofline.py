"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

  compute term    = FLOPs / (chips * 197e12)
  memory term     = HBM bytes / (chips * 819e9)
  collective term = transit bytes / (chips' links)  [ICI 4x50GB/s, DCI 25GB/s]

Sources and caveats (verified experimentally, see EXPERIMENTS.md §Dry-run):
* collective bytes: parsed from compiled HLO with while-loop trip-count
  correction (launch.hlo_stats) — ``cost_analysis`` has no collective
  accounting.
* FLOPs: XLA's ``cost_analysis`` counts a rolled loop body ONCE (a scan of
  8 matmuls reports 1/8 of the unrolled flops), and whether XLA unrolls a
  given scan varies per cell — so the compute term uses a documented
  analytic model; the raw HLO number is reported as a cross-check.
* HBM bytes: same loop caveat; the memory term uses an analytic model of
  parameter+activation traffic, with raw HLO bytes as cross-check.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.configs.base import SHAPES, ArchFamily, get_config
from repro.distributed import comm_model as CM

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.jsonl")


# ---------------------------------------------------------------------------
# analytic FLOPs / bytes
# ---------------------------------------------------------------------------


def _attn_layers(cfg) -> float:
    """Effective full-attention layer count (gemma3 local layers count at
    window/seq fraction; returned as a weight applied to S^2)."""
    if cfg.family == ArchFamily.SSM:
        return 0.0
    if cfg.family == ArchFamily.HYBRID:
        return float(cfg.num_shared_attn_calls)
    return float(cfg.num_layers)


def analytic_train_flops(cfg, global_batch: int, seq: int,
                         remat: bool = True) -> float:
    """Matmul + attention flops for one train step (fwd+bwd+remat)."""
    tokens = global_batch * seq
    mat_fwd = 2.0 * cfg.active_param_count() * tokens
    hhd = cfg.num_heads * cfg.resolved_head_dim
    attn_fwd = 0.0
    if hhd:
        for i in range(int(_attn_layers(cfg))):
            s_eff = seq
            if cfg.sliding_window and cfg.layer_is_local(i):
                s_eff = min(seq, cfg.sliding_window)
            # qk^T + av, causal halves the square
            attn_fwd += 2.0 * global_batch * seq * s_eff * hhd
    fwd = mat_fwd + attn_fwd
    return fwd * (4.0 if remat else 3.0)  # bwd = 2x fwd; remat adds ~1x


def analytic_infer_flops(cfg, batch: int, seq: int, kind: str) -> float:
    hhd = cfg.num_heads * cfg.resolved_head_dim
    if kind == "prefill":
        tokens = batch * seq
        attn = 2.0 * batch * seq * seq * hhd * _attn_layers(cfg) if hhd else 0
        return 2.0 * cfg.active_param_count() * tokens + attn
    # decode: one token against a seq-long history
    attn = 4.0 * batch * seq * hhd * _attn_layers(cfg) if hhd else 0
    return 2.0 * cfg.active_param_count() * batch + attn


def analytic_hbm_bytes(cfg, cell, n_chips: int, kind: str) -> float:
    """Per-chip HBM traffic: parameter reads (+grad/opt passes for train)
    + KV/state traffic for decode. Activation traffic is folded in as 20%
    overhead (documented approximation)."""
    p_bytes = cfg.param_count() * 2 / n_chips  # bf16, fully sharded
    if kind == "train":
        micro = 8
        # fwd + remat reads per microbatch, grad write, momentum rw, update
        traffic = p_bytes * (2 * micro + 4)
    elif kind == "prefill":
        traffic = p_bytes * 1.2
    else:  # decode: params + full KV cache read per token
        kv = 0.0
        if cfg.num_kv_heads:
            kv = (2 * cell.global_batch * cell.seq_len * cfg.num_kv_heads
                  * cfg.resolved_head_dim
                  * (1 if cfg.kv_cache_dtype == "int8" else 2)
                  * _attn_layers(cfg) / n_chips)
        if cfg.ssm.enabled:
            kv += (cfg.num_layers * cell.global_batch
                   * cfg.ssm.n_heads(cfg.d_model) * cfg.ssm.head_dim
                   * cfg.ssm.state_dim * 4 / n_chips)
        traffic = p_bytes + kv
    return traffic * 1.2


# ---------------------------------------------------------------------------
# table
# ---------------------------------------------------------------------------


def load_records(path: str = RESULTS) -> List[Dict]:
    if not os.path.exists(path):
        return []
    return [json.loads(l) for l in open(path)]


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    cell = SHAPES[rec["shape"]]
    chips = rec["n_chips"]
    micro = rec.get("microbatches", 1)
    if cell.kind == "train":
        flops = analytic_train_flops(cfg, cell.global_batch, cell.seq_len)
    else:
        flops = analytic_infer_flops(cfg, cell.global_batch, cell.seq_len,
                                     cell.kind)
    flops_chip = flops / chips
    hbm_chip = analytic_hbm_bytes(cfg, cell, chips, cell.kind)
    coll = rec["collectives"]
    t_compute = flops_chip / CM.PEAK_FLOPS
    t_memory = hbm_chip / CM.HBM_BW
    t_coll = (coll["transit_bytes_ici"] / (CM.ICI_BW_PER_LINK * CM.ICI_LINKS)
              + coll["transit_bytes_dci"] / CM.DCI_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    model_flops = (6.0 if cell.kind == "train" else 2.0) \
        * cfg.active_param_count() \
        * (cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1))
    bound = max(terms.values())
    frac = (t_compute / bound) if bound > 0 else 0.0
    suggestions = {
        "compute": "compute-bound: already at the useful-flops roof; gains "
                   "need lower remat recompute or sparsity",
        "memory": "HBM-bound: raise arithmetic intensity (larger "
                  "microbatch, fuse optimizer passes, int8 cache)",
        "collective": "collective-bound: cheapen the dominant collective "
                      "(vote compression already 1-8 bit; next: overlap, "
                      "fewer FSDP gathers, EP all-to-all scheduling)",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "opt": rec.get("opt", ""),
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": frac,
        "model_flops": model_flops,
        "flops_analytic": flops,
        "useful_flops_ratio": model_flops / flops,
        "flops_hlo_raw_chip": rec.get("flops_per_chip", 0.0),
        "hbm_hlo_raw_chip": rec.get("hbm_bytes_per_chip", 0.0),
        "peak_gib_chip": rec["memory"]["peak_bytes_per_chip"] / 2 ** 30,
        "ici_gib": coll["transit_bytes_ici"] / 2 ** 30,
        "dci_gib": coll["transit_bytes_dci"] / 2 ** 30,
        "note": suggestions[dominant],
    }


def table(records: Optional[List[Dict]] = None) -> List[Dict]:
    records = records if records is not None else load_records()
    rows_, seen = [], set()
    for rec in records:
        key = (rec["arch"], rec["shape"], rec["mesh"], rec.get("opt"))
        if key in seen:
            continue
        r = roofline_row(rec)
        if r is not None:
            seen.add(key)
            rows_.append(r)
    return rows_


def markdown_table(rows_: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (ms) | memory (ms) | "
           "collective (ms) | dominant | roofline frac | useful-flops | "
           "peak GiB |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows_:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s'] * 1e3:.2f} | {r['memory_s'] * 1e3:.2f} "
            f"| {r['collective_s'] * 1e3:.2f} | {r['dominant']} "
            f"| {r['roofline_fraction']:.2f} "
            f"| {r['useful_flops_ratio']:.2f} | {r['peak_gib_chip']:.1f} |")
    return "\n".join(lines)


def rows():
    """CSV rows for benchmarks.run (single-pod signum cells)."""
    out = []
    for r in table():
        if r["mesh"] != "16x16" or r["opt"] not in ("signum_vote", ""):
            continue
        out.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['dominant']}",
            r["roofline_fraction"],
            f"c={r['compute_s'] * 1e3:.2f}ms m={r['memory_s'] * 1e3:.2f}ms "
            f"coll={r['collective_s'] * 1e3:.2f}ms "
            f"useful={r['useful_flops_ratio']:.2f}"))
    return out


def main() -> None:
    from benchmarks.common import rows_main
    rows_main("roofline", __doc__, rows)


if __name__ == "__main__":
    main()
