"""Byzantine adversary models (paper §3.4, Fig. 4).

Adversaries are *non-cooperating*: each manipulates only its own sign
vector, keyed on the replica's index along the vote axes. Transforms are
jit-compatible and applied between local sign computation and the vote, so
they compose with every vote strategy — including the fused
vote-in-backward path.

Modes
  sign_flip  — send the negation (the paper's strongest adversary)
  random     — send random ±1 (corrupted-worker model)
  zero       — abstain every step (crashed/mute worker)
  none       — honest
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ByzantineConfig


def replica_index(axis_names: Sequence[str], like=None) -> jax.Array:
    """Linear index of this replica over the (manual) vote axes.

    `like` anchors the legacy-JAX emulation's sharding (see
    ``compat.axis_index``); pass any traced array from the manual region.
    """
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * compat.axis_size(name) + compat.axis_index(name,
                                                               like=like)
    return idx


def apply_adversary(signs: jax.Array, cfg: ByzantineConfig,
                    axis_names: Sequence[str], *,
                    step: jax.Array | None = None,
                    salt: int = 0) -> jax.Array:
    """Transform this replica's int8 sign tensor per the adversary model.

    Replicas with linear index < cfg.num_adversaries act adversarially
    (which replicas are adversarial is immaterial to the vote — only the
    count matters, Theorem 2).
    """
    if cfg.mode == "none" or cfg.num_adversaries == 0:
        return signs
    idx = replica_index(axis_names, like=signs)
    is_adv = idx < cfg.num_adversaries
    if cfg.mode == "sign_flip":
        evil = -signs
    elif cfg.mode == "zero":
        evil = jnp.zeros_like(signs)
    elif cfg.mode == "random":
        key = jax.random.PRNGKey(cfg.seed + salt)
        key = jax.random.fold_in(key, idx)
        if step is not None:
            key = jax.random.fold_in(key, step)
        rnd = jax.random.bernoulli(key, 0.5, signs.shape)
        evil = jnp.where(rnd, jnp.int8(1), jnp.int8(-1))
    else:
        raise ValueError(f"unknown byzantine mode {cfg.mode!r}")
    return jnp.where(is_adv, evil, signs)
