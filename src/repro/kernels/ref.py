"""Pure-jnp oracles for every Pallas kernel in this package.

These define the exact semantics the kernels must reproduce; tests sweep
shapes/dtypes and ``assert_allclose`` kernel-vs-oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sign_compress as sc

PACK = sc.PACK


def bitpack(x: jax.Array) -> jax.Array:
    """(rows, 32*w) real -> (rows, w) uint32; bit j of word k = x[.,32k+j]>=0."""
    return sc.pack_signs(x)


def bitunpack(packed: jax.Array, dtype=jnp.float32) -> jax.Array:
    """(rows, w) uint32 -> (rows, 32*w) of ±1 in `dtype`."""
    return sc.unpack_signs(packed, dtype)


def majority(packed: jax.Array) -> jax.Array:
    """(M, w) packed -> (w,) packed majority (ties -> +1)."""
    return sc.packed_majority(packed)


def fused_majority(x: jax.Array) -> jax.Array:
    """(M, n) real, n % 32 == 0 -> (n//32,) packed majority: the composed
    sign+pack+popcount semantics the fused kernel must reproduce."""
    return sc.packed_majority(sc.pack_signs(x))


def ternary_pack(s: jax.Array) -> jax.Array:
    """(rows, 16*w) int in {-1,0,+1} -> (rows, w) uint32; 2-bit fields,
    +1 -> 0b01, -1 -> 0b11, abstain -> 0b00 (codec ``ternary2bit``)."""
    return sc.pack_ternary(s.astype(jnp.int8))


def ternary_unpack(packed: jax.Array, dtype=jnp.int8) -> jax.Array:
    """(rows, w) uint32 -> (rows, 16*w) of {-1,0,+1} in `dtype`."""
    return sc.unpack_ternary(packed, dtype)


def ternary_majority(packed: jax.Array) -> jax.Array:
    """(M, w) packed ternary -> (w,) packed ternary majority (sign of the
    symbol sum: abstentions abstain, ties -> 0)."""
    return sc.ternary_majority(packed)


def momentum_sign_pack(g: jax.Array, m: jax.Array, beta: float
                       ) -> tuple[jax.Array, jax.Array]:
    """SIGNUM worker-side hot loop: m' = beta*m + (1-beta)*g;
    packed = pack(sign(m')). g/m (rows, 32*w). Returns (m', packed)."""
    m_new = beta * m + (1.0 - beta) * g.astype(m.dtype)
    return m_new, sc.pack_signs(m_new)


def apply_vote(p: jax.Array, votes_packed: jax.Array, eta: float,
               weight_decay: float) -> jax.Array:
    """x <- x - eta*(unpack(vote) + lambda*x); p (rows, 32*w)."""
    v = sc.unpack_signs(votes_packed, jnp.float32)
    p32 = p.astype(jnp.float32)
    return (p32 - eta * (v + weight_decay * p32)).astype(p.dtype)
