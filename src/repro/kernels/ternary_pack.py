"""Pallas TPU kernels: ternary 2-bit packing and field-sliced tally.

The ``ternary2bit`` codec's wire (DESIGN.md §8): 16 ternary symbols per
uint32 word, 2-bit two's-complement fields, little-endian within the word
(+1 → 0b01, -1 → 0b11, 0/abstain → 0b00 — the layout of
``sign_compress.pack_ternary``, which is these kernels' oracle).

* ``ternary_pack_2d`` — pack a block of int32 ternary signs with an
  unrolled shift/OR tree over the 16 sub-lanes of each output word. Like
  ``bitpack``: pure VPU bit arithmetic, bandwidth-bound, 1 read of the
  symbol source and a 1/16-size write.
* ``ternary_tally_2d`` — the "server" inner loop after the packed
  all-gather: (M, w) packed words -> (w,) packed ternary majority.
  Field-sliced: for each of the 16 fields, sign-extend across the M
  voters, sum, take the sign of the count (abstentions abstain, exact
  ties -> 0 — the integer-count tie convention, unlike the 1-bit wire's
  ties -> +1), re-pack. No unpacked ±1 tensor ever touches HBM.

Block shapes: pack input (8, 2048) int32 -> (8, 128) uint32 per grid
step; tally (M, 512) words per grid step (M is small — data-parallel
replicas, 16..32 — so a whole voter column fits VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK2 = 16
ROWS = 8
WORDS = 128   # output lane dim; input lane dim = 16*128 = 2048
WBLOCK = 512


def _ternary_pack_kernel(s_ref, out_ref):
    s = s_ref[...]                                   # (ROWS, WORDS*16) int32
    sym = (s & 0x3).astype(jnp.uint32)               # 2-bit two's complement
    fields = sym.reshape(s.shape[0], s.shape[1] // PACK2, PACK2)
    acc = jnp.zeros(fields.shape[:2], jnp.uint32)
    for j in range(PACK2):                           # unrolled shift/OR tree
        acc = acc | (fields[:, :, j] << jnp.uint32(2 * j))
    out_ref[...] = acc


def _ternary_tally_kernel(p_ref, out_ref):
    p = p_ref[...]                                   # (M, WBLOCK) uint32
    acc = jnp.zeros((p.shape[1],), jnp.uint32)
    for j in range(PACK2):                           # field-sliced count
        f = (p >> jnp.uint32(2 * j)) & jnp.uint32(0x3)
        s = jnp.where(f == 1, 1, jnp.where(f == 3, -1, 0))   # (M, W) int32
        cnt = jnp.sum(s, axis=0)                     # (W,)
        maj = jnp.where(cnt > 0, jnp.uint32(1),
                        jnp.where(cnt < 0, jnp.uint32(3), jnp.uint32(0)))
        acc = acc | (maj << jnp.uint32(2 * j))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def ternary_pack_2d(s: jax.Array, *, interpret: bool = False) -> jax.Array:
    """s (rows, 16*w) int32 in {-1,0,+1}, rows % 8 == 0, w % 128 == 0
    -> (rows, w) uint32."""
    rows, n = s.shape
    w = n // PACK2
    grid = (rows // ROWS, w // WORDS)
    return pl.pallas_call(
        _ternary_pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, WORDS * PACK2),
                               lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((ROWS, WORDS), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, w), jnp.uint32),
        interpret=interpret,
    )(s)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ternary_tally_packed(packed: jax.Array, *, interpret: bool = False
                         ) -> jax.Array:
    """packed (M, w) uint32, w % 512 == 0 -> (w,) packed ternary majority."""
    m, w = packed.shape
    grid = (w // WBLOCK,)
    return pl.pallas_call(
        _ternary_tally_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, WBLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((WBLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.uint32),
        interpret=interpret,
    )(packed)
