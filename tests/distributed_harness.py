"""Multi-device validation harness, run in a subprocess by
test_distributed.py (so the main pytest session keeps 1 CPU device).

Validates on an 8-device (data=4, model=2) mesh:
  1. tree_vote strategies == flat numpy reference (incl. Byzantine);
  2. fused ZeRO gather-vote backward == per-replica sign/sum/sign;
  3. Mode A mesh train step == single-process per-worker-vote reference;
  3b. VotePlan bucketed step == leaf-wise step bit for bit (sign1bit),
      mixed-codec plan compiles and trains (DESIGN.md §9);
  4. Mode B fused train step runs and learns;
  5. dense SGDM baseline mesh step == psum-mean reference;
  6. stale-vote straggler substitution preserves convergence direction.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.compat import AxisType
from repro.configs.base import (ByzantineConfig, MomentumMode,
                                OptimizerConfig, TrainConfig, VoteStrategy,
                                get_config, reduced_config)
from repro.core import sign_compress as sc
from repro.core.majority_vote import make_gather_vote, tree_vote
from repro.core.vote_engine import VoteEngine
from repro.models import model as M
from repro.train import train_step as TS

MESH = compat.make_mesh((4, 2), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)
RNG = np.random.default_rng(0)


def check_tree_vote():
    def f(g):
        g = jax.tree.map(lambda x: x[0], g)
        out = {}
        for strat in VoteStrategy:
            if strat == VoteStrategy.AUTO:
                continue  # resolves to one of the concrete rows below
            out[strat.value] = tree_vote(g, strat, ("data",))
        return jax.tree.map(lambda x: x[None], out)

    sh = compat.shard_map(f, mesh=MESH, in_specs=(P("data"),),
                          out_specs=P("data"), axis_names={"data"},
                          check_vma=False)
    g = {"a": jnp.asarray(RNG.normal(size=(4, 37)).astype(np.float32)),
         "b": jnp.asarray(RNG.normal(size=(4, 8, 5)).astype(np.float32))}
    out = jax.jit(sh)(g)
    for k in g:
        s = np.sign(np.asarray(g[k])).astype(np.int32)
        count = s.sum(axis=0)
        for strat in VoteStrategy:
            if strat == VoteStrategy.AUTO:
                continue
            got = np.asarray(out[strat.value][k][0])
            if strat == VoteStrategy.PSUM_INT8:
                expect = np.sign(count)
            else:
                expect = np.where(count >= 0, 1, -1)
            np.testing.assert_array_equal(got, expect.astype(np.float32),
                                          err_msg=f"{strat} {k}")
    print("OK tree_vote strategies")


def check_byzantine_vote():
    byz = ByzantineConfig(mode="sign_flip", num_adversaries=1)

    def f(g):
        g = jax.tree.map(lambda x: x[0], g)
        v = tree_vote(g, VoteStrategy.PSUM_INT8, ("data",), byz)
        return jax.tree.map(lambda x: x[None], v)

    sh = compat.shard_map(f, mesh=MESH, in_specs=(P("data"),),
                          out_specs=P("data"), axis_names={"data"},
                          check_vma=False)
    g = {"a": jnp.asarray(RNG.normal(size=(4, 33)).astype(np.float32))}
    out = jax.jit(sh)(g)
    s = np.sign(np.asarray(g["a"])).astype(np.int32)
    count = -s[0] + s[1:].sum(axis=0)
    np.testing.assert_array_equal(np.asarray(out["a"][0]),
                                  np.sign(count).astype(np.float32))
    print("OK byzantine sign-flip in vote")


def check_fused_gather_vote():
    W = jnp.asarray(RNG.normal(size=(16, 12)).astype(np.float32))
    xs = jnp.asarray(RNG.normal(size=(4, 4, 16)).astype(np.float32))

    def step(w_slice, x):
        gather = make_gather_vote(0, "data", None, vote=True)

        def loss(ws):
            return jnp.sum((x[0] @ gather(ws)) ** 2)

        return jax.grad(loss)(w_slice)[None]

    sh = compat.shard_map(step, mesh=MESH, in_specs=(P("data"), P("data")),
                          out_specs=P("data"), axis_names={"data"},
                          check_vma=False)
    gr = np.asarray(jax.jit(sh)(W, xs)).reshape(16, 12)
    count = sum(np.sign(np.asarray(
        jax.grad(lambda w: jnp.sum((xs[i] @ w) ** 2))(W)))
        for i in range(4))
    np.testing.assert_array_equal(gr, np.sign(count))
    print("OK fused gather-vote backward")


def _mesh_batch(batch):
    return jax.tree.map(
        lambda a: jax.device_put(np.asarray(a),
                                 NamedSharding(MESH, P("data"))), batch)


def check_mode_a_matches_reference():
    cfg = reduced_config(get_config("glm4-9b"), num_layers=2)
    eta = 3e-3
    tcfg = TrainConfig(global_batch=8, seq_len=32,
                       optimizer=OptimizerConfig(kind="signum_vote",
                                                 learning_rate=eta))
    art = TS.make_train_step(cfg, tcfg, mesh=MESH)
    params, opt = TS.materialize_state(cfg, tcfg, art,
                                       jax.random.PRNGKey(0), MESH)
    batch = M.make_batch(cfg, 8, 32, jax.random.PRNGKey(1))
    pm, om, met = art.step_fn(params, opt, _mesh_batch(batch), jnp.int32(0))

    p0 = M.init_params(cfg, jax.random.PRNGKey(0))
    beta = tcfg.optimizer.momentum
    votes = {k: 0 for k in p0}
    for i in range(4):
        local = jax.tree.map(lambda x: x[i * 2:(i + 1) * 2], batch)
        g = jax.grad(lambda p: M.loss_fn(cfg, p, local)[0])(p0)
        for k in p0:
            votes[k] = votes[k] + np.sign(
                np.asarray((1 - beta) * g[k], np.float32))
    for k in p0:
        expect = np.asarray(p0[k], np.float32) - eta * np.sign(votes[k])
        np.testing.assert_allclose(
            np.asarray(pm[k], np.float32), expect, atol=2e-2, rtol=0,
            err_msg=k)
    print("OK Mode A mesh == flat reference")


def check_vote_plan_mode_a():
    """The bucketed wire (§9) on the real 8-device step: sign1bit votes
    are coordinate-wise majorities, so the VotePlan step must land
    BIT-IDENTICAL params to the leaf-wise step; a mixed-codec plan on
    the gathered wire must compile and train."""
    cfg = reduced_config(get_config("glm4-9b"), num_layers=2)

    def step_once(**opt_kw):
        tcfg = TrainConfig(global_batch=8, seq_len=32,
                           optimizer=OptimizerConfig(
                               kind="signum_vote", learning_rate=3e-3,
                               **opt_kw))
        art = TS.make_train_step(cfg, tcfg, mesh=MESH)
        params, opt = TS.materialize_state(cfg, tcfg, art,
                                           jax.random.PRNGKey(0), MESH)
        batch = _mesh_batch(M.make_batch(cfg, 8, 32, jax.random.PRNGKey(1)))
        params, opt, met = art.step_fn(params, opt, batch, jnp.int32(0))
        return art, params, float(met["loss"])

    _, p_leaf, _ = step_once()
    art, p_plan, _ = step_once(bucket_bytes=4096)
    assert art.plan is not None and art.plan.n_buckets > 1, \
        "plan step must actually bucket the wire"
    for k in p_leaf:
        np.testing.assert_array_equal(
            np.asarray(p_leaf[k], np.float32),
            np.asarray(p_plan[k], np.float32), err_msg=k)
    art2, _, loss2 = step_once(
        bucket_bytes=4096, vote_strategy=VoteStrategy.ALLGATHER_1BIT,
        codec_map=(("embed*", "ternary2bit"), ("*", "sign1bit")))
    assert {g.codec for g in art2.plan.groups} == \
        {"ternary2bit", "sign1bit"}
    assert np.isfinite(loss2)
    print(f"OK VotePlan Mode A: {art.plan.n_buckets}-bucket step == "
          f"leaf-wise bitwise; mixed-codec plan trains ({loss2:.2f})")


def check_mode_b_learns():
    cfg = reduced_config(get_config("glm4-9b"), num_layers=2)
    tcfg = TrainConfig(
        global_batch=8, seq_len=32, fsdp=True, remat="full",
        optimizer=OptimizerConfig(kind="signsgd_vote",
                                  momentum_mode=MomentumMode.GLOBAL,
                                  vote_strategy=VoteStrategy.HIERARCHICAL,
                                  learning_rate=3e-3))
    art = TS.make_train_step(cfg, tcfg, mesh=MESH)
    assert art.fused_leaves, "expected FSDP-fused leaves"
    params, opt = TS.materialize_state(cfg, tcfg, art,
                                       jax.random.PRNGKey(0), MESH)
    batch = _mesh_batch(M.make_batch(cfg, 8, 32, jax.random.PRNGKey(1)))
    first = None
    for i in range(20):
        params, opt, met = art.step_fn(params, opt, batch, jnp.int32(i))
        if first is None:
            first = float(met["loss"])
    last = float(met["loss"])
    assert last < first - 2.0, (first, last)
    print(f"OK Mode B fused learns ({first:.2f} -> {last:.2f})")


def check_dense_baseline_matches_mean():
    cfg = reduced_config(get_config("glm4-9b"), num_layers=1)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    eta = 0.1
    tcfg = TrainConfig(global_batch=8, seq_len=16,
                       optimizer=OptimizerConfig(kind="sgd",
                                                 learning_rate=eta))
    art = TS.make_train_step(cfg, tcfg, mesh=MESH)
    params, opt = TS.materialize_state(cfg, tcfg, art,
                                       jax.random.PRNGKey(0), MESH)
    batch = M.make_batch(cfg, 8, 16, jax.random.PRNGKey(1))
    pm, _, _ = art.step_fn(params, opt, _mesh_batch(batch), jnp.int32(0))
    p0 = M.init_params(cfg, jax.random.PRNGKey(0))
    g_mean = {k: 0 for k in p0}
    for i in range(4):
        local = jax.tree.map(lambda x: x[i * 2:(i + 1) * 2], batch)
        g = jax.grad(lambda p: M.loss_fn(cfg, p, local)[0])(p0)
        for k in p0:
            g_mean[k] = g_mean[k] + np.asarray(g[k], np.float32) / 4
    for k in p0:
        expect = np.asarray(p0[k], np.float32) - eta * g_mean[k]
        np.testing.assert_allclose(np.asarray(pm[k], np.float32), expect,
                                   atol=5e-4, rtol=1e-3, err_msg=k)
    print("OK dense SGD mesh == psum-mean reference")


def check_stale_votes():
    """Stale-vote substitution runs through the SAME VoteEngine as the
    trainer (fault_tolerance.vote_with_failures)."""
    from repro.distributed.fault_tolerance import vote_with_failures

    engine = VoteEngine(strategy=VoteStrategy.PSUM_INT8, axes=("data",))

    def f(signs, prev):
        out = vote_with_failures(engine, signs[0].astype(jnp.float32),
                                 prev[0].astype(jnp.float32), n_stale=2)
        return out[None]

    sh = compat.shard_map(f, mesh=MESH, in_specs=(P("data"), P("data")),
                          out_specs=P("data"), axis_names={"data"},
                          check_vma=False)
    signs = jnp.asarray(np.sign(RNG.normal(size=(4, 16))).astype(np.int8))
    prev = jnp.asarray(np.sign(RNG.normal(size=(4, 16))).astype(np.int8))
    out = np.asarray(jax.jit(sh)(signs, prev))
    eff = np.concatenate([np.asarray(prev)[:2], np.asarray(signs)[2:]])
    np.testing.assert_array_equal(out[0], np.sign(eff.sum(0)))
    print("OK stale-vote straggler substitution via VoteEngine")


if __name__ == "__main__":
    check_tree_vote()
    check_byzantine_vote()
    check_fused_gather_vote()
    check_mode_a_matches_reference()
    check_vote_plan_mode_a()
    check_mode_b_learns()
    check_dense_baseline_matches_mean()
    check_stale_votes()
    print("ALL DISTRIBUTED CHECKS PASSED")
