"""pixtral-12b — VLM: mistral-nemo decoder backbone; ViT frontend stubbed.

[hf:mistralai/Pixtral-12B-2409; unverified]  40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072.  ``input_specs()`` provides precomputed patch
embeddings for the image prefix (embed_frontend_stub); text tokens embed
normally.
"""
from repro.configs.base import SKIP_LONG, ArchFamily, ModelConfig, register


@register("pixtral-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        family=ArchFamily.VLM,
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=131_072,
        head_dim=128,
        embed_frontend_stub=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        skip_shapes=(SKIP_LONG,),
    )
