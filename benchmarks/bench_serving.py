"""Serving benchmark: continuous vs static batching under fixed load.

The CI face of the serve engine (DESIGN.md §14). One deterministic
Poisson request schedule (splitmix64-keyed, like the Scenario Lab's
draws) is served four ways and the lanes cross-check each other:

* **continuous** — the headline lane: in-flight admission over a
  recycled slot pool. Reports goodput (tokens/tick), TTFT/TPOT and
  p50/p95/p99 latency in *virtual ticks* — schedule-deterministic
  numbers the perf gate compares exactly — plus wall-clock ``*_ms``
  rows under the usual one-sided tolerance.
* **static** — same engine, same compiled step, but admission waits for
  the whole pool to drain (classic static batching). The
  ``goodput_ratio`` row is the paper-style headline: continuous must
  beat static at equal offered load (RuntimeError if not).
* **prefill** — continuous again but admitting via batched prefill at
  bucketed prompt lengths; must be bit-identical in sampled tokens to
  inline admission.
* **hot swap** — a trainer-side CheckpointEmitter publishes new params
  mid-run; the engine swaps them between ticks. Zero dropped in-flight
  requests, and every request admitted *after* the swap must match a
  fresh server started on the new params, token for token.

A final traced replay (TraceRecorder active) must reproduce the
untraced token stream bit for bit, and the obs compile counter must
show EXACTLY ONE decode-step compilation across every lane — the
static-shape claim the whole engine design rests on.

Usage:
    python -m benchmarks.bench_serving --smoke   # CI lane, <10 s
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

_JSON_DEFAULT = "BENCH_serving.json"

#: one schedule for every lane: modest pool, mixed prompt lengths, load
#: high enough that static batching visibly queues (rate in req/tick)
_N_REQUESTS = 14
_RATE = 0.35
_PROMPT_LENS = (4, 8, 12)
_GEN_RANGE = (4, 10)
_SEED = 7


def _gate(ok: bool, msg: str) -> float:
    """Acceptance bar: RuntimeError (not assert — survives ``-O``)."""
    if not ok:
        raise RuntimeError(f"bench_serving: {msg}")
    return 1.0


def smoke_rows():
    import jax

    from repro.configs.base import get_config, reduced_config
    from repro.models import model as M
    from repro.obs import recorder as obs
    from repro.serve import (CheckpointEmitter, CheckpointWatcher,
                             ServeConfig, ServeEngine, like_tree,
                             poisson_requests)

    cfg = reduced_config(get_config("glm4-9b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    sc = ServeConfig(n_slots=4, max_len=48,
                     prompt_pad=max(_PROMPT_LENS), seed=_SEED)
    reqs = poisson_requests(
        n_requests=_N_REQUESTS, rate=_RATE, vocab_size=cfg.vocab_size,
        prompt_lens=_PROMPT_LENS, gen_range=_GEN_RANGE, seed=_SEED)
    compiles0 = obs.COUNTERS.get("serve.decode.compiles")

    # -- lane 1: continuous batching (the headline) --
    rep_c = ServeEngine(cfg, params, sc).run(reqs)
    toks_c = rep_c.tokens_by_request()

    # -- lane 2: static batching baseline (same compiled step) --
    rep_s = ServeEngine(
        cfg, params,
        ServeConfig(n_slots=sc.n_slots, max_len=sc.max_len,
                    prompt_pad=sc.prompt_pad, seed=_SEED,
                    scheduler="static")).run(reqs)
    ratio = (rep_c.goodput_tokens_per_tick
             / max(rep_s.goodput_tokens_per_tick, 1e-12))

    # -- lane 3: prefill admission == inline admission, token for token --
    rep_p = ServeEngine(
        cfg, params,
        ServeConfig(n_slots=sc.n_slots, max_len=sc.max_len,
                    prompt_pad=sc.prompt_pad, seed=_SEED,
                    admit="prefill",
                    prefill_buckets=_PROMPT_LENS)).run(reqs)
    prefill_eq = _gate(rep_p.tokens_by_request() == toks_c,
                       "prefill admission diverged from inline")

    # -- lane 4: hot checkpoint swap mid-run --
    params2 = M.init_params(cfg, jax.random.PRNGKey(1))
    with tempfile.TemporaryDirectory() as d:
        emitter = CheckpointEmitter(d)
        eng = ServeEngine(cfg, params, sc,
                          watcher=CheckpointWatcher(d, like_tree(params)))
        swap_tick = rep_c.ticks // 2

        def on_tick(_e, t):
            if t == swap_tick:
                emitter.emit(100, params2)

        rep_w = eng.run(reqs, on_tick=on_tick)
    _gate(rep_w.swaps == 1, f"expected 1 swap, saw {rep_w.swaps}")
    swap_ok = _gate(rep_w.dropped == 0,
                    f"hot swap dropped {rep_w.dropped} in-flight requests")
    post = {rid for rid, r in rep_w.records.items()
            if r.param_version_admit == eng.param_version}
    _gate(0 < len(post) < _N_REQUESTS,
          f"swap at tick {swap_tick} split nothing ({len(post)} post)")
    oracle = ServeEngine(cfg, params2, sc).run(
        [r.with_arrival(0.0) for r in reqs if r.req_id in post]
    ).tokens_by_request()
    got = {rid: t for rid, t in rep_w.tokens_by_request().items()
           if rid in post}
    swap_oracle = _gate(got == oracle,
                        "post-swap requests diverged from a fresh "
                        "server on the new params")

    # -- lane 5: traced replay must be bit-identical --
    with tempfile.TemporaryDirectory() as d:
        trace_path = os.path.join(d, "serve_trace.jsonl")
        rec = obs.TraceRecorder(trace_path)
        with obs.recording(rec):
            rep_t = ServeEngine(cfg, params, sc).run(reqs)
        rec.close()
        n_steps = sum(1 for r in obs.read_trace(trace_path)
                      if r["kind"] == "step")
    traced_eq = _gate(rep_t.tokens_by_request() == toks_c,
                      "traced serve run diverged from untraced")
    _gate(n_steps == rep_c.ticks,
          f"trace carries {n_steps} step records for {rep_c.ticks} ticks")

    # -- the static-shape claim: one decode compile across ALL lanes --
    compiles = obs.COUNTERS.get("serve.decode.compiles") - compiles0
    _gate(compiles == 1,
          f"{compiles} decode-step compiles across the lanes (want 1)")

    # -- wall-clock lane (compiles warm): per-tick decode dispatch --
    t0 = time.perf_counter()
    rep_hot = ServeEngine(cfg, params, sc).run(reqs)
    wall_ms = (time.perf_counter() - t0) * 1e3

    g = "tokens/tick over the run (virtual ticks; schedule-exact)"
    return [
        ("serving/continuous_goodput_tok_per_tick",
         rep_c.goodput_tokens_per_tick, g),
        ("serving/static_goodput_tok_per_tick",
         rep_s.goodput_tokens_per_tick, g),
        ("serving/goodput_ratio_continuous_over_static",
         _gate(ratio > 1.0,
               f"continuous ({rep_c.goodput_tokens_per_tick:.3f}) did "
               f"not beat static ({rep_s.goodput_tokens_per_tick:.3f}) "
               "at equal offered load") and ratio,
         f"continuous {rep_c.ticks} ticks vs static {rep_s.ticks}"),
        ("serving/continuous_ttft_p50_ticks", rep_c.ttft_p50,
         "arrival -> first token"),
        ("serving/continuous_tpot_mean_ticks", rep_c.tpot_mean,
         "ticks per output token after the first"),
        ("serving/continuous_latency_p50_ticks", rep_c.latency_p50, ""),
        ("serving/continuous_latency_p95_ticks", rep_c.latency_p95, ""),
        ("serving/continuous_latency_p99_ticks", rep_c.latency_p99, ""),
        ("serving/continuous_occupancy", rep_c.occupancy_mean,
         "mean busy-slot fraction"),
        ("serving/completed_requests", float(rep_c.completed),
         f"of {_N_REQUESTS} offered"),
        ("serving/total_tokens", float(rep_c.total_tokens), ""),
        ("serving/decode_step_compiles", float(compiles),
         "across continuous+static+prefill+swap+traced lanes (static "
         "shapes: admissions/retirements never recompile)"),
        ("serving/prefill_eq_inline", prefill_eq,
         "bucketed prefill admission == inline, token for token"),
        ("serving/hot_swap_zero_dropped", swap_ok,
         f"swap at tick {swap_tick}; {rep_w.completed} completed"),
        ("serving/swap_post_match_oracle", swap_oracle,
         f"{len(post)} post-swap requests == fresh server on new params"),
        ("serving/traced_eq_untraced", traced_eq,
         f"{n_steps} step records; identical sampled tokens"),
        ("serving/continuous_run_wall_ms", wall_ms,
         f"{rep_hot.ticks} ticks, warm compiles"),
        ("serving/decode_tick_ms", wall_ms / max(rep_hot.ticks, 1),
         "mean wall-clock per engine tick (host loop + dispatch)"),
    ]


#: the benchmarks.run driver path — the smoke lane IS the serving
#: benchmark (CPU-scale engine; the production mesh runs the same
#: compiled steps via the dry-run shardings)
rows = smoke_rows


def emit_json(rs, path: str) -> None:
    """Machine-readable baseline, same ``{"rows": [...]}`` schema as
    ``benchmarks.run --emit-json`` (gated by scripts/perf_gate.py);
    delegates to :func:`repro.obs.emit_bench_json` (one shared writer)."""
    from repro.obs import emit_bench_json
    emit_bench_json(rs, path)


def main() -> None:
    from repro.obs import recorder as obs
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="continuous/static/prefill/swap/traced lanes "
                         "+ the one-compile gate (CI lane, <10 s)")
    ap.add_argument("--emit-json", dest="json_out", nargs="?",
                    const=_JSON_DEFAULT, default=None,
                    help=f"write rows as JSON (default {_JSON_DEFAULT})")
    obs.add_trace_arg(ap)
    args = ap.parse_args()

    rec = obs.activate_trace(args)
    rs = smoke_rows()
    if args.smoke and args.json_out is None:   # CI smoke seeds the JSON
        args.json_out = _JSON_DEFAULT
    print("name,value,derived")
    for name, value, derived in rs:
        print(f"{name},{value:.6g},{derived}", flush=True)
    if args.json_out:
        emit_json(rs, args.json_out)
        print(f"# wrote {args.json_out}", flush=True)
    obs.finish_trace(rec)


if __name__ == "__main__":
    main()
