"""Version-compat layer over the JAX APIs this repo targets.

The codebase is written against the modern mesh/shard_map surface
(``jax.shard_map`` with ``axis_names=``, ``jax.sharding.get_abstract_mesh``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``,
``jax.set_mesh``). Older installs (e.g. 0.4.x) lack all of these; this
module maps each call onto whatever the installed JAX provides so the rest
of ``src/`` never branches on a version string at a call site.

Every shim is behaviour-preserving where the old API can express the new
semantics, and degrades to a documented no-op where it cannot:

* ``shard_map`` — new kwarg style maps to the legacy positional signature
  (``axis_names`` -> ``auto`` complement, ``check_vma`` -> ``check_rep``).
  On legacy JAX a *nested* shard_map (manual sub-region inside a manual
  region) is executed inline: the nesting exists upstream only to steer the
  partitioner away from fp32 replication (see majority_vote.make_gather_vote);
  the collectives inside are equally valid in the enclosing manual region.
* ``get_abstract_mesh`` — on legacy JAX, resolves from this module's own
  tracing-context stack (maintained by the ``shard_map`` / ``set_mesh``
  shims), so ``distributed.sharding.shard`` can keep asking "what mesh am I
  under, and which axes are Manual here?" uniformly.
* ``make_mesh`` — drops ``axis_types`` where unsupported (legacy meshes are
  implicitly Auto, which is what every caller passes).
"""
from __future__ import annotations

import contextlib
import dataclasses
import enum
import threading
from typing import Any, Dict, Optional, Sequence, Set, Tuple

import jax

__all__ = [
    "AxisType", "all_gather", "axis_size", "cost_analysis_dict",
    "get_abstract_mesh", "make_mesh", "set_mesh", "shard_map",
    "tree_leaves_with_path",
]

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_GET_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")

#: legacy partial-auto shard_map aborts the SPMD partitioner on a lax.scan
#: whose xs derive from manually-sharded operands (the microbatch loop);
#: scans over replicated xs (the depth scan) are fine. Callers unroll the
#: affected loop when this is False.
SCAN_OVER_MANUAL_XS_SAFE = _HAS_NEW_SHARD_MAP

# Modern JAX defaults jax_threefry_partitionable=True; legacy defaults False,
# under which random.normal computed under a dim-0 out_sharding yields
# DIFFERENT values than the same call unsharded (observed on 0.4.37: mesh
# materialize_state vs single-process init diverged on every 'model'-dim-0
# param). Placement-invariant RNG is a correctness requirement for the
# mesh-vs-flat reference checks, so align the legacy default.
try:
    if not jax.config.jax_threefry_partitionable:
        jax.config.update("jax_threefry_partitionable", True)
except AttributeError:
    pass


# ---------------------------------------------------------------------------
# AxisType
# ---------------------------------------------------------------------------

if _HAS_AXIS_TYPE:
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for ``jax.sharding.AxisType`` on legacy JAX (where every
        mesh axis is implicitly Auto and Manual-ness comes from shard_map)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# ---------------------------------------------------------------------------
# mesh-context tracking (legacy path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _MeshView:
    """The subset of the AbstractMesh surface the repo consumes:
    ``empty`` / ``axis_names`` / ``axis_sizes`` / ``axis_types``."""

    axis_names: Tuple[str, ...]
    axis_sizes: Tuple[int, ...]
    axis_types: Tuple[Any, ...]
    concrete: Any = None  # the jax.sharding.Mesh, when known

    @property
    def empty(self) -> bool:
        return not self.axis_names

    @property
    def shape(self) -> Dict[str, int]:
        return dict(zip(self.axis_names, self.axis_sizes))


_EMPTY_VIEW = _MeshView((), (), ())


class _ContextStack(threading.local):
    def __init__(self):
        self.stack = []


_CTX = _ContextStack()


def _view_of(mesh, manual: Set[str]) -> _MeshView:
    names = tuple(mesh.axis_names)
    sizes = tuple(mesh.devices.shape) if hasattr(mesh, "devices") \
        else tuple(mesh.axis_sizes)
    types = tuple(AxisType.Manual if n in manual else AxisType.Auto
                  for n in names)
    return _MeshView(names, sizes, types, concrete=mesh)


@contextlib.contextmanager
def _pushed(view: _MeshView):
    _CTX.stack.append(view)
    try:
        yield
    finally:
        _CTX.stack.pop()


def get_abstract_mesh():
    """The mesh of the current tracing context (or an empty view).

    New JAX: delegates to ``jax.sharding.get_abstract_mesh``. Legacy JAX:
    returns the innermost mesh recorded by this module's ``shard_map`` /
    ``set_mesh`` shims, falling back to the ``with mesh:`` thread-resource
    context. The result always exposes ``empty``, ``axis_names``,
    ``axis_sizes`` and ``axis_types``.
    """
    if _HAS_GET_ABSTRACT_MESH:
        return jax.sharding.get_abstract_mesh()
    if _CTX.stack:
        return _CTX.stack[-1]
    env_mesh = getattr(
        getattr(jax._src.mesh.thread_resources, "env", None),
        "physical_mesh", None)
    if env_mesh is not None and env_mesh.devices.size:
        return _view_of(env_mesh, manual=set())
    return _EMPTY_VIEW


def _current_concrete_mesh():
    m = get_abstract_mesh()
    if isinstance(m, _MeshView):
        return m.concrete
    if m is None or m.empty:
        return None
    return m


def _manual_axes_here() -> Set[str]:
    if _CTX.stack:
        v = _CTX.stack[-1]
        return {n for n, t in zip(v.axis_names, v.axis_types)
                if t == AxisType.Manual}
    return set()


# ---------------------------------------------------------------------------
# mesh construction / activation
# ---------------------------------------------------------------------------


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, axis_types: Optional[Sequence[Any]] = None, **kw):
    """``jax.make_mesh`` that tolerates installs without ``axis_types``
    (legacy meshes are implicitly Auto — the only type callers pass)."""
    try:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=axis_types, **kw)
    except TypeError:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


@contextlib.contextmanager
def set_mesh(mesh):
    """``jax.set_mesh`` fallback: activates `mesh` for sharding resolution.

    On legacy JAX this both enters the ``with mesh:`` resource context (so
    bare-PartitionSpec ``with_sharding_constraint`` resolves) and records the
    mesh for :func:`get_abstract_mesh`.
    """
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
        return
    with mesh, _pushed(_view_of(mesh, manual=set())):
        yield mesh


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------


def shard_map(f, *, mesh=None, in_specs, out_specs,
              axis_names: Optional[Set[str]] = None, check_vma: bool = False):
    """New-style ``jax.shard_map`` (kwargs, partial-manual via `axis_names`)
    on any JAX.

    Legacy mapping: ``axis_names`` becomes the complement ``auto=`` set and
    ``check_vma`` becomes ``check_rep``. When `mesh` is omitted it is taken
    from the active context (set by an enclosing shard_map / set_mesh).
    A nested call inside an already-manual region runs `f` inline on legacy
    JAX — legacy partial-auto nesting aborts the SPMD partitioner, and the
    nesting is a partitioner hint, not a semantic requirement.
    """
    if _HAS_NEW_SHARD_MAP:
        kwargs = dict(in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        if mesh is not None:
            kwargs["mesh"] = mesh
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    already_manual = _manual_axes_here()
    if mesh is None and already_manual:
        # nested manual sub-region: run inline (see docstring)
        return f
    concrete = mesh if mesh is not None else _current_concrete_mesh()
    if concrete is None:
        raise ValueError(
            "compat.shard_map: no mesh given and none active in context")
    all_axes = set(concrete.axis_names)
    manual = set(axis_names) if axis_names is not None else all_axes
    auto = frozenset(all_axes - manual)

    def traced(*args, **kw):
        with _pushed(_view_of(concrete, manual=manual)):
            return f(*args, **kw)

    return _legacy_shard_map(traced, concrete, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma,
                             auto=auto)


# ---------------------------------------------------------------------------
# small API deltas
# ---------------------------------------------------------------------------


def _partial_auto_active() -> bool:
    """True when tracing inside a legacy shard_map that left some mesh axes
    auto (the configuration whose all-gather lowering aborts the legacy
    SPMD partitioner)."""
    if _HAS_NEW_SHARD_MAP or not _CTX.stack:
        return False
    v = _CTX.stack[-1]
    return any(t != AxisType.Manual for t in v.axis_types)


def axis_index(axis_name: str, like=None):
    """``jax.lax.axis_index`` that survives legacy partial-auto shard_map.

    The native op lowers to a PartitionId instruction the legacy SPMD
    partitioner rejects inside partial-auto regions; only psum/psum_scatter
    lower there, so the index is recovered as
    ``psum_scatter(arange(m)) / m`` — replica r receives
    ``sum_replicas(arange(m)[r]) = m * r``. The partitioner also aborts on
    collectives over pure constants (no manual sharding to inherit), so
    `like` — any traced array from the surrounding manual region — anchors
    the operand; it is required on the emulated path.
    """
    import jax.numpy as jnp
    if not _partial_auto_active():
        return jax.lax.axis_index(axis_name)
    if like is None:
        raise ValueError(
            "compat.axis_index inside a legacy partial-auto region needs a "
            "`like=` traced array to anchor the emulation's sharding")
    m = axis_size(axis_name)
    anchor = (jnp.ravel(like)[0] * 0).astype(jnp.int32)
    row = jnp.arange(m, dtype=jnp.int32) + anchor
    scattered = jax.lax.psum_scatter(row, axis_name, scatter_dimension=0,
                                     tiled=True)          # (1,) = m * index
    return (scattered[0] // m).astype(jnp.int32)


def all_gather(x, axis_name: str, *, axis: int = 0, tiled: bool = False):
    """``jax.lax.all_gather`` that survives legacy partial-auto shard_map.

    Inside a legacy partial-auto region the native all-gather lowering hits
    ``Check failed: IsManualSubgroup()`` in the SPMD partitioner (hard
    abort, observed on 0.4.37); there it is emulated as a one-hot
    ``psum`` — each replica contributes its block at its own index and the
    sum reassembles the gather. Everywhere else the native op is used.
    """
    if not _partial_auto_active():
        return jax.lax.all_gather(x, axis_name, axis=axis, tiled=tiled)
    import jax.numpy as jnp
    m = axis_size(axis_name)
    idx = axis_index(axis_name, like=x)
    mask = jax.lax.broadcasted_iota(
        jnp.int32, (m,) + (1,) * x.ndim, 0) == idx
    buf = jnp.where(mask, x[None], jnp.zeros((), x.dtype))
    stacked = jax.lax.psum(buf, axis_name)          # (m,) + x.shape
    stacked = jnp.moveaxis(stacked, 0, axis)
    if not tiled:
        return stacked
    shape = list(x.shape)
    shape[axis] = m * shape[axis]
    return stacked.reshape(shape)


def with_sharding_constraint(x, spec):
    """``jax.lax.with_sharding_constraint`` with a bare PartitionSpec on any
    JAX. Legacy JAX resolves bare specs only under ``with mesh:``; when the
    compat context knows the concrete mesh the spec is bound to a
    NamedSharding, and an unconstrained spec (or no known mesh) is a no-op
    rather than an error."""
    if all(e is None for e in spec):
        return x
    if _HAS_GET_ABSTRACT_MESH:
        return jax.lax.with_sharding_constraint(x, spec)
    concrete = _current_concrete_mesh()
    if concrete is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(concrete, spec))


def zeros_like_traced(x, dtype=None):
    """``jnp.zeros(x.shape, dtype)`` anchored to `x`'s sharding inside
    legacy partial-auto shard_map (a pure-constant zeros tensor feeding the
    scan/collective machinery there trips the same IsManualSubgroup abort
    as constant collectives); a plain constant zeros everywhere else."""
    import jax.numpy as jnp
    dtype = dtype or x.dtype
    if not _partial_auto_active():
        return jnp.zeros(x.shape, dtype)
    return (x * jnp.zeros((), x.dtype)).astype(dtype)


def pad_trailing(x, count: int):
    """Zero-pad the last dim by `count`, safely inside legacy partial-auto
    shard_map (``jnp.pad``'s constant-pad lowering hits the same
    IsManualSubgroup abort as constant collectives; concatenating zeros
    anchored to the operand's sharding does not)."""
    import jax.numpy as jnp
    if count == 0:
        return x
    if not _partial_auto_active():
        return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, count)])
    anchor = (jnp.ravel(x)[0] * 0).astype(x.dtype)
    zeros = jnp.zeros(x.shape[:-1] + (count,), x.dtype) + anchor
    return jnp.concatenate([x, zeros], axis=-1)


def axis_size(name: str) -> int:
    """``jax.lax.axis_size`` fallback: size of a named mapped axis inside
    shard_map (``psum(1)`` constant-folds to the axis size on legacy JAX)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def tree_leaves_with_path(tree):
    """``jax.tree.leaves_with_path`` fallback via ``jax.tree_util``."""
    if hasattr(jax.tree, "leaves_with_path"):
        return jax.tree.leaves_with_path(tree)
    return jax.tree_util.tree_leaves_with_path(tree)


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` as a flat dict on every JAX version
    (legacy returns a one-entry list of per-device dicts)."""
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)
