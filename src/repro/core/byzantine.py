"""Byzantine adversary models (paper §3.4, Fig. 4; DESIGN.md §7).

Transforms are jit-compatible and applied between local sign computation
and the vote, so they compose with every vote strategy — including the
fused vote-in-backward path — and with stale-vote straggler substitution
(``distributed.fault_tolerance``): a straggling adversary perturbs its
*stale* vector, not a fresh one.

Modes
  sign_flip  — send the negation (the paper's strongest non-cooperating
               adversary)
  random     — send random ±1 (corrupted-worker model)
  zero       — abstain every step (crashed/mute worker)
  colluding  — every adversary sends the SAME pseudo-random target
               direction (coordinated attack: a colluding coalition gets
               its full weight behind one direction instead of cancelling
               itself; Mengoli et al. 2025's coordinated model)
  blind      — flip each honest coordinate independently with probability
               ``flip_prob`` per step (Akoun & Meyer 2022's stochastic
               blind adversary; ``flip_prob=1`` degenerates to sign_flip,
               ``flip_prob=0.5`` to random)
  none       — honest

The per-replica transform lives in :func:`evil_signs`, keyed on an
*explicit* replica index — the mesh path (:func:`apply_adversary`) derives
that index from the vote axes via ``compat.axis_index``, while the
Scenario Lab's virtual mesh (:func:`apply_adversary_stacked`) vmaps it
over a stacked voter dimension. Both paths derive PRNG keys through
:func:`adversary_key` (seed + salt, folded with replica index and step),
so a ``random``/``blind``/``colluding`` adversary sends bit-identical
vectors no matter how many hosts or devices replay the scenario.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ByzantineConfig

#: modes where the adversary's vector depends on PRNG draws (and therefore
#: on the seed/salt/step key discipline)
STOCHASTIC_MODES = ("random", "colluding", "blind")
MODES = ("none", "sign_flip", "random", "zero", "colluding", "blind")


def replica_index(axis_names: Sequence[str], like=None) -> jax.Array:
    """Linear index of this replica over the (manual) vote axes.

    `like` anchors the legacy-JAX emulation's sharding (see
    ``compat.axis_index``); pass any traced array from the manual region.
    """
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * compat.axis_size(name) + compat.axis_index(name,
                                                               like=like)
    return idx


def adversary_key(cfg: ByzantineConfig, idx: Optional[jax.Array] = None, *,
                  step: Optional[jax.Array] = None, salt: int = 0
                  ) -> jax.Array:
    """The PRNG key a stochastic adversary draws from.

    ``PRNGKey(seed + salt)`` folded with the replica index (omitted for
    colluding adversaries, whose draw must be shared) and the step. The
    key depends only on *logical* identifiers — replica index within the
    vote, scenario salt, step — never on device placement, which is what
    makes adversarial runs reproducible across host counts (DESIGN.md §7).
    """
    key = jax.random.PRNGKey(cfg.seed + salt)
    if idx is not None:
        key = jax.random.fold_in(key, idx)
    if step is not None:
        key = jax.random.fold_in(key, step)
    return key


def evil_signs(signs: jax.Array, cfg: ByzantineConfig, idx: jax.Array, *,
               step: Optional[jax.Array] = None, salt: int = 0,
               obs=None) -> jax.Array:
    """What replica `idx` would send if it were adversarial.

    `signs` is the replica's honest int8 sign tensor; the result has the
    same shape/dtype. Pure function of (signs, cfg, idx, step, salt) —
    plus, for the adaptive modes dispatched to ``repro.core.attacks``,
    the observation dict ``obs`` (DESIGN.md §15).
    """
    if cfg.mode in ("adaptive_flip", "low_margin", "reputation"):
        # lazy: evil_signs is called at trace time only, and attacks
        # imports this module at top level
        from repro.core.attacks import engine as _attacks
        return _attacks.adaptive_evil_signs(signs, cfg, idx, obs,
                                            step=step, salt=salt)
    if cfg.mode == "sign_flip":
        return -signs
    if cfg.mode == "zero":
        return jnp.zeros_like(signs)
    if cfg.mode == "random":
        rnd = jax.random.bernoulli(
            adversary_key(cfg, idx, step=step, salt=salt), 0.5, signs.shape)
        return jnp.where(rnd, jnp.int8(1), jnp.int8(-1))
    if cfg.mode == "colluding":
        # one shared target direction: the key folds step but NOT idx, so
        # every adversary draws the same vector and the coalition's full
        # weight lands on one direction instead of cancelling itself
        rnd = jax.random.bernoulli(
            adversary_key(cfg, None, step=step, salt=salt), 0.5, signs.shape)
        return jnp.where(rnd, jnp.int8(1), jnp.int8(-1))
    if cfg.mode == "blind":
        # flip each honest coordinate with prob flip_prob; abstentions
        # (sign 0) stay abstentions — a blind adversary corrupts what it
        # sends, it cannot invent votes it does not have
        flip = jax.random.bernoulli(
            adversary_key(cfg, idx, step=step, salt=salt),
            cfg.flip_prob, signs.shape)
        return jnp.where(flip, -signs, signs)
    raise ValueError(f"unknown byzantine mode {cfg.mode!r}")


def apply_adversary(signs: jax.Array, cfg: ByzantineConfig,
                    axis_names: Sequence[str], *,
                    step: jax.Array | None = None,
                    salt: int = 0, obs=None) -> jax.Array:
    """Transform this replica's int8 sign tensor per the adversary model
    (mesh path: the replica index comes from the vote axes).

    Replicas with linear index < cfg.num_adversaries act adversarially
    (which replicas are adversarial is immaterial to the vote — only the
    count matters, Theorem 2).
    """
    if cfg.mode == "none" or cfg.num_adversaries == 0:
        return signs
    idx = replica_index(axis_names, like=signs)
    evil = evil_signs(signs, cfg, idx, step=step, salt=salt, obs=obs)
    return jnp.where(idx < cfg.num_adversaries, evil, signs)


def apply_adversary_stacked(stacked: jax.Array, cfg: ByzantineConfig, *,
                            step: Optional[jax.Array] = None,
                            salt: int = 0,
                            ids: Optional[jax.Array] = None,
                            obs=None) -> jax.Array:
    """The same transform over a stacked (M, ...) voter tensor (virtual
    mesh path: replica index = position along the leading dim).
    Bit-identical to `apply_adversary` run on M mesh replicas (asserted
    by tests/tier2/scenario_harness.py).

    ``ids`` overrides the per-row replica index with *logical* voter
    identities (int32, shape (M,)): a client-sampled or chunk-streamed
    round materializes only some rows of the population, but each row's
    adversary predicate (`id < num_adversaries`) and PRNG stream
    (:func:`adversary_key` folds the id) must depend on who the voter
    IS, not where its row landed — the same client draws the same evil
    vector regardless of sampling or chunking. Default (`None`) keeps
    the historical row-position indexing.
    """
    if cfg.mode == "none" or cfg.num_adversaries == 0:
        return stacked
    m = stacked.shape[0]
    idx = (jnp.arange(m, dtype=jnp.int32) if ids is None
           else jnp.asarray(ids).astype(jnp.int32))
    evil = jax.vmap(
        lambda s, i: evil_signs(s, cfg, i, step=step, salt=salt,
                                obs=obs))(stacked, idx)
    is_adv = (idx < cfg.num_adversaries).reshape(
        (m,) + (1,) * (stacked.ndim - 1))
    return jnp.where(is_adv, evil, stacked)
