#!/usr/bin/env python
"""Render an obs JSONL trace as the standard report (DESIGN.md §13).

Thin CLI over :mod:`repro.obs.report`. Sections: trace meta, per-phase
time, overlap pipeline utilization, measured-vs-predicted exchange per
bucket (the alpha-beta comm model's prediction rides on every
``plan.issue`` span), steps / wire (bytes per step vs the paper's 1/32
ideal), final counters.

Usage:
    PYTHONPATH=src python scripts/trace_report.py TRACE.jsonl [--json]

(The PYTHONPATH is optional — the script falls back to the repo's
``src/`` next to it.)
"""
from __future__ import annotations

import os
import sys

try:
    from repro.obs import report
except ImportError:                       # bare invocation, no PYTHONPATH
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    from repro.obs import report

if __name__ == "__main__":
    sys.exit(report.main(sys.argv[1:]))
