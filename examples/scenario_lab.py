"""Scenario Lab demo: replay the boundary-regime failure drills (adversary
x straggler x elastic) through the production VoteEngine wire path and
watch Theorem 2 hold — and rightly fail past 50%.

Runs the host-count-independent virtual mesh, so it works on any machine;
the same specs replay bit-identically on a real device mesh (see
DESIGN.md §7 and tests/tier2/).

    PYTHONPATH=src python examples/scenario_lab.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sim import ScenarioRunner, preset_scenarios


def main():
    print(f"{'scenario':<28s} {'strategy':<15s} {'adv':>14s} "
          f"{'stale':>5s} {'loss_0':>7s} {'loss_T':>7s} {'margin':>7s} "
          f"{'flip':>6s}")
    for spec in preset_scenarios():
        t = ScenarioRunner(spec).run()
        s = t.summary()
        adv = spec.adversary
        note = ""
        if adv.fraction > 0.5:
            note = "  <- >50% adversarial: vote rightly fails"
        elif adv.schedule:
            note = "  <- time-varying coalition (AttackPhase schedule)"
        elif adv.adaptive:
            note = f"  <- adaptive: observes the {adv.observe!r} channel"
        elif spec.elastic:
            note = "  <- voter set rescaled mid-run"
        print(f"{spec.name:<28s} {spec.strategy.value:<15s} "
              f"{adv.mode:>9s}@{adv.fraction:4.2f} "
              f"{spec.straggler_fraction:5.2f} "
              f"{s['first_loss']:7.3f} {s['final_loss']:7.3f} "
              f"{s['mean_margin']:7.3f} {s['mean_flip_fraction']:6.3f}"
              f"{note}")
    print("\ntraces are structured records; e.g. one step of the last run:")
    print("  ", t.steps[-1])


if __name__ == "__main__":
    main()
