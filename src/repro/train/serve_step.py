"""Serving steps (prefill / decode) and their shardings.

Serving needs no vote, so steps are plain ``jax.jit`` under auto SPMD:
weights keep their (possibly 2D data x model) training layout; the KV /
SSM caches get family-aware specs:

* attention caches (L,B,S,K,hd): batch over ('pod','data') when divisible;
  heads over 'model' when divisible, else sequence over 'model'
  (flash-decode-style partial softmax handled by the chunked decode path /
  XLA reductions);
* batch=1 long-context: sequence over ('data','model') jointly;
* SSM state (L,B,H,P,N): heads over 'model';
* int8 caches carry (L,B,S,K) scale leaves sharded to match.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed import sharding as shd
from repro.models import model as M


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def batch_entry(b: int, sizes: Dict[str, int]):
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    if _div(b, dp) and dp > 1:
        return ("pod", "data") if "pod" in sizes else "data"
    if _div(b, sizes.get("data", 1)) and sizes.get("data", 1) > 1:
        return "data"
    return None


def cache_leaf_spec(name: str, shape: Tuple[int, ...],
                    sizes: Dict[str, int]) -> P:
    model = sizes.get("model", 1)
    if name in ("ssm",):  # (L,B,H,P,N)
        h = shape[2]
        return P(None, batch_entry(shape[1], sizes),
                 "model" if _div(h, model) else None, None, None)
    if name in ("conv",):  # (L,B,W-1,CD)
        return P(None, batch_entry(shape[1], sizes), None,
                 "model" if _div(shape[3], model) else None)
    if name in ("k_scale", "v_scale"):  # (L,B,S,K)
        b, s, k = shape[1], shape[2], shape[3]
        be = batch_entry(b, sizes)
        if _div(k, model):
            return P(None, be, None, "model")
        if be is None and _div(s, model * sizes.get("data", 1)):
            return P(None, None, ("data", "model"), None)
        return P(None, be, "model" if _div(s, model) else None, None)
    if name in ("k", "v", "attn_k", "attn_v", "xk", "xv"):  # (L,B,S,K,hd)
        b, s, k = shape[1], shape[2], shape[3]
        be = batch_entry(b, sizes)
        if _div(k, model):
            return P(None, be, None, "model", None)
        if be is None and _div(s, model * sizes.get("data", 1)):
            return P(None, None, ("data", "model"), None, None)
        return P(None, be, "model" if _div(s, model) else None, None, None)
    return P()


def cache_shardings(cfg: ModelConfig, cache_abs, mesh):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return {
        k: NamedSharding(mesh, cache_leaf_spec(k, v.shape, sizes))
        for k, v in cache_abs.items()
    }


def serve_param_shardings(cfg: ModelConfig, mesh, *, fsdp: bool):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    specs = shd.param_specs(cfg.param_shapes(), fsdp=fsdp, mesh_shape=sizes)
    return {k: NamedSharding(mesh, s) for k, s in specs.items()}


def make_decode_step(cfg: ModelConfig):
    # cache is donated: the updated cache aliases the input buffers, so the
    # decode step never holds two copies of a multi-GB KV cache.
    @functools.partial(jax.jit, donate_argnums=(2,))
    def step(params, tokens, cache, pos):
        return M.decode_step(cfg, params, tokens, cache, pos)

    return step


def make_cache_rehome(cfg: ModelConfig, batch: int, max_len: int):
    """One jitted re-home of a prefill cache into a fresh ``max_len``
    cache, keyed on leaf kind by *shape*, not by name:

    * leaves already at the target shape (recurrent ``ssm``/``conv``
      state, audio cross K/V) pass through untouched — a prompt-length
      SSM state IS the decode state;
    * seq-carrying leaves (attention K/V and their int8 scales) are
      copied into the zero-initialised full-length buffer at the
      origin, in one compiled program for the whole tree.

    Replaces the old host loop in ``launch/serve.py`` that assumed the
    attention layout for every leaf (and skipped recurrent caches
    entirely behind a ``"k" in cache`` gate). A leaf that EXCEEDS the
    target shape on any dim is a caller error and raises at trace time.
    """
    full_abs = jax.eval_shape(lambda: M.init_cache(cfg, batch, max_len))

    @jax.jit
    def rehome(cache):
        if set(cache) != set(full_abs):
            raise ValueError(
                f"cache structure mismatch: got {sorted(cache)}, "
                f"serving cache has {sorted(full_abs)}")
        full = M.init_cache(cfg, batch, max_len)
        out = {}
        for k, dst in full.items():
            src = cache[k].astype(dst.dtype)
            if src.shape == dst.shape:
                out[k] = src
                continue
            if src.ndim != dst.ndim or any(
                    s > d for s, d in zip(src.shape, dst.shape)):
                raise ValueError(
                    f"cache leaf {k!r} {src.shape} does not fit the "
                    f"max_len={max_len} serving cache {dst.shape}")
            out[k] = jax.lax.dynamic_update_slice(
                dst, src, (0,) * dst.ndim)
        return out

    return rehome


def make_prefill(cfg: ModelConfig, cache_shardings_=None):
    # out_shardings pin the produced cache to its serving layout (batch
    # over data, heads-or-seq over model) — otherwise XLA leaves the scan
    # output batch-sharded only and a 32k cache lands 16x too large.
    kw = {}
    if cache_shardings_ is not None:
        kw["out_shardings"] = (None, cache_shardings_)

    @functools.partial(jax.jit, **kw)
    def step(params, batch):
        return M.prefill(cfg, params, batch)

    return step


def make_prefill_sharded(cfg: ModelConfig, mesh, *, fsdp: bool,
                         global_batch: int):
    """Prefill as shard_map manual over the batch axes, auto over 'model'
    — the same layout as training. Keeps MoE token dispatch replica-LOCAL:
    under pure auto-SPMD the capacity gather/scatter goes global and the
    partitioner materialises (E, 16*C, d) fp32 dispatch buffers (measured
    43 GiB on qwen2-moe prefill_32k). FSDP-sharded params are gathered by
    the standard hooks (vote=False: no backward runs in serving).
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.majority_vote import make_fsdp_hooks
    from repro.distributed import sharding as shd

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    dp = 1
    for a in batch_axes:
        dp *= sizes[a]
    if dp <= 1 or global_batch % dp != 0:
        return make_prefill(cfg)

    specs = shd.param_specs(cfg.param_shapes(), fsdp=fsdp, mesh_shape=sizes)
    hook = (make_fsdp_hooks(specs, tuple(mesh.axis_names), vote=False)
            if fsdp else None)
    p_manual = {k: _strip_to_manual(s, batch_axes) for k, s in specs.items()}

    def local_fn(params, batch):
        return M.prefill(cfg, params, batch, hook=hook)

    # batch sharded over the batch axes; logits/cache carry the batch dim
    bspec = P(batch_axes)
    out_specs = (bspec, _cache_out_specs(cfg, batch_axes))
    fn = compat.shard_map(local_fn, mesh=mesh,
                          in_specs=(p_manual, bspec), out_specs=out_specs,
                          axis_names=set(batch_axes), check_vma=False)
    return jax.jit(fn)


def _strip_to_manual(spec, manual):
    def fix(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(x for x in e if x in manual)
            return kept if kept else None
        return e if e in manual else None

    from jax.sharding import PartitionSpec as P
    return P(*(fix(e) for e in spec))


def _cache_out_specs(cfg: ModelConfig, batch_axes):
    """Manual (batch-axes-only) out_specs for the prefill cache: every
    cache leaf carries batch at dim 1 (L, B, ...)."""
    from jax.sharding import PartitionSpec as P

    cache_abs = jax.eval_shape(lambda: M.init_cache(cfg, 8, 128))
    return {k: P(None, batch_axes) for k in cache_abs}


def abstract_serve_inputs(cfg: ModelConfig, cell: ShapeCell, mesh,
                          *, fsdp: bool):
    """ShapeDtypeStructs with shardings for a serve-shape dry-run."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    p_sh = serve_param_shardings(cfg, mesh, fsdp=fsdp)
    shapes = cfg.param_shapes()
    dt = jnp.dtype(cfg.dtype)
    params = {k: jax.ShapeDtypeStruct(v, dt, sharding=p_sh[k])
              for k, v in shapes.items()}
    specs = M.input_specs(cfg, cell)
    if cell.kind == "prefill":
        batch = specs["batch"]
        bspec = {k: NamedSharding(
            mesh, P(batch_entry(v.shape[0], sizes)))
            for k, v in batch.items()}
        batch = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=bspec[k])
                 for k, v in batch.items()}
        return {"params": params, "batch": batch}
    cache = specs["cache"]
    c_sh = cache_shardings(cfg, cache, mesh)
    cache = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=c_sh[k])
             for k, v in cache.items()}
    tok = specs["tokens"]
    tok = jax.ShapeDtypeStruct(
        tok.shape, tok.dtype,
        sharding=NamedSharding(mesh, P(batch_entry(tok.shape[0], sizes))))
    pos = jax.ShapeDtypeStruct(
        (), jnp.int32, sharding=NamedSharding(mesh, P()))
    return {"params": params, "tokens": tok, "cache": cache, "pos": pos}
