"""Deterministic serve traffic: Poisson arrivals keyed by splitmix64.

The generator is the serving twin of the Scenario Lab's host-side draw
discipline (DESIGN.md §12): every request-level quantity — inter-arrival
gap, prompt length, prompt tokens, generation budget — is a pure
function of ``(seed, tag, request id)`` through the splitmix64
finalizer, never of call order, host count, or library version. Two
calls with the same seed produce bit-identical schedules, so the bench
rows built from a schedule (goodput, latency percentiles) are exact,
gate-able numbers, and a traced run replays an untraced one exactly.

Arrival times are in *ticks* — the engine's virtual clock, one tick per
scheduler round (admissions + one decode step). Measuring load in ticks
keeps the offered-load comparison (continuous vs static batching)
deterministic; wall-clock rows are reported separately as ``*_ms``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

import numpy as np

_SM64 = (np.uint64(0x9E3779B97F4A7C15), np.uint64(0xBF58476D1CE4E5B9),
         np.uint64(0x94D049BB133111EB))

#: one draw stream per request-level quantity
_TAG_GAP, _TAG_PLEN, _TAG_TOKENS, _TAG_GEN = 1, 2, 3, 4


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """The splitmix64 finalizer over a uint64 array (elementwise,
    vectorized, wrap-around arithmetic — same constants as sim.runner)."""
    with np.errstate(over="ignore"):   # wrap-around is the algorithm
        x = (np.asarray(x, np.uint64) + _SM64[0]).astype(np.uint64)
        x = ((x ^ (x >> np.uint64(30))) * _SM64[1]).astype(np.uint64)
        x = ((x ^ (x >> np.uint64(27))) * _SM64[2]).astype(np.uint64)
        return x ^ (x >> np.uint64(31))


def _stream(seed: int, tag: int, rid: int) -> np.uint64:
    """A uint64 stream constant chaining (seed, tag, request id)."""
    h = np.zeros((), np.uint64)
    for v in (seed, tag, rid):
        h = _splitmix64(h ^ np.uint64(v))
    return h


def _uniform01(h: np.ndarray) -> np.ndarray:
    """uint64 hash -> float64 uniform in [0, 1) (53-bit mantissa)."""
    return (np.asarray(h, np.uint64) >> np.uint64(11)).astype(np.float64) \
        * (2.0 ** -53)


@dataclasses.dataclass(frozen=True)
class Request:
    """One serve request: a prompt and a generation budget."""

    req_id: int
    arrival: float                 # tick the request enters the queue
    prompt: Tuple[int, ...]        # prompt token ids (length >= 1)
    max_gen: int                   # generation budget (sampled tokens)

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    def with_arrival(self, arrival: float) -> "Request":
        """The same request rebased to a new arrival tick (oracle replays
        admit post-swap requests against a fresh server at tick 0)."""
        return dataclasses.replace(self, arrival=arrival)


def poisson_requests(*, n_requests: int, rate: float, vocab_size: int,
                     prompt_lens: Sequence[int] = (8, 16, 32),
                     gen_range: Tuple[int, int] = (4, 16),
                     seed: int = 0, start_id: int = 0,
                     start_tick: float = 0.0) -> Tuple[Request, ...]:
    """A deterministic Poisson request schedule.

    ``rate`` is the offered load in requests per tick; inter-arrival
    gaps are Exp(rate) draws from the per-request splitmix64 stream, so
    request ``start_id + i`` always arrives at the same tick whatever
    the process (or recorder) state. Prompt lengths are drawn from the
    ``prompt_lens`` bucket ladder — the engine's batched-prefill compile
    ladder — and generation budgets uniformly from ``gen_range``
    (inclusive).
    """
    if n_requests < 0:
        raise ValueError(f"n_requests must be >= 0, got {n_requests}")
    if rate <= 0.0:
        raise ValueError(f"rate must be > 0 requests/tick, got {rate}")
    if not prompt_lens or any(p < 1 for p in prompt_lens):
        raise ValueError(f"prompt_lens must be >= 1, got {prompt_lens}")
    lo, hi = gen_range
    if not (1 <= lo <= hi):
        raise ValueError(f"gen_range must satisfy 1 <= lo <= hi, "
                         f"got {gen_range}")
    lens = tuple(int(p) for p in prompt_lens)
    reqs = []
    t = float(start_tick)
    for i in range(n_requests):
        rid = start_id + i
        u = float(_uniform01(_stream(seed, _TAG_GAP, rid)))
        t += -math.log(1.0 - u) / rate
        plen = lens[int(_stream(seed, _TAG_PLEN, rid) % np.uint64(len(lens)))]
        toks = _splitmix64(np.arange(plen, dtype=np.uint64)
                           ^ _stream(seed, _TAG_TOKENS, rid)) \
            % np.uint64(vocab_size)
        max_gen = lo + int(_stream(seed, _TAG_GEN, rid)
                           % np.uint64(hi - lo + 1))
        reqs.append(Request(req_id=rid, arrival=t,
                            prompt=tuple(int(x) for x in toks),
                            max_gen=max_gen))
    return tuple(reqs)
