"""The streamed population engine + the federated scenario axis
(DESIGN.md §12):

* ``streamed_vote`` is bit-identical to the dense stacked path — votes
  AND server state — across codec x strategy, the M ladder up to the
  1024 acceptance bar, ragged chunk boundaries, sampled voter ids,
  dataset weights, stale substitution and every adversary mode (the
  exactness-by-integers argument of core/population.py, asserted);
* ``count_for_fraction`` is exact rational arithmetic (the federated-
  scale boundary case the old float product got one replica wrong);
* the ``VirtualVoteEngine`` shim no longer zeroes a requested
  ``n_stale`` silently, and its ``vote_with_failures`` surfaces the
  wire signs through ``VoteOutcome.wire_signs`` instead of recomputing
  the failure composition;
* ``PopulationSpec``/``ChurnEvent`` validation, JSON roundtrip, and the
  ScenarioRunner population drills: chunk-size digest invariance,
  churn-driven state refits, and the actionable rejection of every
  incompatible knob.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import codecs as codecs_mod
from repro.core import population
from repro.core import vote_api as va
from repro.distributed.fault_tolerance import count_for_fraction
from repro.sim import (AdversarySpec, ChurnEvent, ElasticEvent, PlanSpec,
                       PopulationSpec, ScenarioRunner, ScenarioSpec,
                       VirtualVoteEngine)


# ---------------------------------------------------------------------------
# count_for_fraction: exact integers at federated scale
# ---------------------------------------------------------------------------


def test_count_for_fraction_half_up_boundary():
    # the §7 tie regime: 0.5 of 16 is EXACTLY 8 adversaries
    assert count_for_fraction(0.5, 16) == 8
    assert count_for_fraction(0.5, 15) == 8          # 7.5 rounds half-up
    assert count_for_fraction(0.0, 10 ** 6) == 0
    assert count_for_fraction(1.0, 10 ** 6) == 10 ** 6


def test_count_for_fraction_is_exact_at_scale():
    # float 0.1 is slightly ABOVE 1/10; at n=10^17 the true product is
    # 10^16 + 0.55..., so the half-up count is 10^16 + 1. A float
    # product (int(f * n + 0.5)) loses that — the rational path keeps it
    assert count_for_fraction(0.1, 10 ** 17) == 10 ** 16 + 1
    # representable fractions stay exact however large n grows
    for k in (3, 6, 9, 12):
        assert count_for_fraction(0.25, 4 * 10 ** k) == 10 ** k
    assert count_for_fraction(0.3, 10) == 3


def test_count_for_fraction_rejects_out_of_range():
    with pytest.raises(ValueError):
        count_for_fraction(-0.1, 8)
    with pytest.raises(ValueError):
        count_for_fraction(1.5, 8)


# ---------------------------------------------------------------------------
# the VirtualVoteEngine shim: no silent n_stale drop; wire_signs surfaced
# ---------------------------------------------------------------------------


def test_engine_rejects_stale_request_without_prev():
    # the shim used to zero n_stale when prev was None, silently
    # dropping a requested failure; now the build-time validation raises
    eng = VirtualVoteEngine(strategy=VoteStrategy.PSUM_INT8)
    vals = jnp.asarray(np.random.default_rng(0).normal(
        size=(4, 16)).astype(np.float32))
    with pytest.raises(ValueError, match="prev"):
        eng.vote_with_failures(vals, None, n_stale=2, step=jnp.int32(0))


def test_vote_with_failures_returns_the_wire_signs():
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.normal(size=(6, 24)).astype(np.float32))
    prev = jnp.asarray(rng.integers(-1, 2, size=(6, 24)).astype(np.int8))
    eng = VirtualVoteEngine(
        strategy=VoteStrategy.ALLGATHER_1BIT,
        byz=ByzantineConfig(mode="sign_flip", num_adversaries=2, seed=3),
        salt=7)
    vote, signs = eng.vote_with_failures(vals, prev, n_stale=1,
                                         step=jnp.int32(4))
    # the outcome's signs ARE the effective composition (stale
    # substitution -> adversary) — not a re-derivation with fresh PRNG
    np.testing.assert_array_equal(
        np.asarray(signs),
        np.asarray(eng.effective_signs(vals, prev, 1, jnp.int32(4))))
    assert np.asarray(vote).shape == (24,)


# ---------------------------------------------------------------------------
# streamed == dense (the §12 bit-identity bar)
# ---------------------------------------------------------------------------

_CELLS = [
    (VoteStrategy.PSUM_INT8, "sign1bit"),
    (VoteStrategy.PSUM_INT8, "ternary2bit"),
    (VoteStrategy.ALLGATHER_1BIT, "sign1bit"),
    (VoteStrategy.ALLGATHER_1BIT, "ternary2bit"),
    (VoteStrategy.ALLGATHER_1BIT, "ef_sign"),
    (VoteStrategy.ALLGATHER_1BIT, "weighted_vote"),
]


def _dense_vs_streamed(m, n, strategy, codec, *, chunk, ids=None,
                       weights=None, n_stale=0, byz=None, seed=0):
    """Execute the same voters through the dense stacked path and the
    streamed engine; assert votes and server state bit-identical."""
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    prev_arr = (jnp.asarray(rng.integers(-1, 2, size=(m, n))
                            .astype(np.int8)) if n_stale else None)
    pop = int(ids[-1]) + 1 if ids is not None else m
    state = (codecs_mod.get_codec(codec).init_server_state(pop)
             if codecs_mod.get_codec(codec).server_state else None)
    dense = va.VirtualBackend().execute(va.VoteRequest(
        payload=vals, form="stacked", strategy=strategy, codec=codec,
        voter_ids=ids, weights=weights,
        failures=va.FailureSpec(n_stale=n_stale, byz=byz), prev=prev_arr,
        step=jnp.int32(2), salt=5, server_state=state))
    stream = va.PopulationStream(
        n_voters=m, n_coords=n, ids=ids, weights=weights,
        values=lambda want, _v=vals, _i=jnp.asarray(
            ids if ids is not None else np.arange(m)):
            _v[jnp.searchsorted(_i, want)],
        prev=(None if prev_arr is None else
              lambda want, _p=prev_arr, _i=jnp.asarray(
                  ids if ids is not None else np.arange(m)):
              _p[jnp.searchsorted(_i, want)]))
    streamed = va.VirtualBackend(chunk_size=chunk).execute(va.VoteRequest(
        payload=stream, form="streamed", strategy=strategy, codec=codec,
        failures=va.FailureSpec(n_stale=n_stale, byz=byz),
        step=jnp.int32(2), salt=5, server_state=state))
    np.testing.assert_array_equal(np.asarray(dense.votes),
                                  np.asarray(streamed.votes))
    assert set(dense.server_state) == set(streamed.server_state)
    for k in dense.server_state:
        np.testing.assert_array_equal(
            np.asarray(dense.server_state[k]),
            np.asarray(streamed.server_state[k]))
    return streamed


@pytest.mark.parametrize("strategy,codec", _CELLS)
def test_streamed_matches_dense_across_codecs(strategy, codec):
    # full participation, a ragged chunk (33 = 4x7 + 5), sign-flippers.
    # weighted_vote pins ids=arange: its dense twin is the ANNOTATED
    # stacked path (one-chunk population engine) — the legacy stacked
    # decode runs the EMA update inside jit, where XLA may fuse the
    # float expression 1 ulp away from the eager evaluation; votes are
    # exact either way, so the un-annotated form is asserted votes-only
    # below
    ids = np.arange(33) if codec == "weighted_vote" else None
    _dense_vs_streamed(
        33, 40, strategy, codec, chunk=7, ids=ids,
        byz=ByzantineConfig(mode="sign_flip", num_adversaries=5, seed=2))


def test_streamed_matches_legacy_weighted_votes_exactly():
    # the un-annotated legacy stacked decode: votes must still be
    # bit-identical (the integer wire tally); only the float EMA state
    # is allowed its known jit-vs-eager ulp (see above)
    m, n = 33, 40
    rng = np.random.default_rng(2)
    vals = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    state = codecs_mod.get_codec("weighted_vote").init_server_state(m)
    dense = va.VirtualBackend().execute(va.VoteRequest(
        payload=vals, form="stacked",
        strategy=VoteStrategy.ALLGATHER_1BIT, codec="weighted_vote",
        server_state=state))
    stream = va.PopulationStream(
        n_voters=m, n_coords=n, values=lambda ids, _v=vals: _v[ids])
    streamed = va.VirtualBackend(chunk_size=7).execute(va.VoteRequest(
        payload=stream, form="streamed",
        strategy=VoteStrategy.ALLGATHER_1BIT, codec="weighted_vote",
        server_state=state))
    np.testing.assert_array_equal(np.asarray(dense.votes),
                                  np.asarray(streamed.votes))
    np.testing.assert_allclose(
        np.asarray(dense.server_state["flip_ema"]),
        np.asarray(streamed.server_state["flip_ema"]), rtol=1e-6)


@pytest.mark.parametrize("m", [1, 2, 7, 33, 128, 1024])
def test_streamed_matches_dense_up_the_m_ladder(m):
    # the acceptance bar: bit-identical at every M <= 1024 (fixed
    # adversary count so the jitted chunk stage compiles per shape only)
    byz = (ByzantineConfig(mode="colluding", num_adversaries=1, seed=4)
           if m > 1 else None)
    _dense_vs_streamed(m, 24, VoteStrategy.ALLGATHER_1BIT, "sign1bit",
                       chunk=13, byz=byz, seed=m)


def test_streamed_matches_dense_with_sampled_ids_and_weights():
    # a client-sampled round with dataset weights: logical ids drive the
    # adversary PRNG, weights multiply the votes — dense annotated twin
    m, n = 29, 31
    rng = np.random.default_rng(9)
    ids = np.sort(rng.choice(200, size=m, replace=False)).astype(np.int32)
    w = rng.integers(1, 50, size=m).astype(np.int32)
    for strategy, codec in [(VoteStrategy.PSUM_INT8, "sign1bit"),
                            (VoteStrategy.ALLGATHER_1BIT, "sign1bit"),
                            (VoteStrategy.ALLGATHER_1BIT,
                             "weighted_vote")]:
        _dense_vs_streamed(
            m, n, strategy, codec, chunk=6, ids=ids, weights=w,
            byz=ByzantineConfig(mode="blind", num_adversaries=40, seed=8,
                                flip_prob=0.7))


def test_streamed_matches_dense_under_stale_substitution():
    _dense_vs_streamed(
        17, 20, VoteStrategy.PSUM_INT8, "sign1bit", chunk=4, n_stale=3,
        byz=ByzantineConfig(mode="zero", num_adversaries=2, seed=1))


def test_streamed_is_chunk_size_invariant():
    # integer partial sums commute and associate exactly: every chunking
    # of the same stream lands on the same bits
    m, n = 65, 48
    rng = np.random.default_rng(3)
    vals = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    stream = va.PopulationStream(
        n_voters=m, n_coords=n, values=lambda ids, _v=vals: _v[ids])
    outs = []
    for chunk in (1, 9, 64, 65, 1000):
        v, _, margin, _ = population.streamed_vote(
            stream, strategy=VoteStrategy.ALLGATHER_1BIT,
            codec="sign1bit", chunk_size=chunk)
        outs.append((np.asarray(v), margin))
    for v, margin in outs[1:]:
        np.testing.assert_array_equal(outs[0][0], v)
        assert margin == outs[0][1]


def test_streamed_stats_accounting():
    m, chunk = 50, 8
    vals = jnp.asarray(np.random.default_rng(0).normal(
        size=(m, 16)).astype(np.float32))
    stream = va.PopulationStream(
        n_voters=m, n_coords=16, values=lambda ids, _v=vals: _v[ids])
    population.streamed_vote(stream, strategy=VoteStrategy.PSUM_INT8,
                             codec="sign1bit", chunk_size=chunk)
    stats = dict(population.LAST_STATS)
    assert stats["n_voters"] == m
    assert stats["peak_rows"] <= chunk
    assert stats["n_chunks"] == -(-m // chunk)
    assert stats["n_passes"] == 1
    # the weighted_vote codec walks the stream twice (vote, then the
    # flip-rate observation against the final vote)
    state = codecs_mod.get_codec("weighted_vote").init_server_state(m)
    population.streamed_vote(stream,
                             strategy=VoteStrategy.ALLGATHER_1BIT,
                             codec="weighted_vote", chunk_size=chunk,
                             server_state=state)
    assert population.LAST_STATS["n_passes"] == 2
    assert population.LAST_STATS["peak_rows"] <= chunk


# ---------------------------------------------------------------------------
# engine + stream + request validation
# ---------------------------------------------------------------------------


def _tiny_stream(m=4, n=8, **kw):
    vals = jnp.ones((m, n), jnp.float32)
    return va.PopulationStream(n_voters=m, n_coords=n,
                               values=lambda ids, _v=vals: _v[ids], **kw)


def test_streamed_engine_rejects_hierarchical_and_bad_chunk():
    with pytest.raises(ValueError, match="[Hh]ierarchical"):
        population.streamed_vote(_tiny_stream(),
                                 strategy=VoteStrategy.HIERARCHICAL,
                                 codec="sign1bit")
    with pytest.raises(ValueError, match="chunk_size"):
        population.streamed_vote(_tiny_stream(),
                                 strategy=VoteStrategy.PSUM_INT8,
                                 codec="sign1bit", chunk_size=0)


def test_streamed_engine_guards_int32_partial_overflow():
    big_w = np.full(4, 2 ** 20, dtype=np.int64)
    with pytest.raises(ValueError, match="int32"):
        population.streamed_vote(
            _tiny_stream(weights=big_w),
            strategy=VoteStrategy.PSUM_INT8, codec="sign1bit",
            chunk_size=2 ** 12)


def test_streamed_engine_demands_population_sized_weighted_state():
    ids = np.asarray([0, 5, 9, 11], dtype=np.int32)
    state = codecs_mod.get_codec("weighted_vote").init_server_state(10)
    with pytest.raises(ValueError, match="flip_ema"):
        population.streamed_vote(
            _tiny_stream(ids=ids),
            strategy=VoteStrategy.ALLGATHER_1BIT, codec="weighted_vote",
            chunk_size=2, server_state=state)   # id 11 >= pop 10


def test_population_stream_validation():
    with pytest.raises(ValueError, match="callable"):
        va.PopulationStream(n_voters=4, n_coords=8,
                            values=np.zeros((4, 8)))
    with pytest.raises(ValueError, match="strictly increasing"):
        _tiny_stream(ids=np.asarray([3, 1, 2, 0], dtype=np.int32))
    with pytest.raises(ValueError, match="shape"):
        _tiny_stream(ids=np.arange(5, dtype=np.int32))
    with pytest.raises(ValueError, match=">= 1"):
        _tiny_stream(weights=np.asarray([1, 0, 2, 3], dtype=np.int32))


def test_streamed_request_validation():
    stream = _tiny_stream()
    with pytest.raises(ValueError, match="PopulationStream"):
        va.VoteRequest(payload=jnp.ones((4, 8)), form="streamed")
    with pytest.raises(ValueError, match="prev"):
        # stale substitution needs a prev chunk producer ON the stream
        va.VoteRequest(payload=stream, form="streamed",
                       strategy=VoteStrategy.PSUM_INT8,
                       failures=va.FailureSpec(n_stale=1))
    with pytest.raises(ValueError, match="PopulationStream"):
        va.VoteRequest(payload=stream, form="streamed",
                       voter_ids=np.arange(4))
    with pytest.raises(ValueError, match="MeshBackend|mesh"):
        va.MeshBackend().execute(va.VoteRequest(
            payload=stream, form="streamed",
            strategy=VoteStrategy.PSUM_INT8))


# ---------------------------------------------------------------------------
# PopulationSpec / ChurnEvent (spec layer)
# ---------------------------------------------------------------------------


def test_churn_event_validation():
    with pytest.raises(ValueError, match="step >= 1"):
        ChurnEvent(0, join=4)
    with pytest.raises(ValueError, match="neither"):
        ChurnEvent(3)
    ev = ChurnEvent(3, join=2, leave=1, note="ok")
    assert (ev.join, ev.leave) == (2, 1)


def test_population_spec_validation_and_clients_at():
    with pytest.raises(ValueError, match="n_clients > 0"):
        PopulationSpec(sample_fraction=0.5)      # axes without a pop
    with pytest.raises(ValueError, match="sample_fraction"):
        PopulationSpec(n_clients=10, sample_fraction=0.0)
    with pytest.raises(ValueError, match="weighting"):
        PopulationSpec(n_clients=10, weighting="loss")
    with pytest.raises(ValueError, match="min_data"):
        PopulationSpec(n_clients=10, min_data=5, max_data=2)
    with pytest.raises(ValueError, match="step-sorted"):
        PopulationSpec(n_clients=10,
                       churn=(ChurnEvent(4, join=1), ChurnEvent(2, join=1)))
    with pytest.raises(ValueError, match="empties"):
        PopulationSpec(n_clients=10, churn=(ChurnEvent(2, leave=10),))
    p = PopulationSpec(n_clients=10, churn=(ChurnEvent(2, leave=4),
                                            ChurnEvent(5, join=7)))
    assert [p.clients_at(s) for s in (0, 1, 2, 4, 5, 99)] == \
        [10, 10, 6, 6, 13, 13]


def test_population_spec_json_roundtrip():
    spec = ScenarioSpec(
        "pop/roundtrip", n_steps=2, dim=16, momentum=0.0,
        strategy=VoteStrategy.PSUM_INT8,
        adversary=AdversarySpec("sign_flip", 0.1),
        population=PopulationSpec(
            n_clients=40, sample_fraction=0.5, weighting="dataset",
            max_data=9, churn=(ChurnEvent(1, join=5, note="j"),),
            chunk_size=8))
    back = ScenarioSpec.from_dict(spec.to_dict())
    assert back == spec
    assert isinstance(back.population.churn[0], ChurnEvent)


@pytest.mark.parametrize("kw,msg", [
    (dict(strategy=VoteStrategy.HIERARCHICAL), "hierarchical"),
    (dict(plan=PlanSpec(bucket_bytes=8)), "plan"),
    (dict(elastic=(ElasticEvent(1, 4),)), "ChurnEvent"),
    (dict(momentum=0.9), "momentum"),
    (dict(straggler_fraction=0.25), "straggler|participation"),
    (dict(codec="ef_sign"), "worker-stateless"),
])
def test_population_spec_rejects_incompatible_knobs(kw, msg):
    base = dict(n_steps=2, dim=16, momentum=0.0,
                strategy=VoteStrategy.ALLGATHER_1BIT,
                population=PopulationSpec(n_clients=20))
    base.update(kw)
    with pytest.raises(ValueError, match=msg):
        ScenarioSpec("pop/bad", **base)


def test_population_mode_is_virtual_backend_only():
    spec = ScenarioSpec("pop/mesh", n_steps=1, dim=8, momentum=0.0,
                        strategy=VoteStrategy.PSUM_INT8,
                        population=PopulationSpec(n_clients=12))
    with pytest.raises(ValueError, match="virtual"):
        ScenarioRunner(spec, backend="mesh")


# ---------------------------------------------------------------------------
# ScenarioRunner population drills
# ---------------------------------------------------------------------------


def _pop_spec(**kw):
    pop_kw = dict(n_clients=30, sample_fraction=0.4, chunk_size=5)
    pop_kw.update(kw.pop("population", {}))
    base = dict(n_steps=3, dim=24, momentum=0.0,
                strategy=VoteStrategy.ALLGATHER_1BIT,
                adversary=AdversarySpec("sign_flip", 0.2),
                population=PopulationSpec(**pop_kw))
    base.update(kw)
    return ScenarioSpec(kw.get("name", "pop/drill"), **{
        k: v for k, v in base.items() if k != "name"})


def test_population_drill_runs_and_traces():
    tr = ScenarioRunner(_pop_spec()).run()
    assert len(tr.steps) == 3
    for s in tr.steps:
        assert s.n_population == 30
        assert s.n_workers == count_for_fraction(0.4, 30)
        # adversaries counted over the LOGICAL population
        assert s.n_adversaries == count_for_fraction(0.2, 30)
        assert 0.0 <= s.flip_fraction <= 1.0
    assert population.LAST_STATS["peak_rows"] <= 5


def test_population_drill_is_chunk_size_invariant():
    spec = _pop_spec(population=dict(weighting="dataset", max_data=20))
    d1 = ScenarioRunner(spec).run().digest
    respec = dataclasses.replace(
        spec, population=dataclasses.replace(spec.population,
                                             chunk_size=30))
    assert ScenarioRunner(respec).run().digest == d1


def test_population_drill_churn_refits_state():
    # weighted_vote keeps a (pop,) flip-rate EMA; churn must refit it by
    # the §6 leading-axis rule (truncate leavers, pad joiners) mid-run
    spec = _pop_spec(
        codec="weighted_vote",
        population=dict(n_clients=24, sample_fraction=0.5,
                        churn=(ChurnEvent(1, leave=8, note="drop"),
                               ChurnEvent(2, join=10, note="rejoin")),
                        chunk_size=4))
    tr = ScenarioRunner(spec).run()
    assert [s.n_population for s in tr.steps] == [24, 16, 26]
    # sampled voter count follows the current population
    assert [s.n_workers for s in tr.steps] == \
        [count_for_fraction(0.5, p) for p in (24, 16, 26)]
    # and the run stays chunk-size invariant THROUGH the churn refits
    respec = dataclasses.replace(
        spec, population=dataclasses.replace(spec.population,
                                             chunk_size=26))
    assert ScenarioRunner(respec).run().digest == tr.digest


def test_population_sampling_is_step_keyed_and_stable():
    from repro.sim.runner import _sample_ids
    spec = _pop_spec()
    a = _sample_ids(spec, 3, 30, 10)
    b = _sample_ids(spec, 3, 30, 10)
    c = _sample_ids(spec, 4, 30, 10)
    np.testing.assert_array_equal(a, b)          # deterministic replay
    assert not np.array_equal(a, c)              # fresh draw per step
    assert a.size == 10 and np.all(np.diff(a) > 0)
    np.testing.assert_array_equal(_sample_ids(spec, 0, 6, 9),
                                  np.arange(6))  # k >= pop: everyone


def test_client_sizes_follow_the_logical_id():
    from repro.sim.runner import _client_sizes
    spec = _pop_spec(population=dict(weighting="dataset", min_data=2,
                                     max_data=11))
    ids = np.asarray([1, 4, 17, 29], dtype=np.int32)
    sizes = _client_sizes(spec, ids)
    assert sizes.min() >= 2 and sizes.max() <= 11
    # a client keeps its size whatever batch it is queried in
    np.testing.assert_array_equal(
        sizes[2:], _client_sizes(spec, ids[2:]))
