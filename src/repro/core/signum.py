"""SIGNUM / signSGD with majority vote — the paper's Algorithm 1 — plus the
dense baselines it is benchmarked against (distributed SGD/SGDM/Adam).

Optimizers are (init, update) pairs operating on *replica-local* trees;
they are called inside the manual-axes shard_map built by
``train/train_step.py``. Cross-replica aggregation is explicit:

* Mode A (``signum_vote``, paper-faithful): each replica keeps its own
  momentum ``v_m = beta*v_m + (1-beta)*g_m``; the vote aggregates
  ``sign(v_m)`` (Algorithm 1 line-for-line). The trainer stores the
  momentum with a leading vote-axis so every replica owns a distinct
  buffer.
* Mode B (``signsgd_vote``, DESIGN.md §3): replicas vote on ``sign(g_m)``
  (= Algorithm 1 with beta=0); momentum applies to the *voted* sign and is
  shardable like the params. When the fused ZeRO path is active the FSDP
  leaves arrive **already voted** by the backward reduce-scatter
  (``voted_leaves``), so only the small replicated leaves vote here.

Update rule (both modes): ``x <- x - eta * (vote + weight_decay * x)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ByzantineConfig, MomentumMode, OptimizerConfig
from repro.core import codecs as codecs_mod
from repro.core import sign_compress as sc
from repro.core import vote_api as va
from repro.core import vote_plan as vp
from repro.core.majority_vote import tree_mean
from repro.obs import recorder as obs


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step) -> (params, state, diag)


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    lr = jnp.float32(cfg.learning_rate)
    if cfg.warmup_steps:
        warm = jnp.minimum(step / cfg.warmup_steps, 1.0)
        lr = lr * warm
    if cfg.total_steps:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        lr = lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return lr


def _split(tree: Dict, names: Sequence[str]) -> Tuple[Dict, Dict]:
    a = {k: v for k, v in tree.items() if k in names}
    b = {k: v for k, v in tree.items() if k not in names}
    return a, b


# (The vote_margin / vote_agreement diagnostics moved into the vote API:
# they arrive on the VoteOutcome's WireReport, computed once per vote —
# DESIGN.md §10.)


# ---------------------------------------------------------------------------
# the paper's optimizer family
# ---------------------------------------------------------------------------


def make_sign_optimizer(cfg: OptimizerConfig, axes: Sequence[str],
                        byz: Optional[ByzantineConfig] = None,
                        voted_leaves: Sequence[str] = (),
                        diagnostics: bool = False,
                        n_vote_replicas: int = 1,
                        plan: Optional[vp.VotePlan] = None) -> Optimizer:
    """SIGNUM/signSGD with majority vote.

    `axes`: manual mesh axes the vote runs over.
    `voted_leaves`: param names whose gradients arrive pre-voted via the
    fused ZeRO backward (Mode B only).
    `n_vote_replicas`: static voter count (sizes the server-stateful
    codecs' decode memory; 1 in the single-process degenerate case).
    `plan`: optional :class:`~repro.core.vote_plan.VotePlan` (§9) — the
    explicitly-voted leaves go to the wire as one flat bucketed buffer
    instead of leaf by leaf; per-leaf codecs come from the plan's map.

    The wire is codec-parametric (DESIGN.md §8): `cfg.resolved_codec`
    selects what goes on it. Worker-side codec memory (the EF residual)
    lives under ``state["error"]`` — per-worker under Mode A, so it
    refits across elastic rescale like the momentum (§6); server-side
    decode memory (the weighted vote's reliability estimates) lives under
    ``state["codec"]``, replicated. Under a plan with a codec map the
    residual tree holds ONLY the leaves mapped to a worker-state codec.
    """
    beta = cfg.momentum
    mode = cfg.momentum_mode
    mom_dtype = jnp.dtype(cfg.momentum_dtype)
    codec = codecs_mod.get_codec(cfg.resolved_codec)
    ef_leaves = (plan.worker_state_leaves if plan is not None
                 else None)   # None = legacy single-codec rule
    ef = (bool(ef_leaves) if plan is not None else codec.worker_state)
    server_state = (plan.has_server_state if plan is not None
                    else codec.server_state)
    if ef and mode != MomentumMode.PER_WORKER:
        # Mode B votes on raw gradient signs and keeps momentum on the
        # vote — there is no per-worker encode input for a residual to
        # fold into. Rejecting the combination beats silently training
        # as sign1bit with a dead momentum-sized error tree.
        raise ValueError(
            f"codec {codec.name if plan is None else ef_leaves!r} carries "
            "a per-worker EF residual and requires "
            "momentum_mode=per_worker (Mode A); Mode B has no "
            "worker-side encode input (DESIGN.md §3/§8)")

    leaf_codec_names = plan.leaf_codecs() if plan is not None else None

    def _leaf_codec(name: str):
        if leaf_codec_names is None:
            return codec
        return codecs_mod.get_codec(leaf_codec_names[name])

    def init(params):
        state = {"count": jnp.zeros((), jnp.int32)}
        if beta > 0 or mode == MomentumMode.GLOBAL:
            state["momentum"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, mom_dtype), params)
        if cfg.delayed_vote:
            # one-round vote buffer (DESIGN.md §11): step t applies the
            # majority voted at t-1. int8 ternary signs, replicated
            # (every replica applies the same previous decision); zeros
            # at step 0, so the first update is weight decay only.
            state["delayed"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.int8), params)
        if ef:
            state["error"] = {
                k: jnp.zeros(p.shape, mom_dtype) for k, p in params.items()
                if ef_leaves is None or k in ef_leaves}
        if server_state:
            state["codec"] = (plan.init_server_state(n_vote_replicas)
                              if plan is not None
                              else codec.init_server_state(n_vote_replicas))
        return state

    def encode(tree, err):
        # codec encode: fold each EF leaf's residual into the vote input
        # (identity for residual-free leaves/codecs)
        with obs.get_recorder().span("codec.encode", codec=codec.name,
                                     n_leaves=len(tree)):
            return {k: _leaf_codec(k).encode_leaf(v, err.get(k))
                    for k, v in tree.items()}

    def feedback(encoded, votes, err):
        # codec feedback: residual vs the APPLIED vote, EF leaves only
        with obs.get_recorder().span("codec.feedback", codec=codec.name,
                                     n_leaves=len(err)):
            return {k: _leaf_codec(k).feedback_leaf(encoded[k], votes[k], e)
                    for k, e in err.items()}

    backend = va.MeshBackend(axes=tuple(axes))

    def _vote(tree, step, cstate):
        """Dispatch the explicit vote through the declarative API: one
        VoteRequest whether the wire is the bucketed plan schedule or
        leaf-wise — margin/agreement come back on the WireReport,
        computed once (DESIGN.md §10)."""
        out = backend.execute(va.VoteRequest(
            payload=tree, form="tree", strategy=cfg.vote_strategy,
            codec=codec.name, plan=plan, failures=va.FailureSpec(byz=byz),
            step=step, server_state=cstate, diagnostics=diagnostics,
            overlap=cfg.overlap))
        diag = {}
        if diagnostics:
            diag["vote_agreement"] = out.wire.agreement
            diag["vote_margin"] = out.wire.margin
        return out.votes, out.server_state, diag

    def update(grads, state, params, step):
        eta = lr_at(cfg, step)
        diag = {}
        cstate = state.get("codec")
        if mode == MomentumMode.PER_WORKER:
            # --- Algorithm 1 verbatim ---
            if beta > 0:
                v = jax.tree.map(
                    lambda m, g: beta * m + (1 - beta) * g.astype(mom_dtype),
                    state["momentum"], grads)
                state = {**state, "momentum": v}
            else:
                v = grads
            if ef:
                v = encode(v, state["error"])
            votes, new_cstate, diag = _vote(v, step, cstate)
            if ef:
                state = {**state, "error": feedback(v, votes,
                                                    state["error"])}
            if server_state:
                state = {**state, "codec": new_cstate}
        else:
            # --- Mode B: vote on sign(g), momentum on the vote ---
            pre, raw = _split(grads, voted_leaves)
            if raw:
                raw_votes, new_cstate, diag = _vote(raw, step, cstate)
                if server_state:
                    state = {**state, "codec": new_cstate}
            else:
                raw_votes = {}
            votes = {**pre, **raw_votes}
            if diagnostics and not raw:
                # every leaf took the fused vote-in-backward path: the
                # wire is not observable here, but the metric keys are
                # a contract when diagnostics=True
                diag["vote_agreement"] = jnp.float32(jnp.nan)
                diag["vote_margin"] = jnp.float32(jnp.nan)
            if beta > 0:
                u = jax.tree.map(
                    lambda m, vt: beta * m + (1 - beta) * vt.astype(mom_dtype),
                    state["momentum"], votes)
                state = {**state, "momentum": u}
                votes = jax.tree.map(lambda x: jnp.sign(x), u)
        if cfg.delayed_vote:
            # apply the PREVIOUS step's majority; bank this step's fresh
            # decision for t+1. EF feedback and the diagnostics above
            # observed the FRESH vote — only the parameter update lags.
            applied = state["delayed"]
            state = {**state, "delayed": jax.tree.map(sc.sign_ternary,
                                                      votes)}
        else:
            applied = votes

        def apply(p, vt):
            # barrier: without it XLA CSEs this f32 cast with the ZeRO
            # hook's gather operand and all-gathers params in fp32
            # (measured 2x wire + expert replication on qwen3-moe)
            p32 = jax.lax.optimization_barrier(p).astype(jnp.float32)
            upd = vt.astype(jnp.float32) + cfg.weight_decay * p32
            return (p32 - eta * upd).astype(p.dtype)

        new_params = jax.tree.map(apply, params, applied)
        state = {**state, "count": state["count"] + 1}
        return new_params, state, diag

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# dense baselines (the paper's comparison arm)
# ---------------------------------------------------------------------------


def make_dense_optimizer(cfg: OptimizerConfig, axes: Sequence[str],
                         mean_leaves: Sequence[str] = ()) -> Optimizer:
    """Distributed SGD / SGDM / Adam with psum-mean gradient aggregation.

    `mean_leaves`: names already mean-reduced by the fused ZeRO backward.
    """
    kind = cfg.kind

    def init(params):
        state = {"count": jnp.zeros((), jnp.int32)}
        if kind in ("sgdm", "adam"):
            state["m"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if kind == "adam":
            state["v"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return state

    def update(grads, state, params, step):
        eta = lr_at(cfg, step)
        pre, raw = _split(grads, mean_leaves)
        raw = tree_mean(raw, axes) if raw else {}
        g = {**pre, **raw}
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        cnt = state["count"] + 1
        if kind == "sgd":
            upd = g
        elif kind == "sgdm":
            m = jax.tree.map(lambda m_, g_: cfg.momentum * m_ + g_,
                             state["m"], g)
            state = {**state, "m": m}
            upd = m
        elif kind == "adam":
            b1, b2 = cfg.momentum, cfg.beta2
            m = jax.tree.map(lambda m_, g_: b1 * m_ + (1 - b1) * g_,
                             state["m"], g)
            v = jax.tree.map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ * g_,
                             state["v"], g)
            state = {**state, "m": m, "v": v}
            t = cnt.astype(jnp.float32)
            upd = jax.tree.map(
                lambda m_, v_: (m_ / (1 - b1 ** t))
                / (jnp.sqrt(v_ / (1 - b2 ** t)) + cfg.eps), m, v)
        else:
            raise ValueError(kind)
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32)
                          - eta * (u + cfg.weight_decay
                                   * p.astype(jnp.float32))).astype(p.dtype),
            params, upd)
        return new_params, {**state, "count": cnt}, {}

    return Optimizer(init, update)


def build_optimizer(cfg: OptimizerConfig, axes: Sequence[str],
                    byz: Optional[ByzantineConfig] = None,
                    fused_leaves: Sequence[str] = (),
                    diagnostics: bool = False,
                    n_vote_replicas: int = 1,
                    plan: Optional[vp.VotePlan] = None) -> Optimizer:
    if cfg.kind in ("signum_vote", "signsgd_vote"):
        return make_sign_optimizer(cfg, axes, byz, voted_leaves=fused_leaves,
                                   diagnostics=diagnostics,
                                   n_vote_replicas=n_vote_replicas,
                                   plan=plan)
    return make_dense_optimizer(cfg, axes, mean_leaves=fused_leaves)
