"""Virtual mesh: the production vote pipeline over a stacked voter dim.

The Scenario Lab must replay an M-voter drill on however many devices the
host happens to have (1 laptop CPU or an 8-device harness) and produce
bit-identical results either way. Since the vote API redesign (DESIGN.md
§10) the host-side execution itself lives in
:class:`repro.core.vote_api.VirtualBackend` — the *production*
``VoteStrategyImpl.pack`` / ``tally`` / ``unpack`` stage methods with
only the **exchange** stage's mesh collectives replaced by their
mathematically-exact host-side equivalents over a stacked leading voter
dim:

    psum            ->  sum over the voter dim (cast back to wire dtype)
    all_gather      ->  the stacked wire IS the gathered tensor
    psum_scatter    ->  sum over voters, split last dim into M shards
    tiled re-gather ->  concatenate the per-shard decisions

No aggregation logic is re-implemented: ties, abstentions, padding bits
and wire dtypes all come from the same code the trainer compiles. The
tier-2 harness (``tests/tier2/scenario_harness.py``) asserts the virtual
backend is bit-identical to the real ``shard_map`` + collectives path on
an 8-device mesh, for every strategy and failure composition.

This module keeps the legacy ``virtual_*`` entry points as deprecation
shims plus :class:`VirtualVoteEngine`, the stacked-engine convenience
wrapper the failure-composition tests drive.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax

from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import vote_api as va


def virtual_vote(signs: jax.Array, strategy: VoteStrategy) -> jax.Array:
    """DEPRECATED shim: (M, n) stacked int8 signs -> (n,) int8 majority
    through the strategy's own pack/tally/unpack stages (exchange
    virtualised)."""
    va.warn_legacy("virtual_mesh.virtual_vote")
    return va.VirtualBackend().execute(va.VoteRequest(
        payload=signs, form="stacked", strategy=strategy)).votes


def virtual_vote_codec(signs: jax.Array, strategy: VoteStrategy,
                       codec: str = "sign1bit", server_state=None):
    """DEPRECATED shim: (M, n) stacked int8 signs -> ((n,) int8
    majority, new server state) through the codec's wire stages."""
    va.warn_legacy("virtual_mesh.virtual_vote_codec")
    out = va.VirtualBackend().execute(va.VoteRequest(
        payload=signs, form="stacked", strategy=strategy, codec=codec,
        server_state=server_state))
    return out.votes, out.server_state


def virtual_plan_vote(signs: jax.Array, plan, server_state=None):
    """DEPRECATED shim: (M, n_params) stacked int8 signs ->
    ((n_params,) int8 votes, new server state) through a
    :class:`~repro.core.vote_plan.VotePlan` bucket schedule."""
    va.warn_legacy("virtual_mesh.virtual_plan_vote")
    out = va.VirtualBackend().execute(va.VoteRequest(
        payload=signs, form="stacked", plan=plan,
        server_state=server_state))
    return out.votes, out.server_state


@dataclasses.dataclass(frozen=True)
class VirtualVoteEngine:
    """Stacked-voter-dim engine semantics, now a thin wrapper over
    :class:`~repro.core.vote_api.VirtualBackend`.

    Mirrors the mesh engine stage for stage: ternary sign extraction,
    then stale-vote substitution, then the compiled Byzantine model
    (same ``core.byzantine`` transforms, same PRNG keys — replica index
    = row index), then the strategy wire path, in the pinned
    ``FailureSpec`` order.
    """

    strategy: VoteStrategy
    byz: Optional[ByzantineConfig] = None
    salt: int = 0
    codec: str = "sign1bit"

    def effective_signs(self, values: jax.Array,
                        prev_signs: Optional[jax.Array] = None,
                        n_stale: int = 0,
                        step: Optional[jax.Array] = None) -> jax.Array:
        """The (M, n) int8 sign tensor that actually reaches the wire:
        sign extraction -> stale substitution -> adversary perturbation."""
        return va.effective_stacked_signs(values, prev_signs, n_stale,
                                          self.byz, step, self.salt)

    def _request(self, values, prev=None, n_stale: int = 0, step=None):
        # n_stale passes through unchanged: requesting stale substitution
        # without prev signs is a caller error, and VoteRequest's
        # build-time validation raises the actionable message (the shim
        # used to zero n_stale silently, dropping a requested failure)
        return va.VoteRequest(
            payload=values, form="stacked", strategy=self.strategy,
            codec=self.codec,
            failures=va.FailureSpec(n_stale=n_stale, byz=self.byz),
            prev=prev, step=step, salt=self.salt)

    def vote(self, values: jax.Array,
             step: Optional[jax.Array] = None) -> jax.Array:
        """(M, n) stacked replica-local values -> (n,) int8 majority."""
        return va.VirtualBackend().execute(
            self._request(values, step=step)).votes

    def vote_with_failures(self, values: jax.Array,
                           prev_signs: Optional[jax.Array] = None,
                           n_stale: int = 0,
                           step: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, jax.Array]:
        """One aggregation under failures; returns (vote, effective
        signs). The signs come back through ``VoteOutcome.wire_signs`` —
        the tensor ``execute()`` itself put on the wire — so trace
        capture observes exactly what was voted instead of recomputing
        the failure composition (and re-drawing the adversary PRNG) a
        second time outside the backend."""
        out = va.VirtualBackend().execute(
            self._request(values, prev_signs, n_stale, step))
        return out.votes, out.wire_signs
