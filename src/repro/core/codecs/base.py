"""The GradientCodec interface (DESIGN.md §8).

The paper's wire is one point on a compression/robustness frontier: raw
1-bit signs with unweighted majority decoding. A :class:`GradientCodec`
factors that choice out of the vote pipeline so the frontier becomes
pluggable — what each worker *encodes* onto the wire (signs, error-fed
signs, abstain-capable ternary symbols) and how the server *decodes* the
arrivals (unweighted majority, reliability-weighted vote) vary per codec,
while the VoteEngine's pack → exchange → tally → unpack transport and the
Byzantine/straggler machinery in front of it stay shared.

A codec owns up to three pieces of state and behaviour:

* **worker state** (``init_state`` / ``encode_leaf`` / ``feedback_leaf``)
  — per-replica memory carried in the optimizer state beside the momentum
  (e.g. the EF residual). Shaped like the values it encodes; under Mode A
  it gets the leading vote-axis dim and survives elastic rescale through
  ``checkpoint.refit_leading_axis`` exactly like the momentum (§6).
* **server state** (``init_server_state`` / ``decode_stacked``) — per-
  voter-set memory the decode rule updates (e.g. reliability estimates).
  Replicated across the mesh: every chip plays the server, so every chip
  holds — and identically updates — the same copy.
* **the wire** (``supported_strategies`` / ``wire_bits``) — which §2
  strategies can transport this codec's symbols and at what width, which
  is what the AUTO selector prices.

Implementations are stateless singletons (state lives in the caller's
trees), safe to close over in jit.
"""
from __future__ import annotations

import abc
from typing import Dict, Optional, Tuple

import jax

from repro.configs.base import VoteStrategy


class GradientCodec(abc.ABC):
    """One point on the compression/robustness frontier."""

    #: registry key (also the ScenarioSpec / OptimizerConfig spelling)
    name: str
    #: wire bits per parameter on the codec's native packed exchange
    bits_per_param: float
    #: strategies whose exchange can transport this codec's symbols
    supported_strategies: Tuple[VoteStrategy, ...]
    #: True if encode carries per-worker memory (EF residual)
    worker_state: bool = False
    #: True if decode carries server-side memory (reliability weights)
    server_state: bool = False

    # ---- worker side -----------------------------------------------------

    def init_state(self, values: jax.Array) -> Optional[jax.Array]:
        """Per-worker encode memory for one leaf (None if stateless)."""
        return None

    def encode_leaf(self, values: jax.Array,
                    state: Optional[jax.Array]) -> jax.Array:
        """values -> the tensor whose SIGNS go to the wire (the 'encode
        input'); stateful codecs fold their memory in here."""
        return values

    def feedback_leaf(self, encoded: jax.Array, vote: jax.Array,
                      state: Optional[jax.Array]) -> Optional[jax.Array]:
        """Post-vote worker-state update (e.g. the EF residual); `encoded`
        is what encode_leaf returned, `vote` the decoded ±1/0 tensor."""
        return state

    # ---- server side -----------------------------------------------------

    def init_server_state(self, n_workers: int) -> Dict[str, jax.Array]:
        """Server-side decode memory for an M-voter set ({} if none).

        All-zero is the uninformed prior for every codec (matches the
        trainer's zeros-materialised opt state and the §6 elastic rule:
        refit_leading_axis zero-pads joiners)."""
        return {}

    def ties(self, strategy: VoteStrategy) -> str:
        """Decoded tie convention under `strategy` ("zero"/"plus_one")."""
        from repro.core.vote_engine import STRATEGIES
        return STRATEGIES[strategy].ties

    def wire_bits(self, strategy: VoteStrategy) -> float:
        """Wire bits per param this codec puts on `strategy`'s exchange."""
        from repro.core.vote_engine import STRATEGIES
        if strategy == VoteStrategy.ALLGATHER_1BIT:
            return self.bits_per_param
        return STRATEGIES[strategy].wire_bits_per_param

    def validate_strategy(self, strategy: VoteStrategy) -> None:
        if strategy not in self.supported_strategies:
            raise ValueError(
                f"codec {self.name!r} cannot ride strategy "
                f"{strategy.value!r}; supported: "
                f"{tuple(s.value for s in self.supported_strategies)}")


# The tree-level encode/feedback folds live with their only caller,
# `core.signum.make_sign_optimizer` — since the VotePlan codec map (§9)
# they are per-leaf-codec-aware dict folds, not whole-tree maps.
