"""Fig. 4 demo: train the same model with 0%..44% of vote replicas acting
adversarially (sign inversion) and show the vote shrugging it off.

Runs the REAL distributed train step over 8 fake devices (data=8), so the
adversaries are actual mesh replicas keyed by axis_index, exactly as they
would be on a pod.

    python examples/byzantine_demo.py        # sets its own XLA_FLAGS
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import (ByzantineConfig, OptimizerConfig,
                                TrainConfig, get_config, reduced_config)
from repro.models import model as M
from repro.train import train_step as TS


def main():
    mesh = compat.make_mesh((8, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    print(f"{'adversaries':>12s} {'alpha':>6s} {'lr':>7s} "
          f"{'loss_0':>8s} {'loss_40':>8s}")
    # high-adversarial cases use a re-tuned (lower) learning rate, exactly
    # as the paper does for its 43% case (Fig. 4 right)
    for n_adv, lr in [(0, 3e-3), (1, 3e-3), (2, 3e-3), (3, 3e-3),
                      (3, 1e-3), (5, 1e-3)]:
        cfg = reduced_config(get_config("glm4-9b"), num_layers=2)
        tcfg = TrainConfig(
            global_batch=8, seq_len=32,
            optimizer=OptimizerConfig(kind="signum_vote",
                                      learning_rate=lr),
            byzantine=ByzantineConfig(mode="sign_flip",
                                      num_adversaries=n_adv))
        art = TS.make_train_step(cfg, tcfg, mesh=mesh)
        params, opt = TS.materialize_state(cfg, tcfg, art,
                                           jax.random.PRNGKey(0), mesh)
        batch = M.make_batch(cfg, 8, 32, jax.random.PRNGKey(1))
        batch = jax.tree.map(
            lambda a: jax.device_put(np.asarray(a),
                                     NamedSharding(mesh, P("data"))), batch)
        first = last = None
        for i in range(40):
            params, opt, met = art.step_fn(params, opt, batch, jnp.int32(i))
            if first is None:
                first = float(met["loss"])
            last = float(met["loss"])
        note = "  <- 5/8 adversarial: vote rightly fails" if n_adv > 4 else ""
        print(f"{n_adv:>12d} {n_adv / 8:6.2f} {lr:7.0e} "
              f"{first:8.3f} {last:8.3f}{note}")


if __name__ == "__main__":
    main()
