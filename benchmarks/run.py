"""Benchmark driver — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows:
  fig1  toy-quadratic convergence incl. adversaries   (bench_convergence)
  fig2  gradient-noise unimodality/symmetry on an LM  (bench_noise)
  fig3  SNR vs the critical line                      (bench_noise)
  fig4  Byzantine training robustness sweep           (bench_robustness)
  attacks  adaptive-attack breaking points vs the
           Theorem 2 bound, defense-aware degradation (bench_attacks)
  fig5  communication volume/time vs dense all-reduce (bench_comm)
  fig6  end-to-end step-time speedup model            (bench_speedup)
  codecs  codec frontier: convergence vs bits/param   (bench_codecs)
  federated  streamed population engine: sampling,
             churn, weighted votes, 100k-client bound  (bench_federated)
  serving  continuous vs static batching, hot swap,
           one-compile + bit-identity gates            (bench_serving)
  roofline  per-cell terms from the dry-run artifacts (roofline)

``--emit-json FILE`` additionally writes every produced row as JSON —
the machine-readable bench baseline (e.g. ``--only codecs --emit-json
BENCH_codecs.json`` seeds the codec trajectory; the CI codec-smoke stage
writes the same file via ``bench_codecs --smoke``).

Run:  PYTHONPATH=src python -m benchmarks.run [--only fig1,fig5]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from repro.obs import recorder as obs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys "
                         "(fig1..fig6,attacks,codecs,vote_plan,federated,"
                         "serving,roofline)")
    ap.add_argument("--list", action="store_true",
                    help="enumerate the registered suites (key, module, "
                         "one-line description) and exit")
    ap.add_argument("--emit-json", dest="json_out", default=None,
                    help="also write the produced rows to this JSON file")
    obs.add_trace_arg(ap)
    args = ap.parse_args()
    rec = obs.activate_trace(args)

    from benchmarks import (bench_attacks, bench_codecs, bench_comm,
                            bench_convergence, bench_federated, bench_noise,
                            bench_robustness, bench_serving, bench_speedup,
                            bench_vote_plan, roofline)
    suites = {
        "fig1": bench_convergence, "fig2": bench_noise, "fig3": bench_noise,
        "fig4": bench_robustness, "fig5": bench_comm, "fig6": bench_speedup,
        "attacks": bench_attacks, "codecs": bench_codecs,
        "vote_plan": bench_vote_plan,
        "federated": bench_federated, "serving": bench_serving,
        "roofline": roofline,
    }
    if args.list:
        for key, mod in suites.items():
            desc = (mod.__doc__ or "").strip().splitlines()[0]
            print(f"{key:<10s} {mod.__name__:<28s} {desc}")
        return
    only = set(args.only.split(",")) if args.only else None
    seen_mods = set()
    print("name,value,derived")
    failures = 0
    collected = []
    for key, mod in suites.items():
        if only and key not in only:
            continue
        if id(mod) in seen_mods:
            continue
        seen_mods.add(id(mod))
        try:
            with obs.get_recorder().span("bench.suite", key=key):
                suite_rows = mod.rows()
            for name, value, derived in suite_rows:
                print(f"{name},{value:.6g},{derived}", flush=True)
                collected.append({"name": name, "value": value,
                                  "derived": derived})
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{key}/ERROR,-1,see stderr", flush=True)
            # the JSON must carry the failure too — a partially failed
            # sweep must not emit a healthy-looking baseline
            collected.append({"name": f"{key}/ERROR", "value": -1.0,
                              "derived": "see stderr"})
    if args.json_out:
        # the ONE shared bench-JSON writer (same schema every bench
        # emits; gated by scripts/perf_gate.py)
        obs.emit_bench_json(collected, args.json_out)
        print(f"# wrote {args.json_out}", flush=True)
    obs.finish_trace(rec)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
