"""Scenario specifications for the failure-drill simulator (DESIGN.md §7).

A :class:`ScenarioSpec` is a complete, serialisable description of one
failure drill: how many voters, which adversary model at what fraction,
what fraction of stragglers, an elastic schedule of voter-set rescales,
which VoteEngine wire strategy, and the tie-break policy the caller
expects. Specs are frozen dataclasses (hashable, usable as jit static
args) and round-trip through plain dicts / JSON, so an entire sweep —
the paper's Fig. 4 grid included — lives in one config file
(``benchmarks/configs/fig4_grid.json``).

Determinism: every PRNG draw a scenario makes (gradient noise, random /
blind / colluding adversaries) is keyed by ``(seed + salt(name), step,
replica index)`` — never by device placement — so a scenario replays
bit-identically on 1 host or 64 (:func:`scenario_salt`; asserted by the
tier-2 golden-trace tests).
"""
from __future__ import annotations

import dataclasses
import json
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ByzantineConfig, VoteStrategy
from repro.core import byzantine

#: tie policies a spec may request; "auto" takes the wire format's own
#: convention (DESIGN.md §5: integer-count wires -> "zero", 1-bit wires
#: -> "plus_one")
TIE_POLICIES = ("auto", "zero", "plus_one")


def scenario_salt(name: str) -> int:
    """Stable 31-bit hash of a scenario id, folded into every PRNG key the
    scenario derives (adversary draws and gradient noise), so two
    scenarios in one sweep never share an adversary stream. 31 bits so the
    salt is a valid int32 for ``jax.random.fold_in`` on every version."""
    return zlib.crc32(name.encode("utf-8")) & 0x7FFFFFFF


@dataclasses.dataclass(frozen=True)
class AdversarySpec:
    """Which adversary model, at what fraction of the current voter set.

    The §15 attack axes: ``mode`` may also be one of the adaptive
    ``repro.core.attacks`` modes, in which case ``observe`` MUST name
    the mode's observation channel (``attacks.MODE_CHANNEL``) — the
    spec states explicitly what the adversary is allowed to see, and a
    dangling or mismatched channel is a build error, not a silent
    no-op. ``schedule`` is the time-varying coalition
    (:class:`~repro.core.attacks.AttackPhase` overrides, applied at
    their steps); all adaptive modes a schedule can reach must share
    one channel. ``target_fraction`` (low_margin) and ``strike_below``
    (reputation) are the adaptive modes' own knobs."""

    mode: str = "none"        # byzantine.MODES | attacks.ATTACK_MODES
    fraction: float = 0.0     # of the CURRENT voter count (elastic-aware)
    flip_prob: float = 0.5    # blind mode only
    observe: str = "none"     # attacks.OBSERVE_CHANNELS
    schedule: Tuple[Any, ...] = ()         # attacks.AttackPhase overrides
    target_fraction: float = 0.25          # low_margin mode only
    strike_below: float = 0.1              # reputation mode only

    def __post_init__(self):
        from repro.core import attacks
        if (self.mode not in byzantine.MODES
                and self.mode not in attacks.ATTACK_MODES):
            raise ValueError(f"unknown adversary mode {self.mode!r}; "
                             f"have {byzantine.MODES} plus adaptive "
                             f"{attacks.ATTACK_MODES}")
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError(f"adversary fraction {self.fraction} not in "
                             "[0, 1]")
        if not 0.0 <= self.flip_prob <= 1.0:
            raise ValueError(f"flip_prob {self.flip_prob} not in [0, 1]")
        if not 0.0 < self.target_fraction <= 1.0:
            raise ValueError(f"target_fraction {self.target_fraction} "
                             "not in (0, 1]")
        if not 0.0 <= self.strike_below <= 1.0:
            raise ValueError(f"strike_below {self.strike_below} not in "
                             "[0, 1]")
        if self.observe not in attacks.OBSERVE_CHANNELS:
            raise ValueError(f"unknown observation channel "
                             f"{self.observe!r}; have "
                             f"{attacks.OBSERVE_CHANNELS}")
        attacks.validate_schedule(self.schedule)
        need = attacks.required_channel(
            attacks.modes_used(self.schedule, self.mode))
        if need == "none" and self.observe != "none":
            raise ValueError(
                f"observe={self.observe!r} grants an observation "
                "channel but no adaptive mode consumes it (mode/"
                "schedule are all oblivious) — drop observe or use an "
                f"adaptive mode {attacks.ATTACK_MODES}")
        if need != "none" and self.observe != need:
            raise ValueError(
                f"adaptive mode(s) here consume the {need!r} channel; "
                f"the spec says observe={self.observe!r} — state the "
                "channel the adversary actually sees (observe="
                f"{need!r})")

    @property
    def adaptive(self) -> bool:
        return self.observe != "none"

    def phase_at(self, step: int) -> Tuple[str, float]:
        """The (mode, fraction) in force at `step` under the schedule."""
        from repro.core import attacks
        return attacks.phase_at(self.schedule, self.mode, self.fraction,
                                step)

    def byz_config(self, n_workers: int, seed: int) -> ByzantineConfig:
        """The core-layer config for a concrete voter count (the count is
        re-derived after every elastic event), ignoring the schedule —
        the pre-run coalition."""
        return self.byz_config_at(0, n_workers, seed)

    def byz_config_at(self, step: int, n_workers: int,
                      seed: int) -> ByzantineConfig:
        """The config in force at `step`: schedule resolution, then the
        exact-``Fraction`` coalition count, through the sanctioned
        ``repro.core.attacks`` factory."""
        from repro.core import attacks
        mode, fraction = self.phase_at(step)
        return attacks.coalition_config(
            mode, fraction, n_workers, seed=seed,
            flip_prob=self.flip_prob,
            target_fraction=self.target_fraction,
            strike_below=self.strike_below)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "AdversarySpec":
        from repro.core.attacks import AttackPhase
        d = dict(d)
        d["schedule"] = tuple(
            p if isinstance(p, AttackPhase) else AttackPhase(**p)
            for p in d.get("schedule", ()))
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """The scenario's VotePlan axis (DESIGN.md §9).

    ``bucket_bytes = 0`` (the default) keeps the legacy single-shot wire
    — the whole gradient voted in one pack/exchange/tally/unpack round.
    ``bucket_bytes > 0`` builds a :class:`~repro.core.vote_plan.VotePlan`
    over the drill's flat buffer and BOTH backends (mesh and virtual)
    walk the same bucket schedule, so plan digests stay backend- and
    host-count-invariant like everything else in the lab.

    `leaves` names segments of the flat buffer (``(("embed", 48),
    ("body", 208))``; lengths must sum to ``dim``; empty = one segment
    ``"x"`` of the whole dim) purely so `codec_map` has names to glob
    against — e.g. ternary embeddings + sign1bit body. Worker-state
    codecs (``ef_sign``) cannot appear in the map (the drill keeps its
    EF residual whole-buffer at the spec level); they remain valid as
    the spec-level ``codec``.
    """

    bucket_bytes: int = 0
    codec_map: Tuple[Tuple[str, str], ...] = ()
    leaves: Tuple[Tuple[str, int], ...] = ()
    overlap: bool = False   # double-buffered bucket walk (DESIGN.md §11)

    def __post_init__(self):
        from repro.core.vote_plan import AUTO_BUCKET_BYTES
        if self.bucket_bytes < 0 and self.bucket_bytes != AUTO_BUCKET_BYTES:
            raise ValueError(f"bucket_bytes {self.bucket_bytes} < 0 "
                             "(use -1 for the priced AUTO ladder)")
        if (self.codec_map or self.leaves) and not self.enabled:
            raise ValueError("codec_map/leaves need bucket_bytes > 0 "
                             "(or the -1 AUTO ladder)")
        if self.overlap and not self.enabled:
            raise ValueError("overlap=True double-buffers the bucket "
                             "schedule; it needs bucket_bytes != 0")

    @property
    def enabled(self) -> bool:
        return self.bucket_bytes != 0

    def leaf_shapes(self, dim: int) -> Dict[str, Tuple[int, ...]]:
        leaves = self.leaves or (("x", dim),)
        return {name: (int(length),) for name, length in leaves}


@dataclasses.dataclass(frozen=True)
class ElasticEvent:
    """At `step`, rescale the voter set to `n_workers` (shrink = node
    deaths, grow = nodes joining). Per-worker momentum is refit by the
    checkpoint rule (truncate / zero-pad, §6): joiners start with zero
    momentum and an all-zero stale vector — an abstention on the
    integer-count wire, +1 votes on the 1-bit wires (which cannot encode
    "abstain"; DESIGN.md §5)."""

    step: int
    n_workers: int
    note: str = ""

    def __post_init__(self):
        if self.step < 0 or self.n_workers < 1:
            raise ValueError(f"bad elastic event {self}")


@dataclasses.dataclass(frozen=True)
class ChurnEvent:
    """At `step`, `join` new clients enter the logical population and
    `leave` existing ones exit (DESIGN.md §12). The generalization of
    :class:`ElasticEvent` to federated populations: events are *deltas*
    on the client count, joiners take fresh logical ids at the top of
    the id range (their dataset sizes and PRNG streams follow the id,
    so a client that exists in two runs behaves identically), leavers
    drop from the top — per-client server state (the weighted vote's
    flip-rate EMA) refits by the checkpoint rule (truncate / zero-pad,
    §6), exactly like an elastic rescale."""

    step: int
    join: int = 0
    leave: int = 0
    note: str = ""

    def __post_init__(self):
        if self.step < 1 or self.join < 0 or self.leave < 0:
            raise ValueError(f"bad churn event {self} (step >= 1; "
                             "pre-run churn is just a different "
                             "n_clients)")
        if self.join == 0 and self.leave == 0:
            raise ValueError(f"churn event at step {self.step} neither "
                             "joins nor leaves anyone")


@dataclasses.dataclass(frozen=True)
class PopulationSpec:
    """The scenario's federated-population axis (DESIGN.md §12).

    ``n_clients = 0`` (the default) keeps the classic dense drill —
    every voter materialized as a row of one stacked tensor.
    ``n_clients > 0`` switches the runner to the streamed population
    engine: the logical population holds `n_clients` voters (far more
    than any host stacks densely), each round samples
    ``sample_fraction`` of them (PRNG keyed by (scenario salt, step) —
    host-count-invariant replay), and the vote streams through
    :func:`repro.core.population.streamed_vote` in voter-chunks of
    ``chunk_size`` rows, so peak sign-buffer memory is O(chunk x dim)
    however large the population.

    ``weighting="dataset"`` gives every client an integer dataset size
    drawn once per *logical id* (uniform on [min_data, max_data]; PRNG
    follows the id, not the round) and counts its vote with that
    multiplicity — the federated dataset-weighted majority. ``churn``
    is the population's join/leave schedule (:class:`ChurnEvent`)."""

    n_clients: int = 0
    sample_fraction: float = 1.0
    churn: Tuple[ChurnEvent, ...] = ()
    weighting: str = "uniform"          # "uniform" | "dataset"
    min_data: int = 1
    max_data: int = 64
    chunk_size: int = 2048

    def __post_init__(self):
        if self.n_clients < 0:
            raise ValueError(f"n_clients {self.n_clients} < 0")
        if not self.enabled and (self.churn or self.sample_fraction != 1.0
                                 or self.weighting != "uniform"):
            raise ValueError("population axes (sampling/churn/weighting) "
                             "need n_clients > 0")
        if not 0.0 < self.sample_fraction <= 1.0:
            raise ValueError(f"sample_fraction {self.sample_fraction} "
                             "not in (0, 1]")
        if self.weighting not in ("uniform", "dataset"):
            raise ValueError(f"weighting {self.weighting!r} not in "
                             "('uniform', 'dataset')")
        if not 1 <= self.min_data <= self.max_data:
            raise ValueError(f"need 1 <= min_data <= max_data, got "
                             f"[{self.min_data}, {self.max_data}]")
        if self.chunk_size < 1:
            raise ValueError(f"chunk_size {self.chunk_size} < 1")
        steps = [e.step for e in self.churn]
        if steps != sorted(steps) or len(set(steps)) != len(steps):
            raise ValueError("churn events must be strictly step-sorted")
        n = self.n_clients
        for ev in self.churn:
            n += ev.join - ev.leave
            if self.enabled and n < 1:
                raise ValueError(
                    f"churn at step {ev.step} empties the population "
                    f"({n} clients left); it must stay >= 1")

    @property
    def enabled(self) -> bool:
        return self.n_clients > 0

    def clients_at(self, step: int) -> int:
        """Logical population size in effect at `step`."""
        n = self.n_clients
        for ev in self.churn:
            if ev.step <= step:
                n += ev.join - ev.leave
        return n


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One deterministic failure drill through the production vote path."""

    name: str
    n_workers: int = 8
    n_steps: int = 20
    dim: int = 256                      # toy-quadratic dimensionality
    strategy: VoteStrategy = VoteStrategy.PSUM_INT8
    adversary: AdversarySpec = AdversarySpec()
    straggler_fraction: float = 0.0     # stale-vote substitution fraction
    elastic: Tuple[ElasticEvent, ...] = ()
    tie_break: str = "auto"             # TIE_POLICIES
    seed: int = 0
    noise_scale: float = 1.0            # grad noise sigma (0 = deterministic)
    learning_rate: float = 0.05
    momentum: float = 0.9               # per-worker (Mode A) beta; 0 = signSGD
    codec: str = "sign1bit"             # gradient codec (DESIGN.md §8)
    plan: PlanSpec = PlanSpec()         # bucketed wire schedule (§9)
    delayed_vote: bool = False          # apply step t's vote at t+1 (§11)
    population: PopulationSpec = PopulationSpec()   # federated axis (§12)

    def __post_init__(self):
        if self.strategy == VoteStrategy.AUTO:
            raise ValueError("scenarios pin a concrete wire strategy; "
                             "AUTO is a trainer-side selector")
        if self.tie_break not in TIE_POLICIES:
            raise ValueError(f"tie_break {self.tie_break!r} not in "
                             f"{TIE_POLICIES}")
        from repro.core import codecs as codecs_mod
        c = codecs_mod.get_codec(self.codec)   # raises on unknown codec
        c.validate_strategy(self.strategy)
        # tie_break must be realisable by EVERY codec actually on the
        # wire — under a plan codec_map that is the mapped set, not just
        # the spec-level codec
        if self.tie_break != "auto":
            for name in self.wire_codecs():
                ties = codecs_mod.get_codec(name).ties(self.strategy)
                if self.tie_break != ties:
                    raise ValueError(
                        f"codec {name!r} over {self.strategy.value} "
                        f"resolves ties to {ties!r}; a "
                        f"{self.tie_break!r} tie policy would need a "
                        "different wire format (DESIGN.md §5/§8/§9)")
        if not 0.0 <= self.straggler_fraction <= 1.0:
            raise ValueError("straggler_fraction not in [0, 1]")
        if self.n_workers < 1 or self.n_steps < 1 or self.dim < 1:
            raise ValueError(f"bad scenario sizes in {self.name!r}")
        if self.plan.enabled:
            shapes = self.plan.leaf_shapes(self.dim)
            if len(shapes) != len(self.plan.leaves or ("x",)):
                raise ValueError(
                    f"duplicate plan leaf names in {self.name!r}")
            if sum(s[0] for s in shapes.values()) != self.dim or \
                    any(s[0] < 1 for s in shapes.values()):
                raise ValueError(
                    f"plan leaves of {self.name!r} must be positive and "
                    f"sum to dim={self.dim}")
            for _, codec_name in self.plan.codec_map:
                mc = codecs_mod.get_codec(codec_name)
                mc.validate_strategy(self.strategy)
                if mc.worker_state:
                    raise ValueError(
                        f"codec {codec_name!r} carries per-worker state "
                        "and cannot appear in a scenario codec_map (use "
                        "the spec-level codec field; the drill's EF "
                        "residual is whole-buffer)")
        steps = [e.step for e in self.elastic]
        if steps != sorted(steps) or len(set(steps)) != len(steps):
            raise ValueError("elastic events must be strictly step-sorted")
        if self.population.enabled:
            # the federated axis runs the streamed population engine
            # (core.population) — every incompatible knob is rejected
            # here, with the reason, instead of failing deep in the run
            if self.strategy == VoteStrategy.HIERARCHICAL:
                raise ValueError(
                    f"{self.name!r}: hierarchical's reduce-scatter wire "
                    "pads to PACK*M words — an O(M) layout the streamed "
                    "population engine exists to avoid; use psum_int8 "
                    "or allgather_1bit")
            if self.plan.enabled:
                raise ValueError(
                    f"{self.name!r}: the plan axis bucketizes a dense "
                    "stacked buffer; population mode streams the flat "
                    "buffer whole (set bucket_bytes=0)")
            if self.elastic:
                raise ValueError(
                    f"{self.name!r}: population mode replaces elastic "
                    "events with ChurnEvent deltas "
                    "(PopulationSpec.churn)")
            if self.momentum > 0:
                raise ValueError(
                    f"{self.name!r}: per-client momentum is O(population "
                    "x dim) state the streamed engine exists to avoid; "
                    "population drills run momentum=0 (pure signSGD)")
            if self.straggler_fraction > 0:
                raise ValueError(
                    f"{self.name!r}: stale-vote substitution needs an "
                    "O(population x dim) prev-signs buffer; in federated "
                    "mode partial participation IS the straggler model "
                    "(sample_fraction < 1)")
            if c.worker_state:
                raise ValueError(
                    f"{self.name!r}: codec {self.codec!r} keeps an "
                    "O(population x dim) per-client residual; population "
                    "drills need a worker-stateless codec")

    # ---- derived ----

    @property
    def salt(self) -> int:
        return scenario_salt(self.name)

    def wire_codecs(self) -> Tuple[str, ...]:
        """The codecs actually on the wire, resolved per leaf when a
        plan codec_map is set (sorted, deduplicated); just the
        spec-level codec otherwise."""
        if not (self.plan.enabled and self.plan.codec_map):
            return (self.codec,)
        from repro.core.vote_plan import resolve_codec_map
        per_leaf = resolve_codec_map(
            sorted(self.plan.leaf_shapes(self.dim)),
            self.plan.codec_map, self.codec)
        return tuple(sorted(set(per_leaf.values())))

    @property
    def tie_policy(self) -> str:
        """The resolved tie convention ("zero" or "plus_one") — the
        codec's, which may override the wire strategy's (§8). A plan
        whose codec map mixes conventions reports "mixed": per-bucket
        codecs deliver per-segment tie semantics on one wire (§9)."""
        from repro.core import codecs as codecs_mod
        ties = {codecs_mod.get_codec(n).ties(self.strategy)
                for n in self.wire_codecs()}
        return ties.pop() if len(ties) == 1 else "mixed"

    def workers_at(self, step: int) -> int:
        """Voter count in effect at `step` under the elastic schedule."""
        n = self.n_workers
        for ev in self.elastic:
            if ev.step <= step:
                n = ev.n_workers
        return n

    def runtime_plan(self, data_size: int):
        """The concrete :class:`~repro.core.vote_plan.VotePlan` for a
        voter-set size (rebuilt at elastic boundaries: only the
        hierarchical wire's bucket alignment depends on it), or None
        when the plan axis is disabled."""
        if not self.plan.enabled:
            return None
        from repro.core import vote_plan as vp
        return vp.build_plan(self.plan.leaf_shapes(self.dim),
                             bucket_bytes=self.plan.bucket_bytes,
                             codec_map=self.plan.codec_map,
                             default_codec=self.codec,
                             strategy=self.strategy,
                             data_size=data_size,
                             overlap=self.plan.overlap)

    # ---- (de)serialisation ----

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["strategy"] = self.strategy.value
        d["elastic"] = [dataclasses.asdict(e) for e in self.elastic]
        d["population"] = {
            **dataclasses.asdict(self.population),
            "churn": [dataclasses.asdict(e)
                      for e in self.population.churn]}
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ScenarioSpec":
        d = dict(d)
        if "strategy" in d:
            d["strategy"] = VoteStrategy(d["strategy"])
        if "adversary" in d and isinstance(d["adversary"], dict):
            d["adversary"] = AdversarySpec.from_dict(d["adversary"])
        if "elastic" in d:
            d["elastic"] = tuple(
                e if isinstance(e, ElasticEvent) else ElasticEvent(**e)
                for e in d["elastic"])
        if "plan" in d and isinstance(d["plan"], dict):
            p = dict(d["plan"])
            # JSON turns the nested tuples into lists; re-freeze them
            p["codec_map"] = tuple(
                (str(g), str(c)) for g, c in p.get("codec_map", ()))
            p["leaves"] = tuple(
                (str(n), int(ln)) for n, ln in p.get("leaves", ()))
            d["plan"] = PlanSpec(**p)
        if "population" in d and isinstance(d["population"], dict):
            p = dict(d["population"])
            p["churn"] = tuple(
                e if isinstance(e, ChurnEvent) else ChurnEvent(**e)
                for e in p.get("churn", ()))
            d["population"] = PopulationSpec(**p)
        return cls(**d)


def load_scenarios(path: str) -> List[ScenarioSpec]:
    """Scenarios from a JSON config file.

    Accepts either a bare list of spec dicts or ``{"defaults": {...},
    "scenarios": [...]}`` where each scenario overlays the defaults, plus
    an optional ``"grid"`` block expanded by :func:`expand_grid`."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, list):
        specs = [ScenarioSpec.from_dict(d) for d in doc]
    else:
        defaults = doc.get("defaults", {})
        specs = [ScenarioSpec.from_dict({**defaults, **d})
                 for d in doc.get("scenarios", [])]
        if "grid" in doc:
            specs.extend(expand_grid(doc["grid"], defaults))
    names = [s.name for s in specs]
    dupes = sorted({n for n in names if names.count(n) > 1})
    if dupes:
        # duplicate names would alias PRNG streams (crc32(name) salt) and
        # benchmark row keys — a config error, never a silent re-run
        raise ValueError(f"duplicate scenario names in {path}: {dupes}")
    return specs


def expand_grid(grid: Dict[str, Any],
                defaults: Optional[Dict[str, Any]] = None
                ) -> List[ScenarioSpec]:
    """Cross-product expansion of a Fig.-4-style sweep block:

    ``{"fractions": [...], "modes": [...], "strategies": [...],
    "base": {...}}`` -> one scenario per (fraction, mode, strategy) cell,
    named ``<prefix>/<mode>/<strategy>/f<pct>``. An optional ``"codecs"``
    list adds a codec axis (§8); its cells are named
    ``<prefix>/<codec>/<mode>/<strategy>/f<pct>`` so the codec-less grid
    keeps its historical names (and PRNG salts). An optional
    ``"delayed"`` list of booleans adds the delayed-vote axis (§11):
    true cells insert a ``delayed`` name segment after the codec; false
    cells keep the historical names, so adding the axis to an existing
    grid never perturbs its PRNG streams.
    """
    base = {**(defaults or {}), **grid.get("base", {})}
    prefix = grid.get("prefix", "grid")
    codecs_axis = grid.get("codecs")
    delayed_axis = grid.get("delayed")
    out, seen = [], set()
    for codec in (codecs_axis or [None]):
      for delayed in (delayed_axis if delayed_axis is not None else [None]):
        for mode in grid["modes"]:
            for strategy in grid["strategies"]:
                for frac in grid["fractions"]:
                    # fraction 0 is the same honest configuration whatever
                    # the mode, so it collapses to ONE anchor cell per
                    # (codec, strategy) — every mode's curve shares its
                    # origin (same name -> same PRNG salt -> same baseline
                    # trace). %g keeps distinct nonzero fractions distinct
                    # (a rounded-percent name would collide sub-percent
                    # cells and alias their PRNG streams).
                    eff_mode = mode if frac > 0 else "none"
                    cell = f"{eff_mode}/{strategy}/f{frac:g}"
                    parts = [prefix]
                    if codec:
                        parts.append(codec)
                    if delayed:
                        parts.append("delayed")
                    name = "/".join(parts + [cell])
                    if name in seen:
                        continue
                    seen.add(name)
                    adv = {"mode": eff_mode, "fraction": frac,
                           **grid.get("adversary_extra", {})}
                    from repro.core import attacks
                    if eff_mode in attacks.MODE_CHANNEL:
                        # adaptive cells state their channel explicitly
                        # (AdversarySpec validation demands it)
                        adv.setdefault("observe",
                                       attacks.MODE_CHANNEL[eff_mode])
                    elif frac == 0:
                        # the honest anchor cell: adaptive-only knobs
                        # from adversary_extra would dangle
                        adv.pop("observe", None)
                        adv.pop("schedule", None)
                    doc = {
                        **base,
                        "name": name,
                        "strategy": strategy,
                        "adversary": adv,
                    }
                    if codec:
                        doc["codec"] = codec
                    if delayed is not None:
                        doc["delayed_vote"] = bool(delayed)
                    out.append(ScenarioSpec.from_dict(doc))
    return out


# ---------------------------------------------------------------------------
# preset library — the boundary regimes the follow-up papers study
# ---------------------------------------------------------------------------


def preset_scenarios() -> List[ScenarioSpec]:
    """Named drills covering the interesting boundary regimes: the paper's
    <50% guarantee, the exact-50% tie, >50% blind adversaries (vote
    rightly fails), colluding coalitions, straggler x adversary
    composition, a mid-run shrink/regrow, and the §15 adaptive
    attackers (margin-targeting, and a sleeper coalition waking into
    the defense-aware reputation mode against the weighted vote)."""
    from repro.core.attacks import AttackPhase
    S = VoteStrategy
    return [
        ScenarioSpec("honest/baseline", n_workers=15, strategy=S.PSUM_INT8),
        ScenarioSpec("adv/sign_flip_25", n_workers=16,
                     strategy=S.ALLGATHER_1BIT,
                     adversary=AdversarySpec("sign_flip", 0.25)),
        ScenarioSpec("adv/tie_at_half", n_workers=16, strategy=S.PSUM_INT8,
                     noise_scale=0.0,
                     adversary=AdversarySpec("sign_flip", 0.5)),
        ScenarioSpec("adv/blind_majority", n_workers=15,
                     strategy=S.HIERARCHICAL,
                     adversary=AdversarySpec("blind", 0.6, flip_prob=0.9)),
        ScenarioSpec("adv/colluding_40", n_workers=15, strategy=S.PSUM_INT8,
                     adversary=AdversarySpec("colluding", 0.4)),
        ScenarioSpec("straggle/stale_adversary", n_workers=16,
                     strategy=S.ALLGATHER_1BIT, straggler_fraction=0.25,
                     adversary=AdversarySpec("sign_flip", 0.25)),
        ScenarioSpec("elastic/shrink_regrow", n_workers=8,
                     strategy=S.PSUM_INT8, n_steps=30,
                     adversary=AdversarySpec("random", 0.25),
                     elastic=(ElasticEvent(10, 4, "pod failure"),
                              ElasticEvent(20, 6, "partial rejoin"))),
        ScenarioSpec("adv/adaptive_low_margin", n_workers=15,
                     strategy=S.ALLGATHER_1BIT,
                     adversary=AdversarySpec("low_margin", 0.375,
                                             observe="margin")),
        ScenarioSpec("adv/sleeper_reputation", n_workers=15,
                     strategy=S.ALLGATHER_1BIT, codec="weighted_vote",
                     adversary=AdversarySpec(
                         "none", 0.0, observe="reputation",
                         schedule=(AttackPhase(step=5, mode="reputation",
                                               fraction=1 / 3),))),
    ]


def fig4_grid(n_workers: int = 16, n_steps: int = 25, dim: int = 512,
              fractions: Sequence[float] = (0.0, 0.125, 0.25, 0.375, 0.5),
              modes: Sequence[str] = ("sign_flip", "random", "zero",
                                      "colluding"),
              strategies: Sequence[str] = ("psum_int8", "allgather_1bit",
                                           "hierarchical"),
              ) -> List[ScenarioSpec]:
    """The paper's Fig. 4 robustness sweep as scenarios: adversary fraction
    0 -> 0.5 x adversary mode x wire strategy (DESIGN.md §7)."""
    return expand_grid({
        "prefix": "fig4",
        "fractions": list(fractions),
        "modes": list(modes),
        "strategies": list(strategies),
        "base": {"n_workers": n_workers, "n_steps": n_steps, "dim": dim},
    })
