#!/usr/bin/env bash
# Tier-1 CI lane: the full test suite plus the communication benchmark's
# smoke pass (VoteEngine wire accounting + fused-kernel-vs-oracle checks).
#
#   scripts/ci.sh          # everything
#   scripts/ci.sh --quick  # skip tests marked slow (the distributed
#                          # subprocess harness is the long pole)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=()
if [[ "${1:-}" == "--quick" ]]; then
  MARK=(-m "not slow")
fi

echo "== tier-1 tests =="
python -m pytest -x -q "${MARK[@]}"

echo "== bench_comm smoke =="
python -m benchmarks.bench_comm --smoke

echo "CI OK"
